"""Hypothesis property tests for deeper system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (NetworkParams, delay_jacobian,
                        expected_relative_delay, throughput)
from repro.core.buzen import log_normalizing_constants


def params_from(seed, n, with_cs=False):
    rng = np.random.default_rng(seed)
    p = rng.dirichlet(np.ones(n) * 2.0)
    params = NetworkParams(
        p=jnp.asarray(p),
        mu_c=jnp.asarray(rng.uniform(0.2, 6.0, n)),
        mu_d=jnp.asarray(rng.uniform(0.2, 6.0, n)),
        mu_u=jnp.asarray(rng.uniform(0.2, 6.0, n)))
    return params.with_cs(rng.uniform(0.5, 6.0)) if with_cs else params


@pytest.mark.slow  # ~45 s: 15 Jacobian examples, each a fresh jit trace
@settings(max_examples=15, deadline=None)
@given(st.integers(2, 5), st.integers(2, 8), st.integers(0, 10_000),
       st.booleans())
def test_jacobian_columns_sum_to_zero(n, m, seed, with_cs):
    """d/dp_j sum_i E0[D_i] = d/dp_j (m-1) = 0: every column of the delay
    Jacobian sums to zero (conservation of total staleness, Eq. 7)."""
    params = params_from(seed, n, with_cs)
    J = np.asarray(delay_jacobian(params, m))
    np.testing.assert_allclose(J.sum(axis=0), 0.0, atol=1e-7)


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 5), st.integers(1, 10), st.integers(0, 10_000))
def test_throughput_monotone_in_m(n, m, seed):
    """Closed-network throughput is non-decreasing in the population size."""
    params = params_from(seed, n)
    lam1 = float(throughput(params, m))
    lam2 = float(throughput(params, m + 1))
    assert lam2 >= lam1 - 1e-10


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 4), st.integers(2, 6), st.integers(0, 10_000))
def test_throughput_monotone_in_service_rates(n, m, seed):
    """Uniformly faster servers can only increase throughput."""
    params = params_from(seed, n)
    faster = NetworkParams(p=params.p, mu_c=params.mu_c * 1.5,
                           mu_d=params.mu_d * 1.5, mu_u=params.mu_u * 1.5)
    assert float(throughput(faster, m)) >= float(throughput(params, m)) - 1e-10


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 4), st.integers(2, 6), st.integers(0, 10_000))
def test_throughput_scaling_law(n, m, seed):
    """Speeding every server by c scales lambda by exactly c (time rescale)."""
    params = params_from(seed, n)
    c = 2.7
    scaled = NetworkParams(p=params.p, mu_c=params.mu_c * c,
                           mu_d=params.mu_d * c, mu_u=params.mu_u * c)
    np.testing.assert_allclose(float(throughput(scaled, m)),
                               c * float(throughput(params, m)), rtol=1e-9)


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 4), st.integers(2, 6), st.integers(0, 10_000))
def test_delays_invariant_under_time_rescale(n, m, seed):
    """Relative delay counts updates, not seconds: invariant to c * mu."""
    params = params_from(seed, n)
    c = 3.3
    scaled = NetworkParams(p=params.p, mu_c=params.mu_c * c,
                           mu_d=params.mu_d * c, mu_u=params.mu_u * c)
    np.testing.assert_allclose(np.asarray(expected_relative_delay(scaled, m)),
                               np.asarray(expected_relative_delay(params, m)),
                               rtol=1e-9)


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 4), st.integers(2, 7), st.integers(0, 10_000))
def test_Z_log_concavity_ratios(n, m, seed):
    """Z_{m+1} Z_{m-1} <= Z_m^2 (log-concavity of normalizing constants —
    equivalent to lambda(m) = Z_{m-1}/Z_m being non-decreasing in m)."""
    params = params_from(seed, n)
    logZ = np.asarray(log_normalizing_constants(params, m + 1))
    for k in range(1, m + 1):
        assert logZ[k + 1] + logZ[k - 1] <= 2 * logZ[k] + 1e-9


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000))
def test_symmetry_uniform_clients(seed):
    """Identical clients + uniform routing => identical delays = (m-1)/n."""
    rng = np.random.default_rng(seed)
    n, m = 4, 7
    mu = rng.uniform(0.3, 5.0, 3)
    params = NetworkParams(p=jnp.full((n,), 1 / n),
                           mu_c=jnp.full((n,), mu[0]),
                           mu_d=jnp.full((n,), mu[1]),
                           mu_u=jnp.full((n,), mu[2]))
    d = np.asarray(expected_relative_delay(params, m))
    np.testing.assert_allclose(d, (m - 1) / n, rtol=1e-9)
