"""Sharded-execution integration tests (8 fake CPU devices, subprocess).

The device count must be set before jax initializes, so these tests run in
a child interpreter.  They verify:
  * the EP all-to-all MoE path == the collective-free ragged path;
  * a sharded train step on a (2, 4) data x model mesh runs and matches the
    unsharded step numerically;
  * the dry-run driver itself succeeds end-to-end for a reduced config.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

# 8-fake-device sharded execution in a child interpreter: slow compiles
pytestmark = pytest.mark.slow


def run_py(code: str, timeout=560) -> str:
    env = dict(os.environ, PYTHONPATH=SRC,
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


@pytest.mark.parametrize("n_experts", [4, 6])  # 6: padded EP (6 -> 8 on ep=4)
def test_moe_ep_matches_ragged(n_experts):
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.models.moe import init_moe, moe_ffn, _moe_ragged
        from repro.models.parallel import ParallelContext
        import dataclasses

        cfg = get_config("qwen2-moe-a2.7b").reduced()
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, num_experts=N_EXPERTS,
                                         top_k=2, capacity_factor=8.0))""".replace(
        "N_EXPERTS", str(n_experts)) + """
        from repro.compat import make_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        ctx = ParallelContext(mesh=mesh)
        params = init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model),
                              jnp.float32) * 0.3

        y_ep, aux_ep = jax.jit(lambda p, x: moe_ffn(p, x, cfg, ctx))(params, x)
        y_rg, aux_rg = _moe_ragged(
            {"router": params["router"], "experts": params["experts"]}, x, cfg)
        if cfg.moe.num_shared:
            from repro.models.layers import dense_ffn
            gate = jax.nn.sigmoid(x.astype(jnp.float32) @ params["shared_gate"])
            y_rg = y_rg + dense_ffn(params["shared"], x,
                                    ParallelContext()) * gate.astype(x.dtype)
        err = float(jnp.max(jnp.abs(y_ep - y_rg)))
        print("MAXERR", err)
        assert err < 2e-4, err
    """)
    assert "MAXERR" in out


def test_sharded_train_step_matches_unsharded():
    run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.models import build_model
        from repro.models.parallel import ParallelContext

        cfg = get_config("internlm2-1.8b").reduced()
        from repro.compat import make_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        b0 = build_model(cfg)
        b1 = build_model(cfg, ParallelContext(mesh=mesh))
        params = b0.init(jax.random.PRNGKey(0))
        batch = {"tokens": jnp.ones((4, 16), jnp.int32),
                 "targets": jnp.ones((4, 16), jnp.int32)}
        l0, _ = b0.loss_fn(params, batch)
        l1, _ = jax.jit(b1.loss_fn)(params, batch)
        print("LOSSES", float(l0), float(l1))
        assert abs(float(l0) - float(l1)) < 1e-4
        opt = b1.optimizer.init(params)
        p2, opt, m = jax.jit(b1.train_step)(params, opt, batch)
        assert np.isfinite(float(m["loss"]))
    """)


def test_moe_sharded_train_step_runs():
    run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.models import build_model
        from repro.models.parallel import ParallelContext

        cfg = get_config("qwen2-moe-a2.7b").reduced()
        from repro.compat import make_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        bundle = build_model(cfg, ParallelContext(mesh=mesh))
        params = bundle.init(jax.random.PRNGKey(0))
        batch = {"tokens": jnp.ones((4, 16), jnp.int32),
                 "targets": jnp.ones((4, 16), jnp.int32)}
        opt = bundle.optimizer.init(params)
        p2, opt, m = jax.jit(bundle.train_step)(params, opt, batch)
        print("LOSS", float(m["loss"]))
        assert np.isfinite(float(m["loss"]))
    """)


def test_jamba_sharded_decode_runs():
    run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.models import build_model
        from repro.models.parallel import ParallelContext

        cfg = get_config("jamba-v0.1-52b").reduced()
        from repro.compat import make_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        bundle = build_model(cfg, ParallelContext(mesh=mesh))
        params = bundle.init(jax.random.PRNGKey(0))
        cache = bundle.init_cache(4, 32)
        logits, cache = jax.jit(bundle.decode_step)(
            params, cache, jnp.ones((4, 1), jnp.int32), jnp.int32(0))
        assert np.isfinite(np.asarray(logits)).all()
        print("OK", logits.shape)
    """)
