"""The serve subsystem: wire protocol, micro-batching, bitwise equality.

The server's contract is that it is a *transport*, not a different
engine: every response payload must be bitwise-equal to the
``encode_entry`` of a direct ``ScenarioSuite.run`` on the same scenario
and seeds — coalescing concurrent requests into spare lanes must never
change a bit.  The error contract is that every failure is a structured
``error`` event and the server keeps serving afterwards (no resident
program is poisoned by a bad request).
"""
import json
import os
import queue
import subprocess
import sys
import tempfile
import threading
import time
import types

import numpy as np
import pytest

from repro.core.complexity import LearningConstants
from repro.scenario import (DataSpec, LearningSpec, NetworkSpec, Scenario,
                            ScenarioSuite, StrategySpec)
from repro.serve.batcher import MicroBatcher
from repro.serve.client import ServeClient, ServeError
from repro.serve.metrics import Histogram, Metrics
from repro.serve.protocol import (MAX_M, WireError, encode_entry,
                                  parse_request)
from repro.serve.server import ServeConfig, Server

CONSTS = LearningConstants(L=1.0, delta=1.0, sigma=1.0, M=2.0, G=5.0,
                           eps=1.0)
DATA = dict(dataset="synthetic", num_classes=2, samples_per_class=6)
MODEL_SPEC = {"kind": "mlp", "input_dim": 28 * 28, "num_classes": 2,
              "hidden": [4]}
TRAIN_OPTS = dict(horizon_time=4.0, batch_size=4, eval_every_time=2.0)


def make_scenario(n, seed=0, m=2, data=True):
    """A small explicit-strategy scenario; ``seed`` varies the rates so
    each test gets distinct response-cache keys."""
    rng = np.random.default_rng(seed)
    return Scenario(
        network=NetworkSpec(mu_c=list(rng.uniform(1.0, 2.0, n)),
                            mu_d=[2.0] * n, mu_u=[2.0] * n),
        learning=LearningSpec(consts=CONSTS),
        strategy=StrategySpec("explicit", p=list(np.full(n, 1.0 / n)), m=m),
        data=DataSpec(**DATA) if data else None)


def direct_payload(scn, mode, seeds=(0,), **options):
    """What the server must produce, computed without the server."""
    if mode == "train":
        from repro.fl.models import mlp_classifier

        options = dict(options)
        spec = options.pop("model")
        options["model"] = mlp_classifier(spec["input_dim"],
                                          spec["num_classes"],
                                          hidden=tuple(spec["hidden"]))
    res = ScenarioSuite(scn, seeds=seeds).run(mode=mode, **options)
    (entry,) = res.entries.values()
    return encode_entry(mode, entry)


def bitwise_equal(a, b) -> bool:
    return json.dumps(a) == json.dumps(b)


# ---------------------------------------------------------------------------
# metrics (unit)
# ---------------------------------------------------------------------------

def test_histogram_percentiles_exact():
    h = Histogram()
    for v in range(1, 101):
        h.observe(float(v))
    assert h.count == 100
    assert h.percentile(0.0) == 1.0
    assert h.percentile(1.0) == 100.0
    assert h.percentile(0.5) == 51.0  # nearest rank of 0.5*(n-1)
    s = h.summary()
    assert s["count"] == 100 and s["mean"] == pytest.approx(50.5)


def test_metrics_labels_and_snapshot():
    m = Metrics()
    m.inc("suite.requests", mode="analyze")
    m.inc("suite.requests", by=2, mode="analyze")
    m.observe("suite.lanes_per_dispatch", 4, mode="simulate")
    with m.timed("suite.dispatch", mode="simulate"):
        pass
    snap = m.snapshot()
    assert snap["counters"]["suite.requests{mode=analyze}"] == 3
    assert snap["latency"]["suite.lanes_per_dispatch{mode=simulate}"][
        "p50"] == 4
    assert m.counter("suite.requests", mode="analyze") == 3


def test_direct_suite_run_reports_metrics():
    """Satellite: direct (serverless) runs surface the same per-bucket
    counters the server exports."""
    suite = ScenarioSuite({"a": make_scenario(2, seed=40),
                           "b": make_scenario(3, seed=41)}, seeds=(0, 1))
    res = suite.run(mode="analyze")
    assert res.metrics is not None
    counters = res.metrics["counters"]
    assert counters["suite.requests{mode=analyze}"] == 2
    lanes = res.metrics["latency"]["suite.lanes_per_dispatch{mode=analyze}"]
    assert lanes["count"] >= 1
    assert "suite.run{mode=analyze}" in res.metrics["latency"]


# ---------------------------------------------------------------------------
# micro-batcher (unit — no jax, no sockets)
# ---------------------------------------------------------------------------

def _fake_req(bucket, seeds=(0,)):
    return types.SimpleNamespace(bucket=bucket, seeds=tuple(seeds))


def test_batcher_window_groups_by_bucket():
    q = queue.Queue()
    b = MicroBatcher(q, lambda r: r.bucket, max_wait=0.05, max_lanes=64)
    for r in (_fake_req("A"), _fake_req("B"), _fake_req("A")):
        q.put(r)
    window = b.next_window(timeout=1.0)
    assert len(window) == 3
    groups = b.group(window)
    assert [(err, [r.bucket for r in g]) for err, g in groups] == [
        (None, ["A", "A"]), (None, ["B"])]


def test_batcher_lane_budget_bounds_window():
    q = queue.Queue()
    b = MicroBatcher(q, lambda r: r.bucket, max_wait=5.0, max_lanes=4)
    for _ in range(4):
        q.put(_fake_req("A", seeds=(0, 1)))
    t0 = time.monotonic()
    window = b.next_window(timeout=1.0)
    # 2 requests x 2 seeds hit the 4-lane budget: no waiting out max_wait
    assert len(window) == 2
    assert time.monotonic() - t0 < 4.0


def test_batcher_key_errors_become_singletons():
    q = queue.Queue()

    def key(r):
        if r.bucket == "boom":
            raise WireError("ProtocolError", "bad bucket")
        return r.bucket

    b = MicroBatcher(q, key, max_wait=0.05, max_lanes=64)
    for r in (_fake_req("A"), _fake_req("boom"), _fake_req("A")):
        q.put(r)
    groups = b.group(b.next_window(timeout=1.0))
    assert len(groups) == 2
    errs = [err for err, _ in groups if err is not None]
    assert len(errs) == 1 and isinstance(errs[0], WireError)


# ---------------------------------------------------------------------------
# protocol validation (unit)
# ---------------------------------------------------------------------------

def _msg(**over):
    base = {"id": "r0", "verb": "run", "mode": "analyze",
            "scenario": make_scenario(2, seed=50).to_dict(),
            "seeds": [0], "options": {}}
    base.update(over)
    return base


def _etype(msg):
    with pytest.raises(WireError) as exc:
        parse_request(msg)
    return exc.value.etype


def test_parse_request_validation():
    assert _etype(_msg(id=None)) == "ProtocolError"
    assert _etype(_msg(mode="explode")) == "ProtocolError"
    assert _etype(_msg(scenario="nope")) == "ProtocolError"
    assert _etype(_msg(seeds=[])) == "ProtocolError"
    assert _etype(_msg(options={"volume": 11})) == "ProtocolError"
    # unknown strategy name surfaces the spec's eager validation error
    bad = make_scenario(2, seed=50).to_dict()
    bad["strategy"]["name"] = "zigzag"
    assert _etype(_msg(scenario=bad)) == "ValueError"
    # oversized m (explicit and requested) is refused at admission
    big = make_scenario(2, seed=50, m=MAX_M + 1).to_dict()
    assert _etype(_msg(scenario=big)) == "ProtocolError"
    sim = _msg(mode="simulate",
               options={"num_updates": 10, "m_max": MAX_M + 1})
    assert _etype(sim) == "ProtocolError"
    # train without a DataSpec cannot build client datasets server-side
    nodata = make_scenario(2, seed=50, data=False).to_dict()
    opts = dict(TRAIN_OPTS, model=MODEL_SPEC)
    assert _etype(_msg(mode="train", scenario=nodata,
                       options=opts)) == "ProtocolError"


# ---------------------------------------------------------------------------
# the live server
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def served(tmp_path_factory):
    sock = str(tmp_path_factory.mktemp("serve") / "repro.sock")
    server = Server(ServeConfig(socket_path=sock, max_wait=0.25,
                                max_lanes=16))
    server.start()
    yield sock, server
    server.stop()


def test_analyze_bitwise_and_response_cache(served):
    sock, server = served
    scn = make_scenario(3, seed=1)
    with ServeClient(sock, timeout=120) as c:
        rid = c.submit(scn, mode="analyze")
        msg = c.collect(rid)
        assert msg["cached"] is False
        assert [e["event"] for e in c.events_for(rid)] == ["accepted",
                                                           "scheduled"]
        assert bitwise_equal(c.unwrap(msg), direct_payload(scn, "analyze"))
        # the repeat is answered at admission: no accepted/scheduled events
        rid2 = c.submit(scn, mode="analyze")
        msg2 = c.collect(rid2)
        assert msg2["cached"] is True
        assert c.events_for(rid2) == []
        assert bitwise_equal(c.unwrap(msg2), c.unwrap(msg))
    assert server.metrics.counter("serve.cache_hits", mode="analyze") >= 1


def test_concurrent_simulate_coalesced_and_bitwise(served):
    sock, _ = served
    scns = [make_scenario(3, seed=2), make_scenario(5, seed=3)]
    opts = dict(num_updates=60)
    with ServeClient(sock, timeout=300) as a, \
            ServeClient(sock, timeout=300) as b:
        # two *connections* submit into the same micro-batch window
        ra = a.submit(scns[0], mode="simulate", seeds=(0, 1), **opts)
        rb = b.submit(scns[1], mode="simulate", seeds=(0, 1), **opts)
        pa = a.unwrap(a.collect(ra))
        pb = b.unwrap(b.collect(rb))
        sched = [e for e in a.events_for(ra) if e["event"] == "scheduled"]
    # mixed populations (n=3, n=5) coalesced into ONE padded dispatch
    assert sched and sched[0]["requests"] == 2 and sched[0]["lanes"] == 4
    assert bitwise_equal(pa, direct_payload(scns[0], "simulate",
                                            seeds=(0, 1), **opts))
    assert bitwise_equal(pb, direct_payload(scns[1], "simulate",
                                            seeds=(0, 1), **opts))


def test_train_mixed_n_coalesced_and_bitwise(served):
    sock, _ = served
    scns = [make_scenario(2, seed=4), make_scenario(3, seed=5)]
    opts = dict(TRAIN_OPTS, model=MODEL_SPEC)
    with ServeClient(sock, timeout=600) as c:
        ids = [c.submit(s, mode="train", seeds=(0,), **opts) for s in scns]
        payloads = [c.unwrap(c.collect(i)) for i in ids]
        sched = [e for e in c.events_for(ids[0])
                 if e["event"] == "scheduled"]
    # the mixed-n train bucket: both populations share one lane program
    assert sched and sched[0]["requests"] == 2
    for scn, payload in zip(scns, payloads):
        assert bitwise_equal(payload,
                             direct_payload(scn, "train", **opts))


def test_errors_are_structured_and_server_keeps_serving(served):
    sock, _ = served
    with ServeClient(sock, timeout=120) as c:
        # malformed JSON
        c.send_raw(b'{"id": "oops", not json\n')
        msg = c.collect(None)  # unparseable line -> id is None
        assert msg["event"] == "error"
        assert msg["error"]["type"] == "ProtocolError"
        # unknown strategy name (spec validation, with the request id)
        bad = make_scenario(2, seed=6).to_dict()
        bad["strategy"]["name"] = "zigzag"
        c.send({"id": "r-bad", "verb": "run", "mode": "analyze",
                "scenario": bad, "seeds": [0], "options": {}})
        msg = c.collect("r-bad")
        assert msg["error"]["type"] == "ValueError"
        # unknown verb
        c.send({"id": "r-verb", "verb": "dance"})
        assert c.collect("r-verb")["error"]["type"] == "ProtocolError"
        # oversized m_max
        c.send({"id": "r-m", "verb": "run", "mode": "simulate",
                "scenario": make_scenario(2, seed=6).to_dict(),
                "seeds": [0],
                "options": {"num_updates": 10, "m_max": MAX_M + 1}})
        assert c.collect("r-m")["error"]["type"] == "ProtocolError"
        # ...and the SAME connection still gets bitwise-correct results
        scn = make_scenario(2, seed=7)
        assert bitwise_equal(c.run(scn, mode="analyze"),
                             direct_payload(scn, "analyze"))


def test_killed_inflight_request_does_not_poison_the_server(served):
    sock, _ = served
    scn = make_scenario(4, seed=8)
    killer = ServeClient(sock, timeout=120)
    killer.submit(scn, mode="simulate", num_updates=60)
    killer.close()  # walk away with the request in flight
    # the dispatch completes into a dead transport; the server, the
    # resident programs and the response cache all stay healthy:
    with ServeClient(sock, timeout=300) as c:
        assert bitwise_equal(
            c.run(scn, mode="simulate", num_updates=60),
            direct_payload(scn, "simulate", num_updates=60))
        assert c.stats()["counters"]


def test_stats_verb_reports_counters_and_latency(served):
    sock, _ = served
    with ServeClient(sock, timeout=120) as c:
        scn = make_scenario(2, seed=9)
        c.run(scn, mode="analyze")
        st = c.stats()
    assert st["uptime"] > 0
    assert st["response_cache_size"] >= 1
    assert st["counters"]["serve.requests{mode=analyze}"] >= 1
    lat = st["latency"]
    assert any(k.startswith("serve.request_latency") for k in lat)
    key = next(k for k in lat if k.startswith("serve.dispatch"))
    assert lat[key]["count"] >= 1 and lat[key]["p99"] >= lat[key]["p50"]


def test_shutdown_drains_then_refuses():
    with tempfile.TemporaryDirectory() as tmp:
        sock = os.path.join(tmp, "s.sock")
        server = Server(ServeConfig(socket_path=sock, max_wait=0.02))
        server.start()
        with ServeClient(sock, timeout=60) as c:
            scn = make_scenario(2, seed=10)
            c.run(scn, mode="analyze")
            assert c.shutdown() == "draining"
        server._stopped.wait(timeout=60)
        assert server._stopped.is_set()
        assert not os.path.exists(sock)


def test_draining_server_refuses_new_requests():
    with tempfile.TemporaryDirectory() as tmp:
        sock = os.path.join(tmp, "s.sock")
        server = Server(ServeConfig(socket_path=sock, max_wait=0.02))
        server.start()
        server._draining.set()  # drain announced, listener still up
        try:
            with ServeClient(sock, timeout=60) as c:
                rid = c.submit(make_scenario(2, seed=11), mode="analyze")
                msg = c.collect(rid)
                assert msg["error"]["type"] == "Unavailable"
        finally:
            server._draining.clear()
            server.stop()


# ---------------------------------------------------------------------------
# warm restart: the persistent compilation cache
# ---------------------------------------------------------------------------

_RESTART_SCRIPT = r"""
import json, sys, tempfile, os
import numpy as np
from repro.serve.xla_cache import enable_persistent_cache
enable_persistent_cache()
from repro.analysis import tracecheck
from repro.serve.server import Server, ServeConfig
from repro.serve.client import ServeClient
from repro.scenario import (Scenario, NetworkSpec, LearningSpec,
                            StrategySpec, DataSpec)
from repro.core.complexity import LearningConstants

scn = Scenario(
    network=NetworkSpec(mu_c=[1.0, 1.5, 2.0], mu_d=[2.0] * 3,
                        mu_u=[2.0] * 3),
    learning=LearningSpec(consts=LearningConstants(
        L=1.0, delta=1.0, sigma=1.0, M=2.0, G=5.0, eps=1.0)),
    strategy=StrategySpec("explicit", p=[1 / 3] * 3, m=2))
sock = tempfile.mktemp(suffix=".sock")
server = Server(ServeConfig(socket_path=sock, max_wait=0.02))
server.start()
with tracecheck.watch() as w:
    with ServeClient(sock, timeout=300) as c:
        c.run(scn, mode="analyze")
        c.run(scn, mode="simulate", num_updates=40)
server.stop()
print(json.dumps({"compiles": w.compiles, "cache_hits": w.cache_hits,
                  "fresh": w.fresh_compiles}))
"""


def test_restarted_server_first_request_pays_zero_fresh_compiles(tmp_path):
    """Satellite: two boots of the server process against one
    ``JAX_COMPILATION_CACHE_DIR`` — the second boot's first requests
    deserialize every program from disk (zero *fresh* XLA compiles)."""
    env = dict(os.environ)
    env["JAX_COMPILATION_CACHE_DIR"] = str(tmp_path / "xla")
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])

    def boot():
        out = subprocess.run([sys.executable, "-c", _RESTART_SCRIPT],
                             capture_output=True, text=True, env=env,
                             timeout=600)
        assert out.returncode == 0, out.stderr[-2000:]
        return json.loads(out.stdout.strip().splitlines()[-1])

    cold = boot()
    assert cold["fresh"] > 0  # first boot really compiled
    warm = boot()
    assert warm["compiles"] > 0
    assert warm["fresh"] == 0, warm  # restart: everything from disk
