"""Device event engine (repro.core.events) vs theory and the host reference.

``AsyncNetworkSim`` is the exact per-task-identity reference; the device
engine consumes randomness differently, so cross-checks are distributional
(documented tolerances: throughput within ~5%, per-client conditional mean
delays within ~10% + small absolute slack at CI sample sizes)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (NetworkParams, PowerProfile, energy_per_round,
                        expected_relative_delay, throughput)
from repro.core.events import init_state, next_update, simulate_stats, step_event
from repro.core.simulator import AsyncNetworkSim, make_sampler


def random_params(seed, n, with_cs=False):
    rng = np.random.default_rng(seed)
    params = NetworkParams(
        p=jnp.asarray(rng.dirichlet(np.ones(n) * 2.0)),
        mu_c=jnp.asarray(rng.uniform(0.5, 4.0, n)),
        mu_d=jnp.asarray(rng.uniform(0.5, 4.0, n)),
        mu_u=jnp.asarray(rng.uniform(0.5, 4.0, n)))
    return params.with_cs(1.5) if with_cs else params


# ---------------------------------------------------------------------------
# stationary statistics vs closed forms (Prop. 4 / Thm 2) and the host sim
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("with_cs", [False, True])
def test_throughput_matches_prop4_and_host(with_cs):
    params = random_params(8, 4, with_cs)
    m = 6
    lam_th = float(throughput(params, m))
    for seed in (0, 1):
        st = simulate_stats(params, m, 20_000, warmup=3_000, seed=seed)
        np.testing.assert_allclose(float(st.throughput), lam_th, rtol=0.05)
    sim = AsyncNetworkSim(params, m, seed=0)
    host = sim.run(20_000, warmup=3_000)
    np.testing.assert_allclose(float(st.throughput), host.throughput,
                               rtol=0.06)
    # closed network: time-averaged occupancy sums to m exactly
    np.testing.assert_allclose(float(jnp.sum(st.mean_queue_counts)), m,
                               rtol=1e-9)


def test_mean_delay_matches_host_and_thm2():
    params = random_params(3, 4)
    m = 6
    st = simulate_stats(params, m, 30_000, warmup=4_000, seed=0)
    sim = AsyncNetworkSim(params, m, seed=1)
    host = sim.run(30_000, warmup=4_000)
    # same estimator (unscaled per-client conditional mean E0[R_i])
    np.testing.assert_allclose(np.asarray(st.mean_delay), host.mean_delay,
                               rtol=0.10, atol=0.05)
    d_th = np.asarray(expected_relative_delay(params, m))
    d_dev = np.asarray(params.p) * np.asarray(st.mean_delay)
    np.testing.assert_allclose(d_dev, d_th, rtol=0.08, atol=0.03)
    # staleness identity (Eq. 7): sum_i p_i E0[R_i] = m - 1
    np.testing.assert_allclose(d_dev.sum(), m - 1, rtol=0.03)


def test_energy_matches_formula_and_host():
    params = random_params(7, 4)
    rng = np.random.default_rng(2)
    power = PowerProfile(P_c=jnp.asarray(rng.uniform(1, 5, 4)),
                         P_u=jnp.asarray(rng.uniform(0.5, 2, 4)),
                         P_d=jnp.asarray(rng.uniform(0.2, 1, 4)))
    m = 5
    st = simulate_stats(params, m, 20_000, warmup=2_000, seed=1, power=power)
    per_round = float(st.energy) / int(st.updates)
    np.testing.assert_allclose(per_round, float(energy_per_round(params, power)),
                               rtol=0.05)
    host = AsyncNetworkSim(params, m, seed=3, power=power).run(20_000,
                                                              warmup=2_000)
    np.testing.assert_allclose(per_round, host.energy / host.updates,
                               rtol=0.08)


@pytest.mark.parametrize("dist", ["deterministic", "lognormal"])
def test_nonexponential_agrees_with_host(dist):
    params = random_params(10, 3)
    m = 4
    st = simulate_stats(params, m, 10_000, warmup=1_000, seed=0,
                        distribution=dist)
    host = AsyncNetworkSim(params, m, distribution=dist, seed=0).run(
        10_000, warmup=1_000)
    np.testing.assert_allclose(float(st.throughput), host.throughput,
                               rtol=0.06)
    np.testing.assert_allclose(np.asarray(st.mean_delay), host.mean_delay,
                               rtol=0.15, atol=0.1)
    assert np.isfinite(np.asarray(st.mean_delay)).all()


# ---------------------------------------------------------------------------
# batching semantics (vmap over seeds, padded (p, m) lanes)
# ---------------------------------------------------------------------------

def test_vmapped_seed_batch_bitwise_equals_stacked_singles():
    params = random_params(5, 3)
    keys = jax.random.split(jax.random.PRNGKey(42), 4)

    def run(k):
        return simulate_stats(params, 5, 800, warmup=100, key=k, m_max=5)

    batched = jax.vmap(run)(keys)
    singles = [run(k) for k in keys]
    for field in ("throughput", "mean_delay", "delay_counts", "energy",
                  "mean_queue_counts", "time"):
        b = np.asarray(getattr(batched, field))
        s = np.stack([np.asarray(getattr(r, field)) for r in singles])
        np.testing.assert_array_equal(b, s, err_msg=field)


def test_padded_pm_batch_equals_singles():
    params = random_params(6, 4)
    rng = np.random.default_rng(1)
    ps = jnp.stack([params.p, jnp.asarray(rng.dirichlet(np.ones(4)))])
    ms = jnp.asarray([3, 6])

    def run(p, m):
        return simulate_stats(params._replace(p=p), m, 3_000, warmup=400,
                              seed=7, m_max=6)

    batched = jax.vmap(run)(ps, ms)
    for i in range(2):
        single = run(ps[i], ms[i])
        np.testing.assert_array_equal(np.asarray(batched.throughput[i]),
                                      np.asarray(single.throughput))
        lam_th = float(throughput(params._replace(p=ps[i]), int(ms[i])))
        np.testing.assert_allclose(float(batched.throughput[i]), lam_th,
                                   rtol=0.08)


def test_inactive_slots_stay_inactive():
    """Padded slots never enter the dynamics: with m < m_max the total
    occupancy is m and padded slots keep phase INACTIVE."""
    from repro.core import events as E

    params = random_params(4, 3)
    st = init_state(params, 2, jax.random.PRNGKey(0), m_max=5)
    for _ in range(50):
        st, _ = step_event(params, st)
    phase = np.asarray(st.phase)
    assert (phase == E.INACTIVE).sum() == 3
    assert float(jnp.sum(st.occ_int)) <= st.t * 2 + 1e-9


def test_next_update_emits_every_update_once():
    """Scanning next_update k times yields k strictly increasing update
    times and round counter k."""
    params = random_params(2, 3)
    st = init_state(params, 4, jax.random.PRNGKey(3), m_max=4)

    def body(st, _):
        st, upd = next_update(params, st)
        return st, upd.time

    st, times = jax.lax.scan(body, st, None, length=200)
    times = np.asarray(times)
    assert int(st.round) == 200
    assert np.all(np.diff(times) > 0)


# ---------------------------------------------------------------------------
# guards (satellite: sampler validation, TrainLog robustness)
# ---------------------------------------------------------------------------

def test_make_sampler_rejects_nonpositive_rate():
    rng = np.random.default_rng(0)
    for kind in ("exponential", "deterministic", "lognormal"):
        sample = make_sampler(kind, rng)
        assert sample(1.0) > 0
        with pytest.raises(ValueError, match="positive"):
            sample(0.0)
        with pytest.raises(ValueError, match="positive"):
            sample(-1.0)


def test_simulate_stats_rejects_unknown_distribution():
    params = random_params(0, 3)
    with pytest.raises(ValueError, match="distribution"):
        simulate_stats(params, 3, 10, distribution="weibull")


def test_time_to_accuracy_guards():
    from repro.fl import TrainLog

    empty = TrainLog(times=[], accuracies=[], losses=[], updates=[])
    assert empty.time_to_accuracy(0.5) == float("inf")
    nan_log = TrainLog(times=[0.0, 1.0, 2.0],
                       accuracies=[float("nan"), 0.3, 0.7],
                       losses=[1.0, 1.0, 1.0], updates=[0, 1, 2])
    assert nan_log.time_to_accuracy(0.5) == 2.0
    assert nan_log.time_to_accuracy(0.9) == float("inf")


# ---------------------------------------------------------------------------
# fused trainer (repro.fl.engine) vs the host reference loop
# ---------------------------------------------------------------------------

def _tiny_fl_problem(n=4, seed=0):
    from repro.data import (iid_partition, make_synthetic_image_dataset,
                            train_test_split)

    full = make_synthetic_image_dataset(num_classes=4, samples_per_class=40,
                                        seed=seed)
    ds, test = train_test_split(full, 0.25, seed=seed + 1)
    parts = iid_partition(ds.y, n, seed=seed)
    clients = [(ds.x[i], ds.y[i]) for i in parts]
    rng = np.random.default_rng(seed)
    net = NetworkParams(
        p=jnp.full((n,), 1.0 / n),
        mu_c=jnp.asarray(rng.uniform(0.5, 3.0, n)),
        mu_d=jnp.asarray(rng.uniform(1.0, 5.0, n)),
        mu_u=jnp.asarray(rng.uniform(1.0, 5.0, n)))
    return clients, (test.x, test.y), net


def test_device_trainer_matches_host_statistics():
    """Fused-scan training run: queueing statistics agree with the host
    reference loop in distribution, the eval grid is complete and the
    staleness identity holds."""
    from repro.fl import AsyncFLConfig, AsyncFLTrainer, mlp_classifier

    clients, test, net = _tiny_fl_problem()
    m = 4
    horizon = 120.0
    kw = dict(eta=0.05, batch_size=16, eval_every_time=30.0, seed=0)
    model = mlp_classifier(28 * 28, 4, hidden=(16,))
    dev = AsyncFLTrainer(model, clients, net, m,
                         config=AsyncFLConfig(backend="device", **kw),
                         test_data=test)
    dlog = dev.run(horizon_time=horizon)
    host = AsyncFLTrainer(model, clients, net, m,
                          config=AsyncFLConfig(backend="host", **kw),
                          test_data=test)
    hlog = host.run(horizon_time=horizon)

    # same eval grid shape: 0, 30, ..., 90 < t_end plus the final point
    assert dlog.times == hlog.times
    assert dlog.updates[-1] == pytest.approx(hlog.updates[-1], rel=0.35)
    assert np.isfinite(dlog.losses).all()
    # update counters at grid times are non-decreasing and end at the total
    assert all(a <= b for a, b in zip(dlog.updates, dlog.updates[1:]))
    p = np.asarray(net.p)
    staleness = float(np.sum(p * dlog.mean_delay))
    assert abs(staleness - (m - 1)) < 1.0
    np.testing.assert_allclose(dlog.throughput, hlog.throughput, rtol=0.35)
    assert dlog.accuracies[-1] > 0.4   # learns well above 1/4 chance


@pytest.mark.slow
def test_device_trainer_multiseed_close_to_host_mean():
    """Multi-seed Monte-Carlo: seed-averaged device throughput and staleness
    match the host loop tightly (slow tier: many full runs)."""
    from repro.fl import AsyncFLConfig, AsyncFLTrainer, mlp_classifier

    clients, test, net = _tiny_fl_problem(seed=2)
    m, horizon = 4, 200.0
    kw = dict(eta=0.05, batch_size=16, eval_every_time=100.0)
    model = mlp_classifier(28 * 28, 4, hidden=(16,))
    dev = AsyncFLTrainer(model, clients, net, m,
                         config=AsyncFLConfig(backend="device", **kw),
                         test_data=test)
    dlogs = dev.run_seeds(horizon, seeds=range(8))
    thr_dev = np.mean([l.throughput for l in dlogs])
    host_thr = []
    for seed in range(4):
        h = AsyncFLTrainer(model, clients, net, m,
                           config=AsyncFLConfig(backend="host", seed=seed,
                                                **kw),
                           test_data=test)
        host_thr.append(h.run(horizon_time=horizon).throughput)
    np.testing.assert_allclose(thr_dev, np.mean(host_thr), rtol=0.10)
    for l in dlogs:
        assert abs(float(np.sum(np.asarray(net.p) * l.mean_delay)) - (m - 1)) < 1.0


def test_device_trainer_max_updates_binds():
    from repro.fl import AsyncFLConfig, AsyncFLTrainer, mlp_classifier

    clients, test, net = _tiny_fl_problem(seed=1)
    model = mlp_classifier(28 * 28, 4, hidden=(16,))
    tr = AsyncFLTrainer(model, clients, net, 3,
                        config=AsyncFLConfig(backend="device", eta=0.05,
                                             batch_size=16,
                                             eval_every_time=1e9),
                        test_data=test)
    log = tr.run(horizon_time=1e9, max_updates=50)
    assert log.updates[-1] == 50
    assert int(np.sum(log.mean_delay >= 0)) == net.n
