"""Theorem 3 / Prop 4 / Section 6 energy results and the optimizers."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (LearningConstants, NetworkParams, PowerProfile,
                        energy_complexity, energy_optimal_routing,
                        energy_per_round, eta_max, joint_optimal,
                        make_energy_objective, make_round_objective,
                        make_throughput_objective, make_time_objective,
                        minimal_energy, optimize_routing, per_task_energy,
                        round_complexity, round_complexity_unbounded,
                        sequential_concurrency_search, throughput,
                        wallclock_time)


def small_params(n=4, seed=0):
    rng = np.random.default_rng(seed)
    return NetworkParams(
        p=jnp.full((n,), 1.0 / n),
        mu_c=jnp.asarray(rng.uniform(0.3, 6.0, n)),
        mu_d=jnp.asarray(rng.uniform(0.3, 6.0, n)),
        mu_u=jnp.asarray(rng.uniform(0.3, 6.0, n)),
    )


CONSTS = LearningConstants(L=1.0, delta=1.0, sigma=1.0, M=2.0, G=5.0, eps=1.0)


def test_round_complexity_monotone_in_m():
    """Section 4.2: K_eps is non-decreasing in m for fixed routing."""
    params = small_params()
    ks = [float(round_complexity(params, m, CONSTS)) for m in range(1, 10)]
    assert all(b >= a - 1e-9 for a, b in zip(ks, ks[1:]))


def test_round_complexity_m1_is_serial_sgd():
    """At m=1 the staleness term vanishes; K depends only on sum 1/p_i."""
    params = small_params()
    k1 = float(round_complexity(params, 1, CONSTS))
    n, p = params.n, params.p
    expected = (24 * CONSTS.L * CONSTS.delta / (n * CONSTS.eps)
                * (4 + CONSTS.B / CONSTS.eps) * float(jnp.sum(1 / (n * p))))
    assert k1 == pytest.approx(expected, rel=1e-12)


def test_uniform_minimizes_first_term():
    """sum 1/p_i is minimized at uniform routing (Section 4.2)."""
    params = small_params()
    k_uni = float(round_complexity(params, 1, CONSTS))
    rng = np.random.default_rng(0)
    for _ in range(5):
        p = rng.dirichlet(np.ones(params.n))
        k = float(round_complexity(params._replace(p=jnp.asarray(p)), 1, CONSTS))
        assert k >= k_uni - 1e-9


def test_eta_max_positive_and_unbounded_variant():
    params = small_params()
    for m in (1, 4, 8):
        assert float(eta_max(params, m, CONSTS)) > 0
        assert float(round_complexity_unbounded(params, m, CONSTS)) > 0


def test_wallclock_tradeoff_has_interior_optimum():
    """Fig. 2: E0[tau_eps] decreases then increases in m — interior m*."""
    params = small_params(n=2, seed=3)
    taus = [float(wallclock_time(params, m, CONSTS)) for m in range(1, 40)]
    m_star = int(np.argmin(taus)) + 1
    assert 1 < m_star < 40
    # and it's not monotone
    assert taus[0] > min(taus) and taus[-1] > min(taus)


# ---------------------------------------------------------------------------
# energy (Section 6)
# ---------------------------------------------------------------------------

def power_profile(params):
    kappa = jnp.asarray([0.5, 2.0, 0.1, 1.0])
    return PowerProfile.from_dvfs(kappa, params.mu_c,
                                  P_u=jnp.asarray([1.0, 2.0, 0.5, 1.5]),
                                  P_d=jnp.asarray([0.5, 1.0, 0.2, 0.7]))


def test_energy_per_round_independent_of_m():
    params = small_params()
    power = power_profile(params)
    assert float(energy_per_round(params, power)) == pytest.approx(
        float(jnp.sum(params.p * per_task_energy(params, power))), rel=1e-12)


def test_energy_minimized_at_m1():
    """Section 6.3: E0[E_eps] is minimized at m=1 for fixed p."""
    params = small_params()
    power = power_profile(params)
    es = [float(energy_complexity(params, m, CONSTS, power)) for m in range(1, 8)]
    assert es[0] == min(es)
    assert all(b >= a - 1e-9 for a, b in zip(es, es[1:]))


def test_cauchy_schwarz_optimal_routing():
    """Eq. 16: numeric optimizer at m=1 recovers p* ∝ 1/sqrt(E_i) and Eq. 17."""
    params = small_params()
    power = power_profile(params)
    p_star = np.asarray(energy_optimal_routing(params, power))
    obj = make_energy_objective(params, CONSTS, power)
    res = optimize_routing(obj, params.n, 1, steps=2500, lr=0.05)
    np.testing.assert_allclose(np.asarray(res.p), p_star, rtol=2e-3)
    e_star = float(minimal_energy(params, CONSTS, power))
    assert res.value == pytest.approx(e_star, rel=1e-4)
    # optimum is a lower bound over random routings
    rng = np.random.default_rng(1)
    for _ in range(5):
        p = jnp.asarray(rng.dirichlet(np.ones(params.n)))
        assert float(energy_complexity(params._replace(p=p), 1, CONSTS, power)) >= e_star - 1e-9


def test_energy_sim_matches_formula():
    """Prop 5: mean energy per round E[P(0)]/lambda == sum_i p_i E_i (simulated)."""
    from repro.core.simulator import AsyncNetworkSim
    params = small_params(seed=7)
    power = power_profile(params)
    m = 5
    sim = AsyncNetworkSim(params, m, seed=11, power=power)
    stats = sim.run(60_000, warmup=6_000)
    per_round_sim = stats.energy / stats.updates
    per_round_th = float(energy_per_round(params, power))
    np.testing.assert_allclose(per_round_sim, per_round_th, rtol=0.04)


# ---------------------------------------------------------------------------
# optimizers (Section 5.3.2 / 6.4)
# ---------------------------------------------------------------------------

def test_routing_optimizers_beat_uniform():
    params = small_params(seed=5)
    m = 6
    uni = jnp.full((params.n,), 1.0 / params.n)

    t_obj = make_time_objective(params, CONSTS)
    res = optimize_routing(t_obj, params.n, m, steps=800)
    assert res.value <= float(t_obj(uni, m)) + 1e-9

    k_obj = make_round_objective(params, CONSTS)
    res_k = optimize_routing(k_obj, params.n, m, steps=800)
    assert res_k.value <= float(k_obj(uni, m)) + 1e-9

    l_obj = make_throughput_objective(params)
    res_l = optimize_routing(l_obj, params.n, m, steps=800)
    assert -res_l.value >= float(throughput(params, m)) - 1e-9


def test_sequential_search_finds_interior_m():
    params = small_params(n=3, seed=2)
    res = sequential_concurrency_search(
        make_time_objective(params, CONSTS), params.n,
        m_start=1, m_max=30, steps=250, patience=2)
    assert 1 <= res.m < 30
    assert res.value > 0


def test_joint_rho_pareto_monotone():
    """Higher rho (more energy weight) => optimal energy non-increasing."""
    params = small_params(seed=4)
    power = power_profile(params)
    tau_res = sequential_concurrency_search(
        make_time_objective(params, CONSTS), params.n, m_start=1, m_max=20,
        steps=200)
    tau_star = tau_res.value
    e_star = float(minimal_energy(params, CONSTS, power))
    energies = []
    for rho in (0.0, 0.5, 1.0):
        res = joint_optimal(params, CONSTS, power, rho, tau_star, e_star,
                            m_max=20, steps=200)
        energies.append(float(energy_complexity(
            params._replace(p=res.p), res.m, CONSTS, power)))
    assert energies[0] >= energies[1] - 1e-6 >= energies[2] - 2e-6
