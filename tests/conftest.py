"""Test bootstrap: src importability + an optional-`hypothesis` shim.

``hypothesis`` is a dev-only dependency (see ``requirements-dev.txt``).  When
it is absent — e.g. in the minimal CI container — we install a small
*deterministic* stand-in into ``sys.modules`` so the property-test modules
still collect and run: ``@given`` replays a fixed, seed-derived set of
examples instead of searching, and ``@settings`` only honours
``max_examples``.  Only the strategy surface used by this suite
(``st.integers``, ``st.booleans``) is provided.
"""
import functools
import inspect
import os
import sys
import zlib

# Make ``src`` importable when pytest is run without PYTHONPATH=src.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import pytest  # noqa: E402


@pytest.fixture
def tracecheck():
    """The recompile sentinel (``repro.analysis.tracecheck``), per-test.

    Use ``tracecheck.expect(...)`` / ``tracecheck.forbid(...)`` /
    ``tracecheck.counting(fn)`` — see the module docstring.  Imported
    lazily so collecting jax-free test modules stays jax-free.
    """
    from repro.analysis import tracecheck as tc

    return tc


@pytest.fixture(autouse=True, scope="module")
def _drop_compiled_programs():
    """Release each module's compiled executables when the module ends.

    The full tier-1 run now compiles several hundred programs; keeping
    every executable live for the whole session grows the process past
    ~8 GB and has segfaulted XLA's CPU compiler late in the suite.
    Programs are not shared across test modules (each module owns its
    shapes), so clearing jit caches at module teardown caps the resident
    set without changing any test's semantics — a builder memoized by
    ``lru_cache`` simply recompiles on its next call.  Imported lazily so
    jax-free modules stay jax-free.
    """
    yield
    if "jax" in sys.modules:
        sys.modules["jax"].clear_caches()

try:
    import hypothesis  # noqa: F401
except ImportError:
    import types

    import numpy as _np

    _DEFAULT_MAX_EXAMPLES = 10

    class _Strategy:
        """A deterministic sampler: draw(rng) -> value."""

        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    def _integers(min_value, max_value):
        lo, hi = int(min_value), int(max_value)

        def draw(rng):
            # hit the endpoints first, then seeded interior draws
            roll = rng.integers(0, 8)
            if roll == 0:
                return lo
            if roll == 1:
                return hi
            return int(rng.integers(lo, hi + 1))

        return _Strategy(draw)

    def _booleans():
        return _Strategy(lambda rng: bool(rng.integers(0, 2)))

    def _given(*strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper():
                n_ex = getattr(wrapper, "_shim_max_examples",
                               _DEFAULT_MAX_EXAMPLES)
                seed = zlib.crc32(fn.__qualname__.encode())
                rng = _np.random.default_rng(seed)
                for _ in range(n_ex):
                    drawn = tuple(s.draw(rng) for s in strategies)
                    fn(*drawn)

            # hide the wrapped signature so pytest doesn't see the strategy
            # parameters as fixtures (real @given also yields a 0-arg test)
            del wrapper.__wrapped__
            wrapper.__signature__ = inspect.Signature()
            return wrapper

        return deco

    def _settings(max_examples=_DEFAULT_MAX_EXAMPLES, **_ignored):
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn

        return deco

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.__version__ = "0.0-shim"
    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.booleans = _booleans
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
