"""The padded traced-``n`` convention (mirror of the ``m_max`` contract).

Contracts under test:

  * :func:`repro.core.buzen.pad_network` pads a network to ``n_max`` rows
    (zero routing mass, unit rates, ``n_active`` = real count) such that
    every downstream quantity is **bitwise** what the unpadded network
    produces:

      - closed forms (Buzen DP, throughput, delays, K_eps, tau, energy,
        second moments, delay Jacobian) — property-tested over random
        ``(n, n_max, m, m_max)`` and both CS variants;
      - event trajectories — the routing draw is a shape-independent
        inverse-CDF (``events._route_client``), so ``simulate_stats`` on
        the padded network, unpadded via ``events.unpad_stats``, equals
        the unpadded run exactly, for every registered timing law;
      - the fused trainer (``repro.fl.engine``): the ``eta/(n p_C)`` bias
        correction uses the real population and padded clients contribute
        no updates.

  * ``ScenarioSuite`` buckets mixed-population scenarios by the shared
    ``(n_max, m_max)`` padding — ONE compiled program per structure where
    the pre-padding planner compiled one per distinct ``n`` — and its
    entries reproduce the per-scenario unpadded runs.

  * The ``"emnist"`` dataset rides ``DataSpec`` beside ``"synthetic"``
    (download-free: local cache or deterministic fallback).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (LearningConstants, NetworkParams, PowerProfile,
                        pad_network, unpad_stats)
from repro.core import events as E
from repro.core import jackson
from repro.core.batched import (delay_jacobian_padded,
                                energy_complexity_padded,
                                expected_relative_delay_padded,
                                round_complexity_padded,
                                second_moment_matrix_padded,
                                throughput_padded)
from repro.core.buzen import log_normalizing_constants
from repro.scenario.laws import law_names

CONSTS = LearningConstants(L=1.0, delta=1.0, sigma=1.0, M=2.0, G=5.0, eps=1.0)


def params_from(seed, n, with_cs=False):
    rng = np.random.default_rng(seed)
    params = NetworkParams(
        p=jnp.asarray(rng.dirichlet(np.ones(n) * 2.0)),
        mu_c=jnp.asarray(rng.uniform(0.3, 5.0, n)),
        mu_d=jnp.asarray(rng.uniform(0.3, 5.0, n)),
        mu_u=jnp.asarray(rng.uniform(0.3, 5.0, n)))
    return params.with_cs(rng.uniform(0.5, 4.0)) if with_cs else params


def power_from(seed, n):
    rng = np.random.default_rng(seed + 100)
    return PowerProfile(P_c=jnp.asarray(rng.uniform(1, 5, n)),
                        P_u=jnp.asarray(rng.uniform(0.5, 2, n)),
                        P_d=jnp.asarray(rng.uniform(0.2, 1, n)))


# ---------------------------------------------------------------------------
# pad_network basics
# ---------------------------------------------------------------------------

def test_pad_network_layout_and_validation():
    params = params_from(0, 3, with_cs=True)
    padded = pad_network(params, 5)
    assert padded.n == 5 and int(padded.n_active) == 3
    assert params.n_active is None and params.active_mask is None
    np.testing.assert_array_equal(np.asarray(padded.p[3:]), 0.0)
    np.testing.assert_array_equal(np.asarray(padded.mu_c[3:]), 1.0)
    np.testing.assert_array_equal(np.asarray(padded.p[:3]),
                                  np.asarray(params.p))
    np.testing.assert_array_equal(np.asarray(padded.active_mask),
                                  [True, True, True, False, False])
    # re-padding keeps the original real count
    again = pad_network(padded, 7)
    assert again.n == 7 and int(again.n_active) == 3
    with pytest.raises(ValueError, match="n_max=2"):
        pad_network(params, 2)


# ---------------------------------------------------------------------------
# closed forms: padded-n bitwise vs unpadded, static cross-check
# ---------------------------------------------------------------------------

def _closed_forms(prm, m, m_max, power):
    logZ = log_normalizing_constants(prm, m_max)
    return (throughput_padded(logZ, m),
            expected_relative_delay_padded(prm, m, logZ, m_max),
            round_complexity_padded(prm, m, CONSTS, logZ, m_max),
            energy_complexity_padded(prm, m, CONSTS, power, logZ, m_max),
            second_moment_matrix_padded(prm, m, logZ, m_max),
            delay_jacobian_padded(prm, m, logZ, m_max))


@settings(max_examples=8, deadline=None)
@given(st.integers(2, 5), st.integers(0, 4), st.integers(2, 6),
       st.integers(0, 3), st.integers(0, 10_000), st.booleans())
def test_padded_closed_forms_bitwise_and_match_static(n, extra_n, m, extra_m,
                                                      seed, with_cs):
    params = params_from(seed, n, with_cs)
    power = power_from(seed, n)
    n_max = n + extra_n
    m_max = m + extra_m
    padded = pad_network(params, n_max)
    power_pad = power._replace(
        P_c=jnp.concatenate([power.P_c, jnp.zeros(extra_n)]),
        P_u=jnp.concatenate([power.P_u, jnp.zeros(extra_n)]),
        P_d=jnp.concatenate([power.P_d, jnp.zeros(extra_n)]))

    fn = jax.jit(_closed_forms, static_argnames=("m_max",))
    thr, d, k, en, sm, jac = fn(params, m, m_max, power)
    thr2, d2, k2, en2, sm2, jac2 = fn(padded, m, m_max, power_pad)

    # bitwise: padding is invisible
    assert float(thr) == float(thr2)
    assert float(k) == float(k2)
    assert float(en) == float(en2)
    np.testing.assert_array_equal(np.asarray(d), np.asarray(d2)[:n])
    np.testing.assert_array_equal(np.asarray(d2)[n:], 0.0)
    np.testing.assert_array_equal(np.asarray(sm), np.asarray(sm2)[:n, :n])
    np.testing.assert_array_equal(np.asarray(sm2)[n:, :], 0.0)
    np.testing.assert_array_equal(np.asarray(jac), np.asarray(jac2)[:n, :n])
    np.testing.assert_array_equal(np.asarray(jac2)[:, n:], 0.0)

    # cross-check vs the static closed forms (float64 round-off, the same
    # tolerance class as every other padded-vs-static contract)
    np.testing.assert_allclose(
        np.asarray(sm), np.asarray(jackson.second_moment_matrix(params, m)),
        rtol=1e-11, atol=1e-13)
    np.testing.assert_allclose(
        np.asarray(jac), np.asarray(jackson.delay_jacobian(params, m)),
        rtol=1e-9, atol=1e-11)
    np.testing.assert_allclose(float(thr),
                               float(jackson.throughput(params, m)),
                               rtol=1e-12)


def test_padded_round_complexity_grad_finite():
    """Review regression: grad w.r.t. p of the padded closed forms on a
    padded network must be finite (the 1/p divisions run on a pinned-safe
    p; a where() after an inf primal would leak NaN cotangents)."""
    params = params_from(4, 3, with_cs=True)
    padded = pad_network(params, 6)
    m, m_max = 3, 5

    def k_eps(p):
        prm = padded._replace(p=p)
        logZ = log_normalizing_constants(prm, m_max)
        return round_complexity_padded(prm, m, CONSTS, logZ, m_max)

    g = np.asarray(jax.grad(k_eps)(padded.p))
    assert np.isfinite(g[:3]).all()
    assert np.isfinite(float(k_eps(padded.p)))


def test_padded_jacobian_columns_sum_to_zero():
    """Conservation of total staleness (Eq. 7) survives the padding: the
    active block's columns sum to zero, padded columns are exactly zero."""
    params = params_from(3, 4, with_cs=True)
    padded = pad_network(params, 7)
    m, m_max = 5, 6
    logZ = log_normalizing_constants(padded, m_max)
    J = np.asarray(delay_jacobian_padded(padded, m, logZ, m_max))
    np.testing.assert_allclose(J.sum(axis=0), 0.0, atol=1e-7)


def test_buzen_pallas_padded_forward_and_masked_vjp():
    """The Pallas DP treats load-0 (padded) stations as convolution
    identities and the custom VJP returns exactly-zero cotangents for
    them."""
    from repro.kernels.buzen import buzen_log_Z_batched

    params = params_from(1, 4)
    padded = pad_network(params, 6)
    m_max = 5

    def rows(prm):
        log_rho = jnp.log(prm.p)[None, :] - jnp.log(prm.mu_c)[None, :]
        return log_rho, jnp.log(jnp.sum(prm.gamma))[None]

    lr, lg = rows(params)
    lrp, lgp = rows(padded)
    z = buzen_log_Z_batched(lr, lg, m_max)
    zp = buzen_log_Z_batched(lrp, lgp, m_max)
    np.testing.assert_array_equal(np.asarray(z), np.asarray(zp))

    g = jax.grad(lambda a, b: jnp.sum(buzen_log_Z_batched(a, b, m_max)))(
        lrp, lgp)
    assert np.isfinite(np.asarray(g)).all()
    np.testing.assert_array_equal(np.asarray(g)[:, 4:], 0.0)
    g_ref = jax.grad(lambda a, b: jnp.sum(buzen_log_Z_batched(a, b, m_max)))(
        lr, lg)
    np.testing.assert_allclose(np.asarray(g)[:, :4], np.asarray(g_ref),
                               rtol=1e-12, atol=1e-12)


# ---------------------------------------------------------------------------
# event trajectories: bitwise invariant to n-padding, every registered law
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(st.integers(2, 5), st.integers(2, 5), st.integers(0, 3),
       st.integers(0, 10_000), st.booleans())
def test_event_trajectories_bitwise_under_n_padding(n, m, law_i, seed,
                                                    with_cs):
    """``simulate_stats`` on the padded network == the unpadded run,
    bitwise, across random ``(n, n_max, m, m_max)`` and every registered
    timing law (``m_max``/``n_max`` pinned to shared bounds so the compile
    cache is reused across examples; trajectories ARE ``m_max``-dependent,
    hence the shared table size on both sides)."""
    law = sorted(law_names())[law_i % len(law_names())]
    n_max, m_max = 6, 6
    params = params_from(seed, n, with_cs)
    padded = pad_network(params, n_max)
    kw = dict(warmup=10, seed=seed % 7, distribution=law, m_max=m_max)
    want = E.simulate_stats(params, m, 80, **kw)
    got = unpad_stats(E.simulate_stats(padded, m, 80, **kw), n)
    for f in want._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(want, f)), np.asarray(getattr(got, f)),
            err_msg=f"{law} cs={with_cs} {f}")


def test_event_stats_energy_bitwise_under_n_padding():
    params = params_from(5, 4, with_cs=True)
    power = power_from(5, 4)
    padded = pad_network(params, 7)
    power_pad = power._replace(
        P_c=jnp.concatenate([power.P_c, jnp.zeros(3)]),
        P_u=jnp.concatenate([power.P_u, jnp.zeros(3)]),
        P_d=jnp.concatenate([power.P_d, jnp.zeros(3)]))
    kw = dict(warmup=20, seed=1, m_max=5)
    want = E.simulate_stats(params, 4, 150, power=power, **kw)
    got = unpad_stats(E.simulate_stats(padded, 4, 150, power=power_pad,
                                       **kw), 4)
    assert float(want.energy) == float(got.energy)
    np.testing.assert_array_equal(np.asarray(want.mean_queue_counts),
                                  np.asarray(got.mean_queue_counts))


def test_pallas_backend_bitwise_under_n_padding():
    """The events kernel path consumes the same padding-invariant
    randomness: padded pallas lanes == unpadded reference lanes."""
    from repro.sim import simulate_stats_lanes

    params = params_from(2, 3)
    padded = pad_network(params, 5)
    ref = simulate_stats_lanes([params] * 2, [3, 4], 200, warmup=40,
                               seeds=(0, 1), m_max=4, backend="reference")
    pal = simulate_stats_lanes([padded] * 2, [3, 4], 200, warmup=40,
                               seeds=(0, 1), m_max=4, backend="pallas")
    pal = unpad_stats(pal, 3)
    for f in ref._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(ref, f)), np.asarray(getattr(pal, f)),
            err_msg=f)


# ---------------------------------------------------------------------------
# mixed-population ScenarioSuite: 1-2 programs, per-scenario bitwise
# ---------------------------------------------------------------------------

def _mixed_suite(seeds=(0, 1)):
    from repro.scenario import (LearningSpec, NetworkSpec, Scenario,
                                ScenarioSuite, StrategySpec)

    rng = np.random.default_rng(11)
    scns = {}
    for i, n in enumerate((3, 4, 6)):
        scns[f"n{n}"] = Scenario(
            network=NetworkSpec(mu_c=rng.uniform(0.5, 3, n),
                                mu_d=rng.uniform(0.5, 3, n),
                                mu_u=rng.uniform(0.5, 3, n)),
            learning=LearningSpec(consts=CONSTS),
            strategy=StrategySpec("explicit",
                                  p=rng.dirichlet(np.ones(n)), m=2 + i))
    return ScenarioSuite(scns, seeds=seeds)


def test_mixed_population_suite_plans_one_program():
    """The acceptance regression: a mixed-n suite compiles ONE program per
    mode (the pre-padding planner compiled one per distinct n)."""
    suite = _mixed_suite()
    ana = suite.run(mode="analyze")
    assert ana.programs == 1
    sim = suite.run(mode="simulate", num_updates=150, warmup=20)
    assert sim.programs == 1
    assert set(sim.entries) == set(suite.scenarios)
    # a structurally-different member (CS buffer) still only adds a bucket
    import dataclasses

    from repro.scenario import ScenarioSuite

    mixed = dict(suite.scenarios)
    mixed["cs"] = mixed["n3"].replace(network=dataclasses.replace(
        mixed["n3"].network, mu_cs=1.5))
    both = ScenarioSuite(mixed, seeds=(0,)).run(mode="analyze")
    assert both.programs == 2


def test_mixed_population_suite_matches_unpadded_runs_bitwise():
    """Mixed-n suite entries == per-scenario unpadded runs: closed forms
    and lane-for-lane event trajectories (same shared table size)."""
    suite = _mixed_suite(seeds=(0, 2))
    strategies = suite.resolve()
    m_shared = max(m for _, m in strategies.values())

    ana = suite.run(mode="analyze")
    for name, (p, m) in strategies.items():
        params = suite.scenarios[name].params(p)
        ent = ana.entries[name]
        assert ent["delays"].shape == (suite.scenarios[name].n,)
        np.testing.assert_allclose(
            ent["throughput"], float(jackson.throughput(params, m)),
            rtol=1e-10)
        np.testing.assert_allclose(
            ent["delays"], np.asarray(jackson.expected_relative_delay(
                params, m)), rtol=1e-10, atol=1e-12)

    sim = suite.run(mode="simulate", num_updates=150, warmup=20)
    for name, (p, m) in strategies.items():
        scn = suite.scenarios[name]
        for seed, got in zip(suite.seeds, sim.entries[name]):
            want = E.simulate_stats(scn.params(p), m, 150, warmup=20,
                                    key=jax.random.PRNGKey(seed),
                                    m_max=m_shared)
            for f in want._fields:
                np.testing.assert_array_equal(
                    np.asarray(getattr(want, f)),
                    np.asarray(getattr(got, f)),
                    err_msg=f"{name}/{seed}/{f}")


# ---------------------------------------------------------------------------
# fused trainer under the traced-n convention
# ---------------------------------------------------------------------------

def test_device_trainer_bitwise_under_n_padding():
    from repro.data import iid_partition, make_synthetic_image_dataset
    from repro.fl import AsyncFLConfig, mlp_classifier
    from repro.fl.engine import DeviceTrainer

    n, n_max = 3, 5
    params = params_from(7, n)
    padded = pad_network(params, n_max)
    full = make_synthetic_image_dataset(num_classes=4, samples_per_class=18,
                                        image_size=8, seed=7)
    parts = iid_partition(full.y, n, seed=7)
    clients = [(full.x[i], full.y[i]) for i in parts]
    model = mlp_classifier(8 * 8, 4, hidden=(8,))
    cfg = AsyncFLConfig(eta=0.05, batch_size=8, eval_every_time=2.0)

    rng = np.random.default_rng(7)
    ps = [np.asarray(params.p), rng.dirichlet(np.ones(n))]
    ps_pad = [np.concatenate([p, np.zeros(n_max - n)]) for p in ps]
    kw = dict(ms=[2, 3], etas=[0.05, 0.05], seeds=[0, 1], horizon_time=6.0)

    t1 = DeviceTrainer(model, clients, params, cfg,
                       test_data=(full.x, full.y))
    logs1, fin1 = t1.run_lanes(ps=ps, **kw)
    t2 = DeviceTrainer(model, clients, padded, cfg,
                       test_data=(full.x, full.y))
    assert t2.n == n_max and t2.n_act == n
    logs2, fin2 = t2.run_lanes(ps=ps_pad, **kw)

    for a, b in zip(logs1, logs2):
        assert a.times == b.times
        assert a.losses == b.losses
        assert a.accuracies == b.accuracies
        assert a.throughput == b.throughput
        assert a.energy == b.energy
        np.testing.assert_array_equal(a.mean_delay, b.mean_delay)
        assert a.mean_delay.shape == (n,) and b.mean_delay.shape == (n,)
    for la, lb in zip(jax.tree_util.tree_leaves(fin1),
                      jax.tree_util.tree_leaves(fin2)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_device_trainer_rejects_client_count_mismatch():
    from repro.fl import AsyncFLConfig, mlp_classifier
    from repro.fl.engine import DeviceTrainer

    padded = pad_network(params_from(7, 3), 5)
    model = mlp_classifier(4, 2, hidden=(4,))
    clients = [(np.zeros((2, 4), np.float32), np.zeros(2, np.int32))] * 4
    with pytest.raises(ValueError, match="active"):
        DeviceTrainer(model, clients, padded, AsyncFLConfig())


# ---------------------------------------------------------------------------
# the emnist DataSpec dataset
# ---------------------------------------------------------------------------

def test_emnist_loader_fallback_shapes_and_determinism(tmp_path):
    from repro.data import load_emnist

    ds1 = load_emnist(num_classes=3, samples_per_class=5, seed=2,
                      path=str(tmp_path / "missing.npz"))
    ds2 = load_emnist(num_classes=3, samples_per_class=5, seed=2,
                      path=str(tmp_path / "missing.npz"))
    assert ds1.x.shape == (15, 28, 28, 1) and ds1.x.dtype == np.float32
    assert ds1.y.shape == (15,) and set(np.unique(ds1.y)) == {0, 1, 2}
    np.testing.assert_array_equal(ds1.x, ds2.x)
    # distinct from the plain synthetic dataset at the same settings
    from repro.data import make_synthetic_image_dataset

    syn = make_synthetic_image_dataset(num_classes=3, samples_per_class=5,
                                       seed=2)
    assert not np.array_equal(ds1.x, syn.x)


def test_emnist_loader_reads_local_cache(tmp_path):
    from repro.data import load_emnist

    rng = np.random.default_rng(0)
    x = rng.integers(0, 256, (40, 28, 28)).astype(np.uint8)
    y = np.repeat(np.arange(4), 10).astype(np.int64)
    path = tmp_path / "emnist.npz"
    np.savez(path, x=x, y=y)
    ds = load_emnist(num_classes=2, samples_per_class=6, seed=0,
                     path=str(path))
    assert ds.x.shape == (12, 28, 28, 1)
    assert float(ds.x.max()) <= 1.0  # uint8 cache rescaled
    with pytest.raises(ValueError, match="classes"):
        load_emnist(num_classes=10, samples_per_class=6, path=str(path))


def test_emnist_dataspec_train_end_to_end():
    from repro.fl import mlp_classifier
    from repro.scenario import (DataSpec, LearningSpec, NetworkSpec,
                                Scenario, ScenarioSuite, StrategySpec)

    scn = Scenario(
        network=NetworkSpec(mu_c=[1.0, 2.0, 1.5], mu_d=[2.0] * 3,
                            mu_u=[2.0] * 3),
        learning=LearningSpec(consts=CONSTS),
        strategy=StrategySpec("asyncsgd"),
        data=DataSpec(dataset="emnist", num_classes=4,
                      samples_per_class=12))
    back = Scenario.from_json(scn.to_json())
    assert back == scn and back.data.dataset == "emnist"
    model = mlp_classifier(28 * 28, 4, hidden=(8,))
    res = ScenarioSuite(scn, seeds=(0,)).run(
        mode="train", model=model, horizon_time=12.0, batch_size=8,
        eval_every_time=6.0)
    log = res.entries[list(res.entries)[0]][0]
    assert log.updates[-1] > 0 and np.isfinite(log.losses).all()
