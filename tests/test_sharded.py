"""Multi-device lane sharding (``repro.sim.sharded``).

Bitwise contract: lanes are lane-local programs, so ``shard_map`` over the
lane axis only changes WHERE a lane runs — ``backend="sharded"`` equals
``backend="batched"`` lane-by-lane at ANY device count.  In-process tests
run at whatever the process device count is (1 on plain CPU; the CI
multi-device leg forces 8 with ``--xla_force_host_platform_device_count``);
the subprocess test always exercises a real 8-device mesh plus the
non-divisible lane-padding path.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.buzen import NetworkParams
from repro.sim.batched_events import simulate_stats_lanes
from repro.sim.sharded import device_count

ROOT = os.path.join(os.path.dirname(__file__), "..")


def random_params(seed, n, with_cs=False):
    rng = np.random.default_rng(seed)
    params = NetworkParams(
        p=jnp.asarray(rng.dirichlet(np.ones(n) * 2.0)),
        mu_c=jnp.asarray(rng.uniform(0.5, 4.0, n)),
        mu_d=jnp.asarray(rng.uniform(0.5, 4.0, n)),
        mu_u=jnp.asarray(rng.uniform(0.5, 4.0, n)))
    return params.with_cs(1.5) if with_cs else params


def assert_trees_equal(a, b):
    for x, y in zip(jax.tree_util.tree_leaves(a),
                    jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_sharded_backend_registered():
    from repro.sim.backend import BACKENDS

    assert "sharded" in BACKENDS


@pytest.mark.parametrize("with_cs", [False, True])
def test_sharded_lanes_bitwise_vs_batched(with_cs):
    lanes = [random_params(s, 6, with_cs) for s in range(5)]
    ms = [3, 4, 5, 3, 4]
    kw = dict(warmup=50, m_max=5, seeds=range(5))
    a = simulate_stats_lanes(lanes, ms, 200, backend="batched", **kw)
    b = simulate_stats_lanes(lanes, ms, 200, backend="sharded", **kw)
    assert_trees_equal(a, b)


def test_sharded_class_lanes_bitwise_vs_batched():
    from repro.core.buzen import ClassParams
    from repro.sim.batched_events import build_class_lanes_fn, stack_lanes

    def mk(seed):
        rng = np.random.default_rng(seed)
        cnt = np.array([3, 2, 5])
        p = rng.dirichlet(np.ones(3))
        return ClassParams(p=jnp.asarray(p / cnt), mu_c=jnp.asarray(
            rng.uniform(0.5, 4.0, 3)),
            mu_d=jnp.asarray(rng.uniform(2.0, 6.0, 3)),
            mu_u=jnp.asarray(rng.uniform(2.0, 6.0, 3)),
            count=jnp.asarray(cnt))

    lane_classes = stack_lanes([mk(s) for s in range(4)])
    m_vec = jnp.asarray([3, 4, 5, 3], jnp.int32)
    keys = jnp.stack([jax.random.PRNGKey(s) for s in range(4)])
    fb = build_class_lanes_fn("batched", 200, 50, "exponential", 5, False)
    fs = build_class_lanes_fn("sharded", 200, 50, "exponential", 5, False)
    assert_trees_equal(fb(lane_classes, m_vec, keys, None),
                       fs(lane_classes, m_vec, keys, None))


def test_sharded_suite_bitwise_vs_batched():
    from repro.scenario import NetworkSpec, Scenario, ScenarioSuite
    from repro.scenario.spec import ClusterSpec, LearningSpec

    rows = (ClusterSpec("A", 1.0, 6.0, 6.0, 3),
            ClusterSpec("B", 2.0, 7.0, 7.0, 3))
    base = Scenario(network=NetworkSpec.from_clusters(rows),
                    learning=LearningSpec())
    mk = lambda: ScenarioSuite(base.with_strategy("asyncsgd", m=4),
                               seeds=(0, 1, 2))
    ra = mk().run(mode="simulate", num_updates=200, warmup=50,
                  backend="batched")
    rb = mk().run(mode="simulate", num_updates=200, warmup=50,
                  backend="sharded")
    for k in ra.entries:
        for a, b in zip(ra.entries[k], rb.entries[k]):
            assert_trees_equal(a, b)


def test_class_lanes_pallas_backend_rejected():
    from repro.sim.batched_events import build_class_lanes_fn

    with pytest.raises(ValueError, match="pallas"):
        build_class_lanes_fn("pallas", 100, 0, "exponential", 4, False)


_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=8")
import jax
import jax.numpy as jnp
import numpy as np
from repro.core.buzen import NetworkParams
from repro.sim.batched_events import simulate_stats_lanes
from repro.sim.sharded import device_count

assert device_count() == 8, device_count()

def mk(seed, n=6):
    rng = np.random.default_rng(seed)
    return NetworkParams(
        p=jnp.asarray(rng.dirichlet(np.ones(n) * 2.0)),
        mu_c=jnp.asarray(rng.uniform(0.5, 4.0, n)),
        mu_d=jnp.asarray(rng.uniform(0.5, 4.0, n)),
        mu_u=jnp.asarray(rng.uniform(0.5, 4.0, n)))

# L=5 is NOT a multiple of 8: exercises the repeat-last-lane padding
lanes = [mk(s) for s in range(5)]
ms = [3, 4, 5, 3, 4]
kw = dict(warmup=30, m_max=5, seeds=range(5))
a = simulate_stats_lanes(lanes, ms, 120, backend="batched", **kw)
b = simulate_stats_lanes(lanes, ms, 120, backend="sharded", **kw)
for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
print("OK devices=8 bitwise")
"""


def test_sharded_eight_devices_subprocess():
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _CHILD],
                         capture_output=True, text=True, timeout=560,
                         env=env, cwd=ROOT)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "OK devices=8 bitwise" in out.stdout


def test_device_count_positive():
    assert device_count() >= 1
