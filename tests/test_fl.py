"""FL engine: data pipeline, optimizers, async trainer end-to-end."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import LearningConstants, NetworkParams
from repro.data import (dirichlet_partition, iid_partition,
                        make_language_modeling_dataset,
                        make_synthetic_image_dataset, pathological_partition)
from repro.fl import (AsyncFLConfig, AsyncFLTrainer, build_network_params,
                      cnn_classifier, make_strategies, mlp_classifier)
from repro.fl.strategies import PAPER_CLUSTERS_TABLE1, build_power_profile
from repro.optim import adafactor, adamw, apply_updates, momentum, sgd


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_synthetic_dataset_shapes_and_balance():
    ds = make_synthetic_image_dataset(num_classes=10, samples_per_class=20,
                                      seed=0)
    assert ds.x.shape == (200, 28, 28, 1)
    assert ds.x.min() >= 0 and ds.x.max() <= 1
    counts = np.bincount(ds.y, minlength=10)
    assert np.all(counts == 20)


def test_partitions_cover_and_disjoint():
    ds = make_synthetic_image_dataset(num_classes=10, samples_per_class=30)
    for parts in (iid_partition(ds.y, 7), dirichlet_partition(ds.y, 7, 0.2),
                  pathological_partition(ds.y, 7, 3)):
        allidx = np.concatenate(parts)
        assert len(allidx) == len(ds.y)
        assert len(np.unique(allidx)) == len(ds.y)


def test_dirichlet_is_skewed_vs_iid():
    ds = make_synthetic_image_dataset(num_classes=10, samples_per_class=100)
    iid = iid_partition(ds.y, 10, seed=1)
    dir_ = dirichlet_partition(ds.y, 10, alpha=0.2, seed=1)

    def skew(parts):
        # mean TV distance between client label dist and global dist
        tv = []
        for part in parts:
            h = np.bincount(ds.y[part], minlength=10) / len(part)
            tv.append(0.5 * np.abs(h - 0.1).sum())
        return np.mean(tv)

    assert skew(dir_) > 3 * skew(iid)


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("opt", [sgd(0.1), momentum(0.1), adamw(0.05),
                                 adafactor(0.05)])
def test_optimizers_minimize_quadratic(opt):
    params = {"w": jnp.array([[3.0, -2.0], [1.0, 4.0]]), "b": jnp.array([5.0])}
    state = opt.init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)

    l0 = float(loss(params))
    for _ in range(200):
        grads = jax.grad(loss)(params)
        updates, state = opt.update(grads, state, params)
        params = apply_updates(params, updates)
    assert float(loss(params)) < 0.05 * l0


# ---------------------------------------------------------------------------
# async FL training end-to-end
# ---------------------------------------------------------------------------

def _small_setup(n_clients=6, non_iid=False, seed=0):
    from repro.data import train_test_split
    full = make_synthetic_image_dataset(num_classes=8, samples_per_class=75,
                                        seed=seed)
    ds, test = train_test_split(full, 0.2, seed=seed + 1)
    if non_iid:
        parts = dirichlet_partition(ds.y, n_clients, alpha=0.2, seed=seed)
    else:
        parts = iid_partition(ds.y, n_clients, seed=seed)
    clients = [(ds.x[idx], ds.y[idx]) for idx in parts]
    rng = np.random.default_rng(seed)
    net = NetworkParams(
        p=jnp.full((n_clients,), 1.0 / n_clients),
        mu_c=jnp.asarray(rng.uniform(0.5, 5.0, n_clients)),
        mu_d=jnp.asarray(rng.uniform(1.0, 8.0, n_clients)),
        mu_u=jnp.asarray(rng.uniform(1.0, 8.0, n_clients)))
    return clients, (test.x, test.y), net


def test_async_training_learns():
    clients, test, net = _small_setup()
    model = mlp_classifier(28 * 28, 8, hidden=(64,))
    tr = AsyncFLTrainer(model, clients, net, m=6,
                        config=AsyncFLConfig(eta=0.1, batch_size=32,
                                             eval_every_time=50.0, seed=0),
                        test_data=test)
    log = tr.run(horizon_time=150.0)
    assert log.accuracies[-1] > 0.5        # well above 1/8 chance
    assert log.losses[-1] < log.losses[0]
    assert log.throughput > 0
    # staleness identity (Eq. 7): sum_i p_i E0[R_i] = m - 1; mean_delay is
    # the unscaled per-client conditional mean, matching SimStats.mean_delay
    p = np.asarray(net.p)
    assert abs(np.sum(p * log.mean_delay) - (6 - 1)) < 1.5


def test_async_training_nonexponential():
    clients, test, net = _small_setup(seed=2)
    model = mlp_classifier(28 * 28, 8, hidden=(32,))
    for dist in ("deterministic", "lognormal"):
        tr = AsyncFLTrainer(model, clients, net, m=4,
                            config=AsyncFLConfig(eta=0.1, batch_size=32,
                                                 eval_every_time=100.0,
                                                 distribution=dist, seed=1),
                            test_data=test)
        log = tr.run(horizon_time=100.0)
        assert np.isfinite(log.losses).all()


def test_bias_correction_unbiased_updates():
    """With the 1/(n p_i) scaling, the *expected* aggregate drift equals the
    global gradient direction even under skewed routing: train with a very
    non-uniform p on non-IID data and check the model still learns all
    classes (rather than collapsing to fast clients' classes)."""
    clients, test, net = _small_setup(n_clients=6, non_iid=True, seed=3)
    p = np.array([0.4, 0.25, 0.15, 0.1, 0.06, 0.04])
    net = net._replace(p=jnp.asarray(p))
    model = mlp_classifier(28 * 28, 8, hidden=(64,))
    tr = AsyncFLTrainer(model, clients, net, m=6,
                        config=AsyncFLConfig(eta=0.05, batch_size=32,
                                             eval_every_time=100.0, seed=0),
                        test_data=test)
    log = tr.run(horizon_time=250.0)
    assert log.accuracies[-1] > 0.4


def test_trainer_delay_matches_simulator():
    """Trainer-side and simulator-side mean-delay estimates agree exactly on
    the same seed (regression: the trainer used to report p_i-scaled values
    while AsyncNetworkSim.run reported unscaled conditional means)."""
    from repro.core.simulator import AsyncNetworkSim

    clients, test, net = _small_setup(seed=5)
    model = mlp_classifier(28 * 28, 8, hidden=(16,))
    K = 400
    # pinned to the host reference loop: the assertion replays the exact
    # numpy RNG stream of AsyncNetworkSim (the device engine only agrees in
    # distribution, see tests/test_events.py)
    tr = AsyncFLTrainer(model, clients, net, m=5,
                        config=AsyncFLConfig(eta=0.05, batch_size=16,
                                             eval_every_time=1e9, seed=7,
                                             backend="host"))
    log = tr.run(horizon_time=1e9, max_updates=K)
    # the trainer's break happens after next_update() has applied one more
    # event to the sim statistics, hence K + 1 below
    sim = AsyncNetworkSim(net, 5, seed=7)
    stats = sim.run(K + 1)
    np.testing.assert_allclose(log.mean_delay, stats.mean_delay,
                               rtol=1e-12, atol=1e-12)


def test_simstats_zero_updates_guarded():
    """run(0) must not divide by a zero horizon."""
    from repro.core.simulator import AsyncNetworkSim

    rng = np.random.default_rng(0)
    net = NetworkParams(p=jnp.full((3,), 1 / 3),
                        mu_c=jnp.asarray(rng.uniform(0.5, 2.0, 3)),
                        mu_d=jnp.asarray(rng.uniform(0.5, 2.0, 3)),
                        mu_u=jnp.asarray(rng.uniform(0.5, 2.0, 3)))
    stats = AsyncNetworkSim(net, 2, seed=0).run(0)
    assert stats.throughput == 0.0
    assert np.isfinite(stats.throughput)


def test_eval_grid_uses_pre_update_snapshot():
    """Grid times strictly before an update event must log the pre-update
    parameters: with one eval point between update k and k+1, the logged
    update counter at that grid time is k, not k+1."""
    clients, test, net = _small_setup(seed=6)
    model = mlp_classifier(28 * 28, 8, hidden=(16,))
    # host backend: the grid check below replays the same-seed event times
    # of AsyncNetworkSim
    tr = AsyncFLTrainer(model, clients, net, m=3,
                        config=AsyncFLConfig(eta=0.05, batch_size=16,
                                             eval_every_time=0.25, seed=3,
                                             backend="host"),
                        test_data=test)
    log = tr.run(horizon_time=30.0, max_updates=200)
    sim = __import__("repro.core.simulator", fromlist=["AsyncNetworkSim"]) \
        .AsyncNetworkSim(net, 3, seed=3)
    # replay the event times: the update count logged at grid time t must be
    # the number of updates with ev.time <= t
    times = []
    for _ in range(200):
        ev = sim.next_update()
        sim.dispatch_next()
        times.append(ev.time)
    times = np.asarray(times)
    for t, k in zip(log.times[:-1], log.updates[:-1]):  # last entry is at horizon
        assert k == int(np.sum(times <= t)), (t, k, int(np.sum(times <= t)))


def test_cnn_forward():
    model = cnn_classifier(28, 10)
    params = model.init(jax.random.PRNGKey(0))
    x = jnp.zeros((4, 28, 28, 1))
    logits = model.apply(params, x)
    assert logits.shape == (4, 10)
    assert np.isfinite(np.asarray(logits)).all()


def test_strategies_factory_small():
    net = build_network_params(PAPER_CLUSTERS_TABLE1, scale=20)
    power = build_power_profile(PAPER_CLUSTERS_TABLE1, scale=20)
    consts = LearningConstants(L=1, delta=1, sigma=1, M=2, G=5, eps=1)
    strat = make_strategies(net, consts, power, steps=120, m_max=net.n + 4,
                            which=("asyncsgd", "max_throughput", "round_opt",
                                   "time_opt", "energy_opt"))
    n = net.n
    for name, (p, m) in strat.items():
        assert p.shape == (n,)
        assert abs(p.sum() - 1) < 1e-6
        assert 1 <= m <= net.n + 8
    assert strat["energy_opt"][1] == 1
