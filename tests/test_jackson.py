"""Theorem 2 / Prop 4 closed forms: brute-force, autodiff and simulation checks."""
import itertools
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (NetworkParams, delay_jacobian, expected_relative_delay,
                        second_moment_matrix, throughput, throughput_grad)
from repro.core.buzen import log_normalizing_constants
from repro.core.simulator import AsyncNetworkSim, jump_chain_throughput


def random_params(rng, n, with_cs=False):
    p = rng.dirichlet(np.ones(n))
    params = NetworkParams(
        p=jnp.asarray(p),
        mu_c=jnp.asarray(rng.uniform(0.2, 8.0, n)),
        mu_d=jnp.asarray(rng.uniform(0.2, 8.0, n)),
        mu_u=jnp.asarray(rng.uniform(0.2, 8.0, n)),
    )
    if with_cs:
        params = params.with_cs(rng.uniform(0.5, 8.0))
    return params


# ---------------------------------------------------------------------------
# brute-force oracle over the embedded stationary distribution pi_{n, m-1}
# ---------------------------------------------------------------------------

def _enumerate_states(S, m):
    for comp in itertools.combinations(range(m + S - 1), S - 1):
        prev = -1
        xs = []
        for c in comp:
            xs.append(c - prev - 1)
            prev = c
        xs.append(m + S - 2 - prev)
        yield xs


def brute_force_moments(params, m):
    """Exact E[S_i], E[S_i S_j] under pi_{n, m-1} by enumeration (no CS)."""
    n = params.n
    p = np.asarray(params.p)
    mu_c = np.asarray(params.mu_c)
    mu_d = np.asarray(params.mu_d)
    mu_u = np.asarray(params.mu_u)
    loads = np.concatenate([p / mu_c, p / mu_d, p / mu_u])
    is_is = np.array([False] * n + [True] * (2 * n))
    pop = m - 1
    Z = 0.0
    mean = np.zeros(n)
    second = np.zeros((n, n))
    for xs in _enumerate_states(3 * n, pop):
        xs = np.asarray(xs)
        w = np.prod(loads**xs)
        for s in range(3 * n):
            if is_is[s]:
                w /= math.factorial(xs[s])
        S_i = xs[:n] + xs[n:2 * n] + xs[2 * n:]
        Z += w
        mean += w * S_i
        second += w * np.outer(S_i, S_i)
    return mean / Z, second / Z


@pytest.mark.parametrize("n,m", [(2, 2), (2, 4), (3, 3), (3, 5)])
def test_moments_vs_enumeration(n, m):
    rng = np.random.default_rng(n * 10 + m)
    params = random_params(rng, n)
    mean_bf, second_bf = brute_force_moments(params, m)
    d = np.asarray(expected_relative_delay(params, m))
    s = np.asarray(second_moment_matrix(params, m))
    np.testing.assert_allclose(d, mean_bf, rtol=1e-9)
    np.testing.assert_allclose(s, second_bf, rtol=1e-9)


# ---------------------------------------------------------------------------
# invariants and gradients
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(2, 6), st.integers(1, 12), st.integers(0, 10_000),
       st.booleans())
def test_total_delay_identity(n, m, seed, with_cs):
    """Eq. (7): sum_i E0[D_i] = m - 1, for any p, mu (and with CS buffer)."""
    rng = np.random.default_rng(seed)
    params = random_params(rng, n, with_cs)
    d = expected_relative_delay(params, m)
    assert float(jnp.sum(d)) == pytest.approx(m - 1, abs=1e-8)


@pytest.mark.parametrize("with_cs", [False, True])
@pytest.mark.parametrize("m", [2, 3, 7])
def test_delay_jacobian_matches_autodiff(with_cs, m):
    """Closed-form covariance Jacobian (Eq. 4 / 22) == jax.jacobian."""
    rng = np.random.default_rng(42 + m)
    params = random_params(rng, 5, with_cs)
    J = delay_jacobian(params, m)
    J_ad = jax.jacobian(
        lambda p: expected_relative_delay(params._replace(p=p), m))(params.p)
    np.testing.assert_allclose(np.asarray(J), np.asarray(J_ad),
                               rtol=1e-8, atol=1e-10)


@pytest.mark.parametrize("with_cs", [False, True])
def test_throughput_grad_matches_autodiff(with_cs):
    rng = np.random.default_rng(3)
    params = random_params(rng, 4, with_cs)
    m = 6
    g = throughput_grad(params, m)
    g_ad = jax.grad(lambda p: throughput(params._replace(p=p), m))(params.p)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ad),
                               rtol=1e-8, atol=1e-12)


def test_m_equals_one_no_staleness():
    """m = 1 is serial SGD: all relative delays are zero (Section 4.2)."""
    rng = np.random.default_rng(0)
    params = random_params(rng, 4)
    d = np.asarray(expected_relative_delay(params, 1))
    np.testing.assert_allclose(d, 0.0, atol=1e-12)


def test_delay_nondecreasing_in_m():
    """E0[D_i] is non-decreasing in m (Section 3.3 via [55, Lemma 2])."""
    rng = np.random.default_rng(5)
    params = random_params(rng, 3)
    prev = np.zeros(3)
    for m in range(1, 10):
        d = np.asarray(expected_relative_delay(params, m))
        assert np.all(d >= prev - 1e-9)
        prev = d


def test_cs_limit_recovers_base_model():
    """mu_cs -> inf recovers Theorem 2 from Theorem 7 (Section 7.3)."""
    rng = np.random.default_rng(11)
    params = random_params(rng, 4)
    m = 5
    d_base = np.asarray(expected_relative_delay(params, m))
    d_cs = np.asarray(expected_relative_delay(params.with_cs(1e9), m))
    np.testing.assert_allclose(d_cs, d_base, rtol=1e-6)
    J_base = np.asarray(delay_jacobian(params, m))
    J_cs = np.asarray(delay_jacobian(params.with_cs(1e9), m))
    np.testing.assert_allclose(J_cs, J_base, rtol=1e-5, atol=1e-7)


# ---------------------------------------------------------------------------
# simulation cross-checks (Monte Carlo tolerance)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("with_cs", [False, True])
def test_simulator_matches_theory(with_cs):
    rng = np.random.default_rng(8)
    n, m = 4, 6
    params = random_params(rng, n, with_cs)
    sim = AsyncNetworkSim(params, m, seed=123)
    stats = sim.run(120_000, warmup=15_000)
    p = np.asarray(params.p)
    d_sim = p * stats.mean_delay  # E0[D_i] = p_i E0[R_i] (proof of Thm 2)
    d_th = np.asarray(expected_relative_delay(params, m))
    np.testing.assert_allclose(d_sim, d_th, rtol=0.06, atol=0.02)
    np.testing.assert_allclose(stats.throughput, float(throughput(params, m)),
                               rtol=0.03)


def test_jump_chain_matches_throughput():
    rng = np.random.default_rng(9)
    params = random_params(rng, 3)
    m = 5
    lam, occ = jump_chain_throughput(params, m, 150_000, seed=1)
    np.testing.assert_allclose(lam, float(throughput(params, m)), rtol=0.05)
    # total occupancy must equal m at all times (closed network)
    np.testing.assert_allclose(occ.sum(), m, rtol=1e-6)


def test_nonexponential_distributions_run():
    rng = np.random.default_rng(10)
    params = random_params(rng, 3)
    for dist in ["deterministic", "lognormal"]:
        sim = AsyncNetworkSim(params, 4, distribution=dist, seed=0)
        stats = sim.run(5_000, warmup=500)
        assert stats.throughput > 0
        assert np.isfinite(stats.mean_delay).all()
