"""The observability subsystem (``repro.obs``).

Contracts under test:

* **bitwise non-invasive** — with telemetry rings on, simulation
  statistics and training trajectories are bit-identical to rings off,
  on every sim backend (the traced scan is a separate program; the
  untraced one is untouched);
* ring wraparound keeps exactly the most recent records, in order;
* the Perfetto exporter emits the golden schema pinned by
  ``tests/data/trace_schema.json`` and a consistent span decomposition;
* the drift monitor accepts a healthy smoke-scale run, flags a
  corrupted ring, and restricts itself to conservation off the
  product-form domain;
* the serve layer exposes the shared registry (``metrics`` verb), a
  drift summary (``stats``), and ``repro.serve.metrics`` stays a
  backward-compatible shim over ``repro.obs.metrics``.
"""
import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.buzen import NetworkParams
from repro.obs.rings import (EventRing, decode, decode_lane,
                             event_ring_append, event_ring_init,
                             update_ring_append, update_ring_init)

DATA_DIR = os.path.join(os.path.dirname(__file__), "data")


def _net(n=3, seed=0):
    rng = np.random.default_rng(seed)
    return NetworkParams(
        p=jnp.asarray(rng.dirichlet(np.ones(n))),
        mu_c=jnp.asarray(rng.uniform(0.5, 4.0, n)),
        mu_d=jnp.asarray(rng.uniform(2.0, 6.0, n)),
        mu_u=jnp.asarray(rng.uniform(2.0, 6.0, n)))


def _tree_bitwise_equal(a, b) -> bool:
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb))


# ---------------------------------------------------------------------------
# rings (unit)
# ---------------------------------------------------------------------------

def _append_n(ring, k, t0=0.0):
    for i in range(k):
        ring = event_ring_append(
            ring, time=t0 + i, station=i % 5, station_to=(i + 1) % 5,
            kind=i % 4, slot=i % 3, client=i % 2, delay=i, update=i % 2)
    return ring


def test_event_ring_wraparound_keeps_latest_in_order():
    ring = _append_n(event_ring_init(8), 12)
    dec = decode(ring)
    assert dec["count"] == 12 and dec["capacity"] == 8
    assert dec["dropped"] == 4
    np.testing.assert_array_equal(dec["time"], np.arange(4.0, 12.0))
    np.testing.assert_array_equal(dec["delay"], np.arange(4, 12))


def test_event_ring_not_full_decodes_prefix():
    dec = decode(_append_n(event_ring_init(8), 5))
    assert dec["count"] == 5 and dec["dropped"] == 0
    np.testing.assert_array_equal(dec["time"], np.arange(5.0))


def test_event_ring_capacity_zero_is_static_noop():
    ring = event_ring_init(0)
    out = _append_n(ring, 3)
    assert out is ring  # the append is DCE'd before jax ever runs
    dec = decode(ring)
    assert dec["count"] == 0 and dec["capacity"] == 0
    assert dec["time"].shape == (0,)


def test_ring_append_valid_gate_blocks_record_and_count():
    ring = event_ring_init(4)
    ring = event_ring_append(ring, time=1.0, station=0, station_to=1,
                             kind=0, slot=0, client=0, delay=0, update=1,
                             valid=jnp.asarray(False))
    assert int(ring.count) == 0
    ring = event_ring_append(ring, time=2.0, station=0, station_to=1,
                             kind=0, slot=0, client=0, delay=0, update=1,
                             valid=jnp.asarray(True))
    dec = decode(ring)
    assert dec["count"] == 1
    np.testing.assert_array_equal(dec["time"], [2.0])


def test_update_ring_roundtrip_dtypes():
    ring = update_ring_init(4)
    ring = update_ring_append(ring, time=1.5, client=2, staleness=3,
                              grad_norm=0.25, snapshot_age=0.5)
    dec = decode(ring)
    assert dec["time"].dtype == np.float64
    assert dec["staleness"].dtype == np.int32
    np.testing.assert_allclose(dec["grad_norm"], [0.25])


def test_ring_append_inside_jit_and_decode_lane():
    @jax.jit
    def fill(_):
        ring = event_ring_init(4)
        for i in range(3):
            ring = event_ring_append(
                ring, time=float(i), station=i, station_to=i + 1, kind=0,
                slot=i, client=i, delay=i, update=0)
        return ring

    stacked = jax.vmap(fill)(jnp.arange(2))
    dec = decode_lane(stacked, 1)
    assert dec["count"] == 3
    np.testing.assert_array_equal(dec["slot"], [0, 1, 2])


# ---------------------------------------------------------------------------
# bitwise non-invasiveness (the padding-contract-style property)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["reference", "batched", "pallas",
                                     "sharded"])
def test_simulate_stats_bitwise_with_rings_on(backend):
    from repro.sim.batched_events import simulate_stats_lanes

    params = [_net(3, seed=1), _net(3, seed=2)]
    kw = dict(warmup=20, m_max=3, backend=backend,
              interpret=True if backend == "pallas" else None)
    base = simulate_stats_lanes(params, [2, 3], 150, **kw)
    traced, rings = simulate_stats_lanes(params, [2, 3], 150,
                                         trace_events=256, **kw)
    assert _tree_bitwise_equal(base, traced)
    for lane in range(2):
        dec = decode_lane(rings, lane)
        assert dec["count"] > 0
        assert np.all(np.diff(dec["time"]) >= 0)  # chronological


def test_trainer_bitwise_with_update_ring_on():
    from repro.fl.engine import DeviceTrainer
    from repro.fl.models import mlp_classifier
    from repro.fl.trainer import AsyncFLConfig

    rng = np.random.default_rng(5)
    n = 3
    net = _net(n, seed=5)
    clients = [(rng.normal(size=(6, 4)).astype(np.float32),
                rng.integers(0, 2, size=6).astype(np.int32))
               for _ in range(n)]
    test = (rng.normal(size=(8, 4)).astype(np.float32),
            rng.integers(0, 2, size=8).astype(np.int32))
    model = mlp_classifier(4, 2, hidden=(4,))
    cfg = AsyncFLConfig(eta=0.05, batch_size=2, eval_every_time=2.0)

    def run(trace_updates):
        tr = DeviceTrainer(model, clients, net, cfg, test_data=test,
                           trace_updates=trace_updates)
        ps = jnp.stack([jnp.asarray(net.p)] * 2)
        logs, _ = tr.run_lanes(ps, [2, 2], [0.05, 0.05], [0, 1], 8.0)
        return logs, tr.last_update_rings

    base_logs, base_rings = run(0)
    traced_logs, rings = run(128)
    assert base_rings is None and rings is not None
    assert len(base_logs) == len(traced_logs)
    for a, b in zip(base_logs, traced_logs):  # TrainLog is not a pytree
        for field in a.__dataclass_fields__:
            assert _tree_bitwise_equal(getattr(a, field),
                                       getattr(b, field)), field
    dec = decode(rings[0])
    assert dec["count"] > 0
    assert np.all(dec["staleness"] >= 0)
    assert np.all(dec["grad_norm"] > 0)
    assert np.all(dec["snapshot_age"] >= 0)


def test_suite_simulate_traced_bitwise_and_cache_roundtrip():
    from repro.scenario import (NetworkSpec, Scenario, ScenarioSuite,
                                SimSpec, TraceSpec)

    rng = np.random.default_rng(7)
    n = 3
    net = NetworkSpec(mu_c=list(rng.uniform(0.8, 1.2, n)),
                      mu_d=[4.0] * n, mu_u=[4.0] * n)
    plain = Scenario(network=net, name="s")
    traced = Scenario(network=net, name="s",
                      sim=SimSpec(trace=TraceSpec(events=1024)))
    r0 = ScenarioSuite({"s": plain}, seeds=(0, 1)).run(
        mode="simulate", num_updates=300, warmup=30)
    suite = ScenarioSuite({"s": traced}, seeds=(0, 1))
    r1 = suite.run(mode="simulate", num_updates=300, warmup=30)
    assert r0.traces is None and r0.drift is None
    assert _tree_bitwise_equal(r0.entries["s"], r1.entries["s"])
    assert len(r1.traces["s"]) == 2 and len(r1.drift["s"]) == 2
    assert all(r["ok"] for r in r1.drift["s"])
    # cache hit must round-trip traces and drift too
    r2 = suite.run(mode="simulate", num_updates=300, warmup=30)
    assert r2.cache_hits == 1
    assert _tree_bitwise_equal(r1.traces["s"], r2.traces["s"])
    assert r2.drift["s"] == r1.drift["s"]


def test_suite_traces_class_network():
    """Class rings (per-class station indexing) through ScenarioSuite.run:
    traced class lanes return stats bitwise equal to the untraced run,
    decoded rings, and drift reports whose delay predictions are folded
    onto the class axis."""
    from repro.scenario import (ClassSpec, NetworkSpec, Scenario,
                                ScenarioSuite, SimSpec, StrategySpec,
                                TraceSpec)

    cls = ClassSpec(mu_c=[1.0, 2.0], mu_d=[4.0, 4.0], mu_u=[4.0, 4.0],
                    count=[3, 2])
    scn = Scenario(
        network=NetworkSpec(classes=cls),
        strategy=StrategySpec("explicit", p=[0.1, 0.1], m=2))
    traced = scn.replace(sim=SimSpec(trace=TraceSpec(events=2048)))
    r0 = ScenarioSuite({"c": scn}, seeds=(0, 1)).run(
        mode="simulate", num_updates=400, warmup=40)
    suite = ScenarioSuite({"c": traced}, seeds=(0, 1))
    r1 = suite.run(mode="simulate", num_updates=400, warmup=40)
    assert r0.traces is None and r0.drift is None
    assert _tree_bitwise_equal(r0.entries["c"], r1.entries["c"])
    assert len(r1.traces["c"]) == 2 and len(r1.drift["c"]) == 2
    C = 2
    for dec, rep in zip(r1.traces["c"], r1.drift["c"]):
        # the "client" channel carries the CLASS index in class lanes
        assert int(np.asarray(dec["client"]).max()) < C
        delays = [c for c in rep["checks"] if c["metric"] == "staleness"]
        assert delays and all(r["ok"] for r in rep["checks"]
                              if r["metric"] == "occupancy")
    # cache hit round-trips traces and drift
    r2 = suite.run(mode="simulate", num_updates=400, warmup=40)
    assert r2.cache_hits == 1
    assert _tree_bitwise_equal(r1.traces["c"], r2.traces["c"])
    assert r2.drift["c"] == r1.drift["c"]


def test_tracespec_roundtrip_and_hash_stability():
    from repro.scenario import (NetworkSpec, Scenario, SimSpec, TraceSpec)

    net = NetworkSpec(mu_c=[1.0, 2.0], mu_d=[3.0] * 2, mu_u=[3.0] * 2)
    plain = Scenario(network=net)
    traced = Scenario(network=net,
                      sim=SimSpec(trace=TraceSpec(events=64, updates=32,
                                                  tolerance=0.1)))
    # absent-when-unset: pre-obs hashes must not move
    assert "trace" not in SimSpec().to_dict()
    assert plain.hash() != traced.hash()
    rt = Scenario.from_dict(traced.to_dict())
    assert rt.hash() == traced.hash()
    assert rt.trace.events == 64 and rt.trace.updates == 32
    assert rt.trace.tolerance == 0.1
    with pytest.raises(ValueError):
        TraceSpec(events=-1)


# ---------------------------------------------------------------------------
# trace export
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def traced_lane():
    from repro.sim.batched_events import simulate_stats_lanes

    _, rings = simulate_stats_lanes([_net(3, seed=11)], [3], 200,
                                    warmup=20, trace_events=1024,
                                    backend="batched")
    return decode_lane(rings, 0)


def test_station_spans_partition_the_window(traced_lane):
    from repro.obs.trace import station_spans

    spans = station_spans(traced_lane)
    assert spans
    t1 = float(traced_lane["time"][-1])
    per_slot: dict = {}
    for s in spans:
        assert s["duration"] >= 0
        per_slot.setdefault(s["slot"], []).append(s)
    # per slot: contiguous coverage of [0, t1] (no ring wrap here)
    for slot, ss in per_slot.items():
        ss.sort(key=lambda s: s["start"])
        assert ss[0]["start"] == 0.0
        for a, b in zip(ss, ss[1:]):
            assert a["start"] + a["duration"] == pytest.approx(b["start"])
        last = ss[-1]
        assert last["start"] + last["duration"] == pytest.approx(t1)
    assert len(per_slot) == 3  # every in-flight slot shows up (m = 3)


def test_station_occupancy_sums_to_m(traced_lane):
    from repro.obs.trace import station_occupancy

    occ = station_occupancy(traced_lane, 3)
    assert occ.shape == (3 * 3 + 1,)
    assert float(occ.sum()) == pytest.approx(3.0, rel=1e-6)


def test_station_label_layout():
    from repro.obs.trace import station_label

    assert station_label(0, 3) == "down/0"
    assert station_label(4, 3) == "comp/1"
    assert station_label(8, 3) == "up/2"
    assert station_label(9, 3) == "cs"


_SCHEMA_TYPES = {"str": str, "int": int, "number": (int, float),
                 "bool": bool, "any": object}


def _check_schema(spec, value, path="doc"):
    if isinstance(spec, str):
        assert isinstance(value, _SCHEMA_TYPES[spec]), \
            f"{path}: {value!r} is not {spec}"
        if spec in ("int", "number"):
            assert not isinstance(value, bool), f"{path}: bool is not {spec}"
    elif isinstance(spec, list):
        assert isinstance(value, list), f"{path}: {type(value)} != list"
        for i, item in enumerate(value):
            _check_schema(spec[0], item, f"{path}[{i}]")
    elif isinstance(spec, dict):
        assert isinstance(value, dict), f"{path}: {type(value)} != dict"
        if "__each__" in spec:
            for k, v in value.items():
                _check_schema(spec["__each__"], v, f"{path}.{k}")
        else:
            missing = set(spec) - set(value)
            extra = set(value) - set(spec)
            assert not missing, f"{path}: missing keys {sorted(missing)}"
            assert not extra, f"{path}: extra keys {sorted(extra)}"
            for k in spec:
                _check_schema(spec[k], value[k], f"{path}.{k}")


def test_perfetto_trace_matches_golden_schema(traced_lane):
    from repro.obs.trace import perfetto_trace

    with open(os.path.join(DATA_DIR, "trace_schema.json")) as fh:
        golden = json.load(fh)
    doc = perfetto_trace(traced_lane, 3)
    _check_schema(golden, doc)
    json.dumps(doc)  # must serialize without a custom encoder
    phases = {e["ph"] for e in doc["traceEvents"]}
    assert phases == {"M", "X", "i"}
    # updates are instants at their span's end
    upd = [e for e in doc["traceEvents"] if e["ph"] == "i"]
    assert upd and all(e["name"] == "update" for e in upd)


def test_perfetto_trace_carries_host_and_compile_tracks(traced_lane):
    from repro.obs.trace import (PID_HOST, TID_COMPILES, TID_HOST_SPANS,
                                 perfetto_trace)

    host = [{"name": "suite.dispatch", "labels": {"mode": "simulate"},
             "start": 100.0, "duration": 0.5}]
    compiles = [("lanes", 100.8, 0.3)]
    doc = perfetto_trace(traced_lane, 3, host_spans=host,
                         compile_spans=compiles,
                         metadata={"extra": 1})
    rows = [e for e in doc["traceEvents"]
            if e["pid"] == PID_HOST and e["ph"] == "X"]
    tids = {e["tid"] for e in rows}
    assert tids == {TID_HOST_SPANS, TID_COMPILES}
    # both tracks rebased to the common earliest start (host at 100.0)
    assert min(e["ts"] for e in rows) == 0.0
    comp = next(e for e in rows if e["tid"] == TID_COMPILES)
    assert comp["ts"] == pytest.approx((100.8 - 0.3 - 100.0) * 1e6)
    assert doc["metadata"]["extra"] == 1


# ---------------------------------------------------------------------------
# drift monitors
# ---------------------------------------------------------------------------

def test_drift_report_accepts_healthy_run(traced_lane):
    from repro.obs.drift import drift_report

    rep = drift_report(traced_lane, params=_net(3, seed=11), m=3)
    assert rep["ok"], rep
    assert {c["metric"] for c in rep["checks"]} == {"throughput",
                                                    "staleness",
                                                    "occupancy"}
    occ = next(c for c in rep["checks"] if c["metric"] == "occupancy")
    assert occ["rel_err"] == pytest.approx(0.0, abs=1e-9)  # conservation


def test_drift_report_flags_corrupted_ring(traced_lane):
    from repro.obs.drift import drift_report

    bad = dict(traced_lane)
    bad["time"] = np.asarray(bad["time"]) * 3.0  # clock stretched 3x
    rep = drift_report(bad, params=_net(3, seed=11), m=3)
    assert not rep["ok"]
    thr = next(c for c in rep["checks"] if c["metric"] == "throughput")
    assert not thr["ok"] and thr["rel_err"] > 0.25


def test_drift_non_exponential_law_keeps_conservation_only(traced_lane):
    from repro.obs.drift import drift_report

    rep = drift_report(traced_lane, params=_net(3, seed=11), m=3,
                       law="lognormal")
    assert [c["metric"] for c in rep["checks"]] == ["occupancy"]
    assert rep["ok"]


def test_drift_report_needs_predictions_or_params():
    from repro.obs.drift import drift_report

    with pytest.raises(ValueError, match="predictions"):
        drift_report({"time": np.zeros(0)})


def test_predict_delays_profile_sums_to_m_minus_one():
    from repro.obs.drift import predict

    preds = predict(_net(4, seed=3), 5)
    # conservation identity: sum_i E0[D_i] = m - 1 for any timing law
    assert sum(preds["delays"]) == pytest.approx(4.0, rel=1e-9)
    assert preds["occupancy"] == 5.0


# ---------------------------------------------------------------------------
# metrics / serve integration
# ---------------------------------------------------------------------------

def test_serve_metrics_module_is_a_shim():
    import repro.obs.metrics as obs_metrics
    import repro.serve.metrics as serve_metrics

    assert serve_metrics.Metrics is obs_metrics.Metrics
    assert serve_metrics.Histogram is obs_metrics.Histogram


def test_prometheus_exposition_format():
    from repro.obs.metrics import Metrics

    m = Metrics()
    m.inc("serve.requests", mode="simulate")
    m.inc("serve.requests", mode="simulate")
    m.observe("suite.dispatch", 0.5, mode="simulate")
    text = m.exposition()
    lines = text.splitlines()
    assert "# TYPE serve_requests counter" in lines
    assert 'serve_requests{mode="simulate"} 2.0' in lines
    assert "# TYPE suite_dispatch summary" in lines
    assert any(l.startswith('suite_dispatch{mode="simulate",quantile="0.5"}')
               for l in lines)
    assert 'suite_dispatch_count{mode="simulate"} 1' in lines
    # every sample line is NAME{LABELS} VALUE or NAME VALUE
    for line in lines:
        if line.startswith("#") or not line:
            continue
        name, _, value = line.rpartition(" ")
        float(value)
        assert name and " " not in name.split("{")[0]


def test_metrics_records_spans_for_the_host_track():
    from repro.obs.metrics import Metrics

    m = Metrics()
    with m.timed("suite.plan", mode="simulate"):
        pass
    rows = m.spans()
    assert rows and rows[0]["name"] == "suite.plan"
    assert rows[0]["labels"] == {"mode": "simulate"}
    assert rows[0]["duration"] >= 0.0


def test_server_metrics_verb_and_drift_stats(tmp_path):
    import time as _time

    from repro.scenario import (NetworkSpec, Scenario, SimSpec, TraceSpec)
    from repro.serve.client import ServeClient
    from repro.serve.server import ServeConfig, Server

    sock = str(tmp_path / "obs.sock")
    server = Server(ServeConfig(socket_path=sock, max_wait=0.05))
    server.start()
    try:
        _time.sleep(0.1)
        rng = np.random.default_rng(13)
        scn = Scenario(
            network=NetworkSpec(mu_c=list(rng.uniform(0.8, 1.2, 2)),
                                mu_d=[4.0] * 2, mu_u=[4.0] * 2),
            sim=SimSpec(trace=TraceSpec(events=512)))
        with ServeClient(sock, timeout=300) as c:
            c.run(scn, mode="simulate", seeds=(0,), num_updates=200,
                  warmup=20)
            st = c.stats()
            assert st["drift"]["checked"] == 1
            assert st["drift"]["breaches"] == 0
            assert st["drift"]["last"]["ok"] is True
            text = c.metrics()
        assert "# TYPE serve_requests counter" in text
        assert 'serve_requests{mode="simulate"} 1.0' in text
    finally:
        server.stop()


# ---------------------------------------------------------------------------
# the CLI (smoke -> check -> report round-trip)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_obs_cli_roundtrip(tmp_path, capsys):
    from repro.obs.__main__ import main

    out = str(tmp_path / "trace.json")
    assert main(["smoke", "--out", out, "--updates", "600",
                 "--warmup", "60", "--seeds", "1"]) == 0
    assert main(["check", out]) == 0
    assert main(["report", out]) == 0
    doc = json.load(open(out))
    assert doc["metadata"]["ring_data"]
    assert all(r["ok"] for r in doc["metadata"]["drift"])
    # tamper with the embedded ring: check must re-verify, not trust
    doc["metadata"]["ring_data"]["time"] = [
        t * 3.0 for t in doc["metadata"]["ring_data"]["time"]]
    bad = str(tmp_path / "bad.json")
    json.dump(doc, open(bad, "w"))
    capsys.readouterr()
    assert main(["check", bad]) == 1
