"""The unified declarative Scenario API (spec -> registries -> suite).

Covers the acceptance criteria of the Scenario redesign:
  * registries: decorator registration, duplicate guard, unknown keys raise
    listing the registered options (incl. eager ``AsyncFLConfig`` /
    ``make_sampler`` / ``simulate_stats`` validation);
  * serialization: ``from_dict(to_dict(s))`` round-trips **bitwise** for
    every registered law x strategy x objective, JSON-safely;
  * the hyperexponential timing law: correct mean/SCV on both engines and
    host-vs-device distributional agreement end-to-end;
  * ``pruned_concurrency_sweep`` == full batched sweep on small grids with
    fewer evaluated rows;
  * ``ScenarioSuite``: ``simulate`` runs S scenarios x R seeds in fewer
    compiled programs than scenarios AND bitwise-matches per-lane
    ``simulate_stats``; ``analyze`` matches the static closed forms;
    ``train`` matches ``run_strategy_grid`` on the same lanes;
  * every registered benchmark scenario (``benchmarks/scenarios.py``)
    round-trips and builds its spec without any jax dispatch.
"""
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))  # benchmarks

from repro.core import (LearningConstants, NetworkParams,
                        batched_concurrency_sweep, expected_relative_delay,
                        make_time_objective_padded, pruned_concurrency_sweep,
                        round_complexity, simulate_stats, throughput,
                        wallclock_time)
from repro.core.simulator import AsyncNetworkSim, make_sampler
from repro.scenario import (EXPLICIT, EnergySpec, LearningSpec, NetworkSpec,
                            OBJECTIVES, ObjectiveSpec, Registry, Scenario,
                            ScenarioSuite, StrategySpec, TIMING_LAWS,
                            get_law, law_names)

CONSTS = LearningConstants(M=2.0, G=5.0)


def small_network(n=4, seed=0, *, law="exponential", with_cs=False,
                  with_p=False):
    rng = np.random.default_rng(seed)
    return NetworkSpec(
        mu_c=rng.uniform(0.5, 6.0, n), mu_d=rng.uniform(0.5, 6.0, n),
        mu_u=rng.uniform(0.5, 6.0, n),
        p=rng.dirichlet(np.ones(n)) if with_p else None,
        mu_cs=float(rng.uniform(1.0, 4.0)) if with_cs else None, law=law)


# ---------------------------------------------------------------------------
# registries
# ---------------------------------------------------------------------------

def test_registry_unknown_key_lists_options():
    r = Registry("widget")
    r.register("alpha")(object())
    r.register("beta")(object())
    with pytest.raises(ValueError, match="alpha.*beta") as e:
        r.get("gamma")
    assert "widget" in str(e.value)


def test_registry_duplicate_registration_raises():
    r = Registry("thing")
    r.register("x")(object())
    with pytest.raises(ValueError, match="already registered"):
        r.register("x")(object())


def test_partitions_registered_by_name():
    from repro.data import dirichlet_partition  # triggers registration
    from repro.scenario import PARTITIONS

    assert {"iid", "dirichlet", "pathological"} <= set(PARTITIONS.names())
    assert PARTITIONS.get("dirichlet") is dirichlet_partition


def test_eager_validation_everywhere():
    # spec construction
    with pytest.raises(ValueError, match="hyperexponential"):
        small_network(law="weibull")
    with pytest.raises(ValueError, match="time_opt"):
        StrategySpec("frobnicate")
    with pytest.raises(ValueError, match="joint"):
        ObjectiveSpec("frobnicate")
    # trainer config (used to fail only inside the first jit trace)
    from repro.fl import AsyncFLConfig

    with pytest.raises(ValueError, match="registered service distributions"):
        AsyncFLConfig(distribution="weibull")
    # host sampler + device engine entry points
    with pytest.raises(ValueError, match="distribution"):
        make_sampler("weibull", np.random.default_rng(0))
    with pytest.raises(ValueError, match="distribution"):
        simulate_stats(small_network(3).params(), 3, 10,
                       distribution="weibull")


def test_explicit_strategy_requires_p_and_m():
    with pytest.raises(ValueError, match="explicit"):
        StrategySpec(EXPLICIT, m=3)


# ---------------------------------------------------------------------------
# serialization: bitwise round-trip over the full registry cross-product
# ---------------------------------------------------------------------------

def _scenario_for(law, strat_name, obj_name, seed):
    rng = np.random.default_rng(seed)
    n = 4
    net = small_network(n, seed, law=law, with_cs=bool(seed % 2),
                       with_p=True)
    energy = EnergySpec(kappa=rng.uniform(0.1, 2.0, n),
                        P_u=rng.uniform(0.5, 3.0, n),
                        P_d=rng.uniform(0.5, 3.0, n))
    if strat_name == EXPLICIT:
        strat = StrategySpec(EXPLICIT, p=rng.dirichlet(np.ones(n)), m=3)
    else:
        strat = StrategySpec(strat_name, steps=17, m_max=n + 3,
                             search="pruned")
    return Scenario(
        network=net,
        learning=LearningSpec(consts=LearningConstants(
            *rng.uniform(0.5, 3.0, 6)), eta=float(rng.uniform(0.01, 0.1)),
            grad_clip=5.0),
        energy=energy, strategy=strat,
        objective=ObjectiveSpec(obj_name, rho=float(rng.uniform())),
        name=f"rt_{law}_{strat_name}_{obj_name}")


def test_roundtrip_bitwise_all_laws_strategies_objectives():
    from repro.scenario import STRATEGIES

    seed = 0
    for law in law_names():
        for strat_name in tuple(STRATEGIES.names()) + (EXPLICIT,):
            for obj_name in OBJECTIVES.names():
                seed += 1
                s = _scenario_for(law, strat_name, obj_name, seed)
                s2 = Scenario.from_json(s.to_json())
                assert s2 == s, (law, strat_name, obj_name)
                assert s2.hash() == s.hash()
                # bitwise, not approximate: JSON floats are repr-exact
                np.testing.assert_array_equal(s2.network.mu_c,
                                              s.network.mu_c)
                np.testing.assert_array_equal(
                    np.asarray(s2.params().p), np.asarray(s.params().p))


def test_from_dict_unknown_registry_keys_raise_with_options():
    s = _scenario_for("exponential", "time_opt", "time", 99)
    d = json.loads(s.to_json())
    bad = json.loads(json.dumps(d))
    bad["network"]["law"] = "weibull"
    with pytest.raises(ValueError, match="registered service distributions"):
        Scenario.from_dict(bad)
    bad = json.loads(json.dumps(d))
    bad["strategy"]["name"] = "nope"
    with pytest.raises(ValueError, match="registered strategies"):
        Scenario.from_dict(bad)
    bad = json.loads(json.dumps(d))
    bad["objective"]["name"] = "nope"
    with pytest.raises(ValueError, match="registered objectives"):
        Scenario.from_dict(bad)


def test_hash_ignores_cosmetic_name():
    """Identical physics must hash equal regardless of the display name —
    renames must not sever the BENCH_smoke.json trajectory."""
    a = _scenario_for("exponential", "time_opt", "time", 7)
    b = a.replace(name="totally-different-label")
    assert a.hash() == b.hash()
    c = a.replace(strategy=StrategySpec("time_opt", steps=18, m_max=7,
                                        search="pruned"))
    assert c.hash() != a.hash()  # physical fields still count


def test_eta_defaults_follow_strategy():
    net = small_network(3)
    assert Scenario(network=net, strategy=StrategySpec(
        "max_throughput")).eta() == pytest.approx(0.01)
    assert Scenario(network=net).eta() == pytest.approx(0.05)
    s = Scenario(network=net, learning=LearningSpec(eta=0.123),
                 strategy=StrategySpec("max_throughput"))
    assert s.eta() == pytest.approx(0.123)


# ---------------------------------------------------------------------------
# hyperexponential law: moments + host-vs-device end-to-end
# ---------------------------------------------------------------------------

def test_hyperexponential_moments_host_and_device():
    mu = 2.5
    N = 60_000
    # host sampler
    sampler = make_sampler("hyperexponential", np.random.default_rng(0))
    xs = np.array([sampler(mu) for _ in range(N)])
    assert xs.mean() == pytest.approx(1.0 / mu, rel=0.05)
    scv = xs.var() / xs.mean() ** 2
    assert scv == pytest.approx(4.0, rel=0.15)
    # device draw
    law = get_law("hyperexponential")
    ys = np.asarray(law.device_draw(jax.random.PRNGKey(1),
                                    jnp.asarray(mu), (N,)))
    assert ys.mean() == pytest.approx(1.0 / mu, rel=0.05)
    assert ys.var() / ys.mean() ** 2 == pytest.approx(4.0, rel=0.15)
    # positive-rate guard matches the other laws
    with pytest.raises(ValueError, match="positive"):
        sampler(0.0)


def test_hyperexponential_agrees_with_host_reference():
    """Same tolerances as the det/lognormal cross-checks in test_events."""
    net = small_network(3, seed=10, law="hyperexponential")
    params = net.params()
    m = 4
    st = simulate_stats(params, m, 10_000, warmup=1_000, seed=0,
                        distribution="hyperexponential")
    host = AsyncNetworkSim(params, m, distribution="hyperexponential",
                           seed=0).run(10_000, warmup=1_000)
    np.testing.assert_allclose(float(st.throughput), host.throughput,
                               rtol=0.06)
    np.testing.assert_allclose(np.asarray(st.mean_delay), host.mean_delay,
                               rtol=0.15, atol=0.1)
    assert np.isfinite(np.asarray(st.mean_delay)).all()


# ---------------------------------------------------------------------------
# pruned concurrency search vs the full batched sweep
# ---------------------------------------------------------------------------

def test_pruned_sweep_matches_full_on_small_grid():
    net = small_network(6, seed=3)
    params = net.params()
    m_max = 20
    obj = make_time_objective_padded(params, CONSTS, m_max)
    grid = jnp.arange(2, m_max + 1)
    full = batched_concurrency_sweep(obj, params, m_grid=grid, m_max=m_max,
                                     steps=250)
    pruned = pruned_concurrency_sweep(obj, params, m_grid=grid, m_max=m_max,
                                      steps=250)
    assert pruned.best.m == full.best.m
    np.testing.assert_allclose(pruned.best.value, full.best.value, rtol=1e-6)
    assert len(pruned.values) < len(full.values)  # actually pruned
    # tiny grids fall back to the full sweep
    tiny = pruned_concurrency_sweep(obj, params, m_grid=jnp.arange(2, 7),
                                    m_max=m_max, steps=50)
    assert len(tiny.values) == 5


def test_pruned_sweep_defaults_m_max_from_objective():
    """Regression: the refine window's smaller grid max must not trip the
    padding guard when the caller omits m_max."""
    net = small_network(4, seed=6)
    params = net.params()
    obj = make_time_objective_padded(params, CONSTS, 20)
    res = pruned_concurrency_sweep(obj, params, m_grid=jnp.arange(2, 21),
                                   steps=30)
    assert 2 <= res.best.m <= 20


def test_pruned_search_through_time_optimal_and_strategy_spec():
    from repro.core import time_optimal
    from repro.scenario import resolve_strategy

    net = small_network(5, seed=4)
    params = net.params()
    full = time_optimal(params, CONSTS, m_max=14, steps=200)
    pruned = time_optimal(params, CONSTS, m_max=14, steps=200,
                          search="pruned")
    assert pruned.m == full.m
    np.testing.assert_allclose(pruned.value, full.value, rtol=1e-6)
    # and via the declarative spec
    scn = Scenario(network=net, learning=LearningSpec(consts=CONSTS),
                   strategy=StrategySpec("time_opt", steps=200, m_max=14,
                                         search="pruned"))
    p, m = resolve_strategy(scn)
    assert m == full.m
    # warm-started refinement: same optimum to optimizer tolerance
    np.testing.assert_allclose(p, np.asarray(full.p), atol=1e-4)
    np.testing.assert_allclose(
        float(wallclock_time(params._replace(p=jnp.asarray(p)), m, CONSTS)),
        full.value, rtol=1e-6)


# ---------------------------------------------------------------------------
# ScenarioSuite: bucketed dispatch
# ---------------------------------------------------------------------------

def _explicit_suite(seeds=(0, 1)):
    """Three structurally-alike scenarios (explicit strategies: no
    optimizer cost) differing in routing and concurrency."""
    rng = np.random.default_rng(5)
    net = small_network(4, seed=5)
    scns = {}
    for i, m in enumerate((3, 5, 4)):
        scns[f"s{i}"] = Scenario(
            network=net, learning=LearningSpec(consts=CONSTS),
            strategy=StrategySpec(EXPLICIT, p=rng.dirichlet(np.ones(4)),
                                  m=m))
    return ScenarioSuite(scns, seeds=seeds)


def test_suite_simulate_fewer_programs_and_bitwise_vs_singles():
    suite = _explicit_suite(seeds=(0, 3))
    res = suite.run(mode="simulate", num_updates=300, warmup=50)
    assert res.programs < len(suite) == 3
    assert res.lanes == 6
    m_max = max(m for _, m in suite.resolve().values())
    for name, (p, m) in suite.resolve().items():
        for seed, got in zip(suite.seeds, res.entries[name]):
            want = simulate_stats(
                suite.scenarios[name].params(p), m, 300, warmup=50,
                key=jax.random.PRNGKey(seed), m_max=m_max)
            for field in ("throughput", "mean_delay", "energy", "time",
                          "mean_queue_counts"):
                np.testing.assert_array_equal(
                    np.asarray(getattr(got, field)),
                    np.asarray(getattr(want, field)),
                    err_msg=f"{name}/{seed}/{field}")


def test_suite_simulate_buckets_mixed_laws_separately():
    suite = _explicit_suite(seeds=(0,))
    import dataclasses

    mixed = dict(suite.scenarios)
    mixed["hyper"] = mixed["s0"].replace(network=dataclasses.replace(
        mixed["s0"].network, law="hyperexponential"))
    suite2 = ScenarioSuite(mixed, seeds=(0,))
    res = suite2.run(mode="simulate", num_updates=120)
    assert res.programs == 2  # one per law bucket, still < 4 scenarios
    assert set(res.entries) == set(mixed)


def test_suite_simulate_rejects_undersized_m_max():
    suite = _explicit_suite(seeds=(0,))  # largest resolved m is 5
    with pytest.raises(ValueError, match="m_max"):
        suite.run(mode="simulate", num_updates=50, m_max=3)


def test_with_strategy_explicit_freezes_resolved_eta():
    """Regression: pinning max_throughput's resolved (p, m) as an explicit
    strategy must keep its 20x-reduced step size."""
    net = small_network(3, seed=9)
    scn = Scenario(network=net, strategy=StrategySpec("max_throughput"))
    pinned = scn.with_strategy(EXPLICIT, p=np.full(3, 1 / 3), m=2)
    assert pinned.eta() == pytest.approx(0.01)
    # an explicit learning-spec eta still wins
    scn2 = Scenario(network=net, learning=LearningSpec(eta=0.2),
                    strategy=StrategySpec("max_throughput"))
    assert scn2.with_strategy(EXPLICIT, p=np.full(3, 1 / 3),
                              m=2).eta() == pytest.approx(0.2)


def test_analyze_value_none_when_objective_lacks_power():
    """An energy objective without an EnergySpec must not report tau as
    its 'value'."""
    net = small_network(3, seed=12)
    scn = Scenario(network=net, learning=LearningSpec(consts=CONSTS),
                   strategy=StrategySpec(EXPLICIT, p=np.full(3, 1 / 3),
                                         m=2),
                   objective=ObjectiveSpec("energy"))
    res = ScenarioSuite({"e": scn}).run(mode="analyze")
    assert res.entries["e"]["value"] is None
    assert res.entries["e"]["energy"] is None
    assert np.isfinite(res.entries["e"]["tau"])


def test_resolve_cache_not_shared_across_energy_specs():
    """Regression: two joint scenarios on the same network but different
    power profiles must not reuse each other's e_star normalizer."""
    rng = np.random.default_rng(13)
    net = small_network(3, seed=13)
    e1 = EnergySpec(kappa=rng.uniform(0.1, 1.0, 3),
                    P_u=rng.uniform(1, 3, 3), P_d=rng.uniform(1, 3, 3))
    e2 = EnergySpec(kappa=e1.kappa * 40.0, P_u=e1.P_u, P_d=e1.P_d)
    mk = lambda e: Scenario(
        network=net, learning=LearningSpec(consts=CONSTS), energy=e,
        strategy=StrategySpec("joint", steps=60, m_max=5),
        objective=ObjectiveSpec("joint", rho=0.9))
    suite = ScenarioSuite({"cheap": mk(e1), "hot": mk(e2)})
    strat = suite.resolve()
    alone = ScenarioSuite({"hot": mk(e2)}).resolve()["hot"]
    assert strat["hot"][1] == alone[1]
    np.testing.assert_allclose(strat["hot"][0], alone[0], atol=1e-12)


def test_suite_analyze_matches_static_closed_forms():
    suite = _explicit_suite(seeds=(0,))
    res = suite.run(mode="analyze")
    assert res.programs == 1
    for name, (p, m) in suite.resolve().items():
        ent = res.entries[name]
        params = suite.scenarios[name].params(p)
        np.testing.assert_allclose(ent["throughput"],
                                   float(throughput(params, m)), rtol=1e-10)
        np.testing.assert_allclose(ent["K_eps"],
                                   float(round_complexity(params, m, CONSTS)),
                                   rtol=1e-10)
        np.testing.assert_allclose(ent["tau"],
                                   float(wallclock_time(params, m, CONSTS)),
                                   rtol=1e-10)
        np.testing.assert_allclose(
            ent["delays"], np.asarray(expected_relative_delay(params, m)),
            rtol=1e-10, atol=1e-12)


def test_suite_train_matches_run_strategy_grid():
    """The suite's train mode is the same fused engine as
    run_strategy_grid: identical lanes -> identical logs."""
    from repro.data import make_synthetic_image_dataset, iid_partition
    from repro.fl import mlp_classifier, run_strategy_grid

    rng = np.random.default_rng(8)
    net = small_network(3, seed=8)
    full = make_synthetic_image_dataset(num_classes=4, samples_per_class=24,
                                        image_size=8, seed=8)
    parts = iid_partition(full.y, 3, seed=8)
    clients = [(full.x[i], full.y[i]) for i in parts]
    model = mlp_classifier(8 * 8, 4, hidden=(8,))
    strategies = {"a": (np.full(3, 1 / 3), 3),
                  "b": (rng.dirichlet(np.ones(3)), 2)}

    scns = {name: Scenario(network=net,
                           learning=LearningSpec(consts=CONSTS, eta=0.05),
                           strategy=StrategySpec(EXPLICIT, p=p, m=m))
            for name, (p, m) in strategies.items()}
    suite = ScenarioSuite(scns, seeds=(0, 1))
    res = suite.run(mode="train", model=model, clients=clients,
                    test_data=(full.x, full.y), horizon_time=6.0,
                    batch_size=8, eval_every_time=2.0)

    from repro.fl import AsyncFLConfig

    cfg = AsyncFLConfig(eta=0.05, batch_size=8, eval_every_time=2.0)
    grid = run_strategy_grid(model, clients, net.params(), strategies, cfg,
                             horizon_time=6.0, seeds=(0, 1), etas=0.05,
                             test_data=(full.x, full.y))
    for name in strategies:
        for got, want in zip(res.entries[name], grid.logs[name]):
            assert got.times == want.times
            assert got.losses == want.losses
            np.testing.assert_array_equal(got.mean_delay, want.mean_delay)
            assert got.throughput == want.throughput


# ---------------------------------------------------------------------------
# benchmark scenarios: registered specs round-trip and build trace-free
# ---------------------------------------------------------------------------

def test_bench_scenarios_roundtrip_and_build_without_tracing(tracecheck):
    from benchmarks.scenarios import BENCH_SCENARIOS

    assert len(BENCH_SCENARIOS) >= 8
    rebuilt = {}
    with tracecheck.forbid("spec round-trip must not touch the compiler"):
        for name, scn in BENCH_SCENARIOS.items():
            s2 = Scenario.from_json(scn.to_json())
            assert s2 == scn, name
            assert s2.hash() == scn.hash()
            rebuilt[name] = s2
    # materialization is eager and well-formed (tiny convert ops only)
    for name, scn in rebuilt.items():
        params = scn.params()
        assert params.p.shape == (scn.n,)
        assert float(jnp.sum(params.p)) == pytest.approx(1.0)
        if scn.energy is not None:
            prof = scn.power()
            assert prof.P_c.shape == (scn.n,)


def test_stack_structurally_identical_scenarios():
    """Alike scenarios stack leaf-wise into one vmap-ready pytree; mixed
    static structure is rejected (that's the suite's bucketing job)."""
    from repro.scenario import stack

    rng = np.random.default_rng(11)
    base = small_network(4, seed=11)
    scns = [Scenario(network=dataclasses_replace_p(base, rng.dirichlet(
        np.ones(4))), learning=LearningSpec(consts=CONSTS))
        for _ in range(3)]
    batched = stack(scns)
    assert batched.network.mu_c.shape == (3, 4)
    assert batched.network.p.shape == (3, 4)
    with pytest.raises(ValueError, match="mixed static structure"):
        stack([scns[0], scns[0].replace(network=small_network(
            4, seed=11, law="lognormal"))])


def dataclasses_replace_p(net, p):
    import dataclasses

    return dataclasses.replace(net, p=p)


def test_suite_serialization_roundtrip():
    suite = _explicit_suite(seeds=(0, 2))
    d = json.loads(json.dumps(suite.to_dict()))
    back = ScenarioSuite.from_dict(d)
    assert back.seeds == suite.seeds
    assert set(back.scenarios) == set(suite.scenarios)
    for k in suite.scenarios:
        assert back.scenarios[k] == suite.scenarios[k]
