"""Batched (p, m) optimizer engine vs the static per-m reference paths.

Covers the acceptance criteria of the batched-sweep refactor:
  * padded closed forms == static closed forms for every m;
  * batched sweep rows == per-m ``optimize_routing`` (n=4, m <= 8);
  * batched sweep optimum == seed sequential warm-start search on a
    reference n=8 network (values within 1e-6 relative);
  * ONE trace of the objective per sweep — no per-m recompilation;
  * batched Pallas Buzen kernel == ``repro.core.buzen`` in interpret mode,
    including the gradient (custom-VJP through the float64 reference).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (LearningConstants, NetworkParams, PowerProfile,
                        batch_log_normalizing_constants,
                        batched_concurrency_sweep, energy_complexity,
                        energy_complexity_padded, expected_relative_delay,
                        expected_relative_delay_padded, joint_optimal,
                        log_normalizing_constants, make_round_objective,
                        make_time_objective, make_time_objective_padded,
                        optimize_routing, round_complexity,
                        round_complexity_padded, make_round_objective_padded,
                        sequential_concurrency_search, throughput,
                        throughput_padded, wallclock_time,
                        wallclock_time_padded)


def reference_params(rng, n, with_cs=False):
    p = rng.dirichlet(np.ones(n))
    params = NetworkParams(
        p=jnp.asarray(p),
        mu_c=jnp.asarray(rng.uniform(0.3, 8.0, n)),
        mu_d=jnp.asarray(rng.uniform(0.3, 8.0, n)),
        mu_u=jnp.asarray(rng.uniform(0.3, 8.0, n)))
    if with_cs:
        params = params.with_cs(rng.uniform(0.5, 8.0))
    return params


CONSTS = LearningConstants(M=2.0, G=5.0)


# ---------------------------------------------------------------------------
# padded closed forms == static closed forms
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("with_cs", [False, True])
def test_padded_forms_match_static(with_cs):
    rng = np.random.default_rng(3)
    params = reference_params(rng, 5, with_cs)
    m_max = 9
    logZ = log_normalizing_constants(params, m_max)
    power = PowerProfile.from_dvfs(
        jnp.asarray(rng.uniform(0.1, 2.0, 5)), params.mu_c,
        jnp.asarray(rng.uniform(1.0, 5.0, 5)),
        jnp.asarray(rng.uniform(1.0, 5.0, 5)))
    for m in range(1, m_max + 1):
        mt = jnp.asarray(m)
        np.testing.assert_allclose(
            np.asarray(expected_relative_delay_padded(params, mt, logZ, m_max)),
            np.asarray(expected_relative_delay(params, m)), rtol=1e-10,
            atol=1e-12)
        np.testing.assert_allclose(
            float(throughput_padded(logZ, mt)),
            float(throughput(params, m)), rtol=1e-10)
        np.testing.assert_allclose(
            float(round_complexity_padded(params, mt, CONSTS, logZ, m_max)),
            float(round_complexity(params, m, CONSTS)), rtol=1e-10)
        np.testing.assert_allclose(
            float(wallclock_time_padded(params, mt, CONSTS, logZ, m_max)),
            float(wallclock_time(params, m, CONSTS)), rtol=1e-10)
        np.testing.assert_allclose(
            float(energy_complexity_padded(params, mt, CONSTS, power, logZ,
                                           m_max)),
            float(energy_complexity(params, m, CONSTS, power)), rtol=1e-10)


def test_padded_gradients_finite_at_m1():
    """The masked staleness sqrt must have a finite gradient at m = 1."""
    rng = np.random.default_rng(4)
    params = reference_params(rng, 4)
    logZ = log_normalizing_constants(params, 4)

    def f(p):
        return round_complexity_padded(params._replace(p=p), jnp.asarray(1),
                                       CONSTS, logZ, 4)

    g = jax.grad(f)(params.p)
    assert np.all(np.isfinite(np.asarray(g)))


# ---------------------------------------------------------------------------
# batched sweep rows == per-m optimize_routing (n=4, m <= 8)
# ---------------------------------------------------------------------------

def test_sweep_rows_match_per_m_optimize_routing():
    rng = np.random.default_rng(11)
    n, m_hi, steps = 4, 8, 300
    params = reference_params(rng, n)
    obj_static = make_time_objective(params, CONSTS)
    sweep = batched_concurrency_sweep(
        make_time_objective_padded(params, CONSTS, m_hi), params,
        m_grid=jnp.arange(1, m_hi + 1), steps=steps)
    for b, m in enumerate(range(1, m_hi + 1)):
        ref = optimize_routing(obj_static, n, m, steps=steps)
        np.testing.assert_allclose(sweep.values[b], ref.value, rtol=1e-6)
        np.testing.assert_allclose(np.asarray(sweep.p[b]), np.asarray(ref.p),
                                   atol=1e-6)


def test_sweep_round_objective_matches():
    rng = np.random.default_rng(12)
    n, m = 4, 6
    params = reference_params(rng, n)
    ref = optimize_routing(make_round_objective(params, CONSTS), n, m,
                           steps=250)
    got = batched_concurrency_sweep(
        make_round_objective_padded(params, CONSTS, m), params,
        m_grid=jnp.asarray([m]), steps=250).best
    np.testing.assert_allclose(got.value, ref.value, rtol=1e-6)


# ---------------------------------------------------------------------------
# batched sweep == seed sequential search (reference n=8 network)
# ---------------------------------------------------------------------------

def test_sweep_matches_sequential_search_n8():
    rng = np.random.default_rng(42)
    n = 8
    params = reference_params(rng, n)
    m_max = n + 8
    seq = sequential_concurrency_search(
        make_time_objective(params, CONSTS), n, m_start=2, m_max=m_max,
        steps=400)
    bat = batched_concurrency_sweep(
        make_time_objective_padded(params, CONSTS, m_max), params,
        m_grid=jnp.arange(2, m_max + 1), steps=400).best
    assert bat.m == seq.m
    np.testing.assert_allclose(bat.value, seq.value, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(bat.p), np.asarray(seq.p),
                               atol=1e-6)


def test_joint_optimal_batched_matches_sequential():
    rng = np.random.default_rng(13)
    n = 4
    params = reference_params(rng, n)
    power = PowerProfile.from_dvfs(
        jnp.asarray(rng.uniform(0.1, 2.0, n)), params.mu_c,
        jnp.asarray(rng.uniform(1.0, 5.0, n)),
        jnp.asarray(rng.uniform(1.0, 5.0, n)))
    kw = dict(m_max=n + 4, steps=250)
    seq = joint_optimal(params, CONSTS, power, 0.3, 10.0, 100.0,
                        search="sequential", patience=100, **kw)
    bat = joint_optimal(params, CONSTS, power, 0.3, 10.0, 100.0, **kw)
    assert bat.m == seq.m
    np.testing.assert_allclose(bat.value, seq.value, rtol=1e-6)


# ---------------------------------------------------------------------------
# no per-m recompilation: ONE trace of the objective per sweep
# ---------------------------------------------------------------------------

def test_sweep_traces_objective_once(tracecheck):
    rng = np.random.default_rng(5)
    n, m_hi = 4, 8
    params = reference_params(rng, n)
    counted = tracecheck.counting(
        make_time_objective_padded(params, CONSTS, m_hi))
    batched_concurrency_sweep(counted, params,
                              m_grid=jnp.arange(1, m_hi + 1), steps=30)
    # scan + value_and_grad trace the loss a few times, plus one final
    # row_values evaluation — but never once per m (the B=8 grid rows all
    # share a single vmapped trace)
    assert counted.traces < m_hi, \
        f"objective traced {counted.traces}x for B={m_hi}"


# ---------------------------------------------------------------------------
# batched Pallas kernel vs core reference (interpret mode)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("with_cs", [False, True])
def test_batch_logZ_pallas_matches_jnp(with_cs):
    rng = np.random.default_rng(21)
    n, m_max, B = 6, 14, 5
    params = reference_params(rng, n, with_cs)
    ps = jnp.asarray(rng.dirichlet(np.ones(n), size=B))
    want = batch_log_normalizing_constants(params, ps, m_max, backend="jnp")
    got = batch_log_normalizing_constants(params, ps, m_max, backend="pallas")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-5,
                               atol=3e-5)


@pytest.mark.parametrize("with_cs", [False, True])
def test_single_logZ_pallas_dispatch(with_cs):
    rng = np.random.default_rng(22)
    params = reference_params(rng, 7, with_cs)
    want = np.asarray(log_normalizing_constants(params, 11))
    got = np.asarray(log_normalizing_constants(params, 11, backend="pallas"))
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


def test_pallas_backend_rejects_literal_method():
    rng = np.random.default_rng(24)
    params = reference_params(rng, 3)
    with pytest.raises(ValueError, match="aggregate"):
        log_normalizing_constants(params, 4, method="literal",
                                  backend="pallas")


def test_pallas_backend_gradient_matches_reference():
    """custom-VJP: grads through the Pallas forward equal the float64 path."""
    rng = np.random.default_rng(23)
    n, m_max = 5, 8
    params = reference_params(rng, n)

    def val(p, backend):
        logZ = batch_log_normalizing_constants(params, p[None], m_max,
                                               backend=backend)[0]
        return wallclock_time_padded(params._replace(p=p), jnp.asarray(m_max),
                                     CONSTS, logZ, m_max)

    g_ref = jax.grad(lambda p: val(p, "jnp"))(params.p)
    g_pal = jax.grad(lambda p: val(p, "pallas"))(params.p)
    np.testing.assert_allclose(np.asarray(g_pal), np.asarray(g_ref),
                               rtol=2e-3, atol=1e-5)
