"""Dry-run integration: the 512-device production mesh lowers + compiles.

Runs in a subprocess (the forced device count must precede jax init).
One representative combo per step kind keeps this in CI budget; the full
10 x 4 x 2 matrix is exercised by ``python -m repro.launch.dryrun`` and
recorded in EXPERIMENTS.md.
"""
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")

# 512-device lowering + compile in a child interpreter: minutes each
pytestmark = pytest.mark.slow


@pytest.mark.parametrize("arch,shape", [
    ("internlm2-1.8b", "decode_32k"),
    ("xlstm-350m", "long_500k"),
])
def test_dryrun_single_pod(arch, shape):
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--mesh", "single", "--out",
         "/tmp/test_dryrun_out"],
        capture_output=True, text=True, timeout=560, env=env, cwd=ROOT)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "[ok]" in out.stdout


def test_dryrun_multi_pod_one_combo():
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "whisper-medium", "--shape", "decode_32k", "--mesh", "multi",
         "--out", "/tmp/test_dryrun_out"],
        capture_output=True, text=True, timeout=560, env=env, cwd=ROOT)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "2x16x16" in out.stdout or "[ok]" in out.stdout
