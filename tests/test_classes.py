"""Client-class aggregation: O(#classes) forms vs the expanded oracle.

The contract (``repro.core.buzen.ClassParams`` / ``repro.core.batched``
class forms / the class-aggregated event engine in ``repro.core.events``):

  * closed forms agree with the padded per-client forms evaluated on
    ``classes.expand()`` to f64 roundoff (the DP fold order differs, so
    the two representations are not bitwise against each other);
  * everything is **bitwise** invariant to class padding
    (``pad_classes`` count-0 classes), mirroring the traced-``n``
    convention of ``tests/test_padded_n.py``;
  * the class event engine matches the expanded per-client engine
    distributionally (the PRNG key-split trees differ, so trajectories
    are not comparable draw-by-draw);
  * the Scenario layer round-trips ``ClassSpec`` and plans class suites
    against the same numbers as the expanded per-client suites.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.batched import (energy_complexity_classes,
                                energy_complexity_padded,
                                expected_relative_delay_classes,
                                expected_relative_delay_padded,
                                round_complexity_classes,
                                round_complexity_padded, throughput_padded,
                                wallclock_time_classes)
from repro.core.buzen import (ClassParams, class_log_normalizing_constants,
                              classes_from_network,
                              log_normalizing_constants, pad_classes)
from repro.core.complexity import LearningConstants
from repro.core.energy import PowerProfile
from repro.core.events import (expand_class_stats, simulate_stats,
                               simulate_stats_classes)

CONSTS = LearningConstants(L=1.0, delta=1.0, sigma=1.0, M=2.0, G=5.0,
                           eps=0.5)


def example_classes(with_cs=False, normalized=True):
    cls = ClassParams(
        p=jnp.asarray([0.05, 0.1, 0.025]),
        mu_c=jnp.asarray([1.0, 2.0, 3.0]),
        mu_d=jnp.asarray([6.0, 7.0, 8.0]),
        mu_u=jnp.asarray([6.0, 7.0, 8.0]),
        count=jnp.asarray([4, 2, 8]))
    if normalized:
        mass = float(jnp.sum(cls.count * cls.p))
        cls = cls._replace(p=cls.p / mass)
    return cls._replace(mu_cs=jnp.asarray(5.0)) if with_cs else cls


# ---------------------------------------------------------------------------
# closed forms vs the expanded per-client oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("with_cs", [False, True])
def test_class_log_Z_matches_expanded(with_cs):
    cls = example_classes(with_cs)
    prm = cls.expand()
    m_max = 10
    logZ_c = class_log_normalizing_constants(cls, m_max)
    logZ_p = log_normalizing_constants(prm, m_max)
    np.testing.assert_allclose(np.asarray(logZ_c), np.asarray(logZ_p),
                               rtol=1e-12)


@pytest.mark.parametrize("with_cs", [False, True])
def test_class_closed_forms_match_expanded(with_cs):
    cls = example_classes(with_cs)
    prm = cls.expand()
    m_max = 10
    m = jnp.asarray(6)
    logZ_c = class_log_normalizing_constants(cls, m_max)
    logZ_p = log_normalizing_constants(prm, m_max)

    np.testing.assert_allclose(
        float(throughput_padded(logZ_c, m)),
        float(throughput_padded(logZ_p, m)), rtol=1e-12)

    # per-class delays repeat across each class's members
    d_c = np.asarray(expected_relative_delay_classes(cls, m, logZ_c, m_max))
    d_p = np.asarray(expected_relative_delay_padded(prm, m, logZ_p, m_max))
    np.testing.assert_allclose(np.repeat(d_c, np.asarray(cls.count)), d_p,
                               rtol=1e-10)

    np.testing.assert_allclose(
        float(round_complexity_classes(cls, m, CONSTS, logZ_c, m_max)),
        float(round_complexity_padded(prm, m, CONSTS, logZ_p, m_max)),
        rtol=1e-10)

    np.testing.assert_allclose(
        float(wallclock_time_classes(cls, m, CONSTS, logZ_c, m_max)),
        float(round_complexity_padded(prm, m, CONSTS, logZ_p, m_max)
              / throughput_padded(logZ_p, m)), rtol=1e-10)


def test_class_energy_matches_expanded():
    cls = example_classes()
    prm = cls.expand()
    m_max = 10
    m = jnp.asarray(5)
    pw_c = PowerProfile(P_c=jnp.asarray([2.0, 3.0, 4.0]),
                        P_u=jnp.asarray([0.5, 0.6, 0.7]),
                        P_d=jnp.asarray([0.3, 0.4, 0.5]))
    cnt = np.asarray(cls.count)
    pw_p = PowerProfile(P_c=jnp.asarray(np.repeat(pw_c.P_c, cnt)),
                        P_u=jnp.asarray(np.repeat(pw_c.P_u, cnt)),
                        P_d=jnp.asarray(np.repeat(pw_c.P_d, cnt)))
    logZ_c = class_log_normalizing_constants(cls, m_max)
    logZ_p = log_normalizing_constants(prm, m_max)
    np.testing.assert_allclose(
        float(energy_complexity_classes(cls, m, CONSTS, pw_c, logZ_c,
                                        m_max)),
        float(energy_complexity_padded(prm, m, CONSTS, pw_p, logZ_p,
                                       m_max)), rtol=1e-10)


@pytest.mark.parametrize("with_cs", [False, True])
def test_class_forms_bitwise_invariant_to_padding(with_cs):
    cls = example_classes(with_cs)
    pad = pad_classes(cls, 6)
    m_max = 10
    m = jnp.asarray(6)
    logZ = class_log_normalizing_constants(cls, m_max)
    logZ_pad = class_log_normalizing_constants(pad, m_max)
    np.testing.assert_array_equal(np.asarray(logZ), np.asarray(logZ_pad))
    a = round_complexity_classes(cls, m, CONSTS, logZ, m_max)
    b = round_complexity_classes(pad, m, CONSTS, logZ_pad, m_max)
    assert float(a) == float(b)
    d = expected_relative_delay_classes(pad, m, logZ_pad, m_max)
    np.testing.assert_array_equal(
        np.asarray(d)[:cls.C],
        np.asarray(expected_relative_delay_classes(cls, m, logZ, m_max)))


def test_classes_from_network_round_trip():
    cls = example_classes()
    prm = cls.expand()
    back = classes_from_network(prm)
    # expanding the recovered classes reproduces the per-client arrays
    re = back.expand()
    for f in ("p", "mu_c", "mu_d", "mu_u"):
        np.testing.assert_array_equal(np.asarray(getattr(re, f)),
                                      np.asarray(getattr(prm, f)))


# ---------------------------------------------------------------------------
# class-aggregated event engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("with_cs", [False, True])
def test_class_events_bitwise_invariant_to_class_padding(with_cs):
    cls = example_classes(with_cs)
    pad = pad_classes(cls, 5)
    m, nu, wu = 5, 300, 100
    a = simulate_stats_classes(cls, m, nu, warmup=wu, seed=0)
    b = simulate_stats_classes(pad, m, nu, warmup=wu, seed=0)
    C = cls.C
    for f in ("updates", "time", "throughput", "energy"):
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)), err_msg=f)
    np.testing.assert_array_equal(np.asarray(a.mean_delay),
                                  np.asarray(b.mean_delay)[:C])
    np.testing.assert_array_equal(np.asarray(a.delay_counts),
                                  np.asarray(b.delay_counts)[:C])
    # occupancy: [3C+1] segments; padded classes contribute empty segments
    occ_a = np.asarray(a.mean_queue_counts)
    occ_b = np.asarray(b.mean_queue_counts)
    Cp = pad.C
    for s in range(3):
        np.testing.assert_array_equal(occ_a[s * C:(s + 1) * C],
                                      occ_b[s * Cp:s * Cp + C])
    np.testing.assert_array_equal(occ_a[-1], occ_b[-1])


def test_class_events_match_expanded_distributionally():
    cls = example_classes()
    prm = cls.expand()
    m, nu, wu = 6, 2500, 500
    st_c = simulate_stats_classes(cls, m, nu, warmup=wu, seed=0)
    st_p = simulate_stats(prm, m, nu, warmup=wu, seed=1)
    thr_c = float(st_c.throughput)
    thr_p = float(st_p.throughput)
    assert abs(thr_c - thr_p) / thr_p < 0.1
    # per-class mean delays vs the class-averaged expanded ones
    d_p = np.asarray(st_p.mean_delay)
    cnt = np.asarray(cls.count)
    edges = np.concatenate([[0], np.cumsum(cnt)])
    d_p_cls = np.asarray([d_p[edges[i]:edges[i + 1]].mean()
                          for i in range(cls.C)])
    np.testing.assert_allclose(np.asarray(st_c.mean_delay), d_p_cls,
                               rtol=0.25)


def test_class_events_staleness_identity():
    # Eq. 7 in class space: sum_c massfrac_c E0[R_c] = m - 1
    cls = example_classes()
    m = 8
    st = simulate_stats_classes(cls, m, 4000, warmup=500, seed=0)
    mass = np.asarray(cls.mass)
    frac = mass / mass.sum()
    stale = float(np.sum(frac * np.asarray(st.mean_delay)))
    assert abs(stale - (m - 1)) / (m - 1) < 0.05


def test_expand_class_stats_shapes_and_weights():
    cls = example_classes()
    st = simulate_stats_classes(cls, 5, 300, warmup=100, seed=0)
    ex = expand_class_stats(st, cls.count)
    n = int(np.asarray(cls.count).sum())
    assert ex.mean_delay.shape == (n,)
    assert ex.mean_queue_counts.shape == (3 * n + 1,)
    # class means repeat across members
    cnt = np.asarray(cls.count)
    np.testing.assert_array_equal(
        np.asarray(ex.mean_delay),
        np.repeat(np.asarray(st.mean_delay), cnt))
    # per-member delay counts average the class total
    np.testing.assert_allclose(
        np.asarray(ex.delay_counts),
        np.repeat(np.asarray(st.delay_counts) / cnt, cnt))


def test_class_events_power_accounting():
    cls = example_classes()
    pw = PowerProfile(P_c=jnp.asarray([2.0, 3.0, 4.0]),
                      P_u=jnp.asarray([0.5, 0.6, 0.7]),
                      P_d=jnp.asarray([0.3, 0.4, 0.5]))
    st = simulate_stats_classes(cls, 5, 300, warmup=100, seed=0, power=pw)
    assert float(st.energy) > 0.0


# ---------------------------------------------------------------------------
# kernel backend (interpret mode off-TPU)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("with_cs", [False, True])
def test_class_pallas_kernel_matches_jnp(with_cs):
    cls = example_classes(with_cs)
    m_max = 10
    ref = np.asarray(class_log_normalizing_constants(cls, m_max,
                                                     backend="jnp"))
    pal = np.asarray(class_log_normalizing_constants(cls, m_max,
                                                     backend="pallas"))
    np.testing.assert_allclose(pal, ref, rtol=2e-5, atol=2e-5)


def test_class_pallas_kernel_bitwise_class_padding():
    cls = example_classes()
    m_max = 10
    a = np.asarray(class_log_normalizing_constants(cls, m_max,
                                                   backend="pallas"))
    b = np.asarray(class_log_normalizing_constants(pad_classes(cls, 6),
                                                   m_max, backend="pallas"))
    np.testing.assert_array_equal(a, b)


def test_class_pallas_gradients_match_jnp():
    from repro.core.batched import batch_class_log_normalizing_constants

    cls = example_classes()
    ps = jnp.stack([cls.p, cls.p * jnp.asarray([1.2, 0.9, 0.95])])

    def total(p, backend):
        return batch_class_log_normalizing_constants(cls, p, 8,
                                                     backend=backend).sum()

    g_p = jax.grad(lambda p: total(p, "pallas"))(ps)
    g_j = jax.grad(lambda p: total(p, "jnp"))(ps)
    assert bool(jnp.all(jnp.isfinite(g_p)))
    np.testing.assert_allclose(np.asarray(g_p), np.asarray(g_j), rtol=1e-10)


# ---------------------------------------------------------------------------
# Scenario layer: ClassSpec round-trip + class suite planning
# ---------------------------------------------------------------------------

def _cluster_rows():
    from repro.scenario.spec import ClusterSpec

    return (ClusterSpec("A", 1.0, 6.0, 6.0, 4),
            ClusterSpec("B", 2.0, 7.0, 7.0, 2),
            ClusterSpec("C", 3.0, 8.0, 8.0, 6))


def test_classspec_json_round_trip_and_hash_stability():
    from repro.scenario import NetworkSpec, Scenario
    from repro.scenario.spec import LearningSpec

    net = NetworkSpec.from_clusters(_cluster_rows(), aggregate=True)
    scn = Scenario(network=net, learning=LearningSpec())
    again = Scenario.from_json(scn.to_json())
    assert again.hash() == scn.hash()
    assert again.network.classes.C == net.classes.C
    np.testing.assert_array_equal(again.network.classes.count,
                                  net.classes.count)
    # per-client scenarios don't grow a "classes" key (hash stability)
    plain = Scenario(network=NetworkSpec.from_clusters(_cluster_rows()),
                     learning=LearningSpec())
    assert "classes" not in plain.to_dict()["network"]


def test_aggregate_expands_to_per_client_network():
    from repro.scenario import NetworkSpec

    agg = NetworkSpec.from_clusters(_cluster_rows(), aggregate=True)
    plain = NetworkSpec.from_clusters(_cluster_rows())
    assert agg.n == plain.n
    pa, pp = agg.params(), plain.params()
    for f in ("p", "mu_c", "mu_d", "mu_u"):
        np.testing.assert_array_equal(np.asarray(getattr(pa, f)),
                                      np.asarray(getattr(pp, f)))


def test_class_suite_analyze_matches_expanded_suite():
    from repro.scenario import NetworkSpec, Scenario, ScenarioSuite
    from repro.scenario.spec import LearningSpec

    net_c = NetworkSpec.from_clusters(_cluster_rows(), aggregate=True)
    net_p = NetworkSpec.from_clusters(_cluster_rows())
    base_c = Scenario(network=net_c, learning=LearningSpec())
    base_p = Scenario(network=net_p, learning=LearningSpec())
    sc = ScenarioSuite({
        "a": base_c.with_strategy("asyncsgd", m=6),
        "t": base_c.with_strategy("time_opt", m_max=16)})
    sp = ScenarioSuite({
        "a": base_p.with_strategy("asyncsgd", m=6),
        "t": base_p.with_strategy("time_opt", m_max=16)})
    rc = sc.run(mode="analyze")
    rp = sp.run(mode="analyze")
    assert rc.programs == 1  # both class scenarios share one bucket
    for k in ("a", "t"):
        np.testing.assert_allclose(rc.entries[k]["throughput"],
                                   rp.entries[k]["throughput"], rtol=1e-9)
        np.testing.assert_allclose(rc.entries[k]["K_eps"],
                                   rp.entries[k]["K_eps"], rtol=1e-4)
    # the class-space optimizer lands on the per-client optimum
    assert rc.strategies["t"][1] == rp.strategies["t"][1]
    # asyncsgd class delays repeat to the per-client ones
    np.testing.assert_allclose(
        np.repeat(rc.entries["a"]["delays"], [4, 2, 6]),
        rp.entries["a"]["delays"], rtol=1e-9)


def test_class_suite_simulate_runs_and_unpads_to_classes():
    from repro.scenario import NetworkSpec, Scenario, ScenarioSuite
    from repro.scenario.spec import LearningSpec

    net = NetworkSpec.from_clusters(_cluster_rows(), aggregate=True)
    base = Scenario(network=net, learning=LearningSpec())
    suite = ScenarioSuite(base.with_strategy("asyncsgd", m=5), seeds=(0, 1))
    res = suite.run(mode="simulate", num_updates=300, warmup=100)
    (stats_list,) = res.entries.values()
    assert len(stats_list) == 2
    assert stats_list[0].mean_delay.shape == (net.classes.C,)


def test_class_strategy_guards():
    from repro.scenario import NetworkSpec, Scenario
    from repro.scenario.spec import LearningSpec
    from repro.scenario.suite import resolve_strategy

    net = NetworkSpec.from_clusters(_cluster_rows(), aggregate=True)
    base = Scenario(network=net, learning=LearningSpec())
    with pytest.raises(ValueError, match="m_max"):
        resolve_strategy(base.with_strategy("time_opt"))
    with pytest.raises(ValueError, match="class-space resolver"):
        resolve_strategy(base.with_strategy("round_opt"))
