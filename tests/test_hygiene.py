"""Tier-1 wrapper around ``tools/check_hygiene.py``: no tracked bytecode."""
import os
import sys

import pytest

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(REPO_ROOT, "tools"))

import check_hygiene  # noqa: E402


def test_no_tracked_bytecode_or_caches():
    tracked = check_hygiene.tracked_files()
    if not tracked:
        pytest.skip("git unavailable or not a repository")
    assert check_hygiene.tracked_junk() == []
