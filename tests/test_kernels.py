"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + hypothesis."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import ops, ref


def rand(rng, shape, dtype):
    x = rng.normal(size=shape).astype(np.float32)
    return jnp.asarray(x, dtype)


TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Sq,Sk,H,KV,D", [
    (1, 128, 128, 4, 4, 64),    # MHA, block-aligned
    (2, 100, 100, 8, 2, 64),    # GQA 4:1, ragged seq
    (1, 33, 257, 4, 1, 128),    # MQA, cross lengths, ragged blocks
])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 64),
                                           (False, None)])
def test_flash_attention_matches_oracle(dtype, B, Sq, Sk, H, KV, D, causal,
                                        window):
    rng = np.random.default_rng(hash((B, Sq, H, causal)) % 2**31)
    q = rand(rng, (B, Sq, H, D), dtype)
    k = rand(rng, (B, Sk, KV, D), dtype)
    v = rand(rng, (B, Sk, KV, D), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              block_q=32, block_k=32, interpret=True)
    want = ref.flash_attention_oracle(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


def test_flash_matches_chunked_reference():
    """Pallas kernel == the chunked XLA path used by the models."""
    from repro.models.attention import flash_attention_ref
    rng = np.random.default_rng(0)
    q = rand(rng, (2, 96, 8, 64), jnp.float32)
    k = rand(rng, (2, 96, 4, 64), jnp.float32)
    v = rand(rng, (2, 96, 4, 64), jnp.float32)
    a = ops.flash_attention(q, k, v, causal=True, block_q=32, block_k=32,
                            interpret=True)
    b = flash_attention_ref(q, k, v, causal=True, block_k=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                               atol=2e-5)


@settings(max_examples=12, deadline=None)
@given(st.integers(1, 3), st.integers(1, 90), st.integers(0, 3),
       st.booleans())
def test_flash_attention_property(B, S, kv_log, causal):
    """Random shapes: kernel == oracle (GQA ratios 1/2/4/8)."""
    KV = 1
    G = 2 ** kv_log
    H = KV * G
    D = 64
    rng = np.random.default_rng(S * 7 + G)
    q = rand(rng, (B, S, H, D), jnp.float32)
    k = rand(rng, (B, S, KV, D), jnp.float32)
    v = rand(rng, (B, S, KV, D), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=causal, block_q=32, block_k=32,
                              interpret=True)
    want = ref.flash_attention_oracle(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=3e-5,
                               atol=3e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,KV,D,length", [
    (2, 256, 8, 2, 64, 200),
    (1, 100, 4, 4, 128, 100),
    (3, 513, 4, 1, 64, 77),
])
def test_decode_attention_matches_oracle(dtype, B, S, H, KV, D, length):
    rng = np.random.default_rng(S + H)
    q = rand(rng, (B, 1, H, D), dtype)
    kc = rand(rng, (B, S, KV, D), dtype)
    vc = rand(rng, (B, S, KV, D), dtype)
    out = ops.decode_attention(q, kc, vc, jnp.int32(length), block_s=64,
                               interpret=True)
    want = ref.decode_attention_oracle(q, kc, vc, jnp.int32(length))
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


def test_decode_attention_per_batch_lengths():
    rng = np.random.default_rng(5)
    B, S, H, KV, D = 3, 128, 4, 2, 64
    q = rand(rng, (B, 1, H, D), jnp.float32)
    kc = rand(rng, (B, S, KV, D), jnp.float32)
    vc = rand(rng, (B, S, KV, D), jnp.float32)
    lengths = jnp.asarray([10, 64, 128], jnp.int32)
    out = ops.decode_attention(q, kc, vc, lengths, block_s=32, interpret=True)
    want = ref.decode_attention_oracle(q, kc, vc, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-5,
                               atol=2e-5)


# ---------------------------------------------------------------------------
# buzen
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.integers(1, 12), st.integers(1, 40), st.integers(0, 10_000))
def test_buzen_kernel_matches_core(n, m, seed):
    rng = np.random.default_rng(seed)
    p = rng.dirichlet(np.ones(n))
    mu_c = rng.uniform(0.2, 8.0, n)
    mu_d = rng.uniform(0.2, 8.0, n)
    mu_u = rng.uniform(0.2, 8.0, n)
    from repro.core.buzen import NetworkParams, log_normalizing_constants
    params = NetworkParams(p=jnp.asarray(p), mu_c=jnp.asarray(mu_c),
                           mu_d=jnp.asarray(mu_d), mu_u=jnp.asarray(mu_u))
    want = np.asarray(log_normalizing_constants(params, m))
    got = np.asarray(ops.buzen_log_Z(params.log_rho, params.log_gamma_total,
                                     m, interpret=True))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_buzen_kernel_paper_scale():
    """n=100 clients, m=100 tasks (the paper's experimental scale)."""
    from repro.core.buzen import NetworkParams, log_normalizing_constants
    from repro.fl.strategies import PAPER_CLUSTERS_TABLE1, build_network_params
    params = build_network_params(PAPER_CLUSTERS_TABLE1)
    want = np.asarray(log_normalizing_constants(params, 100))
    got = np.asarray(ops.buzen_log_Z(params.log_rho, params.log_gamma_total,
                                     100, interpret=True))
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-4)


# ---------------------------------------------------------------------------
# fused async update
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_update_matches_oracle(dtype):
    rng = np.random.default_rng(3)
    params = {"a": rand(rng, (37, 19), dtype), "b": rand(rng, (1001,), dtype)}
    grads = {"a": rand(rng, (37, 19), dtype), "b": rand(rng, (1001,), dtype)}
    scale = 0.137
    new, norm = ops.fused_async_update(params, grads, scale, interpret=True)
    want_new, want_norm = ref.fused_async_update_oracle(params, grads, scale)
    for kk in params:
        np.testing.assert_allclose(np.asarray(new[kk], np.float32),
                                   np.asarray(want_new[kk], np.float32),
                                   **TOL[dtype])
    np.testing.assert_allclose(float(norm), float(want_norm), rtol=1e-4)
