"""Megastep (``chunk > 1``) == ``chunk`` single steps, **bitwise** (PR 10).

The contract under test: for every backend (reference / batched / pallas /
sharded), every registered timing law, CS on/off, padded-``n`` and
class-aggregated configurations, and tracing on/off, running the event
engine with ``chunk=E`` produces *bit-identical* trajectories and
statistics to the single-step (``chunk=1``) program — including stats
windows (``warmup``/``cap``) landing on exact event boundaries via masked
partial chunks (every ``num_events`` here is chosen NOT to divide the
chunk).  Plus: ``next_update`` megasteps don't change update semantics,
``SimSpec(chunk=...)`` round-trips with hash stability, the fused trainer
is bitwise invariant to ``sim_chunk``, and chunked suites hold the 1-2
program planner budget.

Both sides of every comparison run under jit: all production paths are
jitted, and eager-vs-compiled is NOT bitwise on CPU (XLA may contract
mul-add chains differently between the two), so an eager baseline would
test a program that never runs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import NetworkParams
from repro.core import events as E
from repro.core.buzen import ClassParams, pad_network
from repro.scenario import law_names
from repro.sim import simulate_stats_lanes

LAWS = law_names()
CHUNKS = (2, 7)  # 7 never divides the event counts below: partial chunks


def net_params(seed, n, with_cs=False):
    rng = np.random.default_rng(seed)
    params = NetworkParams(
        p=jnp.asarray(rng.dirichlet(np.ones(n) * 2.0)),
        mu_c=jnp.asarray(rng.uniform(0.5, 4.0, n)),
        mu_d=jnp.asarray(rng.uniform(0.5, 4.0, n)),
        mu_u=jnp.asarray(rng.uniform(0.5, 4.0, n)))
    return params.with_cs(1.5) if with_cs else params


def class_params(with_cs=False):
    return ClassParams(
        p=jnp.asarray([0.12, 0.08]),
        mu_c=jnp.asarray([1.0, 2.0]), mu_d=jnp.asarray([2.0, 3.0]),
        mu_u=jnp.asarray([3.0, 4.0]), count=jnp.asarray([3, 2]),
        mu_cs=jnp.asarray(1.5) if with_cs else None)


def assert_tree_equal(a, b, err=""):
    la, ta = jax.tree_util.tree_flatten(a)
    lb, tb = jax.tree_util.tree_flatten(b)
    assert ta == tb, f"{err}: tree structure {ta} != {tb}"
    for i, (x, y) in enumerate(zip(la, lb)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=f"{err}[leaf {i}]")


# ---------------------------------------------------------------------------
# core engine: laws x CS x partial chunks, padded-n, classes, rings
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("with_cs", (False, True))
@pytest.mark.parametrize("law", LAWS)
def test_engine_megastep_bitwise_every_law(law, with_cs):
    params = net_params(3, 4, with_cs)
    key = jax.random.PRNGKey(11)
    base = E._simulate_stats(params, 3, key, 40, 10, law, 5, None, 1)
    for chunk in CHUNKS:
        got = E._simulate_stats(params, 3, key, 40, 10, law, 5, None, chunk)
        assert_tree_equal(base, got, err=f"{law}/cs={with_cs}/E={chunk}")


@pytest.mark.parametrize("chunk", CHUNKS)
def test_engine_megastep_bitwise_padded_n(chunk):
    params = net_params(5, 3)
    padded = pad_network(params, 6)
    key = jax.random.PRNGKey(2)
    single = E._simulate_stats(padded, 3, key, 40, 10, "exponential", 5,
                               None, 1)
    mega = E._simulate_stats(padded, 3, key, 40, 10, "exponential", 5,
                             None, chunk)
    assert_tree_equal(single, mega, err=f"padded/E={chunk}")
    # composes with padding invariance: unpadded single == unpadded mega
    plain = E._simulate_stats(params, 3, key, 40, 10, "exponential", 5,
                              None, 1)
    assert_tree_equal(E.unpad_stats(plain, 3),
                      E.unpad_stats(mega, 3), err=f"pad-invariance/E={chunk}")


@pytest.mark.parametrize("with_cs", (False, True))
@pytest.mark.parametrize("law", ("exponential", "lognormal"))
def test_class_engine_megastep_bitwise(law, with_cs):
    cp = class_params(with_cs)
    key = jax.random.PRNGKey(7)
    base = E._simulate_stats_classes(cp, 3, key, 40, 10, law, 5, None, 1)
    for chunk in (3, 8):
        got = E._simulate_stats_classes(cp, 3, key, 40, 10, law, 5, None,
                                        chunk)
        assert_tree_equal(base, got, err=f"class/{law}/cs={with_cs}/"
                                         f"E={chunk}")


@pytest.mark.parametrize("chunk", CHUNKS)
def test_traced_megastep_bitwise_stats_and_rings(chunk):
    """Rings thread through the chunked carry: the traced chunked program
    matches the traced single-step one bitwise — stats AND ring contents —
    and tracing stays non-invasive under chunking."""
    params = net_params(9, 4, with_cs=True)
    key = jax.random.PRNGKey(3)
    s1, r1 = E._simulate_stats_traced(params, 3, key, 40, 10, "exponential",
                                      5, None, 64, 1)
    s2, r2 = E._simulate_stats_traced(params, 3, key, 40, 10, "exponential",
                                      5, None, 64, chunk)
    assert_tree_equal(s1, s2, err=f"traced-stats/E={chunk}")
    assert_tree_equal(r1, r2, err=f"ring/E={chunk}")
    plain = E._simulate_stats(params, 3, key, 40, 10, "exponential", 5,
                              None, chunk)
    assert_tree_equal(plain, s2, err=f"non-invasive/E={chunk}")


def test_traced_class_megastep_bitwise():
    cp = class_params(with_cs=True)
    key = jax.random.PRNGKey(4)
    s1, r1 = E._simulate_stats_classes_traced(cp, 3, key, 40, 10,
                                              "exponential", 5, None, 64, 1)
    s2, r2 = E._simulate_stats_classes_traced(cp, 3, key, 40, 10,
                                              "exponential", 5, None, 64, 8)
    assert_tree_equal(s1, s2, err="class-traced-stats")
    assert_tree_equal(r1, r2, err="class-ring")


# ---------------------------------------------------------------------------
# all four sim backends through the public lanes API
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("law", LAWS)
@pytest.mark.parametrize("backend", ("reference", "batched", "pallas",
                                     "sharded"))
def test_backend_megastep_bitwise(backend, law):
    lanes = [net_params(s, 4) for s in (0, 1)]
    kw = dict(warmup=15, distribution=law, backend=backend,
              interpret=True if backend == "pallas" else None)
    base = simulate_stats_lanes(lanes, [4, 3], 90, chunk=1, **kw)
    mega = simulate_stats_lanes(lanes, [4, 3], 90, chunk=5, **kw)
    assert_tree_equal(base, mega, err=f"{backend}/{law}")


@pytest.mark.parametrize("backend", ("reference", "batched", "pallas"))
def test_backend_megastep_bitwise_cs_traced(backend):
    lanes = [net_params(s, 3, with_cs=True) for s in (4, 5)]
    kw = dict(warmup=10, distribution="exponential", backend=backend,
              trace_events=64,
              interpret=True if backend == "pallas" else None)
    base = simulate_stats_lanes(lanes, [3, 2], 70, chunk=1, **kw)
    mega = simulate_stats_lanes(lanes, [3, 2], 70, chunk=6, **kw)
    assert_tree_equal(base, mega, err=f"{backend}/cs-traced")


@pytest.mark.parametrize("backend", ("reference", "batched", "sharded"))
def test_class_backend_megastep_bitwise(backend):
    from repro.sim.batched_events import build_class_lanes_fn

    cp = class_params(with_cs=True)
    lanes = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs), *([cp] * 2))
    m_vec = jnp.asarray([3, 2], jnp.int32)
    keys = jnp.stack([jax.random.PRNGKey(s) for s in (0, 1)])
    base = build_class_lanes_fn(backend, 80, 10, "exponential", 4,
                                False)(lanes, m_vec, keys, None)
    mega = build_class_lanes_fn(backend, 80, 10, "exponential", 4,
                                False, chunk=6)(lanes, m_vec, keys, None)
    assert_tree_equal(base, mega, err=f"class/{backend}")


# ---------------------------------------------------------------------------
# next_update: megasteps leave update semantics bitwise unchanged
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("with_cs", (False, True))
@pytest.mark.parametrize("backend", ("batched", "pallas"))
def test_next_update_megastep_bitwise(backend, with_cs):
    params = net_params(6, 4, with_cs)
    interp = True if backend == "pallas" else None

    def run(chunk):
        @jax.jit
        def go(key):
            st = E.init_state(params, 3, key, m_max=5,
                              distribution="lognormal", warmup=0, cap=999)

            def body(st, _):
                st, upd = E.next_update(params, st,
                                        distribution="lognormal",
                                        backend=backend, interpret=interp,
                                        chunk=chunk)
                return st, upd

            return jax.lax.scan(body, st, None, length=6)

        return go(jax.random.PRNGKey(8))

    st1, upds1 = run(1)
    for chunk in (4, 9):
        st2, upds2 = run(chunk)
        assert_tree_equal(upds1, upds2, err=f"{backend}/upds/E={chunk}")
        assert_tree_equal(st1, st2, err=f"{backend}/state/E={chunk}")


def test_trainer_bitwise_under_sim_chunk():
    from repro.fl.engine import DeviceTrainer
    from repro.fl.models import mlp_classifier
    from repro.fl.trainer import AsyncFLConfig

    rng = np.random.default_rng(5)
    n = 3
    net = net_params(5, n)
    clients = [(rng.normal(size=(6, 4)).astype(np.float32),
                rng.integers(0, 2, size=6).astype(np.int32))
               for _ in range(n)]
    test = (rng.normal(size=(8, 4)).astype(np.float32),
            rng.integers(0, 2, size=8).astype(np.int32))
    model = mlp_classifier(4, 2, hidden=(4,))
    cfg = AsyncFLConfig(eta=0.05, batch_size=2, eval_every_time=2.0)

    def run(sim_chunk):
        tr = DeviceTrainer(model, clients, net, cfg, test_data=test,
                           sim_chunk=sim_chunk)
        ps = jnp.stack([jnp.asarray(net.p)] * 2)
        return tr.run_lanes(ps, [2, 2], [0.05, 0.05], [0, 1], 8.0)

    base_logs, base_fin = run(1)
    mega_logs, mega_fin = run(4)
    assert_tree_equal(base_fin, mega_fin, err="trainer-params")
    for i, (a, b) in enumerate(zip(base_logs, mega_logs)):
        for f in ("times", "accuracies", "losses", "updates", "mean_delay",
                  "throughput", "energy"):
            np.testing.assert_array_equal(
                np.asarray(getattr(a, f)), np.asarray(getattr(b, f)),
                err_msg=f"trainer-log[{i}].{f}")


# ---------------------------------------------------------------------------
# SimSpec plumbing + suite dispatch + planner budget
# ---------------------------------------------------------------------------

def test_simspec_chunk_roundtrip_validation_and_hash():
    from repro.scenario import NetworkSpec, Scenario, SimSpec

    # absent-when-default: pre-megastep hashes must not move
    assert "chunk" not in SimSpec().to_dict()
    assert SimSpec.from_dict(SimSpec(chunk=8).to_dict()).chunk == 8
    net = NetworkSpec(mu_c=[1.0, 2.0], mu_d=[3.0] * 2, mu_u=[3.0] * 2)
    plain = Scenario(network=net)
    chunked = Scenario(network=net, sim=SimSpec(chunk=8))
    assert plain.hash() != chunked.hash()
    rt = Scenario.from_dict(chunked.to_dict())
    assert rt.hash() == chunked.hash() and rt.sim.chunk == 8
    with pytest.raises(ValueError, match="chunk"):
        SimSpec(chunk=0)


def _chunked_suite(chunk, seeds=(0, 1), sim=None):
    from repro.core import LearningConstants
    from repro.scenario import (LearningSpec, NetworkSpec, Scenario,
                                ScenarioSuite, SimSpec, StrategySpec)

    rng = np.random.default_rng(17)
    scns = {}
    for i, m in enumerate((3, 4)):
        n = 4
        scns[f"s{i}"] = Scenario(
            network=NetworkSpec(mu_c=rng.uniform(0.5, 4.0, n),
                                mu_d=rng.uniform(0.5, 4.0, n),
                                mu_u=rng.uniform(0.5, 4.0, n)),
            learning=LearningSpec(consts=LearningConstants(M=2.0, G=5.0)),
            strategy=StrategySpec("explicit", p=rng.dirichlet(np.ones(n)),
                                  m=m),
            sim=SimSpec(chunk=chunk) if chunk != 1 else sim)
    return ScenarioSuite(scns, seeds=seeds)


def test_suite_chunked_bitwise_and_program_budget(tracecheck):
    """`SimSpec(chunk=...)` scenarios run through the suite bitwise equal
    to the default, and a chunked suite still plans into 1-2 programs
    (unique num_updates: the process-wide builder memo must not leak)."""
    base = _chunked_suite(1).run(mode="simulate", num_updates=181,
                                 warmup=20)
    suite = _chunked_suite(8)
    with tracecheck.expect(max_programs=2,
                           pattern=tracecheck.PLANNER_PROGRAMS,
                           what="chunked suite planner") as w:
        res = suite.run(mode="simulate", num_updates=181, warmup=20)
    assert res.programs == 1  # one structure bucket -> one megastep program
    assert len(w.programs(tracecheck.PLANNER_PROGRAMS)) <= 2
    for name in base.entries:
        assert_tree_equal(base.entries[name], res.entries[name],
                          err=f"suite/{name}")
