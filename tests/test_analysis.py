"""The static-analysis subsystem (``repro.analysis``).

Covers the three parts and their acceptance criteria:
  * contract linter: every rule has pass/fail fixture snippets, the
    suppression grammar is enforced (justification mandatory, unknown
    rules rejected), the hardcoded registry names track the live
    registries, and the repo itself lints clean;
  * jaxpr auditor: the resident-program registry is complete, the
    report matches the golden schema (``tests/data/audit_schema.json``)
    and its f64 / host-callback findings are populated;
  * recompile sentinel: a deliberately-recompiling function trips its
    budget, cached dispatch stays silent, and the mixed-population
    suite planner holds its "1-2 programs" budget — with a mutation
    (per-scenario re-planning, the pre-suite behaviour) shown to FAIL
    the budget, so the sentinel is known to have teeth.
"""
import json
import os

import numpy as np
import pytest

from repro.analysis import lint

DATA = os.path.join(os.path.dirname(__file__), "data")


def _active(violations):
    return [v for v in violations if not v.suppressed]


def _rules(violations):
    return sorted({v.rule for v in _active(violations)})


# ---------------------------------------------------------------------------
# linter: per-rule fail + pass snippets
# ---------------------------------------------------------------------------

def test_raw_reduction_flagged_in_marked_modules():
    bad = "import jax.numpy as jnp\ntotal = jnp.sum(x)\n"
    assert _rules(lint.lint_source(bad, marked=True)) == ["raw-reduction"]
    # the bitwise-sequential forms pass
    good = "from repro.core.numerics import seqsum\ntotal = seqsum(x)\n"
    assert lint.lint_source(good, marked=True) == []
    # unmarked modules may sum freely (they are off the padding contract)
    assert lint.lint_source(bad, marked=False) == []


def test_raw_reduction_flags_method_calls_and_cumsum():
    src = "a = x.sum()\nb = jnp.cumsum(y)\nc = arr.cumsum(axis=0)\n"
    vs = _active(lint.lint_source(src, marked=True))
    assert [v.rule for v in vs] == ["raw-reduction"] * 3
    assert [v.line for v in vs] == [1, 2, 3]


def test_marker_comment_autodetected():
    src = ("# contract: padded-n — client-axis reductions live here\n"
           "import jax.numpy as jnp\n"
           "total = jnp.sum(x)\n")
    assert _rules(lint.lint_source(src)) == ["raw-reduction"]


def test_categorical_routing_flagged_everywhere():
    # flagged regardless of the padding marker: Gumbel draws with the
    # logits' shape break bitwise padding *and* cost O(n) randomness
    src = "i = jax.random.categorical(key, logits)\n"
    assert _rules(lint.lint_source(src, marked=False)) == \
        ["categorical-routing"]
    src2 = "from jax.random import categorical\ni = categorical(k, lg)\n"
    assert _rules(lint.lint_source(src2)) == ["categorical-routing"]
    # unrelated .categorical attributes on other modules pass
    assert lint.lint_source("x = pd.categorical(s)\n") == []


def test_stringly_dispatch_flags_if_chains_and_dicts():
    chain = (
        'def f(law):\n'
        '    if law == "exponential":\n'
        '        return 1\n'
        '    elif law == "lognormal":\n'
        '        return 2\n'
    )
    assert _rules(lint.lint_source(chain)) == ["stringly-dispatch"]
    membership = (
        'def f(s):\n'
        '    if s in ("energy_opt", "joint"):\n'
        '        return 1\n'
    )
    assert _rules(lint.lint_source(membership)) == ["stringly-dispatch"]
    table = 'FNS = {"exponential": draw_e, "deterministic": draw_d}\n'
    assert _rules(lint.lint_source(table)) == ["stringly-dispatch"]


def test_stringly_dispatch_ignores_non_registry_strings():
    # branching on strings that are not registered law/strategy names is
    # ordinary code, and a single registered name is validation, not
    # dispatch
    ok = (
        'def f(mode):\n'
        '    if mode == "fast":\n'
        '        return 1\n'
        '    elif mode == "slow":\n'
        '        return 2\n'
        'def g(law):\n'
        '    if law == "exponential":\n'
        '        return 1\n'
    )
    assert lint.lint_source(ok) == []


def test_numpy_in_jit_flagged_only_inside_traced_functions():
    bad = (
        'import numpy as np\n'
        '@jax.jit\n'
        'def f(x):\n'
        '    return np.sin(x)\n'
    )
    assert _rules(lint.lint_source(bad)) == ["numpy-in-jit"]
    # numpy metadata (dtypes etc.) is host-safe under a trace
    meta = (
        '@jax.jit\n'
        'def f(x):\n'
        '    return x.astype(np.float32(0).dtype)\n'
    )
    assert lint.lint_source(meta) == []
    # the same call outside any traced function passes
    assert lint.lint_source("y = np.sin(x)\n") == []


def test_numpy_in_jit_sees_functions_passed_to_transforms():
    src = (
        'def body(c, _):\n'
        '    return np.add(c, 1), None\n'
        'out = jax.lax.scan(body, c0, None, length=3)\n'
    )
    assert _rules(lint.lint_source(src)) == ["numpy-in-jit"]


def test_traced_branch_flagged():
    bad = (
        '@jax.jit\n'
        'def f(x):\n'
        '    if jnp.any(x > 0):\n'
        '        return x\n'
        '    return -x\n'
    )
    assert _rules(lint.lint_source(bad)) == ["traced-branch"]
    good = (
        '@jax.jit\n'
        'def f(x):\n'
        '    return jnp.where(x > 0, x, -x)\n'
    )
    assert lint.lint_source(good) == []


def test_env_read_flagged_inside_traced_functions():
    bad = (
        '@jax.jit\n'
        'def f(x):\n'
        '    if os.environ.get("REPRO_SIM_BACKEND") == "x":\n'
        '        return x\n'
        '    y = os.environ["REPRO_FLAG"]\n'
        '    z = os.getenv("REPRO_MODE")\n'
        '    return x\n'
    )
    vs = _active(lint.lint_source(bad))
    assert [v.rule for v in vs] == ["env-read"] * 3
    # resolving the flag eagerly, in plain (untraced) runtime code, passes
    ok = ('def configure():\n'
          '    backend = os.environ.get("REPRO_SIM_BACKEND")\n'
          '    return backend\n'
          '@jax.jit\n'
          'def f(x):\n'
          '    return x\n')
    assert lint.lint_source(ok) == []


def test_env_read_flagged_at_module_scope():
    # import-time reads freeze server config for the process lifetime
    bad = ('backend = os.environ.get("REPRO_SIM_BACKEND")\n'
           'flag = os.environ["REPRO_FLAG"]\n'
           'mode = os.getenv("REPRO_MODE")\n')
    vs = _active(lint.lint_source(bad))
    assert [v.rule for v in vs] == ["env-read"] * 3
    assert all("module scope" in v.message for v in vs)
    # environment WRITES at module scope are fine (Store ctx)
    ok = 'os.environ["XLA_FLAGS"] = "--xla_force_host_platform"\n'
    assert lint.lint_source(ok) == []
    # a justified suppression documents the read
    sup = ('# contract: allow(env-read): read once at import, documented\n'
           'backend = os.environ.get("REPRO_SIM_BACKEND")\n')
    vs = lint.lint_source(sup)
    assert len(vs) == 1 and vs[0].suppressed


# ---------------------------------------------------------------------------
# linter: suppression grammar
# ---------------------------------------------------------------------------

def test_suppression_with_justification_suppresses():
    src = ("import jax.numpy as jnp\n"
           "# contract: allow(raw-reduction): exact 0/1 indicator count\n"
           "total = jnp.sum(flags)\n")
    vs = lint.lint_source(src, marked=True)
    assert len(vs) == 1 and vs[0].suppressed
    assert vs[0].justification == "exact 0/1 indicator count"
    # trailing same-line comments work too
    inline = ("import jax.numpy as jnp\n"
              "total = jnp.sum(flags)"
              "  # contract: allow(raw-reduction): indicator count\n")
    vs = lint.lint_source(inline, marked=True)
    assert len(vs) == 1 and vs[0].suppressed


def test_suppression_without_justification_rejected():
    src = ("import jax.numpy as jnp\n"
           "# contract: allow(raw-reduction)\n"
           "total = jnp.sum(flags)\n")
    rules = _rules(lint.lint_source(src, marked=True))
    # the violation stays active AND the empty allow is itself flagged
    assert rules == ["bad-suppression", "raw-reduction"]


def test_suppression_of_unknown_rule_rejected():
    src = "# contract: allow(frobnicate): because reasons\nx = 1\n"
    assert _rules(lint.lint_source(src)) == ["bad-suppression"]


def test_suppression_must_match_the_rule():
    src = ("import jax.numpy as jnp\n"
           "# contract: allow(numpy-in-jit): wrong rule for this line\n"
           "total = jnp.sum(flags)\n")
    assert "raw-reduction" in _rules(lint.lint_source(src, marked=True))


# ---------------------------------------------------------------------------
# linter: the repo itself + registry drift
# ---------------------------------------------------------------------------

def test_repo_lints_clean():
    """Acceptance: zero unsuppressed violations across ``src/repro``."""
    active = _active(lint.lint_tree())
    assert not active, "\n".join(v.format() for v in active)


def test_repo_suppressions_all_carry_justifications():
    for v in lint.lint_tree():
        if v.suppressed:
            assert v.justification, v.format()


def test_hardcoded_registry_names_match_live_registries():
    """The linter hardcodes law/strategy names to stay import-light;
    this is the drift guard the hardcoding is conditioned on."""
    import repro.scenario.suite  # noqa: F401 — registers the strategies
    from repro.scenario import STRATEGIES, law_names

    assert set(law_names()) == set(lint.LAW_NAMES)
    assert set(STRATEGIES.names()) == set(lint.STRATEGY_NAMES)


def test_lint_cli_green_on_repo(capsys):
    assert lint.main([]) == 0
    out = capsys.readouterr().out
    assert "contract lint: 0 violation(s)" in out


# ---------------------------------------------------------------------------
# recompile sentinel
# ---------------------------------------------------------------------------

def test_sentinel_catches_deliberate_recompiles(tracecheck):
    import jax
    import jax.numpy as jnp

    @jax.jit
    def recompiles_me(x):
        return x * 2.0

    with pytest.raises(tracecheck.TraceBudgetExceeded,
                       match="recompiles_me"):
        with tracecheck.expect(max_programs=1, pattern="^recompiles_me$",
                               what="shape-polymorphic loop"):
            for k in (2, 3, 4):  # three shapes -> three compiles
                recompiles_me(jnp.ones(k))


def test_sentinel_allows_cached_dispatch(tracecheck):
    import jax
    import jax.numpy as jnp

    @jax.jit
    def cached_fn(x):
        return x + 1.0

    cached_fn(jnp.ones(3))  # warm the cache
    with tracecheck.forbid("second same-shape call must hit the cache"):
        cached_fn(jnp.ones(3))


def test_sentinel_watch_records_program_names(tracecheck):
    import jax
    import jax.numpy as jnp

    @jax.jit
    def named_program(x):
        return x - 0.5

    with tracecheck.watch() as w:
        named_program(jnp.ones(4))
    assert w.programs("^named_program$") == ["named_program"]
    assert w.compiles >= 1 and w.traces >= 1


def test_counting_wrapper_counts_python_traces(tracecheck):
    import jax
    import jax.numpy as jnp

    counted = tracecheck.counting(lambda x: x * 3.0)
    fn = jax.jit(counted)
    fn(jnp.ones(2))
    fn(jnp.ones(2))  # cache hit: body must not run again
    fn(jnp.ones(5))  # new shape: one more trace
    assert counted.traces == 2


# ---------------------------------------------------------------------------
# sentinel x suite planner: the machine-checked "1-2 programs" property
# ---------------------------------------------------------------------------

def _mixed_population_suite(seeds=(0,)):
    from repro.core import LearningConstants
    from repro.scenario import (EXPLICIT, LearningSpec, NetworkSpec,
                                Scenario, ScenarioSuite, StrategySpec)

    consts = LearningConstants(M=2.0, G=5.0)
    scns = {}
    for i, n in enumerate((3, 4, 6)):  # mixed populations: padded-n planner
        rng = np.random.default_rng(40 + i)
        net = NetworkSpec(mu_c=rng.uniform(0.5, 6.0, n),
                          mu_d=rng.uniform(0.5, 6.0, n),
                          mu_u=rng.uniform(0.5, 6.0, n))
        scns[f"n{n}"] = Scenario(
            network=net, learning=LearningSpec(consts=consts),
            strategy=StrategySpec(EXPLICIT, p=rng.dirichlet(np.ones(n)),
                                  m=n - 1))
    return ScenarioSuite(scns, seeds=seeds)


# NOTE: each planner test uses a unique num_updates so the process-wide
# build_lanes_fn memoization cannot leak compiled programs across tests.

def test_suite_mixed_population_holds_program_budget(tracecheck):
    suite = _mixed_population_suite(seeds=(0, 1))
    with tracecheck.expect(max_programs=2,
                           pattern=tracecheck.PLANNER_PROGRAMS,
                           what="mixed-n suite planner") as w:
        res = suite.run(mode="simulate", num_updates=173)
    assert res.programs == 1  # one law bucket -> one padded program
    assert len(w.programs(tracecheck.PLANNER_PROGRAMS)) <= 2


def test_sentinel_catches_per_scenario_replanning(tracecheck):
    """Mutation: re-plan each scenario in its own suite (the pre-padded-n
    behaviour — one program per population).  The sentinel must fail it,
    proving the budget check has teeth."""
    from repro.scenario import ScenarioSuite

    suite = _mixed_population_suite(seeds=(0,))
    with pytest.raises(tracecheck.TraceBudgetExceeded, match="budget"):
        with tracecheck.expect(max_programs=2,
                               pattern=tracecheck.PLANNER_PROGRAMS,
                               what="per-scenario re-planning mutation"):
            for name, scn in suite.scenarios.items():
                ScenarioSuite({name: scn}, seeds=(0,)).run(
                    mode="simulate", num_updates=179)


# ---------------------------------------------------------------------------
# jaxpr auditor
# ---------------------------------------------------------------------------

EXPECTED_PROGRAMS = {
    "suite_analyze", "suite_analyze_classes", "suite_simulate_batched",
    "suite_simulate_batched_traced", "suite_simulate_batched_megastep",
    "suite_simulate_classes", "suite_simulate_pallas",
    "suite_simulate_pallas_megastep", "suite_simulate_sharded",
    "simulate_reference_lane", "trainer_scan", "trainer_scan_traced",
    "trainer_scan_lane_nets", "kernel_buzen", "kernel_buzen_classes",
    "kernel_events", "kernel_events_megastep",
}


def test_audit_registry_covers_every_resident_program():
    from repro.analysis import audit

    assert set(audit.resident_programs()) == EXPECTED_PROGRAMS


@pytest.fixture(scope="module")
def audit_report():
    """A two-program report (the cheap analyze + Buzen-kernel builders);
    the full twelve-program artifact is CI's job (AUDIT_jaxpr.json)."""
    from repro.analysis import audit

    return audit.build_report(names=["suite_analyze", "kernel_buzen"])


_SCHEMA_TYPES = {"str": str, "int": int, "number": (int, float),
                 "bool": bool}


def _check_schema(spec, value, path="report"):
    if isinstance(spec, str):
        assert isinstance(value, _SCHEMA_TYPES[spec]), \
            f"{path}: {value!r} is not {spec}"
        if spec in ("int", "number"):
            assert not isinstance(value, bool), f"{path}: bool is not {spec}"
    elif isinstance(spec, list):
        assert isinstance(value, list), f"{path}: {type(value)} != list"
        for i, item in enumerate(value):
            _check_schema(spec[0], item, f"{path}[{i}]")
    elif isinstance(spec, dict):
        assert isinstance(value, dict), f"{path}: {type(value)} != dict"
        if "__each__" in spec:
            for k, v in value.items():
                _check_schema(spec["__each__"], v, f"{path}.{k}")
        else:
            missing = set(spec) - set(value)
            extra = set(value) - set(spec)
            assert not missing, f"{path}: missing keys {sorted(missing)}"
            assert not extra, f"{path}: unexpected keys {sorted(extra)}"
            for k in spec:
                _check_schema(spec[k], value[k], f"{path}.{k}")
    else:  # pragma: no cover - malformed golden file
        raise AssertionError(f"bad schema node at {path}: {spec!r}")


def test_audit_report_matches_golden_schema(audit_report):
    with open(os.path.join(DATA, "audit_schema.json")) as fh:
        golden = json.load(fh)
    _check_schema(golden, audit_report)
    assert audit_report["schema"] == {"name": "repro.analysis.audit",
                                      "version": 1}


def test_audit_findings_populated(audit_report):
    progs = audit_report["programs"]
    analyze = progs["suite_analyze"]
    # x64 clocks: the closed forms carry f64 primitives off-TPU, and the
    # auditor must see (and blame) them with source-located examples
    assert audit_report["x64_enabled"] is True
    assert analyze["f64"]["count"] > 0
    assert analyze["f64"]["examples"]
    assert analyze["tpu_compilable"] is False
    assert "f64-primitives" in analyze["tpu_blockers"]
    # host-callback findings are populated (count 0 is a finding too)
    for entry in progs.values():
        assert entry["host_callbacks"]["count"] == 0
        assert entry["total_primitives"] > 0
    # the f32 Buzen kernel is the one TPU-ready program of this pair
    buzen = progs["kernel_buzen"]
    assert buzen["f64"]["count"] == 0
    assert buzen["tpu_compilable"] is True
    summary = audit_report["summary"]
    assert summary["programs"] == 2
    assert "kernel_buzen" in summary["tpu_ready"]
    assert "suite_analyze" in summary["tpu_blocked"]
