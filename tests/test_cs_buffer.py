"""Section 7 (CS-side buffer) — behaviour beyond the closed-form identity
tests in test_jackson (which already cover Thm 7 vs autodiff/brute force)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (LearningConstants, NetworkParams, PowerProfile,
                        energy_complexity, energy_optimal_routing,
                        expected_relative_delay, make_time_objective,
                        optimize_routing, throughput, wallclock_time)


def params_with_cs(mu_cs, n=4, seed=0):
    rng = np.random.default_rng(seed)
    base = NetworkParams(
        p=jnp.full((n,), 1.0 / n),
        mu_c=jnp.asarray(rng.uniform(0.5, 5.0, n)),
        mu_d=jnp.asarray(rng.uniform(0.5, 5.0, n)),
        mu_u=jnp.asarray(rng.uniform(0.5, 5.0, n)))
    return base.with_cs(mu_cs) if mu_cs else base


CONSTS = LearningConstants(L=1, delta=1, sigma=1, M=2, G=5, eps=1)


def test_slow_cs_reduces_throughput():
    """A congested CS throttles lambda toward mu_cs (Eq 26)."""
    m = 6
    lam_fast = float(throughput(params_with_cs(None), m))
    lam_slow = float(throughput(params_with_cs(0.5), m))
    assert lam_slow < lam_fast
    assert lam_slow < 0.5 + 1e-9  # cannot exceed the CS service rate


def test_cs_monotone_in_mu_cs():
    m = 5
    lams = [float(throughput(params_with_cs(mu), m))
            for mu in (0.3, 1.0, 3.0, 30.0, 1e6)]
    assert all(b >= a - 1e-12 for a, b in zip(lams, lams[1:]))
    lam_base = float(throughput(params_with_cs(None), m))
    assert lams[-1] == pytest.approx(lam_base, rel=1e-4)


def test_cs_simulation_agreement():
    from repro.core.simulator import AsyncNetworkSim
    params = params_with_cs(1.5, seed=3)
    m = 5
    sim = AsyncNetworkSim(params, m, seed=7)
    stats = sim.run(80_000, warmup=10_000)
    np.testing.assert_allclose(stats.throughput,
                               float(throughput(params, m)), rtol=0.03)
    d_sim = np.asarray(params.p) * stats.mean_delay
    np.testing.assert_allclose(
        d_sim, np.asarray(expected_relative_delay(params, m)),
        rtol=0.08, atol=0.03)


def test_time_optimization_under_cs_congestion():
    """Routing optimization still improves tau with the CS queue modelled."""
    params = params_with_cs(1.0, seed=5)
    m = 6
    obj = make_time_objective(params, CONSTS)
    res = optimize_routing(obj, params.n, m, steps=400)
    uni = jnp.full((params.n,), 1.0 / params.n)
    assert res.value <= float(obj(uni, m)) + 1e-9


def test_cs_energy_routing_closed_form():
    """Eq 28: p*_E ∝ 1/sqrt(P_cs/mu_cs + E_i) recovered numerically."""
    params = params_with_cs(2.0, seed=1)
    n = params.n
    power = PowerProfile(P_c=jnp.asarray([1.0, 4.0, 0.5, 2.0]),
                         P_u=jnp.asarray([1.0, 1.0, 2.0, 0.5]),
                         P_d=jnp.asarray([0.5, 0.2, 1.0, 0.3]),
                         P_cs=jnp.asarray(3.0))
    p_closed = np.asarray(energy_optimal_routing(params, power))
    from repro.core import make_energy_objective
    res = optimize_routing(make_energy_objective(params, CONSTS, power),
                           n, 1, steps=2500, lr=0.05)
    np.testing.assert_allclose(np.asarray(res.p), p_closed, rtol=5e-3)
