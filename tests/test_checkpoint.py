"""Checkpoint round-trip + corruption checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint


def test_roundtrip(tmp_path):
    tree = {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "nested": [{"b": jnp.ones((2,), jnp.bfloat16)},
                       jnp.int32(7)]}
    path = tmp_path / "ckpt.npz"
    save_checkpoint(path, tree, step=42, metadata={"loss": 1.5})
    restored, step, meta = load_checkpoint(path, tree)
    assert step == 42 and meta["loss"] == 1.5
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_shape_mismatch_rejected(tmp_path):
    path = tmp_path / "c.npz"
    save_checkpoint(path, {"w": jnp.zeros((3,))})
    with pytest.raises(ValueError):
        load_checkpoint(path, {"w": jnp.zeros((4,))})


def test_model_params_roundtrip(tmp_path):
    from repro.configs import get_config
    from repro.models import build_model
    cfg = get_config("xlstm-350m").reduced()
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    path = tmp_path / "model.npz"
    save_checkpoint(path, params, step=1)
    restored, step, _ = load_checkpoint(path, params)
    x = jnp.ones((1, 8), jnp.int32)
    l1, _ = bundle.loss_fn(params, {"tokens": x, "targets": x})
    l2, _ = bundle.loss_fn(restored, {"tokens": x, "targets": x})
    assert float(l1) == pytest.approx(float(l2), rel=1e-6)
