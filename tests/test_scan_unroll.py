"""Scanned vs unrolled layer execution must be numerically identical —
this underpins the dry-run's 1/2-group cost extrapolation."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "jamba-v0.1-52b",
                                  "kimi-k2-1t-a32b", "whisper-medium"])
def test_scan_equals_unroll(arch):
    cfg = get_config(arch).reduced()
    cfg_u = dataclasses.replace(cfg, scan_layers=False)
    b_s = build_model(cfg)
    b_u = build_model(cfg_u)
    params = b_s.init(jax.random.PRNGKey(0))
    B, S = 2, 12
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                   jnp.int32),
             "targets": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                    jnp.int32)}
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.normal(0, 0.02, (B, cfg.encoder_frames, cfg.d_model)),
            jnp.float32)
    l_s, _ = b_s.loss_fn(params, batch)
    l_u, _ = b_u.loss_fn(params, batch)
    assert float(l_s) == pytest.approx(float(l_u), rel=2e-4)


def test_remat_policy_dots_same_loss():
    cfg = get_config("internlm2-1.8b").reduced()
    cfg_d = dataclasses.replace(cfg, remat_policy="dots")
    b0, b1 = build_model(cfg), build_model(cfg_d)
    params = b0.init(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.ones((2, 8), jnp.int32),
             "targets": jnp.ones((2, 8), jnp.int32)}
    l0, _ = b0.loss_fn(params, batch)
    l1, _ = b1.loss_fn(params, batch)
    assert float(l0) == pytest.approx(float(l1), rel=1e-6)
    # gradients identical too (remat changes schedule, not math)
    g0 = jax.grad(lambda p: b0.loss_fn(p, batch)[0])(params)
    g1 = jax.grad(lambda p: b1.loss_fn(p, batch)[0])(params)
    for a, b in zip(jax.tree_util.tree_leaves(g0),
                    jax.tree_util.tree_leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                                   atol=1e-6)


def test_prefill_last_only_same_next_token():
    cfg = get_config("internlm2-1.8b").reduced()
    cfg_l = dataclasses.replace(cfg, prefill_last_only=True)
    b0, b1 = build_model(cfg), build_model(cfg_l)
    params = b0.init(jax.random.PRNGKey(1))
    batch = {"tokens": jnp.arange(10, dtype=jnp.int32)[None] % cfg.vocab}
    l0, c0 = b0.prefill(params, batch)
    l1, c1 = b1.prefill(params, batch)
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1), rtol=1e-4, atol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(c0),
                    jax.tree_util.tree_leaves(c1)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), rtol=1e-4,
                                   atol=1e-5)
