"""End-to-end behaviour of the paper's system.

The full pipeline: heterogeneous population -> closed-form analysis ->
routing/concurrency optimization -> async FL training in virtual wall-clock
time -> the optimized schedule beats the AsyncSGD baseline.  This is the
paper's central claim exercised through every layer of the framework.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (LearningConstants, expected_relative_delay,
                        throughput, wallclock_time)
from repro.data import (dirichlet_partition, make_synthetic_image_dataset,
                        train_test_split)
from repro.fl import (AsyncFLConfig, AsyncFLTrainer, make_strategies,
                      mlp_classifier)
from repro.fl.strategies import PAPER_CLUSTERS_TABLE1, build_network_params

CONSTS = LearningConstants(L=1, delta=1, sigma=1, M=2, G=5, eps=1)


@pytest.fixture(scope="module")
def population():
    return build_network_params(PAPER_CLUSTERS_TABLE1, scale=20)  # n = 6


@pytest.fixture(scope="module")
def strategies(population):
    return make_strategies(population, CONSTS, steps=200,
                           m_max=population.n + 6,
                           which=("asyncsgd", "time_opt", "round_opt"))


def test_time_opt_improves_theoretical_tau(population, strategies):
    p_t, m_t = strategies["time_opt"]
    tau_opt = float(wallclock_time(
        population._replace(p=jnp.asarray(p_t)), m_t, CONSTS))
    tau_uni = float(wallclock_time(population, population.n, CONSTS))
    assert tau_opt < tau_uni


def test_round_opt_favors_stragglers(population, strategies):
    """Round-opt shifts routing mass toward slow clients (Section 4.2)."""
    p_k, _ = strategies["round_opt"]
    mu = np.asarray(population.mu_c)
    slowest, fastest = int(np.argmin(mu)), int(np.argmax(mu))
    assert p_k[slowest] > p_k[fastest]


def test_end_to_end_training_ordering(population, strategies):
    """Trained in virtual time, time-opt reaches the accuracy target no
    later than AsyncSGD (paper Fig. 3 / Table 3)."""
    n = population.n
    full = make_synthetic_image_dataset(num_classes=8, samples_per_class=90,
                                        seed=4)
    train, test = train_test_split(full, 0.2, seed=5)
    parts = dirichlet_partition(train.y, n, alpha=0.2, seed=4)
    clients = [(train.x[i], train.y[i]) for i in parts]

    hits = {}
    for name in ("asyncsgd", "time_opt"):
        p, m = strategies[name]
        model = mlp_classifier(28 * 28, 8, hidden=(64,))
        tr = AsyncFLTrainer(
            model, clients, population._replace(p=jnp.asarray(p)), m,
            config=AsyncFLConfig(eta=0.05, batch_size=32,
                                 eval_every_time=6.0, seed=0, grad_clip=5.0),
            test_data=(test.x, test.y))
        log = tr.run(horizon_time=220.0)
        hits[name] = log.time_to_accuracy(0.5)
        assert np.isfinite(log.losses).all()
    assert hits["time_opt"] <= hits["asyncsgd"] * 1.05  # small MC slack


def test_staleness_identity_through_stack(population):
    for m in (1, 3, population.n):
        d = expected_relative_delay(population, m)
        assert float(jnp.sum(d)) == pytest.approx(m - 1, abs=1e-8)
        assert float(throughput(population, m)) > 0
