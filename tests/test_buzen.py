"""Buzen normalization constants: literal vs aggregate vs brute force."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.buzen import (NetworkParams, brute_force_log_Z,
                              log_normalizing_constants)


def random_params(rng, n, with_cs=False):
    p = rng.dirichlet(np.ones(n))
    params = NetworkParams(
        p=jnp.asarray(p),
        mu_c=jnp.asarray(rng.uniform(0.1, 10.0, n)),
        mu_d=jnp.asarray(rng.uniform(0.1, 10.0, n)),
        mu_u=jnp.asarray(rng.uniform(0.1, 10.0, n)),
    )
    if with_cs:
        params = params.with_cs(rng.uniform(0.5, 10.0))
    return params


@pytest.mark.parametrize("n,m", [(1, 1), (2, 3), (3, 4), (4, 3)])
@pytest.mark.parametrize("with_cs", [False, True])
def test_brute_force_agreement(n, m, with_cs):
    rng = np.random.default_rng(n * 100 + m)
    params = random_params(rng, n, with_cs)
    logZ = log_normalizing_constants(params, m)
    for k in range(1, m + 1):
        np.testing.assert_allclose(float(logZ[k]), brute_force_log_Z(params, k),
                                   rtol=1e-10)


@pytest.mark.parametrize("with_cs", [False, True])
def test_literal_equals_aggregate(with_cs):
    rng = np.random.default_rng(7)
    params = random_params(rng, 5, with_cs)
    a = log_normalizing_constants(params, 12, method="aggregate")
    b = log_normalizing_constants(params, 12, method="literal")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-11)


def test_Z0_is_one():
    rng = np.random.default_rng(0)
    params = random_params(rng, 3)
    logZ = log_normalizing_constants(params, 5)
    assert float(logZ[0]) == pytest.approx(0.0, abs=1e-12)


def test_extreme_rates_no_overflow():
    """Log-space handles rate spreads of 1e6 without inf/nan."""
    n = 20
    params = NetworkParams(
        p=jnp.full((n,), 1.0 / n),
        mu_c=jnp.asarray(np.geomspace(1e-3, 1e3, n)),
        mu_d=jnp.asarray(np.geomspace(1e3, 1e-3, n)),
        mu_u=jnp.full((n,), 1.0),
    )
    logZ = log_normalizing_constants(params, 200)
    assert np.all(np.isfinite(np.asarray(logZ)))


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 5), st.integers(1, 8), st.integers(0, 10_000))
def test_monotone_ratio_property(n, m, seed):
    """Z_{m-1}/Z_m (= throughput) is positive; Z log-concave in m for
    single-chain closed networks implies non-increasing ratios Z[m-1]/Z[m]
    as loads saturate — we check positivity + finiteness as the invariant."""
    rng = np.random.default_rng(seed)
    params = random_params(rng, n)
    logZ = log_normalizing_constants(params, m + 1)
    vals = np.asarray(logZ)
    assert np.all(np.isfinite(vals))
    lam = np.exp(vals[:-1] - vals[1:])
    assert np.all(lam > 0)
