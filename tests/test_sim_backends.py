"""The repro.sim backend subsystem: reference / batched / pallas.

Contracts under test:

  * ``"reference"`` and ``"batched"`` are **bitwise identical** on
    structurally-alike lanes (vmap of the same pure step function);
  * ``"pallas"`` (interpret mode on CPU) reproduces the reference engine's
    per-event trajectories — bitwise for the rate-free unit-draw laws
    (exponential / deterministic), to float-rescale accuracy (1e-12) for
    lognormal / hyperexponential;
  * the maintained occupancy carries equal a full table recount;
  * distributional agreement vs the host ``AsyncNetworkSim`` at the
    tolerances documented in ``tests/test_events.py``;
  * vmapped lanes == stacked singles through the public lanes API;
  * unknown backends fail listing the registered options, everywhere;
  * ``SimSpec`` / ``DataSpec`` round-trip bitwise through JSON and drive
    ``ScenarioSuite`` (backend routing, result cache, spec-built clients).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import NetworkParams, throughput
from repro.core import events as E
from repro.core.simulator import AsyncNetworkSim
from repro.kernels.events import event_step_tables, step_event_pallas1
from repro.kernels.ref import event_step_oracle
from repro.sim import (BACKENDS, get_backend, resolve_backend, set_backend,
                       simulate_stats_lanes)


def random_params(seed, n, with_cs=False):
    rng = np.random.default_rng(seed)
    params = NetworkParams(
        p=jnp.asarray(rng.dirichlet(np.ones(n) * 2.0)),
        mu_c=jnp.asarray(rng.uniform(0.5, 4.0, n)),
        mu_d=jnp.asarray(rng.uniform(0.5, 4.0, n)),
        mu_u=jnp.asarray(rng.uniform(0.5, 4.0, n)))
    return params.with_cs(1.5) if with_cs else params


def assert_stats_equal(a, b, *, exact=True, err=""):
    for f in a._fields:
        x, y = np.asarray(getattr(a, f)), np.asarray(getattr(b, f))
        if exact:
            np.testing.assert_array_equal(x, y, err_msg=f"{err}{f}")
        else:
            np.testing.assert_allclose(x, y, rtol=1e-10, atol=1e-12,
                                       err_msg=f"{err}{f}")


# ---------------------------------------------------------------------------
# backend flag
# ---------------------------------------------------------------------------

def test_backend_flag_roundtrip_and_unknown_listed():
    prev = get_backend()
    try:
        for name in BACKENDS:
            set_backend(name)
            assert get_backend() == name
            assert resolve_backend(None) == name
        assert resolve_backend("reference") == "reference"
        with pytest.raises(ValueError,
                           match="batched.*pallas.*reference"):
            set_backend("cuda")
        with pytest.raises(ValueError, match="sim backend"):
            resolve_backend("jnp")
    finally:
        set_backend(prev)


def test_simulate_stats_lanes_rejects_unknown_backend():
    params = random_params(0, 3)
    with pytest.raises(ValueError, match="registered backends"):
        simulate_stats_lanes([params], [3], 10, backend="weibull")


# ---------------------------------------------------------------------------
# reference == batched (bitwise), vmapped lanes == stacked singles
# ---------------------------------------------------------------------------

def test_reference_equals_batched_bitwise_on_alike_lanes():
    rng = np.random.default_rng(3)
    base = random_params(1, 4)
    lanes = [base._replace(p=jnp.asarray(rng.dirichlet(np.ones(4))))
             for _ in range(3)]
    ms = [3, 6, 5]
    kw = dict(warmup=100, m_max=6, seeds=(0, 1, 2))
    ref = simulate_stats_lanes(lanes, ms, 800, backend="reference", **kw)
    bat = simulate_stats_lanes(lanes, ms, 800, backend="batched", **kw)
    assert_stats_equal(ref, bat, err="reference vs batched: ")


def test_batched_lanes_equal_stacked_singles():
    params = random_params(5, 3)
    keys = jax.random.split(jax.random.PRNGKey(42), 4)
    bat = simulate_stats_lanes([params] * 4, [5] * 4, 600, warmup=100,
                               keys=keys, m_max=5, backend="batched")
    for i, key in enumerate(keys):
        single = E.simulate_stats(params, 5, 600, warmup=100, key=key,
                                  m_max=5)
        one = jax.tree_util.tree_map(lambda a: a[i], bat)
        assert_stats_equal(one, single, err=f"lane {i}: ")


# ---------------------------------------------------------------------------
# pallas kernel: oracle contract + per-event trajectories vs reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("with_cs", [False, True])
def test_event_kernel_matches_jnp_oracle(with_cs):
    """Raw tables-level contract: kernel == jnp oracle, bitwise."""
    rng = np.random.default_rng(7)
    K, m_max, n = 3, 5, 4
    params = random_params(11, n, with_cs)
    st = jax.vmap(lambda k: E.init_state(params, 4, k, m_max=m_max))(
        jax.random.split(jax.random.PRNGKey(0), K))
    # drive a few reference steps so tables hold a nontrivial mix of phases
    for _ in range(7):
        st, _ = jax.vmap(lambda s: E.step_event(params, s))(st)
    fscal = jnp.asarray(rng.uniform(0.2, 2.0, (K, 4)))
    iscal = jnp.stack([jnp.asarray(rng.integers(0, n, K), jnp.int32),
                       st.seq_ctr, st.round], axis=-1).astype(jnp.int32)
    mu_c = jnp.broadcast_to(params.mu_c, (K, n))
    mu_u = jnp.broadcast_to(params.mu_u, (K, n))
    args = (st.finish, st.phase, st.client, st.seq, st.disp_round,
            mu_c, mu_u, fscal, iscal)
    got = event_step_tables(*args, has_cs=with_cs)
    want = event_step_oracle(*args, has_cs=with_cs)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


@pytest.mark.parametrize("dist,exact", [
    ("exponential", True), ("deterministic", True),
    ("lognormal", False), ("hyperexponential", False)])
def test_pallas_step_tracks_reference_trajectory(dist, exact):
    """Lock-step state comparison over 60 events at small n/m: bitwise for
    the scale-free unit draws, 1e-12 otherwise (one extra f64 rescale)."""
    params = random_params(0, 3)
    st_r = E.init_state(params, 4, jax.random.PRNGKey(1), m_max=4,
                        distribution=dist, warmup=2, cap=40)
    st_p = st_r
    for step in range(60):
        st_r, out_r = E.step_event(params, st_r, distribution=dist)
        st_p, out_p = step_event_pallas1(params, st_p, distribution=dist)
        for f in st_r._fields:
            a = np.asarray(getattr(st_r, f))
            b = np.asarray(getattr(st_p, f))
            if exact or not np.issubdtype(a.dtype, np.floating):
                assert np.array_equal(a, b), (dist, step, f)
            else:
                np.testing.assert_allclose(a, b, rtol=1e-12, atol=1e-12,
                                           err_msg=f"{dist} step {step} {f}")
        assert int(out_r.slot) == int(out_p.slot)
        assert bool(out_r.is_update) == bool(out_p.is_update)


def test_pallas_simulate_stats_bitwise_cs_power():
    """End-to-end simulate_stats through the kernel (CS buffer + energy
    accounting): bitwise vs the reference backend on the exponential law."""
    from repro.core import PowerProfile

    rng = np.random.default_rng(2)
    params = random_params(8, 4, with_cs=True)
    power = PowerProfile(P_c=jnp.asarray(rng.uniform(1, 5, 4)),
                         P_u=jnp.asarray(rng.uniform(0.5, 2, 4)),
                         P_d=jnp.asarray(rng.uniform(0.2, 1, 4)))
    kw = dict(warmup=50, m_max=6, power=power, seeds=(0, 1))
    ref = simulate_stats_lanes([params] * 2, [6, 4], 400,
                               backend="reference", **kw)
    pal = simulate_stats_lanes([params] * 2, [6, 4], 400,
                               backend="pallas", **kw)
    assert_stats_equal(ref, pal, err="reference vs pallas: ")


# ---------------------------------------------------------------------------
# occupancy carries (the O(1)-update refactor behind the batched speedup)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("with_cs", [False, True])
def test_occupancy_carries_match_full_recount(with_cs):
    params = random_params(4, 3, with_cs)
    st = E.init_state(params, 3, jax.random.PRNGKey(9), m_max=5)
    for _ in range(150):
        st, _ = E.step_event(params, st)
    down, comp_total, serving, up, cs_total, cs_busy = E._station_counts(
        st.phase, st.client, params.n)
    np.testing.assert_array_equal(
        np.asarray(st.occ),
        np.asarray(jnp.concatenate([down, comp_total, up, cs_total[None]])))
    np.testing.assert_array_equal(np.asarray(st.serving),
                                  np.asarray(serving))
    assert bool(st.cs_busy) == bool(cs_busy)


# ---------------------------------------------------------------------------
# distributional agreement vs the host reference simulator
# ---------------------------------------------------------------------------

def test_batched_lanes_agree_with_host_distributionally():
    """tests/test_events.py tolerances: throughput ~5-6%, staleness
    identity ~3%, through the multi-lane batched program."""
    params = random_params(8, 4)
    m = 6
    st = simulate_stats_lanes([params] * 2, [m, m], 20_000, warmup=3_000,
                              seeds=(0, 1), m_max=m, backend="batched")
    lam_th = float(throughput(params, m))
    p = np.asarray(params.p)
    for i in range(2):
        np.testing.assert_allclose(float(st.throughput[i]), lam_th,
                                   rtol=0.05)
        stale = float(np.sum(p * np.asarray(st.mean_delay[i])))
        np.testing.assert_allclose(stale, m - 1, rtol=0.03)
    host = AsyncNetworkSim(params, m, seed=0).run(20_000, warmup=3_000)
    np.testing.assert_allclose(float(st.throughput[0]), host.throughput,
                               rtol=0.06)


# ---------------------------------------------------------------------------
# Scenario integration: SimSpec / DataSpec / suite routing + result cache
# ---------------------------------------------------------------------------

def _scenario(**kw):
    from repro.scenario import NetworkSpec, Scenario, StrategySpec

    net = NetworkSpec(mu_c=[1.0, 2.0, 1.5], mu_d=[2.0] * 3, mu_u=[2.0] * 3)
    return Scenario(network=net, strategy=StrategySpec("asyncsgd"), **kw)


def test_simspec_dataspec_roundtrip_bitwise():
    from repro.scenario import DataSpec, Scenario, SimSpec

    scn = _scenario(sim=SimSpec(backend="pallas", interpret=True),
                    data=DataSpec(partition="dirichlet", alpha=0.35,
                                  num_classes=3, samples_per_class=17,
                                  test_fraction=0.2, seed=5))
    back = Scenario.from_json(scn.to_json())
    assert back == scn
    assert back.hash() == scn.hash()
    assert back.sim.backend == "pallas" and back.data.alpha == 0.35
    # scenarios without the new specs keep their canonical JSON (and hash)
    plain = _scenario()
    assert "sim" not in plain.to_dict() and "data" not in plain.to_dict()


def test_simspec_validates_backend_eagerly():
    from repro.scenario import SimSpec

    with pytest.raises(ValueError, match="registered backends"):
        SimSpec(backend="gpu")


def test_dataspec_validates_eagerly():
    from repro.scenario import DataSpec

    with pytest.raises(ValueError, match="registered partitions"):
        DataSpec(partition="by_vibes")
    with pytest.raises(ValueError, match="datasets"):
        DataSpec(dataset="imagenet")


def test_suite_simulate_backends_bitwise_and_cached():
    from repro.scenario import ScenarioSuite, SimSpec

    def make():
        return ScenarioSuite(
            {"a": _scenario(), "b": _scenario()}, seeds=(0, 1))

    kw = dict(num_updates=300, warmup=50)
    res_b = make().run(mode="simulate", backend="batched", **kw)
    res_r = make().run(mode="simulate", backend="reference", **kw)
    assert res_b.cache_hits == 0
    for name in res_b.entries:
        for sb, sr in zip(res_b.entries[name], res_r.entries[name]):
            assert_stats_equal(sb, sr, err=f"{name}: ")

    # result cache: identical re-run is served entirely from cache
    suite = make()
    first = suite.run(mode="simulate", **kw)
    again = suite.run(mode="simulate", **kw)
    assert first.cache_hits == 0
    assert again.cache_hits == len(suite.scenarios)
    for name in first.entries:
        for sa, sb in zip(first.entries[name], again.entries[name]):
            assert_stats_equal(sa, sb)
    # changed settings miss the cache
    other = suite.run(mode="simulate", num_updates=301, warmup=50)
    assert other.cache_hits == 0

    # a SimSpec pins the backend per scenario (bitwise same stats here)
    pinned = ScenarioSuite(
        {"a": _scenario(sim=SimSpec(backend="reference")),
         "b": _scenario()}, seeds=(0, 1))
    res_p = pinned.run(mode="simulate", **kw)
    assert res_p.programs == 2  # one per backend bucket
    for name in res_p.entries:
        for sp, sb in zip(res_p.entries[name], first.entries[name]):
            assert_stats_equal(sp, sb, err=f"pinned {name}: ")


def test_simulate_cache_key_tracks_effective_table_size():
    """Review regression: the result-cache key must carry the *effective*
    m_max (the bucket's max m), not the raw kwarg — otherwise a cached
    entry computed under one bucket composition is served where a fresh
    run would have used a larger table (different trajectories)."""
    from repro.scenario import ScenarioSuite, SimSpec, StrategySpec

    def explicit(m, **kw):
        return _scenario(**kw).replace(strategy=StrategySpec(
            "explicit", p=np.full(3, 1.0 / 3), m=m))

    scns = {"a": explicit(5, sim=SimSpec(backend="reference")),
            "b": explicit(3)}
    suite = ScenarioSuite(dict(scns), seeds=(0,))
    suite.run(mode="simulate", num_updates=200)  # a@mx=5, b@mx=3 buckets
    # forcing one backend merges the buckets: b now shares a's mx=5 table
    merged = suite.run(mode="simulate", num_updates=200,
                       backend="reference")
    fresh = ScenarioSuite(dict(scns), seeds=(0,)).run(
        mode="simulate", num_updates=200, backend="reference")
    for name in scns:
        assert_stats_equal(merged.entries[name][0], fresh.entries[name][0],
                           err=f"{name}: ")


def test_train_trainer_memo_not_stale_across_test_data():
    """Review regression: same model/clients but a new test_data object
    must rebuild the trainer (not evaluate against the superseded set)."""
    from repro.fl import mlp_classifier
    from repro.scenario import DataSpec, ScenarioSuite

    scn = _scenario(data=DataSpec(num_classes=4, samples_per_class=20))
    suite = ScenarioSuite(scn, seeds=(0,))
    clients, (tx, ty) = scn.data.build(scn.n)
    model = mlp_classifier(28 * 28, 4, hidden=(8,))
    rng = np.random.default_rng(0)
    kw = dict(model=model, clients=clients, horizon_time=20.0,
              batch_size=8, eval_every_time=10.0)
    r1 = suite.run(mode="train", test_data=(tx, ty), **kw)
    # same arrays, labels shuffled: accuracies must reflect the NEW set
    r2 = suite.run(mode="train",
                   test_data=(tx, np.asarray(ty)[rng.permutation(len(ty))]),
                   **kw)
    name = list(r1.entries)[0]
    acc1 = r1.entries[name][0].accuracies
    acc2 = r2.entries[name][0].accuracies
    assert r2.cache_hits == 0
    assert acc1 != acc2


def test_suite_train_builds_clients_from_dataspec():
    from repro.fl import mlp_classifier
    from repro.scenario import DataSpec, ScenarioSuite

    scn = _scenario(data=DataSpec(num_classes=4, samples_per_class=20))
    suite = ScenarioSuite(scn, seeds=(0,))
    model = mlp_classifier(28 * 28, 4, hidden=(8,))
    res = suite.run(mode="train", model=model, horizon_time=25.0,
                    batch_size=8, eval_every_time=12.5)
    log = res.entries[list(res.entries)[0]][0]
    assert log.updates[-1] > 0 and np.isfinite(log.losses).all()
    # identical re-run hits the result cache (same model object)
    res2 = suite.run(mode="train", model=model, horizon_time=25.0,
                     batch_size=8, eval_every_time=12.5)
    assert res2.cache_hits == 1
    # a scenario without DataSpec still requires explicit clients
    bare = ScenarioSuite(_scenario(), seeds=(0,))
    with pytest.raises(ValueError, match="DataSpec"):
        bare.run(mode="train", model=model, horizon_time=5.0)
