"""Per-architecture smoke tests (reduced configs) + decode/forward parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import build_model


def make_batch(cfg, B, S, seed=0):
    rng = np.random.default_rng(seed)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
    }
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.asarray(
            rng.normal(0, 0.02, (B, cfg.num_image_tokens, cfg.d_model)),
            jnp.dtype(cfg.dtype))
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.normal(0, 0.02, (B, cfg.encoder_frames, cfg.d_model)),
            jnp.dtype(cfg.dtype))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke(arch):
    """Reduced variant: one forward + one train step, shapes + finiteness."""
    cfg = get_config(arch).reduced()
    assert cfg.d_model <= 512 and cfg.n_groups <= 2
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = make_batch(cfg, B, S)
    loss, metrics = bundle.loss_fn(params, batch)
    assert np.isfinite(float(loss))
    opt_state = bundle.optimizer.init(params)
    params2, opt_state, metrics = jax.jit(bundle.train_step)(
        params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually changed
    diffs = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        params, params2)
    assert max(jax.tree_util.tree_leaves(diffs)) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_decode_smoke(arch):
    cfg = get_config(arch).reduced()
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    B = 2
    cache = bundle.init_cache(B, 32)
    tok = jnp.zeros((B, 1), jnp.int32)
    step = jax.jit(bundle.decode_step)
    logits, cache = step(params, cache, tok, jnp.int32(0))
    assert logits.shape == (B, 1, cfg.vocab)
    logits, cache = step(params, cache, tok, jnp.int32(1))
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch", ["qwen3-8b", "xlstm-350m", "jamba-v0.1-52b",
                                  "qwen2-moe-a2.7b", "qwen2-vl-2b",
                                  "internlm2-1.8b", "kimi-k2-1t-a32b"])
def test_decode_matches_forward(arch):
    """Token-by-token decode reproduces the full teacher-forced logits —
    validates KV caches, ring buffers, and all recurrent state updates."""
    cfg = get_config(arch).reduced()
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(1))
    B, T = 1, 10
    batch = make_batch(cfg, B, T, seed=3)
    from repro.models import lm as lm_mod
    from repro.models.parallel import ParallelContext
    ctx = ParallelContext()
    image_embeds = batch.get("image_embeds")
    out = lm_mod.lm_forward(params, cfg, ctx, batch["tokens"],
                            image_embeds=image_embeds)
    full_logits = np.asarray(out.logits)  # [B, n_img + T, V]
    n_img = image_embeds.shape[1] if image_embeds is not None else 0

    cache = bundle.init_cache(B, n_img + T + 2)
    step = jax.jit(bundle.decode_step)
    if n_img:
        # feed image embeddings through decode? (vlm decode covers text only;
        # skip the image prefix by decoding from the cacheless forward)
        pytest.skip("vlm decode parity covered by text-only path below")
    for t in range(T):
        logits, cache = step(params, cache, batch["tokens"][:, t:t + 1],
                             jnp.int32(t))
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]), full_logits[:, n_img + t],
            rtol=2e-2, atol=2e-3)


def test_whisper_decode_matches_forward():
    cfg = get_config("whisper-medium").reduced()
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(1))
    B, T = 1, 8
    batch = make_batch(cfg, B, T, seed=5)
    from repro.models import encdec
    from repro.models.parallel import ParallelContext
    ctx = ParallelContext()
    enc_out = encdec.encode(params, cfg, batch["frames"], ctx)
    full_logits = np.asarray(
        encdec.decode_train(params, cfg, batch["tokens"], enc_out, ctx))
    cache = encdec.build_decode_cache(params, cfg, enc_out, T + 1, ctx)
    for t in range(T):
        logits, cache = encdec.decode_step(params, cfg, cache,
                                           batch["tokens"][:, t:t + 1],
                                           jnp.int32(t), ctx)
        np.testing.assert_allclose(np.asarray(logits[:, 0]), full_logits[:, t],
                                   rtol=2e-2, atol=2e-3)


def test_sliding_window_decode_ring_buffer():
    """SWA decode with a ring cache equals full attention restricted to the
    window (positions beyond the window are masked out)."""
    cfg = get_config("internlm2-1.8b").reduced(sliding_window=None)
    bundle_full = build_model(cfg)
    params = bundle_full.init(jax.random.PRNGKey(2))
    W = 4
    bundle_swa = build_model(cfg, window_override=W)
    B, T = 1, 9
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, T)), jnp.int32)
    # reference: full forward with window mask
    from repro.models import lm as lm_mod
    from repro.models.parallel import ParallelContext
    ctx = ParallelContext()
    out = lm_mod.lm_forward(params, cfg, ctx, toks, window=W)
    ref = np.asarray(out.logits)
    cache = bundle_swa.init_cache(B, T, use_window=W)
    step = jax.jit(bundle_swa.decode_step)
    for t in range(T):
        logits, cache = step(params, cache, toks[:, t:t + 1], jnp.int32(t))
        np.testing.assert_allclose(np.asarray(logits[:, 0]), ref[:, t],
                                   rtol=2e-2, atol=2e-3)


def test_tiny_lm_learns():
    """A reduced dense LM overfits a tiny Markov dataset (loss drops)."""
    from repro.data import make_language_modeling_dataset
    cfg = get_config("internlm2-1.8b").reduced(vocab=128, n_layers=2)
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    ds = make_language_modeling_dataset(num_sequences=64, seq_len=32,
                                        vocab=128, seed=0)
    opt_state = bundle.optimizer.init(params)
    step = jax.jit(bundle.train_step)
    rng = np.random.default_rng(0)
    losses = []
    for it in range(60):
        idx = rng.integers(0, 64, size=8)
        toks = ds.tokens[idx]
        batch = {"tokens": jnp.asarray(toks[:, :-1]),
                 "targets": jnp.asarray(toks[:, 1:])}
        params, opt_state, m = step(params, opt_state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[::10]
