"""Roofline machinery: HLO collective scraping, param counting, mesh fn."""
import numpy as np
import pytest

from repro.launch.roofline import (CollectiveStats, parse_collectives,
                                   count_params, model_flops, _shape_bytes,
                                   _link_factor)
from repro.models.config import INPUT_SHAPES


HLO = """
HloModule jit_f

%region_0 (a: f32[]) -> f32[] { ... }

%body.1 (arg: (s32[], f32[16,128])) -> (s32[], f32[16,128]) {
  %ar = f32[16,128]{1,0} all-reduce(%x), channel_id=1, replica_groups=[16,16]<=[256], use_global_device_ids=true, to_apply=%region_0
  ROOT %t = tuple(...)
}

ENTRY %main {
  %w = while((s32[], f32[16,128]) %init), condition=%cond, body=%body.1, backend_config={"known_trip_count":{"n":"12"}}
  %ag = bf16[32,1024]{1,0} all-gather(%y), channel_id=2, replica_groups=[16,16]<=[256], dimensions={1}
  %cp = f32[8,8]{1,0} collective-permute(%z), channel_id=3, source_target_pairs={{0,1}}
  %a2a = (f32[4,64]{1,0}, f32[4,64]{1,0}) all-to-all(%u, %v), channel_id=4, replica_groups=[32,8]<=[256]
}
"""


def test_shape_bytes():
    assert _shape_bytes("f32[16,128]{1,0}") == 16 * 128 * 4
    assert _shape_bytes("bf16[32,1024]") == 32 * 1024 * 2
    assert _shape_bytes("(f32[4,64], f32[4,64])") == 2 * 4 * 64 * 4
    assert _shape_bytes("pred[]") == 1


def test_parse_collectives_with_trip_counts():
    stats = parse_collectives(HLO)
    # all-reduce inside the while body is weighted by trip count 12
    assert stats.counts["all-reduce"] == 12
    assert stats.output_bytes["all-reduce"] == 12 * 16 * 128 * 4
    assert stats.counts["all-gather"] == 1
    assert stats.counts["collective-permute"] == 1
    assert stats.counts["all-to-all"] == 1
    # link bytes: ring factors applied with parsed group sizes
    expected = (12 * 16 * 128 * 4 * 2 * 15 / 16        # all-reduce n=16
                + 32 * 1024 * 2 * 15 / 16              # all-gather n=16
                + 8 * 8 * 4 * 1                        # permute
                + 2 * 4 * 64 * 4 * 7 / 8)              # all-to-all n=8
    np.testing.assert_allclose(stats.link_bytes, expected, rtol=1e-9)


def test_link_factors():
    assert _link_factor("all-reduce", 16) == pytest.approx(2 * 15 / 16)
    assert _link_factor("all-gather", 4) == pytest.approx(3 / 4)
    assert _link_factor("collective-permute", 8) == 1.0
    assert _link_factor("all-reduce", 1) == 0.0


@pytest.mark.parametrize("arch,expected_b,tol", [
    ("internlm2-1.8b", 1.9e9, 0.15),
    ("qwen3-8b", 8.2e9, 0.15),
    ("llama3-405b", 405e9, 0.10),
    # granite-34b/whisper use 2-matrix MLPs upstream; this framework's blocks
    # are SwiGLU (3-matrix), so the assigned layer dims give ~47B / ~1.0B.
    ("granite-34b", 47e9, 0.10),
    ("whisper-medium", 1.0e9, 0.15),
])
def test_count_params_matches_model_cards(arch, expected_b, tol):
    from repro.configs import get_config
    total, active = count_params(get_config(arch))
    assert abs(total - expected_b) / expected_b < tol, total
    assert active <= total + 1


def test_moe_active_params():
    from repro.configs import get_config
    total, active = count_params(get_config("kimi-k2-1t-a32b"))
    assert total > 0.8e12          # ~1T total
    assert 20e9 < active < 60e9    # ~32B active


def test_count_params_matches_actual_init():
    """Analytic count == actual initialized leaf count (reduced configs)."""
    import jax
    from repro.configs import get_config
    from repro.models import build_model
    for arch in ("internlm2-1.8b", "qwen2-moe-a2.7b", "xlstm-350m"):
        cfg = get_config(arch).reduced()
        bundle = build_model(cfg)
        params = jax.eval_shape(bundle.init, jax.random.PRNGKey(0))
        actual = sum(int(np.prod(l.shape))
                     for l in jax.tree_util.tree_leaves(params))
        analytic, _ = count_params(cfg)
        # analytic ignores norms/small biases: allow 5%
        assert abs(actual - analytic) / actual < 0.05, (arch, actual, analytic)


def test_model_flops_shapes():
    from repro.configs import get_config
    cfg = get_config("qwen3-8b")
    f_train = model_flops(cfg, INPUT_SHAPES["train_4k"], 256)
    f_dec = model_flops(cfg, INPUT_SHAPES["decode_32k"], 256)
    assert f_train > f_dec * 1000


def test_make_mesh_shapes():
    # mesh construction (the 512-device dry-run variant runs in subprocess
    # tests; here we only validate the host mesh helper)
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh()
    assert mesh.axis_names == ("data", "model")
