"""Telemetry-ring overhead benchmark: rings off vs on, plus drift.

The observability contract (``repro.obs``) promises two things a bench
can hold it to:

* tracing OFF is free — the untraced program is byte-identical to the
  pre-obs one (property-tested in ``tests/test_obs.py``), so the
  ``obs_rings_off`` row IS the baseline;
* tracing ON costs a bounded constant factor — the ring is two fused
  dynamic-update-slices per event inside the same scan.  The
  ``obs_rings_on`` row records the measured ratio and the run *fails*
  if it leaves ``MAX_OVERHEAD_RATIO`` (a regression guard, not a perf
  target: a blown ratio means the ring stopped fusing).

The ``obs_drift`` row runs the closed-form drift monitor on the traced
run and reports the worst relative error across checks — the same
comparison ``python -m repro.obs check`` gates in CI, riding along here
so the number lands in the perf trajectory too.
"""
from __future__ import annotations

import time

NUM_UPDATES = 1500
WARMUP = 150
REPS = 4
#: regression guard on traced/untraced wall-clock (generous: smoke-scale
#: runs are jitter-prone; the ring's steady-state cost is ~1.2-1.6x)
MAX_OVERHEAD_RATIO = 5.0


def _scenario(traced: bool):
    from benchmarks import scenarios as bench_scenarios
    from repro.scenario import Scenario

    scn = bench_scenarios.obs_scenario()
    if traced:
        return bench_scenarios.record("obs", scn)
    d = scn.to_dict()
    d.pop("sim", None)  # same spec with the ring disabled
    return Scenario.from_dict(d)


def _time(scn, caches) -> float:
    """Mean seconds per suite dispatch, post-compile, cache-miss seeds."""
    from repro.scenario import ScenarioSuite

    ScenarioSuite({"obs": scn}, seeds=(999,), caches=caches).run(
        mode="simulate", num_updates=NUM_UPDATES, warmup=WARMUP)  # warm
    t0 = time.perf_counter()
    for rep in range(REPS):
        ScenarioSuite({"obs": scn}, seeds=(rep,), caches=caches).run(
            mode="simulate", num_updates=NUM_UPDATES, warmup=WARMUP)
    return (time.perf_counter() - t0) / REPS


def run():
    from repro.scenario import ScenarioSuite
    from repro.scenario.suite import SuiteCaches

    caches = SuiteCaches()
    t_off = _time(_scenario(traced=False), caches)
    scn_on = _scenario(traced=True)
    t_on = _time(scn_on, caches)
    ratio = t_on / t_off
    yield f"obs_rings_off,{t_off * 1e6:.1f},baseline_untraced"
    yield (f"obs_rings_on,{t_on * 1e6:.1f},"
           f"overhead_ratio={ratio:.2f};guard={MAX_OVERHEAD_RATIO:.1f}")
    if ratio > MAX_OVERHEAD_RATIO:
        raise AssertionError(
            f"telemetry-ring overhead {ratio:.2f}x exceeds the "
            f"{MAX_OVERHEAD_RATIO:.1f}x guard — the ring appends likely "
            f"stopped fusing into the event scan")

    t0 = time.perf_counter()
    res = ScenarioSuite({"obs": scn_on}, seeds=(0,), caches=caches).run(
        mode="simulate", num_updates=NUM_UPDATES, warmup=WARMUP)
    t_drift = time.perf_counter() - t0
    rep = res.drift["obs"][0]
    worst = max((c["rel_err"] for c in rep["checks"]), default=0.0)
    yield (f"obs_drift,{t_drift * 1e6:.1f},"
           f"ok={rep['ok']};worst_rel_err={worst:.4f};"
           f"checks={len(rep['checks'])}")
