"""Figure 2 analogue: E0[tau_eps] over (m, p1) for the two-client system,
homogeneous and heterogeneous (client 2 = 3x faster).

The whole (24 x 17) surface is evaluated in ONE jitted batch via
``repro.core.batched.tau_surface`` (padded traced-m closed forms + batched
Buzen DP) instead of 408 per-point retraces."""
from __future__ import annotations

import time

import numpy as np

from repro.core.batched import tau_surface

from .common import row
from .scenarios import record, two_client_scenario


def surface(mu2: float):
    scn = record("tau_surface", two_client_scenario(mu2))
    params = scn.params(p=[0.5, 0.5])
    CONSTS = scn.consts
    p1s = np.linspace(0.1, 0.9, 17)
    ms = np.arange(1, 25)
    p_rows = np.stack([p1s, 1.0 - p1s], axis=-1)
    grid = np.asarray(tau_surface(params, CONSTS, ms, p_rows))  # [24, 17]
    flat = int(np.argmin(grid))
    mi, pj = np.unravel_index(flat, grid.shape)
    return int(ms[mi]), p1s[pj], grid.min(), grid[0].min(), grid

def run() -> list[str]:
    out = []
    t0 = time.perf_counter()
    m_h, p1_h, best_h, serial_h, _ = surface(1.0)
    m_x, p1_x, best_x, serial_x, _ = surface(3.0)
    us = (time.perf_counter() - t0) * 1e6
    out.append(row("fig2_tau_homogeneous", us / 2,
                   f"m*={m_h}_p1*={p1_h:.2f}_tau*={best_h:.1f}_vs_m1={serial_h:.1f}"))
    out.append(row("fig2_tau_heterogeneous", us / 2,
                   f"m*={m_x}_p1*={p1_x:.2f}_tau*={best_x:.1f}_vs_m1={serial_x:.1f}"))
    # paper claim: interior optimum m* > 1, and heterogeneous routing favors
    # the fast client (p1 < 0.5 = less weight on slow client 1)
    out.append(row("fig2_claims", 0.0,
                   f"interior_opt={m_h > 1 and m_x > 1};fast_client_favored={p1_x < 0.5}"))
    return out
