"""Table 5 analogue: relative time and energy reduction of (p*_rho, m*_rho)
at rho = 0.1 vs AsyncSGD on simulated async FL training with the Table-4
power profiles.  Paper reports 36-49% energy savings at comparable speed.

Declarative: one energy-aware Scenario, two strategies resolved by the
registry, the seeds x strategies grid trained through
``ScenarioSuite.run(mode="train")`` on the fused device engine."""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.data import (dirichlet_partition, make_synthetic_image_dataset,
                        train_test_split)
from repro.fl import mlp_classifier
from repro.scenario import ScenarioSuite

from .common import row
from .scenarios import record, table1_scenario


def run(scale: int = 10, horizon: float = 240.0, target: float = 0.55,
        dists=("exponential",), seeds=(0, 1)) -> list[str]:
    out = []
    base = record("energy_joint",
                  table1_scenario(scale, strategy="joint", with_power=True,
                                  steps=200, eta=0.05, rho=0.1,
                                  name=f"energy_joint_s{scale}"))
    base = base.replace(strategy=dataclasses.replace(base.strategy,
                                                     m_max=base.n + 6))
    n = base.n

    full = make_synthetic_image_dataset(num_classes=10, samples_per_class=120,
                                        seed=2)
    train, test_ds = train_test_split(full, 0.2, seed=3)
    parts = dirichlet_partition(train.y, n, alpha=0.2, seed=2)
    clients = [(train.x[i], train.y[i]) for i in parts]
    test = (test_ds.x, test_ds.y)

    # resolve once (closed forms are law-independent), pin as explicit
    # strategies per service law — mirrors bench_training_comparison
    res_suite = ScenarioSuite.strategy_grid(base, ("asyncsgd", "joint"))
    strat = res_suite.resolve()

    t0 = time.perf_counter()
    for dist in dists:
        # both strategies x all seeds in ONE fused, vmapped device scan
        net = dataclasses.replace(base.network, law=dist)
        scns = {name: src.replace(
                    network=net,
                    strategy=dataclasses.replace(src.strategy,
                                                 name="explicit",
                                                 p=strat[name][0],
                                                 m=strat[name][1]))
                for name, src in res_suite.scenarios.items()}
        suite = ScenarioSuite(scns, seeds=seeds)
        model = mlp_classifier(28 * 28, 10, hidden=(64,))
        grid = suite.run(mode="train", model=model, clients=clients,
                         test_data=test, horizon_time=horizon,
                         batch_size=32, eval_every_time=horizon / 60)
        res = {}
        for name, logs in grid.entries.items():
            ts, es = [], []
            for log in logs:
                t_hit = log.time_to_accuracy(target)
                ts.append(t_hit)
                # energy consumed up to the hit time (linear interpolation of
                # cumulative energy over the horizon run)
                frac = min(t_hit, horizon) / max(log.times[-1], 1e-9)
                es.append(log.energy * frac)
            res[name] = (float(np.mean(ts)), float(np.mean(es)))
        (t0_, e0_), (t1_, e1_) = res["asyncsgd"], res["joint"]
        dt = 100 * (1 - t1_ / t0_) if np.isfinite(t1_ / t0_) else float("nan")
        de = 100 * (1 - e1_ / e0_)
        out.append(row(f"table5_joint_rho0.1_{dist}", 0.0,
                       f"time_reduction={dt:.1f}%_energy_reduction={de:.1f}%"
                       f"_m_joint={strat['joint'][1]}"))
    us = (time.perf_counter() - t0) * 1e6
    out.append(row("table5_total_bench", us, f"target={target}"))
    return out
