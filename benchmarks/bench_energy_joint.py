"""Table 5 analogue: relative time and energy reduction of (p*_rho, m*_rho)
at rho = 0.1 vs AsyncSGD on simulated async FL training with the Table-4
power profiles.  Paper reports 36-49% energy savings at comparable speed."""
from __future__ import annotations

import time

import numpy as np

from repro.core import LearningConstants
from repro.data import (dirichlet_partition, make_synthetic_image_dataset,
                        train_test_split)
from repro.fl import (AsyncFLConfig, make_strategies, mlp_classifier,
                      run_strategy_grid)
from repro.fl.strategies import (PAPER_CLUSTERS_TABLE1, build_network_params,
                                 build_power_profile)

from .common import row

CONSTS = LearningConstants(L=1.0, delta=1.0, sigma=1.0, M=2.0, G=5.0, eps=1.0)


def run(scale: int = 10, horizon: float = 240.0, target: float = 0.55,
        dists=("exponential",), seeds=(0, 1)) -> list[str]:
    out = []
    net = build_network_params(PAPER_CLUSTERS_TABLE1, scale=scale)
    power = build_power_profile(PAPER_CLUSTERS_TABLE1, scale=scale)
    n = net.n
    strat = make_strategies(net, CONSTS, power=power, rho=0.1, steps=200,
                            m_max=n + 6, which=("asyncsgd", "time_opt",
                                                "joint"))

    full = make_synthetic_image_dataset(num_classes=10, samples_per_class=120,
                                        seed=2)
    train, test_ds = train_test_split(full, 0.2, seed=3)
    parts = dirichlet_partition(train.y, n, alpha=0.2, seed=2)
    clients = [(train.x[i], train.y[i]) for i in parts]
    test = (test_ds.x, test_ds.y)

    t0 = time.perf_counter()
    for dist in dists:
        # both strategies x all seeds in ONE fused, vmapped device scan
        cfg = AsyncFLConfig(eta=0.05, batch_size=32,
                            eval_every_time=horizon / 60,
                            distribution=dist, grad_clip=5.0)
        model = mlp_classifier(28 * 28, 10, hidden=(64,))
        grid = run_strategy_grid(
            model, clients, net,
            {k: strat[k] for k in ("asyncsgd", "joint")}, cfg,
            horizon_time=horizon, seeds=seeds, etas=0.05,
            test_data=test, power=power)
        res = {}
        for name, logs in grid.logs.items():
            ts, es = [], []
            for log in logs:
                t_hit = log.time_to_accuracy(target)
                ts.append(t_hit)
                # energy consumed up to the hit time (linear interpolation of
                # cumulative energy over the horizon run)
                frac = min(t_hit, horizon) / max(log.times[-1], 1e-9)
                es.append(log.energy * frac)
            res[name] = (float(np.mean(ts)), float(np.mean(es)))
        (t0_, e0_), (t1_, e1_) = res["asyncsgd"], res["joint"]
        dt = 100 * (1 - t1_ / t0_) if np.isfinite(t1_ / t0_) else float("nan")
        de = 100 * (1 - e1_ / e0_)
        out.append(row(f"table5_joint_rho0.1_{dist}", 0.0,
                       f"time_reduction={dt:.1f}%_energy_reduction={de:.1f}%"
                       f"_m_joint={strat['joint'][1]}"))
    us = (time.perf_counter() - t0) * 1e6
    out.append(row("table5_total_bench", us, f"target={target}"))
    return out
