"""Table 2 / Table 7 analogue: optimized routing probabilities and staleness
impact factors per cluster, for the Table-1 population (scaled for CPU).

Strategy resolution AND the closed-form reporting both run through the
Scenario API: one ``ScenarioSuite.strategy_grid`` resolves the four
configurations via the strategy registry, and ``run(mode="analyze")``
evaluates throughput / delays for all of them in a single jitted batch."""
from __future__ import annotations

import time

import numpy as np

from repro.scenario import ScenarioSuite

from .common import row
from .scenarios import record, table1_scenario

STRATEGIES = ("asyncsgd", "max_throughput", "round_opt", "time_opt")


def run(scale: int = 5, steps: int = 250) -> list[str]:
    out = []
    base = record("routing_table",
                  table1_scenario(scale, strategy="time_opt", steps=steps,
                                  name=f"routing_table_s{scale}"))
    labels = np.array(base.network.labels)
    n = base.n

    t0 = time.perf_counter()
    suite = ScenarioSuite.strategy_grid(base, STRATEGIES, m_max=n + 8)
    res = suite.run(mode="analyze")
    us = (time.perf_counter() - t0) * 1e6

    lam = {}
    for name in STRATEGIES:
        ent = res.entries[name]
        p, m = ent["p"], ent["m"]
        lam[name] = ent["throughput"]
        impact = np.asarray(ent["delays"]) / np.maximum(p, 1e-12) ** 2
        per_cluster_p = {}
        per_cluster_i = {}
        for lab, pi, ii in zip(labels, p, impact):
            per_cluster_p.setdefault(lab, []).append(pi)
            per_cluster_i.setdefault(lab, []).append(ii)
        summary = ";".join(
            f"{lab}:p={np.mean(per_cluster_p[lab]) * 100:.3f}%"
            f":impact={np.mean(per_cluster_i[lab]):.1f}"
            for lab in sorted(per_cluster_p))
        out.append(row(f"table2_routing_{name}_m{m}", 0.0, summary))

    out.append(row("table2_strategy_optimization", us,
                   "lambda:" + ";".join(f"{k}={v:.2f}" for k, v in lam.items())))
    # paper's qualitative claims to check downstream: lambda order
    ok = lam["max_throughput"] >= lam["asyncsgd"] >= lam["round_opt"]
    out.append(row("table2_throughput_ordering", 0.0,
                   f"max>=uni>=roundopt:{ok}"))
    out.append(row("table2_analyze_programs", 0.0,
                   f"scenarios={len(suite)}_programs={res.programs}"))
    return out
