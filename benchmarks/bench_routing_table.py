"""Table 2 / Table 7 analogue: optimized routing probabilities and staleness
impact factors per cluster, for the Table-1 population (scaled for CPU)."""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import (LearningConstants, expected_relative_delay, throughput)
from repro.fl import make_strategies
from repro.fl.strategies import (PAPER_CLUSTERS_TABLE1, build_network_params,
                                 cluster_labels)

from .common import row

CONSTS = LearningConstants(L=1.0, delta=1.0, sigma=1.0, M=2.0, G=5.0, eps=1.0)


def run(scale: int = 5, steps: int = 250) -> list[str]:
    out = []
    params = build_network_params(PAPER_CLUSTERS_TABLE1, scale=scale)
    labels = cluster_labels(PAPER_CLUSTERS_TABLE1, scale=scale)
    n = params.n

    t0 = time.perf_counter()
    strat = make_strategies(params, CONSTS, steps=steps, m_max=n + 8,
                            which=("asyncsgd", "max_throughput", "round_opt",
                                   "time_opt"))
    us = (time.perf_counter() - t0) * 1e6

    lam = {}
    for name, (p, m) in strat.items():
        pj = jnp.asarray(p)
        lam[name] = float(throughput(params._replace(p=pj), m))
        d = np.asarray(expected_relative_delay(params._replace(p=pj), m))
        impact = d / np.maximum(p, 1e-12) ** 2
        per_cluster_p = {}
        per_cluster_i = {}
        for lab, pi, ii in zip(labels, p, impact):
            per_cluster_p.setdefault(lab, []).append(pi)
            per_cluster_i.setdefault(lab, []).append(ii)
        summary = ";".join(
            f"{lab}:p={np.mean(per_cluster_p[lab]) * 100:.3f}%"
            f":impact={np.mean(per_cluster_i[lab]):.1f}"
            for lab in sorted(per_cluster_p))
        out.append(row(f"table2_routing_{name}_m{m}", 0.0, summary))

    out.append(row("table2_strategy_optimization", us,
                   "lambda:" + ";".join(f"{k}={v:.2f}" for k, v in lam.items())))
    # paper's qualitative claims to check downstream: lambda order
    ok = lam["max_throughput"] >= lam["asyncsgd"] >= lam["round_opt"]
    out.append(row("table2_throughput_ordering", 0.0,
                   f"max>=uni>=roundopt:{ok}"))
    return out
