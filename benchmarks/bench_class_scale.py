"""Class-aggregation scaling sweep: population size as a free variable.

The class axis makes every cost O(#classes) instead of O(n):

  * closed forms — the class Buzen DP + class-weighted population sums
    (``repro.core.batched.*_classes``) vs the padded per-client forms at
    the same (n, m); the tracked number is ``speedup_vs_per_client`` at
    n = 10^4 (the per-client DP is O(n m^2), the class DP O(C m^2));
  * event engine — the class-aggregated engine
    (``repro.core.events.simulate_stats_classes``) across
    n = 10^2 / 10^4 / 10^6 members at fixed C: all three share ONE
    compiled program (the population enters only through the ``count``
    data), so the per-event cost column is n-independent;
  * suite sharding — the same class suite through ``backend="batched"``
    vs ``backend="sharded"`` (``repro.sim.sharded``): bitwise-equal
    entries, lanes split across all local devices (1 on plain CPU;
    the CI leg forces 8 with ``--xla_force_host_platform_device_count``).

Rows are keyed by ``Scenario.hash()`` into ``BENCH_smoke.json`` via the
``class_scale`` entry of ``benchmarks.scenarios.BENCH_SCENARIOS``.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.batched import (round_complexity_padded,
                                round_complexity_classes, throughput_padded,
                                wallclock_time_classes)
from repro.core.buzen import (class_log_normalizing_constants,
                              log_normalizing_constants)
from repro.scenario import ScenarioSuite
from repro.sim.sharded import device_count

from .common import row, time_us
from .scenarios import CONSTS as _CONSTS
from .scenarios import class_scale_scenario, record


def _class_forms_fn(m_max: int):
    @jax.jit
    def fn(classes, m):
        logZ = class_log_normalizing_constants(classes, m_max)
        return (throughput_padded(logZ, m),
                round_complexity_classes(classes, m, _CONSTS, logZ, m_max))

    return fn


def _client_forms_fn(m_max: int):
    @jax.jit
    def fn(prm, m):
        logZ = log_normalizing_constants(prm, m_max)
        return (throughput_padded(logZ, m),
                round_complexity_padded(prm, m, _CONSTS, logZ, m_max))

    return fn


def run(ns=(100, 10_000, 1_000_000), Cs=(1, 4, 16), m: int = 8,
        m_max: int = 16, num_updates: int = 300, warmup: int = 100,
        seeds=(0, 1), client_ns=(100, 10_000)) -> list[str]:
    out = []
    record("class_scale", class_scale_scenario(10_000, 4, m=m))

    # -- closed forms: class-space across the (n, C) grid, per-client
    #    comparison where the expansion is still tractable ------------------
    class_fn = _class_forms_fn(m_max)  # one jit; each C retraces once
    client_fn = _client_forms_fn(m_max)
    for n in ns:
        for C in Cs:
            scn = class_scale_scenario(n, C, m=m)
            classes = scn.class_params()
            us = time_us(lambda c=classes: jax.block_until_ready(
                class_fn(c, m)))
            derived = f"n={n}_C={C}_m={m}"
            if C == Cs[1 % len(Cs)] and n in client_ns:
                # per-client oracle at the same (n, m): expanded params +
                # O(n m^2) DP (n = 10^6 is intentionally NOT expanded —
                # that is the point of the class axis; its row reports the
                # class-space cost only)
                prm = scn.params()
                thr_c, k_c = class_fn(classes, m)
                thr_p, k_p = client_fn(prm, m)
                us_pc = time_us(lambda: jax.block_until_ready(
                    client_fn(prm, m)))
                derived += (f"_speedup_vs_per_client={us_pc / us:.1f}x"
                            f"_thr_rel_err="
                            f"{abs(float(thr_c - thr_p)) / float(thr_p):.2e}"
                            f"_K_rel_err="
                            f"{abs(float(k_c - k_p)) / float(k_p):.2e}")
            out.append(row(f"class_forms_n{n}_C{C}", us, derived))

    # -- event engine: ONE compiled class program per C; the n sweep at
    #    fixed C reuses it (count is data), so per-event cost is flat ------
    from repro.core.events import simulate_stats_classes

    C_ev = Cs[1 % len(Cs)]
    mult = 3
    num_events = mult * (num_updates + warmup) + mult * m + 8
    for n in ns:
        classes = class_scale_scenario(n, C_ev, m=m).class_params()

        def go(c=classes):
            st = simulate_stats_classes(c, m, num_updates, warmup=warmup,
                                        m_max=m)
            jax.block_until_ready(st.throughput)
            return st

        go()  # compile (shared across the n sweep at fixed C)
        t0 = time.perf_counter()
        st = go()
        us = (time.perf_counter() - t0) * 1e6
        thr = float(np.mean(np.asarray(st.throughput)))
        out.append(row(
            f"class_events_n{n}_C{C_ev}", us,
            f"us_per_event={us / num_events:.2f}_updates={num_updates}"
            f"_thr={thr:.3f}"))

    # -- suite sharding: batched vs sharded on the same class lanes --------
    suite_b = ScenarioSuite([class_scale_scenario(n, C_ev, m=m)
                             for n in ns], seeds=seeds)
    suite_s = ScenarioSuite([class_scale_scenario(n, C_ev, m=m)
                             for n in ns], seeds=seeds)

    def run_suite(suite, backend):
        t0 = time.perf_counter()
        res = suite.run(mode="simulate", num_updates=num_updates,
                        warmup=warmup, backend=backend)
        return res, (time.perf_counter() - t0) * 1e6

    res_b, _ = run_suite(suite_b, "batched")    # compile
    res_s, _ = run_suite(suite_s, "sharded")
    suite_b._result_cache.clear()
    suite_s._result_cache.clear()
    res_b, us_b = run_suite(suite_b, "batched")
    res_s, us_s = run_suite(suite_s, "sharded")
    bitwise = all(
        bool(jnp.all(jnp.asarray(x) == jnp.asarray(y)))
        for k in res_b.entries
        for a, b in zip(res_b.entries[k], res_s.entries[k])
        for x, y in zip(jax.tree_util.tree_leaves(a),
                        jax.tree_util.tree_leaves(b)))
    out.append(row(
        "class_suite_sharded", us_s,
        f"devices={device_count()}_lanes={len(ns) * len(seeds)}"
        f"_batched_us={us_b:.0f}_speedup={us_b / us_s:.2f}x"
        f"_bitwise_vs_batched={bitwise}"))
    return out
