"""ScenarioSuite planner benchmark: S scenarios x R seeds through the
device event engine in fewer compiles than scenarios.

The acceptance workload of the Scenario-API PR: four structurally-alike
strategy scenarios (same population, same timing law) x a seed batch run
``mode="simulate"`` as ONE bucketed jitted program (``programs=1 < S``),
plus an ``analyze`` pass and a hyperexponential-law bucket showing a new
``@timing_law`` riding the same lane conventions."""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.scenario import ScenarioSuite

from .common import row
from .scenarios import record, table1_scenario

STRATEGIES = ("asyncsgd", "max_throughput", "round_opt", "time_opt")


def run(scale: int = 20, num_updates: int = 2000, warmup: int = 400,
        seeds=(0, 1, 2, 3), steps: int = 60) -> list[str]:
    out = []
    base = record("scenario_suite",
                  table1_scenario(scale, strategy="time_opt", steps=steps,
                                  name=f"scenario_suite_s{scale}"))
    suite = ScenarioSuite.strategy_grid(base, STRATEGIES, seeds=seeds)

    t0 = time.perf_counter()
    suite.resolve()
    us_resolve = (time.perf_counter() - t0) * 1e6

    t0 = time.perf_counter()
    res = suite.run(mode="simulate", num_updates=num_updates, warmup=warmup)
    us_sim = (time.perf_counter() - t0) * 1e6
    thr = {k: float(np.mean([float(s.throughput) for s in v]))
           for k, v in res.entries.items()}
    out.append(row("scenario_suite_simulate", us_sim,
                   f"scenarios={len(suite)}_lanes={res.lanes}"
                   f"_programs={res.programs}"
                   f"_fewer_compiles_than_scenarios="
                   f"{res.programs < len(suite)}"))
    out.append(row("scenario_suite_resolve", us_resolve, "lambda:" + ";".join(
        f"{k}={v:.2f}" for k, v in thr.items())))

    ana = suite.run(mode="analyze")
    rel = max(abs(thr[k] - ana.entries[k]["throughput"])
              / ana.entries[k]["throughput"] for k in thr)
    out.append(row("scenario_suite_analyze", 0.0,
                   f"programs={ana.programs}"
                   f"_max_rel_thr_err_vs_sim={rel:.3f}"))

    # a registered extension law (hyperexponential H2, SCV=4) through the
    # same engine: one more bucket, one more compile.  The closed-form
    # (p, m) are law-independent, so pin the resolved strategies explicitly
    # instead of re-optimizing
    strat = suite.resolve()
    hyper = ScenarioSuite(
        {name: s.replace(
            network=dataclasses.replace(s.network, law="hyperexponential"),
            strategy=dataclasses.replace(s.strategy, name="explicit",
                                         p=strat[name][0], m=strat[name][1]))
         for name, s in suite.scenarios.items()}, seeds=seeds[:2])
    t0 = time.perf_counter()
    res_h = hyper.run(mode="simulate", num_updates=num_updates,
                      warmup=warmup)
    us_h = (time.perf_counter() - t0) * 1e6
    thr_h = {k: float(np.mean([float(s.throughput) for s in v]))
             for k, v in res_h.entries.items()}
    out.append(row("scenario_suite_hyperexponential", us_h,
                   f"programs={res_h.programs}_lambda_uni="
                   f"{thr_h['asyncsgd']:.2f}_vs_expo_{thr['asyncsgd']:.2f}"))
    return out
