"""Serve-path benchmark: micro-batching vs one-at-a-time dispatch.

Boots the real server (unix socket, in-process) and pushes one mixed-
population simulate workload through it two ways:

* ``serve_batched`` — every request pipelined into the same micro-batch
  window, so the batcher coalesces them into spare lanes of few
  dispatches (requests/dispatch > 1 is the headline number);
* ``serve_sequential`` — the same requests submitted one-at-a-time
  (wait for each result before the next), the no-batching baseline;
* ``serve_cache_hit`` — a repeat of an already-answered request: served
  from the response cache at admission, zero dispatches.

Rows carry req/s, mean requests- and lanes-per-dispatch (from the
``scheduled`` events) and the server-side p50/p99 request latency.
"""
from __future__ import annotations

import json
import os
import tempfile
import time

import numpy as np

N_REQUESTS = 6
SEEDS = (0, 1)
NUM_UPDATES = 60


def _scenarios():
    from repro.core.complexity import LearningConstants
    from repro.scenario import (LearningSpec, NetworkSpec, Scenario,
                                StrategySpec)

    consts = LearningConstants(L=1.0, delta=1.0, sigma=1.0, M=2.0, G=5.0,
                               eps=1.0)
    out = []
    for i in range(N_REQUESTS):
        n = 3 + (i % 3)  # mixed populations: the padded coalescing case
        rng = np.random.default_rng(100 + i)
        out.append(Scenario(
            network=NetworkSpec(mu_c=list(rng.uniform(1.0, 2.0, n)),
                                mu_d=[2.0] * n, mu_u=[2.0] * n),
            learning=LearningSpec(consts=consts),
            strategy=StrategySpec("explicit", p=list(np.full(n, 1.0 / n)),
                                  m=2)))
    return out


def _sched_stats(client, ids):
    reqs, lanes = [], []
    for rid in ids:
        for ev in client.events_for(rid):
            if ev["event"] == "scheduled":
                reqs.append(ev["requests"])
                lanes.append(ev["lanes"])
    return ((float(np.mean(reqs)) if reqs else 0.0),
            (float(np.mean(lanes)) if lanes else 0.0))


def run():
    from repro.serve.client import ServeClient
    from repro.serve.server import ServeConfig, Server

    scns = _scenarios()
    sock = tempfile.mktemp(suffix=".sock")
    server = Server(ServeConfig(socket_path=sock, max_wait=0.1,
                                max_lanes=64))
    server.start()
    try:
        # warm the resident program (compile cost must not skew either arm)
        with ServeClient(sock, timeout=600) as c:
            c.run(scns[0], mode="simulate", seeds=SEEDS,
                  num_updates=NUM_UPDATES)

        with ServeClient(sock, timeout=600) as c:
            t0 = time.perf_counter()
            ids = [c.submit(s, mode="simulate", seeds=SEEDS,
                            num_updates=NUM_UPDATES) for s in scns[1:]]
            for rid in ids:
                c.unwrap(c.collect(rid))
            wall = time.perf_counter() - t0
            rpd, lpd = _sched_stats(c, ids)
        n = len(scns) - 1
        yield (f"serve_batched,{wall / n * 1e6:.1f},"
               f"req_per_s={n / wall:.1f};requests_per_dispatch={rpd:.2f};"
               f"lanes_per_dispatch={lpd:.2f}")

        # sequential baseline: fresh rates so the response cache cannot help
        seq = []
        for i in range(N_REQUESTS - 1):
            rng = np.random.default_rng(200 + i)
            base = scns[1 + i].to_dict()
            base["network"]["mu_c"] = list(
                rng.uniform(1.0, 2.0, len(base["network"]["mu_c"])))
            seq.append(base)
        with ServeClient(sock, timeout=600) as c:
            t0 = time.perf_counter()
            ids = []
            for s in seq:
                rid = c.submit(s, mode="simulate", seeds=SEEDS,
                               num_updates=NUM_UPDATES)
                c.unwrap(c.collect(rid))
                ids.append(rid)
            wall_seq = time.perf_counter() - t0
            rpd_seq, lpd_seq = _sched_stats(c, ids)
        n = len(seq)
        yield (f"serve_sequential,{wall_seq / n * 1e6:.1f},"
               f"req_per_s={n / wall_seq:.1f};"
               f"requests_per_dispatch={rpd_seq:.2f};"
               f"lanes_per_dispatch={lpd_seq:.2f}")

        # repeat request: response cache at admission, no dispatch
        with ServeClient(sock, timeout=600) as c:
            t0 = time.perf_counter()
            rid = c.submit(scns[1], mode="simulate", seeds=SEEDS,
                           num_updates=NUM_UPDATES)
            msg = c.collect(rid)
            c.unwrap(msg)
            t_hit = time.perf_counter() - t0
            assert msg.get("cached") is True
            assert c.events_for(rid) == []  # no accepted/scheduled: no lanes
            st = c.stats()
        yield f"serve_cache_hit,{t_hit * 1e6:.1f},cached_no_dispatch"
        lat = st["latency"].get("serve.request_latency{mode=simulate}", {})
        yield (f"serve_latency,{lat.get('p50', 0.0) * 1e6:.1f},"
               f"p50_ms={lat.get('p50', 0.0) * 1e3:.2f};"
               f"p99_ms={lat.get('p99', 0.0) * 1e3:.2f}")
    finally:
        server.stop()
