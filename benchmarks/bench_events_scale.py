"""Paper-scale event-engine backend sweep (n = 100, m_max = 132).

The ROADMAP's paper-scale open item: simulate the Section-6 EMNIST
population (Table 1 at full scale, m = 132 in-flight tasks) compiled,
multi-lane, through every ``repro.sim`` backend:

  * ``reference`` — lane-at-a-time single-lane scans (the baseline);
  * ``batched``   — all lanes per scan step in ONE vmapped program (the
    row's ``speedup_vs_reference`` is the PR-over-PR tracked number);
  * ``pallas``    — the per-event table transition in the
    ``repro.kernels.events`` TPU kernel (interpret mode on CPU; the row
    asserts bitwise agreement with ``reference`` on its lanes — the
    exponential unit-draw rescale is exact).

Fidelity columns per row: relative throughput error vs the closed form
(Prop. 4) and relative staleness-identity error (Eq. 7:
``sum_i p_i E0[R_i] = m - 1``), both within the tolerances documented in
``tests/test_events.py`` at the default window (600 updates after a
400-update warmup).  A megastep chunk sweep (E in
``CHUNK_SWEEP``) times the batched backend retiring E events per scan
step at a low lane count (vmapping many lanes already amortizes the
per-step dispatch the megastep targets) — bitwise-equal trajectories
(``tests/test_megastep.py``), so the rows are pure dispatch-amortization
numbers, guarded by ``MAX_CHUNK_SLOWDOWN``.  A final row reruns the sweep through
``ScenarioSuite`` to record the suite-level result cache
(``cache_hits``/``programs``).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import jackson
from repro.scenario import ScenarioSuite
from repro.sim import simulate_stats_lanes

from .common import row
from .scenarios import events_scale_scenario, record

DEFAULT_BACKENDS = ("reference", "batched", "pallas")

#: megastep sizes for the chunk-sweep rows (1 == the single-step baseline)
CHUNK_SWEEP = (1, 8, 32)
#: regression guard: a megastep program must never land slower than this
#: factor of the single-step baseline at smoke scale (it exists to catch a
#: chunked path that stopped fusing, not to pin the speedup)
MAX_CHUNK_SLOWDOWN = 1.2


def _fidelity(params, m, stats):
    p = np.asarray(params.p)
    p = p / p.sum()
    lam = float(jackson.throughput(params, m))
    thr = float(np.mean(np.asarray(stats.throughput)))
    stale = float(np.mean([
        np.sum(p * np.asarray(stats.mean_delay[i]))
        for i in range(stats.throughput.shape[0])]))
    return (abs(thr - lam) / lam, abs(stale - (m - 1)) / (m - 1))


def run(scale: int = 1, m: int = 132, lanes: int = 6,
        num_updates: int = 600, warmup: int = 400,
        backends=DEFAULT_BACKENDS, pallas_lanes: int = 2,
        chunk_lanes: int = 2) -> list[str]:
    out = []
    # canonical order: reference first, so the batched speedup and pallas
    # bitwise comparison columns exist regardless of how --backends was
    # spelled; unknown names were already rejected by the CLI
    backends = [b for b in DEFAULT_BACKENDS if b in backends]
    scn = record("events_scale", events_scale_scenario(scale, m))
    params = scn.params(scn.strategy.p)
    n = scn.n

    def sweep(backend, L):
        def go():
            st = simulate_stats_lanes([params] * L, [m] * L, num_updates,
                                      warmup=warmup, m_max=m,
                                      backend=backend, seeds=range(L))
            jax.block_until_ready(st.throughput)
            return st

        go()  # compile
        t0 = time.perf_counter()
        st = go()
        return st, (time.perf_counter() - t0) * 1e6

    ref_us = None
    ref_small = None
    for backend in backends:
        L = pallas_lanes if backend == "pallas" else lanes
        st, us = sweep(backend, L)
        thr_err, stale_err = _fidelity(params, m, st)
        derived = (f"n={n}_m={m}_lanes={L}_updates={num_updates}"
                   f"_thr_err={thr_err:.3f}_stale_err={stale_err:.3f}")
        if backend == "reference":
            ref_us = us
            if "pallas" in backends:
                # reference stats on the pallas lane subset, bitwise check
                ref_small, _ = sweep("reference", pallas_lanes)
        elif ref_us is not None and backend == "batched":
            derived += f"_speedup_vs_reference={ref_us / us:.2f}x"
        elif backend == "pallas":
            derived += f"_interpret={jax.default_backend() != 'tpu'}"
            if ref_small is not None:
                bitwise = all(
                    np.array_equal(np.asarray(getattr(ref_small, f)),
                                   np.asarray(getattr(st, f)))
                    for f in st._fields)
                derived += f"_bitwise_vs_reference={bitwise}"
        out.append(row(f"events_scale_{backend}", us, derived))

    # -- megastep chunk sweep (batched backend): E events per scan step,
    # same trajectories bitwise (tests/test_megastep.py), so the delta is
    # pure per-step dispatch amortization.  The guard fails the bench run
    # (and CI's smoke job) if any chunked program regresses past
    # MAX_CHUNK_SLOWDOWN x single-step.
    chunk_us = {}
    for chunk in CHUNK_SWEEP:
        def go_chunk(E=chunk):
            st = simulate_stats_lanes([params] * chunk_lanes,
                                      [m] * chunk_lanes,
                                      num_updates, warmup=warmup, m_max=m,
                                      backend="batched",
                                      seeds=range(chunk_lanes), chunk=E)
            jax.block_until_ready(st.throughput)
            return st

        go_chunk()  # compile
        best = None
        for _ in range(3):
            t0 = time.perf_counter()
            go_chunk()
            us = (time.perf_counter() - t0) * 1e6
            best = us if best is None else min(best, us)
        chunk_us[chunk] = best
        derived = (f"n={n}_m={m}_lanes={chunk_lanes}_updates={num_updates}"
                   f"_backend=batched")
        if chunk != 1:
            derived += (f"_speedup_vs_single={chunk_us[1] / best:.2f}x"
                        f";guard={MAX_CHUNK_SLOWDOWN:.1f}")
        out.append(row(f"events_scale_chunk_E{chunk}", best, derived))
    worst = max(us / chunk_us[1] for E, us in chunk_us.items() if E != 1)
    if worst > MAX_CHUNK_SLOWDOWN:
        raise AssertionError(
            f"megastep wall-clock {worst:.2f}x the single-step baseline "
            f"exceeds the {MAX_CHUNK_SLOWDOWN:.1f}x guard — the chunked "
            f"scan body likely stopped fusing (or the block draws went "
            f"sequential on a unit-factorized law)")

    # the loop-invariant routing-CDF hoist: "before" rebuilds the O(n)
    # sequential seqcumsum inside every scan step (route_prefix=None),
    # "after" computes it once outside and passes it in — everything else
    # about the two programs is identical, and the trajectories are the
    # same seqcumsum of the same p, so the work compared is bitwise-equal.
    # On CPU the XLA scan already hoists the loop-invariant cumsum, so this
    # row sits near 1.0x here — it exists to catch the compiled-TPU path
    # (no LICM across a pallas_call boundary) and any regression that makes
    # the prefix loop-variant again
    from repro.core import events as ev
    from repro.core.numerics import seqcumsum

    mult = 4 if params.mu_cs is not None else 3
    num_events = mult * (num_updates + warmup) + mult * m + 8

    def build(hoisted):
        @jax.jit
        def go(prm, key):
            st = ev.init_state(prm, m, key, m_max=m, warmup=warmup,
                               cap=warmup + num_updates)
            prefix = seqcumsum(prm.p) if hoisted else None

            def body(s, _):
                s, _o = ev.step_event(prm, s, route_prefix=prefix)
                return s, None

            st, _ = jax.lax.scan(body, st, None, length=num_events)
            return ev.finalize_stats(st)

        return go

    before_fn, after_fn = build(False), build(True)
    key0 = jax.random.PRNGKey(0)

    def t(fn):
        jax.block_until_ready(fn().throughput)  # compile
        min_us = None
        for _ in range(3):
            t0 = time.perf_counter()
            jax.block_until_ready(fn().throughput)
            us = (time.perf_counter() - t0) * 1e6
            min_us = us if min_us is None else min(min_us, us)
        return min_us

    us_before = t(lambda: before_fn(params, key0))
    us_after = t(lambda: after_fn(params, key0))
    out.append(row("events_scale_cdf_hoist", us_after,
                   f"n={n}_before_us={us_before:.0f}"
                   f"_speedup={us_before / us_after:.2f}x"))

    # the same workload through the Scenario layer: one bucketed program,
    # then a re-run served entirely from the suite-level result cache
    suite = ScenarioSuite(scn, seeds=tuple(range(lanes)))
    t0 = time.perf_counter()
    res = suite.run(mode="simulate", num_updates=num_updates, warmup=warmup,
                    m_max=m, backend="batched")
    us_first = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    res2 = suite.run(mode="simulate", num_updates=num_updates,
                     warmup=warmup, m_max=m, backend="batched")
    us_cached = (time.perf_counter() - t0) * 1e6
    out.append(row(
        "events_scale_suite", us_first,
        f"programs={res.programs}_rerun_cache_hits={res2.cache_hits}"
        f"_rerun_us={us_cached:.0f}"))
    return out
