"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Set BENCH_FAST=1 to shrink
the training-based benches (CI budget).
"""
from __future__ import annotations

import os
import sys
import traceback

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    fast = os.environ.get("BENCH_FAST", "0") == "1"
    from benchmarks import (bench_concurrency_sweep, bench_energy_joint,
                            bench_kernels, bench_pareto, bench_queueing,
                            bench_round_optimization, bench_routing_table,
                            bench_tau_surface, bench_training_comparison)

    suites = [
        ("queueing", lambda: bench_queueing.run()),
        ("routing_table", lambda: bench_routing_table.run(
            scale=10 if fast else 5, steps=120 if fast else 250)),
        ("round_optimization", lambda: bench_round_optimization.run(
            scale=10 if fast else 5, steps=150 if fast else 300)),
        ("tau_surface", lambda: bench_tau_surface.run()),
        ("concurrency_sweep", lambda: bench_concurrency_sweep.run(
            steps=80 if fast else 150)),
        ("pareto", lambda: bench_pareto.run(steps=80 if fast else 150)),
        ("training_comparison", lambda: bench_training_comparison.run(
            horizon=120.0 if fast else 240.0,
            distributions=("exponential",) if fast
            else ("exponential", "lognormal"),
            seeds=(0,) if fast else (0, 1))),
        ("energy_joint", lambda: bench_energy_joint.run(
            horizon=120.0 if fast else 240.0, seeds=(0,) if fast else (0, 1))),
        ("kernels", lambda: bench_kernels.run()),
    ]
    print("name,us_per_call,derived")
    failures = []
    for name, fn in suites:
        try:
            for line in fn():
                print(line, flush=True)
        except Exception as e:  # noqa: BLE001
            failures.append((name, repr(e)))
            traceback.print_exc()
            print(f"{name},nan,FAILED:{e!r}", flush=True)
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
