"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

Modes:
  * default        — full settings (paper-scale CPU budget, ~minutes);
  * BENCH_FAST=1   — shrink the training-based benches (CI budget);
  * ``--smoke``    — a few optimizer steps / tiny horizons per bench and a
    machine-readable ``BENCH_smoke.json`` snapshot (written to the repo
    root, or ``--out PATH``) so the perf trajectory populates over PRs.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback

_REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(_REPO_ROOT, "src"))
sys.path.insert(0, _REPO_ROOT)  # `import benchmarks` when run as a script


def build_suites(mode: str, backends=None):
    from benchmarks import (bench_class_scale, bench_concurrency_sweep,
                            bench_energy_joint,
                            bench_events_scale, bench_kernels, bench_obs,
                            bench_pareto,
                            bench_population_sweep, bench_pruned_sweep,
                            bench_queueing, bench_round_optimization,
                            bench_routing_table, bench_scenario_suite,
                            bench_serve, bench_tau_surface,
                            bench_training_comparison)

    backends = backends or bench_events_scale.DEFAULT_BACKENDS
    fast = mode == "fast"
    if mode == "smoke":
        return [
            ("queueing", lambda: bench_queueing.run()),
            # the event-engine hot path early in the suite: its wall-clock
            # comparison vs the host loop is the PR-over-PR tracked number
            # and should not inherit allocator/cache state from the heavier
            # training benches
            ("event_engine", lambda: bench_training_comparison.run_engine_sweep(
                scale=20, horizon=40.0, seeds=tuple(range(8)))),
            # paper-scale (n=100, m_max=132) sim-backend sweep
            ("events_scale", lambda: bench_events_scale.run(
                backends=backends)),
            # class aggregation: n = 10^2..10^6 members as O(#classes)
            # closed forms + event engine, plus the sharded-suite row
            ("class_scale", lambda: bench_class_scale.run(
                num_updates=200, warmup=100, seeds=(0, 1))),
            ("scenario_suite", lambda: bench_scenario_suite.run(
                scale=20, num_updates=2000, seeds=(0, 1, 2, 3))),
            # mixed-population (n = 9/32/100) suite as ONE program vs the
            # one-program-per-n baseline (the padded traced-n planner win)
            ("population_sweep", lambda: bench_population_sweep.run(
                num_updates=400, seeds=(0, 1))),
            # paper-scale pruned vs full concurrency sweep (ROADMAP item)
            ("pruned_sweep", lambda: bench_pruned_sweep.run(steps=8)),
            ("routing_table", lambda: bench_routing_table.run(
                scale=20, steps=30)),
            ("round_optimization", lambda: bench_round_optimization.run(
                scale=20, steps=30)),
            ("tau_surface", lambda: bench_tau_surface.run()),
            ("concurrency_sweep", lambda: bench_concurrency_sweep.run(
                scale=20, steps=30)),
            ("pareto", lambda: bench_pareto.run(scale=20, steps=30,
                                                rhos=(0.0, 0.1, 1.0))),
            ("training_comparison", lambda: bench_training_comparison.run(
                horizon=40.0, distributions=("exponential",), seeds=(0,))),
            ("energy_joint", lambda: bench_energy_joint.run(
                horizon=40.0, seeds=(0,))),
            # micro-batched vs one-at-a-time dispatch through the server
            ("serve", lambda: bench_serve.run()),
            # telemetry rings off vs on (bounded-overhead guard) + drift
            ("obs", lambda: bench_obs.run()),
            ("kernels", lambda: bench_kernels.run()),
        ]
    return [
        ("queueing", lambda: bench_queueing.run()),
        ("routing_table", lambda: bench_routing_table.run(
            scale=10 if fast else 5, steps=120 if fast else 250)),
        ("round_optimization", lambda: bench_round_optimization.run(
            scale=10 if fast else 5, steps=150 if fast else 300)),
        ("tau_surface", lambda: bench_tau_surface.run()),
        ("concurrency_sweep", lambda: bench_concurrency_sweep.run(
            steps=80 if fast else 150)),
        ("pareto", lambda: bench_pareto.run(steps=80 if fast else 150)),
        ("training_comparison", lambda: bench_training_comparison.run(
            horizon=120.0 if fast else 240.0,
            distributions=("exponential",) if fast
            else ("exponential", "lognormal"),
            seeds=(0,) if fast else (0, 1))),
        ("event_engine", lambda: bench_training_comparison.run_engine_sweep(
            scale=20 if fast else 10, horizon=40.0 if fast else 80.0,
            seeds=tuple(range(8)))),
        ("events_scale", lambda: bench_events_scale.run(
            lanes=6 if fast else 16, backends=backends)),
        ("class_scale", lambda: bench_class_scale.run(
            num_updates=400 if fast else 2000, warmup=200,
            seeds=(0, 1) if fast else tuple(range(4)))),
        ("scenario_suite", lambda: bench_scenario_suite.run(
            scale=20 if fast else 10,
            num_updates=2000 if fast else 10000, seeds=tuple(range(4)))),
        ("population_sweep", lambda: bench_population_sweep.run(
            num_updates=1000 if fast else 4000, seeds=tuple(range(4)))),
        ("pruned_sweep", lambda: bench_pruned_sweep.run(
            steps=30 if fast else 120)),
        ("energy_joint", lambda: bench_energy_joint.run(
            horizon=120.0 if fast else 240.0, seeds=(0,) if fast else (0, 1))),
        ("serve", lambda: bench_serve.run()),
        ("obs", lambda: bench_obs.run()),
        ("kernels", lambda: bench_kernels.run()),
    ]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="few-step run per bench + BENCH_smoke.json snapshot")
    ap.add_argument("--out", default=None,
                    help="JSON output path (smoke mode only); default "
                         "<repo>/BENCH_smoke.json")
    ap.add_argument("--backends", default=None,
                    help="comma-separated repro.sim backends the "
                         "events_scale sweep records per-backend rows for "
                         "(default: reference,batched,pallas)")
    ap.add_argument("--no-compile-cache", action="store_true",
                    help="skip the persistent XLA compilation cache "
                         "(JAX_COMPILATION_CACHE_DIR picks its location)")
    args = ap.parse_args(argv)

    if not args.no_compile_cache:
        # warm restarts for the bench too: repeat runs deserialize their
        # programs instead of recompiling (suite rows record cache_hits)
        from repro.serve.xla_cache import enable_persistent_cache

        print(f"# persistent compilation cache at "
              f"{enable_persistent_cache()}", flush=True)

    backends = None
    if args.backends:
        from repro.sim import resolve_backend

        backends = tuple(resolve_backend(b.strip())
                         for b in args.backends.split(",") if b.strip())

    if args.smoke:
        mode = "smoke"
    elif os.environ.get("BENCH_FAST", "0") == "1":
        mode = "fast"
    else:
        mode = "full"
    suites = build_suites(mode, backends=backends)

    from repro.analysis import tracecheck

    print("name,us_per_call,derived")
    results = []
    failures = []
    t_start = time.time()
    for name, fn in suites:
        t0 = time.time()
        try:
            with tracecheck.watch() as w:
                for line in fn():
                    print(line, flush=True)
                    rname, us, derived = line.split(",", 2)
                    results.append({"suite": name, "name": rname,
                                    "us_per_call": float(us),
                                    "derived": derived})
        except Exception as e:  # noqa: BLE001
            failures.append((name, repr(e)))
            traceback.print_exc()
            print(f"{name},nan,FAILED:{e!r}", flush=True)
        # compile pressure rides next to the wall time: regressions in the
        # suite planner show up here PR-over-PR, not just in latency
        results.append({"suite": name, "name": f"{name}.__suite_s",
                        "us_per_call": (time.time() - t0) * 1e6,
                        "derived": "suite_wall_time",
                        "traces": w.traces, "compiles": w.compiles,
                        "cache_hits": w.cache_hits})

    if mode == "smoke":
        import jax

        # key every row by the hash of the Scenario its suite actually ran
        # (benchmarks/scenarios.py), so the perf trajectory stays joinable
        # across API churn: rows are comparable iff their hashes match
        from benchmarks import scenarios as bench_scenarios

        hashes = bench_scenarios.recorded()
        for r in results:
            h = hashes.get(r["suite"])
            if h is not None:
                r["scenario"] = h
        payload = {
            "mode": mode,
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
            "total_s": time.time() - t_start,
            "jax_version": jax.__version__,
            "backend": jax.default_backend(),
            "scenarios": hashes,
            "failures": [list(f) for f in failures],
            "rows": results,
        }
        out_path = args.out or os.path.join(_REPO_ROOT, "BENCH_smoke.json")
        with open(out_path, "w") as fh:
            json.dump(payload, fh, indent=1)
        print(f"# wrote {out_path}", flush=True)

    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
