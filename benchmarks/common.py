"""Shared benchmark utilities: timing + CSV row emission."""
from __future__ import annotations

import time
from typing import Callable


def time_us(fn: Callable, *args, warmup: int = 2, iters: int = 10) -> float:
    for _ in range(warmup):
        fn(*args)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    try:
        import jax
        jax.block_until_ready(out)
    except Exception:
        pass
    return (time.perf_counter() - t0) / iters * 1e6


def row(name: str, us: float, derived) -> str:
    return f"{name},{us:.1f},{derived}"
