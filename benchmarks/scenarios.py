"""Declarative scenarios behind the benchmark suites.

Every benchmark constructs its run through the Scenario API; the builders
here are the single source of those specs, and ``BENCH_SCENARIOS`` holds
one canonical (smoke-scale) scenario per suite.  Two consumers:

  * ``benchmarks/run.py --smoke`` keys each ``BENCH_smoke.json`` row by the
    serialized scenario hash (``Scenario.hash()`` — a canonical-JSON
    digest), so the perf trajectory stays joinable across API churn: a row
    is comparable with an older one iff the hashes match.  Benches that run
    at non-default scales call :func:`record` with the spec they actually
    executed.
  * ``tests/test_scenario.py`` asserts every registered benchmark scenario
    JSON-round-trips bitwise and builds its ``NetworkParams`` /
    ``PowerProfile`` eagerly (no tracing).
"""
from __future__ import annotations

import numpy as np

from repro.core import LearningConstants
from repro.scenario import (ClassSpec, EnergySpec, LearningSpec, NetworkSpec,
                            ObjectiveSpec, PAPER_CLUSTERS_TABLE1,
                            PAPER_CLUSTERS_TABLE6, Scenario, SimSpec,
                            StrategySpec, TraceSpec)

# The constants used across every benchmark (Assumptions A1-A5).
CONSTS = LearningConstants(L=1.0, delta=1.0, sigma=1.0, M=2.0, G=5.0, eps=1.0)


def table1_scenario(scale: int = 10, *, strategy: str = "asyncsgd",
                    law: str = "exponential", with_power: bool = False,
                    steps: int = 200, m_max=None, rho: float = 0.1,
                    eta=None, grad_clip=5.0, search: str = "batched",
                    name: str = "") -> Scenario:
    """The paper's main population (Table 1 / Table 4), CPU-scaled."""
    return Scenario(
        network=NetworkSpec.from_clusters(PAPER_CLUSTERS_TABLE1, scale,
                                          law=law),
        learning=LearningSpec(consts=CONSTS, eta=eta, grad_clip=grad_clip),
        energy=(EnergySpec.from_clusters(PAPER_CLUSTERS_TABLE1, scale)
                if with_power else None),
        strategy=StrategySpec(strategy, steps=steps, m_max=m_max,
                              search=search),
        objective=ObjectiveSpec("joint" if with_power else "time", rho=rho),
        name=name or f"table1_s{scale}_{strategy}")


def table6_scenario(scale: int = 5, *, strategy: str = "round_opt",
                    steps: int = 300, name: str = "") -> Scenario:
    """The Appendix-H round-complexity population (Table 6)."""
    return Scenario(
        network=NetworkSpec.from_clusters(PAPER_CLUSTERS_TABLE6, scale),
        learning=LearningSpec(consts=CONSTS),
        strategy=StrategySpec(strategy, steps=steps),
        objective=ObjectiveSpec("round"),
        name=name or f"table6_s{scale}_{strategy}")


def events_scale_scenario(scale: int = 1, m: int = 132,
                          name: str = "events_scale") -> Scenario:
    """The paper-scale event-engine workload (Section 6 population at full
    n = 100, concurrency m = 132) with pinned uniform routing — no
    optimizer in the loop, the bench measures the simulation backends."""
    net = NetworkSpec.from_clusters(PAPER_CLUSTERS_TABLE1, scale)
    return Scenario(
        network=net,
        learning=LearningSpec(consts=CONSTS),
        strategy=StrategySpec("explicit", p=np.full(net.n, 1.0 / net.n),
                              m=m, m_max=m),
        name=name)


def population_scenario(scale: int = 1) -> Scenario:
    """Table-1 population at ``scale`` with pinned uniform routing and
    ``m = n`` — a member of the mixed-``n`` ``population_sweep`` suite."""
    net = NetworkSpec.from_clusters(PAPER_CLUSTERS_TABLE1, scale)
    return Scenario(
        network=net,
        strategy=StrategySpec("explicit", p=np.full(net.n, 1.0 / net.n),
                              m=net.n, m_max=net.n),
        name=f"population_n{net.n}")


def class_scale_scenario(n: int = 10_000, C: int = 4, m: int = 8,
                         name: str = "") -> Scenario:
    """A class-aggregated population of ``n`` members in ``C`` classes.

    Rates interpolate the Table-1 spread (mu_c in [1, 3], transfers ~3x
    faster); members split evenly across classes (remainder to the last),
    pinned uniform routing and a small concurrency budget ``m`` — the
    ``class_scale`` bench measures how the closed forms and the event
    engine scale in ``n`` at fixed ``C``.
    """
    base = n // C
    counts = np.full(C, base, np.int64)
    counts[-1] += n - base * C
    t = np.linspace(0.0, 1.0, C) if C > 1 else np.zeros(1)
    classes = ClassSpec(mu_c=1.0 + 2.0 * t, mu_d=6.0 + 2.0 * t,
                        mu_u=6.0 + 2.0 * t, count=counts)
    return Scenario(
        network=NetworkSpec(classes=classes),
        learning=LearningSpec(consts=CONSTS),
        strategy=StrategySpec("explicit", p=np.full(C, 1.0 / n), m=m,
                              m_max=m),
        name=name or f"class_scale_n{n}_C{C}")


def obs_scenario(n: int = 8, trace_events: int = 16384) -> Scenario:
    """The telemetry-overhead workload (``bench_obs``): a heterogeneous
    compute-bound population with the event ring enabled, pinned uniform
    routing at ``m = 2n``."""
    rng = np.random.default_rng(42)
    return Scenario(
        network=NetworkSpec(mu_c=list(0.8 + 0.4 * rng.random(n)),
                            mu_d=[4.0] * n, mu_u=[4.0] * n),
        strategy=StrategySpec("explicit", p=list(np.full(n, 1.0 / n)),
                              m=2 * n, m_max=2 * n),
        sim=SimSpec(trace=TraceSpec(events=trace_events)),
        name="obs_overhead")


def two_client_scenario(mu2: float = 1.0) -> Scenario:
    """The Figure-2 two-client system (client 2 = ``mu2``x faster)."""
    return Scenario(
        network=NetworkSpec(mu_c=[1.0, mu2], mu_d=[1.0, mu2],
                            mu_u=[1.0, mu2]),
        learning=LearningSpec(consts=LearningConstants(
            L=1.0, delta=1.0, sigma=1.0, M=5.0, G=14.0, eps=1.0)),
        name=f"fig2_mu2_{mu2:g}")


# canonical smoke-scale spec per benchmark suite — the registered benchmark
# scenarios (round-trip-tested in tests/test_scenario.py)
BENCH_SCENARIOS: dict[str, Scenario] = {
    "queueing": table1_scenario(1, name="queueing"),
    "event_engine": table1_scenario(20, strategy="time_opt", steps=150,
                                    name="event_engine"),
    "routing_table": table1_scenario(20, strategy="time_opt", steps=30,
                                     name="routing_table"),
    "round_optimization": table6_scenario(20, steps=30,
                                          name="round_optimization"),
    "tau_surface": two_client_scenario(3.0),
    "concurrency_sweep": table1_scenario(20, strategy="time_opt", steps=30,
                                         name="concurrency_sweep"),
    "pareto": table1_scenario(20, strategy="joint", with_power=True,
                              steps=30, name="pareto"),
    "training_comparison": table1_scenario(10, strategy="time_opt",
                                           name="training_comparison"),
    "energy_joint": table1_scenario(10, strategy="joint", with_power=True,
                                    name="energy_joint"),
    "scenario_suite": table1_scenario(20, strategy="time_opt", steps=60,
                                      name="scenario_suite"),
    "events_scale": events_scale_scenario(),
    "class_scale": class_scale_scenario(),
    "population_sweep": population_scenario(1),
    "pruned_sweep": table1_scenario(1, strategy="time_opt", steps=8,
                                    m_max=132, search="pruned",
                                    name="pruned_sweep_s1"),
    "obs": obs_scenario(),
}

# specs actually executed in this process (bench modules call record());
# pre-seeded with the canonical smoke-scale specs
_RUNS: dict[str, str] = {k: s.hash() for k, s in BENCH_SCENARIOS.items()}


def record(suite_name: str, scenario: Scenario) -> Scenario:
    """Note the scenario a bench actually ran (returned unchanged)."""
    _RUNS[suite_name] = scenario.hash()
    return scenario


def recorded() -> dict[str, str]:
    """``{suite name: scenario hash}`` for the rows of this process."""
    return dict(_RUNS)
