"""Figure 4 analogue: time-energy Pareto frontier over rho, with the optimal
concurrency m*(rho) and routing drift away from power-hungry clusters.

The entire frontier — all rho values x all candidate m — runs as ONE
batched sweep (rho enters as the per-row context of the padded joint
objective), so the whole figure costs two jit compiles: the tau* reference
sweep and the joint sweep."""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import (batched_concurrency_sweep,
                        make_energy_objective_padded,
                        make_time_objective_padded, minimal_energy,
                        objective_surface, pareto_sweep)

from .common import row
from .scenarios import record, table1_scenario


def run(scale: int = 10, steps: int = 150,
        rhos=(0.0, 0.1, 0.3, 0.5, 0.8, 1.0)) -> list[str]:
    out = []
    scn = record("pareto",
                 table1_scenario(scale, strategy="joint", with_power=True,
                                 steps=steps, name=f"pareto_s{scale}"))
    params = scn.params()
    power = scn.power()
    labels = list(scn.network.labels)
    CONSTS = scn.consts
    n = scn.n
    m_max = n + 6

    t0 = time.perf_counter()
    tau_res = batched_concurrency_sweep(
        make_time_objective_padded(params, CONSTS, m_max), params,
        m_grid=jnp.arange(2, m_max + 1), steps=steps)
    tau_star = tau_res.best.value
    e_star = float(minimal_energy(params, CONSTS, power))

    # one sweep over the full rho x m product grid, then tau / energy at the
    # per-rho optima (two more one-compile batched evaluations)
    _, per_rho = pareto_sweep(params, CONSTS, power, rhos, tau_star, e_star,
                              m_max=m_max, steps=steps)
    p_rows = jnp.stack([r.p for r in per_rho])
    m_rows = jnp.asarray([r.m for r in per_rho])
    taus = np.asarray(objective_surface(
        make_time_objective_padded(params, CONSTS, m_max), params, p_rows,
        m_rows, m_max=m_max))
    ens = np.asarray(objective_surface(
        make_energy_objective_padded(params, CONSTS, power, m_max), params,
        p_rows, m_rows, m_max=m_max))
    frontier = []
    for r_i, rho in enumerate(rhos):
        pE = np.asarray(per_rho[r_i].p)[np.array(labels) == "E"].mean()
        frontier.append((rho, per_rho[r_i].m, float(taus[r_i]),
                         float(ens[r_i]), pE))
    us = (time.perf_counter() - t0) * 1e6

    out.append(row("fig4_pareto_frontier", us, ";".join(
        f"rho{r}:m={m}:tau={t:.1f}:E={e:.0f}" for r, m, t, e, _ in frontier)))
    # claims: m*(rho) decreases to 1; energy decreases; type-E weight drops
    ms = [f[1] for f in frontier]
    ens_f = [f[3] for f in frontier]
    pEs = [f[4] for f in frontier]
    out.append(row("fig4_claims", 0.0,
                   f"m_monotone_down={all(a >= b for a, b in zip(ms, ms[1:]))}"
                   f";m(rho=1)={ms[-1]}"
                   f";energy_down={ens_f[-1] <= ens_f[0] + 1e-6}"
                   f";typeE_down={pEs[-1] <= pEs[0] + 1e-9}"))
    e01 = [f for f in frontier if f[0] == 0.1]
    if e01:
        _, m01, t01, en01, _ = e01[0]
        t00, en00 = frontier[0][2], frontier[0][3]
        out.append(row("fig4_rho0.1_tradeoff", 0.0,
                       f"energy_saving={100 * (1 - en01 / en00):.1f}%"
                       f"_time_cost={100 * (t01 / t00 - 1):.1f}%_m={m01}"))
    return out
