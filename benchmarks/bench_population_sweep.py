"""Mixed-population ScenarioSuite: one compiled program across distinct n.

The acceptance workload of the padded traced-``n`` PR: scenarios at three
population scales of the Table-1 clusters (n = 9 / 32 / 100) run
``analyze`` and ``simulate`` as ONE suite — lanes are padded to the shared
``n_max`` (``repro.core.buzen.pad_network``) so ``SuiteResult.programs``
is 1 per mode where the pre-PR planner compiled one program per distinct
``n``.  The baseline (each scenario in its own suite — exactly the
per-``n`` compile count the old equal-``n`` bucketing forced) is timed
alongside, and the analyze columns are cross-checked: ``n``-padding is
bitwise invisible at a shared ``m_max`` (``tests/test_padded_n.py``); the
mixed-vs-solo comparison here also changes the per-bucket ``logZ`` padding
``m_max``, so the recorded agreement is float64 round-off.
"""
from __future__ import annotations

import time

from repro.scenario import ScenarioSuite

from .common import row
from .scenarios import population_scenario as _scenario, record


def run(scales=(10, 3, 1), num_updates: int = 400, warmup: int = 80,
        seeds=(0, 1)) -> list[str]:
    out = []
    scns = {s.name: s for s in (_scenario(sc) for sc in scales)}
    ns = [s.n for s in scns.values()]
    # key the BENCH row by the largest-population member (the paper-scale
    # lane that dominates the program's cost)
    record("population_sweep", max(scns.values(), key=lambda s: s.n))

    # -- mixed suite: every population in one plan --------------------------
    mixed = ScenarioSuite(dict(scns), seeds=seeds)
    t0 = time.perf_counter()
    ana = mixed.run(mode="analyze")
    us_ana = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    sim = mixed.run(mode="simulate", num_updates=num_updates, warmup=warmup)
    us_sim = (time.perf_counter() - t0) * 1e6

    # -- baseline: one suite per population (the pre-padding compile count)
    t0 = time.perf_counter()
    solo_ana = {k: ScenarioSuite({k: s}, seeds=seeds).run(mode="analyze")
                for k, s in scns.items()}
    us_solo_ana = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    solo_programs = 0
    for k, s in scns.items():
        r = ScenarioSuite({k: s}, seeds=seeds).run(
            mode="simulate", num_updates=num_updates, warmup=warmup)
        solo_programs += r.programs
    us_solo_sim = (time.perf_counter() - t0) * 1e6

    # n-padding is invisible; the differing per-bucket m_max padding keeps
    # this at float64 round-off rather than exactly zero (see docstring)
    rel = max(
        abs(ana.entries[k]["throughput"]
            - solo_ana[k].entries[k]["throughput"])
        / solo_ana[k].entries[k]["throughput"] for k in scns)

    pops = "-".join(str(n) for n in ns)
    out.append(row(
        "population_sweep_analyze", us_ana,
        f"n={pops}_programs={ana.programs}_vs_per_n="
        f"{sum(r.programs for r in solo_ana.values())}"
        f"_solo_us={us_solo_ana:.0f}_max_rel_diff={rel:.1e}"))
    out.append(row(
        "population_sweep_simulate", us_sim,
        f"lanes={sim.lanes}_programs={sim.programs}"
        f"_vs_per_n={solo_programs}_solo_us={us_solo_sim:.0f}"
        f"_speedup={us_solo_sim / max(us_sim, 1.0):.2f}x"))
    return out
