"""Figure 8 analogue: optimized E0[tau_eps](p*, m) as a function of m —
locates the optimal concurrency m*.

Uses the batched sweep engine (ONE jitted Adam scan for every candidate m)
and cross-times the coarse-to-fine ``search="pruned"`` variant against it —
the pruning that keeps paper-scale grids (ROADMAP open item) tractable.
The network and objective come from the Scenario API: the spec's padded
objective is resolved through the objective registry."""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import batched_concurrency_sweep, pruned_concurrency_sweep
from repro.scenario import get_objective

from .common import row
from .scenarios import record, table1_scenario


def run(scale: int = 10, steps: int = 150) -> list[str]:
    scn = record("concurrency_sweep",
                 table1_scenario(scale, strategy="time_opt", steps=steps,
                                 name=f"concurrency_sweep_s{scale}"))
    params = scn.params()
    n = scn.n
    m_max = n + 5
    objective = get_objective(scn.objective.name).padded(
        params, scn.consts, scn.power(), None, m_max)

    t0 = time.perf_counter()
    res = batched_concurrency_sweep(
        objective, params, m_grid=jnp.arange(1, m_max + 1), m_max=m_max,
        steps=steps)
    us = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    pruned = pruned_concurrency_sweep(
        objective, params, m_grid=jnp.arange(1, m_max + 1), m_max=m_max,
        steps=steps)
    us_pruned = (time.perf_counter() - t0) * 1e6

    values = res.best.history
    m_star, v_star = res.best.m, res.best.value
    v1 = values[0][1]
    v_full = dict(values)[n]
    curve = ";".join(f"m{m}={v:.1f}" for m, v in values[::max(1, len(values)//8)])
    # same discrete optimum; the value can differ slightly at few-step
    # smoke settings (the warm-started refinement often converges *further*
    # than the cold full sweep), so report the signed relative gap
    gap = (pruned.best.value - v_star) / abs(v_star)
    out = [
        row("fig8_concurrency_sweep", us, curve),
        row("fig8_optimum", 0.0,
            f"m*={m_star}_tau*={v_star:.2f}_tau(m=1)={v1:.2f}"
            f"_tau(m=n)={v_full:.2f}"),
        row("fig8_claims", 0.0,
            f"interior={1 < m_star}_beats_serial={v_star < v1}"
            f"_beats_full={v_star <= v_full + 1e-9}"),
        row("fig8_pruned_sweep", us_pruned,
            f"rows={len(pruned.values)}_of_{len(res.values)}"
            f"_same_m={pruned.best.m == m_star}_rel_value_gap={gap:+.1e}"),
    ]
    return out
