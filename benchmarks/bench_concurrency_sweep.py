"""Figure 8 analogue: optimized E0[tau_eps](p*, m) as a function of m with
warm-started sequential search — locates the optimal concurrency m*."""
from __future__ import annotations

import time

import jax.numpy as jnp

from repro.core import (LearningConstants, make_time_objective,
                        optimize_routing)
from repro.fl.strategies import PAPER_CLUSTERS_TABLE1, build_network_params

from .common import row

CONSTS = LearningConstants(L=1.0, delta=1.0, sigma=1.0, M=2.0, G=5.0, eps=1.0)


def run(scale: int = 10, steps: int = 150) -> list[str]:
    params = build_network_params(PAPER_CLUSTERS_TABLE1, scale=scale)
    n = params.n
    obj = make_time_objective(params, CONSTS)
    t0 = time.perf_counter()
    values = []
    p_warm = None
    for m in range(1, n + 6):
        res = optimize_routing(obj, n, m, steps=steps, p_init=p_warm)
        p_warm = res.p
        values.append((m, res.value))
    us = (time.perf_counter() - t0) * 1e6
    m_star, v_star = min(values, key=lambda t: t[1])
    v1 = values[0][1]
    v_full = dict(values)[n]
    curve = ";".join(f"m{m}={v:.1f}" for m, v in values[::max(1, len(values)//8)])
    out = [
        row("fig8_concurrency_sweep", us, curve),
        row("fig8_optimum", 0.0,
            f"m*={m_star}_tau*={v_star:.2f}_tau(m=1)={v1:.2f}"
            f"_tau(m=n)={v_full:.2f}"),
        row("fig8_claims", 0.0,
            f"interior={1 < m_star}_beats_serial={v_star < v1}"
            f"_beats_full={v_star <= v_full + 1e-9}"),
    ]
    return out
