"""Figure 8 analogue: optimized E0[tau_eps](p*, m) as a function of m —
locates the optimal concurrency m*.

Uses the batched sweep engine: ONE jitted Adam scan optimizes routing for
every candidate m simultaneously (no warm-started per-m loop, no per-m
recompilation)."""
from __future__ import annotations

import time

import jax.numpy as jnp

from repro.core import (LearningConstants, batched_concurrency_sweep,
                        make_time_objective_padded)
from repro.fl.strategies import PAPER_CLUSTERS_TABLE1, build_network_params

from .common import row

CONSTS = LearningConstants(L=1.0, delta=1.0, sigma=1.0, M=2.0, G=5.0, eps=1.0)


def run(scale: int = 10, steps: int = 150) -> list[str]:
    params = build_network_params(PAPER_CLUSTERS_TABLE1, scale=scale)
    n = params.n
    m_max = n + 5
    t0 = time.perf_counter()
    res = batched_concurrency_sweep(
        make_time_objective_padded(params, CONSTS, m_max), params,
        m_grid=jnp.arange(1, m_max + 1), steps=steps)
    us = (time.perf_counter() - t0) * 1e6
    values = res.best.history
    m_star, v_star = res.best.m, res.best.value
    v1 = values[0][1]
    v_full = dict(values)[n]
    curve = ";".join(f"m{m}={v:.1f}" for m, v in values[::max(1, len(values)//8)])
    out = [
        row("fig8_concurrency_sweep", us, curve),
        row("fig8_optimum", 0.0,
            f"m*={m_star}_tau*={v_star:.2f}_tau(m=1)={v1:.2f}"
            f"_tau(m=n)={v_full:.2f}"),
        row("fig8_claims", 0.0,
            f"interior={1 < m_star}_beats_serial={v_star < v1}"
            f"_beats_full={v_star <= v_full + 1e-9}"),
    ]
    return out
