"""Queueing-core benchmarks: Theorem 2 validation (delay vs simulation),
Buzen variants (literal vs aggregated vs Pallas kernel), gradient paths."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (NetworkParams, delay_jacobian, expected_relative_delay,
                        simulate_stats, throughput)
from repro.core.buzen import log_normalizing_constants
from repro.core.simulator import AsyncNetworkSim
from repro.kernels import ops

from .common import row, time_us
from .scenarios import record, table1_scenario


def run() -> list[str]:
    out = []
    params = record("queueing", table1_scenario(1)).params()  # n = 100
    n, m = params.n, 100

    # --- Buzen variants (the optimizer inner loop) --------------------------
    f_agg = jax.jit(lambda p: log_normalizing_constants(
        params._replace(p=p), m, method="aggregate"))
    us_agg = time_us(f_agg, params.p)
    f_lit = jax.jit(lambda p: log_normalizing_constants(
        params._replace(p=p), m, method="literal"))
    us_lit = time_us(f_lit, params.p, iters=3)
    us_pal = time_us(lambda: ops.buzen_log_Z(
        params.log_rho, params.log_gamma_total, m, interpret=True), iters=3)
    out.append(row("buzen_aggregate_n100_m100", us_agg,
                   f"speedup_vs_literal={us_lit / us_agg:.1f}x"))
    out.append(row("buzen_literal_n100_m100", us_lit, "prop15_reference"))
    out.append(row("buzen_pallas_interpret_n100_m100", us_pal,
                   "interpret_mode(cpu)"))

    # --- Theorem 2: closed-form delay vs Monte-Carlo ------------------------
    # the MC sweep runs on the jitted device event engine; the host heap
    # simulator stays as the exact per-task-identity reference it is
    # cross-checked against (one row records host-vs-device agreement)
    small = table1_scenario(10).params()  # n = 11
    msml = 12
    d_th = np.asarray(expected_relative_delay(small, msml))

    t0 = time.perf_counter()
    stats = simulate_stats(small, msml, 60_000, warmup=8_000, seed=0)
    stats.throughput.block_until_ready()
    dev_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    host = AsyncNetworkSim(small, msml, seed=0).run(60_000, warmup=8_000)
    host_s = time.perf_counter() - t0

    d_mc = np.asarray(small.p) * np.asarray(stats.mean_delay)
    rel = float(np.max(np.abs(d_mc - d_th) / np.maximum(d_th, 1e-3)))
    us = time_us(jax.jit(lambda p: expected_relative_delay(
        small._replace(p=p), msml)), small.p)
    out.append(row("thm2_delay_closed_form_n11_m12", us,
                   f"max_rel_err_vs_sim={rel:.3f}"))

    lam_th = float(throughput(small, msml))
    out.append(row("prop4_throughput_n11_m12", 0.0,
                   f"sim={float(stats.throughput):.3f}_theory={lam_th:.3f}"))
    rel_host = abs(float(stats.throughput) - host.throughput) / host.throughput
    out.append(row("event_engine_60k_updates_n11_m12", dev_s * 1e6,
                   f"host_heap_s={host_s:.2f}_dev_s={dev_s:.2f}"
                   f"_rel_thr_vs_host={rel_host:.4f}"))

    # --- Jacobian: closed form vs autodiff ----------------------------------
    us_cf = time_us(jax.jit(lambda p: delay_jacobian(
        small._replace(p=p), msml)), small.p, iters=5)
    jac_ad = jax.jit(jax.jacobian(lambda p: expected_relative_delay(
        small._replace(p=p), msml)))
    us_ad = time_us(jac_ad, small.p, iters=5)
    out.append(row("thm2_jacobian_closed_form", us_cf,
                   f"autodiff={us_ad:.0f}us_ratio={us_ad / us_cf:.2f}"))
    return out
