"""Table 3 analogue: wall-clock time reduction of the time-optimized
configuration (p*_tau, m*_tau) vs AsyncSGD / Max-Throughput / Round-Opt on
synthetic-EMNIST async FL training (Dirichlet non-IID), across service-time
distributions.  Paper reports 29-46% reduction vs AsyncSGD (Table 3)."""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import LearningConstants
from repro.data import (dirichlet_partition, make_synthetic_image_dataset,
                        train_test_split)
from repro.fl import (AsyncFLConfig, AsyncFLTrainer, make_strategies,
                      mlp_classifier)
from repro.fl.strategies import PAPER_CLUSTERS_TABLE1, build_network_params

from .common import row

CONSTS = LearningConstants(L=1.0, delta=1.0, sigma=1.0, M=2.0, G=5.0, eps=1.0)


def time_to_acc(strategy, p, m, net, clients, test, dist, horizon, target,
                eta, seed=0):
    model = mlp_classifier(28 * 28, test[1].max() + 1, hidden=(64,))
    tr = AsyncFLTrainer(
        model, clients, net._replace(p=jnp.asarray(p)), m,
        config=AsyncFLConfig(eta=eta, batch_size=32,
                             eval_every_time=horizon / 60,
                             distribution=dist, seed=seed, grad_clip=5.0),
        test_data=test)
    log = tr.run(horizon_time=horizon)
    return log.time_to_accuracy(target), log


def run(scale: int = 10, horizon: float = 240.0, target: float = 0.55,
        distributions=("exponential", "lognormal"), seeds=(0, 1)) -> list[str]:
    out = []
    net = build_network_params(PAPER_CLUSTERS_TABLE1, scale=scale)
    n = net.n
    strat = make_strategies(net, CONSTS, steps=200, m_max=n + 6)

    full = make_synthetic_image_dataset(num_classes=10, samples_per_class=120,
                                        seed=0)
    train, test_ds = train_test_split(full, 0.2, seed=1)
    parts = dirichlet_partition(train.y, n, alpha=0.2, seed=0)
    clients = [(train.x[i], train.y[i]) for i in parts]
    test = (test_ds.x, test_ds.y)

    # max-throughput is unstable at the baseline lr (paper: needed 20x lower)
    etas = {"asyncsgd": 0.05, "round_opt": 0.05, "time_opt": 0.05,
            "max_throughput": 0.01}

    t0 = time.perf_counter()
    for dist in distributions:
        times = {}
        for name, (p, m) in strat.items():
            ts = []
            for seed in seeds:
                t, _ = time_to_acc(name, p, m, net, clients, test, dist,
                                   horizon, target, etas[name], seed)
                ts.append(t)
            times[name] = float(np.mean(ts))
        base = times["asyncsgd"]
        summary = ";".join(f"{k}={v:.1f}" for k, v in times.items())
        out.append(row(f"table3_time_to_{target}_{dist}", 0.0, summary))
        for other in ("asyncsgd", "max_throughput", "round_opt"):
            if np.isfinite(times[other]) and np.isfinite(times["time_opt"]):
                red = 100 * (1 - times["time_opt"] / times[other])
            else:
                red = float("nan")
            out.append(row(f"table3_reduction_vs_{other}_{dist}", 0.0,
                           f"{red:.1f}%"))
    us = (time.perf_counter() - t0) * 1e6
    out.append(row("table3_total_bench", us, f"target={target}"))
    return out
