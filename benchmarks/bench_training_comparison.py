"""Table 3 analogue: wall-clock time reduction of the time-optimized
configuration (p*_tau, m*_tau) vs AsyncSGD / Max-Throughput / Round-Opt on
synthetic-EMNIST async FL training (Dirichlet non-IID), across service-time
distributions.  Paper reports 29-46% reduction vs AsyncSGD (Table 3).

The whole comparison is declarative: ``ScenarioSuite.strategy_grid``
resolves the four strategies through the registry and
``run(mode="train")`` executes the strategies x seeds grid on the fused
device engine (``repro.fl.engine``) as bucketed jitted scans.
``run_engine_sweep`` additionally measures that hot path against the host
event-loop reference (``AsyncFLTrainer.from_scenario(backend="host")``) —
the multi-seed speedup and the statistics agreement are the PR-over-PR
tracked numbers in ``BENCH_smoke.json``."""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.data import (dirichlet_partition, make_synthetic_image_dataset,
                        train_test_split)
from repro.fl import AsyncFLTrainer, DeviceTrainer, mlp_classifier
from repro.scenario import ScenarioSuite

from .common import row
from .scenarios import record, table1_scenario

STRATEGIES = ("asyncsgd", "max_throughput", "round_opt", "time_opt")


def _problem(base, seed_data=0):
    full = make_synthetic_image_dataset(num_classes=10, samples_per_class=120,
                                        seed=seed_data)
    train, test_ds = train_test_split(full, 0.2, seed=seed_data + 1)
    parts = dirichlet_partition(train.y, base.n, alpha=0.2, seed=seed_data)
    clients = [(train.x[i], train.y[i]) for i in parts]
    return clients, (test_ds.x, test_ds.y)


def run(scale: int = 10, horizon: float = 240.0, target: float = 0.55,
        distributions=("exponential", "lognormal"), seeds=(0, 1)) -> list[str]:
    out = []
    base = record("training_comparison",
                  table1_scenario(scale, strategy="time_opt", steps=200,
                                  m_max=None,
                                  name=f"training_comparison_s{scale}"))
    base = base.replace(strategy=dataclasses.replace(base.strategy,
                                                     m_max=base.n + 6))
    clients, test = _problem(base)

    # resolve the strategies once (closed forms are law-independent), then
    # re-run the same explicit (p, m, eta) grid under each service law
    res_suite = ScenarioSuite.strategy_grid(base, STRATEGIES)
    strat = res_suite.resolve()

    t0 = time.perf_counter()
    for dist in distributions:
        net = dataclasses.replace(base.network, law=dist)
        scns = {}
        for name in STRATEGIES:
            src = res_suite.scenarios[name]
            scns[name] = src.replace(
                network=net,
                learning=dataclasses.replace(src.learning, eta=src.eta()),
                strategy=dataclasses.replace(src.strategy, name="explicit",
                                             p=strat[name][0],
                                             m=strat[name][1]))
        suite = ScenarioSuite(scns, seeds=seeds)
        model = mlp_classifier(28 * 28, int(test[1].max()) + 1, hidden=(64,))
        grid = suite.run(mode="train", model=model, clients=clients,
                         test_data=test, horizon_time=horizon,
                         batch_size=32, eval_every_time=horizon / 60)
        times = {name: float(np.mean([log.time_to_accuracy(target)
                                      for log in logs]))
                 for name, logs in grid.entries.items()}
        summary = ";".join(f"{k}={v:.1f}" for k, v in times.items())
        out.append(row(f"table3_time_to_{target}_{dist}", 0.0, summary))
        for other in ("asyncsgd", "max_throughput", "round_opt"):
            if np.isfinite(times[other]) and np.isfinite(times["time_opt"]):
                red = 100 * (1 - times["time_opt"] / times[other])
            else:
                red = float("nan")
            out.append(row(f"table3_reduction_vs_{other}_{dist}", 0.0,
                           f"{red:.1f}%"))
    us = (time.perf_counter() - t0) * 1e6
    out.append(row("table3_total_bench", us, f"target={target}"))
    return out


def run_engine_sweep(scale: int = 20, horizon: float = 40.0,
                     seeds=tuple(range(8))) -> list[str]:
    """Multi-seed strategy comparison on the fused engine vs the host loop.

    The acceptance workload of the event-engine PR: >= 8 seeds x the four
    Table-3 strategies.  Records (a) wall-clock of the host event loop, of
    the first fused call (incl. compile) and of a steady-state fused call;
    (b) throughput / staleness / energy agreement between the engines."""
    out = []
    base = record("event_engine",
                  table1_scenario(scale, strategy="time_opt", steps=150,
                                  name=f"event_engine_s{scale}"))
    base = base.replace(strategy=dataclasses.replace(base.strategy,
                                                     m_max=base.n + 6))
    clients, test = _problem(base)
    seeds = list(seeds)

    suite = ScenarioSuite.strategy_grid(base, STRATEGIES, seeds=seeds)
    strat = suite.resolve()
    model = mlp_classifier(28 * 28, int(test[1].max()) + 1, hidden=(64,))
    eval_kw = dict(batch_size=32, eval_every_time=horizon / 20,
                   eval_batch=256)

    # -- host reference loop (one python event loop per lane) ---------------
    t0 = time.perf_counter()
    host_stats = []
    for name in STRATEGIES:
        scn = suite.scenarios[name]
        p, m = strat[name]
        for seed in seeds:
            tr = AsyncFLTrainer.from_scenario(
                scn.with_strategy("explicit", p=p, m=m), model, clients,
                test_data=test,
                eta=scn.eta(), seed=seed, backend="host", **eval_kw)
            log = tr.run(horizon_time=horizon)
            host_stats.append((log.throughput,
                               float(np.sum(p * log.mean_delay)), int(m)))
    host_s = time.perf_counter() - t0

    # -- fused device engine: whole grid in bucketed vmapped scans ----------
    dev = DeviceTrainer.from_scenario(base, model, clients, test_data=test,
                                      **eval_kw)
    lanes_p = [strat[name][0] for name in STRATEGIES for _ in seeds]
    lanes_m = [int(strat[name][1]) for name in STRATEGIES for _ in seeds]
    lanes_eta = [suite.scenarios[name].eta() for name in STRATEGIES
                 for _ in seeds]
    lanes_seed = [s for _ in STRATEGIES for s in seeds]
    t0 = time.perf_counter()
    logs, _ = dev.run_lanes(lanes_p, lanes_m, lanes_eta, lanes_seed, horizon)
    dev_first_s = time.perf_counter() - t0
    # steady state: best of two re-runs of the identical workload (compile
    # cache fully warm; CI boxes with 2 cores are noisy, hence the min)
    dev_s = float("inf")
    for _ in range(2):
        t0 = time.perf_counter()
        logs, _ = dev.run_lanes(lanes_p, lanes_m, lanes_eta, lanes_seed,
                                horizon)
        dev_s = min(dev_s, time.perf_counter() - t0)

    # -- agreement (seed-averaged, tolerances documented in ROADMAP) --------
    thr_host = np.mean([t for t, _, _ in host_stats])
    thr_dev = np.mean([log.throughput for log in logs])
    stale_host = np.mean([s for _, s, _ in host_stats])
    stale_dev = np.mean([float(np.sum(p * log.mean_delay))
                         for p, log in zip(lanes_p, logs)])
    rel_thr = abs(thr_dev - thr_host) / thr_host
    rel_stale = abs(stale_dev - stale_host) / max(stale_host, 1e-9)
    speed = host_s / dev_s
    lanes = len(lanes_m)
    out.append(row("event_engine_sweep", dev_s * 1e6,
                   f"lanes={lanes}_seeds={len(seeds)}_host_s={host_s:.2f}"
                   f"_dev_first_s={dev_first_s:.2f}_dev_s={dev_s:.2f}"
                   f"_speedup={speed:.1f}x"))
    out.append(row("event_engine_agreement", 0.0,
                   f"rel_thr={rel_thr:.3f}_rel_staleness={rel_stale:.3f}"))
    return out
