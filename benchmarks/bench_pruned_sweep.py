"""Paper-scale pruned vs full concurrency sweep (ROADMAP: "a paper-scale
(n=100, m_max=132) timing comparison ... is still worth recording").

Times the one-compile full-grid ``batched_concurrency_sweep`` against the
coarse-to-fine ``pruned_concurrency_sweep`` on the Table-1 population at
full scale (n = 100, m grid 2..132) and records the speedup plus the
winner agreement — the pruning contract is that both land on the same
(or a value-equivalent) concurrency.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import batched_concurrency_sweep, pruned_concurrency_sweep
from repro.core.batched import make_time_objective_padded

from .common import row
from .scenarios import CONSTS, record, table1_scenario


def run(scale: int = 1, m_max: int = 132, steps: int = 8) -> list[str]:
    scn = record("pruned_sweep",
                 table1_scenario(scale, strategy="time_opt", steps=steps,
                                 m_max=m_max, search="pruned",
                                 name=f"pruned_sweep_s{scale}"))
    params = scn.params()
    obj = make_time_objective_padded(params, CONSTS, m_max)
    m_grid = np.arange(2, m_max + 1)

    t0 = time.perf_counter()
    full = batched_concurrency_sweep(obj, params, m_grid=jnp.asarray(m_grid),
                                     m_max=m_max, steps=steps)
    us_full = (time.perf_counter() - t0) * 1e6

    t0 = time.perf_counter()
    pruned = pruned_concurrency_sweep(obj, params, m_grid=m_grid,
                                      m_max=m_max, steps=steps)
    us_pruned = (time.perf_counter() - t0) * 1e6

    rel = abs(pruned.best.value - full.best.value) / abs(full.best.value)
    rows_full = len(m_grid)
    rows_pruned = len(pruned.best.history)
    return [
        row("pruned_sweep_full", us_full,
            f"n={params.n}_rows={rows_full}_best_m={full.best.m}"),
        row("pruned_sweep_pruned", us_pruned,
            f"rows={rows_pruned}_best_m={pruned.best.m}"
            f"_speedup={us_full / max(us_pruned, 1.0):.2f}x"
            f"_rel_value_err={rel:.2e}"),
    ]
