"""Appendix H / Table 7 analogue: round-complexity-optimized routing on the
Table-6 population — K_eps reduction and staleness-impact homogenization."""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import (LearningConstants, batched_concurrency_sweep,
                        expected_relative_delay, make_round_objective_padded,
                        round_complexity, throughput)
from repro.fl.strategies import (PAPER_CLUSTERS_TABLE6, build_network_params,
                                 cluster_labels)

from .common import row

CONSTS = LearningConstants(L=1.0, delta=1.0, sigma=1.0, M=2.0, G=5.0, eps=1.0)


def run(scale: int = 5, steps: int = 300) -> list[str]:
    out = []
    params = build_network_params(PAPER_CLUSTERS_TABLE6, scale=scale)
    labels = np.array(cluster_labels(PAPER_CLUSTERS_TABLE6, scale=scale))
    n = params.n
    m = n  # full concurrency, as in Appendix H

    t0 = time.perf_counter()
    # single-m sweep (B = 1) through the shared batched engine / Buzen batch
    res = batched_concurrency_sweep(
        make_round_objective_padded(params, CONSTS, m), params,
        m_grid=jnp.asarray([m]), steps=steps).best
    us = (time.perf_counter() - t0) * 1e6

    uni = jnp.full((n,), 1.0 / n)
    k_uni = float(round_complexity(params, m, CONSTS))
    k_opt = res.value
    p = np.asarray(res.p)

    def impact(pv):
        d = np.asarray(expected_relative_delay(
            params._replace(p=jnp.asarray(pv)), m))
        return d / np.maximum(np.asarray(pv), 1e-12) ** 2

    i_uni, i_opt = impact(np.asarray(uni)), impact(p)
    # paper: round-opt prioritizes stragglers (type D) and homogenizes impact
    pD = p[labels == "D"].mean()
    pE = p[labels == "E"].mean()
    out.append(row("table7_round_opt", us,
                   f"K_uni={k_uni:.1f}_K_opt={k_opt:.1f}"
                   f"_reduction={100 * (1 - k_opt / k_uni):.1f}%"))
    out.append(row("table7_straggler_priority", 0.0,
                   f"pD={pD * 100:.3f}%_pE={pE * 100:.3f}%_pD>pE={pD > pE}"))
    out.append(row("table7_impact_homogenized", 0.0,
                   f"max_impact_uni={i_uni.max():.1f}"
                   f"_max_impact_opt={i_opt.max():.1f}"
                   f"_improved={i_opt.max() < i_uni.max()}"))
    lam_opt = float(throughput(params._replace(p=res.p), m))
    lam_uni = float(throughput(params, m))
    out.append(row("table7_throughput_cost", 0.0,
                   f"lambda_uni={lam_uni:.2f}_lambda_opt={lam_opt:.2f}"))
    return out
