"""Appendix H / Table 7 analogue: round-complexity-optimized routing on the
Table-6 population — K_eps reduction and staleness-impact homogenization.

The uniform baseline and the round-optimized configuration are two
scenarios of one suite: the strategy registry resolves ``round_opt`` (a
B = 1 batched sweep through the shared engine) and ``run(mode="analyze")``
reports K_eps / throughput for both in one jitted batch."""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import expected_relative_delay, throughput
from repro.scenario import ScenarioSuite

from .common import row
from .scenarios import record, table6_scenario


def run(scale: int = 5, steps: int = 300) -> list[str]:
    out = []
    base = record("round_optimization",
                  table6_scenario(scale, steps=steps,
                                  name=f"round_optimization_s{scale}"))
    params = base.params()
    labels = np.array(base.network.labels)
    n = base.n
    m = n  # full concurrency, as in Appendix H

    t0 = time.perf_counter()
    suite = ScenarioSuite.strategy_grid(base, ("asyncsgd", "round_opt"), m=m)
    res = suite.run(mode="analyze")
    us = (time.perf_counter() - t0) * 1e6

    k_uni = res.entries["asyncsgd"]["K_eps"]
    k_opt = res.entries["round_opt"]["K_eps"]
    p = res.entries["round_opt"]["p"]

    def impact(pv):
        d = np.asarray(expected_relative_delay(
            params._replace(p=jnp.asarray(pv)), m))
        return d / np.maximum(np.asarray(pv), 1e-12) ** 2

    i_uni = impact(res.entries["asyncsgd"]["p"])
    i_opt = impact(p)
    # paper: round-opt prioritizes stragglers (type D) and homogenizes impact
    pD = p[labels == "D"].mean()
    pE = p[labels == "E"].mean()
    out.append(row("table7_round_opt", us,
                   f"K_uni={k_uni:.1f}_K_opt={k_opt:.1f}"
                   f"_reduction={100 * (1 - k_opt / k_uni):.1f}%"))
    out.append(row("table7_straggler_priority", 0.0,
                   f"pD={pD * 100:.3f}%_pE={pE * 100:.3f}%_pD>pE={pD > pE}"))
    out.append(row("table7_impact_homogenized", 0.0,
                   f"max_impact_uni={i_uni.max():.1f}"
                   f"_max_impact_opt={i_opt.max():.1f}"
                   f"_improved={i_opt.max() < i_uni.max()}"))
    lam_opt = res.entries["round_opt"]["throughput"]
    lam_uni = float(throughput(params, m))
    out.append(row("table7_throughput_cost", 0.0,
                   f"lambda_uni={lam_uni:.2f}_lambda_opt={lam_opt:.2f}"))
    return out
