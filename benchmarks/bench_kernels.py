"""Kernel micro-benchmarks (interpret mode on CPU: correctness + relative
cost only; wall-clock MFU belongs to real TPU runs)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.models.attention import decode_attention_ref, flash_attention_ref

from .common import row, time_us


def run() -> list[str]:
    out = []
    rng = np.random.default_rng(0)
    B, S, H, KV, D = 1, 512, 8, 2, 64
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KV, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KV, D)), jnp.float32)

    us_ref = time_us(jax.jit(lambda q, k, v: flash_attention_ref(
        q, k, v, causal=True, block_k=128)), q, k, v, iters=5)
    us_pal = time_us(lambda: ops.flash_attention(q, k, v, causal=True,
                                                 interpret=True), iters=3)
    err = float(jnp.max(jnp.abs(
        ops.flash_attention(q, k, v, causal=True, interpret=True)
        - flash_attention_ref(q, k, v, causal=True))))
    out.append(row("flash_attention_xla_ref_512", us_ref, "chunked_online_softmax"))
    out.append(row("flash_attention_pallas_interpret_512", us_pal,
                   f"max_err_vs_ref={err:.1e}"))

    qd = jnp.asarray(rng.normal(size=(4, 1, H, D)), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(4, 2048, KV, D)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(4, 2048, KV, D)), jnp.float32)
    us_dref = time_us(jax.jit(lambda q, k, v: decode_attention_ref(
        q, k, v, 2048)), qd, kc, vc, iters=5)
    us_dpal = time_us(lambda: ops.decode_attention(qd, kc, vc,
                                                   jnp.int32(2048),
                                                   interpret=True), iters=3)
    out.append(row("decode_attention_xla_ref_2k", us_dref, "cache=2048"))
    out.append(row("decode_attention_pallas_interpret_2k", us_dpal,
                   "cache=2048"))

    params = {"w": jnp.asarray(rng.normal(size=(512, 512)), jnp.float32)}
    grads = {"w": jnp.asarray(rng.normal(size=(512, 512)), jnp.float32)}
    us_fu = time_us(lambda: ops.fused_async_update(params, grads, 0.01,
                                                   interpret=True), iters=3)
    out.append(row("fused_async_update_interpret_262k", us_fu,
                   "update+gradnorm_one_pass"))
    return out
