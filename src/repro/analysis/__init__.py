"""Static analysis for the padded-``n`` bitwise contract and TPU readiness.

Three cooperating tools, wired into tier-1 and CI (``python -m
repro.analysis``):

  * :mod:`repro.analysis.lint` — AST contract linter: named rules over
    ``src/`` (no raw ``jnp.sum``/``.sum()`` in contract-marked modules, no
    ``jax.random.categorical`` routing, no stringly-typed law/strategy
    dispatch, no host ``numpy``/Python branching/``os.environ`` inside
    traced code) with ``# contract: allow(<rule>): <why>`` suppressions;
  * :mod:`repro.analysis.audit` — jaxpr auditor: builds the jaxpr of every
    resident program (suite analyze/simulate buckets, the trainer scan,
    both Pallas kernels in interpret mode) and reports f64 primitives,
    clock downcasts, host callbacks and op/flop counts as the JSON
    worklist for the real-TPU compiled pass;
  * :mod:`repro.analysis.tracecheck` — recompile sentinel: counts XLA
    compilations/retraces per program name so the suite planner's
    "mixed-``n`` suite == 1-2 programs" is a machine-checked budget.

This package imports jax lazily — ``lint`` and ``hygiene`` run without it.
"""
from __future__ import annotations

from .lint import Violation, lint_file, lint_source, lint_tree  # noqa: F401

__all__ = ["Violation", "lint_file", "lint_source", "lint_tree"]
