"""Jaxpr auditor — the TPU-compilability worklist as a JSON report.

The ROADMAP's real-TPU open item starts with an audit: which resident
programs carry f64 primitives (TPU-hostile — the event engine's clocks
are deliberately f64 on CPU), where clock values get downcast
(``convert_element_type`` f64 -> f32), and whether anything escapes to
the host (callbacks).  This module builds the jaxpr of every resident
program at tiny static sizes and walks it recursively (scan/while/cond
branch jaxprs included, scan bodies weighted by their trip count) to
report, per program:

  * op counts per primitive and a rough flop estimate;
  * f64 primitive count + example source locations;
  * f64 -> f32/bf16 ``convert_element_type`` downcasts (clock truncation
    candidates) + examples;
  * host callbacks (``pure_callback``/``io_callback``/...);
  * unbounded loops (``while_loop`` — trip count unknown, flops undercounted);
  * a ``tpu_compilable`` verdict with the blocking findings named.

``python -m repro.analysis audit --out AUDIT_jaxpr.json`` emits the
report CI uploads next to ``BENCH_smoke.json``; the schema is pinned by
``tests/data/audit_schema.json``.
"""
from __future__ import annotations

import json
from typing import Callable, Optional

SCHEMA_VERSION = 1

_HOST_CALLBACK_PRIMS = frozenset(
    {"pure_callback", "io_callback", "debug_callback", "outside_call",
     "host_callback_call", "python_callback"})

# elementwise primitives: flops ~= output size
_ELEMENTWISE = frozenset(
    {"add", "sub", "mul", "div", "rem", "pow", "integer_pow", "neg", "abs",
     "sign", "floor", "ceil", "round", "exp", "log", "log1p", "expm1",
     "sqrt", "rsqrt", "cbrt", "tanh", "logistic", "erf", "erf_inv", "sin",
     "cos", "tan", "atan2", "max", "min", "and", "or", "xor", "not",
     "select_n", "clamp", "nextafter", "square"})
_REDUCE = frozenset(
    {"reduce_sum", "reduce_max", "reduce_min", "reduce_prod", "reduce_and",
     "reduce_or", "argmax", "argmin", "cumsum", "cumlogsumexp", "cummax",
     "cummin", "cumprod"})


def _subjaxprs(params: dict):
    """(jaxpr, trip_multiplier) pairs nested in one eqn's params —
    duck-typed so pjit/scan/while/cond/custom-vjp/pallas all walk."""
    length = params.get("length", 1) if "length" in params else 1
    for key, val in params.items():
        items = val if isinstance(val, (list, tuple)) else [val]
        for item in items:
            inner = getattr(item, "jaxpr", item)
            if hasattr(inner, "eqns"):
                yield inner, (length if key == "jaxpr" else 1)


def _aval_size(aval) -> int:
    size = 1
    for d in getattr(aval, "shape", ()) or ():
        try:
            size *= int(d)
        except (TypeError, ValueError):  # symbolic dim
            size *= 1
    return size


def _eqn_flops(eqn) -> float:
    name = eqn.primitive.name
    out_size = sum(_aval_size(v.aval) for v in eqn.outvars)
    if name == "dot_general":
        dnums = eqn.params.get("dimension_numbers")
        lhs = eqn.invars[0].aval
        contract = 1
        if dnums is not None:
            for d in dnums[0][0]:
                try:
                    contract *= int(lhs.shape[d])
                except (TypeError, ValueError, IndexError):
                    pass
        return 2.0 * out_size * contract
    if name in _REDUCE:
        return float(sum(_aval_size(v.aval) for v in eqn.invars))
    if name in _ELEMENTWISE:
        return float(out_size)
    return 0.0


def _source_line(eqn) -> Optional[str]:
    try:
        from jax._src import source_info_util

        return source_info_util.summarize(eqn.source_info)
    except Exception:  # noqa: BLE001 — examples are best-effort
        return None


def analyze_jaxpr(closed) -> dict:
    """Walk one (Closed)Jaxpr recursively; return the findings dict."""
    import numpy as np

    op_counts: dict[str, int] = {}
    f64_counts: dict[str, int] = {}
    f64_examples: list[str] = []
    downcasts = 0
    downcast_examples: list[str] = []
    callbacks: dict[str, int] = {}
    unbounded_loops = 0
    flops = 0.0
    total = 0

    def is_f64(dtype) -> bool:
        if dtype is None:
            return False
        try:  # extended dtypes (PRNG key<fry>) are not np dtypes
            return np.dtype(dtype) == np.float64
        except TypeError:
            return False

    def walk(jaxpr, mult: int):
        nonlocal downcasts, unbounded_loops, flops, total
        for eqn in jaxpr.eqns:
            name = eqn.primitive.name
            op_counts[name] = op_counts.get(name, 0) + mult
            total += mult
            flops += mult * _eqn_flops(eqn)
            if name == "while":
                unbounded_loops += 1
            if name in _HOST_CALLBACK_PRIMS:
                callbacks[name] = callbacks.get(name, 0) + mult
            out_dtypes = [getattr(v.aval, "dtype", None)
                          for v in eqn.outvars]
            if any(is_f64(dt) for dt in out_dtypes):
                f64_counts[name] = f64_counts.get(name, 0) + mult
                if len(f64_examples) < 8:
                    src = _source_line(eqn)
                    f64_examples.append(
                        f"{name} @ {src}" if src else name)
            if name == "convert_element_type":
                in_dt = getattr(eqn.invars[0].aval, "dtype", None)
                out_dt = out_dtypes[0] if out_dtypes else None
                if is_f64(in_dt) and out_dt is not None and \
                        np.dtype(out_dt).kind == "f" and \
                        np.dtype(out_dt).itemsize < 8:
                    downcasts += mult
                    if len(downcast_examples) < 8:
                        src = _source_line(eqn)
                        downcast_examples.append(
                            f"f64->{np.dtype(out_dt).name} @ {src}"
                            if src else f"f64->{np.dtype(out_dt).name}")
            for sub, sub_mult in _subjaxprs(eqn.params):
                walk(sub, mult * sub_mult)

    walk(getattr(closed, "jaxpr", closed), 1)
    f64_total = sum(f64_counts.values())
    cb_total = sum(callbacks.values())
    blockers = []
    if f64_total:
        blockers.append("f64-primitives")
    if cb_total:
        blockers.append("host-callbacks")
    return {
        "total_primitives": total,
        "op_counts": dict(sorted(op_counts.items())),
        "flops_estimate": flops,
        "f64": {"count": f64_total,
                "op_counts": dict(sorted(f64_counts.items())),
                "examples": f64_examples},
        "downcasts_f64_to_f32": {"count": downcasts,
                                 "examples": downcast_examples},
        "host_callbacks": {"count": cb_total,
                           "ops": dict(sorted(callbacks.items()))},
        "unbounded_loops": unbounded_loops,
        "tpu_compilable": not blockers,
        "tpu_blockers": blockers,
    }


# ---------------------------------------------------------------------------
# resident programs, built at tiny static sizes
# ---------------------------------------------------------------------------

def _tiny_nets(L: int = 2, n_max: int = 3, cs: bool = False):
    import numpy as np

    from ..core.buzen import NetworkParams, pad_network
    from ..scenario.suite import _stack_params

    rng = np.random.default_rng(7)
    nets = []
    for i in range(L):
        n = n_max - (i % 2)  # mixed populations exercise the padding path
        net = NetworkParams(
            p=rng.dirichlet(np.ones(n)),
            mu_c=rng.uniform(0.5, 4.0, n),
            mu_d=rng.uniform(0.5, 4.0, n),
            mu_u=rng.uniform(0.5, 4.0, n))
        if cs:
            net = net.with_cs(rng.uniform(0.5, 4.0))
        nets.append(pad_network(net, n_max))
    return _stack_params(nets)


def _tiny_classes(L: int = 2, c_max: int = 3):
    import numpy as np

    from ..core.buzen import ClassParams, pad_classes
    from ..scenario.suite import _stack_params

    rng = np.random.default_rng(11)
    lanes = []
    for i in range(L):
        C = c_max - (i % 2)  # mixed class counts exercise pad_classes
        cnt = rng.integers(1, 4, C)
        cls = ClassParams(
            p=rng.dirichlet(np.ones(C)) / cnt,
            mu_c=rng.uniform(0.5, 4.0, C),
            mu_d=rng.uniform(2.0, 6.0, C),
            mu_u=rng.uniform(2.0, 6.0, C),
            count=cnt)
        lanes.append(pad_classes(cls, c_max))
    return _stack_params(lanes)


def resident_programs() -> dict[str, tuple[str, Callable]]:
    """name -> (description, thunk); each thunk returns a ClosedJaxpr.

    Every resident program of the pipeline: the suite's analyze and
    simulate bucket programs (batched / pallas-interpret / the per-lane
    reference scan), the fused trainer scan, and both Pallas kernels'
    interpret paths.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    L, n_max, m_max = 2, 3, 3

    def suite_analyze():
        from ..core.complexity import LearningConstants
        from ..core.energy import PowerProfile
        from ..scenario.suite import (_build_analyze, _pad_power,
                                      _stack_consts, _stack_power)

        prm = _tiny_nets(L, n_max)
        consts = _stack_consts([LearningConstants(M=2.0, G=5.0)] * L)
        power = _stack_power([
            _pad_power(PowerProfile(
                P_c=np.full(n_max - (i % 2), 1.5),
                P_u=np.full(n_max - (i % 2), 1.0),
                P_d=np.full(n_max - (i % 2), 0.5)), n_max)
            for i in range(L)])
        m_vec = jnp.asarray([2, 3], jnp.int64)
        rho = jnp.asarray([0.3, 0.5])
        fn = _build_analyze(m_max, has_power=True)
        return jax.make_jaxpr(fn)(prm, m_vec, consts, power, rho)

    def _sim_args():
        prm = _tiny_nets(L, n_max)
        m_vec = jnp.asarray([2, 3], jnp.int32)
        keys = jnp.stack([jax.random.PRNGKey(s) for s in range(L)])
        return prm, m_vec, keys

    def suite_simulate_batched():
        from ..sim.batched_events import build_lanes_fn

        fn = build_lanes_fn("batched", 6, 2, "exponential", m_max, False)
        prm, m_vec, keys = _sim_args()
        return jax.make_jaxpr(lambda p, m, k: fn(p, m, k, None))(
            prm, m_vec, keys)

    def suite_simulate_batched_traced():
        from ..sim.batched_events import build_lanes_fn

        fn = build_lanes_fn("batched", 6, 2, "exponential", m_max, False,
                            trace_events=8)
        prm, m_vec, keys = _sim_args()
        return jax.make_jaxpr(lambda p, m, k: fn(p, m, k, None))(
            prm, m_vec, keys)

    def suite_simulate_pallas():
        from ..sim.batched_events import build_lanes_fn

        fn = build_lanes_fn("pallas", 6, 2, "exponential", m_max, False,
                            interpret=True)
        prm, m_vec, keys = _sim_args()
        return jax.make_jaxpr(lambda p, m, k: fn(p, m, k, None))(
            prm, m_vec, keys)

    def simulate_reference_lane():
        from ..core import events

        prm, m_vec, keys = _sim_args()
        one = jax.tree_util.tree_map(lambda x: x[0], prm)
        return jax.make_jaxpr(
            lambda p, m, k: events._simulate_stats(
                p, m, k, 6, 2, "exponential", m_max, None))(
            one, m_vec[0], keys[0])

    def trainer_scan():
        from ..fl.engine import DeviceTrainer
        from ..fl.models import mlp_classifier
        from ..fl.trainer import AsyncFLConfig
        from ..core.buzen import NetworkParams

        rng = np.random.default_rng(9)
        n = 3
        net = NetworkParams(
            p=jnp.asarray(rng.dirichlet(np.ones(n))),
            mu_c=jnp.asarray(rng.uniform(0.5, 4.0, n)),
            mu_d=jnp.asarray(rng.uniform(0.5, 4.0, n)),
            mu_u=jnp.asarray(rng.uniform(0.5, 4.0, n)))
        clients = [(rng.normal(size=(4, 4)).astype(np.float32),
                    rng.integers(0, 2, size=4).astype(np.int32))
                   for _ in range(n)]
        test = (rng.normal(size=(6, 4)).astype(np.float32),
                rng.integers(0, 2, size=6).astype(np.int32))
        model = mlp_classifier(4, 2, hidden=(4,))
        trainer = DeviceTrainer(
            model, clients, net,
            AsyncFLConfig(eta=0.05, batch_size=2, eval_every_time=2.0),
            test_data=test)
        K, G = 4, 2
        fn = trainer._build(K, G, m_max, 6.0, "batched", None)
        params0 = jax.vmap(model.init)(
            jnp.stack([jax.random.PRNGKey(s) for s in range(L)]))
        p_mat = jnp.asarray(np.stack([np.asarray(net.p)] * L))
        ms = jnp.asarray([2] * L, jnp.int32)
        etas = jnp.asarray([0.05] * L)
        sim_keys = jnp.stack([jax.random.PRNGKey(10 + s) for s in range(L)])
        data_keys = jnp.stack([jax.random.PRNGKey(20 + s) for s in range(L)])
        return jax.make_jaxpr(fn)(params0, p_mat, ms, etas, sim_keys,
                                  data_keys)

    def trainer_scan_traced():
        from ..fl.engine import DeviceTrainer
        from ..fl.models import mlp_classifier
        from ..fl.trainer import AsyncFLConfig
        from ..core.buzen import NetworkParams

        rng = np.random.default_rng(9)
        n = 3
        net = NetworkParams(
            p=jnp.asarray(rng.dirichlet(np.ones(n))),
            mu_c=jnp.asarray(rng.uniform(0.5, 4.0, n)),
            mu_d=jnp.asarray(rng.uniform(0.5, 4.0, n)),
            mu_u=jnp.asarray(rng.uniform(0.5, 4.0, n)))
        clients = [(rng.normal(size=(4, 4)).astype(np.float32),
                    rng.integers(0, 2, size=4).astype(np.int32))
                   for _ in range(n)]
        test = (rng.normal(size=(6, 4)).astype(np.float32),
                rng.integers(0, 2, size=6).astype(np.int32))
        model = mlp_classifier(4, 2, hidden=(4,))
        trainer = DeviceTrainer(
            model, clients, net,
            AsyncFLConfig(eta=0.05, batch_size=2, eval_every_time=2.0),
            test_data=test)
        K, G = 4, 2
        fn = trainer._build(K, G, m_max, 6.0, "batched", None,
                            trace_updates=8)
        params0 = jax.vmap(model.init)(
            jnp.stack([jax.random.PRNGKey(s) for s in range(L)]))
        p_mat = jnp.asarray(np.stack([np.asarray(net.p)] * L))
        ms = jnp.asarray([2] * L, jnp.int32)
        etas = jnp.asarray([0.05] * L)
        sim_keys = jnp.stack([jax.random.PRNGKey(10 + s) for s in range(L)])
        data_keys = jnp.stack([jax.random.PRNGKey(20 + s) for s in range(L)])
        return jax.make_jaxpr(fn)(params0, p_mat, ms, etas, sim_keys,
                                  data_keys)

    def trainer_scan_lane_nets():
        from ..fl.engine import DeviceTrainer, pad_client_data
        from ..fl.models import mlp_classifier
        from ..fl.trainer import AsyncFLConfig
        from ..core.buzen import NetworkParams, pad_network

        rng = np.random.default_rng(9)
        n_top = 3

        def mk_net(n):
            return NetworkParams(
                p=jnp.asarray(rng.dirichlet(np.ones(n))),
                mu_c=jnp.asarray(rng.uniform(0.5, 4.0, n)),
                mu_d=jnp.asarray(rng.uniform(0.5, 4.0, n)),
                mu_u=jnp.asarray(rng.uniform(0.5, 4.0, n)))

        def mk_clients(n, s):
            return [(rng.normal(size=(s, 4)).astype(np.float32),
                     rng.integers(0, 2, size=s).astype(np.int32))
                    for _ in range(n)]

        test = (rng.normal(size=(6, 4)).astype(np.float32),
                rng.integers(0, 2, size=6).astype(np.int32))
        model = mlp_classifier(4, 2, hidden=(4,))
        trainer = DeviceTrainer(
            model, mk_clients(n_top, 4), mk_net(n_top),
            AsyncFLConfig(eta=0.05, batch_size=2, eval_every_time=2.0),
            test_data=test)
        K, G = 4, 2
        fn = trainer._build(K, G, m_max, 6.0, "batched", None,
                            lane_mode=True)
        # mixed populations: lane 1 is a 2-client net padded to n_top
        sizes_n = [n_top, n_top - 1]
        nets = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs),
            *[pad_network(mk_net(n), n_top) for n in sizes_n])
        tables = [pad_client_data(mk_clients(n, 3 + n % 2), n_total=n_top,
                                  min_samples=4) for n in sizes_n]
        lane_x = jnp.stack([t.x for t in tables])
        lane_y = jnp.stack([t.y for t in tables])
        lane_sizes = jnp.stack([t.sizes for t in tables])
        n_acts = jnp.asarray(np.asarray(sizes_n, np.float64))
        params0 = jax.vmap(model.init)(
            jnp.stack([jax.random.PRNGKey(s) for s in range(L)]))
        p_mat = jnp.stack([
            jnp.pad(net_p, (0, n_top - net_p.shape[0]))
            for net_p in (mk_net(n).p for n in sizes_n)])
        ms = jnp.asarray([2] * L, jnp.int32)
        etas = jnp.asarray([0.05] * L)
        sim_keys = jnp.stack([jax.random.PRNGKey(10 + s) for s in range(L)])
        data_keys = jnp.stack([jax.random.PRNGKey(20 + s) for s in range(L)])
        return jax.make_jaxpr(fn)(params0, nets, lane_x, lane_y,
                                  lane_sizes, n_acts, p_mat, ms, etas,
                                  sim_keys, data_keys)

    def suite_analyze_classes():
        from ..core.complexity import LearningConstants
        from ..scenario.suite import _build_analyze_classes, _stack_consts

        cls = _tiny_classes(L, n_max)
        consts = _stack_consts([LearningConstants(M=2.0, G=5.0)] * L)
        m_vec = jnp.asarray([2, 3], jnp.int64)
        rho = jnp.asarray([0.3, 0.5])
        fn = _build_analyze_classes(m_max, has_power=False)
        return jax.make_jaxpr(lambda c, m, co, r: fn(c, m, co, None, r))(
            cls, m_vec, consts, rho)

    def suite_simulate_classes():
        from ..sim.batched_events import build_class_lanes_fn

        fn = build_class_lanes_fn("batched", 6, 2, "exponential", m_max,
                                  False)
        cls = _tiny_classes(L, n_max)
        m_vec = jnp.asarray([2, 3], jnp.int32)
        keys = jnp.stack([jax.random.PRNGKey(s) for s in range(L)])
        return jax.make_jaxpr(lambda c, m, k: fn(c, m, k, None))(
            cls, m_vec, keys)

    def suite_simulate_sharded():
        from ..sim.sharded import build_sharded_lanes_fn

        fn = build_sharded_lanes_fn(6, 2, "exponential", m_max, False)
        prm, m_vec, keys = _sim_args()
        return jax.make_jaxpr(lambda p, m, k: fn(p, m, k, None))(
            prm, m_vec, keys)

    def kernel_buzen():
        from ..kernels.buzen import buzen_pallas_batched

        rng = np.random.default_rng(3)
        log_rho = jnp.asarray(rng.normal(size=(L, 3 * n_max)), jnp.float32)
        log_gamma = jnp.asarray(rng.normal(size=(L,)), jnp.float32)
        return jax.make_jaxpr(
            lambda lr, lg: buzen_pallas_batched(lr, lg, m_max,
                                                interpret=True))(
            log_rho, log_gamma)

    def kernel_buzen_classes():
        from ..kernels.buzen import buzen_classes_pallas_batched

        rng = np.random.default_rng(5)
        log_rho = jnp.asarray(rng.normal(size=(L, n_max)), jnp.float32)
        counts = jnp.asarray(rng.integers(1, 4, size=(L, n_max)))
        log_gamma = jnp.asarray(rng.normal(size=(L,)), jnp.float32)
        return jax.make_jaxpr(
            lambda lr, c, lg: buzen_classes_pallas_batched(
                lr, c, lg, m_max, interpret=True))(log_rho, counts,
                                                   log_gamma)

    def kernel_events():
        from ..core import events
        from ..kernels.events import step_event_pallas

        prm, m_vec, keys = _sim_args()
        st = jax.vmap(lambda p, m, k: events.init_state(
            p, m, k, m_max=m_max, distribution="exponential", warmup=0,
            cap=8))(prm, m_vec, keys)
        return jax.make_jaxpr(
            lambda p, s: step_event_pallas(
                p, s, distribution="exponential", power=None,
                interpret=True)[0])(prm, st)

    def suite_simulate_batched_megastep():
        from ..sim.batched_events import build_lanes_fn

        fn = build_lanes_fn("batched", 6, 2, "exponential", m_max, False,
                            chunk=2)
        prm, m_vec, keys = _sim_args()
        return jax.make_jaxpr(lambda p, m, k: fn(p, m, k, None))(
            prm, m_vec, keys)

    def suite_simulate_pallas_megastep():
        from ..sim.batched_events import build_lanes_fn

        fn = build_lanes_fn("pallas", 6, 2, "exponential", m_max, False,
                            interpret=True, chunk=2)
        prm, m_vec, keys = _sim_args()
        return jax.make_jaxpr(lambda p, m, k: fn(p, m, k, None))(
            prm, m_vec, keys)

    def kernel_events_megastep():
        from ..core import events
        from ..kernels.events import megastep_event_pallas

        prm, m_vec, keys = _sim_args()
        st = jax.vmap(lambda p, m, k: events.init_state(
            p, m, k, m_max=m_max, distribution="exponential", warmup=0,
            cap=8))(prm, m_vec, keys)
        return jax.make_jaxpr(
            lambda p, s: megastep_event_pallas(
                p, s, chunk=2, distribution="exponential", power=None,
                interpret=True)[0])(prm, st)

    return {
        "suite_analyze": (
            "ScenarioSuite analyze bucket: jit(vmap) of the padded closed "
            "forms (energy column on)", suite_analyze),
        "suite_simulate_batched": (
            "ScenarioSuite simulate bucket, batched backend: jit(vmap) of "
            "the single-lane event scan", suite_simulate_batched),
        "suite_simulate_batched_traced": (
            "ScenarioSuite simulate bucket with the event telemetry ring "
            "threaded as scan carry (repro.obs)",
            suite_simulate_batched_traced),
        "suite_simulate_pallas": (
            "ScenarioSuite simulate bucket, pallas backend (interpret): "
            "lock-step lane scan around the event kernel",
            suite_simulate_pallas),
        "suite_simulate_batched_megastep": (
            "ScenarioSuite simulate bucket, batched backend, chunk=2 "
            "megastep: block-drawn randomness + fused multi-event scan "
            "body (bitwise equal to the single-step program)",
            suite_simulate_batched_megastep),
        "suite_simulate_pallas_megastep": (
            "ScenarioSuite simulate bucket, pallas backend (interpret), "
            "chunk=2 megastep: one kernel launch retires up to 2 events "
            "against the resident finish-clock table",
            suite_simulate_pallas_megastep),
        "suite_analyze_classes": (
            "ScenarioSuite analyze bucket, class networks: jit(vmap) of "
            "the O(#classes) class closed forms", suite_analyze_classes),
        "suite_simulate_classes": (
            "ScenarioSuite simulate bucket, class networks: jit(vmap) of "
            "the class-aggregated event scan", suite_simulate_classes),
        "suite_simulate_sharded": (
            "ScenarioSuite simulate bucket, sharded backend: "
            "jit(shard_map) of the lane sweep over the device mesh",
            suite_simulate_sharded),
        "simulate_reference_lane": (
            "reference backend per-lane program: events._simulate_stats "
            "bounded scan", simulate_reference_lane),
        "trainer_scan": (
            "DeviceTrainer fused training scan (suite train bucket): "
            "jit(vmap) over lanes", trainer_scan),
        "trainer_scan_traced": (
            "DeviceTrainer fused training scan with the update telemetry "
            "ring threaded as scan carry (repro.obs)", trainer_scan_traced),
        "trainer_scan_lane_nets": (
            "DeviceTrainer lane-mode training scan (serve mixed-n train "
            "bucket): network + padded client table vmapped per lane",
            trainer_scan_lane_nets),
        "kernel_buzen": (
            "Pallas Buzen DP kernel, interpret path "
            "(kernels.buzen.buzen_pallas_batched)", kernel_buzen),
        "kernel_buzen_classes": (
            "Pallas class-space Buzen DP kernel, interpret path "
            "(kernels.buzen.buzen_classes_pallas_batched)",
            kernel_buzen_classes),
        "kernel_events": (
            "Pallas event-step kernel, interpret path "
            "(kernels.events.step_event_pallas)", kernel_events),
        "kernel_events_megastep": (
            "Pallas megastep event kernel, interpret path "
            "(kernels.events.megastep_event_pallas, chunk=2)",
            kernel_events_megastep),
    }


def build_report(names=None) -> dict:
    """The full audit report (optionally restricted to ``names``)."""
    import jax

    programs = {}
    registry = resident_programs()
    if names:
        registry = {k: registry[k] for k in names}
    for name, (description, thunk) in registry.items():
        entry = {"description": description}
        entry.update(analyze_jaxpr(thunk()))
        programs[name] = entry
    return {
        "schema": {"name": "repro.analysis.audit",
                   "version": SCHEMA_VERSION},
        "jax_version": jax.__version__,
        "default_backend": jax.default_backend(),
        "x64_enabled": bool(jax.config.jax_enable_x64),
        "programs": programs,
        "summary": {
            "programs": len(programs),
            "tpu_ready": sorted(k for k, v in programs.items()
                                if v["tpu_compilable"]),
            "tpu_blocked": sorted(k for k, v in programs.items()
                                  if not v["tpu_compilable"]),
        },
    }


def main(argv=None) -> int:
    import argparse
    import sys

    ap = argparse.ArgumentParser(
        prog="repro.analysis audit", description=__doc__.splitlines()[0])
    ap.add_argument("--out", default=None,
                    help="write the JSON report here (default: stdout)")
    ap.add_argument("--programs", default=None,
                    help="comma-separated subset of resident programs")
    args = ap.parse_args(argv)
    names = ([s.strip() for s in args.programs.split(",") if s.strip()]
             if args.programs else None)
    report = build_report(names)
    text = json.dumps(report, indent=1)
    if args.out:
        with open(args.out, "w") as fh:
            fh.write(text + "\n")
        blocked = report["summary"]["tpu_blocked"]
        print(f"audit: {report['summary']['programs']} programs -> "
              f"{args.out} ({len(blocked)} TPU-blocked: {blocked})")
    else:
        print(text)
    return 0 if report["programs"] else 1


if __name__ == "__main__":
    import sys

    sys.exit(main())
