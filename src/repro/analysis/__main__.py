"""CLI gate: ``python -m repro.analysis [lint|hygiene|audit|all]``.

With no subcommand, runs the fast static gates (contract lint + repo
hygiene) and exits non-zero on any unsuppressed violation — the CI entry
point that subsumes ``tools/check_hygiene.py``.  ``audit`` builds the
jaxpr TPU-compilability report (imports jax; the other gates do not).
"""
from __future__ import annotations

import sys


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    cmd = "check"
    if argv and not argv[0].startswith("-"):
        cmd = argv.pop(0)

    if cmd == "lint":
        from . import lint

        return lint.main(argv)
    if cmd == "hygiene":
        from . import hygiene

        return hygiene.main()
    if cmd == "audit":
        from . import audit

        return audit.main(argv)
    if cmd in ("check", "all"):
        from . import hygiene, lint

        rc = lint.main(argv if cmd == "check" else [])
        rc |= hygiene.main()
        if cmd == "all":
            from . import audit

            rc |= audit.main(argv)
        return rc
    print(f"unknown command {cmd!r}; expected lint | hygiene | audit | all",
          file=sys.stderr)
    return 2


if __name__ == "__main__":
    sys.exit(main())
