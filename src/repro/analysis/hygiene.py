"""Repo-hygiene check: fail when generated files are tracked by git.

Bytecode has been accidentally committed before (27 ``__pycache__/*.pyc``
files rode along in a PR); ``.gitignore`` prevents *new* additions, but
only a check that runs in CI/tier-1 keeps already-tracked junk from
coming back.  Lives here so ``python -m repro.analysis`` runs it next to
the contract linter; ``tools/check_hygiene.py`` remains as a thin shim.
"""
from __future__ import annotations

import os
import subprocess
import sys

# src/repro/analysis/hygiene.py -> repo root is four levels up
REPO_ROOT = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "..", ".."))

# path fragments that must never be tracked
FORBIDDEN = ("__pycache__/", ".pytest_cache/")
FORBIDDEN_SUFFIXES = (".pyc", ".pyo")


def tracked_files(repo_root: str = REPO_ROOT) -> list[str]:
    """``git ls-files`` of the repo (empty if git is unavailable)."""
    try:
        out = subprocess.run(
            ["git", "ls-files"], cwd=repo_root, check=True,
            capture_output=True, text=True)
    except (OSError, subprocess.CalledProcessError):
        return []
    return [line for line in out.stdout.splitlines() if line]


def tracked_junk(repo_root: str = REPO_ROOT) -> list[str]:
    """Tracked paths violating repo hygiene (bytecode, tool caches)."""
    bad = []
    for path in tracked_files(repo_root):
        if (path.endswith(FORBIDDEN_SUFFIXES)
                or any(frag in path for frag in FORBIDDEN)):
            bad.append(path)
    return bad


def main() -> int:
    bad = tracked_junk()
    if bad:
        print("tracked files violating repo hygiene:", file=sys.stderr)
        for path in bad:
            print(f"  {path}", file=sys.stderr)
        print(f"fix with: git rm --cached {' '.join(bad[:5])} ...",
              file=sys.stderr)
        return 1
    print(f"hygiene OK ({len(tracked_files())} tracked files clean)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
