"""Recompile sentinel — trace/compile budgets as machine-checked asserts.

The suite planner's headline property ("a mixed-population suite compiles
into 1-2 programs, not one per scenario") used to live in bench notes and
ad-hoc counting closures.  This module counts what jax actually does:

  * every jaxpr trace and XLA compile, via the ``jax.monitoring``
    duration events (``/jax/core/compile/...``) — cache hits fire nothing;
  * the *name* of each traced/compiled program, via the
    ``jax._src.dispatch`` debug log ("Finished tracing + transforming
    {name} for pjit", "Finished XLA compilation of jit({name})") — eager
    op dispatch shows up under primitive names (``multiply``, ``iota``),
    resident programs under their Python function names, so budgets can
    be scoped to the programs under test and stay immune to incidental
    eager-op compiles.

Usage::

    from repro.analysis import tracecheck

    with tracecheck.expect(max_programs=2,
                           pattern=tracecheck.PLANNER_PROGRAMS) as watch:
        suite.run(mode="simulate", num_updates=2000)
    # raises TraceBudgetExceeded on the way out if >2 matching compiles

    with tracecheck.forbid("spec round-trip must not touch jax"):
        Scenario.from_json(scn.to_json())

    counted = tracecheck.counting(objective)   # Python-trace counter
    sweep(counted, ...); assert counted.traces == 1

The pytest fixture (``tests/conftest.py``) injects this module per-test.
"""
from __future__ import annotations

import contextlib
import dataclasses
import logging
import re
import time
from typing import Optional

_TRACE_EVENT = "/jax/core/compile/jaxpr_trace_duration"
_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"
_CACHE_HIT_EVENT = "/jax/compilation_cache/cache_hits"
_COMPILE_MSG = re.compile(r"Finished XLA compilation of jit\((.+?)\) in ")
_TRACE_MSG = re.compile(r"Finished tracing \+ transforming (.+?) for pjit")
# the same messages end "... in {seconds} sec": captured for compile spans
_SPAN_SECS = re.compile(r" in ([0-9.eE+-]+) sec")

#: the inner functions of every resident suite program: the analyze bucket
#: (``analyze_lanes``/``one``), the simulate bucket (``lanes``/``one`` for
#: batched, ``fn`` for pallas, ``_simulate_stats`` per reference lane) and
#: the trainer scan (``single``).  Budgets scoped to this pattern count
#: planner programs only, never incidental eager-op compiles.
PLANNER_PROGRAMS = (
    r"^(lanes|analyze_lanes|one|fn|single|single_lanes|_simulate_stats)$")


class TraceBudgetExceeded(AssertionError):
    """A watched block traced/compiled more programs than its budget."""


@dataclasses.dataclass
class Watch:
    """Counters for one watched block (still live inside the block)."""

    traces: int = 0                # jaxpr traces (monitoring events)
    compiles: int = 0              # XLA compiles (monitoring events)
    cache_hits: int = 0            # persistent-compilation-cache hits
    compiled: list = dataclasses.field(default_factory=list)  # names
    traced: list = dataclasses.field(default_factory=list)    # names
    #: per-compile ``(program, end_perf_counter, seconds)`` triples — the
    #: compile track of the repro.obs Perfetto export
    #: (``repro.obs.trace.perfetto_trace(compile_spans=...)``)
    spans: list = dataclasses.field(default_factory=list)

    @property
    def fresh_compiles(self) -> int:
        """Compiles that actually ran XLA: the ``backend_compile``
        duration event still fires when the executable came out of the
        persistent compilation cache (jax deserializes under the same
        timer), so warm-restart checks must subtract the hits."""
        return self.compiles - self.cache_hits

    def programs(self, pattern: Optional[str] = None) -> list:
        """Compiled program names, optionally filtered by regex."""
        if pattern is None:
            return list(self.compiled)
        rx = re.compile(pattern)
        return [n for n in self.compiled if rx.search(n)]

    def retraces(self, pattern: Optional[str] = None) -> int:
        """Traces beyond the first per program name (shape-driven
        retraces of one jit object count here)."""
        names = self.traced if pattern is None else [
            n for n in self.traced if re.search(pattern, n)]
        return len(names) - len(set(names))


_active: list[Watch] = []
_installed = False


def _on_event(event: str, duration, **_kw) -> None:
    if not _active:
        return
    if event == _TRACE_EVENT:
        for w in _active:
            w.traces += 1
    elif event == _COMPILE_EVENT:
        for w in _active:
            w.compiles += 1


def _on_cache_event(event: str, **_kw) -> None:
    """Non-duration monitoring events: persistent-cache hits."""
    if _active and event == _CACHE_HIT_EVENT:
        for w in _active:
            w.cache_hits += 1


class _QuietDispatchDebug(logging.Filter):
    """Keep pre-existing stderr handlers at their old threshold.

    Lowering ``jax._src.dispatch`` to DEBUG (so our handler sees the
    per-program compile messages) would also spill those records onto
    jax's own stderr ``StreamHandler`` attached to the parent ``jax``
    logger.  This filter, added to the *pre-existing* handlers only,
    drops the sub-WARNING records we unlocked — console behaviour stays
    exactly as before installation."""

    def filter(self, record: logging.LogRecord) -> bool:
        return not (record.name == "jax._src.dispatch"
                    and record.levelno < logging.WARNING)


class _DispatchLogHandler(logging.Handler):
    def emit(self, record: logging.LogRecord) -> None:
        if not _active:
            return
        try:
            msg = record.getMessage()
        except Exception:  # noqa: BLE001 — never let logging break a run
            return
        m = _COMPILE_MSG.search(msg)
        if m:
            secs = _SPAN_SECS.search(msg)
            span = (m.group(1), time.perf_counter(),
                    float(secs.group(1)) if secs else 0.0)
            for w in _active:
                w.compiled.append(m.group(1))
                w.spans.append(span)
            return
        m = _TRACE_MSG.search(msg)
        if m:
            for w in _active:
                w.traced.append(m.group(1))


def _install() -> None:
    """One process-wide listener + log handler dispatching to the active
    watch stack (jax.monitoring has no unregister — never pile up)."""
    global _installed
    if _installed:
        return
    import jax
    from jax import monitoring

    monitoring.register_event_duration_secs_listener(_on_event)
    monitoring.register_event_listener(_on_cache_event)
    # the per-program names are logged at DEBUG unless jax_log_compiles;
    # capture them without enabling the (stderr-noisy) flag
    logger = logging.getLogger("jax._src.dispatch")
    if logger.getEffectiveLevel() > logging.DEBUG:
        quiet = _QuietDispatchDebug()
        node: Optional[logging.Logger] = logger
        while node is not None:
            for h in node.handlers:
                h.addFilter(quiet)
            node = node.parent if node.propagate else None
        logger.setLevel(logging.DEBUG)
    logger.addHandler(_DispatchLogHandler())
    del jax
    _installed = True


@contextlib.contextmanager
def watch():
    """Count traces/compiles (and program names) inside the block."""
    _install()
    w = Watch()
    _active.append(w)
    try:
        yield w
    finally:
        _active.remove(w)


@contextlib.contextmanager
def expect(max_programs: Optional[int] = None,
           pattern: Optional[str] = None,
           max_compiles: Optional[int] = None,
           max_traces: Optional[int] = None,
           what: str = ""):
    """Budget-checked :func:`watch`: raises :class:`TraceBudgetExceeded`
    on exit when the block exceeded any given budget.

    ``max_programs`` bounds *named* XLA compiles matching ``pattern``
    (default: every name) — the right check for planner budgets, immune
    to eager-op compiles.  ``max_compiles``/``max_traces`` bound the raw
    monitoring counters (eager ops included) — the right check for
    "this block must not touch the compiler at all".
    """
    with watch() as w:
        yield w
    label = f" ({what})" if what else ""
    if max_programs is not None:
        progs = w.programs(pattern)
        if len(progs) > max_programs:
            raise TraceBudgetExceeded(
                f"compiled {len(progs)} programs{label}, budget "
                f"{max_programs}: {progs}")
    if max_compiles is not None and w.compiles > max_compiles:
        raise TraceBudgetExceeded(
            f"{w.compiles} XLA compiles{label}, budget {max_compiles}: "
            f"{w.compiled}")
    if max_traces is not None and w.traces > max_traces:
        raise TraceBudgetExceeded(
            f"{w.traces} jaxpr traces{label}, budget {max_traces}: "
            f"{w.traced}")


def forbid(what: str = "block must not trace or compile"):
    """The block must not trace or compile anything — cached dispatch
    only (zero-budget :func:`expect`)."""
    return expect(max_traces=0, max_compiles=0, what=what)


def fresh() -> None:
    """Clear jax's compilation caches for deterministic compile counts."""
    import jax

    jax.clear_caches()


class counting:  # noqa: N801 — reads as a verb at call sites
    """Wrap a function so each *Python execution* is counted.

    Under jit, the wrapped body runs only while tracing — ``.traces`` is
    exactly the number of times jax traced through ``fn``.  Replaces the
    ad-hoc ``traces.append(1)`` closures the trace-count tests grew.
    """

    def __init__(self, fn):
        self.fn = fn
        self.traces = 0

    def __call__(self, *args, **kwargs):
        self.traces += 1
        return self.fn(*args, **kwargs)
