"""Contract linter — the bitwise padding contract as named AST rules.

The mixed-population performance story (padded run == unpadded run
**bitwise**) holds only while a handful of coding rules hold; this module
turns them from ROADMAP prose into checked-in static analysis:

``raw-reduction``
    ``jnp.sum``/``jnp.cumsum`` (or ``np.``, or the ``.sum()``/``.cumsum()``
    methods), and any ``logsumexp``, in a contract-marked module.
    Client-axis AND class-axis reductions must use
    ``numerics.seqsum``/``seqcumsum`` — XLA reduces reassociate with array
    *length*, so a raw sum over a zero-padded axis is not bitwise stable;
    the class closed forms reduce in log-space, so ``logsumexp`` over the
    padded class axis is the same bug wearing a log coat (reductions over
    the static ``m``-convolution axis are fine and say so in an
    ``allow()``).
``categorical-routing``
    ``jax.random.categorical`` anywhere under ``src/``.  The Gumbel trick
    draws noise with the logits' shape, so routing through it depends on
    the padded length; routing must stay inverse-CDF on ONE scalar uniform
    (``repro.core.events._route_client``).
``stringly-dispatch``
    ``if``/``elif`` chains or callable dict-dispatch keyed by two or more
    registered law/strategy names.  Law and strategy lookups go through
    the ``repro.scenario.registry`` decorators so extensions and error
    messages stay in one place.
``numpy-in-jit``
    host ``numpy`` calls inside a traced function — silent host sync at
    best, a tracer leak at worst.
``traced-branch``
    Python ``if``/``while`` on a ``jnp`` expression inside a traced
    function (must be ``lax.cond``/``jnp.where``/``lax.while_loop``).
``env-read``
    ``os.environ``/``os.getenv`` inside a traced function (the value is
    frozen at trace time, invisibly keyed into no cache) or at module
    scope (frozen at *import* time — a server process imports once and
    then ignores the environment forever; read config where it is
    consumed, or suppress with the why).  Writes are fine.
``bad-suppression``
    a ``# contract: allow(...)`` comment without a justification, or
    naming an unknown rule.

A module opts into the marked-module rules with a ``# contract: padded-n``
comment line.  A violation is suppressed by ``# contract:
allow(<rule>): <justification>`` on the violating line or the line above;
the justification is mandatory.

Pure stdlib (``ast``) — runs without jax installed.  The registered
law/strategy names are HARDCODED here so linting stays import-light;
``tests/test_analysis.py`` cross-checks them against the live registries.
"""
from __future__ import annotations

import ast
import dataclasses
import os
import re
import sys
from typing import Iterable, Optional

# Cross-checked against repro.scenario.registry in tests/test_analysis.py.
LAW_NAMES = frozenset(
    {"exponential", "deterministic", "lognormal", "hyperexponential"})
STRATEGY_NAMES = frozenset(
    {"asyncsgd", "max_throughput", "round_opt", "time_opt", "energy_opt",
     "joint"})
DISPATCH_NAMES = LAW_NAMES | STRATEGY_NAMES

RULES = {
    "raw-reduction":
        "raw sum/cumsum/logsumexp in a contract-marked module; client- "
        "and class-axis reductions must use numerics.seqsum/seqcumsum",
    "categorical-routing":
        "jax.random.categorical draws Gumbel noise with the logits' "
        "shape; routing must be inverse-CDF on one scalar uniform",
    "stringly-dispatch":
        "law/strategy dispatch on string literals; route through the "
        "repro.scenario.registry decorators",
    "numpy-in-jit":
        "host numpy call inside a traced function",
    "traced-branch":
        "Python if/while on a traced (jnp) value inside a traced "
        "function; use lax.cond/jnp.where",
    "env-read":
        "os.environ read inside a traced function (frozen at trace "
        "time) or at module scope (frozen at import time); resolve "
        "flags where they are consumed",
    "bad-suppression":
        "contract: allow(...) without a justification or naming an "
        "unknown rule",
}

_MARK_RE = re.compile(r"#\s*contract:\s*padded-n\b")
_ALLOW_RE = re.compile(
    r"#\s*contract:\s*allow\(([A-Za-z0-9_-]+)\)\s*(?::\s*(\S.*?))?\s*$")

# names whose positional function arguments get traced
_TRANSFORMS = frozenset(
    {"jit", "pjit", "vmap", "pmap", "grad", "value_and_grad", "jacfwd",
     "jacrev", "hessian", "scan", "while_loop", "fori_loop", "cond",
     "checkpoint", "remat", "custom_jvp", "custom_vjp", "make_jaxpr"})
_JNP_BASES = ("jnp", "jax.numpy")
_NP_BASES = ("np", "numpy")
# numpy attributes that are metadata, not array computation
_NP_META = frozenset(
    {"dtype", "iinfo", "finfo", "ndarray", "newaxis", "float32", "float64",
     "int32", "int64", "uint32", "bool_", "pi", "inf", "nan"})


@dataclasses.dataclass(frozen=True)
class Violation:
    path: str
    line: int
    rule: str
    message: str
    suppressed: bool = False
    justification: Optional[str] = None

    def format(self) -> str:
        tag = " [suppressed]" if self.suppressed else ""
        return f"{self.path}:{self.line}: {self.rule}: {self.message}{tag}"


def _dotted(node) -> str:
    """``a.b.c`` for an Attribute/Name chain, else ``""``."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _suppressions(text: str):
    """line -> (rule, justification|None) for every allow() comment."""
    out = {}
    for i, line in enumerate(text.splitlines(), start=1):
        m = _ALLOW_RE.search(line)
        if m:
            out[i] = (m.group(1), m.group(2))
    return out


def _traced_nodes(tree: ast.AST):
    """AST nodes (FunctionDef/Lambda) whose bodies run under a trace.

    Over-approximate on purpose: a function is traced if it is decorated
    with (or wrapped by ``functools.partial`` around) a jit, or passed by
    name/lambda to any jax transform or ``lax`` control-flow combinator.
    """
    traced_names: set[str] = set()
    lambda_nodes: list[ast.Lambda] = []

    def transform_call(call: ast.Call) -> bool:
        name = _dotted(call.func)
        return bool(name) and name.split(".")[-1] in _TRANSFORMS

    for node in ast.walk(tree):
        if not isinstance(node, ast.Call) or not transform_call(node):
            continue
        for arg in node.args:
            cand = arg
            # functools.partial(fn, ...) / jax.vmap(fn) as the payload
            if (isinstance(cand, ast.Call)
                    and _dotted(cand.func).split(".")[-1]
                    in _TRANSFORMS | {"partial"} and cand.args):
                cand = cand.args[0]
            if isinstance(cand, ast.Name):
                traced_names.add(cand.id)
            elif isinstance(cand, ast.Lambda):
                lambda_nodes.append(cand)

    nodes: list[ast.AST] = list(lambda_nodes)
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if node.name in traced_names:
            nodes.append(node)
            continue
        for deco in node.decorator_list:
            base = deco.func if isinstance(deco, ast.Call) else deco
            name = _dotted(base)
            leaf = name.split(".")[-1] if name else ""
            if leaf in ("jit", "pjit"):
                nodes.append(node)
                break
            if leaf == "partial" and isinstance(deco, ast.Call) and deco.args:
                inner = _dotted(deco.args[0]).split(".")[-1]
                if inner in ("jit", "pjit"):
                    nodes.append(node)
                    break
    return nodes


def _is_reduction_call(node: ast.Call) -> Optional[str]:
    """Describe a raw sum/cumsum/logsumexp call, else None."""
    if isinstance(node.func, ast.Name):
        # `from jax.scipy.special import logsumexp` is the house idiom
        return ("logsumexp(...)" if node.func.id == "logsumexp" else None)
    if not isinstance(node.func, ast.Attribute):
        return None
    attr = node.func.attr
    if attr == "logsumexp":
        return f"{_dotted(node.func.value)}.{attr}(...)"
    if attr not in ("sum", "cumsum"):
        return None
    base = _dotted(node.func.value)
    if base in _JNP_BASES or base in _NP_BASES:
        return f"{base}.{attr}(...)"
    # any .sum()/.cumsum() method: static analysis cannot prove the
    # receiver is not a padded-axis device array, so flag conservatively
    return f".{attr}() method call"


def _jnp_valued(node: ast.AST) -> bool:
    """Does the expression subtree call into jnp/jax.numpy?"""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            base = _dotted(sub.func)
            if any(base == b or base.startswith(b + ".")
                   for b in _JNP_BASES):
                return True
    return False


def _if_chain_literals(node: ast.If, seen_ids: set):
    """String literals compared in an if/elif chain (Eq / In tests)."""
    literals: list[tuple[str, int]] = []
    cur: ast.stmt = node
    while isinstance(cur, ast.If):
        seen_ids.add(id(cur))
        for sub in ast.walk(cur.test):
            if not isinstance(sub, ast.Compare):
                continue
            for op, comp in zip(sub.ops, sub.comparators):
                if isinstance(op, (ast.Eq, ast.NotEq)) and \
                        isinstance(comp, ast.Constant) and \
                        isinstance(comp.value, str):
                    literals.append((comp.value, cur.lineno))
                elif isinstance(op, (ast.In, ast.NotIn)) and \
                        isinstance(comp, (ast.Tuple, ast.List, ast.Set)):
                    for elt in comp.elts:
                        if isinstance(elt, ast.Constant) and \
                                isinstance(elt.value, str):
                            literals.append((elt.value, cur.lineno))
        cur = cur.orelse[0] if (len(cur.orelse) == 1
                                and isinstance(cur.orelse[0], ast.If)) \
            else None
    return literals


def _module_scope_nodes(tree: ast.Module):
    """AST nodes executed at import time: everything outside function
    and lambda bodies (class bodies DO run at import)."""
    stack = list(tree.body)
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            continue
        yield node
        stack.extend(ast.iter_child_nodes(node))


def _env_read(node) -> Optional[str]:
    """The offending spelling if ``node`` reads the environment."""
    if isinstance(node, ast.Call):
        base = _dotted(node.func)
        if base in ("os.getenv", "os.environ.get"):
            return f"{base}(...)"
    elif isinstance(node, ast.Subscript):
        if _dotted(node.value) == "os.environ" and \
                isinstance(node.ctx, ast.Load):
            return "os.environ[...]"
    return None


def lint_source(text: str, path: str = "<string>",
                marked: Optional[bool] = None) -> list[Violation]:
    """All violations (suppressed and not) in one module's source."""
    try:
        tree = ast.parse(text)
    except SyntaxError as e:
        return [Violation(path, e.lineno or 0, "bad-suppression",
                          f"unparseable module: {e.msg}")]
    if marked is None:
        marked = bool(_MARK_RE.search(text))
    allows = _suppressions(text)
    raw: list[Violation] = []

    def add(line: int, rule: str, message: str):
        raw.append(Violation(path, line, rule, message))

    # -- module-wide rules --------------------------------------------------
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            callee = _dotted(node.func)
            leaf = callee.split(".")[-1] if callee else ""
            if leaf == "categorical" and (
                    ".random." in f".{callee}." or callee == "categorical"):
                add(node.lineno, "categorical-routing",
                    f"{callee or 'categorical'}(...) — "
                    + RULES["categorical-routing"])
            if marked:
                desc = _is_reduction_call(node)
                if desc is not None:
                    add(node.lineno, "raw-reduction",
                        f"{desc} — " + RULES["raw-reduction"])
        elif isinstance(node, ast.Dict):
            keys = [k.value for k in node.keys
                    if isinstance(k, ast.Constant)
                    and isinstance(k.value, str)]
            hits = sorted(set(keys) & DISPATCH_NAMES)
            callable_vals = sum(
                isinstance(v, (ast.Lambda, ast.Name, ast.Attribute))
                for v in node.values)
            if len(hits) >= 2 and callable_vals >= 2:
                add(node.lineno, "stringly-dispatch",
                    f"dict dispatch over registered names {hits} — "
                    + RULES["stringly-dispatch"])

    for node in _module_scope_nodes(tree):
        spelled = _env_read(node)
        if spelled is not None:
            add(node.lineno, "env-read",
                f"{spelled} at module scope — " + RULES["env-read"])

    seen_ifs: set = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.If) and id(node) not in seen_ifs:
            literals = _if_chain_literals(node, seen_ifs)
            hits = sorted({v for v, _ in literals} & DISPATCH_NAMES)
            if len(hits) >= 2:
                add(node.lineno, "stringly-dispatch",
                    f"if/elif chain over registered names {hits} — "
                    + RULES["stringly-dispatch"])

    # -- traced-function rules ----------------------------------------------
    for fn_node in _traced_nodes(tree):
        for node in ast.walk(fn_node):
            if isinstance(node, ast.Call):
                base = _dotted(node.func)
                root = base.split(".")[0] if base else ""
                if root in _NP_BASES:
                    attr = base.split(".")[-1]
                    if attr not in _NP_META:
                        add(node.lineno, "numpy-in-jit",
                            f"{base}(...) — " + RULES["numpy-in-jit"])
                elif base in ("os.getenv", "os.environ.get"):
                    add(node.lineno, "env-read",
                        f"{base}(...) — " + RULES["env-read"])
            elif isinstance(node, ast.Subscript):
                if _env_read(node) is not None:
                    add(node.lineno, "env-read",
                        "os.environ[...] — " + RULES["env-read"])
            elif isinstance(node, (ast.If, ast.While)):
                if _jnp_valued(node.test):
                    kind = "while" if isinstance(node, ast.While) else "if"
                    add(node.lineno, "traced-branch",
                        f"Python `{kind}` on a jnp expression — "
                        + RULES["traced-branch"])

    # -- apply suppressions --------------------------------------------------
    out: list[Violation] = []
    for v in sorted(raw, key=lambda v: (v.line, v.rule)):
        sup = None
        for line in (v.line, v.line - 1):
            hit = allows.get(line)
            if hit is not None and hit[0] == v.rule:
                sup = hit
                break
        if sup is not None and sup[1]:
            out.append(dataclasses.replace(v, suppressed=True,
                                           justification=sup[1]))
        else:
            out.append(v)
    for line, (rule, just) in sorted(allows.items()):
        if rule not in RULES or rule == "bad-suppression":
            out.append(Violation(path, line, "bad-suppression",
                                 f"allow({rule}) names an unknown rule"))
        elif not just:
            out.append(Violation(
                path, line, "bad-suppression",
                f"allow({rule}) needs a justification: "
                f"`# contract: allow({rule}): <why this is exact>`"))
    return sorted(out, key=lambda v: (v.path, v.line, v.rule))


def lint_file(path: str) -> list[Violation]:
    with open(path, encoding="utf-8") as fh:
        return lint_source(fh.read(), path=path)


def default_root() -> str:
    """``src/repro`` relative to this file — the default lint target."""
    return os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def lint_tree(root: Optional[str] = None,
              skip: Iterable[str] = ()) -> list[Violation]:
    """Lint every ``*.py`` under ``root`` (default: ``src/repro``)."""
    root = root or default_root()
    skip = set(skip)
    out: list[Violation] = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = sorted(d for d in dirnames if d != "__pycache__")
        for name in sorted(filenames):
            if not name.endswith(".py") or name in skip:
                continue
            out.extend(lint_file(os.path.join(dirpath, name)))
    return out


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="repro.analysis lint", description=__doc__.splitlines()[0])
    ap.add_argument("--root", default=None,
                    help="directory to lint (default: src/repro)")
    ap.add_argument("--show-suppressed", action="store_true",
                    help="also list suppressed violations")
    args = ap.parse_args(argv)
    violations = lint_tree(args.root)
    active = [v for v in violations if not v.suppressed]
    suppressed = [v for v in violations if v.suppressed]
    for v in active:
        print(v.format(), file=sys.stderr)
    if args.show_suppressed:
        for v in suppressed:
            print(v.format())
    print(f"contract lint: {len(active)} violation(s), "
          f"{len(suppressed)} suppressed")
    return 1 if active else 0


if __name__ == "__main__":
    raise SystemExit(main())
