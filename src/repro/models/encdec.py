"""Encoder-decoder transformer (Whisper-style audio backbone).

Per the assignment brief the mel-spectrogram + conv feature extractor is a
STUB: ``input_specs`` provides precomputed frame embeddings [B, F, d].  The
real implementation here is the transformer: a bidirectional encoder over
frames and a causal decoder with cross-attention, both scanned over stacked
layers.  Decode mode carries a self-attention KV cache plus precomputed
cross-attention K/V (computed once from the encoder output).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from .attention import attention, decode_attention_ref
from .config import ArchConfig
from .layers import (AttnCache, dense_ffn, dtype_of, init_attention,
                     init_dense_ffn, init_rmsnorm, pdtype_of, rmsnorm)
from .parallel import ParallelContext


def _scan_layers(cfg, body_fn, x, stacked, n_layers):
    """scan over stacked layers, or unrolled when cfg.scan_layers=False
    (exact per-layer HLO accounting for the dry-run)."""
    if cfg.scan_layers:
        x, _ = jax.lax.scan(body_fn, x, stacked)
        return x
    for i in range(n_layers):
        lp = jax.tree_util.tree_map(lambda a: a[i], stacked)
        x, _ = body_fn(x, lp)
    return x


def _sinusoidal(positions: jax.Array, d: int) -> jax.Array:
    half = d // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half) / half)
    ang = positions[:, None].astype(jnp.float32) * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _init_xattn(key, cfg: ArchConfig):
    # cross-attention reuses attention projection shapes (MHA: kv == heads)
    return init_attention(key, cfg)


def init_encdec(key, cfg: ArchConfig):
    ks = jax.random.split(key, 6)
    pd = pdtype_of(cfg)

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {"ln1": init_rmsnorm(cfg.d_model, cfg),
                "attn": init_attention(k1, cfg),
                "ln2": init_rmsnorm(cfg.d_model, cfg),
                "ffn": init_dense_ffn(k2, cfg)}

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"ln1": init_rmsnorm(cfg.d_model, cfg),
                "self_attn": init_attention(k1, cfg),
                "ln_x": init_rmsnorm(cfg.d_model, cfg),
                "cross_attn": _init_xattn(k2, cfg),
                "ln2": init_rmsnorm(cfg.d_model, cfg),
                "ffn": init_dense_ffn(k3, cfg)}

    enc_keys = jax.random.split(ks[0], cfg.encoder_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)
    return {
        "embed": (jax.random.normal(ks[2], (cfg.vocab, cfg.d_model),
                                    jnp.float32) * 0.02).astype(pd),
        "enc_layers": jax.vmap(enc_layer)(enc_keys),
        "enc_norm": init_rmsnorm(cfg.d_model, cfg),
        "dec_layers": jax.vmap(dec_layer)(dec_keys),
        "final_norm": init_rmsnorm(cfg.d_model, cfg),
        "lm_head": (jax.random.normal(ks[3], (cfg.d_model, cfg.vocab),
                                      jnp.float32)
                    * cfg.d_model ** -0.5).astype(pd),
    }


def _mha(params, cfg, q_in, kv_in, *, causal, ctx, impl="ref"):
    B, Sq, _ = q_in.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (q_in @ params["wq"]).reshape(B, Sq, H, hd)
    k = (kv_in @ params["wk"]).reshape(B, kv_in.shape[1], KV, hd)
    v = (kv_in @ params["wv"]).reshape(B, kv_in.shape[1], KV, hd)
    q = ctx.shard(q, ("pod", "data"), None, "model", None)
    out = attention(q, k, v, causal=causal, impl=impl)
    return out.reshape(B, Sq, H * hd) @ params["wo"]


def encode(params, cfg: ArchConfig, frames, ctx: ParallelContext, *,
           impl="ref"):
    """frames: [B, F, d] stubbed embeddings -> [B, F, d] encodings."""
    B, F, _ = frames.shape
    x = frames.astype(dtype_of(cfg)) + _sinusoidal(
        jnp.arange(F), cfg.d_model)[None].astype(dtype_of(cfg))
    x = ctx.shard(x, ("pod", "data"), None, None)

    def body(x, lp):
        h = _mha(lp["attn"], cfg, rmsnorm(lp["ln1"], x), rmsnorm(lp["ln1"], x),
                 causal=False, ctx=ctx, impl=impl)
        x = x + h
        x = x + dense_ffn(lp["ffn"], rmsnorm(lp["ln2"], x), ctx)
        return ctx.shard(x, ("pod", "data"), None, None), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x = _scan_layers(cfg, body_fn, x, params["enc_layers"],
                     cfg.encoder_layers)
    return rmsnorm(params["enc_norm"], x)


def decode_train(params, cfg: ArchConfig, tokens, enc_out,
                 ctx: ParallelContext, *, impl="ref"):
    """Teacher-forced decoder pass. Returns logits [B, S, V]."""
    B, S = tokens.shape
    x = params["embed"][tokens].astype(dtype_of(cfg))
    x = x + _sinusoidal(jnp.arange(S), cfg.d_model)[None].astype(x.dtype)
    x = ctx.shard(x, ("pod", "data"), None, None)

    def body(x, lp):
        x = x + _mha(lp["self_attn"], cfg, rmsnorm(lp["ln1"], x),
                     rmsnorm(lp["ln1"], x), causal=True, ctx=ctx, impl=impl)
        x = x + _mha(lp["cross_attn"], cfg, rmsnorm(lp["ln_x"], x), enc_out,
                     causal=False, ctx=ctx, impl=impl)
        x = x + dense_ffn(lp["ffn"], rmsnorm(lp["ln2"], x), ctx)
        return ctx.shard(x, ("pod", "data"), None, None), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x = _scan_layers(cfg, body_fn, x, params["dec_layers"], cfg.n_layers)
    x = rmsnorm(params["final_norm"], x)
    logits = (x @ params["lm_head"]).astype(jnp.float32)
    return ctx.shard(logits, ("pod", "data"), None, "model")


class EncDecCache(NamedTuple):
    self_kv: AttnCache   # stacked [L, B, S_cache, KV, hd]
    cross_k: jax.Array   # [L, B, F, KV, hd]
    cross_v: jax.Array


def build_decode_cache(params, cfg: ArchConfig, enc_out, cache_len: int,
                       ctx: ParallelContext) -> EncDecCache:
    """Precompute cross K/V from encoder output; empty self-attention cache."""
    B, F, _ = enc_out.shape
    KV, hd = cfg.n_kv_heads, cfg.hd

    def per_layer(lp):
        k = (enc_out @ lp["cross_attn"]["wk"]).reshape(B, F, KV, hd)
        v = (enc_out @ lp["cross_attn"]["wv"]).reshape(B, F, KV, hd)
        return k, v

    cross_k, cross_v = jax.vmap(per_layer)(params["dec_layers"])
    cross_k = ctx.shard(cross_k, None, ("pod", "data"), None, "model", None)
    cross_v = ctx.shard(cross_v, None, ("pod", "data"), None, "model", None)
    L = cfg.n_layers
    zeros = jnp.zeros((L, B, cache_len, KV, hd), dtype_of(cfg))
    zeros = ctx.shard(zeros, None, ("pod", "data"), None, "model", None)
    self_kv = AttnCache(k=zeros, v=zeros)
    return EncDecCache(self_kv=self_kv, cross_k=cross_k, cross_v=cross_v)


def decode_step(params, cfg: ArchConfig, cache: EncDecCache, tokens, pos,
                ctx: ParallelContext):
    """One-token decode. tokens [B, 1] -> (logits [B, 1, V], new cache)."""
    B = tokens.shape[0]
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    x = params["embed"][tokens].astype(dtype_of(cfg))
    x = x + _sinusoidal(jnp.asarray(pos)[None], cfg.d_model)[None].astype(x.dtype)

    def body(x, xs):
        lp, kv, ck, cv = xs
        h = rmsnorm(lp["ln1"], x)
        q = (h @ lp["self_attn"]["wq"]).reshape(B, 1, H, hd)
        k1 = (h @ lp["self_attn"]["wk"]).reshape(B, 1, KV, hd)
        v1 = (h @ lp["self_attn"]["wv"]).reshape(B, 1, KV, hd)
        kc = jax.lax.dynamic_update_slice_in_dim(kv.k, k1, pos, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(kv.v, v1, pos, axis=1)
        o = decode_attention_ref(q, kc, vc, jnp.minimum(pos + 1, kc.shape[1]))
        x = x + o.reshape(B, 1, H * hd) @ lp["self_attn"]["wo"]
        hx = rmsnorm(lp["ln_x"], x)
        qx = (hx @ lp["cross_attn"]["wq"]).reshape(B, 1, H, hd)
        ox = decode_attention_ref(qx, ck, cv, ck.shape[1])
        x = x + ox.reshape(B, 1, H * hd) @ lp["cross_attn"]["wo"]
        x = x + dense_ffn(lp["ffn"], rmsnorm(lp["ln2"], x), ctx)
        return x, AttnCache(k=kc, v=vc)

    if cfg.scan_layers:
        x, new_kv = jax.lax.scan(
            body, x, (params["dec_layers"], cache.self_kv, cache.cross_k,
                      cache.cross_v))
    else:
        outs = []
        for i in range(cfg.n_layers):
            sl = jax.tree_util.tree_map(lambda a: a[i],
                                        (params["dec_layers"], cache.self_kv,
                                         cache.cross_k, cache.cross_v))
            x, nc = body(x, sl)
            outs.append(nc)
        new_kv = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *outs)
    x = rmsnorm(params["final_norm"], x)
    logits = (x @ params["lm_head"]).astype(jnp.float32)
    return logits, EncDecCache(self_kv=new_kv, cross_k=cache.cross_k,
                               cross_v=cache.cross_v)
