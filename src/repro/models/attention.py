"""Attention implementations (XLA reference paths).

``flash_attention_ref`` is a chunked online-softmax attention in pure jnp
(``lax.scan`` over KV blocks): O(S * block) memory, so 32k-token prefill
lowers without materializing S x S score matrices.  It is also the oracle
for the Pallas kernel in ``repro.kernels.flash_attention``.

Supports GQA (q heads grouped over kv heads), causal masking, and sliding
windows (the dense archs' ``long_500k`` variant).  ``decode_attention_ref``
is the single-token cache-attention used by ``serve_step``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG = -1e30


def _mask_bias(q_pos, k_pos, causal: bool, window: Optional[int]):
    """[q, k] additive bias implementing causal / sliding-window masks."""
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), dtype=bool)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        ok &= k_pos[None, :] > q_pos[:, None] - window
    return jnp.where(ok, 0.0, NEG)


def flash_attention_ref(
    q: jax.Array,  # [B, S_q, H, D]
    k: jax.Array,  # [B, S_k, KV, D]
    v: jax.Array,  # [B, S_k, KV, D]
    *,
    causal: bool = True,
    window: Optional[int] = None,
    block_k: int = 1024,
    q_offset: int = 0,
) -> jax.Array:
    """Chunked online-softmax attention; returns [B, S_q, H, D]."""
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = D ** -0.5
    orig_dtype = q.dtype
    qf = (q * scale).astype(jnp.float32).reshape(B, Sq, KV, G, D)

    n_blocks = -(-Sk // block_k)
    pad = n_blocks * block_k - Sk
    kf = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(jnp.float32)
    vf = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(jnp.float32)
    kf = kf.reshape(B, n_blocks, block_k, KV, D)
    vf = vf.reshape(B, n_blocks, block_k, KV, D)

    q_pos = q_offset + jnp.arange(Sq)

    def body(carry, blk):
        m, l, acc = carry
        kb, vb, start = blk
        k_pos = start + jnp.arange(block_k)
        s = jnp.einsum("bqngd,bknd->bqngk", qf, kb)  # [B,Sq,KV,G,block]
        bias = _mask_bias(q_pos, k_pos, causal, window)
        bias = jnp.where(k_pos[None, :] < Sk, bias, NEG)  # padding mask
        s = s + bias[None, :, None, None, :]
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum("bqngk,bknd->bqngd", p, vb)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Sq, KV, G), NEG, jnp.float32)
    l0 = jnp.zeros((B, Sq, KV, G), jnp.float32)
    acc0 = jnp.zeros((B, Sq, KV, G, D), jnp.float32)
    starts = jnp.arange(n_blocks) * block_k
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0),
        (jnp.moveaxis(kf, 1, 0), jnp.moveaxis(vf, 1, 0), starts))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(B, Sq, H, D).astype(orig_dtype)


def plain_attention_ref(q, k, v, *, causal=True, window=None, q_offset=0):
    """Naive O(S^2)-memory attention — oracle for tests on small shapes."""
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    qf = q.astype(jnp.float32).reshape(B, Sq, KV, G, D) * D ** -0.5
    s = jnp.einsum("bqngd,bknd->bqngk", qf, k.astype(jnp.float32))
    bias = _mask_bias(q_offset + jnp.arange(Sq), jnp.arange(k.shape[1]),
                      causal, window)
    s = s + bias[None, :, None, None, :]
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqngk,bknd->bqngd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, D).astype(q.dtype)


def decode_attention_ref(
    q: jax.Array,        # [B, 1, H, D]
    k_cache: jax.Array,  # [B, S, KV, D]
    v_cache: jax.Array,  # [B, S, KV, D]
    length: jax.Array,   # scalar or [B] — number of valid cache entries
) -> jax.Array:
    """Single-token attention over a (possibly ring-buffered) KV cache."""
    B, _, H, D = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    qf = q.astype(jnp.float32).reshape(B, KV, G, D) * D ** -0.5
    s = jnp.einsum("bngd,bknd->bngk", qf, k_cache.astype(jnp.float32))
    valid = jnp.arange(S)[None, :] < jnp.broadcast_to(
        jnp.asarray(length).reshape(-1, 1), (B, S))
    s = jnp.where(valid[:, None, None, :], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bngk,bknd->bngd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, D).astype(q.dtype)


def attention(q, k, v, *, causal=True, window=None, impl="ref", q_offset=0,
              block_k=1024):
    """Dispatch between XLA reference and the Pallas TPU kernel."""
    if impl == "pallas":
        from ..kernels import ops as kops
        return kops.flash_attention(q, k, v, causal=causal, window=window)
    if impl == "plain":
        return plain_attention_ref(q, k, v, causal=causal, window=window,
                                   q_offset=q_offset)
    return flash_attention_ref(q, k, v, causal=causal, window=window,
                               q_offset=q_offset, block_k=block_k)
