"""Architecture configuration schema for the assigned model zoo.

Every assigned architecture is expressed as an ``ArchConfig``; repeated layer
structure is grouped into a *block pattern* (one group = ``block_pattern``
layers) so parameters stack along a leading ``n_groups`` axis and the forward
pass is a ``jax.lax.scan`` over groups — HLO size stays O(1) in depth
(126-layer configs lower in seconds).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    expert_ff: int
    num_shared: int = 0          # shared (always-on) experts
    shared_ff: int = 0           # hidden dim of the shared-expert FFN
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    router_z_weight: float = 1e-4
    gather_output: bool = False  # explicit bf16 all-gather at EP exit (§Perf)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | ssm | moe | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    citation: str = ""

    # attention details
    head_dim: Optional[int] = None     # default: d_model // n_heads
    qk_norm: bool = False
    rope: str = "standard"             # standard | mrope | none
    rope_theta: float = 1e6
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)  # of head_dim/2
    sliding_window: Optional[int] = None  # SWA variant for long_500k (dense archs)

    # layer pattern: one group = these layers, scanned n_layers/len(pattern) times
    block_pattern: Tuple[str, ...] = ("attn",)     # attn | mamba | mlstm | slstm
    ffn_pattern: Optional[Tuple[str, ...]] = None  # dense | moe | none (per slot)
    moe: Optional[MoEConfig] = None
    first_k_dense: int = 0            # leading groups forced dense-FFN (kimi)

    # ssm details
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2

    # frontends (stubs per spec)
    num_image_tokens: int = 0         # vlm: precomputed patch embeddings
    encoder_layers: int = 0           # audio: transformer encoder depth
    encoder_frames: int = 0           # audio: precomputed frame embeddings

    # training details
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    scan_layers: bool = True   # False: unroll groups (exact dry-run HLO accounting)
    optimizer: str = "adamw"
    learning_rate: float = 3e-4
    z_loss: float = 1e-4
    remat: bool = True
    remat_policy: str = "full"     # full | dots (save matmul outputs)
    prefill_last_only: bool = False  # lm_head on last token only in prefill
    microbatches: int = 1          # gradient accumulation chunks per step
    seq_parallel: bool = False     # keep residual stream seq-sharded over
                                   # 'model' between blocks (SP; §Perf)
    repeat_kv: bool = False        # materialize GQA kv -> H heads so the
                                   # head dim shards over 'model' even when
                                   # n_kv_heads < model-axis size (§Perf)
    tie_embeddings: bool = False

    def __post_init__(self):
        assert self.n_layers % len(self.block_pattern) == 0, (
            f"{self.name}: n_layers={self.n_layers} not divisible by "
            f"pattern length {len(self.block_pattern)}")
        if self.ffn_pattern is not None:
            assert len(self.ffn_pattern) == len(self.block_pattern)

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def group_size(self) -> int:
        return len(self.block_pattern)

    @property
    def n_groups(self) -> int:
        return self.n_layers // self.group_size

    @property
    def ffns(self) -> Tuple[str, ...]:
        if self.ffn_pattern is not None:
            return self.ffn_pattern
        default = "moe" if self.moe is not None else "dense"
        # ssm blocks carry their own projections; no external FFN by default
        return tuple(default if b == "attn" else ("dense" if self.d_ff > 0 else "none")
                     for b in self.block_pattern)

    def reduced(self, **overrides) -> "ArchConfig":
        """A small same-family variant for CPU smoke tests (<=2 groups,
        d_model <= 512, <= 4 experts)."""
        changes = dict(
            n_layers=min(self.n_layers, 2 * self.group_size),
            d_model=min(self.d_model, 256),
            n_heads=min(self.n_heads, 4),
            n_kv_heads=min(self.n_kv_heads, 2),
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab=min(self.vocab, 512),
            head_dim=64,
            mrope_sections=(8, 12, 12),  # scaled to head_dim 64
            encoder_layers=min(self.encoder_layers, 2),
            encoder_frames=min(self.encoder_frames, 32) if self.encoder_frames else 0,
            num_image_tokens=min(self.num_image_tokens, 16) if self.num_image_tokens else 0,
            dtype="float32",
            param_dtype="float32",
            mamba_d_state=8,
        )
        if self.moe is not None:
            changes["moe"] = dataclasses.replace(
                self.moe, num_experts=4, top_k=2, expert_ff=128,
                num_shared=min(self.moe.num_shared, 1), shared_ff=128,
                capacity_factor=2.0)
        if self.n_kv_heads == self.n_heads:
            changes["n_kv_heads"] = changes["n_heads"]
        if self.n_kv_heads == 1:
            changes["n_kv_heads"] = 1
        changes.update(overrides)
        return dataclasses.replace(self, **changes)


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One of the four assigned workload shapes."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
