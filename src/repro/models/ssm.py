"""State-space / recurrent blocks: Mamba (Jamba), mLSTM and sLSTM (xLSTM).

All three expose a parallel/train form over full sequences and a single-step
decode form carrying an O(1)-size recurrent state — this is what makes the
``long_500k`` decode shape native for the ssm/hybrid architectures (no KV
cache, constant memory in position).

TPU adaptation notes (see DESIGN.md):
  * Mamba's selective scan uses ``jax.lax.associative_scan`` (log-depth tree
    of elementwise ops) instead of the CUDA fused scan kernel.
  * mLSTM uses the stabilized recurrent form (running-max ``m`` state) under
    ``lax.scan``; a chunkwise-parallel variant is a recorded perf iteration.
  * sLSTM is inherently sequential (paper: no parallel form); ``lax.scan``.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .layers import init_rmsnorm, pdtype_of, rmsnorm
from .parallel import ParallelContext


# ---------------------------------------------------------------------------
# Mamba (selective SSM)
# ---------------------------------------------------------------------------

class MambaState(NamedTuple):
    conv: jax.Array  # [B, d_conv - 1, d_inner]
    h: jax.Array     # [B, d_inner, d_state] (f32)


def _mamba_dims(cfg: ArchConfig):
    di = cfg.mamba_expand * cfg.d_model
    dt_rank = max(1, math.ceil(cfg.d_model / 16))
    return di, cfg.mamba_d_state, cfg.mamba_d_conv, dt_rank


def init_mamba(key, cfg: ArchConfig):
    d = cfg.d_model
    di, ds, dc, dtr = _mamba_dims(cfg)
    ks = jax.random.split(key, 6)
    pd = pdtype_of(cfg)
    s = d ** -0.5
    si = di ** -0.5
    A = jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "in_proj": (jax.random.normal(ks[0], (d, 2 * di), jnp.float32) * s).astype(pd),
        "conv_w": (jax.random.normal(ks[1], (dc, di), jnp.float32) * 0.1).astype(pd),
        "conv_b": jnp.zeros((di,), pd),
        "x_proj": (jax.random.normal(ks[2], (di, dtr + 2 * ds), jnp.float32) * si).astype(pd),
        "dt_proj": (jax.random.normal(ks[3], (dtr, di), jnp.float32) * dtr ** -0.5).astype(pd),
        "dt_bias": jnp.full((di,), -2.0, pd),  # softplus(-2) ~ 0.12 init dt
        "A_log": jnp.log(A),                   # f32
        "D_skip": jnp.ones((di,), jnp.float32),
        "out_proj": (jax.random.normal(ks[4], (di, d), jnp.float32) * si).astype(pd),
    }


def _mamba_ssm_params(params, u):
    """Shared projections: u [B, S, di] -> (dt, Bs, Cs) in f32."""
    di = u.shape[-1]
    ds = params["A_log"].shape[1]
    dtr = params["dt_proj"].shape[0]
    proj = (u @ params["x_proj"]).astype(jnp.float32)
    dt, Bs, Cs = jnp.split(proj, [dtr, dtr + ds], axis=-1)
    dt = jax.nn.softplus(dt @ params["dt_proj"].astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))  # [B,S,di]
    return dt, Bs, Cs


def _causal_depthwise_conv(params, x, state=None):
    """x [B, S, di]; returns (y, new_state [B, dc-1, di])."""
    dc = params["conv_w"].shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], dc - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    w = params["conv_w"].astype(x.dtype)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(dc))
    y = y + params["conv_b"].astype(x.dtype)
    new_state = xp[:, -(dc - 1):, :] if dc > 1 else state
    return jax.nn.silu(y), new_state


def mamba_forward(params, cfg: ArchConfig, x, ctx: ParallelContext,
                  state: MambaState | None = None, return_state=False):
    """Full-sequence selective scan. x: [B, S, d] -> [B, S, d]."""
    B, S, d = x.shape
    di, ds, dc, _ = _mamba_dims(cfg)
    u, z = jnp.split(x @ params["in_proj"], 2, axis=-1)
    conv_state = state.conv if state is not None else None
    u, new_conv = _causal_depthwise_conv(params, u, conv_state)
    u = ctx.shard(u, ("pod", "data"), None, "model")
    dt, Bs, Cs = _mamba_ssm_params(params, u)
    A = -jnp.exp(params["A_log"])                       # [di, ds]
    uf = u.astype(jnp.float32)
    aA = jnp.exp(dt[..., None] * A[None, None])         # [B,S,di,ds]
    bB = (dt * uf)[..., None] * Bs[:, :, None, :]       # [B,S,di,ds]
    if state is not None:
        # fold carried state into the first step
        bB = bB.at[:, 0].add(aA[:, 0] * state.h)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    _, hS = jax.lax.associative_scan(combine, (aA, bB), axis=1)
    y = jnp.einsum("btdn,btn->btd", hS, Cs)
    y = y + params["D_skip"][None, None] * uf
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = y @ params["out_proj"]
    if return_state:
        return out, MambaState(conv=new_conv, h=hS[:, -1])
    return out, None


def mamba_decode(params, cfg: ArchConfig, x, state: MambaState,
                 ctx: ParallelContext):
    """One-token step. x: [B, 1, d]."""
    u, z = jnp.split(x @ params["in_proj"], 2, axis=-1)
    u, new_conv = _causal_depthwise_conv(params, u, state.conv)
    dt, Bs, Cs = _mamba_ssm_params(params, u)
    A = -jnp.exp(params["A_log"])
    uf = u.astype(jnp.float32)
    aA = jnp.exp(dt[:, 0, :, None] * A[None])           # [B,di,ds]
    bB = (dt[:, 0] * uf[:, 0])[..., None] * Bs[:, 0, None, :]
    h = aA * state.h + bB
    y = jnp.einsum("bdn,bn->bd", h, Cs[:, 0])
    y = y + params["D_skip"][None] * uf[:, 0]
    y = (y[:, None].astype(x.dtype)) * jax.nn.silu(z)
    return y @ params["out_proj"], MambaState(conv=new_conv, h=h)


def init_mamba_state(cfg: ArchConfig, batch: int, dtype) -> MambaState:
    di, ds, dc, _ = _mamba_dims(cfg)
    return MambaState(conv=jnp.zeros((batch, dc - 1, di), dtype),
                      h=jnp.zeros((batch, di, ds), jnp.float32))


# ---------------------------------------------------------------------------
# mLSTM (matrix-memory LSTM with exponential gating) — xLSTM
# ---------------------------------------------------------------------------

class MLSTMState(NamedTuple):
    C: jax.Array  # [B, H, dh, dh] f32
    n: jax.Array  # [B, H, dh] f32
    m: jax.Array  # [B, H] f32 (log-space stabilizer)


def _mlstm_dims(cfg: ArchConfig):
    di = 2 * cfg.d_model           # projection factor 2 (xLSTM paper)
    H = cfg.n_heads
    dh = di // H
    return di, H, dh


def init_mlstm(key, cfg: ArchConfig):
    d = cfg.d_model
    di, H, dh = _mlstm_dims(cfg)
    ks = jax.random.split(key, 7)
    pd = pdtype_of(cfg)
    s, si = d ** -0.5, di ** -0.5
    return {
        "up_proj": (jax.random.normal(ks[0], (d, 2 * di), jnp.float32) * s).astype(pd),
        "wq": (jax.random.normal(ks[1], (di, di), jnp.float32) * si).astype(pd),
        "wk": (jax.random.normal(ks[2], (di, di), jnp.float32) * si).astype(pd),
        "wv": (jax.random.normal(ks[3], (di, di), jnp.float32) * si).astype(pd),
        "w_i": (jax.random.normal(ks[4], (di, H), jnp.float32) * si).astype(jnp.float32),
        "b_i": jnp.zeros((H,), jnp.float32),
        "w_f": (jax.random.normal(ks[5], (di, H), jnp.float32) * si).astype(jnp.float32),
        "b_f": jnp.full((H,), 3.0, jnp.float32),  # forget-gate bias init high
        "down_proj": (jax.random.normal(ks[6], (di, d), jnp.float32) * si).astype(pd),
        "out_norm": init_rmsnorm(di, cfg),
    }


def _mlstm_step(carry: MLSTMState, inp):
    """Stabilized recurrent step (xLSTM Eqs. 19-27)."""
    q, k, v, i_t, f_t = inp  # q,k,v: [B,H,dh] f32; gates [B,H]
    C, n, m = carry
    log_f = jax.nn.log_sigmoid(f_t)
    m_new = jnp.maximum(log_f + m, i_t)
    i_p = jnp.exp(i_t - m_new)
    f_p = jnp.exp(log_f + m - m_new)
    C_new = f_p[..., None, None] * C + i_p[..., None, None] * (
        v[..., :, None] * k[..., None, :])
    n_new = f_p[..., None] * n + i_p[..., None] * k
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n_new, q)),
                        jnp.exp(-m_new)) + 1e-6
    h = jnp.einsum("bhde,bhe->bhd", C_new, q) / denom[..., None]
    return MLSTMState(C_new, n_new, m_new), h


def mlstm_forward(params, cfg: ArchConfig, x, ctx: ParallelContext,
                  state: MLSTMState | None = None, return_state=False):
    B, S, d = x.shape
    di, H, dh = _mlstm_dims(cfg)
    xm, z = jnp.split(x @ params["up_proj"], 2, axis=-1)
    q = (xm @ params["wq"]).reshape(B, S, H, dh).astype(jnp.float32) * dh ** -0.5
    k = (xm @ params["wk"]).reshape(B, S, H, dh).astype(jnp.float32) * dh ** -0.5
    v = (xm @ params["wv"]).reshape(B, S, H, dh).astype(jnp.float32)
    xf = xm.astype(jnp.float32)
    i_t = xf @ params["w_i"] + params["b_i"]
    f_t = xf @ params["w_f"] + params["b_f"]
    if state is None:
        state = init_mlstm_state(cfg, B, x.dtype)
    seq = (jnp.moveaxis(q, 1, 0), jnp.moveaxis(k, 1, 0), jnp.moveaxis(v, 1, 0),
           jnp.moveaxis(i_t, 1, 0), jnp.moveaxis(f_t, 1, 0))
    new_state, hs = jax.lax.scan(_mlstm_step, state, seq)
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, di).astype(x.dtype)
    h = rmsnorm(params["out_norm"], h)
    out = (h * jax.nn.silu(z)) @ params["down_proj"]
    return (out, new_state) if return_state else (out, None)


def mlstm_decode(params, cfg: ArchConfig, x, state: MLSTMState,
                 ctx: ParallelContext):
    out, new_state = mlstm_forward(params, cfg, x, ctx, state=state,
                                   return_state=True)
    return out, new_state


def init_mlstm_state(cfg: ArchConfig, batch: int, dtype) -> MLSTMState:
    di, H, dh = _mlstm_dims(cfg)
    return MLSTMState(C=jnp.zeros((batch, H, dh, dh), jnp.float32),
                      n=jnp.zeros((batch, H, dh), jnp.float32),
                      m=jnp.full((batch, H), -1e30, jnp.float32))


# ---------------------------------------------------------------------------
# sLSTM (scalar-memory LSTM with exponential gating) — xLSTM
# ---------------------------------------------------------------------------

class SLSTMState(NamedTuple):
    c: jax.Array  # [B, H, dh] f32
    n: jax.Array  # [B, H, dh] f32
    h: jax.Array  # [B, H, dh] f32
    m: jax.Array  # [B, H, dh] f32


def _slstm_dims(cfg: ArchConfig):
    H = cfg.n_heads
    dh = cfg.d_model // H
    return H, dh


def init_slstm(key, cfg: ArchConfig):
    d = cfg.d_model
    H, dh = _slstm_dims(cfg)
    ks = jax.random.split(key, 10)
    pd = pdtype_of(cfg)
    s = d ** -0.5
    sr = dh ** -0.5
    f_ff = int(4 * d / 3)

    def W(k):
        return (jax.random.normal(k, (d, H * dh), jnp.float32) * s).astype(pd)

    def R(k):  # block-diagonal recurrent weights, per head
        return (jax.random.normal(k, (H, dh, dh), jnp.float32) * sr).astype(pd)

    return {
        "w_z": W(ks[0]), "r_z": R(ks[1]),
        "w_i": W(ks[2]), "r_i": R(ks[3]),
        "w_f": W(ks[4]), "r_f": R(ks[5]),
        "w_o": W(ks[6]), "r_o": R(ks[7]),
        "b_z": jnp.zeros((H, dh), jnp.float32),
        "b_i": jnp.zeros((H, dh), jnp.float32),
        "b_f": jnp.full((H, dh), 3.0, jnp.float32),
        "b_o": jnp.zeros((H, dh), jnp.float32),
        "up_proj": (jax.random.normal(ks[8], (d, 2 * f_ff), jnp.float32) * s).astype(pd),
        "down_proj": (jax.random.normal(ks[9], (f_ff, d), jnp.float32)
                      * f_ff ** -0.5).astype(pd),
        "out_norm": init_rmsnorm(d, cfg),
    }


def _slstm_step(params, carry: SLSTMState, wx):
    """wx: dict of pre-computed W @ x_t, each [B, H, dh] (f32)."""
    c, n, h, m = carry

    def rec(name):
        return jnp.einsum("bhd,hde->bhe", h, params[f"r_{name}"].astype(jnp.float32))

    z = jnp.tanh(wx["z"] + rec("z") + params["b_z"])
    i_t = wx["i"] + rec("i") + params["b_i"]
    f_t = wx["f"] + rec("f") + params["b_f"]
    o = jax.nn.sigmoid(wx["o"] + rec("o") + params["b_o"])
    log_f = jax.nn.log_sigmoid(f_t)
    m_new = jnp.maximum(log_f + m, i_t)
    i_p = jnp.exp(i_t - m_new)
    f_p = jnp.exp(log_f + m - m_new)
    c_new = f_p * c + i_p * z
    n_new = f_p * n + i_p
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return SLSTMState(c_new, n_new, h_new, m_new), h_new


def slstm_forward(params, cfg: ArchConfig, x, ctx: ParallelContext,
                  state: SLSTMState | None = None, return_state=False):
    B, S, d = x.shape
    H, dh = _slstm_dims(cfg)
    if state is None:
        state = init_slstm_state(cfg, B, x.dtype)
    wx = {name: jnp.moveaxis(
        (x @ params[f"w_{name}"]).reshape(B, S, H, dh).astype(jnp.float32), 1, 0)
        for name in ("z", "i", "f", "o")}

    def step(carry, inp):
        return _slstm_step(params, carry, inp)

    new_state, hs = jax.lax.scan(
        step, state, {k: v for k, v in wx.items()})
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, d).astype(x.dtype)
    h = rmsnorm(params["out_norm"], h)
    # GeGLU post-FFN (projection factor 4/3, part of the sLSTM block)
    u, g = jnp.split(h @ params["up_proj"], 2, axis=-1)
    out = (u * jax.nn.gelu(g)) @ params["down_proj"]
    return (out, new_state) if return_state else (out, None)


def slstm_decode(params, cfg: ArchConfig, x, state: SLSTMState,
                 ctx: ParallelContext):
    out, new_state = slstm_forward(params, cfg, x, ctx, state=state,
                                   return_state=True)
    return out, new_state


def init_slstm_state(cfg: ArchConfig, batch: int, dtype) -> SLSTMState:
    H, dh = _slstm_dims(cfg)
    z = jnp.zeros((batch, H, dh), jnp.float32)
    return SLSTMState(c=z, n=z, h=z, m=jnp.full((batch, H, dh), -1e30, jnp.float32))
