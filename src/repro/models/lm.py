"""Decoder-only language model assembling arbitrary block patterns.

A model is ``embed -> [prelude groups] -> scan(stacked groups) -> norm -> head``
where one *group* is ``cfg.block_pattern`` (e.g. Jamba's 7x mamba + 1x attn)
and groups are stacked along a leading axis and driven by ``jax.lax.scan``
(+ ``jax.checkpoint`` when ``cfg.remat``) so HLO size is depth-independent.

Each pattern slot is ``mixer (attn | mamba | mlstm | slstm) [+ FFN
(dense | moe | none)]`` with pre-RMSNorm residuals.  The same group code
serves train/prefill (full sequence, optional cache collection) and decode
(single token, carried recurrent state / KV cache).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .layers import (AttnCache, attention_block, attention_decode,
                     dense_ffn, dtype_of, init_attention, init_dense_ffn,
                     init_rmsnorm, pdtype_of, positions_for, rmsnorm)
from .moe import init_moe, moe_ffn
from .parallel import ParallelContext
from .ssm import (MambaState, MLSTMState, SLSTMState, init_mamba,
                  init_mamba_state, init_mlstm, init_mlstm_state, init_slstm,
                  init_slstm_state, mamba_decode, mamba_forward, mlstm_decode,
                  mlstm_forward, slstm_decode, slstm_forward)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_block(key, cfg: ArchConfig, slot: int, force_dense_ffn=False):
    kind = cfg.block_pattern[slot]
    ffn_kind = cfg.ffns[slot]
    if force_dense_ffn and ffn_kind == "moe":
        ffn_kind = "dense"
    k1, k2 = jax.random.split(key)
    params: dict[str, Any] = {"ln1": init_rmsnorm(cfg.d_model, cfg)}
    if kind == "attn":
        params["mixer_attn"] = init_attention(k1, cfg)
    elif kind == "mamba":
        params["mixer_mamba"] = init_mamba(k1, cfg)
    elif kind == "mlstm":
        params["mixer_mlstm"] = init_mlstm(k1, cfg)
    elif kind == "slstm":
        params["mixer_slstm"] = init_slstm(k1, cfg)
    else:
        raise ValueError(kind)
    if ffn_kind != "none":
        params["ln2"] = init_rmsnorm(cfg.d_model, cfg)
        if ffn_kind == "moe":
            params["ffn_moe"] = init_moe(k2, cfg)
        else:
            params["ffn_dense"] = init_dense_ffn(k2, cfg)
    return params


def init_group(key, cfg: ArchConfig, force_dense_ffn=False):
    keys = jax.random.split(key, cfg.group_size)
    return {f"slot{i}": init_block(keys[i], cfg, i, force_dense_ffn)
            for i in range(cfg.group_size)}


def init_lm(key, cfg: ArchConfig):
    k_embed, k_groups, k_head, k_pre = jax.random.split(key, 4)
    pd = pdtype_of(cfg)
    n_pre = cfg.first_k_dense
    n_scan = cfg.n_groups - n_pre
    params = {
        "embed": (jax.random.normal(k_embed, (cfg.vocab, cfg.d_model),
                                    jnp.float32) * 0.02).astype(pd),
        "final_norm": init_rmsnorm(cfg.d_model, cfg),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(
            k_head, (cfg.d_model, cfg.vocab), jnp.float32)
            * cfg.d_model ** -0.5).astype(pd)
    if n_pre:
        pre_keys = jax.random.split(k_pre, n_pre)
        params["prelude"] = [init_group(pre_keys[i], cfg, force_dense_ffn=True)
                             for i in range(n_pre)]
    group_keys = jax.random.split(k_groups, n_scan)
    params["groups"] = jax.vmap(lambda k: init_group(k, cfg))(group_keys)
    return params


# ---------------------------------------------------------------------------
# block application — full sequence
# ---------------------------------------------------------------------------

def apply_block(bparams, cfg: ArchConfig, slot: int, x, positions, ctx,
                *, impl="ref", window=None, collect_cache=False,
                force_dense_ffn=False):
    """Returns (x, aux_loss, cache_entry)."""
    kind = cfg.block_pattern[slot]
    h = rmsnorm(bparams["ln1"], x)
    cache_entry = None
    if kind == "attn":
        y, cache_entry = attention_block(
            bparams["mixer_attn"], cfg, h, positions, ctx, causal=True,
            window=window, impl=impl, return_cache=collect_cache)
    elif kind == "mamba":
        y, cache_entry = mamba_forward(bparams["mixer_mamba"], cfg, h, ctx,
                                       return_state=collect_cache)
    elif kind == "mlstm":
        y, cache_entry = mlstm_forward(bparams["mixer_mlstm"], cfg, h, ctx,
                                       return_state=collect_cache)
    else:  # slstm
        y, cache_entry = slstm_forward(bparams["mixer_slstm"], cfg, h, ctx,
                                       return_state=collect_cache)
    x = x + y
    aux = jnp.zeros((), jnp.float32)
    # FFN kind dispatch by parameter presence (prelude groups may force dense)
    if "ffn_moe" in bparams:
        h2 = rmsnorm(bparams["ln2"], x)
        y2, aux = moe_ffn(bparams["ffn_moe"], h2, cfg, ctx)
        x = x + y2
    elif "ffn_dense" in bparams:
        h2 = rmsnorm(bparams["ln2"], x)
        x = x + dense_ffn(bparams["ffn_dense"], h2, ctx)
    if cfg.seq_parallel:
        # sequence parallelism: residual stays seq-sharded over 'model';
        # XLA inserts all-gather before attention projections and
        # reduce-scatter after — replacing the replicate-based reshard at
        # MoE (seq-sharded) <-> attention (head-sharded) boundaries
        x = ctx.shard(x, ("pod", "data"), "model", None)
    else:
        x = ctx.shard(x, ("pod", "data"), None, None)
    return x, aux, cache_entry


def apply_group(gparams, cfg: ArchConfig, x, positions, ctx, *, impl="ref",
                window=None, collect_cache=False, force_dense_ffn=False):
    aux_total = jnp.zeros((), jnp.float32)
    caches = {}
    for i in range(cfg.group_size):
        x, aux, ce = apply_block(gparams[f"slot{i}"], cfg, i, x, positions,
                                 ctx, impl=impl, window=window,
                                 collect_cache=collect_cache,
                                 force_dense_ffn=force_dense_ffn)
        aux_total = aux_total + aux
        if collect_cache:
            caches[f"slot{i}"] = ce
    return x, aux_total, caches


# ---------------------------------------------------------------------------
# forward — train / prefill
# ---------------------------------------------------------------------------

class ForwardOut(NamedTuple):
    logits: jax.Array
    aux_loss: jax.Array
    cache: Any = None


def embed_inputs(params, cfg: ArchConfig, tokens, image_embeds=None):
    """Token embedding, with optional stubbed modality embeddings prepended."""
    x = params["embed"][tokens].astype(dtype_of(cfg))
    if image_embeds is not None:
        x = jnp.concatenate([image_embeds.astype(dtype_of(cfg)), x], axis=1)
    return x


def _remat(cfg: ArchConfig, fn):
    if not cfg.remat:
        return fn
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


def lm_forward(params, cfg: ArchConfig, ctx: ParallelContext, tokens,
               image_embeds=None, *, impl="ref", window=None,
               collect_cache=False, last_only=False) -> ForwardOut:
    x = embed_inputs(params, cfg, tokens, image_embeds)
    B, S, _ = x.shape
    x = ctx.shard(x, ("pod", "data"), None, None)
    positions = positions_for(cfg, B, S)
    aux_total = jnp.zeros((), jnp.float32)

    pre_caches = []
    for g in params.get("prelude", []):
        x, aux, c = apply_group(g, cfg, x, positions, ctx, impl=impl,
                                window=window, collect_cache=collect_cache,
                                force_dense_ffn=True)
        aux_total = aux_total + aux
        pre_caches.append(c)

    def body(carry, gparams):
        x, aux = carry
        x, a, caches = apply_group(gparams, cfg, x, positions, ctx, impl=impl,
                                   window=window, collect_cache=collect_cache)
        return (x, aux + a), caches

    body_fn = _remat(cfg, body)
    if cfg.scan_layers:
        (x, aux_total), scan_caches = jax.lax.scan(body_fn, (x, aux_total),
                                                   params["groups"])
    else:  # unrolled: exact per-layer HLO accounting for the dry-run
        n_scan = cfg.n_groups - cfg.first_k_dense
        outs = []
        for gi in range(n_scan):
            g = jax.tree_util.tree_map(lambda a: a[gi], params["groups"])
            (x, aux_total), c = body_fn((x, aux_total), g)
            outs.append(c)
        scan_caches = (jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *outs)
                       if collect_cache and outs else None)
    x = rmsnorm(params["final_norm"], x)
    if last_only or (collect_cache and cfg.prefill_last_only):
        x = x[:, -1:]  # prefill only needs the next-token distribution
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x @ head).astype(jnp.float32)
    logits = ctx.shard(logits, ("pod", "data"), None, "model")
    cache = None
    if collect_cache:
        cache = {"prelude": pre_caches, "groups": scan_caches}
    return ForwardOut(logits=logits, aux_loss=aux_total, cache=cache)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_block_cache(cfg: ArchConfig, slot: int, batch: int, cache_len: int,
                     window: Optional[int], dtype):
    kind = cfg.block_pattern[slot]
    if kind == "attn":
        L = min(window, cache_len) if window else cache_len
        return AttnCache(
            k=jnp.zeros((batch, L, cfg.n_kv_heads, cfg.hd), dtype),
            v=jnp.zeros((batch, L, cfg.n_kv_heads, cfg.hd), dtype))
    if kind == "mamba":
        return init_mamba_state(cfg, batch, dtype)
    if kind == "mlstm":
        return init_mlstm_state(cfg, batch, dtype)
    return init_slstm_state(cfg, batch, dtype)


def init_cache(cfg: ArchConfig, batch: int, cache_len: int,
               window: Optional[int] = None, dtype=None):
    dtype = dtype or dtype_of(cfg)

    def one_group():
        return {f"slot{i}": init_block_cache(cfg, i, batch, cache_len, window,
                                             dtype)
                for i in range(cfg.group_size)}

    n_pre = cfg.first_k_dense
    n_scan = cfg.n_groups - n_pre
    stacked = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs), *([one_group()] * n_scan)) if n_scan > 1 \
        else jax.tree_util.tree_map(lambda x: x[None], one_group())
    return {"prelude": [one_group() for _ in range(n_pre)], "groups": stacked}


def decode_block(bparams, cfg: ArchConfig, slot: int, x, pos, cache_entry,
                 ctx, *, window=None):
    kind = cfg.block_pattern[slot]
    h = rmsnorm(bparams["ln1"], x)
    if kind == "attn":
        y, new_cache = attention_decode(bparams["mixer_attn"], cfg, h, pos,
                                        cache_entry, ctx, window=window)
    elif kind == "mamba":
        y, new_cache = mamba_decode(bparams["mixer_mamba"], cfg, h,
                                    cache_entry, ctx)
    elif kind == "mlstm":
        y, new_cache = mlstm_decode(bparams["mixer_mlstm"], cfg, h,
                                    cache_entry, ctx)
    else:
        y, new_cache = slstm_decode(bparams["mixer_slstm"], cfg, h,
                                    cache_entry, ctx)
    x = x + y
    if "ffn_moe" in bparams:
        h2 = rmsnorm(bparams["ln2"], x)
        y2, _ = moe_ffn(bparams["ffn_moe"], h2, cfg, ctx)
        x = x + y2
    elif "ffn_dense" in bparams:
        h2 = rmsnorm(bparams["ln2"], x)
        x = x + dense_ffn(bparams["ffn_dense"], h2, ctx)
    return x, new_cache


def decode_group(gparams, cfg: ArchConfig, x, pos, gcache, ctx, *,
                 window=None, force_dense_ffn=False):
    new_cache = {}
    for i in range(cfg.group_size):
        if force_dense_ffn:
            # prelude groups replace moe with dense; handled by param presence
            pass
        x, nc = decode_block(gparams[f"slot{i}"], cfg, i, x, pos,
                             gcache[f"slot{i}"], ctx, window=window)
        new_cache[f"slot{i}"] = nc
    return x, new_cache


def lm_decode_step(params, cfg: ArchConfig, ctx: ParallelContext, cache,
                   tokens, pos, *, window=None):
    """One decode step. tokens: [B, 1]; pos: scalar int32.  Returns
    (logits [B, 1, V], new cache)."""
    x = params["embed"][tokens].astype(dtype_of(cfg))
    x = ctx.shard(x, ("pod", "data"), None, None)

    new_pre = []
    for g, c in zip(params.get("prelude", []), cache["prelude"]):
        x, nc = decode_group(g, cfg, x, pos, c, ctx, window=window)
        new_pre.append(nc)

    def body(x, xs):
        gparams, gcache = xs
        x, nc = decode_group(gparams, cfg, x, pos, gcache, ctx, window=window)
        return x, nc

    if cfg.scan_layers:
        x, new_scan = jax.lax.scan(body, x,
                                   (params["groups"], cache["groups"]))
    else:
        n_scan = cfg.n_groups - cfg.first_k_dense
        outs = []
        for gi in range(n_scan):
            g = jax.tree_util.tree_map(lambda a: a[gi], params["groups"])
            gc = jax.tree_util.tree_map(lambda a: a[gi], cache["groups"])
            x, nc = decode_group(g, cfg, x, pos, gc, ctx, window=window)
            outs.append(nc)
        new_scan = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *outs)
    x = rmsnorm(params["final_norm"], x)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x @ head).astype(jnp.float32)
    return logits, {"prelude": new_pre, "groups": new_scan}
