"""Mixture-of-Experts FFN with expert parallelism.

Two execution paths with identical routing semantics:

  * **EP path** (mesh active): experts are sharded over the ``model`` axis
    (expert parallelism) and FSDP-sharded over ``data``.  Tokens are
    dispatched to their experts' owners with a fixed-capacity
    ``jax.lax.all_to_all`` inside ``jax.shard_map`` (Switch-/DeepSeek-style:
    top-k routing, per-destination capacity ``ceil(T*k/ep * cf)``, overflow
    dropped), computed locally with ``jax.lax.ragged_dot`` after an argsort
    group-by, and returned with a second all-to-all.  Differentiable
    end-to-end (train_step lowers on the production mesh).

  * **ragged path** (no mesh / 1-device tests): same top-k routing, global
    argsort group-by + ragged_dot, no collectives, no capacity drop.  The EP
    path reduces to this semantics when capacity is generous — tested.

Shared ("always-on") experts (Qwen2-MoE) run as a dense SwiGLU with a
sigmoid gate.  Router auxiliary losses: switch load-balance loss and router
z-loss, averaged across the mesh.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from .config import ArchConfig, MoEConfig
from .layers import dense_ffn, init_dense_ffn
from .parallel import ParallelContext


def init_moe(key, cfg: ArchConfig):
    moe = cfg.moe
    d, E, f = cfg.d_model, moe.num_experts, moe.expert_ff
    keys = jax.random.split(key, 6)
    pd = jnp.dtype(cfg.param_dtype)
    s_in, s_out = d ** -0.5, f ** -0.5
    params = {
        "router": (jax.random.normal(keys[0], (d, E), jnp.float32) * s_in
                   ).astype(jnp.float32),  # router stays f32 for stable top-k
        "experts": {
            "w_gate": (jax.random.normal(keys[1], (E, d, f), jnp.float32) * s_in).astype(pd),
            "w_up": (jax.random.normal(keys[2], (E, d, f), jnp.float32) * s_in).astype(pd),
            "w_down": (jax.random.normal(keys[3], (E, f, d), jnp.float32) * s_out).astype(pd),
        },
    }
    if moe.num_shared > 0:
        params["shared"] = init_dense_ffn(keys[4], cfg,
                                          d_ff=moe.num_shared * moe.shared_ff)
        params["shared_gate"] = (jax.random.normal(keys[5], (d, 1), jnp.float32)
                                 * s_in).astype(jnp.float32)
    return params


def _route(router_w, x_flat, moe: MoEConfig):
    """Top-k routing. Returns (ids [T,k], weights [T,k], aux_loss scalar)."""
    logits = (x_flat.astype(jnp.float32) @ router_w)  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_ids = jax.lax.top_k(probs, moe.top_k)
    top_w = top_w / jnp.maximum(jnp.sum(top_w, axis=-1, keepdims=True), 1e-9)
    # switch load-balance loss: E * sum_e f_e * P_e
    E = logits.shape[-1]
    f_e = jnp.mean(jnp.sum(jax.nn.one_hot(top_ids, E), axis=1), axis=0)  # [E]
    P_e = jnp.mean(probs, axis=0)
    aux = moe.router_aux_weight * E * jnp.sum(f_e * P_e)
    zl = moe.router_z_weight * jnp.mean(
        jnp.square(jax.scipy.special.logsumexp(logits, axis=-1)))
    # keep f32 even under global x64 (test collection enables x64 for the
    # queueing core; scan carries must stay dtype-stable)
    return top_ids, top_w.astype(x_flat.dtype), (aux + zl).astype(jnp.float32)


def _group_by_expert(ids_flat: jax.Array, num_groups: int):
    """Stable argsort group-by. Returns (order, group_sizes, idx_in_group)."""
    order = jnp.argsort(ids_flat, stable=True)
    counts = jnp.bincount(ids_flat, length=num_groups)
    starts = jnp.cumsum(counts) - counts
    idx_sorted = jnp.arange(ids_flat.shape[0]) - starts[ids_flat[order]]
    idx_in_group = jnp.zeros_like(idx_sorted).at[order].set(idx_sorted)
    return order, counts, idx_in_group


def _expert_swiglu(w, x_sorted, group_sizes):
    """ragged SwiGLU over grouped tokens: x [R, d] -> [R, d].

    Exact (no capacity drops); used on the collective-free path.  Note the
    XLA cost model prices ragged_dot as a dense [R,d]x[E,d,f] contraction,
    so the EP path uses :func:`_expert_swiglu_capacity` instead."""
    gs = group_sizes.astype(jnp.int32)
    h = (jax.nn.silu(jax.lax.ragged_dot(x_sorted, w["w_gate"], gs))
         * jax.lax.ragged_dot(x_sorted, w["w_up"], gs))
    return jax.lax.ragged_dot(h, w["w_down"], gs)


def _expert_swiglu_capacity(w, x_sorted, ids_sorted, group_sizes,
                            capacity: int):
    """Capacity-buffer SwiGLU: scatter sorted tokens into a dense
    [E_loc, C, d] buffer, run batched-einsum experts (MXU-shaped, correctly
    priced by the XLA cost model), gather back.  Overflow beyond per-expert
    capacity is dropped (Switch semantics)."""
    R, d = x_sorted.shape
    E_loc = group_sizes.shape[0]
    starts = jnp.cumsum(group_sizes) - group_sizes
    idx_in_e = jnp.arange(R) - starts[ids_sorted]
    keep = idx_in_e < capacity
    slot = jnp.where(keep, ids_sorted * capacity + idx_in_e, E_loc * capacity)
    buf = jnp.zeros((E_loc * capacity, d), x_sorted.dtype).at[slot].set(
        x_sorted, mode="drop").reshape(E_loc, capacity, d)
    h = (jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w["w_gate"]))
         * jnp.einsum("ecd,edf->ecf", buf, w["w_up"]))
    out = jnp.einsum("ecf,efd->ecd", h, w["w_down"]).reshape(
        E_loc * capacity, d)
    y = out[slot.clip(0, E_loc * capacity - 1)]
    return jnp.where(keep[:, None], y, 0)


def _moe_ragged(params, x, cfg: ArchConfig):
    """Collective-free path: global group-by + ragged_dot (exact, no drops)."""
    moe = cfg.moe
    B, S, d = x.shape
    T, k, E = B * S, moe.top_k, moe.num_experts
    xf = x.reshape(T, d)
    ids, w, aux = _route(params["router"], xf, moe)
    rep_ids = ids.reshape(T * k)
    rep_src = jnp.repeat(jnp.arange(T), k)
    order, counts, _ = _group_by_expert(rep_ids, E)
    x_sorted = xf[rep_src[order]]
    y_sorted = _expert_swiglu(params["experts"], x_sorted, counts)
    y = jnp.zeros((T, d), x.dtype).at[rep_src[order]].add(
        y_sorted * w.reshape(T * k)[order][:, None])
    return y.reshape(B, S, d), aux


def _moe_ep_local(params_local, x_local, cfg: ArchConfig, ep: int,
                  data_axes: tuple, all_axes: tuple = (), E_pad: int = 0,
                  gather_out: bool = False, slice_seq: bool = False):
    """shard_map body: x_local [B_loc, S, d]; experts local [E_loc, d(/dp), f].

    ``E_pad`` >= num_experts is the zero-padded expert count (divisible by
    ``ep``); padded experts' router logits are masked to -inf in _route."""
    moe = cfg.moe
    if slice_seq:
        # replicated-in dispatch: each EP rank slices its own seq chunk in
        # bf16 (free), so SPMD never materializes a seq-sharded boundary —
        # avoids f32 cotangent all-gathers in backward (§Perf iteration)
        B, S_full, d = x_local.shape
        S = S_full // ep
        start = jax.lax.axis_index("model") * S
        x_local = jax.lax.dynamic_slice_in_dim(x_local, start, S, axis=1)
    B, S, d = x_local.shape
    T, k = B * S, moe.top_k
    E = E_pad or moe.num_experts
    E_loc = E // ep
    xf = x_local.reshape(T, d)

    # FSDP gather of local expert weights over the data axis (axis=1: d rows)
    def gather(wname, axis):
        w = params_local["experts"][wname]
        for a in data_axes:
            w = jax.lax.all_gather(w, a, axis=axis, tiled=True)
        return w

    w_full = {"w_gate": gather("w_gate", 1), "w_up": gather("w_up", 1),
              "w_down": gather("w_down", 1)}

    ids, wts, aux = _route(params_local["router"], xf, moe)
    rep_ids = ids.reshape(T * k)                       # global expert ids
    rep_w = wts.reshape(T * k)
    rep_src = jnp.repeat(jnp.arange(T), k)             # owning token
    dest = rep_ids // E_loc                            # EP peer in [0, ep)
    e_loc = rep_ids % E_loc

    C = max(1, math.ceil(T * k / ep * moe.capacity_factor))
    _, _, idx_in_dest = _group_by_expert(dest, ep)
    keep = idx_in_dest < C
    slot = jnp.where(keep, dest * C + idx_in_dest, ep * C)  # OOB -> dropped

    send_x = jnp.zeros((ep * C, d), x_local.dtype).at[slot].set(xf[rep_src],
                                                                mode="drop")
    send_e = jnp.zeros((ep * C,), jnp.int32).at[slot].set(
        e_loc.astype(jnp.int32), mode="drop")
    send_valid = jnp.zeros((ep * C,), jnp.bool_).at[slot].set(True, mode="drop")

    recv_x = jax.lax.all_to_all(send_x.reshape(ep, C, d), "model", 0, 0,
                                tiled=False).reshape(ep * C, d)
    recv_e = jax.lax.all_to_all(send_e.reshape(ep, C), "model", 0, 0,
                                tiled=False).reshape(ep * C)
    recv_valid = jax.lax.all_to_all(send_valid.reshape(ep, C), "model", 0, 0,
                                    tiled=False).reshape(ep * C)

    # local expert compute (invalid rows are zeros routed to expert 0)
    recv_e = jnp.where(recv_valid, recv_e, 0)
    order, counts, _ = _group_by_expert(recv_e, E_loc)
    cap_local = max(1, math.ceil(ep * C * moe.capacity_factor / E_loc))
    y_sorted = _expert_swiglu_capacity(w_full, recv_x[order], recv_e[order],
                                       counts, cap_local)
    y_local = jnp.zeros_like(recv_x).at[order].set(y_sorted)
    y_local = jnp.where(recv_valid[:, None], y_local, 0)

    back = jax.lax.all_to_all(y_local.reshape(ep, C, d), "model", 0, 0,
                              tiled=False).reshape(ep * C, d)
    # combine at origin: slot layout matches send_x
    contrib = back[slot.clip(0, ep * C - 1)] * rep_w[:, None]
    contrib = jnp.where(keep[:, None], contrib, 0)
    y = jnp.zeros((T, d), x_local.dtype).at[rep_src].add(contrib)

    # average aux loss across the whole mesh
    for a in all_axes:
        aux = jax.lax.pmean(aux, a)
    y = y.reshape(B, S, d)
    if gather_out:
        # explicit bf16 all-gather of the seq-sharded output: downstream
        # layers want the residual replicated over 'model'; letting SPMD do
        # this reshard costs f32 gathers in fwd+bwd (§Perf iteration)
        y = jax.lax.all_gather(y, "model", axis=1, tiled=True)
    return y, aux


def moe_ffn(params, x, cfg: ArchConfig, ctx: ParallelContext):
    """MoE FFN returning (y, aux_loss)."""
    moe = cfg.moe
    if ctx.mesh is not None and ctx.model_axis is not None \
            and ctx.mesh.shape["model"] > 1:
        mesh = ctx.mesh
        ep = mesh.shape["model"]
        # zero-pad the expert dim to a multiple of the EP degree (padded
        # slots own no router ids and never receive tokens)
        E = moe.num_experts
        E_pad = -(-E // ep) * ep
        experts = params["experts"]
        if E_pad != E:
            experts = jax.tree_util.tree_map(
                lambda w: jnp.pad(w, ((0, E_pad - E),) + ((0, 0),) * (w.ndim - 1)),
                experts)
        data_axes = tuple(a for a in ("data",) if a in mesh.axis_names)
        P = jax.sharding.PartitionSpec
        batch = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        # Sequence-shard the tokens over the model axis when divisible so
        # each EP rank routes a disjoint token slice (decode's S=1 falls back
        # to replicated routing — negligible compute, still correct).
        seq_ax = "model" if x.shape[1] % ep == 0 and x.shape[1] > 1 else None
        bsz = 1
        for a in batch:
            bsz *= mesh.shape[a]
        batch_ax = batch if batch and x.shape[0] % bsz == 0 else None
        in_specs = (
            {"router": P(None, None),
             "experts": {"w_gate": P("model", "data", None),
                         "w_up": P("model", "data", None),
                         "w_down": P("model", "data", None)}},
            P(batch_ax, seq_ax, None),
        )
        out_specs = (P(batch_ax, seq_ax, None), P())
        gather_out = bool(moe.gather_output and seq_ax is not None)
        slice_seq = gather_out  # replicated-in + manual slice pairs with it
        if gather_out:
            in_specs = (in_specs[0], P(batch_ax, None, None))
            out_specs = (P(batch_ax, None, None), P())
        routed = {"router": params["router"], "experts": experts}
        fn = partial(_moe_ep_local, cfg=cfg, ep=ep, data_axes=data_axes,
                     all_axes=tuple(mesh.axis_names), E_pad=E_pad,
                     gather_out=gather_out, slice_seq=slice_seq)
        y, aux = jax.shard_map(
            lambda pr, xx: fn(pr, xx), mesh=mesh,
            in_specs=in_specs, out_specs=out_specs,
            check_vma=False)(routed, x)
    else:
        y, aux = _moe_ragged(params, x, cfg)

    if moe.num_shared > 0:
        gate = jax.nn.sigmoid(
            (x.astype(jnp.float32) @ params["shared_gate"]))
        y = y + dense_ffn(params["shared"], x, ctx) * gate.astype(x.dtype)
    return y, aux
