"""Shared transformer layers: norms, rotary embeddings (standard + M-RoPE),
SwiGLU FFN, and the GQA attention block (train / prefill / decode modes)."""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .attention import attention, decode_attention_ref
from .config import ArchConfig
from .parallel import ParallelContext


def dtype_of(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


def pdtype_of(cfg: ArchConfig):
    return jnp.dtype(cfg.param_dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_rmsnorm(dim: int, cfg: ArchConfig):
    return {"scale": jnp.ones((dim,), pdtype_of(cfg))}


def rmsnorm(params, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * params["scale"].astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Standard RoPE. x: [B, S, H, D]; positions: [S] or [B, S]."""
    D = x.shape[-1]
    freqs = rope_frequencies(D, theta)  # [D/2]
    pos = positions.astype(jnp.float32)
    if pos.ndim == 1:
        pos = pos[None, :]
    angles = pos[..., None] * freqs[None, None, :]        # [B, S, D/2]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions3: jax.Array, theta: float,
                sections: tuple[int, int, int]) -> jax.Array:
    """Qwen2-VL multimodal RoPE: three position streams (t, h, w), each
    rotating its own section of the head dim.  positions3: [3, B, S]."""
    D = x.shape[-1]
    freqs = rope_frequencies(D, theta)  # [D/2]
    # section s owns freqs[offset:offset+sections[s]]
    assert sum(sections) == D // 2, "mrope sections must sum to head_dim/2"
    sect_id = jnp.repeat(jnp.arange(3), jnp.asarray(sections),
                         total_repeat_length=D // 2)  # [D/2]
    pos = positions3.astype(jnp.float32)               # [3, B, S]
    angles_all = pos[..., None] * freqs[None, None, None, :]  # [3, B, S, D/2]
    angles = jnp.take_along_axis(
        angles_all, sect_id[None, None, None, :].repeat(pos.shape[1], 1)
        .repeat(pos.shape[2], 2), axis=0)[0]            # [B, S, D/2]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def positions_for(cfg: ArchConfig, batch: int, seq: int, offset=0):
    """Build the position stream(s) for rope / mrope."""
    pos = offset + jnp.arange(seq)
    if cfg.rope == "mrope":
        # stubbed vision layout: first num_image_tokens form a grid (t=0),
        # text continues at t = grid_size
        n_img = cfg.num_image_tokens
        side = max(int(n_img ** 0.5), 1)
        t = jnp.where(pos < n_img, 0, pos - n_img + side)
        h = jnp.where(pos < n_img, pos // side, pos - n_img + side)
        w = jnp.where(pos < n_img, pos % side, pos - n_img + side)
        p3 = jnp.stack([t, h, w])  # [3, S]
        return jnp.broadcast_to(p3[:, None, :], (3, batch, seq))
    return jnp.broadcast_to(pos[None, :], (batch, seq))


def _rope_q_or_k(cfg: ArchConfig, x, positions):
    if cfg.rope == "standard":
        return apply_rope(x, positions, cfg.rope_theta)
    if cfg.rope == "mrope":
        return apply_mrope(x, positions, cfg.rope_theta, cfg.mrope_sections)
    return x  # "none"


# ---------------------------------------------------------------------------
# dense FFN (SwiGLU)
# ---------------------------------------------------------------------------

def init_dense_ffn(key, cfg: ArchConfig, d_ff: Optional[int] = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = d ** -0.5
    s_out = f ** -0.5
    pd = pdtype_of(cfg)
    return {
        "w_gate": (jax.random.normal(k1, (d, f), jnp.float32) * s_in).astype(pd),
        "w_up": (jax.random.normal(k2, (d, f), jnp.float32) * s_in).astype(pd),
        "w_down": (jax.random.normal(k3, (f, d), jnp.float32) * s_out).astype(pd),
    }


def dense_ffn(params, x, ctx: ParallelContext):
    h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    h = ctx.shard(h, ("pod", "data"), None, "model")
    return h @ params["w_down"]


# ---------------------------------------------------------------------------
# GQA attention block
# ---------------------------------------------------------------------------

class AttnCache(NamedTuple):
    k: jax.Array  # [B, S_cache, KV, D]
    v: jax.Array  # [B, S_cache, KV, D]


def init_attention(key, cfg: ArchConfig):
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = d ** -0.5
    so = (H * hd) ** -0.5
    pd = pdtype_of(cfg)
    params = {
        "wq": (jax.random.normal(k1, (d, H * hd), jnp.float32) * s).astype(pd),
        "wk": (jax.random.normal(k2, (d, KV * hd), jnp.float32) * s).astype(pd),
        "wv": (jax.random.normal(k3, (d, KV * hd), jnp.float32) * s).astype(pd),
        "wo": (jax.random.normal(k4, (H * hd, d), jnp.float32) * so).astype(pd),
    }
    if cfg.qk_norm:
        params["q_norm"] = init_rmsnorm(hd, cfg)
        params["k_norm"] = init_rmsnorm(hd, cfg)
    return params


def _project_qkv(params, cfg: ArchConfig, x, positions, ctx):
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (x @ params["wq"]).reshape(B, S, H, hd)
    k = (x @ params["wk"]).reshape(B, S, KV, hd)
    v = (x @ params["wv"]).reshape(B, S, KV, hd)
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q)
        k = rmsnorm(params["k_norm"], k)
    q = _rope_q_or_k(cfg, q, positions)
    k = _rope_q_or_k(cfg, k, positions)
    q = ctx.shard(q, ("pod", "data"), None, "model", None)
    k = ctx.shard(k, ("pod", "data"), None, "model", None)
    return q, k, v


def attention_block(params, cfg: ArchConfig, x, positions, ctx,
                    *, causal=True, window=None, impl="ref",
                    return_cache=False):
    """Full-sequence attention (train / prefill)."""
    q, k, v = _project_qkv(params, cfg, x, positions, ctx)
    if cfg.repeat_kv and cfg.n_kv_heads < cfg.n_heads:
        # GQA -> MHA layout: lets the head dim shard over the model axis
        # even when n_kv_heads < axis size (avoids replicated attention
        # activations + f32 score all-gathers; §Perf)
        G = cfg.n_heads // cfg.n_kv_heads
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
        k = ctx.shard(k, ("pod", "data"), None, "model", None)
        v = ctx.shard(v, ("pod", "data"), None, "model", None)
    out = attention(q, k, v, causal=causal,
                    window=window or cfg.sliding_window, impl=impl)
    B, S = x.shape[:2]
    y = out.reshape(B, S, -1) @ params["wo"]
    if return_cache:
        return y, AttnCache(k=k, v=v)
    return y, None


def attention_decode(params, cfg: ArchConfig, x, pos, cache: AttnCache, ctx,
                     *, window=None):
    """One-token decode against a KV cache.

    With a sliding window the cache is a ring buffer of size ``window``; the
    write slot is ``pos % window`` and all entries are valid once
    ``pos >= window``.  Without a window the cache has static length
    ``S_cache`` and entries ``< pos`` (+ the new one) are valid.
    """
    B = x.shape[0]
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    positions = jnp.broadcast_to(jnp.asarray(pos)[None, None], (B, 1))
    if cfg.rope == "mrope":
        # decode is always past the image grid: all three streams share the
        # text position value used by positions_for (pos - n_img + side)
        n_img = cfg.num_image_tokens
        side = max(int(n_img ** 0.5), 1)
        val = jnp.asarray(pos) - n_img + side
        positions = jnp.broadcast_to(val[None, None, None], (3, B, 1))
    q, k_new, v_new = _project_qkv(params, cfg, x, positions, ctx)

    S_cache = cache.k.shape[1]
    slot = (pos % S_cache) if window is not None else pos
    k_cache = jax.lax.dynamic_update_slice_in_dim(cache.k, k_new, slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(cache.v, v_new, slot, axis=1)
    length = jnp.minimum(pos + 1, S_cache)
    out = decode_attention_ref(q, k_cache, v_cache, length)
    y = out.reshape(B, 1, H * hd) @ params["wo"]
    return y, AttnCache(k=k_cache, v=v_cache)
