"""Model bundle: a uniform functional API over all assigned architectures.

``build_model(cfg, ctx)`` returns a :class:`ModelBundle` with:

  * ``init(rng) -> params``                     (pure; shape-only via eval_shape)
  * ``loss_fn(params, batch) -> (loss, metrics)``
  * ``train_step(params, opt_state, batch) -> (params, opt_state, metrics)``
  * ``prefill(params, batch) -> (logits, cache)``
  * ``decode_step(params, cache, tokens, pos) -> (logits, cache)``
  * ``init_cache(batch, cache_len, window) -> cache``
  * ``input_specs(shape) -> batch of ShapeDtypeStructs``  (dry-run stand-ins)

Batch dict conventions:
  lm / moe / ssm / hybrid / dense: {"tokens": [B,S], "targets": [B,S]}
  vlm:   + {"image_embeds": [B, n_img, d]} (tokens cover S - n_img positions)
  audio: {"frames": [B,F,d], "tokens": [B,S], "targets": [B,S]}
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from .. import optim
from . import encdec, lm
from .config import ArchConfig, InputShape
from .parallel import ParallelContext


class ModelBundle(NamedTuple):
    cfg: ArchConfig
    init: Callable
    loss_fn: Callable
    train_step: Callable
    prefill: Callable
    decode_step: Callable
    init_cache: Callable
    input_specs: Callable
    optimizer: optim.Optimizer


def _xent(logits: jax.Array, targets: jax.Array, z_loss: float):
    """Token-mean cross entropy with optional z-loss, in f32."""
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    loss = jnp.mean(logz - gold)
    if z_loss:
        loss = loss + z_loss * jnp.mean(jnp.square(logz))
    return loss


def build_model(cfg: ArchConfig, ctx: ParallelContext = ParallelContext(),
                *, attention_impl: str = "ref",
                window_override: Optional[int] = None) -> ModelBundle:
    window = window_override if window_override is not None else cfg.sliding_window
    optimizer = optim.get_optimizer(cfg.optimizer, cfg.learning_rate)
    is_audio = cfg.family == "audio"
    is_vlm = cfg.family == "vlm"

    # -- init ---------------------------------------------------------------
    def init(rng):
        if is_audio:
            return encdec.init_encdec(rng, cfg)
        return lm.init_lm(rng, cfg)

    # -- loss ---------------------------------------------------------------
    def loss_fn(params, batch):
        if is_audio:
            enc_out = encdec.encode(params, cfg, batch["frames"], ctx,
                                    impl=attention_impl)
            logits = encdec.decode_train(params, cfg, batch["tokens"], enc_out,
                                         ctx, impl=attention_impl)
            loss = _xent(logits, batch["targets"], cfg.z_loss)
            return loss, {"loss": loss, "aux_loss": jnp.zeros(())}
        image_embeds = batch.get("image_embeds") if is_vlm else None
        out = lm.lm_forward(params, cfg, ctx, batch["tokens"],
                            image_embeds=image_embeds, impl=attention_impl)
        logits = out.logits
        if is_vlm and image_embeds is not None:
            logits = logits[:, image_embeds.shape[1]:]
        loss = _xent(logits, batch["targets"], cfg.z_loss) + out.aux_loss
        return loss, {"loss": loss, "aux_loss": out.aux_loss}

    # -- train step ----------------------------------------------------------
    def _grads(params, batch):
        mb = cfg.microbatches
        if mb <= 1:
            return jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        # gradient accumulation: scan over microbatch slices of the batch
        # (peak activation memory / mb, identical mean gradient)
        split = jax.tree_util.tree_map(
            lambda x: x.reshape((mb, x.shape[0] // mb) + x.shape[1:]), batch)

        def body(acc, micro):
            (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params,
                                                                  micro)
            acc_g, acc_l = acc
            acc_g = jax.tree_util.tree_map(
                lambda a, b: a + b.astype(a.dtype) / mb, acc_g, g)
            return (acc_g, acc_l + l / mb), m

        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, loss), ms = jax.lax.scan(body, (zeros, jnp.zeros(())), split)
        metrics = jax.tree_util.tree_map(lambda x: x[-1], ms)
        metrics["loss"] = loss
        return (loss, metrics), grads

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = _grads(params, batch)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = optim.apply_updates(params, updates)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree_util.tree_leaves(grads)))
        metrics = dict(metrics, grad_norm=gnorm)
        return params, opt_state, metrics

    # -- prefill ---------------------------------------------------------------
    def prefill(params, batch):
        if is_audio:
            enc_out = encdec.encode(params, cfg, batch["frames"], ctx,
                                    impl=attention_impl)
            logits = encdec.decode_train(params, cfg, batch["tokens"], enc_out,
                                         ctx, impl=attention_impl)
            cache = encdec.build_decode_cache(
                params, cfg, enc_out, cache_len=batch["tokens"].shape[1], ctx=ctx)
            return logits[:, -1:], cache
        image_embeds = batch.get("image_embeds") if is_vlm else None
        out = lm.lm_forward(params, cfg, ctx, batch["tokens"],
                            image_embeds=image_embeds, impl=attention_impl,
                            window=window, collect_cache=True)
        return out.logits[:, -1:], out.cache

    # -- decode ----------------------------------------------------------------
    def decode_step(params, cache, tokens, pos):
        if is_audio:
            return encdec.decode_step(params, cfg, cache, tokens, pos, ctx)
        return lm.lm_decode_step(params, cfg, ctx, cache, tokens, pos,
                                 window=window)

    def init_cache(batch_size: int, cache_len: int,
                   use_window: Optional[int] = None):
        w = use_window if use_window is not None else window
        if is_audio:
            # cross K/V stub shapes (encoder output is required in practice;
            # dry-run uses ShapeDtypeStructs via eval_shape of this function)
            frames = jnp.zeros((batch_size, cfg.encoder_frames, cfg.d_model),
                               jnp.dtype(cfg.dtype))
            return encdec.EncDecCache(
                self_kv=encdec.AttnCache(
                    k=jnp.zeros((cfg.n_layers, batch_size, cache_len,
                                 cfg.n_kv_heads, cfg.hd), jnp.dtype(cfg.dtype)),
                    v=jnp.zeros((cfg.n_layers, batch_size, cache_len,
                                 cfg.n_kv_heads, cfg.hd), jnp.dtype(cfg.dtype))),
                cross_k=jnp.zeros((cfg.n_layers, batch_size, cfg.encoder_frames,
                                   cfg.n_kv_heads, cfg.hd), jnp.dtype(cfg.dtype)),
                cross_v=jnp.zeros((cfg.n_layers, batch_size, cfg.encoder_frames,
                                   cfg.n_kv_heads, cfg.hd), jnp.dtype(cfg.dtype)))
        return lm.init_cache(cfg, batch_size, cache_len, window=w)

    # -- input specs for the dry-run ------------------------------------------
    def input_specs(shape: InputShape, *, for_decode_window: Optional[int] = None):
        B, S = shape.global_batch, shape.seq_len
        ti = jnp.int32
        if shape.kind == "train":
            batch = {"tokens": jax.ShapeDtypeStruct((B, S), ti),
                     "targets": jax.ShapeDtypeStruct((B, S), ti)}
            if is_vlm:
                n_img = cfg.num_image_tokens
                batch["tokens"] = jax.ShapeDtypeStruct((B, S - n_img), ti)
                batch["targets"] = jax.ShapeDtypeStruct((B, S - n_img), ti)
                batch["image_embeds"] = jax.ShapeDtypeStruct(
                    (B, n_img, cfg.d_model), jnp.dtype(cfg.dtype))
            if is_audio:
                batch["frames"] = jax.ShapeDtypeStruct(
                    (B, cfg.encoder_frames, cfg.d_model), jnp.dtype(cfg.dtype))
            return batch
        if shape.kind == "prefill":
            batch = {"tokens": jax.ShapeDtypeStruct((B, S), ti)}
            if is_vlm:
                n_img = cfg.num_image_tokens
                batch["tokens"] = jax.ShapeDtypeStruct((B, S - n_img), ti)
                batch["image_embeds"] = jax.ShapeDtypeStruct(
                    (B, n_img, cfg.d_model), jnp.dtype(cfg.dtype))
            if is_audio:
                batch["frames"] = jax.ShapeDtypeStruct(
                    (B, cfg.encoder_frames, cfg.d_model), jnp.dtype(cfg.dtype))
            return batch
        # decode: (cache, tokens, pos)
        w = for_decode_window if for_decode_window is not None else window
        cache = jax.eval_shape(lambda: init_cache(B, S, use_window=w))
        return {"cache": cache,
                "tokens": jax.ShapeDtypeStruct((B, 1), ti),
                "pos": jax.ShapeDtypeStruct((), ti)}

    return ModelBundle(cfg=cfg, init=init, loss_fn=loss_fn,
                       train_step=train_step, prefill=prefill,
                       decode_step=decode_step, init_cache=init_cache,
                       input_specs=input_specs, optimizer=optimizer)
