"""Parallelism context and sharding rules for the model zoo.

Mesh axes: ``("data", "model")`` single pod, ``("pod", "data", "model")``
multi-pod.  Logical placement:

  * batch                      -> ("pod", "data")      (DP)
  * attention heads / kv heads -> "model"              (TP)
  * FFN hidden / experts       -> "model"              (TP / EP)
  * vocab                      -> "model"
  * d_model rows of weights    -> "data"               (FSDP / ZeRO-3)
  * KV-cache sequence (B == 1) -> "data"               (context sharding)

When ``mesh is None`` (unit tests / single host) everything is a no-op and
the MoE layer uses its collective-free ragged path.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParallelContext:
    mesh: Optional[Mesh] = None

    @property
    def batch_axes(self):
        if self.mesh is None:
            return None
        return tuple(a for a in ("pod", "data") if a in self.mesh.axis_names)

    @property
    def model_axis(self):
        if self.mesh is None or "model" not in self.mesh.axis_names:
            return None
        return "model"

    @property
    def data_axis(self):
        if self.mesh is None or "data" not in self.mesh.axis_names:
            return None
        return "data"

    def spec(self, *axes) -> P:
        """PartitionSpec with axes filtered against the mesh."""
        if self.mesh is None:
            return P()
        names = self.mesh.axis_names

        def ok(a):
            if a is None:
                return None
            if isinstance(a, tuple):
                t = tuple(x for x in a if x in names)
                return t if t else None
            return a if a in names else None

        return P(*[ok(a) for a in axes])

    def shard(self, x, *axes):
        """with_sharding_constraint if a mesh is active, else identity.

        Axes that do not divide the corresponding dimension are dropped
        (e.g. 12 attention heads cannot shard over a 16-way model axis)."""
        if self.mesh is None:
            return x
        spec = self.spec(*axes)
        fixed = []
        for i, a in enumerate(spec):
            if a is None or i >= x.ndim:
                fixed.append(None if i < x.ndim else None)
                continue
            names = a if isinstance(a, tuple) else (a,)
            size = 1
            for nm in names:
                size *= self.mesh.shape[nm]
            fixed.append(a if x.shape[i] % size == 0 else None)
        fixed += [None] * (x.ndim - len(fixed))
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*fixed[:x.ndim])))

    def named_sharding(self, *axes) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(*axes))


# ---------------------------------------------------------------------------
# parameter sharding rules
# ---------------------------------------------------------------------------

def param_spec(path: str, shape: tuple, ctx: ParallelContext) -> P:
    """Sharding rule for a parameter, keyed on its tree path.

    Conventions (leading ``n_groups`` scan axis is never sharded):
      embed/lm_head: vocab -> model, d_model -> data
      attention projections: d_model -> data, heads*hd -> model
      FFN: d_model -> data, hidden -> model
      experts: expert -> model, d_model -> data
      norms / small vectors: replicated
    """
    if ctx.mesh is None:
        return P()
    last2 = [None] * max(0, len(shape) - 2)

    def rule(*axes):
        pad = [None] * (len(shape) - len(axes))
        return ctx.spec(*pad, *axes)

    if "embed" in path or "lm_head" in path:
        # [vocab, d] or [d, vocab]
        if shape[-2] >= shape[-1]:
            return rule("model", "data")
        return rule("data", "model")
    if any(k in path for k in ("wq", "wk", "wv")):
        return rule("data", "model")
    if "wo" in path:
        return rule("model", "data")
    if "experts" in path:
        # [E, d, ff] or [E, ff, d]
        if len(shape) >= 3:
            return ctx.spec(*([None] * (len(shape) - 3)), "model", "data", None)
        return rule(None, None)
    if "router" in path:
        return rule(None, None)
    if any(k in path for k in ("w_gate", "w_up")):
        return rule("data", "model")
    if "w_down" in path:
        return rule("model", "data")
    if "in_proj" in path or "x_proj" in path or "up_proj" in path:
        return rule("data", "model")
    if "out_proj" in path or "down_proj" in path or "dt_proj" in path:
        return rule("model", "data")
    if any(k in path for k in ("conv", "A_log", "D_skip", "dt_bias")):
        return rule(*([None] * min(2, len(shape))))
    if len(shape) >= 2 and shape[-1] >= 1024 and shape[-2] >= 1024:
        return rule("data", "model")
    return P(*([None] * len(shape)))


def shard_params_tree(params, ctx: ParallelContext):
    """Attach NamedShardings to a parameter pytree (by tree path)."""
    if ctx.mesh is None:
        return jax.tree_util.tree_map(lambda x: None, params)

    def visit(path, leaf):
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        return NamedSharding(ctx.mesh, param_spec(name, leaf.shape, ctx))

    return jax.tree_util.tree_map_with_path(visit, params)
