from .config import INPUT_SHAPES, ArchConfig, InputShape, MoEConfig
from .model import ModelBundle, build_model
from .parallel import ParallelContext

__all__ = ["ArchConfig", "MoEConfig", "InputShape", "INPUT_SHAPES",
           "ModelBundle", "build_model", "ParallelContext"]
