"""Compatibility shims across the jax versions this repo runs on.

The container pins an older jax (0.4.x) than some of the sharding helpers
were written against; everything version-dependent funnels through here so
call sites stay clean:

  * ``jax.sharding.AxisType`` and the ``axis_types=`` kwarg of
    ``jax.make_mesh`` only exist in newer jax.  :func:`make_mesh` forwards
    them when available and silently builds a plain mesh otherwise (older
    jax meshes are implicitly all-auto, which is exactly what the
    ``Auto``-typed call sites request).
"""
from __future__ import annotations

import jax

HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")


def make_mesh(shape, axis_names, *, auto_axes: bool = True):
    """``jax.make_mesh`` with ``axis_types=(AxisType.Auto, ...)`` on jax
    versions that support it, and a plain mesh on those that don't."""
    if HAS_AXIS_TYPE and auto_axes:
        return jax.make_mesh(
            shape, axis_names,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names))
    return jax.make_mesh(shape, axis_names)
