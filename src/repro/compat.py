"""Compatibility shims across the jax versions this repo runs on.

The container pins an older jax (0.4.x) than some of the sharding helpers
were written against; everything version-dependent funnels through here so
call sites stay clean:

  * ``jax.sharding.AxisType`` and the ``axis_types=`` kwarg of
    ``jax.make_mesh`` only exist in newer jax.  :func:`make_mesh` forwards
    them when available and silently builds a plain mesh otherwise (older
    jax meshes are implicitly all-auto, which is exactly what the
    ``Auto``-typed call sites request).
  * ``shard_map`` moved from ``jax.experimental.shard_map`` to ``jax`` (and
    dropped ``check_rep``).  :func:`shard_map` calls whichever exists.
"""
from __future__ import annotations

import jax

HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")


def shard_map(f, mesh, *, in_specs, out_specs):
    """``jax.shard_map`` on new jax, ``jax.experimental.shard_map`` on 0.4.x.

    The 0.4.x path passes ``check_rep=False``: the repo's sharded programs
    are strictly lane-local (no collectives), which the replication checker
    of that era mis-handles around closed-over constants inside ``scan``.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs)
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)


def make_mesh(shape, axis_names, *, auto_axes: bool = True):
    """``jax.make_mesh`` with ``axis_types=(AxisType.Auto, ...)`` on jax
    versions that support it, and a plain mesh on those that don't."""
    if HAS_AXIS_TYPE and auto_axes:
        return jax.make_mesh(
            shape, axis_names,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names))
    return jax.make_mesh(shape, axis_names)
