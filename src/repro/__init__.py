"""repro — Generalized AsyncSGD stochastic-networks framework.

Subpackages are imported lazily; see README.md for the map.
"""

__version__ = "1.0.0"
