"""Minimal optax-style optimizers (the container ships no optax).

``init(params) -> state`` ; ``update(grads, state, params) -> (updates, state)``
where ``updates`` are *subtracted* via :func:`apply_updates`.

``adafactor`` implements factored second moments (Shazeer & Stern) so the
>=34B assigned configs carry O(rows + cols) optimizer state instead of
O(rows * cols) — the standard choice for trillion-parameter dry-runs.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable
    name: str = "optimizer"


def apply_updates(params, updates):
    return jax.tree_util.tree_map(lambda p, u: (p - u).astype(p.dtype), params, updates)


def sgd(lr: float) -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params=None):
        return jax.tree_util.tree_map(lambda g: lr * g, grads), state

    return Optimizer(init, update, "sgd")


def momentum(lr: float, beta: float = 0.9) -> Optimizer:
    def init(params):
        return jax.tree_util.tree_map(jnp.zeros_like, params)

    def update(grads, state, params=None):
        new_m = jax.tree_util.tree_map(lambda m, g: beta * m + g, state, grads)
        return jax.tree_util.tree_map(lambda m: lr * m, new_m), new_m

    return Optimizer(init, update, "momentum")


def adamw(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    class State(NamedTuple):
        step: jax.Array
        mu: object
        nu: object

    def init(params):
        z = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return State(jnp.zeros((), jnp.int32),
                     jax.tree_util.tree_map(z, params),
                     jax.tree_util.tree_map(z, params))

    def update(grads, state, params):
        t = state.step + 1
        mu = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                                    state.mu, grads)
        nu = jax.tree_util.tree_map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu, grads)
        c1 = 1 - b1 ** t.astype(jnp.float32)
        c2 = 1 - b2 ** t.astype(jnp.float32)

        def upd(m, v, p):
            step = lr * (m / c1) / (jnp.sqrt(v / c2) + eps)
            if weight_decay:
                step = step + lr * weight_decay * p.astype(jnp.float32)
            return step.astype(p.dtype) if p.dtype == jnp.float32 else step

        updates = jax.tree_util.tree_map(upd, mu, nu, params)
        return updates, State(t, mu, nu)

    return Optimizer(init, update, "adamw")


def adafactor(lr: float = 0.01, eps: float = 1e-30,
              clip_threshold: float = 1.0) -> Optimizer:
    """Factored RMS optimizer: O(r + c) state per (r, c) matrix."""

    class Slot(NamedTuple):
        vr: jax.Array | None  # row accumulator (for >=2D)
        vc: jax.Array | None  # col accumulator
        v: jax.Array | None   # full accumulator (for <2D)

    class State(NamedTuple):
        step: jax.Array
        slots: object

    def _make_slot(p):
        if p.ndim >= 2:
            return Slot(jnp.zeros(p.shape[:-1], jnp.float32),
                        jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                        None)
        return Slot(None, None, jnp.zeros_like(p, dtype=jnp.float32))

    def init(params):
        return State(jnp.zeros((), jnp.int32),
                     jax.tree_util.tree_map(_make_slot, params,
                                            is_leaf=lambda x: isinstance(x, jax.Array)))

    def update(grads, state, params):
        t = state.step + 1
        decay = 1.0 - (t.astype(jnp.float32) + 1.0) ** -0.8

        def upd(slot, g, p):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + eps
            if p.ndim >= 2:
                vr = decay * slot.vr + (1 - decay) * jnp.mean(g2, axis=-1)
                vc = decay * slot.vc + (1 - decay) * jnp.mean(g2, axis=-2)
                denom = (vr / jnp.mean(vr, axis=-1, keepdims=True))[..., None] * vc[..., None, :]
                u = g32 / jnp.sqrt(denom + eps)
                new_slot = Slot(vr, vc, None)
            else:
                v = decay * slot.v + (1 - decay) * g2
                u = g32 / jnp.sqrt(v + eps)
                new_slot = Slot(None, None, v)
            # update clipping (RMS <= clip_threshold)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + eps)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            return lr * u, new_slot

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_s = treedef.flatten_up_to(state.slots)
        outs = [upd(s, g, p) for s, g, p in zip(flat_s, flat_g, flat_p)]
        updates = treedef.unflatten([o[0] for o in outs])
        slots = treedef.unflatten([o[1] for o in outs])
        return updates, State(t, slots)

    return Optimizer(init, update, "adafactor")


def get_optimizer(name: str, lr: float) -> Optimizer:
    if name == "sgd":
        return sgd(lr)
    if name == "momentum":
        return momentum(lr)
    if name == "adamw":
        return adamw(lr)
    if name == "adafactor":
        return adafactor(lr)
    raise ValueError(f"unknown optimizer: {name}")
