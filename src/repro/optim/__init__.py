from .optimizers import (Optimizer, adafactor, adamw, apply_updates,
                         get_optimizer, momentum, sgd)

__all__ = ["Optimizer", "sgd", "momentum", "adamw", "adafactor",
           "apply_updates", "get_optimizer"]
