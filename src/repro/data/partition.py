"""Client data partitioners (Section 5.3.1 / Appendix H.1).

Each partitioner is registered in the ``PARTITIONS`` registry of the
Scenario API (``repro.scenario``), so data layouts are selectable by name
(``PARTITIONS.get("dirichlet")``) next to timing laws, strategies and
objectives — and new ones plug in with ``@partition("name")``.
"""
from __future__ import annotations

import numpy as np

from ..scenario.registry import partition


@partition("iid")
def iid_partition(y: np.ndarray, n_clients: int, seed: int = 0) -> list[np.ndarray]:
    """Uniform shuffle-and-split: identical class mix per client."""
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(y))
    return [np.sort(part) for part in np.array_split(idx, n_clients)]


@partition("dirichlet")
def dirichlet_partition(y: np.ndarray, n_clients: int, alpha: float = 0.2,
                        seed: int = 0, min_size: int = 2) -> list[np.ndarray]:
    """Label-skew partition: per class k, client shares ~ Dir_n(alpha)
    (Yurochkin et al. / Li et al., as used in Section 5.3.1)."""
    rng = np.random.default_rng(seed)
    classes = np.unique(y)
    while True:
        buckets: list[list[int]] = [[] for _ in range(n_clients)]
        for k in classes:
            idx_k = np.flatnonzero(y == k)
            rng.shuffle(idx_k)
            q = rng.dirichlet(np.full(n_clients, alpha))
            cuts = (np.cumsum(q)[:-1] * len(idx_k)).astype(int)
            for j, part in enumerate(np.split(idx_k, cuts)):
                buckets[j].extend(part.tolist())
        if min(len(b) for b in buckets) >= min_size:
            return [np.sort(np.asarray(b)) for b in buckets]


@partition("pathological")
def pathological_partition(y: np.ndarray, n_clients: int,
                           classes_per_client: int = 3,
                           seed: int = 0) -> list[np.ndarray]:
    """Extreme label skew: each client sees only ``classes_per_client`` labels
    (Appendix H.1 'Highly Heterogeneous')."""
    rng = np.random.default_rng(seed)
    classes = np.unique(y)
    assignment = [rng.choice(classes, size=classes_per_client, replace=False)
                  for _ in range(n_clients)]
    # round-robin samples of each class over the clients that own it
    owners: dict[int, list[int]] = {int(k): [] for k in classes}
    for j, ks in enumerate(assignment):
        for k in ks:
            owners[int(k)].append(j)
    for k in classes:  # ensure every class has at least one owner
        if not owners[int(k)]:
            owners[int(k)].append(int(rng.integers(n_clients)))
    buckets: list[list[int]] = [[] for _ in range(n_clients)]
    for k in classes:
        idx_k = np.flatnonzero(y == k)
        rng.shuffle(idx_k)
        own = owners[int(k)]
        for t, i in enumerate(idx_k):
            buckets[own[t % len(own)]].append(int(i))
    return [np.sort(np.asarray(b)) for b in buckets]
