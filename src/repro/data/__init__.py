from .emnist import emnist_cache_path, load_emnist
from .partition import dirichlet_partition, iid_partition, pathological_partition
from .synthetic import (make_language_modeling_dataset,
                        make_synthetic_image_dataset, train_test_split)

# dataset builders by DataSpec name: (num_classes, samples_per_class, seed)
# -> ImageDataset.  Registered beside the partitioners so a Scenario's
# DataSpec can name any of them declaratively.
DATASETS = {
    "synthetic": lambda num_classes, samples_per_class, seed:
        make_synthetic_image_dataset(num_classes=num_classes,
                                     samples_per_class=samples_per_class,
                                     seed=seed),
    "emnist": lambda num_classes, samples_per_class, seed:
        load_emnist(num_classes=num_classes,
                    samples_per_class=samples_per_class, seed=seed),
}


def get_dataset(name: str, *, num_classes: int, samples_per_class: int,
                seed: int):
    """Build a registered dataset; unknown names list the options."""
    builder = DATASETS.get(name)
    if builder is None:
        raise ValueError(f"unknown dataset: {name!r}; registered datasets: "
                         f"{sorted(DATASETS)}")
    return builder(num_classes, samples_per_class, seed)


__all__ = [
    "make_synthetic_image_dataset", "make_language_modeling_dataset",
    "train_test_split", "load_emnist", "emnist_cache_path",
    "DATASETS", "get_dataset",
    "dirichlet_partition", "iid_partition", "pathological_partition",
]
