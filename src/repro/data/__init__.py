from .partition import dirichlet_partition, iid_partition, pathological_partition
from .synthetic import (make_language_modeling_dataset,
                        make_synthetic_image_dataset, train_test_split)

__all__ = [
    "make_synthetic_image_dataset", "make_language_modeling_dataset",
    "train_test_split",
    "dirichlet_partition", "iid_partition", "pathological_partition",
]
