"""Download-free EMNIST-style dataset loader.

The container has no internet, so this loader never downloads anything.
Resolution order:

  1. a **local cache**: an ``.npz`` file with arrays ``x`` (``[N, 28, 28]``
     or ``[N, 28, 28, 1]``, uint8 or float) and ``y`` (``[N]`` integer
     labels) at ``$REPRO_EMNIST_PATH`` or ``~/.cache/repro/emnist.npz`` —
     e.g. a converted EMNIST-Balanced split dropped in by the user;
  2. a **deterministic synthetic fallback** with exactly the EMNIST tensor
     format (28x28 grayscale, float32 in [0, 1], int32 labels): the
     class-structured glyph generator of ``repro.data.synthetic`` seeded
     off this module's namespace, so the fallback is stable across runs
     and distinct from the ``"synthetic"`` dataset.

Either way the result is an :class:`repro.data.synthetic.ImageDataset`
subsampled to ``num_classes`` x ``samples_per_class`` — the same shapes and
dtypes on every machine, which is what lets ``DataSpec(dataset="emnist")``
drive ``ScenarioSuite.run(mode="train")`` end-to-end in CI.
"""
from __future__ import annotations

import os
from typing import Optional

import numpy as np

from .synthetic import ImageDataset, make_synthetic_image_dataset

_IMAGE_SIZE = 28
_FALLBACK_SEED_OFFSET = 0xE3157  # "emnist"-namespace: differ from synthetic


def emnist_cache_path() -> str:
    """The resolved local cache location (the file need not exist)."""
    env = os.environ.get("REPRO_EMNIST_PATH")
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "emnist.npz")


def _subsample(x: np.ndarray, y: np.ndarray, num_classes: int,
               samples_per_class: int, seed: int) -> ImageDataset:
    """Deterministic class-balanced subsample in the canonical format."""
    rng = np.random.default_rng(seed)
    labels = np.unique(y)
    if len(labels) < num_classes:
        raise ValueError(
            f"cached EMNIST file has {len(labels)} classes, "
            f"DataSpec asks for {num_classes}")
    keep = rng.permutation(labels)[:num_classes]
    xs, ys = [], []
    for new_c, c in enumerate(sorted(keep)):
        idx = np.flatnonzero(y == c)
        if len(idx) < samples_per_class:
            raise ValueError(
                f"class {c} has only {len(idx)} samples, need "
                f"{samples_per_class}")
        pick = rng.permutation(idx)[:samples_per_class]
        xs.append(x[pick])
        ys.append(np.full(samples_per_class, new_c, dtype=np.int32))
    x_out = np.concatenate(xs).astype(np.float32)
    if x_out.max() > 1.5:  # uint8-scaled cache
        x_out = x_out / 255.0
    if x_out.ndim == 3:
        x_out = x_out[..., None]
    y_out = np.concatenate(ys)
    perm = rng.permutation(len(y_out))
    return ImageDataset(x=x_out[perm], y=y_out[perm],
                        num_classes=num_classes)


def load_emnist(num_classes: int = 47, samples_per_class: int = 40,
                seed: int = 0, path: Optional[str] = None) -> ImageDataset:
    """EMNIST-format dataset: local ``.npz`` cache if present, else the
    deterministic synthetic fallback (see the module docstring)."""
    path = emnist_cache_path() if path is None else path
    if os.path.exists(path):
        with np.load(path) as npz:
            x = np.asarray(npz["x"])
            y = np.asarray(npz["y"])
        if x.ndim not in (3, 4) or x.shape[1:3] != (_IMAGE_SIZE, _IMAGE_SIZE):
            raise ValueError(
                f"{path}: expected [N, 28, 28(, 1)] images, got {x.shape}")
        return _subsample(x, y, num_classes, samples_per_class, seed)
    return make_synthetic_image_dataset(
        num_classes=num_classes, samples_per_class=samples_per_class,
        image_size=_IMAGE_SIZE, seed=seed + _FALLBACK_SEED_OFFSET)
