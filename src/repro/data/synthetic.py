"""Synthetic, offline stand-ins for the paper's datasets.

The container has no torchvision/internet, so EMNIST/KMNIST are replaced by
procedurally generated class-structured image datasets with the same tensor
format (28x28 grayscale, 47/10 balanced classes).  Each class owns a smooth
random "prototype" field plus a stroke skeleton; samples are random
translations/scalings of the prototype with additive noise — hard enough
that a linear model underfits, easy enough that the paper's small CNN
separates them, which is all the FL experiments need (they compare *relative*
convergence speed of scheduling strategies, not absolute accuracy).

Also provides a token dataset for the LM-based examples.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np


class ImageDataset(NamedTuple):
    x: np.ndarray  # [N, H, W, 1] float32 in [0, 1]
    y: np.ndarray  # [N] int32
    num_classes: int


def _class_prototype(rng: np.random.Generator, size: int) -> np.ndarray:
    """Smooth random field + random stroke segments — a class 'glyph'."""
    # low-frequency random field
    freqs = rng.normal(size=(4, 4))
    yy, xx = np.mgrid[0:size, 0:size] / size * 2 * np.pi
    field = np.zeros((size, size))
    for i in range(4):
        for j in range(4):
            field += freqs[i, j] * np.sin((i + 1) * yy + (j + 1) * xx + rng.uniform(0, 2 * np.pi))
    field = (field - field.min()) / (np.ptp(field) + 1e-9)
    # stroke skeleton: 3 random line segments, thickened
    img = 0.3 * field
    for _ in range(3):
        x0, y0, x1, y1 = rng.uniform(4, size - 4, size=4)
        t = np.linspace(0, 1, 64)
        xs = (x0 + t * (x1 - x0)).astype(int)
        ys = (y0 + t * (y1 - y0)).astype(int)
        for dx in (-1, 0, 1):
            for dy in (-1, 0, 1):
                img[np.clip(ys + dy, 0, size - 1), np.clip(xs + dx, 0, size - 1)] = 1.0
    return img.astype(np.float32)


def make_synthetic_image_dataset(
    num_classes: int = 47,
    samples_per_class: int = 200,
    image_size: int = 28,
    seed: int = 0,
    noise: float = 0.15,
) -> ImageDataset:
    rng = np.random.default_rng(seed)
    protos = [_class_prototype(rng, image_size) for _ in range(num_classes)]
    xs, ys = [], []
    for c, proto in enumerate(protos):
        for _ in range(samples_per_class):
            shift = rng.integers(-3, 4, size=2)
            img = np.roll(proto, shift, axis=(0, 1))
            scale = rng.uniform(0.7, 1.3)
            img = np.clip(img * scale + rng.normal(0, noise, img.shape), 0, 1)
            xs.append(img.astype(np.float32))
            ys.append(c)
    x = np.stack(xs)[..., None]
    y = np.asarray(ys, dtype=np.int32)
    perm = rng.permutation(len(y))
    return ImageDataset(x=x[perm], y=y[perm], num_classes=num_classes)


def train_test_split(ds: ImageDataset, test_fraction: float = 0.2,
                     seed: int = 0) -> tuple[ImageDataset, ImageDataset]:
    """Split one generated dataset into train/test (same class prototypes —
    the test set is 'unseen samples', matching the paper's protocol)."""
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(ds.y))
    cut = int(len(idx) * (1 - test_fraction))
    tr, te = idx[:cut], idx[cut:]
    return (ImageDataset(ds.x[tr], ds.y[tr], ds.num_classes),
            ImageDataset(ds.x[te], ds.y[te], ds.num_classes))


class TokenDataset(NamedTuple):
    tokens: np.ndarray  # [N, S+1] int32 (inputs = [:, :-1], targets = [:, 1:])
    vocab: int


def make_language_modeling_dataset(
    num_sequences: int = 2048,
    seq_len: int = 256,
    vocab: int = 4096,
    seed: int = 0,
) -> TokenDataset:
    """Markov-chain token streams: learnable structure for LM smoke training."""
    rng = np.random.default_rng(seed)
    # sparse stochastic transition structure: each token has 8 likely successors
    succ = rng.integers(0, vocab, size=(vocab, 8))
    toks = np.empty((num_sequences, seq_len + 1), dtype=np.int32)
    state = rng.integers(0, vocab, size=num_sequences)
    for t in range(seq_len + 1):
        toks[:, t] = state
        choose = rng.integers(0, 8, size=num_sequences)
        nxt = succ[state, choose]
        # 10% uniform noise
        noise_mask = rng.random(num_sequences) < 0.1
        nxt = np.where(noise_mask, rng.integers(0, vocab, size=num_sequences), nxt)
        state = nxt
    return TokenDataset(tokens=toks, vocab=vocab)
