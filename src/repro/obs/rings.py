"""On-device telemetry rings for the event engine and the fused trainer.

A ring is a NamedTuple of fixed-size device arrays plus a monotone write
counter, threaded through a ``lax.scan`` as extra carry state.  Appends
write at ``count % capacity`` (wraparound keeps the most recent records)
and are **bitwise non-invasive** by construction: they consume no
randomness and never feed back into the simulation state, so a traced
run equals an untraced run exactly (property-tested like the padding
contract, ``tests/test_obs.py``).

Capacity 0 is the statically-disabled channel: the arrays are
zero-length, :func:`_append` is a Python-level no-op, and XLA dead-code
eliminates the carry — the untraced program is unchanged.

Channels:

  * :class:`EventRing` — one record per *event* (service completion) of
    the closed network: completion clock, the station the task completed
    at (``repro.core.events._station_index`` layout: down_i / comp_i /
    up_i / CS), the post-transition station, pre-event phase, task slot,
    client, relative delay, and the update flag.  Enough to reconstruct
    the full simulated timeline (``repro.obs.trace``) and the empirical
    throughput / staleness / occupancy the drift monitors compare
    against the closed forms (``repro.obs.drift``).
  * :class:`UpdateRing` — one record per *applied* model update of the
    fused trainer: apply clock, client, staleness (relative delay),
    gradient norm and snapshot age.

Decoding is host-side (:func:`decode`): wraparound is unrolled so the
records come back in chronological order, with the number of dropped
(overwritten) records reported.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np


class EventRing(NamedTuple):
    """Per-event channel (all arrays ``[capacity]``; ``count`` scalar)."""

    time: jax.Array        # completion clock (f64)
    station: jax.Array     # station completed at (pre-event, [3n+1] layout)
    station_to: jax.Array  # station the task moved to
    kind: jax.Array        # pre-event phase (DOWN/COMP_SERV/UP/CS_SERV)
    slot: jax.Array        # task-table row
    client: jax.Array      # owning client (class index on the class engine)
    delay: jax.Array       # relative delay round - dispatch_round
    update: jax.Array      # 1 iff this event applied a model update
    count: jax.Array       # total records ever appended (monotone)


class UpdateRing(NamedTuple):
    """Per-applied-update channel of the fused trainer."""

    time: jax.Array          # apply clock (f64)
    client: jax.Array        # gradient's client C_k
    staleness: jax.Array     # relative delay of the applied gradient
    grad_norm: jax.Array     # global L2 norm of the applied gradient
    snapshot_age: jax.Array  # apply clock minus the stale snapshot's clock
    count: jax.Array


_EVENT_DTYPES = {"time": jnp.float64, "station": jnp.int32,
                 "station_to": jnp.int32, "kind": jnp.int32,
                 "slot": jnp.int32, "client": jnp.int32,
                 "delay": jnp.int32, "update": jnp.int32}
_UPDATE_DTYPES = {"time": jnp.float64, "client": jnp.int32,
                  "staleness": jnp.int32, "grad_norm": jnp.float64,
                  "snapshot_age": jnp.float64}


def event_ring_init(capacity: int) -> EventRing:
    """An empty event ring (``capacity == 0`` disables the channel)."""
    cap = int(capacity)
    cols = {k: jnp.zeros((cap,), dt) for k, dt in _EVENT_DTYPES.items()}
    return EventRing(count=jnp.zeros((), jnp.int32), **cols)


def update_ring_init(capacity: int) -> UpdateRing:
    """An empty update ring (``capacity == 0`` disables the channel)."""
    cap = int(capacity)
    cols = {k: jnp.zeros((cap,), dt) for k, dt in _UPDATE_DTYPES.items()}
    return UpdateRing(count=jnp.zeros((), jnp.int32), **cols)


def _append(ring, valid: Optional[jax.Array], cols: dict):
    """Write one record at ``count % capacity`` and bump the counter.

    ``valid`` (a traced bool, e.g. "this update landed before the
    horizon") gates the write and the bump; ``None`` appends
    unconditionally.  Static no-op at capacity 0.
    """
    cap = ring.time.shape[0]
    if cap == 0:
        return ring
    idx = ring.count % cap
    upd = {}
    for name, value in cols.items():
        col = getattr(ring, name)
        v = jnp.asarray(value).astype(col.dtype)
        if valid is not None:
            v = jnp.where(valid, v, col[idx])
        upd[name] = col.at[idx].set(v)
    inc = 1 if valid is None else jnp.asarray(valid).astype(jnp.int32)
    return ring._replace(count=ring.count + inc, **upd)


def event_ring_append(ring: EventRing, *, time, station, station_to, kind,
                      slot, client, delay, update,
                      valid: Optional[jax.Array] = None) -> EventRing:
    return _append(ring, valid, {
        "time": time, "station": station, "station_to": station_to,
        "kind": kind, "slot": slot, "client": client, "delay": delay,
        "update": update})


def update_ring_append(ring: UpdateRing, *, time, client, staleness,
                       grad_norm, snapshot_age,
                       valid: Optional[jax.Array] = None) -> UpdateRing:
    return _append(ring, valid, {
        "time": time, "client": client, "staleness": staleness,
        "grad_norm": grad_norm, "snapshot_age": snapshot_age})


def decode(ring) -> dict:
    """Host-side view of one ring (one lane — index any lane axes first).

    Returns ``{column: np.ndarray}`` in chronological order plus
    ``count`` (records ever appended), ``capacity`` and ``dropped``
    (records overwritten by wraparound).
    """
    count = int(np.asarray(ring.count))
    cap = int(ring.time.shape[0])
    out: dict = {}
    for name in ring._fields:
        if name == "count":
            continue
        col = np.asarray(getattr(ring, name))
        if count <= cap:
            col = col[:count]
        else:
            col = np.roll(col, -(count % cap), axis=0)
        out[name] = col
    out["count"] = count
    out["capacity"] = cap
    out["dropped"] = max(0, count - cap)
    return out


def decode_lane(ring, lane: int) -> dict:
    """:func:`decode` of one lane of a lane-stacked ring."""
    return decode(jax.tree_util.tree_map(lambda x: x[lane], ring))
