"""Closed-form drift monitors: ring empirics vs the product-form theory.

The paper's planning surface (``repro.core.batched``) predicts the
stationary behaviour of the closed queueing network in closed form —
throughput ``lambda(p, m)`` (Thm 1), the expected relative delays
``E0[R_i]`` (Thm 2) and the task-conservation invariant (the closed
network holds exactly ``m`` tasks at all times).  The telemetry rings
(``repro.obs.rings``) record what the event engine *actually did*.  This
module closes the loop: :func:`drift_report` estimates the same
quantities from a decoded ring and flags any that leave the configured
relative-tolerance band around the prediction.

A drift breach means one of three things, all worth an alarm:

  * the simulated scale is too small for stationarity (tolerance or
    warmup too tight for the run length — a *configuration* problem);
  * the engine and the closed forms have diverged (a *correctness*
    problem: this is the check CI runs on every smoke trace);
  * the scenario left the closed forms' domain (non-exponential law:
    the throughput/staleness checks are skipped — Thm 1/2 are
    product-form results — and only conservation is asserted).

Everything here is host-side numpy on decoded rings; nothing is traced.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

__all__ = ["predict", "empirical", "drift_report"]

_TINY = 1e-12


def predict(params, m, *, m_max: Optional[int] = None) -> dict:
    """Closed-form predictions for one client network at concurrency ``m``.

    ``throughput`` and the per-client relative delays ``delays``
    (``E0[D_i] = p_i E0[R_i]``, Thm 2) come from the padded product-form
    kernels of ``repro.core.batched``; ``occupancy`` is the conservation
    constant ``m``.  NOT predicted: the update-weighted mean staleness —
    it is identically ``m - 1`` for any law (each completion sees the
    other ``m - 1`` in-flight tasks finish exactly once in between), so
    the staleness drift check compares the per-client *profile* instead.
    Valid for the exponential law (see module docstring).
    """
    import jax.numpy as jnp

    from ..core.batched import (expected_relative_delay_padded,
                                throughput_padded)
    from ..core.buzen import log_normalizing_constants

    mx = int(m) if m_max is None else int(m_max)
    logZ = log_normalizing_constants(params, mx)
    thr = float(throughput_padded(logZ, jnp.asarray(int(m))))
    delays = np.asarray(expected_relative_delay_padded(
        params, jnp.asarray(int(m)), logZ, mx), dtype=np.float64)
    return {"throughput": thr, "delays": [float(d) for d in delays],
            "occupancy": float(int(m))}


def empirical(decoded: dict, *, n: Optional[int] = None,
              burn: float = 0.25) -> dict:
    """Ring estimates of the predicted quantities.

    ``decoded`` is one lane's :func:`repro.obs.rings.decode` output.  The
    first ``burn`` fraction of recorded *update* events is discarded
    (transient suppression — the ring usually starts at the simulation's
    own warmup, but a wrapped ring starts wherever it wrapped).
    ``delays`` is the per-client ``E0[D_i]`` estimator ``(updates from
    i / updates) * mean(R | client i)`` — i.e. client ``i``'s share of
    the total recorded staleness — sized by ``n`` (default: largest
    client index seen + 1).  Keys missing when inestimable (fewer than
    two post-burn updates).
    """
    t = np.asarray(decoded["time"], dtype=np.float64)
    upd = np.asarray(decoded["update"]) != 0
    out: dict = {}
    if t.size:
        occ = _mean_total_occupancy(decoded)
        if occ is not None:
            out["occupancy"] = occ
    ut = t[upd]
    ud = np.asarray(decoded["delay"], dtype=np.float64)[upd]
    uc = np.asarray(decoded["client"])[upd]
    skip = int(len(ut) * float(burn))
    ut, ud, uc = ut[skip:], ud[skip:], uc[skip:]
    if len(ut) >= 2 and ut[-1] > ut[0]:
        out["throughput"] = float((len(ut) - 1) / (ut[-1] - ut[0]))
        n_eff = int(uc.max()) + 1 if n is None else int(n)
        # contract: allow(raw-reduction): host-side numpy on decoded telemetry — the traced path never sees it
        d = np.bincount(uc, weights=ud, minlength=n_eff) / len(ut)
        out["delays"] = [float(v) for v in d[:n_eff]]
    return out


def _mean_total_occupancy(decoded: dict) -> Optional[float]:
    """Time-averaged number of in-flight tasks reconstructed from the ring.

    Each event row moves task ``slot`` from ``station`` to ``station_to``
    at ``time``; between consecutive events of a slot the task sits at the
    later event's *from*-station, and that from-station also extends back
    past the window start (it is wherever the previous — unrecorded —
    event left the task).  Integrating the per-slot coverage over the
    window therefore counts every slot that produced at least one event:
    for a healthy engine this equals ``m`` exactly (task conservation),
    and any gap means events were lost or mis-attributed.
    """
    t = np.asarray(decoded["time"], dtype=np.float64)
    slots = np.asarray(decoded["slot"])
    if t.size < 2:
        return None
    t0, t1 = float(t[0]), float(t[-1])
    if int(decoded.get("dropped", 0)) == 0:
        t0 = 0.0  # full history: the window opens at the simulation start
    if not t1 > t0:
        return None
    covered = 0.0
    for j in np.unique(slots):
        tj = t[slots == j]
        # [t0, first event]: the from-station span reaching back into the
        # window; [last event, t1]: the station_to tail
        covered += (min(float(tj[0]), t1) - t0) + (t1 - min(float(tj[-1]), t1))
        if len(tj) > 1:
            covered += float(tj[-1] - tj[0])
    return covered / (t1 - t0)


def drift_report(decoded: dict, *, params=None, m: Optional[int] = None,
                 predictions: Optional[dict] = None,
                 law: str = "exponential", tolerance: float = 0.25,
                 burn: float = 0.25) -> dict:
    """Compare one lane's ring against the closed forms.

    Predictions come from ``predictions`` (a prior :func:`predict` output,
    e.g. re-checking an exported trace file) or are computed from
    ``(params, m)``.  Non-exponential laws keep only the conservation
    check.  Returns a JSON-friendly report::

        {"ok": bool, "law": str, "tolerance": float,
         "checks": [{"metric", "empirical", "predicted",
                     "rel_err", "tol", "ok"}, ...]}

    Check semantics: ``throughput`` — plain relative error;
    ``staleness`` — total-variation distance between the per-client
    delay profiles, ``sum_i |D_emp_i - D_pred_i| / sum_i D_pred_i``
    (the scalars report the profile sums, both ``~ m - 1`` by the
    conservation identity — the *profile* carries the Thm 2 signal);
    ``occupancy`` — held to the tighter of ``tolerance`` and 1%, since
    conservation is exact in theory and a loose user band must not mask
    a broken ring.
    """
    if predictions is None:
        if params is None or m is None:
            raise ValueError("drift_report needs either predictions= or "
                             "both params= and m=")
        predictions = predict(params, m)
    n = (len(predictions["delays"])
         if isinstance(predictions.get("delays"), (list, tuple)) else None)
    emp = empirical(decoded, n=n, burn=burn)
    tol = float(tolerance)
    checks = []
    exp_law = law == "exponential"  # product-form domain (module docstring)
    if exp_law and "throughput" in predictions and "throughput" in emp:
        pred, got = float(predictions["throughput"]), float(emp["throughput"])
        rel = abs(got - pred) / max(abs(pred), _TINY)
        checks.append({"metric": "throughput", "empirical": got,
                       "predicted": pred, "rel_err": float(rel),
                       "tol": tol, "ok": bool(rel <= tol)})
    if exp_law and "delays" in predictions and "delays" in emp:
        dp = np.asarray(predictions["delays"], dtype=np.float64)
        de = np.asarray(emp["delays"], dtype=np.float64)
        k = min(len(dp), len(de))
        dp, de = dp[:k], de[:k]
        # contract: allow(raw-reduction): host-side numpy on decoded telemetry — the traced path never sees it
        rel = float(np.sum(np.abs(de - dp)) / max(np.sum(dp), _TINY))
        checks.append({"metric": "staleness", "empirical": float(de.sum()),
                       "predicted": float(dp.sum()), "rel_err": rel,
                       "tol": tol, "ok": bool(rel <= tol)})
    if "occupancy" in predictions and "occupancy" in emp:
        t_m = min(tol, 0.01)
        pred, got = float(predictions["occupancy"]), float(emp["occupancy"])
        rel = abs(got - pred) / max(abs(pred), _TINY)
        checks.append({"metric": "occupancy", "empirical": got,
                       "predicted": pred, "rel_err": float(rel),
                       "tol": t_m, "ok": bool(rel <= t_m)})
    return {"ok": all(c["ok"] for c in checks), "law": str(law),
            "tolerance": tol, "checks": checks}
