"""Chrome-trace / Perfetto export of the *simulated* timeline.

The event ring records every service completion as ``(time, station,
station_to, kind, slot, client, delay, update)``.  Because a closed
network's task sits at exactly one station between consecutive events of
its slot, the ring is a complete interval decomposition of the simulated
clock: :func:`station_spans` rebuilds one span per (event, slot) pair and
:func:`perfetto_trace` lays them out on one track per station — client
downlinks, compute queues, uplinks and the central server — exactly the
"what was every task doing at simulated time t" view the host-side
``AsyncNetworkSim`` never had.

The same file carries the *host* timeline on a second process track:
``repro.obs.metrics`` span samples (suite planning, bucket dispatches,
micro-batcher windows) and ``repro.analysis.tracecheck`` compile spans.
Load the JSON in ``chrome://tracing`` or https://ui.perfetto.dev.

Every emitted event uses the SAME key set ``{name, ph, ts, dur, pid,
tid, args}`` regardless of phase (``M`` metadata / ``X`` complete /
``i`` instant) so the golden schema (``tests/data/trace_schema.json``)
stays homogeneous.  ``ts``/``dur`` are microseconds: one unit of
simulated time maps to one second by default (``time_scale=1e6``).
"""
from __future__ import annotations

from typing import Optional

import numpy as np

__all__ = ["station_label", "station_spans", "station_occupancy",
           "perfetto_trace"]

PID_SIM = 1    # the simulated network timeline
PID_HOST = 2   # host-side planning / dispatch / compile spans

TID_HOST_SPANS = 1
TID_COMPILES = 2

_KIND_NAMES = {-1: "inactive", 0: "down", 1: "comp_wait", 2: "comp",
               3: "up", 4: "cs_wait", 5: "cs"}


def station_label(station: int, n: int) -> str:
    """Human label of a ``[3n+1]`` station row (``events._station_index``
    layout: down_i / comp_i / up_i / CS)."""
    s = int(station)
    if s < n:
        return f"down/{s}"
    if s < 2 * n:
        return f"comp/{s - n}"
    if s < 3 * n:
        return f"up/{s - 2 * n}"
    return "cs"


def station_spans(decoded: dict) -> list:
    """Interval decomposition of one lane's ring.

    Returns dict rows ``{station, slot, client, kind, start, duration,
    update}`` sorted by start time: task ``slot`` sat at ``station`` from
    its previous event (or the window start — the simulation start ``0``
    when the ring never wrapped) until this event's ``time``.  A final
    tail span per slot (``kind=-1``, ``update=0``) covers [last event,
    window end] at the slot's ``station_to``.
    """
    t = np.asarray(decoded["time"], dtype=np.float64)
    if not t.size:
        return []
    t0 = 0.0 if int(decoded.get("dropped", 0)) == 0 else float(t[0])
    t1 = float(t[-1])
    prev: dict = {}
    spans = []
    cols = {k: np.asarray(decoded[k])
            for k in ("station", "station_to", "kind", "slot", "client",
                      "update")}
    for i in range(len(t)):
        j = int(cols["slot"][i])
        start = prev.get(j, t0)
        spans.append({"station": int(cols["station"][i]), "slot": j,
                      "client": int(cols["client"][i]),
                      "kind": int(cols["kind"][i]),
                      "start": float(start),
                      "duration": float(t[i]) - float(start),
                      "update": int(cols["update"][i])})
        prev[j] = float(t[i])
    for i in range(len(t) - 1, -1, -1):  # last event of each slot
        j = int(cols["slot"][i])
        if prev.get(j) is None:
            continue
        if prev[j] == float(t[i]):
            spans.append({"station": int(cols["station_to"][i]), "slot": j,
                          "client": int(cols["client"][i]), "kind": -1,
                          "start": float(t[i]),
                          "duration": t1 - float(t[i]), "update": 0})
            prev[j] = None
    spans.sort(key=lambda s: (s["start"], s["slot"]))
    return spans


def station_occupancy(decoded: dict, n: int) -> Optional[np.ndarray]:
    """Time-averaged ``[3n+1]`` station occupancy reconstructed from the
    ring spans — the empirical counterpart of
    ``EventStats.mean_queue_counts`` (WAIT and SERV share a station, same
    as ``events._station_index``).  ``None`` when the window is empty."""
    t = np.asarray(decoded["time"], dtype=np.float64)
    if t.size < 2:
        return None
    t0 = 0.0 if int(decoded.get("dropped", 0)) == 0 else float(t[0])
    t1 = float(t[-1])
    if not t1 > t0:
        return None
    occ = np.zeros(3 * int(n) + 1, dtype=np.float64)
    for s in station_spans(decoded):
        lo = min(max(s["start"], t0), t1)
        hi = min(s["start"] + s["duration"], t1)
        if hi > lo:
            occ[s["station"]] += hi - lo
    return occ / (t1 - t0)


def _event(name, ph, ts, dur, pid, tid, args) -> dict:
    # ONE shape for every phase — see the module docstring
    return {"name": str(name), "ph": str(ph), "ts": float(ts),
            "dur": float(dur), "pid": int(pid), "tid": int(tid),
            "args": dict(args)}


def perfetto_trace(decoded: dict, n: int, *, name: str = "lane",
                   metadata: Optional[dict] = None,
                   host_spans=None, compile_spans=None,
                   time_scale: float = 1e6) -> dict:
    """One lane's ring (plus optional host/compile spans) as a Chrome-trace
    JSON object ``{"traceEvents": [...], "displayTimeUnit": "ms",
    "metadata": {...}}``.

    ``host_spans`` takes ``repro.obs.metrics.Metrics.spans()`` rows
    (``{name, labels, start, duration}``, perf-counter seconds);
    ``compile_spans`` takes ``repro.analysis.tracecheck`` ``Watch.spans``
    triples ``(program, end, seconds)``.  Both are rebased to their own
    zero so the host track starts alongside the simulated one.
    """
    n = int(n)
    events = [
        _event("process_name", "M", 0, 0, PID_SIM, 0,
               {"name": f"simulated network ({name})"}),
        _event("process_name", "M", 0, 0, PID_HOST, 0,
               {"name": "host"}),
        _event("thread_name", "M", 0, 0, PID_HOST, TID_HOST_SPANS,
               {"name": "suite/serve spans"}),
        _event("thread_name", "M", 0, 0, PID_HOST, TID_COMPILES,
               {"name": "compiles"}),
    ]
    spans = station_spans(decoded)
    for station in sorted({s["station"] for s in spans}):
        events.append(_event("thread_name", "M", 0, 0, PID_SIM, station,
                             {"name": station_label(station, n)}))
    for s in spans:
        label = (_KIND_NAMES.get(s["kind"], "span") if s["kind"] >= 0
                 else station_label(s["station"], n))
        events.append(_event(
            f"{label} slot{s['slot']}", "X", s["start"] * time_scale,
            s["duration"] * time_scale, PID_SIM, s["station"],
            {"slot": s["slot"], "client": s["client"], "kind": s["kind"]}))
        if s["update"]:
            events.append(_event(
                "update", "i", (s["start"] + s["duration"]) * time_scale,
                0.0, PID_SIM, s["station"],
                {"slot": s["slot"], "client": s["client"],
                 "kind": s["kind"]}))
    starts = [float(h["start"]) for h in (host_spans or [])]
    starts += [float(end) - float(secs)
               for _, end, secs in (compile_spans or [])]
    base = min(starts) if starts else 0.0
    for h in host_spans or []:
        events.append(_event(
            h["name"], "X", (float(h["start"]) - base) * 1e6,
            float(h["duration"]) * 1e6, PID_HOST, TID_HOST_SPANS,
            {str(k): str(v) for k, v in dict(h.get("labels") or {}).items()}))
    for prog, end, secs in compile_spans or []:
        events.append(_event(
            f"compile:{prog}", "X", (float(end) - float(secs) - base) * 1e6,
            float(secs) * 1e6, PID_HOST, TID_COMPILES, {"program": str(prog)}))
    meta = {"ring": {"count": int(decoded.get("count", len(spans))),
                     "capacity": int(decoded.get("capacity", 0)),
                     "dropped": int(decoded.get("dropped", 0))},
            "n": n, "time_scale": float(time_scale)}
    if metadata:
        meta.update(metadata)
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "metadata": meta}
