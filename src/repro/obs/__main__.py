"""``python -m repro.obs`` — telemetry smoke traces and drift gating.

Verbs::

    python -m repro.obs smoke --out TRACE_smoke.json
        Run the canonical traced smoke scenario (event engine + rings),
        export the simulated-timeline Perfetto trace with the drift
        report, closed-form predictions AND the raw decoded ring embedded
        in ``metadata`` — the file is self-checking.

    python -m repro.obs check TRACE_smoke.json
        Re-verify an exported trace: validate the event schema,
        re-run the drift comparison from the embedded ring + predictions
        (never trusting the stored verdict), exit 1 on any breach.
        This is the CI gate next to the jaxpr audit.

    python -m repro.obs report TRACE_smoke.json
        Human-readable summary of the same file.

The smoke trace doubles as the observability goldens' source: the
exporter schema is pinned by ``tests/data/trace_schema.json``.
"""
from __future__ import annotations

import argparse
import json
import sys

import numpy as np

_REQUIRED_EVENT_KEYS = ("name", "ph", "ts", "dur", "pid", "tid", "args")


def _smoke(args) -> int:
    from ..analysis import tracecheck
    from ..scenario import (NetworkSpec, Scenario, ScenarioSuite, SimSpec,
                            TraceSpec)
    from .drift import predict
    from .trace import perfetto_trace

    rng = np.random.default_rng(0)
    n = 4
    net = NetworkSpec(mu_c=(0.8 + 0.4 * rng.random(n)).tolist(),
                      mu_d=[4.0] * n, mu_u=[4.0] * n)
    scn = Scenario(network=net, name="obs_smoke",
                   sim=SimSpec(trace=TraceSpec(events=args.events,
                                               tolerance=args.tolerance)))
    suite = ScenarioSuite({"obs_smoke": scn}, seeds=tuple(range(args.seeds)))
    with tracecheck.watch() as w:
        res = suite.run(mode="simulate", num_updates=args.updates,
                        warmup=args.warmup)
    decoded = res.traces["obs_smoke"][0]  # seed 0 carries the timeline
    reports = res.drift["obs_smoke"]
    p, m = res.strategies["obs_smoke"]
    preds = predict(scn.params(p), m)
    ring_data = {k: (v.tolist() if isinstance(v, np.ndarray) else int(v))
                 for k, v in decoded.items()}
    doc = perfetto_trace(
        decoded, scn.n, name="obs_smoke",
        host_spans=suite.metrics.spans(), compile_spans=w.spans,
        metadata={"scenario": scn.to_dict(), "seeds": list(suite.seeds),
                  "law": scn.network.law, "tolerance": args.tolerance,
                  "predictions": preds, "drift": reports,
                  "ring_data": ring_data})
    out = json.dumps(doc, indent=None, separators=(",", ":"))
    if args.out:
        with open(args.out, "w") as f:
            f.write(out)
        print(f"wrote {args.out}: {len(doc['traceEvents'])} events, "
              f"{len(out)} bytes")
    else:
        print(out)
    _print_reports(reports)
    return 0 if all(r["ok"] for r in reports) else 1


def _load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def _schema_errors(doc: dict) -> list:
    errs = []
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        return [f"traceEvents missing or empty ({type(events).__name__})"]
    for i, ev in enumerate(events):
        missing = [k for k in _REQUIRED_EVENT_KEYS if k not in ev]
        if missing:
            errs.append(f"event {i} missing keys {missing}")
        if len(errs) >= 5:
            break
    return errs


def _recheck(doc: dict) -> dict:
    """Drift re-verification from the embedded ring (see module doc)."""
    from .drift import drift_report

    meta = doc.get("metadata", {})
    ring = meta.get("ring_data")
    preds = meta.get("predictions")
    if not ring or not preds:
        raise SystemExit("trace file has no embedded ring_data/predictions "
                         "(not a `repro.obs smoke` export?)")
    decoded = {k: (np.asarray(v) if isinstance(v, list) else v)
               for k, v in ring.items()}
    return drift_report(decoded, predictions=preds,
                        law=meta.get("law", "exponential"),
                        tolerance=meta.get("tolerance", 0.25))


def _print_reports(reports) -> None:
    for i, rep in enumerate(reports):
        print(f"drift[{i}] law={rep['law']} ok={rep['ok']}")
        for c in rep["checks"]:
            flag = "ok" if c["ok"] else "DRIFT"
            print(f"  {c['metric']:11s} empirical={c['empirical']:10.4f} "
                  f"predicted={c['predicted']:10.4f} "
                  f"rel_err={c['rel_err']:8.3%} tol={c['tol']:.0%} [{flag}]")


def _check(args) -> int:
    doc = _load(args.path)
    errs = _schema_errors(doc)
    if errs:
        for e in errs:
            print(f"schema: {e}", file=sys.stderr)
        return 1
    rep = _recheck(doc)
    _print_reports([rep])
    stored = doc.get("metadata", {}).get("drift") or []
    bad = [r for r in stored if not r.get("ok")]
    if bad:
        print(f"{len(bad)} stored drift report(s) flag breaches",
              file=sys.stderr)
    return 0 if rep["ok"] and not bad else 1


def _report(args) -> int:
    doc = _load(args.path)
    meta = doc.get("metadata", {})
    events = doc.get("traceEvents", [])
    by_ph: dict = {}
    for ev in events:
        by_ph[ev.get("ph", "?")] = by_ph.get(ev.get("ph", "?"), 0) + 1
    ring = meta.get("ring", {})
    print(f"{args.path}: {len(events)} events "
          f"({', '.join(f'{k}={v}' for k, v in sorted(by_ph.items()))})")
    print(f"ring: count={ring.get('count')} capacity={ring.get('capacity')} "
          f"dropped={ring.get('dropped')}  n={meta.get('n')}")
    _print_reports(meta.get("drift") or [])
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="telemetry smoke traces and closed-form drift gating")
    sub = ap.add_subparsers(dest="verb", required=True)
    sm = sub.add_parser("smoke", help="run + export the traced smoke scenario")
    sm.add_argument("--out", default=None, help="output JSON path")
    sm.add_argument("--updates", type=int, default=2000)
    sm.add_argument("--warmup", type=int, default=200)
    sm.add_argument("--events", type=int, default=16384)
    sm.add_argument("--seeds", type=int, default=2)
    sm.add_argument("--tolerance", type=float, default=0.25)
    sm.set_defaults(fn=_smoke)
    ck = sub.add_parser("check", help="re-verify an exported trace; exit 1 "
                                      "on schema error or drift breach")
    ck.add_argument("path")
    ck.set_defaults(fn=_check)
    rp = sub.add_parser("report", help="summarize an exported trace")
    rp.add_argument("path")
    rp.set_defaults(fn=_report)
    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
