"""Observability: telemetry rings, trace export, metrics, drift monitors.

Layers (see ``README.md`` "Observability"):

  * ``repro.obs.rings`` — on-device ring buffers carried through the
    event scan and the fused trainer (bitwise non-invasive; statically
    disabled at capacity 0);
  * ``repro.obs.metrics`` — the process-wide counters/histograms/spans
    registry (``repro.serve.metrics`` is a backward-compat shim);
  * ``repro.obs.trace`` — Chrome-trace/Perfetto JSON export of the
    simulated closed-network timeline plus host spans and compiles;
  * ``repro.obs.drift`` — empirical-vs-closed-form drift monitors with
    tolerance bands;
  * ``python -m repro.obs`` — smoke/check/report CLI over saved traces.

Tracing is selected per scenario by ``TraceSpec`` on
``Scenario.sim`` (``repro.scenario.SimSpec``).

This ``__init__`` stays import-light (metrics only): the exporters pull
in the scenario/suite layers and are imported on demand.
"""
from .metrics import Histogram, Metrics

__all__ = ["Histogram", "Metrics"]
