"""Counters, latency histograms and host spans — the one metrics registry.

``SuiteResult.cache_hits`` used to be the only observability the planner
had.  A :class:`Metrics` registry threads through ``ScenarioSuite.run``
(every suite owns one; pass ``metrics=`` to share a registry across
suites, as ``repro.serve`` does across micro-batches) and through the
server's admission/dispatch path, so both report the same per-bucket
counters: programs compiled, lanes dispatched, cache hits, and wall-clock
latency percentiles.

This module moved here from ``repro.serve.metrics`` (which remains as a
backward-compat shim) when observability grew beyond the server: the same
registry now also records a bounded window of **host spans** (every
``timed()`` block keeps its start/duration for the Perfetto exporter in
``repro.obs.trace``) and renders a Prometheus-style text
:meth:`Metrics.exposition` served by the ``metrics`` verb of
``repro.serve``.

The registry is thread-safe (the server observes from reader threads and
the dispatcher thread concurrently) and dependency-free: histograms keep
a bounded reservoir of recent observations — exact percentiles over the
window, O(1) memory.
"""
from __future__ import annotations

import re
import threading
import time
from collections import deque
from typing import Optional

_RESERVOIR = 2048  # recent-observation window per histogram
_SPANS = 4096      # recent-span window kept for the trace exporter

_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")


class Histogram:
    """Bounded-reservoir histogram: exact percentiles over the most
    recent ``_RESERVOIR`` observations, plus all-time count and sum."""

    __slots__ = ("count", "total", "_window")

    def __init__(self):
        self.count = 0
        self.total = 0.0
        self._window = deque(maxlen=_RESERVOIR)

    def observe(self, value: float) -> None:
        self.count += 1
        self.total += float(value)
        self._window.append(float(value))

    def percentile(self, q: float) -> float:
        """Exact q-quantile (0 <= q <= 1) of the recent window (nearest
        rank); 0.0 when nothing has been observed."""
        if not self._window:
            return 0.0
        ordered = sorted(self._window)
        rank = min(len(ordered) - 1, max(0, round(q * (len(ordered) - 1))))
        return ordered[rank]

    def summary(self) -> dict:
        return {"count": self.count,
                "mean": self.total / self.count if self.count else 0.0,
                "p50": self.percentile(0.50),
                "p99": self.percentile(0.99)}


class Metrics:
    """Thread-safe named counters + histograms with optional labels.

    Label values land in the flattened snapshot key as
    ``name{k=v,...}`` — e.g. ``suite.lanes{mode=train}``.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: dict[str, float] = {}
        self._hists: dict[str, Histogram] = {}
        # (name, labels dict, perf_counter start, duration s): the host-span
        # window the Perfetto exporter turns into one track per span name
        self._spans: deque = deque(maxlen=_SPANS)

    @staticmethod
    def _key(name: str, labels: dict) -> str:
        if not labels:
            return name
        inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
        return f"{name}{{{inner}}}"

    def inc(self, name: str, by: float = 1, **labels) -> None:
        key = self._key(name, labels)
        with self._lock:
            self._counters[key] = self._counters.get(key, 0) + by

    def counter(self, name: str, **labels) -> float:
        with self._lock:
            return self._counters.get(self._key(name, labels), 0)

    def observe(self, name: str, value: float, **labels) -> None:
        key = self._key(name, labels)
        with self._lock:
            hist = self._hists.get(key)
            if hist is None:
                hist = self._hists[key] = Histogram()
        hist.observe(value)

    def timed(self, name: str, **labels) -> "_Timer":
        """``with metrics.timed("suite.dispatch", mode="train"): ...``
        observes the block's wall-clock seconds (and keeps the span for
        the trace exporter)."""
        return _Timer(self, name, labels)

    def record_span(self, name: str, labels: dict, start: float,
                    duration: float) -> None:
        """Keep one host span (``start`` on the ``time.perf_counter``
        clock) in the bounded span window."""
        with self._lock:
            self._spans.append((name, dict(labels), float(start),
                                float(duration)))

    def spans(self) -> list:
        """Recent host spans as ``{name, labels, start, duration}`` dicts
        (start on the ``perf_counter`` clock, seconds)."""
        with self._lock:
            return [{"name": n, "labels": lb, "start": s, "duration": d}
                    for n, lb, s, d in self._spans]

    def snapshot(self) -> dict:
        """JSON-able view: ``{"counters": {...}, "latency": {key:
        {count, mean, p50, p99}}}``."""
        with self._lock:
            counters = dict(self._counters)
            hists = {k: h.summary() for k, h in self._hists.items()}
        return {"counters": counters, "latency": hists}

    def exposition(self) -> str:
        """Prometheus text exposition of the registry.

        Counters render as ``counter`` samples, histograms as ``summary``
        quantiles plus ``_sum``/``_count`` — names sanitized to the
        Prometheus charset (``suite.dispatch`` -> ``suite_dispatch``),
        labels quoted.  Served over the wire by the ``metrics`` verb of
        ``repro.serve``.
        """
        snap = self.snapshot()
        lines: list[str] = []
        typed: set[str] = set()

        def emit(kind: str, key: str, render) -> None:
            name, labels = _split_key(key)
            metric = _NAME_RE.sub("_", name)
            if metric not in typed:
                typed.add(metric)
                lines.append(f"# TYPE {metric} {kind}")
            render(metric, labels)

        for key in sorted(snap["counters"]):
            value = snap["counters"][key]
            emit("counter", key, lambda metric, labels: lines.append(
                f"{metric}{_render_labels(labels)} {float(value)}"))
        for key in sorted(snap["latency"]):
            s = snap["latency"][key]

            def render(metric, labels, s=s):
                for q, v in (("0.5", s["p50"]), ("0.99", s["p99"])):
                    lines.append(f"{metric}"
                                 f"{_render_labels(labels, quantile=q)}"
                                 f" {float(v)}")
                lines.append(f"{metric}_sum{_render_labels(labels)}"
                             f" {s['mean'] * s['count']}")
                lines.append(f"{metric}_count{_render_labels(labels)}"
                             f" {s['count']}")

            emit("summary", key, render)
        return "\n".join(lines) + "\n"


def _split_key(key: str) -> tuple[str, dict]:
    """Inverse of :meth:`Metrics._key`: ``name{k=v,...}`` -> (name, dict)."""
    if "{" not in key:
        return key, {}
    name, _, inner = key.partition("{")
    labels = {}
    for part in inner.rstrip("}").split(","):
        if part:
            k, _, v = part.partition("=")
            labels[k] = v
    return name, labels


def _render_labels(labels: dict, **extra) -> str:
    merged = {**labels, **extra}
    if not merged:
        return ""
    inner = ",".join(f'{_NAME_RE.sub("_", k)}="{merged[k]}"'
                     for k in sorted(merged))
    return f"{{{inner}}}"


class _Timer:
    __slots__ = ("_metrics", "_name", "_labels", "_t0")

    def __init__(self, metrics: Metrics, name: str, labels: dict):
        self._metrics = metrics
        self._name = name
        self._labels = labels

    def __enter__(self) -> "_Timer":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> Optional[bool]:
        dt = time.perf_counter() - self._t0
        self._metrics.observe(self._name, dt, **self._labels)
        self._metrics.record_span(self._name, self._labels, self._t0, dt)
        return None
