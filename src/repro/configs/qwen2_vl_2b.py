"""qwen2-vl-2b [vlm] — 28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936,
M-RoPE; vision encoder stubbed to precomputed patch embeddings (256 tokens).
[arXiv:2409.12191]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab=151936,
    head_dim=128,
    rope="mrope",
    rope_theta=1e6,
    mrope_sections=(16, 24, 24),
    sliding_window=8192,
    num_image_tokens=256,
    optimizer="adamw",
    citation="arXiv:2409.12191",
)
