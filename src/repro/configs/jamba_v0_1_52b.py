"""jamba-v0.1-52b [hybrid] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, Mamba+attention 1:7 interleave, MoE 16 experts top-2 on
alternate layers.  [arXiv:2403.19887]"""
from repro.models.config import ArchConfig, MoEConfig

# One Jamba group = 8 layers: attention at index 3 (1:7 ratio), MoE on every
# other layer's FFN (odd slots), dense FFN elsewhere.
_PATTERN = ("mamba", "mamba", "mamba", "attn", "mamba", "mamba", "mamba", "mamba")
_FFNS = ("dense", "moe", "dense", "moe", "dense", "moe", "dense", "moe")

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=65536,
    head_dim=128,
    rope="none",            # Jamba uses no positional encoding (Mamba carries order)
    block_pattern=_PATTERN,
    ffn_pattern=_FFNS,
    moe=MoEConfig(num_experts=16, top_k=2, expert_ff=14336),
    mamba_d_state=16,
    mamba_d_conv=4,
    mamba_expand=2,
    optimizer="adafactor",
    citation="arXiv:2403.19887",
)
