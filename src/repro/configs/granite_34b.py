"""granite-34b [dense] — 88L d_model=6144 48H (MQA kv=1) d_ff=24576
vocab=49152, llama-arch code model.  [arXiv:2405.04324]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="granite-34b",
    family="dense",
    n_layers=88,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab=49152,
    head_dim=128,
    rope="standard",
    rope_theta=1e5,
    sliding_window=8192,
    optimizer="adafactor",
    citation="arXiv:2405.04324",
)
