"""qwen2-moe-a2.7b [moe] — 24L d_model=2048 16H (kv=16) expert d_ff=1408
vocab=151936, 60 routed experts top-4 + 4 shared.  [hf:Qwen/Qwen1.5-MoE-A2.7B]"""
from repro.models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=151936,
    head_dim=128,
    rope="standard",
    rope_theta=1e6,
    sliding_window=8192,
    moe=MoEConfig(num_experts=60, top_k=4, expert_ff=1408,
                  num_shared=4, shared_ff=1408),
    optimizer="adamw",
    citation="hf:Qwen/Qwen1.5-MoE-A2.7B",
)
