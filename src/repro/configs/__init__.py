"""Assigned architecture registry: ``get_config(arch_id)``."""
from __future__ import annotations

import importlib

ARCHS = [
    "qwen3-8b", "xlstm-350m", "qwen2-moe-a2.7b", "kimi-k2-1t-a32b",
    "llama3-405b", "internlm2-1.8b", "qwen2-vl-2b", "whisper-medium",
    "granite-34b", "jamba-v0.1-52b",
]


def get_config(arch_id: str):
    mod = importlib.import_module(
        f"repro.configs.{arch_id.replace('-', '_').replace('.', '_')}")
    return mod.CONFIG


def all_configs():
    return {a: get_config(a) for a in ARCHS}
