"""xlstm-350m [ssm] — 24L d_model=1024 4H d_ff=0 vocab=50304; alternating
sLSTM + mLSTM blocks (projections internal to the blocks, hence d_ff=0).
[arXiv:2405.04517]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    rope="none",
    block_pattern=("mlstm", "slstm"),
    ffn_pattern=("none", "none"),
    optimizer="adamw",
    citation="arXiv:2405.04517",
)
