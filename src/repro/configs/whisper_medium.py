"""whisper-medium [audio] — enc-dec, 24L decoder (+24L encoder) d_model=1024
16H (MHA) d_ff=4096 vocab=51865; mel/conv frontend stubbed to 1500 frame
embeddings.  [arXiv:2212.04356]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=51865,
    rope="none",            # sinusoidal absolute positions
    encoder_layers=24,
    encoder_frames=1500,
    optimizer="adamw",
    citation="arXiv:2212.04356",
)
