"""kimi-k2-1t-a32b [moe] — 61L d_model=7168 64H (GQA kv=8) expert d_ff=2048
vocab=163840, 384 routed experts top-8; first layer dense (DeepSeek-V3-style).
Trillion-parameter paper-table config.  [arXiv:2501.kimi2]"""
from repro.models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=2048,
    vocab=163840,
    head_dim=112,          # 7168 / 64 — note: not 128-aligned (see roofline)
    rope="standard",
    rope_theta=5e6,
    sliding_window=8192,
    moe=MoEConfig(num_experts=384, top_k=8, expert_ff=2048,
                  num_shared=1, shared_ff=2048),
    first_k_dense=1,
    optimizer="adafactor",  # factored state: Adam moments would not fit HBM
    citation="arXiv:2501.kimi2",
)
