"""Jitted public wrappers for the Pallas kernels.

``interpret`` defaults to True because this container is CPU-only (TPU is
the deployment target); on TPU pass ``interpret=False`` (the launcher does
this when ``jax.default_backend() == "tpu"``).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax

from .buzen import buzen_pallas, default_interpret
from .decode_attention import decode_attention_pallas
from .flash_attention import flash_attention_pallas
from .fused_update import fused_async_update as _fused_update


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                                   "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, block_q: int = 128,
                    block_k: int = 128, interpret: Optional[bool] = None):
    interp = default_interpret() if interpret is None else interpret
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  block_q=block_q, block_k=block_k,
                                  interpret=interp)


@partial(jax.jit, static_argnames=("block_s", "interpret"))
def decode_attention(q, k_cache, v_cache, length, *, block_s: int = 256,
                     interpret: Optional[bool] = None):
    interp = default_interpret() if interpret is None else interpret
    return decode_attention_pallas(q, k_cache, v_cache, length,
                                   block_s=block_s, interpret=interp)


@partial(jax.jit, static_argnames=("m_max", "interpret"))
def buzen_log_Z(log_rho, log_gamma_total, m_max: int,
                interpret: Optional[bool] = None):
    interp = default_interpret() if interpret is None else interpret
    return buzen_pallas(log_rho, log_gamma_total, m_max, interpret=interp)


@partial(jax.jit, static_argnames=("interpret",))
def fused_async_update(params, grads, scale,
                       interpret: Optional[bool] = None):
    interp = default_interpret() if interpret is None else interpret
    return _fused_update(params, grads, scale, interpret=interp)
