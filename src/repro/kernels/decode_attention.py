"""Pallas TPU fused decode attention (single query token vs KV cache).

Serving hot spot: memory-bound streaming of the KV cache.  Tiling: the G
query heads sharing one KV head stay resident in VMEM ``(G, D)``; the cache
is streamed in ``(block_s, D)`` tiles along the sequential grid axis with
online-softmax accumulators in VMEM scratch — one HBM pass over the cache,
no (S,) score materialization in HBM.

Validated in interpret mode against ``ref.decode_attention_oracle``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                   *, scale: float, block_s: int, n_s: int):
    si = pl.program_id(1)

    @pl.when(si == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale            # [G, D]
    k = k_ref[0].astype(jnp.float32)                    # [block_s, D]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [G, block_s]
    k_pos = si * block_s + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(k_pos < len_ref[0], s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1)
    v = v_ref[0].astype(jnp.float32)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot(p, v)
    m_scr[...] = m_new

    @pl.when(si == n_s - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def decode_attention_pallas(
    q: jax.Array,        # [B, 1, H, D]
    k_cache: jax.Array,  # [B, S, KV, D]
    v_cache: jax.Array,
    length,              # scalar or [B]
    *,
    block_s: int = 256,
    interpret: bool = True,
) -> jax.Array:
    B, _, H, D = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    scale = D ** -0.5
    n_s = -(-S // block_s)
    pad = n_s * block_s - S

    qf = q.reshape(B, KV, G, D).reshape(B * KV, G, D)
    kf = jnp.moveaxis(k_cache, 2, 1).reshape(B * KV, S, D)
    vf = jnp.moveaxis(v_cache, 2, 1).reshape(B * KV, S, D)
    if pad:
        kf = jnp.pad(kf, ((0, 0), (0, pad), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad), (0, 0)))
    lengths = jnp.broadcast_to(jnp.asarray(length, jnp.int32).reshape(-1),
                               (B,))
    lengths = jnp.repeat(lengths, KV)  # [B*KV]

    kernel = functools.partial(_decode_kernel, scale=scale, block_s=block_s,
                               n_s=n_s)
    out = pl.pallas_call(
        kernel,
        grid=(B * KV, n_s),
        in_specs=[
            pl.BlockSpec((1,), lambda h, si: (h,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, G, D), lambda h, si: (h, 0, 0)),
            pl.BlockSpec((1, block_s, D), lambda h, si: (h, si, 0)),
            pl.BlockSpec((1, block_s, D), lambda h, si: (h, si, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, D), lambda h, si: (h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KV, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=None if interpret else pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
    )(lengths, qf, kf, vf)
    return out.reshape(B, 1, H, D)
