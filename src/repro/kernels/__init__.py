"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel lives in ``<name>.py`` (pl.pallas_call + explicit BlockSpec VMEM
tiling) with its jitted wrapper in ``ops.py`` and pure-jnp oracle in
``ref.py``.  On this CPU-only container all kernels are validated in
``interpret=True`` mode; TPU is the deployment target.
"""
from . import ops, ref

__all__ = ["ops", "ref"]
