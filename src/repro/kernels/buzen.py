"""Pallas TPU kernel for Buzen's convolution algorithm (Proposition 15).

This is the paper's algorithmic inner loop: the routing/concurrency
optimizer re-evaluates the normalization constants ``Z_{n, 0..m}`` at every
Adam step.  The DP is sequential over stations but fully vectorizable over
the population dimension ``m`` (lane axis) — a natural TPU layout:

  * the running log-constant row ``U[0..m]`` lives in VMEM scratch across
    the sequential station grid axis;
  * each station performs the log-space truncated convolution
    ``U'[m] = logsumexp_k (k * log_rho_i + U[m - k])`` as a single
    (m+1, m+1) masked reduction in VMEM (m ~ O(100) so the tile is ~64 KB);
  * the aggregated infinite-server Poisson factor is the row initializer.

Validated in interpret mode against the jnp implementation in
``repro.core.buzen`` (itself validated against brute-force enumeration).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _buzen_kernel(rho_ref, init_ref, out_ref, u_scr, *, n_stations: int,
                  m_pad: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        u_scr[...] = init_ref[...]  # aggregated IS Poisson factor row

    log_rho = rho_ref[0]
    u = u_scr[...]  # [m_pad]
    # T[m, k] = k * log_rho + U[m - k], masked to k <= m
    mm = jax.lax.broadcasted_iota(jnp.int32, (m_pad, m_pad), 0)
    kk = jax.lax.broadcasted_iota(jnp.int32, (m_pad, m_pad), 1)
    valid = kk <= mm
    shifted = jnp.where(valid, (mm - kk), 0)
    terms = jnp.where(valid, kk.astype(jnp.float32) * log_rho
                      + jnp.take_along_axis(
                          jnp.broadcast_to(u[None, :], (m_pad, m_pad)),
                          shifted, axis=1), NEG_INF)
    row_max = jnp.max(terms, axis=1)
    new_u = row_max + jnp.log(
        jnp.sum(jnp.exp(terms - row_max[:, None]), axis=1))
    u_scr[...] = new_u

    @pl.when(i == n_stations - 1)
    def _finalize():
        out_ref[...] = u_scr[...]


def buzen_pallas(log_rho: jax.Array, log_gamma_total: jax.Array, m_max: int,
                 *, interpret: bool = True) -> jax.Array:
    """log Z_{n, 0..m_max} for n single-server stations with log-loads
    ``log_rho`` plus an aggregated IS station with log-load
    ``log_gamma_total``."""
    from jax.scipy.special import gammaln

    n = log_rho.shape[0]
    m_pad = m_max + 1
    k = jnp.arange(m_pad, dtype=jnp.float32)
    init_row = (k * log_gamma_total.astype(jnp.float32)
                - gammaln(k + 1.0)).astype(jnp.float32)
    rho32 = log_rho.astype(jnp.float32)

    kernel = functools.partial(_buzen_kernel, n_stations=n, m_pad=m_pad)
    out = pl.pallas_call(
        kernel,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((m_pad,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((m_pad,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((m_pad,), jnp.float32),
        scratch_shapes=[pltpu.VMEM((m_pad,), jnp.float32)],
        interpret=interpret,
        compiler_params=None if interpret else pltpu.CompilerParams(
            dimension_semantics=("arbitrary",)),
    )(rho32, init_row)
    return out
