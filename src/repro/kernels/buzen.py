"""Pallas TPU kernel for Buzen's convolution algorithm (Proposition 15).

This is the paper's algorithmic inner loop: the routing/concurrency
optimizer re-evaluates the normalization constants ``Z_{n, 0..m}`` at every
Adam step, for every candidate concurrency.  The DP is sequential over
stations but fully vectorizable over the population dimension ``m`` (lane
axis) *and* over the batch of routing vectors (grid axis) — a natural TPU
layout:

  * grid ``(B, n_stations)``: batch rows are independent (``parallel``
    semantics), stations run the sequential recursion (``arbitrary``);
  * the running log-constant row ``U[0..m]`` lives in VMEM scratch across
    the station axis, initialized from the aggregated infinite-server
    Poisson factor at station 0 of each row;
  * each station performs the log-space truncated convolution
    ``U'[m] = logsumexp_k (k * log_rho_i + U[m - k])`` as a single
    ``(m+1, m+1)`` masked reduction in VMEM (m ~ O(100), so ~64 KB).

Public entry points:

  * :func:`buzen_pallas_batched` — raw float32 kernel, ``[B, S] -> [B, m+1]``;
    compiled when running on TPU, interpret fallback elsewhere.
  * :func:`buzen_log_Z_batched` — differentiable wrapper: float32 Pallas
    forward, VJP through the float64 ``jnp`` reference DP (the kernel itself
    has no autodiff rule), so the batched optimizer can run on this backend.
  * :func:`buzen_pallas` — single-row compatibility wrapper (``B = 1``).

Validated in interpret mode against ``repro.core.buzen`` (itself validated
against brute-force state enumeration) in ``tests/test_kernels.py`` and
``tests/test_batched_optimizer.py``.
"""
from __future__ import annotations
# contract: padded-n — reductions here are on the bitwise padding contract

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

# jax renamed TPUCompilerParams -> CompilerParams across releases
_CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")


def _buzen_kernel(rho_ref, init_ref, out_ref, u_scr, *, n_stations: int,
                  m_pad: int):
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        u_scr[...] = init_ref[0]  # aggregated IS Poisson factor row

    log_rho = rho_ref[0, 0]
    u = u_scr[...]  # [m_pad]
    # T[m, k] = k * log_rho + U[m - k], masked to k <= m
    mm = jax.lax.broadcasted_iota(jnp.int32, (m_pad, m_pad), 0)
    kk = jax.lax.broadcasted_iota(jnp.int32, (m_pad, m_pad), 1)
    valid = kk <= mm
    shifted = jnp.where(valid, (mm - kk), 0)
    terms = jnp.where(valid, kk.astype(jnp.float32) * log_rho
                      + jnp.take_along_axis(
                          jnp.broadcast_to(u[None, :], (m_pad, m_pad)),
                          shifted, axis=1), NEG_INF)
    row_max = jnp.max(terms, axis=1)
    # contract: allow(raw-reduction): logsumexp over the m-convolution axis within ONE station — the client/station axis is the kernel's sequential grid loop, and this f32 path is rtol-validated, not bitwise
    sumexp = jnp.sum(jnp.exp(terms - row_max[:, None]), axis=1)
    new_u = row_max + jnp.log(sumexp)
    u_scr[...] = new_u

    @pl.when(i == n_stations - 1)
    def _finalize():
        out_ref[0] = u_scr[...]


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("m_max", "interpret"))
def buzen_pallas_batched(log_rho: jax.Array, log_gamma_total: jax.Array,
                         m_max: int, *,
                         interpret: Optional[bool] = None) -> jax.Array:
    """``log Z_{., 0..m_max}`` for a batch of networks.

    ``log_rho`` is ``[B, S]`` single-server log-loads (S stations per row —
    include the CS station as an extra column if modelled) and
    ``log_gamma_total`` is ``[B]`` aggregated infinite-server log-loads.
    Returns float32 ``[B, m_max + 1]``.
    """
    interp = default_interpret() if interpret is None else interpret
    B, n = log_rho.shape
    m_pad = m_max + 1
    k = jnp.arange(m_pad, dtype=jnp.float32)
    from jax.scipy.special import gammaln
    init_rows = (k[None, :] * log_gamma_total[:, None].astype(jnp.float32)
                 - gammaln(k + 1.0)[None, :]).astype(jnp.float32)
    # load-0 stations (padded clients under the traced-n convention) arrive
    # as log_rho = -inf; clamp to the finite mask value so the kernel's
    # k * log_rho products stay NaN-free — the k >= 1 terms then underflow
    # to exactly 0 in the row logsumexp, making the station a convolution
    # identity, matching the jnp reference's masked geometric series
    rho32 = jnp.maximum(log_rho.astype(jnp.float32), NEG_INF)

    kernel = functools.partial(_buzen_kernel, n_stations=n, m_pad=m_pad)
    return pl.pallas_call(
        kernel,
        grid=(B, n),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, i: (b, i)),
            pl.BlockSpec((1, m_pad), lambda b, i: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, m_pad), lambda b, i: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((B, m_pad), jnp.float32),
        scratch_shapes=[pltpu.VMEM((m_pad,), jnp.float32)],
        interpret=interp,
        compiler_params=None if interp else _CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
    )(rho32, init_rows)


def _reference_log_Z(log_rho: jax.Array, log_gamma_total: jax.Array,
                     m_max: int) -> jax.Array:
    """Float64 ``jnp`` DP on the same ``[B, S]``/``[B]`` layout — VJP donor
    for :func:`buzen_log_Z_batched` (matches ``core.buzen`` "aggregate")."""
    from ..core.buzen import _geometric_series, _log_conv, _poisson_series

    def one(lr_row, lg):
        logZ = _poisson_series(lg, m_max)

        def fold(carry, lr):
            return _log_conv(carry, _geometric_series(lr, m_max)), None

        logZ, _ = jax.lax.scan(fold, logZ, lr_row)
        return logZ

    return jax.vmap(one)(log_rho, log_gamma_total)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def buzen_log_Z_batched(log_rho: jax.Array, log_gamma_total: jax.Array,
                        m_max: int) -> jax.Array:
    """Differentiable batched Buzen DP: Pallas forward, reference VJP.

    Forward runs the float32 TPU kernel (interpret fallback off-TPU) and
    casts back to the input dtype; the backward pass differentiates the
    float64 ``jnp`` recursion at the same primal point, so ``jax.grad``
    through the routing optimizer works on this backend.
    """
    out = buzen_pallas_batched(log_rho, log_gamma_total, m_max)
    return out.astype(log_rho.dtype)


def _buzen_log_Z_fwd(log_rho, log_gamma_total, m_max):
    return (buzen_log_Z_batched(log_rho, log_gamma_total, m_max),
            (log_rho, log_gamma_total))


def _buzen_log_Z_bwd(m_max, residuals, g):
    log_rho, log_gamma_total = residuals
    _, vjp = jax.vjp(
        lambda lr, lg: _reference_log_Z(lr, lg, m_max), log_rho,
        log_gamma_total)
    g_lr, g_lg = vjp(g.astype(log_rho.dtype))
    # padded (load-0) stations enter as log_rho = -inf: the forward value
    # does not depend on them (their geometric factor is the convolution
    # identity), so pin their partials to exactly 0 rather than whatever
    # the -inf arithmetic of the masked series propagated
    return jnp.where(jnp.isfinite(log_rho), g_lr, 0.0), g_lg


buzen_log_Z_batched.defvjp(_buzen_log_Z_fwd, _buzen_log_Z_bwd)


def buzen_pallas(log_rho: jax.Array, log_gamma_total: jax.Array, m_max: int,
                 *, interpret: Optional[bool] = None) -> jax.Array:
    """Single-network compatibility wrapper: ``[n] -> [m_max + 1]``."""
    return buzen_pallas_batched(log_rho[None, :],
                                jnp.asarray(log_gamma_total)[None], m_max,
                                interpret=interpret)[0]


# ---------------------------------------------------------------------------
# class-space kernel: one grid step folds a whole client CLASS
# ---------------------------------------------------------------------------

def _buzen_classes_kernel(series_ref, init_ref, out_ref, u_scr, *,
                          n_stations: int, m_pad: int):
    """Station ``i`` convolves the running row with a PRECOMPUTED series.

    Identical control flow to :func:`_buzen_kernel`, but the station factor
    is the negative-binomial series of a whole class (``count`` identical
    single-server stations folded analytically) instead of the geometric
    series of one client — the grid is ``(B, C)``, not ``(B, n)``, which is
    what makes population size a free variable on this backend.
    """
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        u_scr[...] = init_ref[0]  # aggregated IS Poisson factor row

    series = series_ref[0, 0]  # [m_pad] class series coefficients
    u = u_scr[...]
    # T[m, k] = series[k] + U[m - k], masked to k <= m
    mm = jax.lax.broadcasted_iota(jnp.int32, (m_pad, m_pad), 0)
    kk = jax.lax.broadcasted_iota(jnp.int32, (m_pad, m_pad), 1)
    valid = kk <= mm
    shifted = jnp.where(valid, (mm - kk), 0)
    terms = jnp.where(valid,
                      jnp.broadcast_to(series[None, :], (m_pad, m_pad))
                      + jnp.take_along_axis(
                          jnp.broadcast_to(u[None, :], (m_pad, m_pad)),
                          shifted, axis=1), NEG_INF)
    row_max = jnp.max(terms, axis=1)
    # contract: allow(raw-reduction): logsumexp over the m-convolution axis within ONE class station — the class axis is the kernel's sequential grid loop, and this f32 path is rtol-validated, not bitwise
    sumexp = jnp.sum(jnp.exp(terms - row_max[:, None]), axis=1)
    u_scr[...] = row_max + jnp.log(sumexp)

    @pl.when(i == n_stations - 1)
    def _finalize():
        out_ref[0] = u_scr[...]


@functools.partial(jax.jit, static_argnames=("m_max", "interpret"))
def buzen_classes_pallas_batched(log_rho: jax.Array, counts: jax.Array,
                                 log_gamma_total: jax.Array, m_max: int, *,
                                 interpret: Optional[bool] = None
                                 ) -> jax.Array:
    """``log Z_{., 0..m_max}`` for a batch of CLASS-aggregated networks.

    ``log_rho``/``counts`` are ``[B, S]`` per-member single-server
    log-loads and class multiplicities (append the CS station as a count-1
    column if modelled); ``log_gamma_total`` the ``[B]`` aggregated
    infinite-server log-loads.  Each grid step folds a whole class through
    its negative-binomial generating series

        ``coef[j] = j log_rho + lgamma(j + count) - lgamma(j + 1)
                    - lgamma(count)``

    precomputed on the host in float32 (``j = 0`` pinned to ``0``;
    ``count = 0`` padded classes clamp to the mask value, making them
    exact convolution identities as in the ``jnp`` DP).  Returns float32
    ``[B, m_max + 1]``.  Forward-only — differentiate through
    :func:`buzen_classes_log_Z_batched`.
    """
    from jax.scipy.special import gammaln

    interp = default_interpret() if interpret is None else interpret
    B, S = log_rho.shape
    m_pad = m_max + 1
    k = jnp.arange(m_pad, dtype=jnp.float32)
    init_rows = (k[None, :] * log_gamma_total[:, None].astype(jnp.float32)
                 - gammaln(k + 1.0)[None, :]).astype(jnp.float32)
    cnt = counts.astype(jnp.float32)
    lw = (gammaln(k[None, None, :] + cnt[:, :, None])
          - gammaln(k + 1.0)[None, None, :]
          - gammaln(cnt)[:, :, None])
    lr32 = jnp.maximum(log_rho.astype(jnp.float32), NEG_INF)
    series = k[None, None, :] * lr32[:, :, None] + lw
    series = jnp.where(k[None, None, :] == 0, 0.0,
                       jnp.maximum(series, NEG_INF))

    kernel = functools.partial(_buzen_classes_kernel, n_stations=S,
                               m_pad=m_pad)
    return pl.pallas_call(
        kernel,
        grid=(B, S),
        in_specs=[
            pl.BlockSpec((1, 1, m_pad), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, m_pad), lambda b, i: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, m_pad), lambda b, i: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((B, m_pad), jnp.float32),
        scratch_shapes=[pltpu.VMEM((m_pad,), jnp.float32)],
        interpret=interp,
        compiler_params=None if interp else _CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
    )(series, init_rows)


def _reference_class_log_Z(log_rho: jax.Array, counts: jax.Array,
                           log_gamma_total: jax.Array,
                           m_max: int) -> jax.Array:
    """Float64 ``jnp`` class DP on the ``[B, S]``/``[B]`` layout — VJP
    donor for :func:`buzen_classes_log_Z_batched` (matches
    ``core.buzen.class_log_normalizing_constants``)."""
    from ..core.buzen import _log_conv, _negbinom_series, _poisson_series

    def one(lr_row, cnt_row, lg):
        logZ = _poisson_series(lg, m_max)

        def fold(carry, xs):
            lr, cnt = xs
            return _log_conv(carry, _negbinom_series(lr, cnt, m_max)), None

        logZ, _ = jax.lax.scan(fold, logZ, (lr_row, cnt_row))
        return logZ

    return jax.vmap(one)(log_rho, counts, log_gamma_total)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def buzen_classes_log_Z_batched(log_rho: jax.Array, counts: jax.Array,
                                log_gamma_total: jax.Array,
                                m_max: int) -> jax.Array:
    """Differentiable batched class Buzen DP: Pallas forward, reference VJP.

    The class analogue of :func:`buzen_log_Z_batched`: float32 kernel
    forward, float64 ``jnp`` negative-binomial recursion for the backward
    pass.  ``counts`` are structural multiplicities — their partials are
    pinned to exactly 0.
    """
    out = buzen_classes_pallas_batched(log_rho, counts, log_gamma_total,
                                       m_max)
    return out.astype(log_rho.dtype)


def _buzen_classes_log_Z_fwd(log_rho, counts, log_gamma_total, m_max):
    return (buzen_classes_log_Z_batched(log_rho, counts, log_gamma_total,
                                        m_max),
            (log_rho, counts, log_gamma_total))


def _buzen_classes_log_Z_bwd(m_max, residuals, g):
    log_rho, counts, log_gamma_total = residuals
    _, vjp = jax.vjp(
        lambda lr, lg: _reference_class_log_Z(lr, counts, lg, m_max),
        log_rho, log_gamma_total)
    g_lr, g_lg = vjp(g.astype(log_rho.dtype))
    # padded (count-0) classes enter as log_rho = -inf with an identity
    # series: the forward value does not depend on them, so pin their
    # partials to exactly 0 (and counts are structural integers)
    mask = jnp.isfinite(log_rho) & (counts > 0)
    return (jnp.where(mask, g_lr, 0.0), jnp.zeros_like(g_lr), g_lg)


buzen_classes_log_Z_batched.defvjp(_buzen_classes_log_Z_fwd,
                                   _buzen_classes_log_Z_bwd)
