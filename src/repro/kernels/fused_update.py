"""Pallas TPU fused Generalized-AsyncSGD update.

CS-side update (Algorithm 1, line 6): ``w <- w - (eta / (n p_C)) g`` fused
with the squared-gradient-norm reduction used for staleness/clipping
telemetry — one HBM pass over (w, g) instead of two (update + norm).

Tiling: flat 1-D parameter stream in ``block`` -sized VMEM tiles; the norm
contribution of each tile goes to a per-tile partial-sum output reduced by
the wrapper (deterministic tree reduction).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _update_kernel(scale_ref, w_ref, g_ref, out_ref, norm_ref):
    g = g_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    out_ref[...] = (w - scale_ref[0] * g).astype(out_ref.dtype)
    norm_ref[0] = jnp.sum(g * g)


def fused_async_update_flat(w: jax.Array, g: jax.Array, scale: jax.Array,
                            *, block: int = 4096, interpret: bool = True):
    """w, g: flat [N]. Returns (w_new [N], sum(g^2) scalar f32)."""
    N = w.shape[0]
    n_blocks = -(-N // block)
    pad = n_blocks * block - N
    wp = jnp.pad(w, (0, pad))
    gp = jnp.pad(g, (0, pad))
    scale_arr = jnp.asarray(scale, jnp.float32).reshape(1)

    out, norms = pl.pallas_call(
        _update_kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,), memory_space=pltpu.SMEM),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_blocks * block,), w.dtype),
            jax.ShapeDtypeStruct((n_blocks,), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=None if interpret else pltpu.CompilerParams(
            dimension_semantics=("parallel",)),
    )(scale_arr, wp, gp)
    return out[:N], jnp.sum(norms)


def fused_async_update(params, grads, scale, *, interpret: bool = True):
    """Pytree version: returns (new_params, grad_norm)."""
    leaves, treedef = jax.tree_util.tree_flatten(params)
    gleaves = treedef.flatten_up_to(grads)
    new_leaves, total = [], jnp.zeros((), jnp.float32)
    for w, g in zip(leaves, gleaves):
        nw, sq = fused_async_update_flat(w.reshape(-1), g.reshape(-1), scale,
                                         interpret=interpret)
        new_leaves.append(nw.reshape(w.shape))
        total = total + sq
    return treedef.unflatten(new_leaves), jnp.sqrt(total)
