"""Pure-jnp oracles for every Pallas kernel (the correctness contracts)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def flash_attention_oracle(q, k, v, *, causal=True, window: Optional[int] = None):
    from ..models.attention import plain_attention_ref
    return plain_attention_ref(q, k, v, causal=causal, window=window)


def decode_attention_oracle(q, k_cache, v_cache, length):
    from ..models.attention import decode_attention_ref
    return decode_attention_ref(q, k_cache, v_cache, length)


def buzen_oracle(log_rho, log_gamma_total, m_max):
    """Aggregate-IS Buzen recursion in plain jnp (see repro.core.buzen)."""
    from jax.scipy.special import gammaln
    from ..core.buzen import _log_conv, _geometric_series

    k = jnp.arange(m_max + 1, dtype=jnp.float64)
    logZ = (k * log_gamma_total - gammaln(k + 1.0))
    for i in range(log_rho.shape[0]):
        logZ = _log_conv(logZ, _geometric_series(log_rho[i], m_max))
    return logZ


def event_step_oracle(finish, phase, client, seq, disp_round, mu_c, mu_u,
                      fscal, iscal, *, has_cs: bool):
    """Pure-jnp mirror of ``repro.kernels.events.event_step_tables``
    (same ``[K, ...]`` tables-level contract, ``jnp.argmin`` instead of the
    masked-iota first-index reductions)."""
    from ..core import events as E

    def one(finish, phase, client, seq, disp, mu_c, mu_u, fscal, iscal):
        e_up, e_comp, svc_down, svc_cs = fscal
        c_new, seq_ctr, rnd = iscal
        m_max = finish.shape[0]

        j = jnp.argmin(finish)
        t_new = finish[j]
        c = client[j]
        ph = phase[j]
        delay = rnd - disp[j]
        is_down = ph == E.DOWN
        is_comp = ph == E.COMP_SERV
        is_up = ph == E.UP
        is_cs = ph == E.CS_SERV
        is_update = is_cs if has_cs else is_up
        new_round = rnd + jnp.where(is_update, 1, 0).astype(jnp.int32)
        svc_up = e_up / mu_u[c]
        svc_c = e_comp / mu_c[c]

        phase_j = jnp.where(
            is_down, E.COMP_WAIT,
            jnp.where(is_comp, E.UP,
                      jnp.where(is_update, E.DOWN, E.CS_WAIT)))
        finish_j = jnp.where(
            is_comp, t_new + svc_up,
            jnp.where(is_update, t_new + svc_down, jnp.inf))
        joins_fifo = is_down | (is_up & has_cs)
        seq_j = jnp.where(joins_fifo, seq_ctr, seq[j])
        new_seq_ctr = seq_ctr + joins_fifo.astype(jnp.int32)
        client_j = jnp.where(is_update, c_new, c)
        disp_j = jnp.where(is_update, new_round, disp[j])

        onej = jnp.arange(m_max) == j
        phase = jnp.where(onej, phase_j, phase).astype(jnp.int32)
        finish = jnp.where(onej, finish_j, finish)
        seq = jnp.where(onej, seq_j, seq).astype(jnp.int32)
        client = jnp.where(onej, client_j, client).astype(jnp.int32)
        disp = jnp.where(onej, disp_j, disp).astype(jnp.int32)

        promo_comp = is_down | is_comp
        serving_c = jnp.any((phase == E.COMP_SERV) & (client == c))
        waiting_c = (phase == E.COMP_WAIT) & (client == c)
        pick = jnp.argmin(jnp.where(waiting_c, seq, E._BIG_SEQ))
        do_comp = promo_comp & ~serving_c & jnp.any(waiting_c)
        onep = (jnp.arange(m_max) == pick) & do_comp
        phase = jnp.where(onep, E.COMP_SERV, phase)
        finish = jnp.where(onep, t_new + svc_c, finish)

        do_cs = jnp.zeros((), bool)
        if has_cs:
            promo_cs = is_up | is_cs
            cs_waiting = phase == E.CS_WAIT
            pick_cs = jnp.argmin(jnp.where(cs_waiting, seq, E._BIG_SEQ))
            do_cs = (promo_cs & ~jnp.any(phase == E.CS_SERV)
                     & jnp.any(cs_waiting))
            onec = (jnp.arange(m_max) == pick_cs) & do_cs
            phase = jnp.where(onec, E.CS_SERV, phase)
            finish = jnp.where(onec, t_new + svc_cs, finish)

        t_col = t_new[None]
        int_col = jnp.stack([j.astype(jnp.int32), c,
                             jnp.where(is_update, 1, 0).astype(jnp.int32),
                             delay, new_seq_ctr, new_round, ph,
                             jnp.where(do_comp, 1, 0).astype(jnp.int32),
                             jnp.where(do_cs, 1, 0).astype(jnp.int32)])
        return finish, phase, client, seq, disp, t_col, int_col

    return jax.vmap(one)(finish, phase, client, seq, disp_round, mu_c, mu_u,
                         fscal, iscal)


def fused_async_update_oracle(params, grads, scale):
    new = jax.tree_util.tree_map(
        lambda w, g: (w.astype(jnp.float32)
                      - jnp.float32(scale) * g.astype(jnp.float32)
                      ).astype(w.dtype), params, grads)
    norm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree_util.tree_leaves(grads)))
    return new, norm
