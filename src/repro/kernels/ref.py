"""Pure-jnp oracles for every Pallas kernel (the correctness contracts)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def flash_attention_oracle(q, k, v, *, causal=True, window: Optional[int] = None):
    from ..models.attention import plain_attention_ref
    return plain_attention_ref(q, k, v, causal=causal, window=window)


def decode_attention_oracle(q, k_cache, v_cache, length):
    from ..models.attention import decode_attention_ref
    return decode_attention_ref(q, k_cache, v_cache, length)


def buzen_oracle(log_rho, log_gamma_total, m_max):
    """Aggregate-IS Buzen recursion in plain jnp (see repro.core.buzen)."""
    from jax.scipy.special import gammaln
    from ..core.buzen import _log_conv, _geometric_series

    k = jnp.arange(m_max + 1, dtype=jnp.float64)
    logZ = (k * log_gamma_total - gammaln(k + 1.0))
    for i in range(log_rho.shape[0]):
        logZ = _log_conv(logZ, _geometric_series(log_rho[i], m_max))
    return logZ


def fused_async_update_oracle(params, grads, scale):
    new = jax.tree_util.tree_map(
        lambda w, g: (w.astype(jnp.float32)
                      - jnp.float32(scale) * g.astype(jnp.float32)
                      ).astype(w.dtype), params, grads)
    norm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree_util.tree_leaves(grads)))
    return new, norm
