"""Pallas TPU flash attention (GQA, causal, sliding-window).

Tiling: queries in ``(block_q, D)`` VMEM tiles; K/V streamed in
``(block_k, D)`` tiles along the last (sequential) grid dimension with the
online-softmax accumulators (m, l, acc) held in VMEM scratch across k-steps
— the canonical TPU "revisiting" schedule.  GQA is expressed in the index
maps: the flattened head axis is ``(b * KV + n) * G + g`` so the K/V block
index is just ``head // G`` (no materialized head repetition).

The container is CPU-only; the kernel is validated in ``interpret=True``
mode against ``ref.flash_attention_oracle`` and targets TPU for deployment.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: Optional[int],
                  block_q: int, block_k: int, seq_k: int, n_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale          # [block_q, D]
    k = k_ref[0].astype(jnp.float32)                  # [block_k, D]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [bq, bk]

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    ok = k_pos < seq_k
    if causal:
        ok &= k_pos <= q_pos
    if window is not None:
        ok &= k_pos > q_pos - window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1)
    v = v_ref[0].astype(jnp.float32)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot(p, v)
    m_scr[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,  # [B, S_q, H, D]
    k: jax.Array,  # [B, S_k, KV, D]
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = D ** -0.5

    n_q = -(-Sq // block_q)
    n_k = -(-Sk // block_k)
    pad_q = n_q * block_q - Sq
    pad_k = n_k * block_k - Sk

    # [BH, S, D] with head-major = (b, kv, g)
    qf = jnp.moveaxis(q, 2, 1).reshape(B * H, Sq, D)
    kf = jnp.moveaxis(k, 2, 1).reshape(B * KV, Sk, D)
    vf = jnp.moveaxis(v, 2, 1).reshape(B * KV, Sk, D)
    if pad_q:
        qf = jnp.pad(qf, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kf = jnp.pad(kf, ((0, 0), (0, pad_k), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad_k), (0, 0)))

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, seq_k=Sk, n_k=n_k)

    out = pl.pallas_call(
        kernel,
        grid=(B * H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda h, qi, ki: (h, qi, 0)),
            pl.BlockSpec((1, block_k, D), lambda h, qi, ki: (h // G, ki, 0)),
            pl.BlockSpec((1, block_k, D), lambda h, qi, ki: (h // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda h, qi, ki: (h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, n_q * block_q, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=None if interpret else pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(qf, kf, vf)

    out = out[:, :Sq].reshape(B, H, Sq, D)
    return jnp.moveaxis(out, 1, 2)
