"""Pallas TPU kernel for the closed-network event-engine hot path.

One event of the Fig. 1 / Fig. 6 dynamics = one call of
:func:`repro.core.events.step_event`: an argmin over the ``[m_max]``
finish-clock table, a masked phase/routing transition of the completed
slot, and up to two FIFO promotions (compute queue, CS queue).  All of it
is vectorizable over the table axis and embarrassingly parallel over
simulation *lanes* (seeds x strategy lanes x scenarios), which is exactly
the TPU layout of this kernel:

  * grid ``(K,)`` — one program per lane, ``parallel`` semantics;
  * the lane's five table rows (``finish``/``phase``/``client``/``seq``/
    ``disp_round``, each ``[m_max]``) live in VMEM blocks; the argmin and
    both FIFO picks are first-index reductions over ``broadcasted_iota``
    masks (no sequential scan over slots);
  * the phase promotion / routing / FIFO transition is fused into the same
    kernel as vectorized masked writes (one-hot ``where`` updates).

Randomness stays OUTSIDE the kernel: per-event service variates are drawn
by the registered timing law (``repro.scenario.laws.device_draw``) at unit
rate and the kernel rescales them by the completing client's rate
(``e / mu[c]``) — exact (bitwise) for the scale-family laws whose unit
draw is ``rate``-free (exponential, deterministic) and equal up to one
floating-point rescale otherwise (lognormal, hyperexponential).  The
dispatch-routing draw (``C ~ p``) and the draws whose rate is known before
the argmin (downlink of the re-dispatched task, CS service) are computed
entirely outside, bit-identical to the reference engine.

Like the Buzen kernel, the compiled path targets TPU and everything is
validated in ``interpret=True`` mode on CPU (``tests/test_sim_backends.py``)
against the jnp oracle (``repro.kernels.ref.event_step_oracle``) and the
reference engine; statistics accumulation (occupancy, energy, delay sums)
remains regular jnp around the kernel call (see
``repro.sim.batched_events``).
"""
from __future__ import annotations
# contract: padded-n — reductions here are on the bitwise padding contract

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core import events as E
from ..core.buzen import NetworkParams
from ..core.numerics import seqsum
from ..scenario.laws import get_law

_BIG_SEQ = E._BIG_SEQ


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _first_index_min(values, idx, size: int):
    """First index attaining ``min(values)`` — the TPU-friendly argmin."""
    v_min = jnp.min(values)
    return v_min, jnp.min(jnp.where(values == v_min, idx, size))


def _event_kernel(finish_ref, phase_ref, client_ref, seq_ref, disp_ref,
                  mu_c_ref, mu_u_ref, fscal_ref, iscal_ref,
                  o_finish_ref, o_phase_ref, o_client_ref, o_seq_ref,
                  o_disp_ref, o_t_ref, o_int_ref, *,
                  has_cs: bool, m_max: int, n: int):
    finish = finish_ref[...]   # (1, m_max) float
    phase = phase_ref[...]     # (1, m_max) int32
    client = client_ref[...]
    seq = seq_ref[...]
    disp = disp_ref[...]
    idx = jax.lax.broadcasted_iota(jnp.int32, (1, m_max), 1)
    cli = jax.lax.broadcasted_iota(jnp.int32, (1, n), 1)

    e_up = fscal_ref[0, 0]     # unit-rate service variates (see module doc)
    e_comp = fscal_ref[0, 1]
    svc_down = fscal_ref[0, 2]  # fully drawn outside (rate known pre-argmin)
    svc_cs = fscal_ref[0, 3]
    c_new = iscal_ref[0, 0]
    seq_ctr = iscal_ref[0, 1]
    rnd = iscal_ref[0, 2]

    def gather_i(table, j):
        # x64 mode promotes integer sums to int64: pin the gather to i32
        # contract: allow(raw-reduction): one-hot gather — exactly one non-zero term, bitwise under any association
        return jnp.sum(jnp.where(idx == j, table, 0)).astype(jnp.int32)

    def gather_rate(row_ref, c):
        # contract: allow(raw-reduction): one-hot gather — exactly one non-zero term, bitwise under any association
        return jnp.sum(jnp.where(cli == c, row_ref[...], 0.0))

    # -- the completing slot (parallel argmin over the clock table) ---------
    t_new, j = _first_index_min(finish, idx, m_max)
    onej = idx == j
    c = gather_i(client, j)
    ph = gather_i(phase, j)
    delay = rnd - gather_i(disp, j)

    is_down = ph == E.DOWN
    is_comp = ph == E.COMP_SERV
    is_up = ph == E.UP
    is_cs = ph == E.CS_SERV
    is_update = is_cs if has_cs else is_up
    new_round = rnd + jnp.where(is_update, 1, 0).astype(jnp.int32)

    svc_up = e_up / gather_rate(mu_u_ref, c)
    svc_c = e_comp / gather_rate(mu_c_ref, c)

    # -- fused phase promotion / routing of slot j --------------------------
    phase_j = jnp.where(
        is_down, E.COMP_WAIT,
        jnp.where(is_comp, E.UP, jnp.where(is_update, E.DOWN, E.CS_WAIT)))
    finish_j = jnp.where(
        is_comp, t_new + svc_up,
        jnp.where(is_update, t_new + svc_down, jnp.inf))
    joins_fifo = is_down | (is_up & has_cs)
    seq_j = jnp.where(joins_fifo, seq_ctr, gather_i(seq, j))
    new_seq_ctr = seq_ctr + joins_fifo.astype(jnp.int32)
    client_j = jnp.where(is_update, c_new, c)
    disp_j = jnp.where(is_update, new_round, gather_i(disp, j))

    phase = jnp.where(onej, phase_j, phase).astype(jnp.int32)
    finish = jnp.where(onej, finish_j, finish)
    seq = jnp.where(onej, seq_j, seq).astype(jnp.int32)
    client = jnp.where(onej, client_j, client).astype(jnp.int32)
    disp = jnp.where(onej, disp_j, disp).astype(jnp.int32)

    # -- FIFO promotion at the compute station of client c ------------------
    promo_comp = is_down | is_comp
    # contract: allow(raw-reduction): int32 indicator count over the [m_max] table — exact integer arithmetic, and the table axis is never padded-n
    serving_c = jnp.sum(((phase == E.COMP_SERV) & (client == c))
                        .astype(jnp.int32)) > 0
    waiting_c = (phase == E.COMP_WAIT) & (client == c)
    vals = jnp.where(waiting_c, seq, _BIG_SEQ)
    _, pick = _first_index_min(vals, idx, m_max)
    # contract: allow(raw-reduction): int32 indicator count over the [m_max] table — exact integer arithmetic, and the table axis is never padded-n
    any_wait = jnp.sum(waiting_c.astype(jnp.int32)) > 0
    do_comp = promo_comp & ~serving_c & any_wait
    onep = (idx == pick) & do_comp
    phase = jnp.where(onep, E.COMP_SERV, phase)
    finish = jnp.where(onep, t_new + svc_c, finish)

    if has_cs:
        # -- FIFO promotion at the CS single-server queue -------------------
        promo_cs = is_up | is_cs
        cs_waiting = phase == E.CS_WAIT
        vals_cs = jnp.where(cs_waiting, seq, _BIG_SEQ)
        _, pick_cs = _first_index_min(vals_cs, idx, m_max)
        # contract: allow(raw-reduction): int32 indicator count over the [m_max] table — exact integer arithmetic, and the table axis is never padded-n
        cs_busy = jnp.sum((phase == E.CS_SERV).astype(jnp.int32)) > 0
        # contract: allow(raw-reduction): int32 indicator count over the [m_max] table — exact integer arithmetic, and the table axis is never padded-n
        any_cs_wait = jnp.sum(cs_waiting.astype(jnp.int32)) > 0
        do_cs = promo_cs & ~cs_busy & any_cs_wait
        onec = (idx == pick_cs) & do_cs
        phase = jnp.where(onec, E.CS_SERV, phase)
        finish = jnp.where(onec, t_new + svc_cs, finish)

    o_finish_ref[...] = finish
    o_phase_ref[...] = phase
    o_client_ref[...] = client
    o_seq_ref[...] = seq
    o_disp_ref[...] = disp
    o_t_ref[0, 0] = t_new
    o_int_ref[0, 0] = j
    o_int_ref[0, 1] = c
    o_int_ref[0, 2] = jnp.where(is_update, 1, 0).astype(jnp.int32)
    o_int_ref[0, 3] = delay
    o_int_ref[0, 4] = new_seq_ctr
    o_int_ref[0, 5] = new_round
    # transition descriptors for the caller's O(1) occupancy maintenance
    o_int_ref[0, 6] = ph
    o_int_ref[0, 7] = jnp.where(do_comp, 1, 0).astype(jnp.int32)
    o_int_ref[0, 8] = (jnp.where(do_cs, 1, 0).astype(jnp.int32) if has_cs
                       else jnp.zeros((), jnp.int32))


@functools.partial(jax.jit, static_argnames=("has_cs", "interpret"))
def event_step_tables(finish, phase, client, seq, disp_round, mu_c, mu_u,
                      fscal, iscal, *, has_cs: bool,
                      interpret: Optional[bool] = None):
    """One event per lane on ``K`` stacked task tables.

    Tables are ``[K, m_max]`` (``finish`` float, the rest int32), rates
    ``[K, n]``; ``fscal = [e_up, e_comp, svc_down, svc_cs]`` float ``[K, 4]``
    and ``iscal = [c_new, seq_ctr, round]`` int32 ``[K, 3]`` carry the
    per-lane outside-drawn randomness and counters.  Returns the five
    updated tables plus ``t_new [K, 1]`` and
    ``[j, c, is_update, delay, seq_ctr', round', ph_pre, do_comp, do_cs]``
    ``[K, 9]``.
    """
    interp = default_interpret() if interpret is None else interpret
    K, m_max = finish.shape
    n = mu_c.shape[1]
    kernel = functools.partial(_event_kernel, has_cs=has_cs, m_max=m_max,
                               n=n)
    row = lambda w: pl.BlockSpec((1, w), lambda k: (k, 0))  # noqa: E731
    return pl.pallas_call(
        kernel,
        grid=(K,),
        in_specs=[row(m_max)] * 5 + [row(n)] * 2 + [row(4), row(3)],
        out_specs=[row(m_max)] * 5 + [row(1), row(9)],
        out_shape=[
            jax.ShapeDtypeStruct((K, m_max), finish.dtype),
            jax.ShapeDtypeStruct((K, m_max), jnp.int32),
            jax.ShapeDtypeStruct((K, m_max), jnp.int32),
            jax.ShapeDtypeStruct((K, m_max), jnp.int32),
            jax.ShapeDtypeStruct((K, m_max), jnp.int32),
            jax.ShapeDtypeStruct((K, 1), finish.dtype),
            jax.ShapeDtypeStruct((K, 9), jnp.int32),
        ],
        interpret=interp,
    )(finish, phase, client, seq, disp_round, mu_c, mu_u, fscal, iscal)


# ---------------------------------------------------------------------------
# EventState-level wrapper: statistics in jnp around the kernel transition
# ---------------------------------------------------------------------------

def _lane_randomness(params: NetworkParams, state, distribution: str,
                     has_cs: bool):
    """Per-lane key split + outside draws, bit-matching the reference
    engine's stream (same split arity, same key roles — including the
    padding-invariant inverse-CDF routing draw of
    ``repro.core.events._route_client``)."""
    law = get_law(distribution)
    dtype = state.finish.dtype
    K, n = params.p.shape
    n_acts = (params.n_active if params.n_active is not None
              else jnp.full((K,), n))

    def one(key, p_row, mu_d_row, mu_cs_i, n_act):
        key, k_up, k_disp_cli, k_disp_svc, k_comp, k_cs = jax.random.split(
            key, 6)
        c_new = E._route_client(p_row, k_disp_cli, n_act)
        one_rate = jnp.ones((), dtype)
        e_up = law.device_draw(k_up, one_rate)
        e_comp = law.device_draw(k_comp, one_rate)
        svc_down = law.device_draw(k_disp_svc, mu_d_row[c_new])
        svc_cs = (law.device_draw(k_cs, mu_cs_i) if has_cs
                  else jnp.zeros((), dtype))
        fscal = jnp.stack([e_up, e_comp, svc_down, svc_cs]).astype(dtype)
        return key, c_new, fscal

    mu_cs = params.mu_cs if has_cs else jnp.zeros_like(params.p[..., 0])
    return jax.vmap(one)(state.key, params.p, params.mu_d, mu_cs, n_acts)


def step_event_pallas(params: NetworkParams, state, *,
                      distribution: str = "exponential", power=None,
                      interpret: Optional[bool] = None):
    """Batched-lane analogue of :func:`repro.core.events.step_event`.

    ``state`` leaves carry a leading lane axis ``[K, ...]`` and ``params``
    (and ``power``) leaves ``[K, n]``; the statistics window accumulation
    is plain (vmapped) jnp, the table transition runs in the Pallas kernel.
    Returns the batched ``(EventState, EventOut)``.
    """
    n = params.p.shape[-1]
    has_cs = params.mu_cs is not None

    keys, c_new, fscal = _lane_randomness(params, state, distribution,
                                          has_cs)
    iscal = jnp.stack(
        [c_new, state.seq_ctr, state.round], axis=-1).astype(jnp.int32)
    finish, phase, client, seq, disp, t_col, int_col = event_step_tables(
        state.finish, state.phase, state.client, state.seq, state.disp_round,
        params.mu_c, params.mu_u, fscal, iscal, has_cs=has_cs,
        interpret=interpret)
    t_new = t_col[:, 0]
    c = int_col[:, 1]
    is_update = int_col[:, 2] > 0
    delay = int_col[:, 3]
    seq_ctr = int_col[:, 4]
    new_round = int_col[:, 5]
    ph_pre = int_col[:, 6]
    do_comp = int_col[:, 7] > 0
    do_cs = int_col[:, 8] > 0

    # -- statistics over the sojourn ending at this event (pre-event state),
    # line-for-line the reference engine's accumulation, vmapped over lanes
    def lane_stats(st, t_new, c, is_update, delay, pw):
        measure = (st.round >= st.warmup) & (st.round < st.cap)
        dt_eff = jnp.where(
            measure,
            jnp.clip(jnp.minimum(t_new, st.t_cap)
                     - jnp.minimum(st.t, st.t_cap), 0.0, None),
            0.0)
        occ_int = st.occ_int + dt_eff * st.occ
        energy = st.energy
        if pw is not None:
            p_w = seqsum(pw.P_c * st.serving
                         + pw.P_u * st.occ[2 * n:3 * n]
                         + pw.P_d * st.occ[:n])
            if pw.P_cs is not None:
                p_w = p_w + pw.P_cs * st.cs_busy
            energy = energy + dt_eff * p_w
        upd_measured = is_update & measure
        delay_sum = st.delay_sum.at[c].add(
            jnp.where(upd_measured, delay.astype(st.delay_sum.dtype), 0.0))
        delay_cnt = st.delay_cnt.at[c].add(
            jnp.where(upd_measured, 1, 0).astype(jnp.int32))
        return occ_int, energy, delay_sum, delay_cnt

    if power is None:
        occ_int, energy, delay_sum, delay_cnt = jax.vmap(
            lambda st, t, c, u, d: lane_stats(st, t, c, u, d, None))(
                state, t_new, c, is_update, delay)
    else:
        occ_int, energy, delay_sum, delay_cnt = jax.vmap(lane_stats)(
            state, t_new, c, is_update, delay, power)

    # -- O(1) maintenance of the occupancy carries, mirroring step_event
    # (the kernel reports the slot-j transition; promotions stay within
    # their station and only flip the busy indicators)
    is_comp = ph_pre == E.COMP_SERV
    is_down = ph_pre == E.DOWN
    is_cs = ph_pre == E.CS_SERV
    phase_j = jnp.where(
        is_down, E.COMP_WAIT,
        jnp.where(is_comp, E.UP, jnp.where(is_update, E.DOWN, E.CS_WAIT)))
    client_j = jnp.where(is_update, c_new, c)
    stations = jnp.arange(3 * n + 1)
    occ_new = (state.occ
               + jnp.where(stations[None, :]
                           == E._station_index(phase_j, client_j, n)[:, None],
                           1.0, 0.0)
               - jnp.where(stations[None, :]
                           == E._station_index(ph_pre, c, n)[:, None],
                           1.0, 0.0))
    delta_srv = (jnp.where(do_comp, 1.0, 0.0)
                 - jnp.where(is_comp, 1.0, 0.0))
    serving_new = state.serving + jnp.where(
        jnp.arange(n)[None, :] == c[:, None], delta_srv[:, None], 0.0)
    cs_busy_new = ((state.cs_busy & ~is_cs) | do_cs if has_cs
                   else state.cs_busy)

    t0 = jnp.where(is_update & (new_round == state.warmup), t_new, state.t0)
    t1 = jnp.where(is_update & (new_round == state.cap), t_new, state.t1)

    new_state = E.EventState(
        t=t_new, key=keys, round=new_round, seq_ctr=seq_ctr,
        client=client, phase=phase, finish=finish, seq=seq,
        disp_round=disp,
        warmup=state.warmup, cap=state.cap, t_cap=state.t_cap,
        t0=t0, t1=t1, delay_sum=delay_sum, delay_cnt=delay_cnt,
        energy=energy, occ_int=occ_int,
        occ=occ_new, serving=serving_new, cs_busy=cs_busy_new)
    out = E.EventOut(is_update=is_update, time=t_new,
                     slot=int_col[:, 0], client=c, delay=delay)
    return new_state, out


def step_event_pallas1(params: NetworkParams, state, *,
                       distribution: str = "exponential", power=None,
                       interpret: Optional[bool] = None):
    """Single-lane signature-compatible drop-in for ``events.step_event``
    (adds/strips a K=1 lane axis; batches further via vmap's pallas rule)."""
    up = lambda x: x[None]  # noqa: E731
    st, out = step_event_pallas(
        jax.tree_util.tree_map(up, params),
        jax.tree_util.tree_map(up, state),
        distribution=distribution,
        power=None if power is None else jax.tree_util.tree_map(up, power),
        interpret=interpret)
    down = lambda x: x[0]  # noqa: E731
    return (jax.tree_util.tree_map(down, st),
            jax.tree_util.tree_map(down, out))
