"""Pallas TPU kernel for the closed-network event-engine hot path.

One event of the Fig. 1 / Fig. 6 dynamics = one call of
:func:`repro.core.events.step_event`: an argmin over the ``[m_max]``
finish-clock table, a masked phase/routing transition of the completed
slot, and up to two FIFO promotions (compute queue, CS queue).  All of it
is vectorizable over the table axis and embarrassingly parallel over
simulation *lanes* (seeds x strategy lanes x scenarios), which is exactly
the TPU layout of this kernel:

  * grid ``(K,)`` — one program per lane, ``parallel`` semantics;
  * the lane's five table rows (``finish``/``phase``/``client``/``seq``/
    ``disp_round``, each ``[m_max]``) live in VMEM blocks; the argmin and
    both FIFO picks are first-index reductions over ``broadcasted_iota``
    masks (no sequential scan over slots);
  * the phase promotion / routing / FIFO transition is fused into the same
    kernel as vectorized masked writes (one-hot ``where`` updates).

Randomness stays OUTSIDE the kernel: per-event service variates are drawn
by the registered timing law (``repro.scenario.laws.device_draw``) at unit
rate and the kernel rescales them by the completing client's rate
(``e / mu[c]``) — exact (bitwise) for the scale-family laws whose unit
draw is ``rate``-free (exponential, deterministic) and equal up to one
floating-point rescale otherwise (lognormal, hyperexponential).  The
dispatch-routing draw (``C ~ p``) and the draws whose rate is known before
the argmin (downlink of the re-dispatched task, CS service) are computed
entirely outside, bit-identical to the reference engine.

Like the Buzen kernel, the compiled path targets TPU and everything is
validated in ``interpret=True`` mode on CPU (``tests/test_sim_backends.py``)
against the jnp oracle (``repro.kernels.ref.event_step_oracle``) and the
reference engine; statistics accumulation (occupancy, energy, delay sums)
remains regular jnp around the kernel call (see
``repro.sim.batched_events``).
"""
from __future__ import annotations
# contract: padded-n — reductions here are on the bitwise padding contract

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core import events as E
from ..core.buzen import NetworkParams
from ..core.numerics import seqsum
from ..scenario.laws import get_law

_BIG_SEQ = E._BIG_SEQ


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _first_index_min(values, idx, size: int):
    """First index attaining ``min(values)`` — the TPU-friendly argmin."""
    v_min = jnp.min(values)
    return v_min, jnp.min(jnp.where(values == v_min, idx, size))


def _one_event(tbl, mu_c, mu_u, rand, idx, cli, *,
               has_cs: bool, m_max: int, n: int):
    """One table transition at registers — the shared kernel body.

    ``tbl = (finish, phase, client, seq, disp)`` are the lane's loaded
    ``(1, m_max)`` rows, ``mu_c``/``mu_u`` its loaded ``(1, n)`` rate rows
    and ``rand = (e_up, e_comp, svc_down, svc_cs, c_new, seq_ctr, rnd)``
    the event's outside-drawn scalars and counters.  Returns the updated
    table rows plus the transition descriptors; :func:`_event_kernel`
    calls it once per launch, :func:`_megastep_kernel` ``chunk`` times per
    launch with keep-masked selects in between (identical primitives —
    the megastep trajectory is bitwise the single-step one).
    """
    finish, phase, client, seq, disp = tbl
    e_up, e_comp, svc_down, svc_cs, c_new, seq_ctr, rnd = rand

    def gather_i(table, j):
        # x64 mode promotes integer sums to int64: pin the gather to i32
        # contract: allow(raw-reduction): one-hot gather — exactly one non-zero term, bitwise under any association
        return jnp.sum(jnp.where(idx == j, table, 0)).astype(jnp.int32)

    def gather_rate(row, c):
        # contract: allow(raw-reduction): one-hot gather — exactly one non-zero term, bitwise under any association
        return jnp.sum(jnp.where(cli == c, row, 0.0))

    # -- the completing slot (parallel argmin over the clock table) ---------
    t_new, j = _first_index_min(finish, idx, m_max)
    onej = idx == j
    c = gather_i(client, j)
    ph = gather_i(phase, j)
    delay = rnd - gather_i(disp, j)

    is_down = ph == E.DOWN
    is_comp = ph == E.COMP_SERV
    is_up = ph == E.UP
    is_cs = ph == E.CS_SERV
    is_update = is_cs if has_cs else is_up
    new_round = rnd + jnp.where(is_update, 1, 0).astype(jnp.int32)

    svc_up = e_up / gather_rate(mu_u, c)
    svc_c = e_comp / gather_rate(mu_c, c)

    # -- fused phase promotion / routing of slot j --------------------------
    phase_j = jnp.where(
        is_down, E.COMP_WAIT,
        jnp.where(is_comp, E.UP, jnp.where(is_update, E.DOWN, E.CS_WAIT)))
    finish_j = jnp.where(
        is_comp, t_new + svc_up,
        jnp.where(is_update, t_new + svc_down, jnp.inf))
    joins_fifo = is_down | (is_up & has_cs)
    seq_j = jnp.where(joins_fifo, seq_ctr, gather_i(seq, j))
    new_seq_ctr = seq_ctr + joins_fifo.astype(jnp.int32)
    client_j = jnp.where(is_update, c_new, c)
    disp_j = jnp.where(is_update, new_round, gather_i(disp, j))

    phase = jnp.where(onej, phase_j, phase).astype(jnp.int32)
    finish = jnp.where(onej, finish_j, finish)
    seq = jnp.where(onej, seq_j, seq).astype(jnp.int32)
    client = jnp.where(onej, client_j, client).astype(jnp.int32)
    disp = jnp.where(onej, disp_j, disp).astype(jnp.int32)

    # -- FIFO promotion at the compute station of client c ------------------
    promo_comp = is_down | is_comp
    # contract: allow(raw-reduction): int32 indicator count over the [m_max] table — exact integer arithmetic, and the table axis is never padded-n
    serving_c = jnp.sum(((phase == E.COMP_SERV) & (client == c))
                        .astype(jnp.int32)) > 0
    waiting_c = (phase == E.COMP_WAIT) & (client == c)
    vals = jnp.where(waiting_c, seq, _BIG_SEQ)
    _, pick = _first_index_min(vals, idx, m_max)
    # contract: allow(raw-reduction): int32 indicator count over the [m_max] table — exact integer arithmetic, and the table axis is never padded-n
    any_wait = jnp.sum(waiting_c.astype(jnp.int32)) > 0
    do_comp = promo_comp & ~serving_c & any_wait
    onep = (idx == pick) & do_comp
    phase = jnp.where(onep, E.COMP_SERV, phase)
    finish = jnp.where(onep, t_new + svc_c, finish)

    if has_cs:
        # -- FIFO promotion at the CS single-server queue -------------------
        promo_cs = is_up | is_cs
        cs_waiting = phase == E.CS_WAIT
        vals_cs = jnp.where(cs_waiting, seq, _BIG_SEQ)
        _, pick_cs = _first_index_min(vals_cs, idx, m_max)
        # contract: allow(raw-reduction): int32 indicator count over the [m_max] table — exact integer arithmetic, and the table axis is never padded-n
        cs_busy = jnp.sum((phase == E.CS_SERV).astype(jnp.int32)) > 0
        # contract: allow(raw-reduction): int32 indicator count over the [m_max] table — exact integer arithmetic, and the table axis is never padded-n
        any_cs_wait = jnp.sum(cs_waiting.astype(jnp.int32)) > 0
        do_cs = promo_cs & ~cs_busy & any_cs_wait
        onec = (idx == pick_cs) & do_cs
        phase = jnp.where(onec, E.CS_SERV, phase)
        finish = jnp.where(onec, t_new + svc_cs, finish)
    else:
        do_cs = jnp.zeros((), jnp.bool_)

    desc = (t_new, j, c, is_update, delay, new_seq_ctr, new_round, ph,
            do_comp, do_cs)
    return (finish, phase, client, seq, disp), desc


def _event_kernel(finish_ref, phase_ref, client_ref, seq_ref, disp_ref,
                  mu_c_ref, mu_u_ref, fscal_ref, iscal_ref,
                  o_finish_ref, o_phase_ref, o_client_ref, o_seq_ref,
                  o_disp_ref, o_t_ref, o_int_ref, *,
                  has_cs: bool, m_max: int, n: int):
    tbl = (finish_ref[...],   # (1, m_max) float
           phase_ref[...],    # (1, m_max) int32
           client_ref[...], seq_ref[...], disp_ref[...])
    idx = jax.lax.broadcasted_iota(jnp.int32, (1, m_max), 1)
    cli = jax.lax.broadcasted_iota(jnp.int32, (1, n), 1)
    rand = (fscal_ref[0, 0],   # e_up: unit-rate variate (see module doc)
            fscal_ref[0, 1],   # e_comp
            fscal_ref[0, 2],   # svc_down: drawn outside (rate pre-argmin)
            fscal_ref[0, 3],   # svc_cs
            iscal_ref[0, 0],   # c_new
            iscal_ref[0, 1],   # seq_ctr
            iscal_ref[0, 2])   # round

    tbl, desc = _one_event(tbl, mu_c_ref[...], mu_u_ref[...], rand, idx, cli,
                           has_cs=has_cs, m_max=m_max, n=n)
    finish, phase, client, seq, disp = tbl
    (t_new, j, c, is_update, delay, new_seq_ctr, new_round, ph,
     do_comp, do_cs) = desc

    o_finish_ref[...] = finish
    o_phase_ref[...] = phase
    o_client_ref[...] = client
    o_seq_ref[...] = seq
    o_disp_ref[...] = disp
    o_t_ref[0, 0] = t_new
    o_int_ref[0, 0] = j
    o_int_ref[0, 1] = c
    o_int_ref[0, 2] = jnp.where(is_update, 1, 0).astype(jnp.int32)
    o_int_ref[0, 3] = delay
    o_int_ref[0, 4] = new_seq_ctr
    o_int_ref[0, 5] = new_round
    # transition descriptors for the caller's O(1) occupancy maintenance
    o_int_ref[0, 6] = ph
    o_int_ref[0, 7] = jnp.where(do_comp, 1, 0).astype(jnp.int32)
    o_int_ref[0, 8] = jnp.where(do_cs, 1, 0).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("has_cs", "interpret"))
def event_step_tables(finish, phase, client, seq, disp_round, mu_c, mu_u,
                      fscal, iscal, *, has_cs: bool,
                      interpret: Optional[bool] = None):
    """One event per lane on ``K`` stacked task tables.

    Tables are ``[K, m_max]`` (``finish`` float, the rest int32), rates
    ``[K, n]``; ``fscal = [e_up, e_comp, svc_down, svc_cs]`` float ``[K, 4]``
    and ``iscal = [c_new, seq_ctr, round]`` int32 ``[K, 3]`` carry the
    per-lane outside-drawn randomness and counters.  Returns the five
    updated tables plus ``t_new [K, 1]`` and
    ``[j, c, is_update, delay, seq_ctr', round', ph_pre, do_comp, do_cs]``
    ``[K, 9]``.
    """
    interp = default_interpret() if interpret is None else interpret
    K, m_max = finish.shape
    n = mu_c.shape[1]
    kernel = functools.partial(_event_kernel, has_cs=has_cs, m_max=m_max,
                               n=n)
    row = lambda w: pl.BlockSpec((1, w), lambda k: (k, 0))  # noqa: E731
    return pl.pallas_call(
        kernel,
        grid=(K,),
        in_specs=[row(m_max)] * 5 + [row(n)] * 2 + [row(4), row(3)],
        out_specs=[row(m_max)] * 5 + [row(1), row(9)],
        out_shape=[
            jax.ShapeDtypeStruct((K, m_max), finish.dtype),
            jax.ShapeDtypeStruct((K, m_max), jnp.int32),
            jax.ShapeDtypeStruct((K, m_max), jnp.int32),
            jax.ShapeDtypeStruct((K, m_max), jnp.int32),
            jax.ShapeDtypeStruct((K, m_max), jnp.int32),
            jax.ShapeDtypeStruct((K, 1), finish.dtype),
            jax.ShapeDtypeStruct((K, 9), jnp.int32),
        ],
        interpret=interp,
    )(finish, phase, client, seq, disp_round, mu_c, mu_u, fscal, iscal)


def _megastep_kernel(finish_ref, phase_ref, client_ref, seq_ref, disp_ref,
                     mu_c_ref, mu_u_ref, fscal_ref, iscal_ref,
                     o_finish_ref, o_phase_ref, o_client_ref, o_seq_ref,
                     o_disp_ref, o_t_ref, o_int_ref, *,
                     has_cs: bool, m_max: int, n: int, chunk: int,
                     stop_on_update: bool):
    """Retire up to ``chunk`` events per launch against the resident table.

    The lane's rows load once into VMEM registers and an unrolled
    in-kernel loop applies :func:`_one_event` ``chunk`` times with
    keep-masked selects between iterations — amortizing the launch (and
    the five table round-trips) over ``chunk`` events.  ``keep_i = (i <
    rem) & ~done`` masks the tail of a partial chunk; ``stop_on_update``
    latches ``done`` after the first retired update (the trainer's
    ``next_update`` megastep).  Masked iterations still *compute* a
    transition (values stay in-range: the argmin of an untouched table)
    but select the old rows, so the loop is branch-free; descriptors are
    written unconditionally and the wrapper masks them by the ``keep``
    column.
    """
    tbl = (finish_ref[...], phase_ref[...], client_ref[...], seq_ref[...],
           disp_ref[...])
    mu_c = mu_c_ref[...]
    mu_u = mu_u_ref[...]
    idx = jax.lax.broadcasted_iota(jnp.int32, (1, m_max), 1)
    cli = jax.lax.broadcasted_iota(jnp.int32, (1, n), 1)
    seq_ctr = iscal_ref[0, 0]
    rnd = iscal_ref[0, 1]
    rem = iscal_ref[0, 2]
    done = jnp.zeros((), jnp.bool_)

    for i in range(chunk):
        rand = (fscal_ref[0, 4 * i + 0], fscal_ref[0, 4 * i + 1],
                fscal_ref[0, 4 * i + 2], fscal_ref[0, 4 * i + 3],
                iscal_ref[0, 3 + i], seq_ctr, rnd)
        tbl2, desc = _one_event(tbl, mu_c, mu_u, rand, idx, cli,
                                has_cs=has_cs, m_max=m_max, n=n)
        (t_new, j, c, is_update, delay, new_seq_ctr, new_round, ph,
         do_comp, do_cs) = desc
        keep = i < rem
        if stop_on_update:
            keep = keep & ~done
            done = done | (keep & is_update)
        tbl = tuple(jnp.where(keep, a, b) for a, b in zip(tbl2, tbl))
        seq_ctr = jnp.where(keep, new_seq_ctr, seq_ctr)
        rnd = jnp.where(keep, new_round, rnd)
        o_t_ref[0, i] = t_new
        o_int_ref[0, 10 * i + 0] = j
        o_int_ref[0, 10 * i + 1] = c
        o_int_ref[0, 10 * i + 2] = jnp.where(is_update, 1, 0).astype(
            jnp.int32)
        o_int_ref[0, 10 * i + 3] = delay
        o_int_ref[0, 10 * i + 4] = new_seq_ctr
        o_int_ref[0, 10 * i + 5] = new_round
        o_int_ref[0, 10 * i + 6] = ph
        o_int_ref[0, 10 * i + 7] = jnp.where(do_comp, 1, 0).astype(jnp.int32)
        o_int_ref[0, 10 * i + 8] = jnp.where(do_cs, 1, 0).astype(jnp.int32)
        o_int_ref[0, 10 * i + 9] = jnp.where(keep, 1, 0).astype(jnp.int32)

    finish, phase, client, seq, disp = tbl
    o_finish_ref[...] = finish
    o_phase_ref[...] = phase
    o_client_ref[...] = client
    o_seq_ref[...] = seq
    o_disp_ref[...] = disp


@functools.partial(jax.jit, static_argnames=("has_cs", "chunk",
                                             "stop_on_update", "interpret"))
def megastep_tables(finish, phase, client, seq, disp_round, mu_c, mu_u,
                    fscal, iscal, *, has_cs: bool, chunk: int,
                    stop_on_update: bool = False,
                    interpret: Optional[bool] = None):
    """Up to ``chunk`` events per lane, one launch per lane.

    The chunked analogue of :func:`event_step_tables`: ``fscal`` is
    ``[K, 4 * chunk]`` (``[e_up, e_comp, svc_down, svc_cs]`` per event)
    and ``iscal`` ``[K, 3 + chunk]`` (``[seq_ctr, round, rem]`` then the
    ``chunk`` routed clients).  Returns the five updated tables plus the
    per-event times ``[K, chunk]`` and descriptors ``[K, 10 * chunk]``
    (the single-step 9 columns plus the ``keep`` mask per event).
    """
    interp = default_interpret() if interpret is None else interpret
    K, m_max = finish.shape
    n = mu_c.shape[1]
    kernel = functools.partial(_megastep_kernel, has_cs=has_cs, m_max=m_max,
                               n=n, chunk=chunk,
                               stop_on_update=stop_on_update)
    row = lambda w: pl.BlockSpec((1, w), lambda k: (k, 0))  # noqa: E731
    return pl.pallas_call(
        kernel,
        grid=(K,),
        in_specs=[row(m_max)] * 5 + [row(n)] * 2
        + [row(4 * chunk), row(3 + chunk)],
        out_specs=[row(m_max)] * 5 + [row(chunk), row(10 * chunk)],
        out_shape=[
            jax.ShapeDtypeStruct((K, m_max), finish.dtype),
            jax.ShapeDtypeStruct((K, m_max), jnp.int32),
            jax.ShapeDtypeStruct((K, m_max), jnp.int32),
            jax.ShapeDtypeStruct((K, m_max), jnp.int32),
            jax.ShapeDtypeStruct((K, m_max), jnp.int32),
            jax.ShapeDtypeStruct((K, chunk), finish.dtype),
            jax.ShapeDtypeStruct((K, 10 * chunk), jnp.int32),
        ],
        interpret=interp,
    )(finish, phase, client, seq, disp_round, mu_c, mu_u, fscal, iscal)


# ---------------------------------------------------------------------------
# EventState-level wrapper: statistics in jnp around the kernel transition
# ---------------------------------------------------------------------------

def _lane_randomness(params: NetworkParams, state, distribution: str,
                     has_cs: bool):
    """Per-lane key split + outside draws, bit-matching the reference
    engine's stream (same split arity, same key roles — including the
    padding-invariant inverse-CDF routing draw of
    ``repro.core.events._route_client``).

    Routed through the ``chunk=1`` block draw: the single-step and
    megastep streams must share one fusion structure, because XLA's
    mul-add (FMA) contraction can differ between distinct fusion
    contexts — op-identical draw code in a *different* surrounding
    program is not enough for byte-equal floats (1-ulp divergence on the
    lognormal's ``exp(normal - log(rate) - 0.5)`` chain).  A scan body is
    its own fusion context, so the length-1 scan here contracts exactly
    like the length-``E`` scan in :func:`_lane_chunk_randomness`.
    """
    chain, c_new, fscal = _lane_chunk_randomness(params, state, distribution,
                                                 has_cs, 1)
    return chain[:, 0], c_new[:, 0], fscal[:, 0]


def _lane_stats(st, t_new, c, is_update, delay, pw, n: int):
    """One lane's statistics accumulation over the sojourn ending at this
    event — line-for-line the reference engine's block, shared (vmapped)
    by the single-step and megastep wrappers so both run identical ops."""
    measure = (st.round >= st.warmup) & (st.round < st.cap)
    dt_eff = jnp.where(
        measure,
        jnp.clip(jnp.minimum(t_new, st.t_cap)
                 - jnp.minimum(st.t, st.t_cap), 0.0, None),
        0.0)
    occ_int = st.occ_int + dt_eff * st.occ
    energy = st.energy
    if pw is not None:
        p_w = seqsum(pw.P_c * st.serving
                     + pw.P_u * st.occ[2 * n:3 * n]
                     + pw.P_d * st.occ[:n])
        if pw.P_cs is not None:
            p_w = p_w + pw.P_cs * st.cs_busy
        energy = energy + dt_eff * p_w
    upd_measured = is_update & measure
    delay_sum = st.delay_sum.at[c].add(
        jnp.where(upd_measured, delay.astype(st.delay_sum.dtype), 0.0))
    delay_cnt = st.delay_cnt.at[c].add(
        jnp.where(upd_measured, 1, 0).astype(jnp.int32))
    return occ_int, energy, delay_sum, delay_cnt


def step_event_pallas(params: NetworkParams, state, *,
                      distribution: str = "exponential", power=None,
                      interpret: Optional[bool] = None):
    """Batched-lane analogue of :func:`repro.core.events.step_event`.

    ``state`` leaves carry a leading lane axis ``[K, ...]`` and ``params``
    (and ``power``) leaves ``[K, n]``; the statistics window accumulation
    is plain (vmapped) jnp, the table transition runs in the Pallas kernel.
    Returns the batched ``(EventState, EventOut)``.
    """
    n = params.p.shape[-1]
    has_cs = params.mu_cs is not None

    keys, c_new, fscal = _lane_randomness(params, state, distribution,
                                          has_cs)
    iscal = jnp.stack(
        [c_new, state.seq_ctr, state.round], axis=-1).astype(jnp.int32)
    finish, phase, client, seq, disp, t_col, int_col = event_step_tables(
        state.finish, state.phase, state.client, state.seq, state.disp_round,
        params.mu_c, params.mu_u, fscal, iscal, has_cs=has_cs,
        interpret=interpret)
    t_new = t_col[:, 0]
    c = int_col[:, 1]
    is_update = int_col[:, 2] > 0
    delay = int_col[:, 3]
    seq_ctr = int_col[:, 4]
    new_round = int_col[:, 5]
    ph_pre = int_col[:, 6]
    do_comp = int_col[:, 7] > 0
    do_cs = int_col[:, 8] > 0

    # -- statistics over the sojourn ending at this event (pre-event state),
    # line-for-line the reference engine's accumulation, vmapped over lanes
    if power is None:
        occ_int, energy, delay_sum, delay_cnt = jax.vmap(
            lambda st, t, c, u, d: _lane_stats(st, t, c, u, d, None, n))(
                state, t_new, c, is_update, delay)
    else:
        occ_int, energy, delay_sum, delay_cnt = jax.vmap(
            lambda st, t, c, u, d, pw: _lane_stats(st, t, c, u, d, pw, n))(
                state, t_new, c, is_update, delay, power)

    # -- O(1) maintenance of the occupancy carries, mirroring step_event
    # (the kernel reports the slot-j transition; promotions stay within
    # their station and only flip the busy indicators)
    is_comp = ph_pre == E.COMP_SERV
    is_down = ph_pre == E.DOWN
    is_cs = ph_pre == E.CS_SERV
    phase_j = jnp.where(
        is_down, E.COMP_WAIT,
        jnp.where(is_comp, E.UP, jnp.where(is_update, E.DOWN, E.CS_WAIT)))
    client_j = jnp.where(is_update, c_new, c)
    stations = jnp.arange(3 * n + 1)
    occ_new = (state.occ
               + jnp.where(stations[None, :]
                           == E._station_index(phase_j, client_j, n)[:, None],
                           1.0, 0.0)
               - jnp.where(stations[None, :]
                           == E._station_index(ph_pre, c, n)[:, None],
                           1.0, 0.0))
    delta_srv = (jnp.where(do_comp, 1.0, 0.0)
                 - jnp.where(is_comp, 1.0, 0.0))
    serving_new = state.serving + jnp.where(
        jnp.arange(n)[None, :] == c[:, None], delta_srv[:, None], 0.0)
    cs_busy_new = ((state.cs_busy & ~is_cs) | do_cs if has_cs
                   else state.cs_busy)

    t0 = jnp.where(is_update & (new_round == state.warmup), t_new, state.t0)
    t1 = jnp.where(is_update & (new_round == state.cap), t_new, state.t1)

    new_state = E.EventState(
        t=t_new, key=keys, round=new_round, seq_ctr=seq_ctr,
        client=client, phase=phase, finish=finish, seq=seq,
        disp_round=disp,
        warmup=state.warmup, cap=state.cap, t_cap=state.t_cap,
        t0=t0, t1=t1, delay_sum=delay_sum, delay_cnt=delay_cnt,
        energy=energy, occ_int=occ_int,
        occ=occ_new, serving=serving_new, cs_busy=cs_busy_new)
    out = E.EventOut(is_update=is_update, time=t_new,
                     slot=int_col[:, 0], client=c, delay=delay)
    return new_state, out


def step_event_pallas1(params: NetworkParams, state, *,
                       distribution: str = "exponential", power=None,
                       interpret: Optional[bool] = None):
    """Single-lane signature-compatible drop-in for ``events.step_event``
    (adds/strips a K=1 lane axis; batches further via vmap's pallas rule)."""
    up = lambda x: x[None]  # noqa: E731
    st, out = step_event_pallas(
        jax.tree_util.tree_map(up, params),
        jax.tree_util.tree_map(up, state),
        distribution=distribution,
        power=None if power is None else jax.tree_util.tree_map(up, power),
        interpret=interpret)
    down = lambda x: x[0]  # noqa: E731
    return (jax.tree_util.tree_map(down, st),
            jax.tree_util.tree_map(down, out))


# ---------------------------------------------------------------------------
# megastep: up to `chunk` events per kernel launch
# ---------------------------------------------------------------------------

class MegastepAux(NamedTuple):
    """Per-event descriptors of one megastep (leaves ``[K, chunk]`` except
    ``taken [K]``), pre-masked values — consumers gate on ``keep``."""

    time: jax.Array        # event time t_new
    slot: jax.Array        # completing slot j
    client: jax.Array      # completing client c (pre-event)
    delay: jax.Array       # staleness of the retiring round
    update: jax.Array      # bool: the event retired an update
    kind: jax.Array        # pre-event phase of slot j (the ring's kind)
    station: jax.Array     # station of (kind, client) — ring `station`
    station_to: jax.Array  # station slot j moved to — ring `station_to`
    keep: jax.Array        # bool: event really happened (partial chunks)
    taken: jax.Array       # [K] int32: number of kept events this launch


def _lane_chunk_randomness(params: NetworkParams, state, distribution: str,
                           has_cs: bool, chunk: int):
    """Per-lane key chain + outside draws for ``chunk`` events.

    A tiny-carry scan replays :func:`_lane_randomness`'s per-event split
    arity and draw order ``chunk`` times (same subkeys, same scalar-shape
    primitives — the megastep stream is bitwise the single-step stream);
    returns ``(chain [K, chunk, 2], c_new [K, chunk], fscal [K, chunk,
    4])`` with ``chain[:, i]`` the carried key after ``i + 1`` events.
    """
    law = get_law(distribution)
    dtype = state.finish.dtype
    K, n = params.p.shape
    n_acts = (params.n_active if params.n_active is not None
              else jnp.full((K,), n))

    mu_cs = params.mu_cs if has_cs else jnp.zeros_like(params.p[..., 0])

    def draw_one(k, p_row, mu_d_row, mu_cs_i, n_act):
        k2, k_up, k_disp_cli, k_disp_svc, k_comp, k_cs = (
            jax.random.split(k, 6))
        c_new = E._route_client(p_row, k_disp_cli, n_act)
        one_rate = jnp.ones((), dtype)
        e_up = law.device_draw(k_up, one_rate)
        e_comp = law.device_draw(k_comp, one_rate)
        svc_down = law.device_draw(k_disp_svc, mu_d_row[c_new])
        svc_cs = (law.device_draw(k_cs, mu_cs_i) if has_cs
                  else jnp.zeros((), dtype))
        fscal = jnp.stack([e_up, e_comp, svc_down, svc_cs]).astype(dtype)
        return k2, c_new, fscal

    def body(keys, _):
        # hermetic draw region: optimization_barrier pins the fusion
        # boundaries around each event's draws, so XLA's mul-add (FMA)
        # contraction inside them cannot depend on the surrounding
        # program.  Without it a trip-count-1 scan (the chunk=1 path) is
        # inlined by the while-loop simplifier and the lognormal's
        # exp(normal - log(rate) - 0.5) chain contracts differently than
        # in the length-E scan body — a 1-ulp finish-clock split between
        # megastep and single-step.  (The scan runs over the CHUNK axis
        # with lanes vmapped inside, because optimization_barrier has no
        # batching rule — the lowered per-step ops are the same either
        # way.)
        keys, p_b, mu_d_b, mu_cs_b, n_b = jax.lax.optimization_barrier(
            (keys, params.p, params.mu_d, mu_cs, n_acts))
        k2, c_new, fscal = jax.vmap(draw_one)(keys, p_b, mu_d_b, mu_cs_b,
                                              n_b)
        k2, c_new, fscal = jax.lax.optimization_barrier((k2, c_new, fscal))
        return k2, (k2, c_new, fscal)

    _, (chain, c_new, fscal) = jax.lax.scan(body, state.key, None,
                                            length=chunk)
    return (jnp.moveaxis(chain, 0, 1), jnp.moveaxis(c_new, 0, 1),
            jnp.moveaxis(fscal, 0, 1))


def megastep_event_pallas(params: NetworkParams, state, *, chunk: int,
                          rem=None, distribution: str = "exponential",
                          power=None, interpret: Optional[bool] = None,
                          stop_on_update: bool = False):
    """Advance up to ``chunk`` events per lane in ONE kernel launch.

    The megastep analogue of :func:`step_event_pallas`: the randomness
    block draws up front (:func:`_lane_chunk_randomness`), the table
    transitions retire inside :func:`_megastep_kernel`'s unrolled
    in-VMEM loop, and the statistics replay per event around the kernel
    (a ``chunk``-length scan of the shared :func:`_lane_stats` block plus
    the O(1) occupancy maintenance, keep-masked — bitwise ``chunk``
    single :func:`step_event_pallas` calls).  ``rem`` bounds the kept
    events per lane (scalar or ``[K]``; default ``chunk``);
    ``stop_on_update`` stops each lane after its first retired update.
    Returns ``(EventState, MegastepAux)``.
    """
    n = params.p.shape[-1]
    has_cs = params.mu_cs is not None
    K = state.finish.shape[0]

    chain, c_new, fscal = _lane_chunk_randomness(params, state, distribution,
                                                 has_cs, chunk)
    if rem is None:
        rem = jnp.full((K,), chunk, jnp.int32)
    else:
        rem = jnp.broadcast_to(jnp.asarray(rem, jnp.int32), (K,))
    iscal = jnp.concatenate(
        [state.seq_ctr[:, None], state.round[:, None], rem[:, None], c_new],
        axis=1).astype(jnp.int32)
    finish, phase, client, seq, disp, t_mat, int_mat = megastep_tables(
        state.finish, state.phase, state.client, state.seq, state.disp_round,
        params.mu_c, params.mu_u, fscal.reshape(K, 4 * chunk), iscal,
        has_cs=has_cs, chunk=chunk, stop_on_update=stop_on_update,
        interpret=interpret)
    D = int_mat.reshape(K, chunk, 10)
    upd_mat = D[..., 2] > 0
    ph_pre_mat = D[..., 6]
    keep_mat = D[..., 9] > 0

    # -- statistics replay: one keep-masked `_lane_stats` + O(1) occupancy
    # maintenance per event, sequential over the chunk (the delay/occ
    # accumulation order of `chunk` single steps)
    lead = lambda a: jnp.moveaxis(a, 1, 0)  # noqa: E731
    xs = (lead(t_mat), lead(D[..., 1]), lead(upd_mat), lead(D[..., 3]),
          lead(D[..., 4]), lead(D[..., 5]), lead(ph_pre_mat),
          lead(D[..., 7] > 0), lead(D[..., 8] > 0), lead(keep_mat),
          lead(c_new))

    def body(st, x):
        (t_new, c, is_update, delay, seq_ctr2, new_round, ph_pre,
         do_comp, do_cs, keep, c_new_i) = x
        if power is None:
            occ_int, energy, delay_sum, delay_cnt = jax.vmap(
                lambda s, t, cc, u, d: _lane_stats(s, t, cc, u, d, None, n))(
                    st, t_new, c, is_update, delay)
        else:
            occ_int, energy, delay_sum, delay_cnt = jax.vmap(
                lambda s, t, cc, u, d, pw: _lane_stats(s, t, cc, u, d, pw,
                                                       n))(
                    st, t_new, c, is_update, delay, power)

        is_comp = ph_pre == E.COMP_SERV
        is_down = ph_pre == E.DOWN
        is_cs = ph_pre == E.CS_SERV
        phase_j = jnp.where(
            is_down, E.COMP_WAIT,
            jnp.where(is_comp, E.UP,
                      jnp.where(is_update, E.DOWN, E.CS_WAIT)))
        client_j = jnp.where(is_update, c_new_i, c)
        stations = jnp.arange(3 * n + 1)
        occ_new = (st.occ
                   + jnp.where(stations[None, :]
                               == E._station_index(phase_j, client_j,
                                                   n)[:, None],
                               1.0, 0.0)
                   - jnp.where(stations[None, :]
                               == E._station_index(ph_pre, c, n)[:, None],
                               1.0, 0.0))
        delta_srv = (jnp.where(do_comp, 1.0, 0.0)
                     - jnp.where(is_comp, 1.0, 0.0))
        serving_new = st.serving + jnp.where(
            jnp.arange(n)[None, :] == c[:, None], delta_srv[:, None], 0.0)
        cs_busy_new = ((st.cs_busy & ~is_cs) | do_cs if has_cs
                       else st.cs_busy)
        t0 = jnp.where(is_update & (new_round == st.warmup), t_new, st.t0)
        t1 = jnp.where(is_update & (new_round == st.cap), t_new, st.t1)

        st2 = st._replace(
            t=t_new, round=new_round, seq_ctr=seq_ctr2, t0=t0, t1=t1,
            delay_sum=delay_sum, delay_cnt=delay_cnt, energy=energy,
            occ_int=occ_int, occ=occ_new, serving=serving_new,
            cs_busy=cs_busy_new)
        sel = lambda a, b: jnp.where(  # noqa: E731
            keep.reshape(keep.shape + (1,) * (a.ndim - 1)), a, b)
        return jax.tree_util.tree_map(sel, st2, st), None

    stf, _ = jax.lax.scan(body, state, xs)

    # -- resume key: the chain entry after the last kept event ------------
    # x64 mode promotes integer sums to int64: pin the count to i32
    # contract: allow(raw-reduction): int32 indicator count over the chunk axis — exact integer arithmetic, never a padded client/class axis
    taken = jnp.sum(keep_mat.astype(jnp.int32), axis=1, dtype=jnp.int32)
    idxk = jnp.clip(taken, 1, chunk) - 1
    k_sel = jnp.take_along_axis(chain, idxk[:, None, None], axis=1)[:, 0]
    keys = jnp.where((taken > 0)[:, None], k_sel, state.key)

    new_state = stf._replace(key=keys, client=client, phase=phase,
                             finish=finish, seq=seq, disp_round=disp)
    is_comp_m = ph_pre_mat == E.COMP_SERV
    is_down_m = ph_pre_mat == E.DOWN
    phase_j_m = jnp.where(
        is_down_m, E.COMP_WAIT,
        jnp.where(is_comp_m, E.UP,
                  jnp.where(upd_mat, E.DOWN, E.CS_WAIT)))
    client_j_m = jnp.where(upd_mat, c_new, D[..., 1])
    aux = MegastepAux(
        time=t_mat, slot=D[..., 0], client=D[..., 1], delay=D[..., 3],
        update=upd_mat, kind=ph_pre_mat,
        station=E._station_index(ph_pre_mat, D[..., 1], n),
        station_to=E._station_index(phase_j_m, client_j_m, n),
        keep=keep_mat, taken=taken)
    return new_state, aux


def megastep_event_pallas1(params: NetworkParams, state, *, chunk: int,
                           rem=None, distribution: str = "exponential",
                           power=None, interpret: Optional[bool] = None,
                           stop_on_update: bool = False):
    """Single-lane megastep (adds/strips a K=1 lane axis): the form
    ``events.next_update`` consumes on the pallas backend."""
    up = lambda x: x[None]  # noqa: E731
    st, aux = megastep_event_pallas(
        jax.tree_util.tree_map(up, params),
        jax.tree_util.tree_map(up, state),
        chunk=chunk,
        rem=None if rem is None else jnp.asarray(rem, jnp.int32)[None],
        distribution=distribution,
        power=None if power is None else jax.tree_util.tree_map(up, power),
        interpret=interpret, stop_on_update=stop_on_update)
    down = lambda x: x[0]  # noqa: E731
    return (jax.tree_util.tree_map(down, st),
            jax.tree_util.tree_map(down, aux))
