"""Admission queue → micro-batches of coalescible requests.

The batcher owns the window policy only (no jax, no sockets): it blocks
on the admission queue for the first request, then keeps collecting
until ``max_wait`` elapses or ``max_lanes`` requests are in hand —
partial batches fire on timeout.  Grouping by the executor's bucket key
happens after the window closes, so one window can yield several groups
(each group = one suite dispatch; requests in a group become spare lanes
of the same resident program).

Lane accounting: a request contributes ``len(seeds)`` lanes, so
``max_lanes`` bounds the dispatch width, not the request count.
"""
from __future__ import annotations

import queue
import time
from typing import Callable, Optional


class MicroBatcher:
    """Pulls :class:`repro.serve.protocol.Request`s from a queue and
    yields lists of requests that may share one dispatch."""

    def __init__(self, admission: "queue.Queue",
                 bucket_key: Callable, *,
                 max_wait: float = 0.02, max_lanes: int = 64):
        self.admission = admission
        self.bucket_key = bucket_key
        self.max_wait = float(max_wait)
        self.max_lanes = int(max_lanes)

    def next_window(self, timeout: Optional[float] = None) -> list:
        """Block for the first request (up to ``timeout``; None = forever),
        then drain the window.  Returns [] on timeout or when a ``None``
        sentinel (shutdown) was queued."""
        try:
            first = self.admission.get(timeout=timeout)
        except queue.Empty:
            return []
        if first is None:
            return []
        batch = [first]
        lanes = len(first.seeds)
        deadline = time.monotonic() + self.max_wait
        while lanes < self.max_lanes:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                break
            try:
                nxt = self.admission.get(timeout=remaining)
            except queue.Empty:
                break
            if nxt is None:
                break  # shutdown sentinel: fire what we have
            batch.append(nxt)
            lanes += len(nxt.seeds)
        return batch

    def group(self, batch: list) -> list:
        """Partition a window into dispatch groups by bucket key; key
        errors (e.g. oversized resolved m) split into error singletons
        marked by a ``WireError`` in place of the key."""
        groups: dict = {}
        order: list = []
        for req in batch:
            try:
                key = ("ok", self.bucket_key(req))
            except Exception as e:
                key = ("err", id(req), e)
            if key not in groups:
                groups[key] = []
                order.append(key)
            groups[key].append(req)
        return [(key[2] if key[0] == "err" else None, groups[key])
                for key in order]
