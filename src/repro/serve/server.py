"""The persistent suite server: sockets, admission, dispatch, drain.

Thread model (single-threaded jax use by construction):

- one **reader thread per connection** parses JSON lines; ``stats``,
  ``metrics`` and ``shutdown`` are answered inline; valid ``run``
  requests get an
  ``accepted`` event and enter the admission queue.  Parse errors are
  structured ``error`` events — the connection (and server) keep going.
- ONE **dispatcher thread** owns every jax call: it drains micro-batch
  windows (:class:`repro.serve.batcher.MicroBatcher`), coalesces
  equal-bucket requests into one ``ScenarioSuite`` dispatch over the
  shared :class:`repro.serve.executor.Executor` caches, and streams
  ``scheduled`` → ``result`` events back per request.
- a client that vanished mid-flight (killed in-flight request) surfaces
  as a send failure, which is swallowed per-connection: the dispatch
  still completes, caches stay warm, the server keeps serving.

Graceful shutdown: the ``shutdown`` verb (or SIGTERM) stops admission,
the dispatcher drains in-flight requests, then the listener closes.
"""
from __future__ import annotations

import dataclasses
import json
import os
import queue
import socket
import sys
import threading
import time
from typing import Optional

from .batcher import MicroBatcher
from .executor import Executor
from .metrics import Metrics
from .protocol import (Request, WireError, decode_line, encode,
                       parse_request)


@dataclasses.dataclass
class ServeConfig:
    """Server knobs (the CLI reads env defaults — see ``__main__``)."""

    socket_path: str = ""            # unix socket; "" = stdio fallback
    max_wait: float = 0.02           # micro-batch window (seconds)
    max_lanes: int = 64              # lane budget per dispatch window
    backlog: int = 64


class _Transport:
    """One connection: a line iterator plus a locked writer.  Send
    failures mark the transport dead and are not raised — the peer
    walked away; the server must not."""

    def __init__(self, rfile, wfile, name: str):
        self._rfile = rfile
        self._wfile = wfile
        self._lock = threading.Lock()
        self.name = name
        self.alive = True

    def lines(self):
        return self._rfile

    def send(self, msg: dict) -> bool:
        if not self.alive:
            return False
        try:
            with self._lock:
                self._wfile.write(encode(msg))
                self._wfile.flush()
            return True
        except (BrokenPipeError, ConnectionResetError, OSError, ValueError):
            self.alive = False
            return False


class Server:
    """``Server(config).serve_forever()`` — or ``start()``/``stop()``
    from tests."""

    def __init__(self, config: Optional[ServeConfig] = None,
                 executor: Optional[Executor] = None):
        self.config = config or ServeConfig()
        self.metrics = (executor.metrics if executor is not None
                        else Metrics())
        self.executor = executor or Executor(metrics=self.metrics)
        self.admission: "queue.Queue" = queue.Queue()
        self.batcher = MicroBatcher(self.admission,
                                    self.executor.bucket_key,
                                    max_wait=self.config.max_wait,
                                    max_lanes=self.config.max_lanes)
        self._listener: Optional[socket.socket] = None
        self._dispatcher: Optional[threading.Thread] = None
        self._threads: list = []
        self._draining = threading.Event()
        self._stopped = threading.Event()
        self._t0 = time.monotonic()

    # -- lifecycle ----------------------------------------------------------

    def start(self) -> None:
        """Bind the socket and start the dispatcher (non-blocking)."""
        self._dispatcher = threading.Thread(target=self._dispatch_loop,
                                            name="serve-dispatch",
                                            daemon=True)
        self._dispatcher.start()
        if self.config.socket_path:
            path = self.config.socket_path
            if os.path.exists(path):
                os.unlink(path)
            self._listener = socket.socket(socket.AF_UNIX,
                                           socket.SOCK_STREAM)
            self._listener.bind(path)
            self._listener.listen(self.config.backlog)
            accept = threading.Thread(target=self._accept_loop,
                                      name="serve-accept", daemon=True)
            accept.start()
            self._threads.append(accept)

    def serve_forever(self) -> None:
        self.start()
        if not self.config.socket_path:
            # stdio fallback: serve the single implicit connection
            tr = _Transport(sys.stdin.buffer, sys.stdout.buffer, "stdio")
            self._serve_connection(tr)
            self._drain_and_stop()
        self._stopped.wait()

    def stop(self) -> None:
        """Immediate stop (tests); ``shutdown`` verb drains first."""
        self._drain_and_stop()

    def _drain_and_stop(self) -> None:
        self._draining.set()
        self.admission.put(None)  # wake the dispatcher
        if self._dispatcher is not None:
            self._dispatcher.join(timeout=60)
        if self._listener is not None:
            try:
                self._listener.close()
            finally:
                if os.path.exists(self.config.socket_path):
                    os.unlink(self.config.socket_path)
        self._stopped.set()

    # -- admission ----------------------------------------------------------

    def _accept_loop(self) -> None:
        while not self._draining.is_set():
            try:
                conn, _ = self._listener.accept()
            except OSError:
                break
            tr = _Transport(conn.makefile("rb"), conn.makefile("wb"),
                            f"conn-{len(self._threads)}")
            t = threading.Thread(target=self._serve_connection,
                                 args=(tr,), daemon=True)
            t.start()
            self._threads.append(t)

    def _serve_connection(self, tr: _Transport) -> None:
        for line in tr.lines():
            if not line.strip():
                continue
            try:
                msg = decode_line(line)
                verb = msg.get("verb", "run")
                if verb == "stats":
                    tr.send({"id": msg.get("id"), "event": "result",
                             "value": self.stats()})
                    continue
                if verb == "metrics":
                    # Prometheus text exposition of the shared registry
                    tr.send({"id": msg.get("id"), "event": "result",
                             "value": self.metrics.exposition()})
                    continue
                if verb == "shutdown":
                    tr.send({"id": msg.get("id"), "event": "result",
                             "value": "draining"})
                    threading.Thread(target=self._drain_and_stop,
                                     daemon=True).start()
                    return
                if verb != "run":
                    raise WireError("ProtocolError",
                                    f"unknown verb {verb!r}",
                                    msg.get("id"))
                if self._draining.is_set():
                    raise WireError("Unavailable", "server is draining",
                                    msg.get("id"))
                req = parse_request(msg)
                req.t_admit = time.monotonic()
                req.transport = tr
                cached = self.executor.cached_response(req)
                if cached is not None:
                    # repeat request: answered straight from the response
                    # cache — no admission, no dispatch
                    self.metrics.inc("serve.cache_hits", mode=req.mode)
                    self.metrics.observe("serve.request_latency", 0.0,
                                         mode=req.mode)
                    tr.send({"id": req.id, "event": "result",
                             "cached": True, "value": cached})
                    continue
                self.metrics.inc("serve.requests", mode=req.mode)
                tr.send({"id": req.id, "event": "accepted"})
                self.admission.put(req)
            except WireError as e:
                self.metrics.inc("serve.errors", where="admission")
                tr.send(e.to_msg())
            except Exception as e:  # never let a connection kill the server
                self.metrics.inc("serve.errors", where="admission")
                tr.send(WireError(type(e).__name__, str(e)).to_msg())

    # -- dispatch -----------------------------------------------------------

    def _dispatch_loop(self) -> None:
        while True:
            batch = self.batcher.next_window(timeout=0.25)
            if not batch:
                if self._draining.is_set() and self.admission.empty():
                    return
                continue
            for err, group in self.batcher.group(batch):
                if err is not None:
                    for req in group:
                        self._send_error(req, err)
                    continue
                try:
                    self._dispatch_group(group)
                except Exception as e:  # dispatcher must outlive any group
                    for req in group:
                        self._send_error(req, e)

    def _dispatch_group(self, group: list) -> None:
        mode = group[0].mode
        lanes = sum(len(r.seeds) for r in group)
        for req in group:
            req.transport.send({"id": req.id, "event": "scheduled",
                                "requests": len(group), "lanes": lanes})
        self.metrics.observe("serve.requests_per_dispatch", len(group),
                             mode=mode)
        self.metrics.observe("serve.lanes_per_dispatch", lanes, mode=mode)
        with self.metrics.timed("serve.dispatch", mode=mode):
            completions = self.executor.run_group(group)
        for done in completions:
            req = done.request
            if done.error is not None:
                self._send_error(req, done.error)
                continue
            self.metrics.observe("serve.request_latency",
                                 time.monotonic() - req.t_admit,
                                 mode=req.mode)
            req.transport.send({"id": req.id, "event": "result",
                                "cached": False, "value": done.value})

    def _send_error(self, req: Request, err: Exception) -> None:
        self.metrics.inc("serve.errors", where="dispatch")
        if isinstance(err, WireError):
            msg = WireError(err.etype, str(err), req.id).to_msg()
        else:
            msg = WireError(type(err).__name__, str(err), req.id).to_msg()
        req.transport.send(msg)

    # -- stats --------------------------------------------------------------

    def stats(self) -> dict:
        snap = self.metrics.snapshot()
        return {"uptime": time.monotonic() - self._t0,
                "queued": self.admission.qsize(),
                "response_cache_size": len(self.executor._responses),
                "counters": snap["counters"],
                "latency": snap["latency"],
                "drift": dict(self.executor.drift)}


def run_stdio_server() -> None:
    Server(ServeConfig(socket_path="")).serve_forever()


def main(argv=None) -> None:  # thin alias used by __main__
    from .__main__ import main as _main

    _main(argv)


if __name__ == "__main__":
    main()
