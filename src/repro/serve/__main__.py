"""``python -m repro.serve`` — boot the suite server.

Flags (env defaults in parens): ``--socket PATH``
(``$REPRO_SERVE_SOCKET``, default ``/tmp/repro-serve.sock``),
``--stdio`` (JSON lines on stdin/stdout instead of a socket),
``--max-wait-ms`` (``$REPRO_SERVE_MAX_WAIT_MS``, 20), ``--max-lanes``
(``$REPRO_SERVE_MAX_LANES``, 64), ``--no-compile-cache`` to skip the
persistent XLA cache (``$JAX_COMPILATION_CACHE_DIR`` picks its
location).
"""
from __future__ import annotations

import argparse
import os
import signal
import sys


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(prog="python -m repro.serve",
                                 description="always-on scenario-suite "
                                             "server (JSON lines)")
    # server process config: env read once at startup, flags win — these
    # run before any jax tracing, and the README documents each variable
    # contract: allow(env-read): server startup config — read once in main() before any jit, documented in README Serving
    env = os.environ.get
    ap.add_argument("--socket", default=env("REPRO_SERVE_SOCKET",
                                            "/tmp/repro-serve.sock"))
    ap.add_argument("--stdio", action="store_true",
                    help="serve stdin/stdout instead of a socket")
    # contract: allow(env-read): server startup config — read once in main() before any jit, documented in README Serving
    ap.add_argument("--max-wait-ms", type=float,
                    default=float(env("REPRO_SERVE_MAX_WAIT_MS", "20")))
    # contract: allow(env-read): server startup config — read once in main() before any jit, documented in README Serving
    ap.add_argument("--max-lanes", type=int,
                    default=int(env("REPRO_SERVE_MAX_LANES", "64")))
    ap.add_argument("--no-compile-cache", action="store_true",
                    help="skip the persistent XLA compilation cache")
    args = ap.parse_args(argv)

    if not args.no_compile_cache:
        from .xla_cache import enable_persistent_cache

        path = enable_persistent_cache()
        print(f"serve: persistent compilation cache at {path}",
              file=sys.stderr, flush=True)

    from .server import ServeConfig, Server

    config = ServeConfig(socket_path="" if args.stdio else args.socket,
                         max_wait=args.max_wait_ms / 1000.0,
                         max_lanes=args.max_lanes)
    server = Server(config)
    signal.signal(signal.SIGTERM, lambda *_: server.stop())
    if not args.stdio:
        print(f"serve: listening on {args.socket}", file=sys.stderr,
              flush=True)
    server.serve_forever()


if __name__ == "__main__":
    main()
