"""Warm restarts: the jax persistent compilation cache.

``enable_persistent_cache()`` points jax at an on-disk XLA executable
cache (``JAX_COMPILATION_CACHE_DIR``, default ``~/.cache/repro/xla``)
with the thresholds opened up so every resident program qualifies
(CPU compiles are fast and small — the defaults would skip them all).
A restarted server's first request then deserializes its programs
instead of recompiling: ``repro.analysis.tracecheck`` counts the
persistent-cache hits separately (``Watch.fresh_compiles``), and the
restart subprocess test asserts the second boot pays ZERO fresh
compiles.

Call it before the first jit dispatch; it is idempotent.
"""
from __future__ import annotations

import os
from typing import Optional

DEFAULT_CACHE_DIR = "~/.cache/repro/xla"


def default_cache_dir() -> str:
    env = os.environ.get("JAX_COMPILATION_CACHE_DIR")
    return env if env else os.path.expanduser(DEFAULT_CACHE_DIR)


def enable_persistent_cache(cache_dir: Optional[str] = None) -> str:
    """Enable the on-disk compilation cache; returns the directory."""
    import jax

    path = cache_dir if cache_dir else default_cache_dir()
    os.makedirs(path, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", path)
    # cache EVERY executable: CPU compiles are below the default 1 MiB /
    # 1 s thresholds, which would silently cache nothing here
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0)
    return path
