"""Request execution on shared suite caches.

The executor is the single owner of all jax state in the server: one
:class:`repro.scenario.SuiteCaches` bundle (resident jitted programs,
trainers, result cache, datasets) shared by every micro-batch, one
:class:`Metrics` registry, a content-keyed strategy-resolution cache and
a response cache keyed by ``(mode, Scenario.hash(), seeds, options)`` —
a repeat request is answered from it without any dispatch.

All methods that touch jax MUST be called from one thread (the server's
dispatcher); the admission path only parses and hashes.

Batching contract (why the bucket key looks the way it does): ``n``- and
class-axis padding are bitwise invariant (the PR-5 contract), so
requests with different populations share a dispatch freely.  The task
TABLE size is **not** invariant — trajectories draw per slot — so
simulate/train requests bucket on their *effective* ``m`` and only
equal-``m`` requests coalesce; every response is bitwise what a direct
single-scenario ``ScenarioSuite`` run returns.  Train requests
additionally bucket on everything that keys the suite's structural train
bucket (law, CS-buffer/power structure, grad clip, data spec, overrides,
model architecture).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from ..scenario import ScenarioSuite
from ..scenario.suite import SuiteCaches, resolve_strategy
from .metrics import Metrics
from .protocol import MAX_M, Request, WireError, encode_entry


@dataclasses.dataclass
class Completion:
    """One finished request: the JSON-able payload plus dispatch facts."""

    request: Request
    value: object = None
    cached: bool = False
    error: Optional[WireError] = None


def _freeze(v):
    if isinstance(v, dict):
        return tuple(sorted((k, _freeze(x)) for k, x in v.items()))
    if isinstance(v, (list, tuple)):
        return tuple(_freeze(x) for x in v)
    return v


def _options_key(options: dict) -> tuple:
    return _freeze(options)


class Executor:
    """Builds per-micro-batch suites over one shared cache bundle."""

    def __init__(self, metrics: Optional[Metrics] = None):
        self.caches = SuiteCaches()
        self.metrics = metrics if metrics is not None else Metrics()
        self._resolve_shared: dict = {}   # net_key -> resolve_strategy caches
        self._resolved: dict = {}         # (scenario hash) -> (p, m)
        self._models: dict = {}           # model-spec key -> Model
        self._responses: dict = {}        # response cache
        #: rolling drift-monitor summary over every traced dispatch,
        #: surfaced by the server's ``stats`` verb
        self.drift: dict = {"checked": 0, "breaches": 0, "last": None}

    # -- admission-side helpers (no jax) ------------------------------------

    def response_key(self, req: Request) -> tuple:
        return (req.mode, req.scenario.hash(), req.seeds,
                _options_key(req.options))

    def cached_response(self, req: Request):
        return self._responses.get(self.response_key(req))

    # -- dispatcher-side --------------------------------------------------

    def resolve(self, req: Request):
        """Resolved ``(p, m)`` for a request's scenario (content-cached;
        shared normalizers reused across requests on the same network —
        mirrors ``ScenarioSuite.resolve``)."""
        scn = req.scenario
        rkey = scn.hash()
        hit = self._resolved.get(rkey)
        if hit is not None:
            return hit
        net_key = (str(scn.network.to_dict()), str(scn.learning.to_dict()),
                   str(None if scn.energy is None else scn.energy.to_dict()),
                   scn.strategy.m_max, scn.strategy.steps,
                   scn.strategy.search)
        shared = self._resolve_shared.setdefault(
            net_key, {"cache": {}, "resolved": {}})
        pm = resolve_strategy(scn, resolved=shared["resolved"],
                              cache=shared["cache"])
        shared["resolved"][scn.strategy.name] = pm
        self._resolved[rkey] = pm
        return pm

    def bucket_key(self, req: Request) -> tuple:
        """The micro-batch coalescing key: requests with equal keys run
        as lanes of ONE suite dispatch, bitwise-equal to running alone."""
        scn = req.scenario
        _, m = self.resolve(req)
        m_eff = int(req.options.get("m_max") or m)
        if m_eff > MAX_M:
            raise WireError("ProtocolError",
                            f"resolved concurrency m={m_eff} exceeds the "
                            f"server bound {MAX_M}", req.id)
        structure = (scn.network.law, scn.network.mu_cs is not None,
                     None if scn.energy is None
                     else scn.energy.P_cs is not None,
                     scn.is_class_network, scn.sim_backend,
                     None if scn.sim is None else scn.sim.interpret,
                     # ring capacities key the traced program variants —
                     # traced and untraced requests must not coalesce
                     None if scn.trace is None
                     else (scn.trace.events, scn.trace.updates))
        if req.mode == "analyze":
            # closed forms are padding-invariant on every axis incl. the
            # task table, and analyze results cache by scenario hash alone
            return ("analyze", req.seeds, structure)
        opts = dict(req.options)
        if req.mode == "simulate":
            return ("simulate", req.seeds, structure, m_eff,
                    int(opts["num_updates"]), int(opts.get("warmup", 0)),
                    opts.get("backend"))
        model_key = _options_key(opts.pop("model"))
        opts.pop("horizon_time"), opts.pop("max_updates", None)
        return ("train", req.seeds, structure, int(m), model_key,
                scn.learning.grad_clip,
                str(None if scn.data is None else scn.data.to_dict()),
                float(req.options["horizon_time"]),
                req.options.get("max_updates"), _options_key(opts))

    def _model_for(self, spec) -> object:
        """Architecture from a wire model spec — identity-cached so the
        suite's trainer memo keeps hitting across micro-batches."""
        from ..fl.models import mlp_classifier

        if not isinstance(spec, dict):
            raise WireError("ProtocolError",
                            "options.model must be an object like "
                            '{"kind": "mlp", "input_dim": ..., '
                            '"num_classes": ..., "hidden": [...]}')
        key = _options_key(spec)
        hit = self._models.get(key)
        if hit is not None:
            return hit
        kind = spec.get("kind", "mlp")
        if kind != "mlp":
            raise WireError("ProtocolError",
                            f"unknown model kind {kind!r}; the wire "
                            "format currently serves 'mlp'")
        try:
            model = mlp_classifier(int(spec["input_dim"]),
                                   int(spec["num_classes"]),
                                   hidden=tuple(spec.get("hidden", (8,))))
        except KeyError as e:
            raise WireError("ProtocolError",
                            f"model spec needs {e.args[0]!r}") from e
        self._models[key] = model
        return model

    def run_group(self, requests: list) -> list:
        """ONE suite dispatch for a coalesced group (equal bucket keys).

        Returns a :class:`Completion` per request, in order.  A failure
        is reported on every member (they shared the dispatch) as a
        structured error; the shared caches stay valid — they are
        content-keyed and only written after a successful run.
        """
        mode = requests[0].mode
        # positional suite keys: wire ids are only unique per connection,
        # and one micro-batch spans connections
        suite = ScenarioSuite(
            {f"q{i}": req.scenario for i, req in enumerate(requests)},
            seeds=requests[0].seeds, caches=self.caches,
            metrics=self.metrics)
        # pre-resolved strategies: skip re-resolving inside the suite
        for i, req in enumerate(requests):
            suite._strategies[f"q{i}"] = self.resolve(req)
        opts = dict(requests[0].options)
        try:
            if mode == "analyze":
                res = suite.run(mode="analyze")
            elif mode == "simulate":
                res = suite.run(
                    mode="simulate", num_updates=int(opts["num_updates"]),
                    warmup=int(opts.get("warmup", 0)),
                    m_max=(None if opts.get("m_max") is None
                           else int(opts["m_max"])),
                    backend=opts.get("backend"))
            else:
                model = self._model_for(opts.pop("model"))
                horizon = float(opts.pop("horizon_time"))
                max_updates = opts.pop("max_updates", None)
                res = suite.run(mode="train", model=model,
                                horizon_time=horizon,
                                max_updates=(None if max_updates is None
                                             else int(max_updates)),
                                **opts)
            if getattr(res, "drift", None):
                for reports in res.drift.values():
                    for rep in reports:
                        self.drift["checked"] += 1
                        if not rep.get("ok"):
                            self.drift["breaches"] += 1
                            self.metrics.inc("obs.drift_breaches", mode=mode)
                        self.drift["last"] = rep
            out = []
            for i, req in enumerate(requests):
                payload = encode_entry(mode, res.entries[f"q{i}"])
                self._responses[self.response_key(req)] = payload
                out.append(Completion(request=req, value=payload))
            return out
        except WireError as e:
            return [Completion(request=req,
                               error=WireError(e.etype, str(e), req.id))
                    for req in requests]
        except Exception as e:
            return [Completion(request=req,
                               error=WireError(type(e).__name__, str(e),
                                               req.id))
                    for req in requests]
