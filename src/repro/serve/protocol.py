"""JSON-lines wire protocol for the suite server.

One request per line, one JSON object per line back; the same bitwise
format ``Scenario.to_json`` already guarantees (Python ``json`` emits
``repr``-exact floats, so every float in a response round-trips
bit-identically — the serve tests compare payloads against direct
``ScenarioSuite.run`` results for equality, not tolerance).

Requests::

    {"id": "r1", "verb": "run", "mode": "simulate",
     "scenario": {...Scenario.to_dict()...}, "seeds": [0, 1],
     "options": {"num_updates": 200}}
    {"id": "s1", "verb": "stats"}
    {"id": "m1", "verb": "metrics"}
    {"id": "d1", "verb": "shutdown"}

Streamed responses for a ``run`` (all tagged with the request id)::

    {"id": "r1", "event": "accepted"}
    {"id": "r1", "event": "scheduled", "lanes": 4, "bucket": "..."}
    {"id": "r1", "event": "result", "cached": false, "value": ...}

Any failure becomes ``{"event": "error", "error": {"type", "message"}}``
— a structured reply on the wire, never a dead server process.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional

import numpy as np

from ..scenario import Scenario

MODES = ("analyze", "simulate", "train")
VERBS = ("run", "stats", "metrics", "shutdown")

#: options accepted per mode (anything else is a structured error — an
#: unknown knob silently ignored would poison bitwise reproducibility)
RUN_OPTIONS = {
    "analyze": frozenset(),
    "simulate": frozenset({"num_updates", "warmup", "m_max", "backend"}),
    "train": frozenset({"horizon_time", "model", "max_updates",
                        "batch_size", "eval_every_time", "eval_batch"}),
}

#: admission bound on any requested/resolved task-table size: a huge
#: ``m_max`` would compile (and resident-cache) an absurd program
MAX_M = 4096
#: admission bound on request-line length (8 MiB)
MAX_LINE = 8 * 1024 * 1024


class WireError(Exception):
    """A structured protocol error: ``type`` + ``message`` (+ the request
    id when one could be parsed)."""

    def __init__(self, etype: str, message: str,
                 req_id: Optional[str] = None):
        super().__init__(message)
        self.etype = etype
        self.req_id = req_id

    def to_msg(self) -> dict:
        return {"id": self.req_id, "event": "error",
                "error": {"type": self.etype, "message": str(self)}}


@dataclasses.dataclass
class Request:
    """A validated ``run`` request (``stats``/``shutdown`` never build
    one — they are answered inline by the connection reader)."""

    id: str
    mode: str
    scenario: Scenario
    seeds: tuple
    options: dict
    # filled by the server: admission timestamp for latency accounting,
    # and the originating transport to stream responses back through
    t_admit: float = 0.0
    transport: object = None


def encode(msg: dict) -> bytes:
    """One response line (compact separators, trailing newline)."""
    return (json.dumps(msg, separators=(",", ":")) + "\n").encode()


def decode_line(line: bytes) -> dict:
    if len(line) > MAX_LINE:
        raise WireError("ProtocolError",
                        f"request line exceeds {MAX_LINE} bytes")
    try:
        msg = json.loads(line)
    except json.JSONDecodeError as e:
        raise WireError("ProtocolError", f"malformed JSON: {e}") from e
    if not isinstance(msg, dict):
        raise WireError("ProtocolError",
                        f"expected a JSON object, got {type(msg).__name__}")
    return msg


def parse_request(msg: dict) -> Request:
    """Validate a decoded ``run`` message into a :class:`Request`.

    Raises :class:`WireError` (carrying the request id whenever one is
    present) for every malformed field — unknown verbs/modes/options,
    non-Scenario payloads, unknown law/strategy names (surfaced by the
    spec's eager validation), and oversized ``m_max``.
    """
    req_id = msg.get("id")
    if not isinstance(req_id, str) or not req_id:
        raise WireError("ProtocolError", "request needs a string 'id'")
    mode = msg.get("mode", "analyze")
    if mode not in MODES:
        raise WireError("ProtocolError",
                        f"unknown mode {mode!r}; expected one of {MODES}",
                        req_id)
    scn_dict = msg.get("scenario")
    if not isinstance(scn_dict, dict):
        raise WireError("ProtocolError",
                        "request needs a 'scenario' object "
                        "(Scenario.to_dict() format)", req_id)
    try:
        scenario = Scenario.from_dict(scn_dict)
    except Exception as e:  # eager spec validation: unknown law/strategy/...
        raise WireError(type(e).__name__, str(e), req_id) from e
    seeds = msg.get("seeds", [0])
    if (not isinstance(seeds, list) or not seeds
            or not all(isinstance(s, int) for s in seeds)):
        raise WireError("ProtocolError",
                        "'seeds' must be a non-empty list of ints", req_id)
    options = msg.get("options", {})
    if not isinstance(options, dict):
        raise WireError("ProtocolError", "'options' must be an object",
                        req_id)
    unknown = set(options) - RUN_OPTIONS[mode]
    if unknown:
        raise WireError(
            "ProtocolError",
            f"unknown option(s) for mode {mode!r}: {sorted(unknown)}; "
            f"accepted: {sorted(RUN_OPTIONS[mode])}", req_id)
    if mode == "simulate" and "num_updates" not in options:
        raise WireError("ProtocolError",
                        "mode 'simulate' needs options.num_updates", req_id)
    if mode == "train":
        for need in ("horizon_time", "model"):
            if need not in options:
                raise WireError("ProtocolError",
                                f"mode 'train' needs options.{need}", req_id)
    m_req = options.get("m_max")
    if m_req is not None and int(m_req) > MAX_M:
        raise WireError("ProtocolError",
                        f"m_max={m_req} exceeds the server bound {MAX_M}",
                        req_id)
    if scenario.strategy.name == "explicit" and scenario.strategy.m and \
            int(scenario.strategy.m) > MAX_M:
        raise WireError("ProtocolError",
                        f"strategy m={scenario.strategy.m} exceeds the "
                        f"server bound {MAX_M}", req_id)
    if mode == "train" and scenario.data is None:
        raise WireError("ProtocolError",
                        "mode 'train' over the wire needs a DataSpec on "
                        "the scenario (client datasets are built "
                        "server-side)", req_id)
    return Request(id=req_id, mode=mode, scenario=scenario,
                   seeds=tuple(int(s) for s in seeds), options=dict(options))


# -- result payload encoding (mode-specific, repr-exact floats) -------------


def _listify(x) -> list:
    return np.asarray(x).tolist()


def encode_entry(mode: str, entry) -> object:
    """A suite entry as a JSON-able payload.

    ``analyze``: the closed-form dict with arrays listified.
    ``simulate``: per-seed list of EventStats field dicts.
    ``train``: per-seed list of TrainLog field dicts.
    """
    if mode == "analyze":
        out = dict(entry)
        out["p"] = _listify(out["p"])
        out["delays"] = _listify(out["delays"])
        out["m"] = int(out["m"])
        return out
    if mode == "simulate":
        return [{"updates": int(st.updates), "time": float(st.time),
                 "throughput": float(st.throughput),
                 "mean_delay": _listify(st.mean_delay),
                 "delay_counts": _listify(st.delay_counts),
                 "energy": float(st.energy),
                 "mean_queue_counts": _listify(st.mean_queue_counts)}
                for st in entry]
    if mode == "train":
        return [{"times": _listify(log.times),
                 "accuracies": _listify(log.accuracies),
                 "losses": _listify(log.losses),
                 "updates": _listify(log.updates),
                 "mean_delay": (None if log.mean_delay is None
                                else _listify(log.mean_delay)),
                 "throughput": float(log.throughput),
                 "energy": float(log.energy)}
                for log in entry]
    raise ValueError(f"unknown mode: {mode!r}")
