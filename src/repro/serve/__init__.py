"""``repro.serve`` — the always-on suite service.

A persistent server (``python -m repro.serve``) accepts scenario
requests over JSON lines (unix socket, stdio fallback), coalesces
concurrent requests into spare lanes of the suite planner's resident
programs, answers repeats from a ``Scenario.hash()`` response cache,
and restarts warm through the jax persistent compilation cache.

This ``__init__`` stays import-light (``metrics`` only): the scenario
layer imports :class:`Metrics` from here, and the server/executor pull
in jax-heavy modules only when actually booted.
"""
from .metrics import Histogram, Metrics

__all__ = ["Histogram", "Metrics"]
