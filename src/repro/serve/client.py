"""Blocking JSON-lines client for the suite server.

One :class:`ServeClient` per connection; requests may be pipelined
(submit several, then collect) — responses are demultiplexed by request
id.  Used by the tests, the bench and ``examples/serve_client.py``.
"""
from __future__ import annotations

import itertools
import json
import socket
from typing import Optional

from .protocol import encode


class ServeError(RuntimeError):
    """A structured server-side error, re-raised client-side."""

    def __init__(self, etype: str, message: str):
        super().__init__(f"{etype}: {message}")
        self.etype = etype


class ServeClient:
    def __init__(self, socket_path: str, timeout: Optional[float] = 300.0):
        self._sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        self._sock.settimeout(timeout)
        self._sock.connect(socket_path)
        self._rfile = self._sock.makefile("rb")
        self._ids = itertools.count()
        self._done: dict = {}      # id -> terminal (result/error) message
        self._events: dict = {}    # id -> non-terminal events seen

    def close(self) -> None:
        try:
            self._rfile.close()
        finally:
            self._sock.close()

    def __enter__(self) -> "ServeClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- low level ----------------------------------------------------------

    def send(self, msg: dict) -> None:
        self._sock.sendall(encode(msg))

    def send_raw(self, line: bytes) -> None:
        """Ship arbitrary bytes (protocol-error tests)."""
        self._sock.sendall(line)

    def _read_msg(self) -> dict:
        line = self._rfile.readline()
        if not line:
            raise ConnectionError("server closed the connection")
        return json.loads(line)

    def collect(self, req_id: str) -> dict:
        """Block until the terminal (result/error) message for ``req_id``;
        non-terminal events (accepted/scheduled) are recorded in
        ``events_for``."""
        while req_id not in self._done:
            msg = self._read_msg()
            mid = msg.get("id")
            if msg.get("event") in ("result", "error"):
                self._done[mid] = msg
            else:
                self._events.setdefault(mid, []).append(msg)
        return self._done.pop(req_id)

    def events_for(self, req_id: str) -> list:
        return self._events.get(req_id, [])

    # -- verbs --------------------------------------------------------------

    def submit(self, scenario, mode: str = "analyze", seeds=(0,),
               **options) -> str:
        """Fire a run request; returns its id (collect later)."""
        req_id = f"r{next(self._ids)}"
        scn = scenario if isinstance(scenario, dict) else scenario.to_dict()
        self.send({"id": req_id, "verb": "run", "mode": mode,
                   "scenario": scn, "seeds": list(seeds),
                   "options": options})
        return req_id

    def run(self, scenario, mode: str = "analyze", seeds=(0,), **options):
        """Submit + block for the payload; raises :class:`ServeError` on a
        structured error."""
        msg = self.collect(self.submit(scenario, mode, seeds, **options))
        return self.unwrap(msg)

    @staticmethod
    def unwrap(msg: dict):
        if msg.get("event") == "error":
            err = msg.get("error", {})
            raise ServeError(err.get("type", "Error"),
                             err.get("message", ""))
        return msg["value"]

    def stats(self) -> dict:
        req_id = f"r{next(self._ids)}"
        self.send({"id": req_id, "verb": "stats"})
        return self.unwrap(self.collect(req_id))

    def metrics(self) -> str:
        """Prometheus text exposition of the server's metric registry."""
        req_id = f"r{next(self._ids)}"
        self.send({"id": req_id, "verb": "metrics"})
        return self.unwrap(self.collect(req_id))

    def shutdown(self) -> str:
        req_id = f"r{next(self._ids)}"
        self.send({"id": req_id, "verb": "shutdown"})
        return self.unwrap(self.collect(req_id))
