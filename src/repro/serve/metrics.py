"""Counters and latency histograms shared by the server and direct runs.

``SuiteResult.cache_hits`` used to be the only observability the planner
had.  A :class:`Metrics` registry threads through ``ScenarioSuite.run``
(every suite owns one; pass ``metrics=`` to share a registry across
suites, as ``repro.serve`` does across micro-batches) and through the
server's admission/dispatch path, so both report the same per-bucket
counters: programs compiled, lanes dispatched, cache hits, and wall-clock
latency percentiles.

The registry is thread-safe (the server observes from reader threads and
the dispatcher thread concurrently) and dependency-free: histograms keep
a bounded reservoir of recent observations — exact percentiles over the
window, O(1) memory.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Optional

_RESERVOIR = 2048  # recent-observation window per histogram


class Histogram:
    """Bounded-reservoir histogram: exact percentiles over the most
    recent ``_RESERVOIR`` observations, plus all-time count and sum."""

    __slots__ = ("count", "total", "_window")

    def __init__(self):
        self.count = 0
        self.total = 0.0
        self._window = deque(maxlen=_RESERVOIR)

    def observe(self, value: float) -> None:
        self.count += 1
        self.total += float(value)
        self._window.append(float(value))

    def percentile(self, q: float) -> float:
        """Exact q-quantile (0 <= q <= 1) of the recent window (nearest
        rank); 0.0 when nothing has been observed."""
        if not self._window:
            return 0.0
        ordered = sorted(self._window)
        rank = min(len(ordered) - 1, max(0, round(q * (len(ordered) - 1))))
        return ordered[rank]

    def summary(self) -> dict:
        return {"count": self.count,
                "mean": self.total / self.count if self.count else 0.0,
                "p50": self.percentile(0.50),
                "p99": self.percentile(0.99)}


class Metrics:
    """Thread-safe named counters + histograms with optional labels.

    Label values land in the flattened snapshot key as
    ``name{k=v,...}`` — e.g. ``suite.lanes{mode=train}``.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: dict[str, float] = {}
        self._hists: dict[str, Histogram] = {}

    @staticmethod
    def _key(name: str, labels: dict) -> str:
        if not labels:
            return name
        inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
        return f"{name}{{{inner}}}"

    def inc(self, name: str, by: float = 1, **labels) -> None:
        key = self._key(name, labels)
        with self._lock:
            self._counters[key] = self._counters.get(key, 0) + by

    def counter(self, name: str, **labels) -> float:
        with self._lock:
            return self._counters.get(self._key(name, labels), 0)

    def observe(self, name: str, value: float, **labels) -> None:
        key = self._key(name, labels)
        with self._lock:
            hist = self._hists.get(key)
            if hist is None:
                hist = self._hists[key] = Histogram()
        hist.observe(value)

    def timed(self, name: str, **labels) -> "_Timer":
        """``with metrics.timed("suite.dispatch", mode="train"): ...``
        observes the block's wall-clock seconds."""
        return _Timer(self, name, labels)

    def snapshot(self) -> dict:
        """JSON-able view: ``{"counters": {...}, "latency": {key:
        {count, mean, p50, p99}}}``."""
        with self._lock:
            counters = dict(self._counters)
            hists = {k: h.summary() for k, h in self._hists.items()}
        return {"counters": counters, "latency": hists}


class _Timer:
    __slots__ = ("_metrics", "_name", "_labels", "_t0")

    def __init__(self, metrics: Metrics, name: str, labels: dict):
        self._metrics = metrics
        self._name = name
        self._labels = labels

    def __enter__(self) -> "_Timer":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> Optional[bool]:
        self._metrics.observe(self._name,
                              time.perf_counter() - self._t0,
                              **self._labels)
        return None
