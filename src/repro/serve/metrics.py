"""Backward-compat shim — the metrics registry lives in ``repro.obs``.

The registry started here (PR 8) as a serve-side helper; when
observability grew into its own subsystem the single shared registry
(suite + server + drift monitors) moved to :mod:`repro.obs.metrics`.
Existing imports keep working through this module.
"""
from ..obs.metrics import _RESERVOIR  # noqa: F401  (tests size reservoirs)
from ..obs.metrics import Histogram, Metrics, _Timer  # noqa: F401

__all__ = ["Histogram", "Metrics"]
