"""Minimal pytree checkpointing (npz + structure manifest).

No orbax in the container; this covers the training loop's needs: atomic
save, exact dtype/shape restore, step metadata, and works for any pytree of
arrays (params, optimizer state, RNG keys).
"""
from __future__ import annotations

import json
import os
import tempfile
from pathlib import Path
from typing import Any

import jax
import numpy as np


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten(tree)
    names = [f"leaf_{i}" for i in range(len(flat))]
    return names, flat, treedef


def save_checkpoint(path: str | Path, tree: Any, step: int = 0,
                    metadata: dict | None = None) -> None:
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    names, flat, treedef = _flatten_with_names(tree)

    def to_np(x):
        a = np.asarray(x)
        if a.dtype.kind == "V" or a.dtype.name in ("bfloat16", "float8_e4m3fn",
                                                   "float8_e5m2"):
            return a.astype(np.float32)  # lossless upcast; dtype restored on load
        return a

    arrays = {n: to_np(x) for n, x in zip(names, flat)}
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "n_leaves": len(flat),
        "dtypes": [str(a.dtype) for a in arrays.values()],
        "shapes": [list(a.shape) for a in arrays.values()],
        "metadata": metadata or {},
    }
    # atomic write: temp file in the same directory, then rename
    fd, tmp = tempfile.mkstemp(dir=path.parent, suffix=".npz")
    os.close(fd)
    try:
        with open(tmp, "wb") as f:
            np.savez(f, __manifest__=json.dumps(manifest), **arrays)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)


def load_checkpoint(path: str | Path, like: Any) -> tuple[Any, int, dict]:
    """Restore into the structure of ``like`` (shape/dtype-checked)."""
    with np.load(path, allow_pickle=False) as data:
        manifest = json.loads(str(data["__manifest__"]))
        flat_like, treedef = jax.tree_util.tree_flatten(like)
        if len(flat_like) != manifest["n_leaves"]:
            raise ValueError(
                f"checkpoint has {manifest['n_leaves']} leaves, "
                f"expected {len(flat_like)}")
        leaves = []
        for i, ref in enumerate(flat_like):
            arr = data[f"leaf_{i}"]
            if tuple(arr.shape) != tuple(np.shape(ref)):
                raise ValueError(f"leaf {i}: shape {arr.shape} != "
                                 f"{np.shape(ref)}")
            leaves.append(jax.numpy.asarray(arr, dtype=ref.dtype))
    return (jax.tree_util.tree_unflatten(treedef, leaves),
            manifest["step"], manifest["metadata"])
