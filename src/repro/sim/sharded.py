"""Multi-device lane sharding for the event engine (``backend="sharded"``).

Lanes (seeds x strategy lanes x scenarios) are embarrassingly parallel —
the ``"batched"`` backend already advances them in one vmapped program, but
on ONE device.  This backend splits the lane axis across every local device
with ``shard_map`` (via ``repro.compat``): each device runs the identical
vmapped single-lane scan on its slice of lanes, so a suite sweep scales
with the device count.  On CI the devices are the XLA host-platform CPUs
(``XLA_FLAGS=--xla_force_host_platform_device_count=8`` — the
``repro.launch.dryrun`` trick); on real hardware they are the accelerator
cores.

Bitwise contract: each lane's program is strictly lane-local (no
collectives, no cross-lane reductions), so sharding only changes WHERE a
lane runs, not what it computes — results are bitwise identical to the
``"batched"`` backend lane-by-lane at any device count (asserted in
``tests/test_sharded.py``).  The lane axis is padded to a device-count
multiple by repeating the final lane; padded lanes are computed and
discarded (never observable, and cheaper than a ragged mesh).

With a single local device the mesh is trivial and this backend is the
``"batched"`` program under one extra (identity) partitioning — useful as
the always-on CI configuration of the multi-device path.
"""
from __future__ import annotations
# contract: padded-n — reductions here are on the bitwise padding contract

import functools

import jax
import jax.numpy as jnp

from ..compat import make_mesh, shard_map
from ..core import events


def device_count() -> int:
    """Local devices the lane mesh spans (1 on a plain CPU process; >1
    under ``--xla_force_host_platform_device_count`` or real multi-chip)."""
    return len(jax.devices())


@functools.lru_cache(maxsize=None)
def _build_sharded_fn(nu: int, wu: int, distribution: str, m_max: int,
                      has_power: bool, kind: str = "client",
                      trace_events: int = 0, chunk: int = 1):
    """The compiled sharded lane-sweep program for one static signature.

    Memoized like ``batched_events._build_lanes_fn``; the returned wrapper
    handles lane padding on the host and slices the pad back off.
    ``kind`` selects the per-lane engine: ``"client"`` lanes carry
    ``NetworkParams`` (per-client tables), ``"class"`` lanes carry
    ``ClassParams`` through the O(#classes) class-aggregated engine.
    ``trace_events > 0`` runs the traced engine variant — per-lane
    telemetry rings shard with their lanes (strictly lane-local, so the
    bitwise contract is untouched) and the return becomes
    ``(stats, ring)``.  ``chunk > 1`` runs the megastep engine variant
    (bitwise equal trajectories, lane-local like everything else here).
    """
    ndev = device_count()

    if kind == "class":
        if trace_events:
            def one(prm, m, key, power):
                return events._simulate_stats_classes_traced(
                    prm, m, key, nu, wu, distribution, m_max, power,
                    trace_events, chunk)
        else:
            def one(prm, m, key, power):
                return events._simulate_stats_classes(
                    prm, m, key, nu, wu, distribution, m_max, power, chunk)
    else:
        if trace_events:
            def one(prm, m, key, power):
                return events._simulate_stats_traced(
                    prm, m, key, nu, wu, distribution, m_max, power,
                    trace_events, chunk)
        else:
            def one(prm, m, key, power):
                return events._simulate_stats(prm, m, key, nu, wu,
                                              distribution, m_max, power,
                                              chunk)

    mesh = make_mesh((ndev,), ("lanes",))
    spec = jax.sharding.PartitionSpec("lanes")

    # named (not a lambda) so the compile log — and the
    # repro.analysis.tracecheck program budgets — can identify the sharded
    # planner program by name
    if has_power:
        def sharded_lanes(prm, m, key, pw):
            return jax.vmap(one)(prm, m, key, pw)

        jfn = jax.jit(shard_map(sharded_lanes, mesh,
                                in_specs=(spec, spec, spec, spec),
                                out_specs=spec))
    else:
        def sharded_lanes(prm, m, key):
            return jax.vmap(lambda p_, m_, k_: one(p_, m_, k_, None))(
                prm, m, key)

        jfn = jax.jit(shard_map(sharded_lanes, mesh,
                                in_specs=(spec, spec, spec),
                                out_specs=spec))

    def wrapper(lane_params, m_vec, keys, power):
        L = int(m_vec.shape[0])
        Lp = -(-L // ndev) * ndev

        def pad(x):
            x = jnp.asarray(x)
            if Lp == L:
                return x
            reps = jnp.broadcast_to(x[-1:], (Lp - L,) + x.shape[1:])
            return jnp.concatenate([x, reps], axis=0)

        prm = jax.tree_util.tree_map(pad, lane_params)
        mv, ks = pad(m_vec), pad(keys)
        if has_power:
            out = jfn(prm, mv, ks, jax.tree_util.tree_map(pad, power))
        else:
            out = jfn(prm, mv, ks)
        return jax.tree_util.tree_map(lambda x: x[:L], out)

    return wrapper


def build_sharded_lanes_fn(num_updates: int, warmup: int, distribution: str,
                           m_max: int, has_power: bool,
                           trace_events: int = 0, chunk: int = 1):
    """``fn(lane_params, m_vec, keys, power) -> EventStats`` sharding the
    lane axis over all local devices (the ``"sharded"`` entry of
    ``batched_events._build_lanes_fn``)."""
    return _build_sharded_fn(int(num_updates), int(warmup), distribution,
                             int(m_max), bool(has_power), "client",
                             int(trace_events), int(chunk))


def build_sharded_class_lanes_fn(num_updates: int, warmup: int,
                                 distribution: str, m_max: int,
                                 has_power: bool, trace_events: int = 0,
                                 chunk: int = 1):
    """Class-aggregated variant: ``fn(lane_classes, m_vec, keys, power)``
    where each lane is a :class:`~repro.core.buzen.ClassParams` network run
    through ``events._simulate_stats_classes`` — the ``"sharded"`` entry of
    ``batched_events._build_class_lanes_fn``."""
    return _build_sharded_fn(int(num_updates), int(warmup), distribution,
                             int(m_max), bool(has_power), "class",
                             int(trace_events), int(chunk))
