"""repro.sim — the simulation-backend subsystem of the event engine.

One flag (``REPRO_SIM_BACKEND`` / :func:`set_backend`, per-call
``backend=...``) selects how closed-network trajectories execute:

  * ``"reference"`` — lane-at-a-time single-lane scans (semantic baseline);
  * ``"batched"``   — K lanes per scan step in ONE vmapped program
    (bitwise identical to the reference; the default);
  * ``"pallas"``    — the lock-step scan with the per-event table
    transition in the Pallas TPU kernel ``repro.kernels.events``
    (compiled on TPU, ``interpret=True`` fallback elsewhere);
  * ``"sharded"``   — the batched program ``shard_map``-ped over the lane
    axis so lanes split across all local devices
    (``repro.sim.sharded``; bitwise identical to ``"batched"`` at any
    device count).

Routed through this dispatch: ``repro.core.events.simulate_stats`` /
``next_update``, the fused trainer (``repro.fl.engine``), and
``ScenarioSuite.run(mode="simulate"|"train")``; a
``repro.scenario.SimSpec`` pins the backend per scenario.  The paper-scale
(n = 100, m_max = 132) sweep is benchmarked in
``benchmarks/bench_events_scale.py``.

Import structure mirrors ``repro.scenario``: ``backend`` (dependency-free)
loads eagerly; ``batched_events`` — which imports ``repro.core`` — loads
lazily on first attribute access.
"""
from __future__ import annotations

from .backend import BACKENDS, get_backend, resolve_backend, set_backend

_LANES = ("simulate_stats_lanes", "build_lanes_fn", "build_class_lanes_fn",
          "stack_lanes")

__all__ = ["BACKENDS", "set_backend", "get_backend", "resolve_backend",
           *_LANES]


def __getattr__(name: str):
    if name in _LANES:
        from . import batched_events

        return getattr(batched_events, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(__all__)
