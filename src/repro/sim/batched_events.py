"""Event-batched simulation: K independent lanes per ``lax.scan`` step.

The device event engine (``repro.core.events``) is sequential per
trajectory — one event per scan step — so a single lane cannot saturate a
device.  This module advances **K lanes in lock-step** (one event per lane
per step): seeds x strategy lanes x scenarios stack into ``[K, ...]``
tables and ONE jitted program sweeps them all, which is how the paper-scale
(n = 100, m = 132) populations of Section 6 run compiled next to the Buzen
kernel (``benchmarks/bench_events_scale.py``).

Three backends (see ``repro.sim.backend``), all returning identical
statistics on structurally-alike lanes:

  * ``"reference"`` — host loop over lanes, each a single-lane
    ``events._simulate_stats`` scan (one compile, L sequential executions);
  * ``"batched"``   — ``jax.vmap`` of the same scan: bitwise identical to
    ``"reference"`` lane-by-lane (asserted in
    ``tests/test_sim_backends.py``), one program for all lanes;
  * ``"pallas"``    — the lock-step scan with the per-event table
    transition in the Pallas kernel (``repro.kernels.events``); bitwise
    for the rate-free unit-draw laws (exponential / deterministic), equal
    to one floating-point rescale otherwise.

Entry points: :func:`simulate_stats_lanes` (list-of-params convenience)
and :func:`build_lanes_fn` (the cached-program form ``ScenarioSuite``
dispatches through).
"""
from __future__ import annotations
# contract: padded-n — reductions here are on the bitwise padding contract

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..core import events
from ..core.buzen import NetworkParams
from ..core.events import EventStats, finalize_stats
from ..obs.rings import event_ring_append, event_ring_init
from .backend import resolve_backend


def stack_lanes(trees):
    """Leaf-wise stack of per-lane pytrees (``NetworkParams``,
    ``PowerProfile``, ...) onto a leading lane axis."""
    trees = list(trees)
    if not trees:
        raise ValueError("need at least one lane")
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def _make_pallas_fn(num_updates: int, warmup: int, distribution: str,
                    m_max: int, interpret: Optional[bool],
                    trace_events: int = 0, chunk: int = 1):
    def fn(lane_params, m_vec, keys, power):
        mult = 4 if lane_params.mu_cs is not None else 3
        num_events = mult * (num_updates + warmup) + mult * m_max + 8
        cap = warmup + num_updates
        st = jax.vmap(lambda prm, m, key: events.init_state(
            prm, m, key, m_max=m_max, distribution=distribution,
            warmup=warmup, cap=cap))(lane_params, m_vec, keys)
        n = lane_params.p.shape[-1]
        L = m_vec.shape[0]
        ring = jax.vmap(lambda _: event_ring_init(int(trace_events)))(
            jnp.arange(L))

        def body(carry, _):
            from ..kernels.events import step_event_pallas

            st, ring = carry
            st2, out = step_event_pallas(lane_params, st,
                                         distribution=distribution,
                                         power=power, interpret=interpret)
            if trace_events:
                # ring appends read the pre/post states, never feed back:
                # traced == untraced bitwise (tests/test_obs.py)
                def app(rg, pre, post, o):
                    ph = pre.phase[o.slot]
                    return event_ring_append(
                        rg, time=o.time,
                        station=events._station_index(ph, o.client, n),
                        station_to=events._station_index(
                            post.phase[o.slot], post.client[o.slot], n),
                        kind=ph, slot=o.slot, client=o.client,
                        delay=o.delay, update=o.is_update)

                ring = jax.vmap(app)(ring, st, st2, out)
            return (st2, ring), None

        def megabody(carry, _):
            from ..kernels.events import megastep_event_pallas

            st, rem, ring = carry
            st2, aux = megastep_event_pallas(
                lane_params, st, chunk=chunk, rem=rem,
                distribution=distribution, power=power, interpret=interpret)
            if trace_events:
                # per-event appends replayed from the megastep descriptors,
                # masked by `keep` so partial chunks stay non-invasive
                def app_ev(rg, x):
                    t, stn, stn_to, kind, slot, client, delay, upd, keep = x

                    def app(rg1, t1, s1, s2, k1, sl, c1, d1, u1, v1):
                        return event_ring_append(
                            rg1, time=t1, station=s1, station_to=s2,
                            kind=k1, slot=sl, client=c1, delay=d1,
                            update=u1, valid=v1)

                    return jax.vmap(app)(rg, t, stn, stn_to, kind, slot,
                                         client, delay, upd, keep), None

                lead = lambda a: jnp.moveaxis(a, 1, 0)  # noqa: E731
                ring, _ = jax.lax.scan(app_ev, ring, (
                    lead(aux.time), lead(aux.station), lead(aux.station_to),
                    lead(aux.kind), lead(aux.slot), lead(aux.client),
                    lead(aux.delay), lead(aux.update), lead(aux.keep)))
            return (st2, rem - chunk, ring), None

        if chunk == 1:
            (st, ring), _ = jax.lax.scan(body, (st, ring), None,
                                         length=num_events)
        else:
            n_chunks = -(-num_events // chunk)
            (st, _, ring), _ = jax.lax.scan(
                megabody,
                (st, jnp.full((L,), num_events, jnp.int32), ring), None,
                length=n_chunks)
        stats = jax.vmap(finalize_stats)(st)
        return (stats, ring) if trace_events else stats

    return jax.jit(fn)


def build_lanes_fn(backend: str, num_updates: int, warmup: int,
                   distribution: str, m_max: int, has_power: bool,
                   interpret: Optional[bool] = None, trace_events: int = 0,
                   chunk: int = 1):
    """The compiled lane-sweep program for one static signature.

    Returns ``fn(lane_params, m_vec, keys, power) -> EventStats`` with a
    leading lane axis on every field; ``power`` is ``None`` when
    ``has_power`` is false, else a lane-stacked ``PowerProfile``.
    ``trace_events > 0`` selects the traced program variant: the return
    becomes ``(EventStats, EventRing)`` (per-lane rings of that
    capacity), with statistics bitwise equal to the untraced program.
    ``chunk > 1`` selects the megastep variant (``chunk`` events per scan
    iteration — one kernel launch under ``"pallas"``), trajectories
    bitwise equal to ``chunk = 1``.  Programs are memoized per signature —
    repeated sweeps (and every :func:`simulate_stats_lanes` call) reuse
    the compiled jit entry instead of retracing a fresh closure.
    """
    return _build_lanes_fn(resolve_backend(backend), int(num_updates),
                           int(warmup), distribution, int(m_max),
                           bool(has_power), interpret, int(trace_events),
                           int(chunk))


@functools.lru_cache(maxsize=None)
def _build_lanes_fn(backend: str, nu: int, wu: int, distribution: str,
                    m_max: int, has_power: bool,
                    interpret: Optional[bool], trace_events: int = 0,
                    chunk: int = 1):
    if backend == "reference":
        def fn(lane_params, m_vec, keys, power):
            outs = []
            for i in range(int(m_vec.shape[0])):
                prm = jax.tree_util.tree_map(lambda x: x[i], lane_params)
                pw = (None if power is None
                      else jax.tree_util.tree_map(lambda x: x[i], power))
                if trace_events:
                    outs.append(events._simulate_stats_traced(
                        prm, m_vec[i], keys[i], nu, wu, distribution, m_max,
                        pw, trace_events, chunk))
                else:
                    outs.append(events._simulate_stats(
                        prm, m_vec[i], keys[i], nu, wu, distribution, m_max,
                        pw, chunk))
            return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *outs)

        return fn

    if backend == "pallas":
        return _make_pallas_fn(nu, wu, distribution, m_max, interpret,
                               trace_events, chunk)

    if backend == "sharded":
        from .sharded import build_sharded_lanes_fn

        return build_sharded_lanes_fn(nu, wu, distribution, m_max, has_power,
                                      trace_events, chunk)

    # "batched": one jitted vmap of the single-lane scan
    if trace_events:
        def one_traced(prm, m, key, power):
            return events._simulate_stats_traced(
                prm, m, key, nu, wu, distribution, m_max, power,
                trace_events, chunk)

        if has_power:
            return jax.jit(jax.vmap(one_traced))

        # same planner-program name as the untraced variant: the compile
        # log and the tracecheck budgets see one "lanes" family
        def lanes(prm, m, key, _pw):
            return one_traced(prm, m, key, None)

        return jax.jit(jax.vmap(lanes, in_axes=(0, 0, 0, None)))

    def one(prm, m, key, power):
        return events._simulate_stats(prm, m, key, nu, wu, distribution,
                                      m_max, power, chunk)

    if has_power:
        return jax.jit(jax.vmap(one))

    # named (not a lambda) so the compile log — and the
    # repro.analysis.tracecheck program budgets — can identify the planner
    # program by name
    def lanes(prm, m, key, _pw):
        return one(prm, m, key, None)

    return jax.jit(jax.vmap(lanes, in_axes=(0, 0, 0, None)))


def build_class_lanes_fn(backend: str, num_updates: int, warmup: int,
                         distribution: str, m_max: int, has_power: bool,
                         trace_events: int = 0, chunk: int = 1):
    """The compiled class-lane sweep program for one static signature.

    Like :func:`build_lanes_fn` but each lane is a class-aggregated network
    (``repro.core.buzen.ClassParams``) run through the O(#classes) engine
    ``events._simulate_stats_classes`` — per-lane state scales with the
    number of classes, not the population, so lanes with n = 10^5-10^6
    members fit on device.  ``trace_events > 0`` selects the traced
    variant returning ``(stats, ring)``; ``chunk > 1`` the megastep
    variant (bitwise equal trajectories).  No pallas kernel exists for
    the class engine; ``"pallas"`` raises.
    """
    return _build_class_lanes_fn(resolve_backend(backend), int(num_updates),
                                 int(warmup), distribution, int(m_max),
                                 bool(has_power), int(trace_events),
                                 int(chunk))


@functools.lru_cache(maxsize=None)
def _build_class_lanes_fn(backend: str, nu: int, wu: int, distribution: str,
                          m_max: int, has_power: bool, trace_events: int = 0,
                          chunk: int = 1):
    if backend == "pallas":
        raise ValueError(
            "the class-aggregated event engine has no pallas kernel; pin "
            "backend='batched', 'reference' or 'sharded' for class lanes")

    if trace_events:
        def one(cls_, m, key, power):
            return events._simulate_stats_classes_traced(
                cls_, m, key, nu, wu, distribution, m_max, power,
                trace_events, chunk)
    else:
        def one(cls_, m, key, power):
            return events._simulate_stats_classes(cls_, m, key, nu, wu,
                                                  distribution, m_max, power,
                                                  chunk)

    if backend == "reference":
        def fn(lane_classes, m_vec, keys, power):
            outs = []
            for i in range(int(m_vec.shape[0])):
                cls_ = jax.tree_util.tree_map(lambda x: x[i], lane_classes)
                pw = (None if power is None
                      else jax.tree_util.tree_map(lambda x: x[i], power))
                outs.append(one(cls_, m_vec[i], keys[i], pw))
            return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *outs)

        return fn

    if backend == "sharded":
        from .sharded import build_sharded_class_lanes_fn

        return build_sharded_class_lanes_fn(nu, wu, distribution, m_max,
                                            has_power, trace_events, chunk)

    # "batched": one jitted vmap of the single-lane class scan
    if has_power:
        return jax.jit(jax.vmap(one))

    # named (not a lambda) for the tracecheck program budgets
    def class_lanes(cls_, m, key, _pw):
        return one(cls_, m, key, None)

    return jax.jit(jax.vmap(class_lanes, in_axes=(0, 0, 0, None)))


def simulate_stats_lanes(params, ms, num_updates: int, *, warmup: int = 0,
                         keys=None, seeds=None,
                         distribution: str = "exponential", power=None,
                         m_max: Optional[int] = None,
                         backend: Optional[str] = None,
                         interpret: Optional[bool] = None,
                         trace_events: int = 0,
                         chunk: int = 1) -> EventStats:
    """Stationary statistics for ``L`` lanes through the selected backend.

    ``params`` is a list of per-lane :class:`NetworkParams` (or one
    pre-stacked with ``[L, n]`` leaves); ``ms`` the per-lane concurrencies;
    ``keys``/``seeds`` the per-lane PRNG streams (default
    ``PRNGKey(0..L-1)``); ``power`` ``None``, one shared profile, or a
    per-lane list.  Returns :class:`EventStats` with a leading ``[L]``
    lane axis — or ``(EventStats, EventRing)`` when ``trace_events > 0``
    enables the telemetry ring (statistics bitwise unchanged).  Backends
    agree bitwise on alike lanes ("reference" vs "batched") — see the
    module docstring.
    """
    from ..scenario.laws import get_law

    get_law(distribution)  # eager: unknown laws fail listing the options
    backend = resolve_backend(backend)
    if isinstance(params, NetworkParams):  # NamedTuple: check before tuple
        lane_params = params
    elif isinstance(params, (list, tuple)):
        lane_params = stack_lanes(params)
    else:
        lane_params = params
    L = lane_params.p.shape[0]
    m_vec = jnp.asarray(ms, jnp.int32)
    if m_vec.shape != (L,):
        raise ValueError(f"ms has shape {m_vec.shape}, expected ({L},)")
    if keys is None:
        if seeds is None:
            seeds = range(L)
        keys = jnp.stack([jax.random.PRNGKey(int(s)) for s in seeds])
    if m_max is None:
        m_max = int(jnp.max(m_vec))
    if power is not None:
        if not hasattr(power, "P_c"):  # list of per-lane profiles
            power = stack_lanes(power)
        elif power.P_c.ndim == 1:      # one shared profile -> broadcast
            power = jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(jnp.asarray(x), (L,) + jnp.asarray(x).shape),
                power)
    fn = build_lanes_fn(backend, num_updates, warmup, distribution,
                        int(m_max), power is not None, interpret=interpret,
                        trace_events=trace_events, chunk=chunk)
    return fn(lane_params, m_vec, keys, power)
