"""Process-wide simulation-backend flag for the event engine.

Mirrors ``repro.core.buzen.set_backend``: the closed-network event engine
dispatches behind a named backend —

  * ``"reference"`` — one lane at a time: each lane runs the single-lane
    jitted event scan of ``repro.core.events`` and results are stacked on
    the host.  The semantic baseline (and the bitwise contract for the
    other backends on structurally-alike lanes).
  * ``"batched"``  — all lanes advance together: ONE jitted ``vmap`` over
    the lane axis, one event per lane per scan step, so a multi-lane sweep
    (seeds x strategies x scenarios) saturates the device even though each
    lane is sequential.  Bitwise identical to ``"reference"`` (vmap of the
    same pure step function).
  * ``"pallas"``   — like ``"batched"``, but the per-event hot path (the
    parallel argmin over the ``[m_max]`` finish-clock table and the fused
    phase-promotion / routing / FIFO-pick table transition) runs in the
    Pallas TPU kernel ``repro.kernels.events`` (compiled on TPU,
    ``interpret=True`` fallback elsewhere).
  * ``"sharded"``  — ``"batched"`` with the lane axis split across all
    local devices via ``shard_map`` (``repro.sim.sharded``); bitwise
    identical to ``"batched"`` lane-by-lane at any device count.

Select per call with ``backend=...``, process-wide with
:func:`set_backend`, or via the ``REPRO_SIM_BACKEND`` environment variable.
This module is dependency-free so ``repro.core.events`` and the Scenario
spec can import it without cycles.
"""
from __future__ import annotations

import os
from typing import Optional

BACKENDS = ("reference", "batched", "pallas", "sharded")

_backend: Optional[str] = None  # resolved lazily so a bad env var reports late


def _check(name: str) -> str:
    if name not in BACKENDS:
        raise ValueError(
            f"unknown sim backend: {name!r}; registered backends: "
            f"{sorted(BACKENDS)}")
    return name


def set_backend(name: str) -> None:
    """Set the process-wide default event-engine backend."""
    global _backend
    _backend = _check(name)


def get_backend() -> str:
    """The process-wide default backend (``REPRO_SIM_BACKEND`` or
    ``"batched"``)."""
    global _backend
    if _backend is None:
        _backend = _check(os.environ.get("REPRO_SIM_BACKEND", "batched"))
    return _backend


def resolve_backend(name: Optional[str] = None) -> str:
    """Per-call override resolution: ``name`` if given (validated), else the
    process-wide default."""
    return get_backend() if name is None else _check(name)
