"""§Perf helper: rank dry-run pairs for hillclimbing and diff variants.

  python -m repro.launch.hillclimb rank            # pick interesting pairs
  python -m repro.launch.hillclimb diff A.json B.json
"""
from __future__ import annotations

import json
import sys
from pathlib import Path


def load_all(out_dir="experiments/dryrun"):
    rows = []
    for f in sorted(Path(out_dir).glob("*.json")):
        r = json.loads(f.read_text())
        if not r.get("skipped") and r.get("mesh") == "16x16":
            rows.append(r)
    return rows


def rank():
    rows = load_all()
    print("== worst useful-flops ratio (compute waste) ==")
    by_ratio = sorted(rows, key=lambda r: r["roofline"]["useful_flops_ratio"])
    for r in by_ratio[:6]:
        print(f"  {r['arch']} {r['shape']}: ratio="
              f"{r['roofline']['useful_flops_ratio']:.3f} "
              f"dominant={r['roofline']['dominant']}")
    print("== most collective-bound (collective_s / max(other)) ==")
    def coll_frac(r):
        ro = r["roofline"]
        other = max(ro["compute_s"], ro["memory_s"], 1e-12)
        return ro["collective_s"] / other
    by_coll = sorted(rows, key=coll_frac, reverse=True)
    for r in by_coll[:6]:
        print(f"  {r['arch']} {r['shape']}: frac={coll_frac(r):.2f} "
              f"coll={r['roofline']['collective_s']:.2e}s")
    print("== memory over v5e capacity (peak > 16 GiB) ==")
    for r in rows:
        peak = r["memory"]["peak_bytes"] / 2**30
        if peak > 16:
            print(f"  {r['arch']} {r['shape']}: peak={peak:.2f} GiB")


def diff(a_path, b_path):
    a = json.loads(Path(a_path).read_text())
    b = json.loads(Path(b_path).read_text())

    def line(name, va, vb):
        delta = (vb - va) / va * 100 if va else float("nan")
        print(f"  {name:24s} {va:.4e} -> {vb:.4e}  ({delta:+.1f}%)")

    ra, rb = a["roofline"], b["roofline"]
    print(f"{a['arch']} {a['shape']} {a['mesh']}:")
    for k in ("compute_s", "memory_s", "collective_s", "flops_per_device",
              "bytes_per_device", "collective_link_bytes"):
        line(k, ra[k], rb[k])
    line("peak_bytes", a["memory"]["peak_bytes"], b["memory"]["peak_bytes"])
    line("temp_bytes", a["memory"]["temp_bytes"], b["memory"]["temp_bytes"])


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "diff":
        diff(sys.argv[2], sys.argv[3])
    else:
        rank()
