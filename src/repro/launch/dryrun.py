import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init); everything else follows.

import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import analyze_compiled, model_flops
from repro.models import INPUT_SHAPES, build_model
from repro.models.parallel import ParallelContext, param_spec

SKIPS = {
    # enc-dec decoder anchored to a 1500-frame encoder: no sliding-window
    # analogue preserving cross-attention semantics (DESIGN.md §4)
    ("whisper-medium", "long_500k"),
}


# ---------------------------------------------------------------------------
# sharding attachment helpers
# ---------------------------------------------------------------------------

def _path_str(path):
    return "/".join(str(getattr(k, "key", getattr(k, "name", getattr(k, "idx", k))))
                    for k in path)


def _divides(mesh, dim, axes):
    if axes is None:
        return True
    names = axes if isinstance(axes, tuple) else (axes,)
    size = 1
    for nm in names:
        size *= mesh.shape[nm]
    return dim % size == 0 and dim >= size


def param_sds(bundle, ctx, serve_sharding: bool = False):
    """ShapeDtypeStructs for params with NamedShardings attached.

    ``serve_sharding`` drops the FSDP ('data') axis from parameter shardings
    (replicate over data, shard over model only) — the serving-optimized
    layout that avoids per-step parameter all-gathers (§Perf)."""
    shapes = jax.eval_shape(bundle.init, jax.random.PRNGKey(0))
    mesh = ctx.mesh

    def strip_data(spec):
        def fix(ax):
            if ax == "data":
                return None
            if isinstance(ax, tuple):
                t = tuple(a for a in ax if a != "data")
                return t if t else None
            return ax
        return jax.sharding.PartitionSpec(*[fix(a) for a in spec])

    def visit(path, leaf):
        spec = param_spec(_path_str(path), leaf.shape, ctx)
        if serve_sharding:
            spec = strip_data(spec)
        # drop axes that do not divide
        fixed = []
        for i, ax in enumerate(spec):
            fixed.append(ax if ax is None or _divides(mesh, leaf.shape[i], ax)
                         else None)
        fixed += [None] * (len(leaf.shape) - len(fixed))
        return jax.ShapeDtypeStruct(
            leaf.shape, leaf.dtype,
            sharding=NamedSharding(mesh, P(*fixed[:len(leaf.shape)])))

    return jax.tree_util.tree_map_with_path(visit, shapes)


def opt_state_sds(bundle, params_sds, ctx):
    """Optimizer-state SDS sharded congruently with the parameters."""
    mesh = ctx.mesh
    shapes = jax.eval_shape(bundle.optimizer.init, params_sds)
    # map param path string -> spec
    spec_of = {}
    jax.tree_util.tree_map_with_path(
        lambda p, l: spec_of.__setitem__(_path_str(p), l.sharding.spec),
        params_sds)

    name = bundle.optimizer.name

    def visit(path, leaf):
        ps = _path_str(path)
        spec = P(*([None] * len(leaf.shape)))
        if name in ("adamw", "momentum"):
            key = ps
            for prefix in ("mu/", "nu/", ""):
                stripped = ps.split("/", 1)[-1] if "/" in ps else ps
                if stripped in spec_of:
                    spec = spec_of[stripped]
                    break
        elif name == "adafactor":
            # paths look like slots/<param path>/vr
            parts = ps.split("/")
            if parts and parts[-1] in ("vr", "vc", "v"):
                pkey = "/".join(parts[1:-1])
                if pkey in spec_of:
                    base = list(spec_of[pkey])
                    if parts[-1] == "vr":
                        spec = P(*base[:-1])
                    elif parts[-1] == "vc":
                        spec = P(*(base[:-2] + base[-1:]))
                    else:
                        spec = P(*base)
        # drop non-dividing axes
        fixed = []
        for i, ax in enumerate(spec):
            fixed.append(ax if ax is None or _divides(mesh, leaf.shape[i], ax)
                         else None)
        fixed += [None] * (len(leaf.shape) - len(fixed))
        return jax.ShapeDtypeStruct(
            leaf.shape, leaf.dtype,
            sharding=NamedSharding(mesh, P(*fixed[:len(leaf.shape)])))

    return jax.tree_util.tree_map_with_path(visit, shapes)


def batch_sds(bundle, shape, ctx, window=None):
    """Input SDS (tokens / embeds / decode cache) with shardings."""
    mesh = ctx.mesh
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    specs = bundle.input_specs(shape, for_decode_window=window)

    def leaf_spec(path, leaf):
        shp = leaf.shape
        ps = _path_str(path)
        ndim = len(shp)
        spec = [None] * ndim
        if ps in ("tokens", "targets", "image_embeds", "frames"):
            if _divides(mesh, shp[0], batch_axes):
                spec[0] = batch_axes
        elif ps == "pos":
            pass
        else:  # cache leaves: [G?, B, ...]
            bdim = None
            for i, d in enumerate(shp[:2]):
                if d == shape.global_batch:
                    bdim = i
                    break
            if bdim is not None and _divides(mesh, shp[bdim], batch_axes):
                spec[bdim] = batch_axes
            else:
                # batch too small (long_500k): context-shard the largest dim
                if ndim >= 3:
                    cand = max(range(1, ndim), key=lambda i: shp[i])
                    if _divides(mesh, shp[cand], ("data",)) and "data" in mesh.axis_names:
                        spec[cand] = "data"
            # model-shard a feature dim: prefer the heads dim (-2) of KV
            # caches, then the sequence dim (-3) — sharding head_dim forces
            # full-cache all-gathers in decode attention (§Perf iteration) —
            # else the last divisible feature dim
            order = ([ndim - 2, ndim - 3] if ndim >= 4 else []) + \
                list(range(ndim - 1, 0, -1))
            for i in order:
                if spec[i] is None and shp[i] > 1 and \
                        _divides(mesh, shp[i], ("model",)):
                    spec[i] = "model"
                    break
        return jax.ShapeDtypeStruct(
            shp, leaf.dtype, sharding=NamedSharding(mesh, P(*spec)))

    return jax.tree_util.tree_map_with_path(leaf_spec, specs)


# ---------------------------------------------------------------------------
# one dry-run
# ---------------------------------------------------------------------------

def _lower_for(bundle, shape, ctx, window, *, serve_sharding=False,
               donate=False):
    """jit().lower() the step function matching the shape's kind."""
    p_sds = param_sds(bundle, ctx, serve_sharding=serve_sharding)
    if shape.kind == "train":
        o_sds = opt_state_sds(bundle, p_sds, ctx)
        b_sds = batch_sds(bundle, shape, ctx)
        donate_args = (0, 1) if donate else ()
        return jax.jit(bundle.train_step,
                       donate_argnums=donate_args).lower(p_sds, o_sds, b_sds)
    if shape.kind == "prefill":
        b_sds = batch_sds(bundle, shape, ctx)
        return jax.jit(bundle.prefill).lower(p_sds, b_sds)
    b = batch_sds(bundle, shape, ctx, window=window)
    donate_args = (1,) if donate else ()  # decode: donate the KV cache
    return jax.jit(bundle.decode_step, donate_argnums=donate_args).lower(
        p_sds, b["cache"], b["tokens"], b["pos"])


def run_one(arch: str, shape_name: str, multi_pod: bool, out_dir: Path,
            verbose: bool = True, overrides: dict | None = None,
            tag_suffix: str = "") -> dict:
    """One (arch x shape x mesh) dry-run.

    Three fast compiles:
      (a) the FULL-depth scanned module -> proves lowering/sharding and gives
          exact ``memory_analysis`` (scan keeps HLO size depth-independent);
      (b,c) unrolled 1-group and 2-group variants (full width) -> exact
          per-group FLOPs/bytes/collectives by the linear identity
          ``F(k) = F_fixed + k * F_body`` (layer groups are homogeneous), so
          ``F(G) = F(1) + (G - 1) * (F(2) - F(1))`` — this sidesteps XLA
          cost analysis counting while-loop bodies once.
    """
    import dataclasses as _dc
    shape = INPUT_SHAPES[shape_name]
    cfg = get_config(arch)
    serve_sharding = donate = False
    if overrides:
        overrides = dict(overrides)
        serve_sharding = bool(overrides.pop("serve_sharding", False))
        donate = bool(overrides.pop("donate", False))
        moe_over = {k[4:]: v for k, v in overrides.items()
                    if k.startswith("moe.")}
        plain = {k: v for k, v in overrides.items() if "." not in k}
        if moe_over and cfg.moe is not None:
            plain["moe"] = _dc.replace(cfg.moe, **moe_over)
        cfg = _dc.replace(cfg, **plain)
    if (arch, shape_name) in SKIPS:
        res = {"arch": arch, "shape": shape_name, "skipped": True,
               "reason": "documented skip (DESIGN.md §4)"}
        out_dir.mkdir(parents=True, exist_ok=True)
        tag = f"{arch}__{shape_name}__{'multi' if multi_pod else 'single'}"
        with open(out_dir / f"{tag}.json", "w") as f:
            json.dump(res, f, indent=2)
        return res
    window = None
    if shape_name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        if cfg.sliding_window is None:
            return {"arch": arch, "shape": shape_name, "skipped": True,
                    "reason": "full attention at 500k requires SWA variant"}
        window = cfg.sliding_window

    mesh = make_production_mesh(multi_pod=multi_pod)
    ctx = ParallelContext(mesh=mesh)
    result = {"arch": arch, "shape": shape_name,
              "mesh": "2x16x16" if multi_pod else "16x16",
              "n_devices": mesh.size, "window": window}

    # (a) full-depth scanned module: lower + compile + memory analysis
    bundle = build_model(cfg, ctx, window_override=window)
    t0 = time.time()
    lowered = _lower_for(bundle, shape, ctx, window,
                         serve_sharding=serve_sharding, donate=donate)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    ma = compiled.memory_analysis()

    # (b, c) unrolled shallow variants for exact per-group cost extrapolation
    n_pre = cfg.first_k_dense
    gsz = cfg.group_size
    roofs = []
    for k in (1, 2):
        cfg_k = _dc.replace(cfg, n_layers=(n_pre + k) * gsz,
                            scan_layers=False)
        bundle_k = build_model(cfg_k, ctx, window_override=window)
        comp_k = _lower_for(bundle_k, shape, ctx, window,
                            serve_sharding=serve_sharding,
                            donate=donate).compile()
        roofs.append(analyze_compiled(comp_k))
    G = cfg.n_groups - n_pre

    def extrap(f1, f2):
        # per-group body cost; tiny decode graphs can measure f2 < f1 due to
        # XLA optimization noise — clamp the body to non-negative
        return f1 + (G - 1) * max(f2 - f1, 0.0)

    flops = extrap(roofs[0].flops_per_device, roofs[1].flops_per_device)
    byts = extrap(roofs[0].bytes_per_device, roofs[1].bytes_per_device)
    link = extrap(roofs[0].collectives.link_bytes,
                  roofs[1].collectives.link_bytes)
    counts = {op: extrap(roofs[0].collectives.counts.get(op, 0),
                         roofs[1].collectives.counts.get(op, 0))
              for op in set(roofs[0].collectives.counts)
              | set(roofs[1].collectives.counts)}
    out_b = {op: extrap(roofs[0].collectives.output_bytes.get(op, 0.0),
                        roofs[1].collectives.output_bytes.get(op, 0.0))
             for op in counts}
    from repro.launch.roofline import (CollectiveStats, HBM_BW, ICI_BW,
                                       PEAK_FLOPS, Roofline)
    mf = model_flops(cfg, shape, mesh.size)
    roof = Roofline(
        flops_per_device=flops, bytes_per_device=byts,
        collectives=CollectiveStats(counts=counts, output_bytes=out_b,
                                    link_bytes=link),
        compute_s=flops / PEAK_FLOPS, memory_s=byts / HBM_BW,
        collective_s=link / ICI_BW, model_flops=mf)

    result.update(
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        memory=dict(
            argument_bytes=int(ma.argument_size_in_bytes),
            output_bytes=int(ma.output_size_in_bytes),
            temp_bytes=int(ma.temp_size_in_bytes),
            peak_bytes=int(ma.peak_memory_in_bytes),
        ),
        roofline=roof.to_dict(),
    )
    out_dir.mkdir(parents=True, exist_ok=True)
    tag = f"{arch}__{shape_name}__{'multi' if multi_pod else 'single'}{tag_suffix}"
    with open(out_dir / f"{tag}.json", "w") as f:
        json.dump(result, f, indent=2)
    if verbose:
        print(f"[ok] {tag}: lower {t_lower:.1f}s compile {t_compile:.1f}s "
              f"flops/dev {roof.flops_per_device:.3e} "
              f"peak {ma.peak_memory_in_bytes/2**30:.2f} GiB "
              f"dominant {roof.dominant}", flush=True)
        print(f"     memory_analysis: args={ma.argument_size_in_bytes:,} "
              f"temp={ma.temp_size_in_bytes:,} peak={ma.peak_memory_in_bytes:,}")
        print(f"     cost_analysis(extrapolated): flops={roof.flops_per_device:.3e} "
              f"bytes={roof.bytes_per_device:.3e} "
              f"collective_link_bytes={roof.collectives.link_bytes:.3e}")
    return result


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all",
                    help="input shape name or 'all'")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override key=value (perf variants), e.g. "
                         "remat_policy=dots prefill_last_only=1 "
                         "moe.capacity_factor=1.0")
    ap.add_argument("--tag", default="", help="suffix for the output json")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        if v in ("True", "False", "0", "1") and k != "remat_policy":
            overrides[k] = v in ("True", "1")
        else:
            try:
                overrides[k] = float(v) if "." in v else int(v)
            except ValueError:
                overrides[k] = v

    archs = ARCHS if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    out_dir = Path(args.out)

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    run_one(arch, shape, mp, out_dir, overrides=overrides,
                            tag_suffix=args.tag)
                except Exception as e:  # noqa: BLE001
                    failures.append((arch, shape, mp, repr(e)))
                    print(f"[FAIL] {arch} {shape} "
                          f"{'multi' if mp else 'single'}: {e}")
                    traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} dry-run failures: {failures}")


if __name__ == "__main__":
    main()
