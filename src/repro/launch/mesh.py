"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
init to obtain the placeholder devices.  Mesh construction goes through
``repro.compat.make_mesh`` so the ``axis_types`` kwarg degrades gracefully
on older jax.
"""
from __future__ import annotations

import jax

from ..compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips when ``multi_pod``."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist locally, as a (1, n_dev) data x model mesh —
    used by CPU integration tests of the sharded code paths."""
    n = len(jax.devices())
    return make_mesh((1, n), ("data", "model"))
