"""Batched decode serving driver: prefill a batch of prompts, then decode.

CPU-sized by default (``--preset tiny``); full configs target TPU where the
Pallas decode kernel replaces the XLA path automatically.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--preset", default="tiny", choices=["tiny", "full"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.models import build_model

    cfg = get_config(args.arch)
    if args.preset == "tiny":
        cfg = cfg.reduced(vocab=512, n_layers=2 * cfg.group_size)
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    B, P, N = args.batch, args.prompt_len, args.new_tokens
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (B, P)), jnp.int32)

    cache = bundle.init_cache(B, P + N)
    step = jax.jit(bundle.decode_step)

    # prefill by stepping (simple driver; prefill() is the bulk path)
    t0 = time.time()
    logits = None
    for t in range(P):
        logits, cache = step(params, cache, prompts[:, t:t + 1], jnp.int32(t))
    t_prefill = time.time() - t0

    toks = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out = [toks]
    t0 = time.time()
    for t in range(P, P + N - 1):
        logits, cache = step(params, cache, toks, jnp.int32(t))
        toks = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out.append(toks)
    jax.block_until_ready(toks)
    t_decode = time.time() - t0
    seqs = np.concatenate([np.asarray(t) for t in out], axis=1)
    print(f"[serve] {cfg.name}: batch={B} prompt={P} new={N}")
    print(f"  prefill {t_prefill:.2f}s | decode {t_decode:.2f}s "
          f"({B * (N - 1) / max(t_decode, 1e-9):.1f} tok/s)")
    print(f"  sample continuation: {seqs[0, :16].tolist()}")


if __name__ == "__main__":
    main()
