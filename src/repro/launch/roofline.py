"""Roofline-term derivation from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds (TPU v5e constants):

  compute    = HLO_FLOPs_per_device / PEAK_FLOPS
  memory     = HLO_bytes_per_device / HBM_BW
  collective = link_bytes_per_device / ICI_BW

``cost_analysis()`` reports per-device FLOPs / bytes for the partitioned
module.  Collective bytes are NOT in cost_analysis: we scrape the optimized
HLO (``compiled.as_text()``) summing the output bytes of every collective
op, converted to *link bytes* with the standard ring-algorithm factors
(all-reduce 2(N-1)/N, all-gather/reduce-scatter/all-to-all (N-1)/N,
collective-permute 1), where N is the replica-group size parsed per op.
Ops inside while-loop bodies (scan over layer groups) are multiplied by the
trip count parsed from the loop's shape.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

# TPU v5e per-chip constants (assignment brief)
PEAK_FLOPS = 197e12     # bf16 FLOP/s
HBM_BW = 819e9          # bytes/s
ICI_BW = 50e9           # bytes/s/link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64|pred|c64|c128)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2


def _link_factor(op: str, n: int) -> float:
    if n <= 1:
        return 0.0
    if op == "all-reduce":
        return 2.0 * (n - 1) / n
    if op in ("all-gather", "reduce-scatter", "all-to-all"):
        return (n - 1) / n
    return 1.0  # collective-permute


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    output_bytes: dict     # static per-execution output bytes by op type
    link_bytes: float      # ring-model bytes over ICI per device


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Scrape collective ops from optimized HLO, weighting ops inside while
    bodies by their trip counts (scan over layer groups)."""
    # map computation name -> trip count for while loops:
    # XLA names scan loop bodies like "%while_body...". Trip counts are hard
    # to recover exactly post-optimization; we use the documented convention
    # that jitted scans carry "iteration_count" hints or derive from the
    # induction bound `s32[] constant(N)` preceding the while. As a robust
    # fallback we look for `trip_count=N` backend annotations; otherwise
    # weight 1 (the per-layer collective is then reported per group — noted
    # in EXPERIMENTS.md).
    trip_counts: dict[str, int] = {}
    current_comp = None
    comp_re = re.compile(r"^%?([\w\.\-]+)\s*(?:\([^)]*\))?\s*->.*\{?$")
    counts: dict[str, int] = {}
    out_bytes: dict[str, float] = {}
    link = 0.0

    lines = hlo_text.splitlines()
    # pass 1: find while ops referencing body computations with known trip
    # counts from the config string
    body_weight: dict[str, int] = {}
    for ln in lines:
        if " while(" in ln:
            m = re.search(r"body=%?([\w\.\-]+)", ln)
            t = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', ln)
            if m:
                body_weight[m.group(1)] = int(t.group(1)) if t else 1

    current_weight = 1
    for ln in lines:
        stripped = ln.strip()
        m = re.match(r"^%?([\w\.\-]+)\s*\(", stripped)
        if (stripped.endswith("{") and "=" not in stripped.split("(")[0]
                and m):
            name = m.group(1)
            current_weight = body_weight.get(name, 1)
            continue
        if stripped == "}":
            current_weight = 1
            continue
        for op in _COLLECTIVES:
            token = f" {op}("
            if token in ln and "%" in ln:
                lhs = ln.split(f" {op}(")[0]
                b = _shape_bytes(lhs)
                n = _group_size(ln)
                w = current_weight
                counts[op] = counts.get(op, 0) + w
                out_bytes[op] = out_bytes.get(op, 0.0) + w * b
                link += w * b * _link_factor(op, n)
                break
    return CollectiveStats(counts=counts, output_bytes=out_bytes,
                           link_bytes=link)


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    collectives: CollectiveStats
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float = 0.0

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        if self.flops_per_device <= 0:
            return 0.0
        return self.model_flops / self.flops_per_device

    def to_dict(self):
        return {
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_counts": self.collectives.counts,
            "collective_output_bytes": self.collectives.output_bytes,
            "collective_link_bytes": self.collectives.link_bytes,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops_per_device": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
        }


def analyze_compiled(compiled, model_flops_per_device: float = 0.0) -> Roofline:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    colls = parse_collectives(compiled.as_text())
    return Roofline(
        flops_per_device=flops,
        bytes_per_device=byts,
        collectives=colls,
        compute_s=flops / PEAK_FLOPS,
        memory_s=byts / HBM_BW,
        collective_s=colls.link_bytes / ICI_BW,
        model_flops=model_flops_per_device,
    )


# ---------------------------------------------------------------------------
# analytic MODEL_FLOPS (6 N D for training; 2 N_active D for inference)
# ---------------------------------------------------------------------------

def count_params(cfg) -> tuple[float, float]:
    """(total params, active params per token) — analytic, no allocation."""
    d, f, V = cfg.d_model, cfg.d_ff, cfg.vocab
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    attn = d * H * hd + 2 * d * KV * hd + H * hd * d

    def ffn_dense(ff):
        return 3 * d * ff

    total = V * d + (0 if cfg.tie_embeddings else d * V)
    active = total
    di = cfg.mamba_expand * d
    dtr = max(1, -(-d // 16))
    ds = cfg.mamba_d_state
    mamba = (d * 2 * di + cfg.mamba_d_conv * di + di * (dtr + 2 * ds)
             + dtr * di + di * ds + di + di * d)
    di_m = 2 * d
    mlstm = d * 2 * di_m + 3 * di_m * di_m + 2 * di_m * cfg.n_heads + di_m * d
    f_s = int(4 * d / 3)
    slstm = 4 * (d * d + cfg.n_heads * (d // cfg.n_heads) ** 2) + d * 2 * f_s + f_s * d

    for layer in range(cfg.n_layers):
        slot = layer % cfg.group_size
        kind = cfg.block_pattern[slot]
        group_idx = layer // cfg.group_size
        ffk = cfg.ffns[slot]
        if group_idx < cfg.first_k_dense and ffk == "moe":
            ffk = "dense"
        mix = {"attn": attn, "mamba": mamba, "mlstm": mlstm,
               "slstm": slstm}[kind]
        total += mix
        active += mix
        if ffk == "dense":
            total += ffn_dense(f)
            active += ffn_dense(f)
        elif ffk == "moe":
            moe = cfg.moe
            total += d * moe.num_experts + 3 * d * moe.expert_ff * moe.num_experts
            active += d * moe.num_experts + 3 * d * moe.expert_ff * moe.top_k
            if moe.num_shared:
                sh = 3 * d * moe.num_shared * moe.shared_ff
                total += sh + d
                active += sh + d
    if cfg.family == "audio":
        enc = cfg.encoder_layers * (attn + ffn_dense(f))
        xattn = cfg.n_layers * attn  # cross-attention per decoder layer
        total += enc + xattn
        active += enc + xattn
    return float(total), float(active)


def model_flops(cfg, shape, n_devices: int) -> float:
    """Per-device 'useful' FLOPs: 6*N_active*tokens (train) or
    2*N_active*tokens (inference)."""
    _, active = count_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        mult = 6.0
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        mult = 2.0
    else:
        tokens = shape.global_batch * 1
        mult = 2.0
    return mult * active * tokens / n_devices
