"""Summarize dry-run JSONs into the EXPERIMENTS.md roofline table."""
from __future__ import annotations

import argparse
import json
from pathlib import Path


def fmt_s(x):
    if x >= 1:
        return f"{x:.1f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def load(out_dir: Path, include_variants: bool = False):
    rows = []
    for f in sorted(out_dir.glob("*.json")):
        if not include_variants and f.stem.count("__") != 2:
            continue  # skip §Perf variant runs (arch__shape__mesh__tag)
        with open(f) as fh:
            rows.append(json.load(fh))
    return rows


def table(rows, mesh="16x16"):
    lines = [
        "| arch | shape | compute | memory | collective | dominant | "
        "peak GiB/dev | FLOPs/dev | MODEL/HLO | note |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r.get("skipped"):
            if mesh == "16x16":
                lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — |"
                             f" — | — | — | SKIP: {r['reason']} |")
            continue
        if r["mesh"] != mesh:
            continue
        ro = r["roofline"]
        note = f"SWA w={r['window']}" if r.get("window") else ""
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(ro['compute_s'])} | "
            f"{fmt_s(ro['memory_s'])} | {fmt_s(ro['collective_s'])} | "
            f"{ro['dominant']} | "
            f"{r['memory']['peak_bytes'] / 2**30:.2f} | "
            f"{ro['flops_per_device']:.2e} | "
            f"{ro['useful_flops_ratio']:.2f} | {note} |")
    return "\n".join(lines)


def multi_pod_status(rows):
    lines = ["| arch | shape | compiled | peak GiB/dev | link bytes/dev |",
             "|---|---|---|---|---|"]
    for r in rows:
        if r.get("skipped") or r["mesh"] != "2x16x16":
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | yes | "
            f"{r['memory']['peak_bytes'] / 2**30:.2f} | "
            f"{r['roofline']['collective_link_bytes']:.2e} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    rows = load(Path(args.dir))
    print(f"## Roofline (single pod {args.mesh})\n")
    print(table(rows, args.mesh))
    print("\n## Multi-pod (2x16x16) compile status\n")
    print(multi_pod_status(rows))


if __name__ == "__main__":
    main()
