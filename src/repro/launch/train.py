"""End-to-end training driver.

Two modes:

  * ``--mode sync``  — plain data-parallel training of the selected
    architecture on synthetic LM data (sanity/perf driver; uses the host
    devices, full configs are for TPU).
  * ``--mode async`` — the paper's Generalized AsyncSGD: a heterogeneous
    client population (Table-1 clusters) computes gradient tasks whose
    timing follows the closed Jackson network; routing/concurrency come
    from a strategy in {asyncsgd, max_throughput, round_opt, time_opt}.

Examples (CPU-sized):
  PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \\
      --preset tiny --steps 200
  PYTHONPATH=src python -m repro.launch.train --mode async \\
      --strategy time_opt --horizon 150
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def run_sync(args):
    from repro.configs import get_config
    from repro.data import make_language_modeling_dataset
    from repro.models import build_model

    cfg = get_config(args.arch)
    if args.preset == "tiny":
        cfg = cfg.reduced(vocab=512, n_layers=2 * cfg.group_size)
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(args.seed))
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"[train] {cfg.name} preset={args.preset} params={n_params:,}")

    ds = make_language_modeling_dataset(num_sequences=512,
                                        seq_len=args.seq_len,
                                        vocab=cfg.vocab, seed=args.seed)
    opt_state = bundle.optimizer.init(params)
    step_fn = jax.jit(bundle.train_step)
    rng = np.random.default_rng(args.seed)
    t0 = time.time()
    for step in range(args.steps):
        idx = rng.integers(0, ds.tokens.shape[0], size=args.batch)
        toks = ds.tokens[idx]
        batch = {"tokens": jnp.asarray(toks[:, :-1]),
                 "targets": jnp.asarray(toks[:, 1:])}
        params, opt_state, m = step_fn(params, opt_state, batch)
        if step % max(1, args.steps // 10) == 0 or step == args.steps - 1:
            print(f"  step {step:4d} loss {float(m['loss']):.4f} "
                  f"gnorm {float(m['grad_norm']):.3f} "
                  f"({(time.time()-t0):.1f}s)")
    print(f"[train] done in {time.time()-t0:.1f}s")


def run_async(args):
    from repro.core import LearningConstants
    from repro.data import (dirichlet_partition, make_synthetic_image_dataset,
                            train_test_split)
    from repro.fl import (AsyncFLConfig, AsyncFLTrainer, cnn_classifier,
                          make_strategies)
    from repro.fl.strategies import (PAPER_CLUSTERS_TABLE1,
                                     build_network_params)

    net = build_network_params(PAPER_CLUSTERS_TABLE1, scale=args.scale)
    n = net.n
    consts = LearningConstants(L=1.0, delta=1.0, sigma=1.0, M=2.0, G=5.0,
                               eps=1.0)
    strategies = make_strategies(net, consts, steps=args.opt_steps,
                                 which=(args.strategy,))
    p, m = strategies[args.strategy]
    print(f"[async] strategy={args.strategy} n={n} m={m} "
          f"p range [{p.min():.4f}, {p.max():.4f}]")

    full = make_synthetic_image_dataset(num_classes=args.classes,
                                        samples_per_class=args.per_class,
                                        seed=args.seed)
    train, test = train_test_split(full, 0.2, seed=args.seed)
    parts = dirichlet_partition(train.y, n, alpha=0.2, seed=args.seed)
    clients = [(train.x[i], train.y[i]) for i in parts]
    model = cnn_classifier(28, args.classes)
    trainer = AsyncFLTrainer(
        model, clients, net._replace(p=jnp.asarray(p)), m,
        config=AsyncFLConfig(eta=args.eta, batch_size=args.batch,
                             eval_every_time=args.horizon / 10,
                             distribution=args.distribution, seed=args.seed),
        test_data=(test.x, test.y))
    log = trainer.run(horizon_time=args.horizon)
    for t, a, l in zip(log.times, log.accuracies, log.losses):
        print(f"  t={t:8.1f}  acc={a:.3f}  loss={l:.4f}")
    print(f"[async] updates={log.updates[-1]} "
          f"throughput={log.throughput:.2f}/s energy={log.energy:.1f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="sync", choices=["sync", "async"])
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--preset", default="tiny", choices=["tiny", "full"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    # async mode
    ap.add_argument("--strategy", default="time_opt",
                    choices=["asyncsgd", "max_throughput", "round_opt",
                             "time_opt"])
    ap.add_argument("--scale", type=int, default=10,
                    help="divide Table-1 cluster counts by this")
    ap.add_argument("--horizon", type=float, default=150.0)
    ap.add_argument("--distribution", default="exponential",
                    choices=["exponential", "deterministic", "lognormal"])
    ap.add_argument("--eta", type=float, default=0.05)
    ap.add_argument("--classes", type=int, default=10)
    ap.add_argument("--per-class", type=int, default=100)
    ap.add_argument("--opt-steps", type=int, default=200)
    args = ap.parse_args()
    if args.mode == "sync":
        run_sync(args)
    else:
        run_async(args)


if __name__ == "__main__":
    main()
