"""ScenarioSuite — plan and dispatch batches of Scenarios in few compiles.

One entry point, three execution modes, all driven by the same spec::

    suite = ScenarioSuite.strategy_grid(base, ("asyncsgd", "time_opt"),
                                        seeds=range(4))
    closed  = suite.run(mode="analyze")                    # closed forms
    stats   = suite.run(mode="simulate", num_updates=2000) # event engine
    logs    = suite.run(mode="train", model=m, clients=c,
                        horizon_time=240.0)                # fused trainer

Planning: scenarios x seeds flatten into *lanes*; lanes are bucketed by
static structure (population size, timing law, CS buffer, energy
accounting, padded ``m_max``) and each bucket executes as ONE jitted,
vmapped program — a suite of S structurally-alike scenarios costs one
compile, not S (``SuiteResult.programs`` records the count; the
``scenario_suite`` smoke benchmark tracks it).  ``train`` mode delegates
lane bucketing to the PR-2 planner of ``repro.fl.engine`` (scan lengths
from an exact queueing-only pre-simulation).

This module also hosts the **strategy** and **objective** registrations
(the implementations live in ``repro.core``): the five paper strategies
resolve through ``STRATEGIES``, the closed-form objectives through
``OBJECTIVES`` — the registries that replaced the stringly-typed dispatch
previously scattered across ``make_strategies`` and the ``make_*_objective``
factories.
"""
from __future__ import annotations
# contract: padded-n — reductions here are on the bitwise padding contract

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.buzen import (NetworkParams, class_log_normalizing_constants,
                          log_normalizing_constants, pad_classes,
                          pad_network)
from ..core.events import unpad_stats
from ..core.complexity import LearningConstants, wallclock_time
from ..core.energy import (PowerProfile, energy_optimal_routing,
                           minimal_energy)
from ..core.batched import (energy_complexity_classes,
                            energy_complexity_padded,
                            expected_relative_delay_classes,
                            expected_relative_delay_padded,
                            make_energy_objective_padded,
                            make_joint_objective_padded,
                            make_round_objective_padded,
                            make_throughput_objective_padded,
                            make_time_objective_padded,
                            round_complexity_classes,
                            round_complexity_padded, throughput_padded)
from ..core.optimize import (joint_optimal, make_energy_objective,
                             make_joint_objective, make_round_objective,
                             make_throughput_objective, make_time_objective,
                             optimize_routing, time_optimal)
from .registry import OBJECTIVES, STRATEGIES, objective, strategy
from .spec import EXPLICIT, Scenario

MODES = ("analyze", "simulate", "train")


# ---------------------------------------------------------------------------
# objective registry — named closed-form objectives (static + padded forms)
# ---------------------------------------------------------------------------

class ObjectiveDef(NamedTuple):
    """One optimizable/reportable closed form.

    ``static(params, consts, power, refs)`` returns the classic
    ``obj(p, m)`` callable; ``padded(params, consts, power, refs, m_max)``
    the traced-``m`` ``obj(p, m, logZ[, rho])`` of ``repro.core.batched``.
    ``refs`` carries the joint objective's normalizers
    (``tau_star``/``e_star``); ``uses_ctx`` marks objectives whose padded
    form takes the per-row sweep context (the Pareto weight ``rho``).
    """

    static: Callable
    padded: Callable
    needs_power: bool = False
    needs_refs: bool = False
    uses_ctx: bool = False


@objective("time")
def _obj_time() -> ObjectiveDef:
    return ObjectiveDef(
        static=lambda prm, c, pw, refs: make_time_objective(prm, c),
        padded=lambda prm, c, pw, refs, mx:
            make_time_objective_padded(prm, c, mx))


@objective("round")
def _obj_round() -> ObjectiveDef:
    return ObjectiveDef(
        static=lambda prm, c, pw, refs: make_round_objective(prm, c),
        padded=lambda prm, c, pw, refs, mx:
            make_round_objective_padded(prm, c, mx))


@objective("throughput")
def _obj_throughput() -> ObjectiveDef:
    return ObjectiveDef(
        static=lambda prm, c, pw, refs: make_throughput_objective(prm),
        padded=lambda prm, c, pw, refs, mx:
            make_throughput_objective_padded(prm, mx))


@objective("energy")
def _obj_energy() -> ObjectiveDef:
    return ObjectiveDef(
        static=lambda prm, c, pw, refs: make_energy_objective(prm, c, pw),
        padded=lambda prm, c, pw, refs, mx:
            make_energy_objective_padded(prm, c, pw, mx),
        needs_power=True)


@objective("joint")
def _obj_joint() -> ObjectiveDef:
    return ObjectiveDef(
        static=lambda prm, c, pw, refs: make_joint_objective(
            prm, c, pw, refs["rho"], refs["tau_star"], refs["e_star"]),
        padded=lambda prm, c, pw, refs, mx: make_joint_objective_padded(
            prm, c, pw, refs["tau_star"], refs["e_star"], mx),
        needs_power=True, needs_refs=True, uses_ctx=True)


def get_objective(name: str) -> ObjectiveDef:
    return OBJECTIVES.get(name)()


# ---------------------------------------------------------------------------
# strategy registry — the paper's scheduling configurations (Section 5.3/6.5)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ResolveContext:
    """Inputs a strategy resolver sees (one scenario's worth)."""

    params: NetworkParams             # base network (uniform/base routing)
    consts: LearningConstants
    power: Optional[PowerProfile]
    rho: float                        # Pareto weight (objective spec)
    m: Optional[int]                  # forced concurrency (None = strategy's)
    m_max: int                        # concurrency search bound
    steps: int                        # Adam steps
    search: str                       # "batched" | "pruned" | "sequential"
    resolved: dict                    # earlier (p, m) results in this batch
    cache: dict                       # shared memo (e.g. tau_star / e_star)


def _as_pm(p, m) -> tuple[np.ndarray, int]:
    return np.asarray(p, dtype=np.float64), int(m)


@strategy("asyncsgd")
def _strat_asyncsgd(ctx: ResolveContext):
    """Uniform routing, m = n (Alg. 2 of [29])."""
    n = ctx.params.n
    return _as_pm(np.full(n, 1.0 / n), ctx.m if ctx.m is not None else n)


@strategy("max_throughput")
def _strat_max_throughput(ctx: ResolveContext):
    """p*_lambda at m = n."""
    m = ctx.m if ctx.m is not None else ctx.params.n
    obj = get_objective("throughput").static(ctx.params, ctx.consts,
                                             ctx.power, None)
    res = optimize_routing(obj, ctx.params.n, m, steps=ctx.steps)
    return _as_pm(res.p, m)


@strategy("round_opt")
def _strat_round_opt(ctx: ResolveContext):
    """p*_K at m = n ([31, 2])."""
    m = ctx.m if ctx.m is not None else ctx.params.n
    obj = get_objective("round").static(ctx.params, ctx.consts, ctx.power,
                                        None)
    res = optimize_routing(obj, ctx.params.n, m, steps=ctx.steps)
    return _as_pm(res.p, m)


@strategy("time_opt")
def _strat_time_opt(ctx: ResolveContext):
    """(p*_tau, m*_tau) — the paper's proposed strategy."""
    if ctx.m is not None:
        obj = get_objective("time").static(ctx.params, ctx.consts, ctx.power,
                                           None)
        res = optimize_routing(obj, ctx.params.n, ctx.m, steps=ctx.steps)
        return _as_pm(res.p, ctx.m)
    res = time_optimal(ctx.params, ctx.consts, m_max=ctx.m_max,
                       steps=ctx.steps, search=ctx.search)
    ctx.cache["tau_star"] = float(res.value)
    return _as_pm(res.p, res.m)


@strategy("energy_opt")
def _strat_energy_opt(ctx: ResolveContext):
    """Closed-form (p*_E, m = 1) — Eq. 16."""
    if ctx.power is None:
        raise ValueError("strategy 'energy_opt' needs a power profile "
                         "(EnergySpec)")
    return _as_pm(energy_optimal_routing(ctx.params, ctx.power),
                  ctx.m if ctx.m is not None else 1)


@strategy("joint")
def _strat_joint(ctx: ResolveContext):
    """(p*_rho, m*_rho) — the Eq. 18 scalarization at the scenario's rho."""
    if ctx.power is None:
        raise ValueError("strategy 'joint' needs a power profile "
                         "(EnergySpec)")
    tau_star = ctx.cache.get("tau_star")
    if tau_star is None:
        if "time_opt" in ctx.resolved:
            p_tau, m_tau = ctx.resolved["time_opt"]
            tau_star = float(wallclock_time(
                ctx.params._replace(p=jnp.asarray(p_tau)), m_tau, ctx.consts))
        else:
            tau_star = time_optimal(ctx.params, ctx.consts, m_max=ctx.m_max,
                                    steps=ctx.steps,
                                    search=ctx.search).value
        ctx.cache["tau_star"] = tau_star
    e_star = ctx.cache.get("e_star")
    if e_star is None:
        e_star = ctx.cache["e_star"] = float(
            minimal_energy(ctx.params, ctx.consts, ctx.power))
    res = joint_optimal(ctx.params, ctx.consts, ctx.power, ctx.rho, tau_star,
                        e_star, m_max=ctx.m_max, steps=ctx.steps,
                        search=ctx.search)
    return _as_pm(res.p, res.m)


def default_m_max(n: int) -> int:
    """The historical ``make_strategies`` search bound."""
    return n + max(8, n // 4)


def _resolve_class_strategy(scenario: Scenario, cache: dict
                            ) -> tuple[np.ndarray, int]:
    """Class-space strategy resolution — O(#classes), never expands.

    Returns a PER-CLASS routing vector ``p`` of shape ``[C]`` (one member's
    probability for each class; the class mass is ``count_c * p_c``).
    Supported strategies: ``"asyncsgd"`` (uniform per-member routing,
    ``m = n_total`` unless forced) and ``"time_opt"`` (the class-space
    concurrency sweep of ``repro.core.optimize.time_optimal_classes``;
    requires an explicit ``StrategySpec.m_max`` — the per-client default
    ``n + max(8, n//4)`` would be absurd at ``n = 10^6``).  Other
    registered strategies raise: resolve them on the expanded per-client
    network (``aggregate=False``) when the population is small enough.
    """
    from ..core.optimize import time_optimal_classes

    spec = scenario.strategy
    classes = scenario.class_params()
    n_total = int(scenario.n)
    C = scenario.network.classes.C
    if spec.name == "asyncsgd":
        m = spec.m if spec.m is not None else n_total
        return _as_pm(np.full(C, 1.0 / n_total), m)
    if spec.name == "time_opt":
        if spec.m_max is None:
            raise ValueError(
                "class-network 'time_opt' needs an explicit "
                "StrategySpec.m_max: the per-client default scales with the "
                f"population (n_total = {n_total} here)")
        if spec.m is not None and spec.m > spec.m_max:
            raise ValueError(f"forced m={spec.m} exceeds m_max={spec.m_max}")
        from ..core.batched import make_time_objective_classes
        from ..core.optimize import batched_concurrency_sweep

        if spec.m is not None:
            res = batched_concurrency_sweep(
                make_time_objective_classes(classes, scenario.consts,
                                            spec.m_max),
                classes, m_grid=[spec.m], m_max=spec.m_max,
                steps=spec.steps).best
        else:
            res = time_optimal_classes(classes, scenario.consts, spec.m_max,
                                       search=spec.search, steps=spec.steps)
        cache.setdefault("tau_star", float(res.value))
        return _as_pm(res.p, res.m)
    raise ValueError(
        f"strategy {scenario.strategy.name!r} has no class-space resolver; "
        "class networks support 'explicit', 'asyncsgd' and 'time_opt' "
        "(expand with NetworkSpec.from_clusters(..., aggregate=False) to "
        "use the per-client resolvers)")


def resolve_strategy(scenario: Scenario, *, resolved: Optional[dict] = None,
                     cache: Optional[dict] = None
                     ) -> tuple[np.ndarray, int]:
    """One scenario's ``(p, m)``: explicit spec or registry resolver.

    Class-aggregated networks dispatch to the O(#classes) resolvers BEFORE
    any per-client array exists — ``scenario.params()`` would expand the
    population, which is exactly what the class axis avoids.
    """
    spec = scenario.strategy
    if spec.name == EXPLICIT:
        return _as_pm(spec.p, spec.m)
    if scenario.is_class_network:
        return _resolve_class_strategy(scenario,
                                       {} if cache is None else cache)
    n = scenario.n
    ctx = ResolveContext(
        params=scenario.params(), consts=scenario.consts,
        power=scenario.power(), rho=scenario.objective.rho, m=spec.m,
        m_max=spec.m_max if spec.m_max is not None else default_m_max(n),
        steps=spec.steps, search=spec.search,
        resolved={} if resolved is None else resolved,
        cache={} if cache is None else cache)
    return STRATEGIES.get(spec.name)(ctx)


# ---------------------------------------------------------------------------
# the suite
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SuiteResult:
    """Result of one :meth:`ScenarioSuite.run` call.

    ``entries[name]`` is mode-dependent: a closed-form dict (``analyze``),
    a per-seed list of ``EventStats`` (``simulate``), or a per-seed list of
    ``TrainLog`` (``train``).  ``programs`` counts the distinct compiled
    programs (buckets) the call dispatched — the bucketing win is
    ``programs < len(entries)`` for structurally-alike scenarios.
    ``cache_hits`` counts entries served from the suite-level result cache
    (keyed by ``Scenario.hash()`` x seeds x mode x run settings): re-running
    an unchanged scenario costs nothing.

    Scenarios carrying a ``TraceSpec`` (``SimSpec.trace``) additionally
    fill ``traces[name]`` — per-seed decoded telemetry rings
    (``repro.obs.rings.decode`` dicts for ``simulate``, update-ring dicts
    for ``train``) — and, for ``simulate``, ``drift[name]``: per-seed
    ``repro.obs.drift.drift_report`` comparisons of the ring empirics
    against the closed forms.  Both stay ``None`` when nothing traced.
    """

    mode: str
    entries: dict
    seeds: tuple
    lanes: int
    programs: int
    strategies: dict  # name -> (p, m) resolved routing/concurrency
    cache_hits: int = 0
    metrics: Optional[dict] = None  # Metrics.snapshot() of the owning suite
    traces: Optional[dict] = None   # name -> per-seed decoded rings
    drift: Optional[dict] = None    # name -> per-seed drift reports


@dataclasses.dataclass
class SuiteCaches:
    """The content-keyed caches a :class:`ScenarioSuite` runs on, as a
    shareable bundle: pass one ``SuiteCaches`` to many suites (the
    ``repro.serve`` dispatcher builds a fresh suite per micro-batch) and
    they share resident jitted programs, built trainers, per-entry
    results and DataSpec-built datasets.  Name-keyed state (resolved
    strategies) stays per-suite — names are caller-chosen and collide
    across requests."""

    jit: dict = dataclasses.field(default_factory=dict)
    trainers: dict = dataclasses.field(default_factory=dict)
    results: dict = dataclasses.field(default_factory=dict)
    data: dict = dataclasses.field(default_factory=dict)


class ScenarioSuite:
    """A keyed collection of Scenarios sharing a seed set."""

    def __init__(self, scenarios, seeds=(0,), *, caches=None, metrics=None):
        from ..obs.metrics import Metrics  # standalone helper module

        if isinstance(scenarios, Scenario):
            scenarios = [scenarios]
        if not isinstance(scenarios, dict):
            scenarios = {
                (s.name or f"scenario{i}"): s
                for i, s in enumerate(scenarios)}
        if not scenarios:
            raise ValueError("need at least one scenario")
        for k, s in scenarios.items():
            if not isinstance(s, Scenario):
                raise TypeError(f"suite entry {k!r} is not a Scenario: {s!r}")
        self.scenarios: dict[str, Scenario] = dict(scenarios)
        self.seeds = tuple(int(s) for s in seeds)
        self.caches = caches if caches is not None else SuiteCaches()
        self.metrics = metrics if metrics is not None else Metrics()
        self._strategies: dict[str, tuple[np.ndarray, int]] = {}
        self._jit_cache = self.caches.jit
        self._trainers = self.caches.trainers
        self._result_cache = self.caches.results  # Scenario.hash keys
        self._data_cache = self.caches.data  # DataSpec-built datasets

    @classmethod
    def strategy_grid(cls, base: Scenario, strategies, seeds=(0,),
                      **strategy_kw) -> "ScenarioSuite":
        """One suite entry per strategy name, derived from ``base``."""
        return cls({name: base.with_strategy(name, **strategy_kw)
                    for name in strategies}, seeds=seeds)

    def __len__(self) -> int:
        return len(self.scenarios)

    def to_dict(self) -> dict:
        return {"seeds": list(self.seeds),
                "scenarios": {k: s.to_dict()
                              for k, s in self.scenarios.items()}}

    @classmethod
    def from_dict(cls, d: dict) -> "ScenarioSuite":
        return cls({k: Scenario.from_dict(v)
                    for k, v in d["scenarios"].items()},
                   seeds=tuple(d.get("seeds", (0,))))

    # -- strategy resolution (cached) ---------------------------------------

    def resolve(self) -> dict[str, tuple[np.ndarray, int]]:
        """Resolved ``{name: (p, m)}`` for every scenario (cached; shared
        normalizers like tau*/E* are computed once per network).

        The sharing key covers everything the cached values depend on —
        network, constants, energy spec AND the strategy search settings
        (``m_max``/``steps``/``search``) — so a suite sweeping power
        profiles or optimizer budgets never reuses a stale tau*/E*.
        """
        caches: dict = {}
        for name, scn in self.scenarios.items():
            if name in self._strategies:
                continue
            net_key = (str(scn.network.to_dict()),
                       str(scn.learning.to_dict()),
                       str(None if scn.energy is None
                           else scn.energy.to_dict()),
                       scn.strategy.m_max, scn.strategy.steps,
                       scn.strategy.search)
            shared = caches.setdefault(net_key, {"cache": {}, "resolved": {}})
            pm = resolve_strategy(scn, resolved=shared["resolved"],
                                  cache=shared["cache"])
            shared["resolved"][scn.strategy.name] = pm
            self._strategies[name] = pm
        return {name: self._strategies[name] for name in self.scenarios}

    # -- dispatch ------------------------------------------------------------

    def run(self, mode: str = "analyze", **kw) -> SuiteResult:
        runners = {"analyze": self._run_analyze,
                   "simulate": self._run_simulate,
                   "train": self._run_train}
        if mode not in runners:
            raise ValueError(
                f"unknown mode: {mode!r}; expected one of {MODES}")
        with self.metrics.timed("suite.run", mode=mode):
            res = runners[mode](**kw)
        self.metrics.inc("suite.requests", by=len(self.scenarios), mode=mode)
        self.metrics.inc("suite.cache_hits", by=res.cache_hits, mode=mode)
        self.metrics.inc("suite.programs", by=res.programs, mode=mode)
        self.metrics.inc("suite.lanes", by=res.lanes, mode=mode)
        res.metrics = self.metrics.snapshot()
        return res

    # -- analyze: closed forms, one jit per structure bucket -----------------

    def _run_analyze(self) -> SuiteResult:
        """Closed forms for every scenario, bucketed by static structure.

        Populations are padded to the suite-wide ``n_max`` under the
        traced-``n`` convention (``repro.core.buzen.pad_network``), so a
        mixed-population suite plans into buckets keyed only by
        ``(CS buffer, power structure)`` — one compiled program where the
        pre-padding planner compiled one per distinct ``n`` — and the
        padded rows reproduce the unpadded per-scenario closed forms
        bitwise (``tests/test_padded_n.py``).
        """
        strategies = self.resolve()
        names = list(self.scenarios)
        # class-aggregated scenarios never inflate the per-client pad: the
        # suite-wide n_max spans plain scenarios only, class lanes pad on
        # the CLASS axis (c_max) instead
        n_max = max((s.n for s in self.scenarios.values()
                     if not s.is_class_network), default=0)
        c_max = max((s.network.classes.C for s in self.scenarios.values()
                     if s.is_class_network), default=0)
        entries: dict = {}
        cache_hits = 0
        buckets: dict = {}
        for name in names:
            scn = self.scenarios[name]
            ckey = ("analyze", scn.hash())
            hit = self._result_cache.get(ckey)
            if hit is not None:
                entries[name] = hit
                cache_hits += 1
                continue
            key = (scn.network.mu_cs is not None, _power_sig(scn),
                   scn.is_class_network)
            buckets.setdefault(key, []).append(name)

        programs = 0
        for (has_cs, power_sig, is_classes), members in buckets.items():
            has_power = power_sig is not None
            m_max = max(strategies[name][1] for name in members)
            axis_max = c_max if is_classes else n_max
            if is_classes:
                prm = _stack_params(
                    [pad_classes(
                        self.scenarios[n_].class_params(strategies[n_][0]),
                        c_max) for n_ in members])
            else:
                prm = _stack_params(
                    [pad_network(
                        self.scenarios[n_].params(strategies[n_][0]),
                        n_max) for n_ in members])
            consts = _stack_consts([self.scenarios[n_].consts
                                    for n_ in members])
            power = (_stack_power([_pad_power(self.scenarios[n_].power(),
                                              axis_max) for n_ in members])
                     if has_power else None)
            m_vec = jnp.asarray([strategies[n_][1] for n_ in members],
                                jnp.int64)
            rho = jnp.asarray([self.scenarios[n_].objective.rho
                               for n_ in members])
            sig = ("analyze", is_classes, axis_max, has_cs, power_sig, m_max)
            fn = self._jit_cache.get(sig)
            if fn is None:
                build = (_build_analyze_classes if is_classes
                         else _build_analyze)
                fn = self._jit_cache[sig] = build(m_max, has_power)
                programs += 1
            with self.metrics.timed("suite.dispatch", mode="analyze"):
                out = jax.block_until_ready(fn(prm, m_vec, consts, power,
                                               rho))
            self.metrics.observe("suite.lanes_per_dispatch", len(members),
                                 mode="analyze")
            for i, name in enumerate(members):
                # class rows report per-CLASS delays (one member each);
                # truncate to the scenario's own axis either way
                n_i = (self.scenarios[name].network.classes.C if is_classes
                       else self.scenarios[name].n)
                row = {k: np.asarray(v[i]) for k, v in out.items()}
                row["delays"] = row["delays"][:n_i]
                p, m = strategies[name]
                obj_name = self.scenarios[name].objective.name
                # None (not a mislabeled tau) for objectives analyze cannot
                # evaluate: registered extensions without an analyze column
                val_key = _ANALYZE_KEY.get(obj_name)
                entries[name] = {
                    "p": p, "m": m, "eta": self.scenarios[name].eta(),
                    "throughput": float(row["throughput"]),
                    "K_eps": float(row["K_eps"]),
                    "tau": float(row["tau"]),
                    "delays": row["delays"],  # E0[D_i] (Thm 2)
                    "energy": (float(row["energy"]) if has_power else None),
                    "objective": obj_name,
                    "value": (float(row[val_key])
                              if val_key is not None and val_key in row
                              else None),
                }
                self._result_cache[
                    ("analyze", self.scenarios[name].hash())] = entries[name]
        return SuiteResult(mode="analyze", entries=entries, seeds=self.seeds,
                           lanes=len(names), programs=programs,
                           strategies=strategies, cache_hits=cache_hits)

    # -- simulate: device event engine, one jit per structure bucket ---------

    def _run_simulate(self, num_updates: int, *, warmup: int = 0,
                      m_max: Optional[int] = None,
                      backend: Optional[str] = None) -> SuiteResult:
        """Device event engine through the ``repro.sim`` backend dispatch.

        Backend precedence: the ``backend=`` kwarg, else each scenario's
        ``SimSpec``, else the process-wide ``REPRO_SIM_BACKEND``; lanes are
        bucketed by structure AND backend, so pinned scenarios coexist.
        ``"reference"`` and ``"batched"`` are bitwise identical on alike
        lanes (``tests/test_sim_backends.py``).

        Mixed populations share one program: lanes are padded to the
        suite-wide ``n_max`` (clients ``>= n`` carry zero routing mass and
        never receive tasks), and because trajectories are bitwise
        invariant to that padding (``events._route_client``), each lane's
        statistics — unpadded before they are returned/cached — equal the
        per-scenario unpadded run at the same table size exactly.
        """
        from ..sim.backend import resolve_backend
        from ..sim.batched_events import build_class_lanes_fn, build_lanes_fn

        strategies = self.resolve()
        names = list(self.scenarios)
        n_max = max((s.n for s in self.scenarios.values()
                     if not s.is_class_network), default=0)
        c_max = max((s.network.classes.C for s in self.scenarios.values()
                     if s.is_class_network), default=0)
        entries: dict = {}
        traces: dict = {}
        drift: dict = {}
        cache_hits = 0
        buckets: dict = {}
        for name in names:
            scn = self.scenarios[name]
            bk = resolve_backend(backend if backend is not None
                                 else scn.sim_backend)
            interp = None if scn.sim is None else scn.sim.interpret
            tr = 0 if scn.trace is None else int(scn.trace.events)
            ck = 1 if scn.sim is None else int(scn.sim.chunk)
            key = (scn.network.law, scn.network.mu_cs is not None,
                   _power_sig(scn), bk, interp, scn.is_class_network, tr, ck)
            buckets.setdefault(key, []).append(name)

        programs = 0
        S = len(self.seeds)
        for (law, has_cs, power_sig, bk, interp, is_classes, tr, ck), \
                members in buckets.items():
            has_power = power_sig is not None
            # the table size comes from ALL bucket members (trajectories
            # depend on it: init_state draws per-slot), so the *effective*
            # size — not the raw kwarg — keys the result cache: a hit is
            # bitwise identical to what this bucket would compute fresh,
            # regardless of which members happen to be cached already
            m_top = max(strategies[name][1] for name in members)
            mx = m_max or m_top
            if mx < m_top:
                # jit'd gathers clamp silently — a task table smaller than
                # a lane's m would return plausible-but-wrong statistics
                raise ValueError(
                    f"m_max={mx} is smaller than the largest resolved "
                    f"concurrency m={m_top} in this suite")
            todo = []
            for name in members:
                ckey = ("simulate", self.scenarios[name].hash(), self.seeds,
                        int(num_updates), int(warmup), mx, bk, interp)
                hit = self._result_cache.get(ckey)
                if hit is not None:
                    entries[name] = hit
                    cache_hits += 1
                    if tr:  # cached alongside the stats, same ckey
                        thit = self._result_cache.get(("trace",) + ckey)
                        if thit is not None:
                            traces[name], drift[name] = thit
                else:
                    todo.append((name, ckey))
            if not todo:
                continue
            axis_max = c_max if is_classes else n_max
            if is_classes:
                lane_params = _stack_params(
                    [pad_classes(
                        self.scenarios[n_].class_params(strategies[n_][0]),
                        c_max)
                     for n_, _ in todo for _ in self.seeds])
            else:
                lane_params = _stack_params(
                    [pad_network(
                        self.scenarios[n_].params(strategies[n_][0]),
                        n_max)
                     for n_, _ in todo for _ in self.seeds])
            power = (_stack_power([_pad_power(self.scenarios[n_].power(),
                                              axis_max)
                                   for n_, _ in todo for _ in self.seeds])
                     if has_power else None)
            m_vec = jnp.asarray([strategies[n_][1]
                                 for n_, _ in todo for _ in self.seeds],
                                jnp.int32)
            keys = jnp.stack([jax.random.PRNGKey(s)
                              for _ in todo for s in self.seeds])
            sig = ("simulate", is_classes, axis_max, law, has_cs, power_sig,
                   mx, int(num_updates), int(warmup), bk, interp, tr, ck)
            fn = self._jit_cache.get(sig)
            if fn is None:
                if is_classes:
                    fn = self._jit_cache[sig] = build_class_lanes_fn(
                        bk, int(num_updates), int(warmup), law, mx,
                        has_power, trace_events=tr, chunk=ck)
                else:
                    fn = self._jit_cache[sig] = build_lanes_fn(
                        bk, int(num_updates), int(warmup), law, mx,
                        has_power, interpret=interp, trace_events=tr,
                        chunk=ck)
                programs += 1
            with self.metrics.timed("suite.dispatch", mode="simulate"):
                out = jax.block_until_ready(
                    fn(lane_params, m_vec, keys, power))
            stats, rings = out if tr else (out, None)
            self.metrics.observe("suite.lanes_per_dispatch", len(todo) * S,
                                 mode="simulate")
            for i, (name, ckey) in enumerate(todo):
                # class lanes: statistics are per-CLASS — unpad on the
                # class axis (expand_class_stats recovers per-member views)
                n_i = (self.scenarios[name].network.classes.C if is_classes
                       else self.scenarios[name].n)
                entries[name] = [
                    unpad_stats(jax.tree_util.tree_map(
                        lambda a: a[i * S + j], stats), n_i)
                    for j in range(S)]
                self._result_cache[ckey] = entries[name]
                if tr:
                    from ..obs.drift import drift_report, predict
                    from ..obs.rings import decode

                    scn = self.scenarios[name]
                    m_i = strategies[name][1]
                    # closed forms are seed- and run-invariant: one predict
                    # per (scenario, m), cached across suite runs
                    pkey = ("drift_pred", scn.hash(), int(m_i))
                    preds = self._result_cache.get(pkey)
                    if preds is None:
                        # Scenario.params() expands a class network, so the
                        # closed forms always see the member population
                        preds = predict(scn.params(strategies[name][0]), m_i)
                        if is_classes:
                            # class rings index stations per CLASS: fold the
                            # per-member delay predictions onto the class
                            # axis (E0[D_c] = sum of the members' shares)
                            cnt = np.asarray(
                                scn.class_params(strategies[name][0]).count)
                            lbl = np.repeat(np.arange(len(cnt)), cnt)
                            d = np.bincount(
                                lbl,
                                weights=np.asarray(preds["delays"],
                                                   dtype=np.float64),
                                minlength=len(cnt))
                            preds = dict(preds,
                                         delays=[float(v) for v in d])
                        self._result_cache[pkey] = preds
                    traces[name] = [
                        decode(jax.tree_util.tree_map(
                            lambda a: a[i * S + j], rings))
                        for j in range(S)]
                    drift[name] = [
                        drift_report(d, predictions=preds, law=law,
                                     tolerance=scn.trace.tolerance)
                        for d in traces[name]]
                    self._result_cache[("trace",) + ckey] = (traces[name],
                                                             drift[name])
        return SuiteResult(mode="simulate", entries=entries, seeds=self.seeds,
                           lanes=len(names) * S, programs=programs,
                           strategies=strategies, cache_hits=cache_hits,
                           traces=traces or None, drift=drift or None)

    # -- train: fused device trainer (PR-2 lane planner) ---------------------

    def _client_data(self, scn: Scenario, name: str):
        """``(clients, test_data)`` for a scenario's ``DataSpec`` (memoized
        by spec content x population, so alike scenarios share the arrays
        and the trainer memo keeps hitting)."""
        if scn.data is None:
            raise ValueError(
                f"mode='train' for scenario {name!r} needs either an "
                "explicit clients= argument or a DataSpec on the scenario")
        key = (str(scn.data.to_dict()), scn.n)
        hit = self._data_cache.get(key)
        if hit is None:
            hit = self._data_cache[key] = scn.data.build(scn.n)
        return hit

    def _run_train(self, *, model, clients=None, horizon_time: float,
                   test_data=None, max_updates: Optional[int] = None,
                   loss_fn=None, **config_overrides) -> SuiteResult:
        from ..fl.engine import DeviceTrainer  # local: fl imports scenario
        from ..fl.models import cross_entropy_loss

        strategies = self.resolve()
        names = list(self.scenarios)
        run_sig = (float(horizon_time), max_updates,
                   tuple(sorted(config_overrides.items())))
        entries: dict = {}
        traces: dict = {}
        cache_hits = 0
        buckets: dict = {}
        for name in names:
            scn = self.scenarios[name]
            ckey = ("train", scn.hash(), self.seeds, run_sig)
            hit = self._result_cache.get(ckey)
            # identity-checked: a hit requires the SAME model/clients/
            # test_data objects the cached logs were trained with
            if hit is not None and hit[0] is model and hit[1] is clients \
                    and hit[2] is test_data and hit[3] is loss_fn:
                entries[name] = hit[4]
                if hit[5] is not None:
                    traces[name] = hit[5]
                cache_hits += 1
                continue
            if clients is None and not scn.is_class_network:
                # DataSpec-driven scenarios bucket by STRUCTURE (like
                # analyze/simulate): the network, client table and power
                # profile ride each lane as vmapped arguments, so
                # mixed-population train requests share one program.
                # fl_config draws only law/grad_clip from the spec (eta is
                # per-lane); the power profile needs only its structural
                # signature; the data spec pins the shared test set.
                key = ("nets", scn.network.law,
                       scn.network.mu_cs is not None, _power_sig(scn),
                       scn.learning.grad_clip,
                       str(None if scn.data is None else scn.data.to_dict()),
                       scn.sim_backend,
                       None if scn.sim is None else scn.sim.interpret,
                       0 if scn.trace is None else int(scn.trace.updates),
                       tuple(sorted(config_overrides.items())))
            else:
                key = ("exact", str(scn.network.to_dict()),
                       scn.learning.grad_clip,
                       str(None if scn.energy is None
                           else scn.energy.to_dict()),
                       str(None if scn.data is None else scn.data.to_dict()),
                       scn.sim_backend,
                       None if scn.sim is None else scn.sim.interpret,
                       0 if scn.trace is None else int(scn.trace.updates),
                       tuple(sorted(config_overrides.items())))
            buckets.setdefault(key, []).append((name, ckey))

        programs = 0
        for key, members in buckets.items():
            lane_mode = key[0] == "nets"
            # the template scenario sizes the trainer's static row count:
            # the largest population in a structural bucket, any member in
            # an exact one (all identical networks)
            ref_name = (max((nm for nm, _ in members),
                            key=lambda nm: self.scenarios[nm].n)
                        if lane_mode else members[0][0])
            scn0 = self.scenarios[ref_name]
            cfg = scn0.fl_config(**config_overrides)
            if clients is None:
                bucket_clients, built_test = self._client_data(
                    scn0, ref_name)
                bucket_test = test_data if test_data is not None \
                    else built_test
            else:
                bucket_clients, bucket_test = clients, test_data
            # identity-checked memo: the cached trainer holds strong refs
            # to everything it was built from, and a hit requires the SAME
            # objects (model, clients, test data, loss) — never a stale
            # trainer evaluating against a superseded test set
            cached = self._trainers.get(key)
            trainer = None
            if cached is not None and cached[0] is model \
                    and cached[1] is bucket_clients \
                    and cached[2] is bucket_test and cached[3] is loss_fn:
                trainer = cached[4]
            if trainer is None:
                template_net = (pad_network(scn0.params(), scn0.n)
                                if lane_mode else scn0.params())
                trainer = DeviceTrainer(
                    model, bucket_clients, template_net, cfg,
                    test_data=bucket_test,
                    power=None if lane_mode else scn0.power(),
                    loss_fn=loss_fn or cross_entropy_loss,
                    sim_backend=scn0.sim_backend,
                    sim_interpret=None if scn0.sim is None
                    else scn0.sim.interpret,
                    trace_updates=0 if scn0.trace is None
                    else scn0.trace.updates)
                self._trainers[key] = (model, bucket_clients, bucket_test,
                                       loss_fn, trainer)
            n_top = trainer.n
            ps, ms, etas, seeds = [], [], [], []
            nets, lane_clients, lane_powers = [], [], []
            for name, _ in members:
                scn = self.scenarios[name]
                p, m = strategies[name]
                if lane_mode:
                    p = np.concatenate(
                        [np.asarray(p, np.float64),
                         np.zeros(n_top - len(p))])
                    net_i = pad_network(scn.params(), n_top)
                    cl_i, _ = self._client_data(scn, name)
                    pw_i = scn.power()
                    if pw_i is not None:
                        pw_i = _pad_power(pw_i, n_top)
                for s in self.seeds:
                    ps.append(p)
                    ms.append(m)
                    etas.append(scn.eta())
                    seeds.append(s)
                    if lane_mode:
                        nets.append(net_i)
                        lane_clients.append(cl_i)
                        lane_powers.append(pw_i)
            lane_kw = {}
            if lane_mode:
                lane_kw = dict(
                    nets=nets, lane_clients=lane_clients,
                    lane_powers=(None if lane_powers[0] is None
                                 else lane_powers))
            before = len(trainer._jit_cache)
            with self.metrics.timed("suite.dispatch", mode="train"):
                logs, _ = trainer.run_lanes(ps, ms, etas, seeds,
                                            float(horizon_time),
                                            max_updates=max_updates,
                                            **lane_kw)
            self.metrics.observe("suite.lanes_per_dispatch", len(ps),
                                 mode="train")
            programs += max(len(trainer._jit_cache) - before, 0)
            S = len(self.seeds)
            lane_rings = trainer.last_update_rings
            if lane_rings is not None:
                from ..obs.rings import decode
            for i, (name, ckey) in enumerate(members):
                entries[name] = logs[i * S:(i + 1) * S]
                if lane_rings is not None:
                    traces[name] = [decode(lane_rings[i * S + j])
                                    for j in range(S)]
                self._result_cache[ckey] = (model, clients, test_data,
                                            loss_fn, entries[name],
                                            traces.get(name))
        return SuiteResult(mode="train", entries=entries, seeds=self.seeds,
                           lanes=len(names) * len(self.seeds),
                           programs=programs, strategies=strategies,
                           cache_hits=cache_hits, traces=traces or None)


_ANALYZE_KEY = {"time": "tau", "round": "K_eps", "throughput": "throughput",
                "energy": "energy", "joint": "joint"}


# ---------------------------------------------------------------------------
# lane stacking / bucket program builders
# ---------------------------------------------------------------------------

def _power_sig(scn) -> Optional[bool]:
    """Structural signature of a scenario's power profile for bucketing:
    ``None`` (no energy spec) or whether the CS power term is present —
    both change the stacked-pytree structure and the compiled program."""
    if scn.energy is None:
        return None
    return scn.energy.P_cs is not None


def _stack_params(params_list) -> NetworkParams:
    """Stack per-lane NetworkParams leaf-wise ([L, n] / [L] arrays)."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *params_list)


def _pad_power(power: PowerProfile, n_max: int) -> PowerProfile:
    """Pad a power profile to ``n_max`` client rows with zero powers —
    padded clients are never busy, so they contribute exactly 0 energy."""
    def pad(x):
        x = jnp.asarray(x)
        return jnp.concatenate(
            [x, jnp.zeros((n_max - x.shape[0],), dtype=x.dtype)])

    return power._replace(P_c=pad(power.P_c), P_u=pad(power.P_u),
                          P_d=pad(power.P_d))


def _stack_consts(consts_list) -> LearningConstants:
    return LearningConstants(*[jnp.asarray([float(getattr(c, f))
                                            for c in consts_list])
                               for f in LearningConstants._fields])


def _stack_power(power_list) -> PowerProfile:
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *power_list)


def _build_analyze(m_max: int, has_power: bool):
    """One jitted, vmapped closed-form evaluation over scenario lanes."""

    def one(prm, m, consts, power, rho):
        logZ = log_normalizing_constants(prm, m_max)
        thr = throughput_padded(logZ, m)
        delays = expected_relative_delay_padded(prm, m, logZ, m_max)
        k_eps = round_complexity_padded(prm, m, consts, logZ, m_max)
        tau = k_eps / thr
        out = {"throughput": thr, "K_eps": k_eps, "tau": tau,
               "delays": delays}
        if has_power:
            en = energy_complexity_padded(prm, m, consts, power, logZ, m_max)
            out["energy"] = en
            out["joint"] = rho * en + (1.0 - rho) * tau
        return out

    if has_power:
        return jax.jit(jax.vmap(one))

    # named (not a lambda) so repro.analysis.tracecheck program budgets can
    # identify the analyze bucket program in the compile log
    def analyze_lanes(prm, m, consts, _pw, rho):
        return one(prm, m, consts, None, rho)

    return jax.jit(jax.vmap(analyze_lanes, in_axes=(0, 0, 0, None, 0)))


def _build_analyze_classes(m_max: int, has_power: bool):
    """The class-space analogue of :func:`_build_analyze`.

    Each lane is a :class:`~repro.core.buzen.ClassParams` network: the
    class Buzen DP is O(C m^2) and every population sum is class-weighted,
    so the analyze pass never materializes a per-client array — n = 10^6
    scenarios cost the same as n = 10 at equal class counts.  ``delays``
    is per-CLASS (one member of each class).
    """

    def one(cls_, m, consts, power, rho):
        logZ = class_log_normalizing_constants(cls_, m_max)
        thr = throughput_padded(logZ, m)
        delays = expected_relative_delay_classes(cls_, m, logZ, m_max)
        k_eps = round_complexity_classes(cls_, m, consts, logZ, m_max)
        tau = k_eps / thr
        out = {"throughput": thr, "K_eps": k_eps, "tau": tau,
               "delays": delays}
        if has_power:
            en = energy_complexity_classes(cls_, m, consts, power, logZ,
                                           m_max)
            out["energy"] = en
            out["joint"] = rho * en + (1.0 - rho) * tau
        return out

    if has_power:
        return jax.jit(jax.vmap(one))

    # named (not a lambda) for the tracecheck program budgets
    def analyze_class_lanes(prm, m, consts, _pw, rho):
        return one(prm, m, consts, None, rho)

    return jax.jit(jax.vmap(analyze_class_lanes,
                            in_axes=(0, 0, 0, None, 0)))


