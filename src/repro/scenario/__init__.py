"""repro.scenario — the unified declarative Scenario API.

ONE pytree spec (:class:`Scenario`) describes an experiment — network,
learning constants, energy model, strategy, objective — and drives all
three execution paths through :class:`ScenarioSuite`:

  * ``run(mode="analyze")``  — the closed forms (Thm 2/3, Prop 4/5);
  * ``run(mode="simulate")`` — the device-resident event engine;
  * ``run(mode="train")``    — the fused AsyncSGD trainer.

The 5-line EMNIST strategy comparison (replacing the hand-threaded
``NetworkParams`` + ``make_strategies`` + config wiring)::

    net = NetworkSpec.from_clusters(PAPER_CLUSTERS_TABLE1, scale=10)
    base = Scenario(network=net, learning=LearningSpec(grad_clip=5.0))
    suite = ScenarioSuite.strategy_grid(
        base, ("asyncsgd", "max_throughput", "round_opt", "time_opt"),
        seeds=range(3))
    res = suite.run(mode="train", model=cnn_classifier(28, 10),
                    clients=clients, test_data=test, horizon_time=240.0,
                    batch_size=32, eval_every_time=6.0)

Extension points are decorator registries (``repro.scenario.registry``):
``@timing_law`` (service distributions — see the built-in
``hyperexponential`` for the host-sampler + device-draw pattern),
``@strategy``, ``@objective`` and ``@partition``.

Import structure: this ``__init__`` eagerly exposes only the
dependency-free ``registry`` and ``laws`` modules (so the low-level engines
in ``repro.core`` can import them without cycles); ``spec``/``suite`` —
which import ``repro.core`` — load lazily on first attribute access.
"""
from __future__ import annotations

from . import laws  # registers the built-in timing laws  # noqa: F401
from .laws import TimingLaw, get_law, law_names
from .registry import (OBJECTIVES, PARTITIONS, STRATEGIES, TIMING_LAWS,
                       Registry, objective, partition, strategy, timing_law)

_SPEC = ("Scenario", "NetworkSpec", "ClassSpec", "LearningSpec", "EnergySpec",
         "StrategySpec", "ObjectiveSpec", "SimSpec", "TraceSpec", "DataSpec",
         "ClusterSpec",
         "PAPER_CLUSTERS_TABLE1", "PAPER_CLUSTERS_TABLE6", "expand_clusters",
         "DEFAULT_ETA", "MAX_THROUGHPUT_ETA", "EXPLICIT", "stack")
_SUITE = ("ScenarioSuite", "SuiteResult", "ObjectiveDef", "ResolveContext",
          "resolve_strategy", "get_objective", "default_m_max")

__all__ = [
    "Registry", "TIMING_LAWS", "STRATEGIES", "OBJECTIVES", "PARTITIONS",
    "timing_law", "strategy", "objective", "partition",
    "TimingLaw", "get_law", "law_names",
    *_SPEC, *_SUITE,
]


def __getattr__(name: str):
    if name in _SPEC:
        from . import spec

        return getattr(spec, name)
    if name in _SUITE:
        from . import suite

        return getattr(suite, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(__all__)
