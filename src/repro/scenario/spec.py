"""The declarative Scenario spec — ONE pytree drives the whole pipeline.

The paper's object of study is a single thing: a closed queueing network
with timing laws, a routing/concurrency strategy, and an objective.
:class:`Scenario` says exactly that, declaratively::

    net = NetworkSpec.from_clusters(PAPER_CLUSTERS_TABLE1, scale=10)
    scn = Scenario(network=net, learning=LearningSpec(grad_clip=5.0),
                   strategy=StrategySpec("time_opt"))

and every execution mode consumes the same spec (see
``repro.scenario.suite``): ``analyze`` evaluates the closed forms,
``simulate`` runs the device event engine, ``train`` runs the fused
AsyncSGD trainer.

Static/traced field split: each sub-spec is a frozen dataclass registered
as a JAX pytree whose *data* fields are the numeric arrays (rates, routing,
power coefficients, learning constants) and whose *meta* fields are the
structure (timing-law / strategy / objective names, population counts,
optimizer settings).  Two scenarios with equal meta flatten to identical
treedefs, so a batch of them stacks leaf-wise and rides the padded-lane
conventions of ``repro.core.batched`` and ``repro.fl.engine`` under one
compile — batching over *scenarios*, not just seeds.

Serialization: ``to_dict`` / ``from_dict`` round-trip through plain JSON
types **bitwise** (Python's ``json`` emits ``repr``-exact floats), so an
experiment file pins its scenario exactly; :meth:`Scenario.hash` is the
canonical-JSON digest used to key benchmark trajectories
(``BENCH_smoke.json``) across API churn.

Validation is *eager*: unknown timing laws, strategies, objectives or
malformed shapes raise at construction — with the registered options in the
message — not deep inside a jit trace.
"""
from __future__ import annotations

import contextlib
import dataclasses
import hashlib
import json
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.complexity import LearningConstants
from ..core.buzen import ClassParams, NetworkParams
from ..core.energy import PowerProfile
from .registry import OBJECTIVES, PARTITIONS, STRATEGIES, TIMING_LAWS

# The paper's step sizes for the Table-3 comparison: max-throughput needs a
# 20x-reduced learning rate to stay stable (Section 5.3).  Single source of
# truth; ``repro.fl.strategies`` re-exports for seed call sites.
DEFAULT_ETA = 0.05
MAX_THROUGHPUT_ETA = 0.01

EXPLICIT = "explicit"  # StrategySpec.name for a hand-given (p, m)


def _is_tracer(x) -> bool:
    return isinstance(x, jax.core.Tracer)


# Validation nesting guard: pytree unflattening re-runs ``__post_init__``;
# under jit/vmap the leaves are tracers (validation skips itself), but the
# eager :func:`stack` rebuilds specs with *batched* concrete leaves, where
# the 1-D shape checks must be suspended.
_SKIP_VALIDATION = 0


@contextlib.contextmanager
def _no_validation():
    global _SKIP_VALIDATION
    _SKIP_VALIDATION += 1
    try:
        yield
    finally:
        _SKIP_VALIDATION -= 1


def _coerce_vec(obj, field: str, n: Optional[int] = None,
                positive: bool = False) -> Optional[int]:
    """Coerce a 1-D float64 vector field in place (tracer-transparent);
    returns its length (or ``n`` unchanged for an absent optional field)."""
    v = getattr(obj, field)
    if v is None or _is_tracer(v):
        return n
    arr = np.asarray(v, dtype=np.float64)
    if arr.ndim != 1:
        raise ValueError(f"{type(obj).__name__}.{field} must be 1-D, "
                         f"got shape {arr.shape}")
    if n is not None and arr.shape[0] != n:
        raise ValueError(f"{type(obj).__name__}.{field} has length "
                         f"{arr.shape[0]}, expected {n}")
    if positive and not (arr > 0).all():
        raise ValueError(f"{type(obj).__name__}.{field} must be positive")
    object.__setattr__(obj, field, arr)
    return arr.shape[0]


def _pytree_dataclass(data_fields):
    """Register a frozen dataclass as a pytree with the given data fields
    (everything else is meta/static).  Equality must be array-aware, so the
    classes set ``eq=False`` and get a structural ``__eq__`` here."""
    data_fields = tuple(data_fields)

    def deco(cls):
        meta = tuple(f.name for f in dataclasses.fields(cls)
                     if f.name not in data_fields)
        jax.tree_util.register_dataclass(cls, data_fields=list(data_fields),
                                         meta_fields=list(meta))

        def __eq__(self, other):
            if type(other) is not type(self):
                return NotImplemented
            for f in dataclasses.fields(self):
                a, b = getattr(self, f.name), getattr(other, f.name)
                if isinstance(a, np.ndarray) or isinstance(b, np.ndarray):
                    if not (isinstance(a, np.ndarray)
                            and isinstance(b, np.ndarray)
                            and a.shape == b.shape and (a == b).all()):
                        return False
                elif a != b:
                    return False
            return True

        cls.__eq__ = __eq__
        cls.__hash__ = object.__hash__
        return cls

    return deco


def _dict_vec(v):
    return None if v is None else [float(x) for x in np.asarray(v)]


def _opt_float(v):
    return None if v is None else float(v)


# ---------------------------------------------------------------------------
# cluster rows (Table 1 / Table 4 / Table 6)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """One client cluster row of Table 1 / Table 4."""

    name: str
    mu_c: float
    mu_u: float
    mu_d: float
    count: int
    kappa: float = 0.0   # DVFS energy coefficient (Table 4)
    P_u: float = 0.0
    P_d: float = 0.0


# Table 1 — the paper's main experimental population (n = 100).
PAPER_CLUSTERS_TABLE1 = [
    ClusterSpec("A", 10.0, 2.0, 2.5, 15, kappa=0.08, P_u=5.0, P_d=3.0),
    ClusterSpec("B", 0.3, 9.0, 10.0, 15, kappa=200.0, P_u=15.0, P_d=10.0),
    ClusterSpec("C", 5.0, 6.0, 7.0, 20, kappa=0.25, P_u=4.0, P_d=3.0),
    ClusterSpec("D", 0.15, 0.1, 0.12, 40, kappa=14400.0, P_u=0.5, P_d=0.2),
    ClusterSpec("E", 12.0, 10.0, 11.0, 10, kappa=1.50, P_u=50.0, P_d=40.0),
]

# Table 6 — the round-complexity experiment population (Appendix H).
PAPER_CLUSTERS_TABLE6 = [
    ClusterSpec("A", 10.0, 2.0, 2.5, 15),
    ClusterSpec("B", 2.5, 8.0, 9.0, 35),
    ClusterSpec("C", 5.0, 5.0, 6.0, 30),
    ClusterSpec("D", 0.5, 0.8, 1.1, 15),
    ClusterSpec("E", 15.0, 10.0, 11.0, 5),
]


def expand_clusters(clusters, scale: int = 1):
    """Cluster rows -> per-client columns ``(labels, mu_c, mu_d, mu_u,
    kappa, P_u, P_d)`` with the population scaled down by ``scale``."""
    cols = {k: [] for k in ("label", "mu_c", "mu_d", "mu_u",
                            "kappa", "P_u", "P_d")}
    for c in clusters:
        cnt = max(1, c.count // scale)
        cols["label"] += [c.name] * cnt
        for k in ("mu_c", "mu_d", "mu_u", "kappa", "P_u", "P_d"):
            cols[k] += [getattr(c, k)] * cnt
    return (tuple(cols["label"]),) + tuple(
        np.asarray(cols[k], dtype=np.float64)
        for k in ("mu_c", "mu_d", "mu_u", "kappa", "P_u", "P_d"))


# ---------------------------------------------------------------------------
# sub-specs
# ---------------------------------------------------------------------------

@_pytree_dataclass(data_fields=("mu_c", "mu_d", "mu_u", "p", "count"))
@dataclasses.dataclass(frozen=True, eq=False)
class ClassSpec:
    """Client classes with integer multiplicities — the O(C) population axis.

    The product-form network depends on a client only through its
    ``(p, mu_c, mu_d, mu_u)`` profile, so ``count[c]`` identical clients
    collapse into one class (``repro.core.buzen.ClassParams``): closed
    forms run the O(C) negative-binomial Buzen DP, the event engine carries
    O(C) statistics, and the population size ``n_total = sum(count)``
    becomes a free variable — ``n = 10^5..10^6`` scenarios cost the same
    as ``n = 10^2`` ones.  ``p`` is the *per-member* routing mass (class
    ``c`` as a whole carries ``count[c] * p[c]``); ``None`` means uniform
    ``1 / n_total``.  :meth:`NetworkSpec.params` expands back to the
    per-client oracle (O(n), for validation and small-``n`` interop).
    """

    mu_c: np.ndarray                  # [C] computation rates
    mu_d: np.ndarray                  # [C] downlink rates
    mu_u: np.ndarray                  # [C] uplink rates
    count: np.ndarray                 # [C] integer multiplicities (>= 1)
    p: Optional[np.ndarray] = None    # [C] per-member routing (None = uniform)
    labels: Optional[tuple] = None    # per-class cluster labels (meta)

    def __post_init__(self):
        if _SKIP_VALIDATION:
            return
        C = _coerce_vec(self, "mu_c", positive=True)
        C = _coerce_vec(self, "mu_d", C, positive=True)
        C = _coerce_vec(self, "mu_u", C, positive=True)
        _coerce_vec(self, "p", C, positive=True)
        if self.count is not None and not _is_tracer(self.count):
            arr = np.asarray(self.count)
            if arr.ndim != 1:
                raise ValueError(f"ClassSpec.count must be 1-D, got shape "
                                 f"{arr.shape}")
            if C is not None and arr.shape[0] != C:
                raise ValueError(f"ClassSpec.count has length "
                                 f"{arr.shape[0]}, expected {C}")
            if (not np.issubdtype(arr.dtype, np.integer)
                    and not np.all(arr == np.round(arr))):
                raise ValueError("ClassSpec.count must be integers")
            arr = arr.astype(np.int64)
            if not (arr >= 1).all():
                raise ValueError("ClassSpec.count must be >= 1 (padding "
                                 "with count-0 classes happens at the "
                                 "ClassParams level, not in the spec)")
            object.__setattr__(self, "count", arr)
        if self.labels is not None:
            object.__setattr__(self, "labels", tuple(self.labels))
            if C is not None and len(self.labels) != C:
                raise ValueError("labels/rates length mismatch")

    @classmethod
    def from_clusters(cls, clusters, scale: int = 1) -> "ClassSpec":
        """One class per cluster row — the aggregated form of
        :meth:`NetworkSpec.from_clusters` (same ``scale`` semantics)."""
        return cls(
            mu_c=np.asarray([c.mu_c for c in clusters], np.float64),
            mu_d=np.asarray([c.mu_d for c in clusters], np.float64),
            mu_u=np.asarray([c.mu_u for c in clusters], np.float64),
            count=np.asarray([max(1, c.count // scale) for c in clusters],
                             np.int64),
            labels=tuple(c.name for c in clusters))

    @property
    def C(self) -> int:
        return len(self.count)

    @property
    def n_total(self) -> int:
        return int(np.asarray(self.count).sum())

    def class_params(self, p=None, mu_cs=None) -> ClassParams:
        """Materialize :class:`repro.core.buzen.ClassParams` (routing
        override ``p`` > spec base ``p`` > uniform ``1/n_total``)."""
        if p is None:
            p = (self.p if self.p is not None
                 else np.full(self.C, 1.0 / self.n_total))
        cp = ClassParams(
            p=jnp.asarray(p, jnp.float64),
            mu_c=jnp.asarray(self.mu_c), mu_d=jnp.asarray(self.mu_d),
            mu_u=jnp.asarray(self.mu_u),
            count=jnp.asarray(self.count, jnp.int64))
        if mu_cs is not None:
            cp = cp.with_cs(mu_cs)
        return cp

    def to_dict(self) -> dict:
        return {"mu_c": _dict_vec(self.mu_c), "mu_d": _dict_vec(self.mu_d),
                "mu_u": _dict_vec(self.mu_u),
                "count": [int(x) for x in np.asarray(self.count)],
                "p": _dict_vec(self.p),
                "labels": None if self.labels is None else list(self.labels)}

    @classmethod
    def from_dict(cls, d: dict) -> "ClassSpec":
        return cls(**{**d, "labels": None if d.get("labels") is None
                      else tuple(d["labels"])})


@_pytree_dataclass(data_fields=("mu_c", "mu_d", "mu_u", "p", "mu_cs",
                                "classes"))
@dataclasses.dataclass(frozen=True, eq=False)
class NetworkSpec:
    """The closed queueing network: per-client rates, base routing, the
    service-time law, and the optional CS-side buffer (Section 7).

    Two population representations, mutually exclusive:

      * per-client arrays ``mu_c``/``mu_d``/``mu_u``/``p`` (the original
        O(n) form), or
      * ``classes=``, a :class:`ClassSpec` of class profiles with integer
        multiplicities — all closed forms and the event engine then run
        O(#classes), making ``n`` a free variable.
    """

    mu_c: Optional[np.ndarray] = None  # [n] computation rates
    mu_d: Optional[np.ndarray] = None  # [n] downlink rates
    mu_u: Optional[np.ndarray] = None  # [n] uplink rates
    p: Optional[np.ndarray] = None    # [n] base routing (None = uniform)
    mu_cs: Optional[float] = None     # CS buffer rate (None = no CS station)
    law: str = "exponential"          # registered timing law (meta)
    labels: Optional[tuple] = None    # per-client cluster labels (meta)
    classes: Optional[ClassSpec] = None  # class-aggregated population

    def __post_init__(self):
        if _SKIP_VALIDATION:
            return
        if self.classes is not None:
            if any(getattr(self, f) is not None
                   for f in ("mu_c", "mu_d", "mu_u", "p")):
                raise ValueError(
                    "NetworkSpec with classes= must not also carry "
                    "per-client rate/routing arrays — the ClassSpec is the "
                    "population")
        else:
            if self.mu_c is None:
                raise ValueError("NetworkSpec needs either per-client "
                                 "rates (mu_c/mu_d/mu_u) or classes=")
            n = _coerce_vec(self, "mu_c", positive=True)
            n = _coerce_vec(self, "mu_d", n, positive=True)
            n = _coerce_vec(self, "mu_u", n, positive=True)
            _coerce_vec(self, "p", n, positive=True)
            if self.labels is not None:
                object.__setattr__(self, "labels", tuple(self.labels))
                if n is not None and len(self.labels) != n:
                    raise ValueError("labels/rates length mismatch")
        if self.mu_cs is not None and not _is_tracer(self.mu_cs):
            if not float(self.mu_cs) > 0:
                raise ValueError(f"mu_cs must be positive, got {self.mu_cs}")
            object.__setattr__(self, "mu_cs", float(self.mu_cs))
        TIMING_LAWS.get(self.law)  # eager: unknown laws fail here, not in jit

    @classmethod
    def from_clusters(cls, clusters, scale: int = 1, *,
                      mu_cs: Optional[float] = None,
                      law: str = "exponential",
                      aggregate: bool = False) -> "NetworkSpec":
        """Per-client network from cluster rows; ``aggregate=True`` builds
        the class-aggregated form (one :class:`ClassSpec` class per
        cluster) instead of expanding to per-client arrays."""
        if aggregate:
            return cls(classes=ClassSpec.from_clusters(clusters, scale),
                       mu_cs=mu_cs, law=law)
        labels, mu_c, mu_d, mu_u, _, _, _ = expand_clusters(clusters, scale)
        return cls(mu_c=mu_c, mu_d=mu_d, mu_u=mu_u, mu_cs=mu_cs, law=law,
                   labels=labels)

    @property
    def n(self) -> int:
        return (self.classes.n_total if self.classes is not None
                else len(self.mu_c))

    def params(self, p=None) -> NetworkParams:
        """Materialize :class:`repro.core.NetworkParams` (routing override
        ``p`` > spec base ``p`` > uniform).

        For a class network this *expands* the population (O(n) — the
        oracle path; the O(C) planner paths call :meth:`class_params`
        instead), with ``p`` interpreted per-member over classes.
        """
        if self.classes is not None:
            return self.class_params(p).expand()
        if p is None:
            p = self.p if self.p is not None else np.full(self.n, 1.0 / self.n)
        params = NetworkParams(
            p=jnp.asarray(p, jnp.float64),
            mu_c=jnp.asarray(self.mu_c), mu_d=jnp.asarray(self.mu_d),
            mu_u=jnp.asarray(self.mu_u))
        if self.mu_cs is not None:
            params = params.with_cs(self.mu_cs)
        return params

    def class_params(self, p=None) -> ClassParams:
        """Materialize :class:`repro.core.buzen.ClassParams` (class
        networks only; ``p`` is per-member routing over classes)."""
        if self.classes is None:
            raise ValueError("not a class network: construct NetworkSpec "
                             "with classes= for the O(C) forms")
        return self.classes.class_params(p, mu_cs=self.mu_cs)

    def to_dict(self) -> dict:
        d = {"mu_c": _dict_vec(self.mu_c), "mu_d": _dict_vec(self.mu_d),
             "mu_u": _dict_vec(self.mu_u), "p": _dict_vec(self.p),
             "mu_cs": _opt_float(self.mu_cs), "law": self.law,
             "labels": None if self.labels is None else list(self.labels)}
        # absent (not null) when unset — the SimSpec/DataSpec precedent:
        # pre-existing per-client scenarios keep their canonical JSON, and
        # hence their Scenario.hash(), unchanged
        if self.classes is not None:
            d["classes"] = self.classes.to_dict()
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "NetworkSpec":
        return cls(**{**d, "labels": None if d.get("labels") is None
                      else tuple(d["labels"]),
                      "classes": None if d.get("classes") is None
                      else ClassSpec.from_dict(d["classes"])})


@_pytree_dataclass(data_fields=("consts",))
@dataclasses.dataclass(frozen=True, eq=False)
class LearningSpec:
    """Learning-side spec: Assumption A1-A5 constants, the step-size rule
    (``None`` = the per-strategy Table-3 defaults), gradient clipping."""

    consts: LearningConstants = LearningConstants(
        L=1.0, delta=1.0, sigma=1.0, M=2.0, G=5.0, eps=1.0)
    eta: Optional[float] = None       # None -> per-strategy default table
    grad_clip: Optional[float] = None

    def __post_init__(self):
        if _SKIP_VALIDATION:
            return
        if not isinstance(self.consts, LearningConstants):
            object.__setattr__(self, "consts",
                               LearningConstants(*self.consts))

    def eta_for(self, strategy_name: str) -> float:
        """Resolved step size: explicit ``eta`` wins, else the paper's
        per-strategy defaults (Section 5.3)."""
        if self.eta is not None:
            return float(self.eta)
        return (MAX_THROUGHPUT_ETA if strategy_name == "max_throughput"
                else DEFAULT_ETA)

    def to_dict(self) -> dict:
        c = self.consts
        return {"consts": {"L": float(c.L), "delta": float(c.delta),
                           "sigma": float(c.sigma), "M": float(c.M),
                           "G": float(c.G), "eps": float(c.eps)},
                "eta": _opt_float(self.eta),
                "grad_clip": _opt_float(self.grad_clip)}

    @classmethod
    def from_dict(cls, d: dict) -> "LearningSpec":
        return cls(consts=LearningConstants(**d["consts"]), eta=d.get("eta"),
                   grad_clip=d.get("grad_clip"))


@_pytree_dataclass(data_fields=("kappa", "P_u", "P_d", "P_cs"))
@dataclasses.dataclass(frozen=True, eq=False)
class EnergySpec:
    """Phase-dependent power profile (Table 4): cubic-DVFS computation
    power ``kappa * mu_c**3`` plus radio powers (Section 6.5.1)."""

    kappa: np.ndarray                # [n] DVFS coefficients
    P_u: np.ndarray                  # [n] uplink powers
    P_d: np.ndarray                  # [n] downlink powers
    P_cs: Optional[float] = None     # CS processing power (Section 7.5)

    def __post_init__(self):
        if _SKIP_VALIDATION:
            return
        n = _coerce_vec(self, "kappa")
        n = _coerce_vec(self, "P_u", n)
        _coerce_vec(self, "P_d", n)
        if self.P_cs is not None and not _is_tracer(self.P_cs):
            object.__setattr__(self, "P_cs", float(self.P_cs))

    @classmethod
    def from_clusters(cls, clusters, scale: int = 1, *,
                      P_cs: Optional[float] = None) -> "EnergySpec":
        _, _, _, _, kappa, P_u, P_d = expand_clusters(clusters, scale)
        return cls(kappa=kappa, P_u=P_u, P_d=P_d, P_cs=P_cs)

    def profile(self, network: NetworkSpec) -> PowerProfile:
        """For class networks the arrays are per-CLASS (``[C]``, one power
        rating shared by the members of a class)."""
        mu_c = (network.classes.mu_c if network.classes is not None
                else network.mu_c)
        return PowerProfile.from_dvfs(
            jnp.asarray(self.kappa), jnp.asarray(mu_c),
            jnp.asarray(self.P_u), jnp.asarray(self.P_d),
            P_cs=None if self.P_cs is None else jnp.asarray(self.P_cs))

    def to_dict(self) -> dict:
        return {"kappa": _dict_vec(self.kappa), "P_u": _dict_vec(self.P_u),
                "P_d": _dict_vec(self.P_d), "P_cs": _opt_float(self.P_cs)}

    @classmethod
    def from_dict(cls, d: dict) -> "EnergySpec":
        return cls(**d)


@_pytree_dataclass(data_fields=("p",))
@dataclasses.dataclass(frozen=True, eq=False)
class StrategySpec:
    """Routing/concurrency strategy: a registered name (resolved by the
    strategy registry at suite time) or ``"explicit"`` with ``(p, m)``."""

    name: str = "asyncsgd"
    p: Optional[np.ndarray] = None    # explicit routing (name="explicit")
    m: Optional[int] = None           # explicit / forced concurrency
    m_max: Optional[int] = None       # concurrency search bound (default n+8)
    steps: int = 300                  # Adam steps of the routing optimizer
    search: str = "batched"           # "batched" | "pruned" | "sequential"

    def __post_init__(self):
        if _SKIP_VALIDATION:
            return
        _coerce_vec(self, "p", positive=True)
        if self.m is not None:
            object.__setattr__(self, "m", int(self.m))
        if self.m_max is not None:
            object.__setattr__(self, "m_max", int(self.m_max))
        if self.search not in ("batched", "pruned", "sequential"):
            raise ValueError(f"unknown search mode: {self.search!r}; "
                             "expected 'batched', 'pruned' or 'sequential'")
        if self.name == EXPLICIT:
            if self.p is None or self.m is None:
                raise ValueError(
                    "explicit strategy needs both p and m")
        else:
            # registrations live in repro.scenario.suite — make sure they
            # are loaded, then fail eagerly on unknown names
            from . import suite  # noqa: F401
            STRATEGIES.get(self.name)

    def to_dict(self) -> dict:
        return {"name": self.name, "p": _dict_vec(self.p), "m": self.m,
                "m_max": self.m_max, "steps": int(self.steps),
                "search": self.search}

    @classmethod
    def from_dict(cls, d: dict) -> "StrategySpec":
        return cls(**d)


@_pytree_dataclass(data_fields=())
@dataclasses.dataclass(frozen=True, eq=False)
class ObjectiveSpec:
    """What to optimize / report: a registered objective plus its Pareto
    weight ``rho`` (used by the ``"joint"`` objective/strategy, Eq. 18)."""

    name: str = "time"
    rho: float = 0.1

    def __post_init__(self):
        if _SKIP_VALIDATION:
            return
        object.__setattr__(self, "rho", float(self.rho))
        from . import suite  # noqa: F401  (loads objective registrations)
        OBJECTIVES.get(self.name)

    def to_dict(self) -> dict:
        return {"name": self.name, "rho": float(self.rho)}

    @classmethod
    def from_dict(cls, d: dict) -> "ObjectiveSpec":
        return cls(**d)


@_pytree_dataclass(data_fields=())
@dataclasses.dataclass(frozen=True, eq=False)
class TraceSpec:
    """Telemetry-channel selection for ``repro.obs`` (see its docs).

    ``events``/``updates`` are ring capacities (records kept; 0 disables
    the channel — the rings are zero-length and XLA dead-code-eliminates
    them, so an untraced scenario compiles the exact pre-existing
    program).  Tracing is **bitwise non-invasive**: results are identical
    with any capacities.  ``tolerance`` is the relative drift band the
    monitors (``repro.obs.drift``) allow between ring empirics and the
    closed-form predictions.
    """

    events: int = 0        # event-ring capacity (engine channel)
    updates: int = 0       # update-ring capacity (fused-trainer channel)
    tolerance: float = 0.25

    def __post_init__(self):
        if _SKIP_VALIDATION:
            return
        for f in ("events", "updates"):
            v = int(getattr(self, f))
            if v < 0:
                raise ValueError(f"TraceSpec.{f} must be >= 0, got {v}")
            object.__setattr__(self, f, v)
        tol = float(self.tolerance)
        if not tol > 0:
            raise ValueError(f"TraceSpec.tolerance must be > 0, got {tol}")
        object.__setattr__(self, "tolerance", tol)

    def to_dict(self) -> dict:
        return {"events": int(self.events), "updates": int(self.updates),
                "tolerance": float(self.tolerance)}

    @classmethod
    def from_dict(cls, d: dict) -> "TraceSpec":
        return cls(**d)


@_pytree_dataclass(data_fields=())
@dataclasses.dataclass(frozen=True, eq=False)
class SimSpec:
    """Event-engine execution knobs: which ``repro.sim`` backend runs this
    scenario's trajectories (``None`` = the process-wide
    ``REPRO_SIM_BACKEND`` default), for the Pallas backend an
    ``interpret``-mode override (``None`` = auto: compiled on TPU,
    interpreted elsewhere), the megastep chunk size (``chunk``: events
    retired per scan iteration / kernel launch — trajectories are bitwise
    invariant to it, default 1), and the optional ``repro.obs`` telemetry
    channels (``trace``; ``None`` = tracing off)."""

    backend: Optional[str] = None     # "reference" | "batched" | "pallas"
    interpret: Optional[bool] = None
    chunk: int = 1                    # megastep events per scan iteration
    trace: Optional[TraceSpec] = None

    def __post_init__(self):
        if _SKIP_VALIDATION:
            return
        if self.backend is not None:
            from ..sim.backend import _check  # dependency-free

            object.__setattr__(self, "backend", _check(str(self.backend)))
        if self.interpret is not None:
            object.__setattr__(self, "interpret", bool(self.interpret))
        object.__setattr__(self, "chunk", int(self.chunk))
        if self.chunk < 1:
            raise ValueError(f"chunk must be a positive integer, got "
                             f"{self.chunk}")
        if self.trace is not None and not isinstance(self.trace, TraceSpec):
            object.__setattr__(self, "trace", TraceSpec(**dict(self.trace)))

    def to_dict(self) -> dict:
        d = {"backend": self.backend, "interpret": self.interpret}
        # absent (not null) when unset: pre-obs SimSpec JSON — and every
        # Scenario.hash() over it — is unchanged by the trace field
        if self.trace is not None:
            d["trace"] = self.trace.to_dict()
        # same convention for the megastep knob: absent at the default, so
        # pre-megastep hashes are stable and chunk=1 stays byte-identical
        if self.chunk != 1:
            d["chunk"] = self.chunk
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "SimSpec":
        d = dict(d)
        trace = d.pop("trace", None)
        return cls(trace=None if trace is None
                   else TraceSpec.from_dict(trace), **d)


@_pytree_dataclass(data_fields=())
@dataclasses.dataclass(frozen=True, eq=False)
class DataSpec:
    """Declarative training data: a dataset builder plus an ``@partition``
    registry key (and its dirichlet ``alpha``), so
    ``ScenarioSuite.run(mode="train")`` can build the per-client datasets
    from the spec instead of requiring an explicit ``clients=``.

    Registered datasets (``repro.data.DATASETS``): ``"synthetic"`` (the
    procedural class-glyph images) and ``"emnist"`` — a download-free
    EMNIST-style loader that reads a local ``.npz`` cache
    (``$REPRO_EMNIST_PATH`` / ``~/.cache/repro/emnist.npz``) when present
    and otherwise falls back to a deterministic synthetic stand-in with
    the same 28x28 tensor format (``repro.data.emnist``)."""

    dataset: str = "synthetic"        # dataset builder name
    partition: str = "iid"            # @partition registry key
    alpha: float = 0.2                # dirichlet concentration (if used)
    num_classes: int = 4
    samples_per_class: int = 40
    test_fraction: float = 0.25
    seed: int = 0

    def __post_init__(self):
        if _SKIP_VALIDATION:
            return
        from .. import data  # registers the partitioners + dataset builders

        if self.dataset not in data.DATASETS:
            raise ValueError(f"unknown dataset: {self.dataset!r}; "
                             f"registered datasets: "
                             f"{sorted(data.DATASETS)}")
        PARTITIONS.get(self.partition)
        object.__setattr__(self, "alpha", float(self.alpha))
        for f in ("num_classes", "samples_per_class", "seed"):
            object.__setattr__(self, f, int(getattr(self, f)))
        object.__setattr__(self, "test_fraction", float(self.test_fraction))

    def build(self, n: int):
        """Materialize ``(clients, test_data)`` for an ``n``-client network:
        ``clients[i] = (x_i, y_i)`` per the registered partitioner."""
        import inspect

        from ..data import get_dataset, train_test_split

        full = get_dataset(
            self.dataset, num_classes=self.num_classes,
            samples_per_class=self.samples_per_class, seed=self.seed)
        ds, test = train_test_split(full, self.test_fraction,
                                    seed=self.seed + 1)
        part = PARTITIONS.get(self.partition)
        kw = {"seed": self.seed}
        if "alpha" in inspect.signature(part).parameters:
            kw["alpha"] = self.alpha
        parts = part(ds.y, n, **kw)
        clients = [(ds.x[i], ds.y[i]) for i in parts]
        return clients, (test.x, test.y)

    def to_dict(self) -> dict:
        return {"dataset": self.dataset, "partition": self.partition,
                "alpha": float(self.alpha),
                "num_classes": int(self.num_classes),
                "samples_per_class": int(self.samples_per_class),
                "test_fraction": float(self.test_fraction),
                "seed": int(self.seed)}

    @classmethod
    def from_dict(cls, d: dict) -> "DataSpec":
        return cls(**d)


# ---------------------------------------------------------------------------
# the Scenario
# ---------------------------------------------------------------------------

@_pytree_dataclass(data_fields=("network", "learning", "energy", "strategy",
                                "objective", "sim", "data"))
@dataclasses.dataclass(frozen=True, eq=False)
class Scenario:
    """One complete experiment: network x learning x energy x strategy x
    objective (x optional sim backend and data layout).  See the module
    docstring for the 5-line EMNIST example."""

    network: NetworkSpec
    learning: LearningSpec = dataclasses.field(default_factory=LearningSpec)
    energy: Optional[EnergySpec] = None
    strategy: StrategySpec = dataclasses.field(default_factory=StrategySpec)
    objective: ObjectiveSpec = dataclasses.field(
        default_factory=ObjectiveSpec)
    sim: Optional[SimSpec] = None     # None = process-default backend
    data: Optional[DataSpec] = None   # None = explicit clients= required
    name: str = ""

    def __post_init__(self):
        if _SKIP_VALIDATION:
            return
        if self.energy is not None and not _is_tracer(self.energy.kappa):
            # class networks carry per-CLASS power arrays
            expected = (self.network.classes.C
                        if self.network.classes is not None
                        else self.network.n)
            if len(self.energy.kappa) != expected:
                raise ValueError("energy/network population mismatch")
        # contract: allow(stringly-dispatch): eager construction-time check that these two strategies need an EnergySpec — resolution itself routes through STRATEGIES
        if (self.strategy.name in ("energy_opt", "joint")
                and self.energy is None):
            raise ValueError(
                f"strategy {self.strategy.name!r} needs an EnergySpec")

    # -- convenience ---------------------------------------------------------

    @property
    def n(self) -> int:
        return self.network.n

    @property
    def consts(self) -> LearningConstants:
        return self.learning.consts

    def params(self, p=None) -> NetworkParams:
        return self.network.params(p)

    def class_params(self, p=None) -> ClassParams:
        return self.network.class_params(p)

    @property
    def is_class_network(self) -> bool:
        return self.network.classes is not None

    def power(self) -> Optional[PowerProfile]:
        return None if self.energy is None else self.energy.profile(
            self.network)

    def eta(self) -> float:
        return self.learning.eta_for(self.strategy.name)

    @property
    def sim_backend(self) -> Optional[str]:
        """The pinned ``repro.sim`` backend (None = process default)."""
        return None if self.sim is None else self.sim.backend

    @property
    def trace(self) -> Optional[TraceSpec]:
        """The ``repro.obs`` telemetry channels (None = tracing off)."""
        return None if self.sim is None else self.sim.trace

    def replace(self, **kw) -> "Scenario":
        return dataclasses.replace(self, **kw)

    def with_strategy(self, strategy, **kw) -> "Scenario":
        """New scenario with a different strategy: pass a name (plus
        StrategySpec field overrides) or a full :class:`StrategySpec`.

        Rewriting a named strategy as ``"explicit"`` (e.g. pinning its
        resolved ``(p, m)``) freezes the *current* resolved step size into
        the learning spec — otherwise ``eta_for("explicit")`` would
        silently revert e.g. max-throughput's 20x-reduced eta to the
        default.
        """
        if isinstance(strategy, StrategySpec):
            spec = dataclasses.replace(strategy, **kw) if kw else strategy
        else:
            spec = dataclasses.replace(self.strategy, name=str(strategy),
                                       **kw)
        learning = self.learning
        if (spec.name == EXPLICIT and self.strategy.name != EXPLICIT
                and learning.eta is None):
            learning = dataclasses.replace(learning, eta=self.eta())
        name = self.name or None
        return dataclasses.replace(
            self, strategy=spec, learning=learning,
            name=f"{name}:{spec.name}" if name else spec.name)

    def fl_config(self, **overrides):
        """Materialize an :class:`repro.fl.AsyncFLConfig` for this scenario
        (law, grad clip and resolved eta pre-filled; kwargs override)."""
        from ..fl.trainer import AsyncFLConfig  # local: fl imports scenario

        kw = dict(eta=self.eta(), distribution=self.network.law,
                  grad_clip=self.learning.grad_clip)
        kw.update(overrides)
        return AsyncFLConfig(**kw)

    # -- serialization -------------------------------------------------------

    def to_dict(self) -> dict:
        d = {
            "version": 1,
            "kind": "Scenario",
            "name": self.name,
            "network": self.network.to_dict(),
            "learning": self.learning.to_dict(),
            "energy": None if self.energy is None else self.energy.to_dict(),
            "strategy": self.strategy.to_dict(),
            "objective": self.objective.to_dict(),
        }
        # absent (not null) when unset: scenarios predating SimSpec/DataSpec
        # keep their canonical JSON — and hence their hash() — unchanged,
        # so the BENCH_smoke.json perf trajectory stays joinable
        if self.sim is not None:
            d["sim"] = self.sim.to_dict()
        if self.data is not None:
            d["data"] = self.data.to_dict()
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "Scenario":
        if d.get("kind", "Scenario") != "Scenario":
            raise ValueError(f"not a Scenario dict: kind={d.get('kind')!r}")
        return cls(
            network=NetworkSpec.from_dict(d["network"]),
            learning=LearningSpec.from_dict(d["learning"]),
            energy=None if d.get("energy") is None
            else EnergySpec.from_dict(d["energy"]),
            strategy=StrategySpec.from_dict(d["strategy"]),
            objective=ObjectiveSpec.from_dict(d["objective"]),
            sim=None if d.get("sim") is None
            else SimSpec.from_dict(d["sim"]),
            data=None if d.get("data") is None
            else DataSpec.from_dict(d["data"]),
            name=d.get("name", ""),
        )

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True,
                          separators=(",", ":"))

    @classmethod
    def from_json(cls, s: str) -> "Scenario":
        return cls.from_dict(json.loads(s))

    def hash(self) -> str:
        """Short digest of the canonical JSON — the churn-stable key for
        benchmark trajectories.

        The cosmetic ``name`` is excluded: two physically identical
        scenarios must hash equal, or a mere rename would sever the
        ``BENCH_smoke.json`` perf trajectory the hash exists to protect.
        """
        d = self.to_dict()
        d.pop("name", None)
        return hashlib.sha256(json.dumps(
            d, sort_keys=True, separators=(",", ":")).encode()
        ).hexdigest()[:12]


def stack(scenarios) -> Scenario:
    """Stack structurally-identical scenarios leaf-wise into one batched
    Scenario pytree (leading axis = scenario lane) — the vmap-ready form.

    All scenarios must share their meta fields (same treedef: same law,
    strategy/objective names, population size, ...); mixed batches belong
    in a :class:`repro.scenario.suite.ScenarioSuite`, which buckets by
    structure first.
    """
    scenarios = list(scenarios)
    if not scenarios:
        raise ValueError("need at least one scenario")
    treedefs = {jax.tree_util.tree_structure(s) for s in scenarios}
    if len(treedefs) != 1:
        raise ValueError(
            "scenarios have mixed static structure and cannot be stacked "
            "directly; run them through ScenarioSuite (which buckets by "
            f"structure): {sorted(map(str, treedefs))}")
    with _no_validation():  # leaves gain a lane axis: skip the 1-D checks
        return jax.tree_util.tree_map(lambda *xs: np.stack(xs), *scenarios)
