"""Typed registries behind the declarative Scenario API.

Every extension point of the pipeline — service-time laws, scheduling
strategies, optimization objectives, data partitioners — is a named entry in
a :class:`Registry`, populated with decorator registration::

    from repro.scenario import timing_law

    @timing_law("hyperexponential")
    def _hyper(): ...

Lookups go through :meth:`Registry.get`, which raises a ``ValueError``
listing the registered names on an unknown key — so a typo in a config file
or an ``AsyncFLConfig.distribution`` fails *eagerly at construction* with
the available options, instead of deep inside a jit trace.

This module is dependency-free (stdlib only): the low-level engines
(``repro.core.events``, ``repro.core.simulator``, ``repro.data.partition``)
import it without pulling the rest of the Scenario machinery, and the
registrations live next to the implementations they name
(``repro.scenario.laws`` for timing laws, ``repro.scenario.suite`` for
strategies and objectives, ``repro.data.partition`` for partitioners).
"""
from __future__ import annotations

from typing import Any, Callable, Iterator


class Registry:
    """A name -> entry mapping with decorator registration and helpful
    unknown-key errors."""

    def __init__(self, kind: str):
        self.kind = kind
        self._entries: dict[str, Any] = {}

    def register(self, name: str) -> Callable:
        """Decorator: ``@REG.register("name")`` stores the decorated object
        under ``name`` and returns it unchanged."""
        if not isinstance(name, str) or not name:
            raise TypeError(
                f"{self.kind} registry keys must be non-empty strings, "
                f"got {name!r}")

        def deco(obj):
            if name in self._entries:
                raise ValueError(
                    f"{self.kind} {name!r} is already registered "
                    f"(to {self._entries[name]!r})")
            self._entries[name] = obj
            return obj

        return deco

    def get(self, name: str):
        """Entry for ``name``; unknown keys raise listing the options."""
        try:
            return self._entries[name]
        except KeyError:
            plural = (self.kind[:-1] + "ies" if self.kind.endswith("y")
                      else self.kind + "s")
            raise ValueError(
                f"unknown {self.kind}: {name!r}; registered {plural}: "
                f"{sorted(self._entries)}") from None

    def names(self) -> tuple[str, ...]:
        return tuple(sorted(self._entries))

    def items(self):
        return self._entries.items()

    def __contains__(self, name: str) -> bool:
        return name in self._entries

    def __iter__(self) -> Iterator[str]:
        return iter(sorted(self._entries))

    def __len__(self) -> int:
        return len(self._entries)

    def __repr__(self) -> str:
        return f"Registry({self.kind!r}, {sorted(self._entries)})"


# The four extension points of the Scenario API.  ``TIMING_LAWS`` is keyed by
# the ``distribution=`` strings the engines always used ("exponential", ...);
# its kind reads "service distribution" so unknown-law errors stay
# grep-compatible with the historical message.
TIMING_LAWS = Registry("service distribution")
STRATEGIES = Registry("strategy")
OBJECTIVES = Registry("objective")
PARTITIONS = Registry("partition")

# decorator aliases: @timing_law("name"), @strategy("name"), ...
timing_law = TIMING_LAWS.register
strategy = STRATEGIES.register
objective = OBJECTIVES.register
partition = PARTITIONS.register
