"""Service-time (timing) laws — one registry entry drives both engines.

A :class:`TimingLaw` packages the two implementations every law needs:

  * ``host_sample(mu, rng)`` — one draw with mean ``1/mu`` from a
    ``numpy.random.Generator`` (the exact per-task-identity heap simulator,
    ``repro.core.simulator.AsyncNetworkSim``);
  * ``device_draw(key, rate, shape)`` — the same distribution as a pure JAX
    function of a PRNG key (the jitted event engine,
    ``repro.core.events``, where service completions race as absolute
    clocks drawn at service start — exact for *any* law registered here).

Laws may additionally provide the *unit factorization* used by the
megastep engine (``repro.core.events`` chunked mode): ``unit_draw(key,
shape)`` draws the rate-independent part of the sample up front, and
``unit_apply(u, rate)`` applies a rate afterwards such that

    unit_apply(unit_draw(key, shape), rate) == device_draw(key, rate, shape)

**bitwise** (same primitives in the same order — e.g. the lognormal
applies ``exp(u - log(rate) - 0.5)``, not ``exp(u - 0.5) / rate``).  The
factorization lets a chunk of draws whose *rates* depend on simulation
state (uplink/computation services keyed by the routed client) be
pre-drawn as a block while the rate is applied inside the event loop.
Laws without it (``unit_draw is None``) still work with ``chunk > 1``:
the engine stores the raw subkeys and calls ``device_draw`` per event.

Built-ins are the paper's Section 5.3.3 laws (exponential, deterministic,
lognormal) plus a **hyperexponential** (H2) law — the balanced-means
two-phase mixture with squared coefficient of variation ``SCV = 4``,
a standard high-variance stress test in the queueing literature: with
probability ``q = (1 + sqrt(3/5)) / 2`` the task is a "fast" exponential of
rate ``2 q mu``, otherwise a "slow" one of rate ``2 (1 - q) mu``; the mean
is ``1/mu`` for every ``mu``.

Register new laws with the decorator::

    @timing_law("mylaw")
    def _mylaw() -> TimingLaw:
        return TimingLaw(host_sample=..., device_draw=...)

(The registry stores the *factory*; :func:`get_law` calls and caches it, so
registration stays import-cheap.)  Both implementations must produce mean
``1/mu`` draws and raise/propagate on non-positive rates on the host side.
"""
from __future__ import annotations

import math
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from .registry import TIMING_LAWS, timing_law


class TimingLaw(NamedTuple):
    """Host and device implementations of one service-time distribution."""

    host_sample: Callable  # (mu: float, rng: np.random.Generator) -> float
    device_draw: Callable  # (key, rate: Array, shape) -> Array
    # optional unit factorization (megastep block draws); both or neither:
    unit_draw: Optional[Callable] = None  # (key, shape) -> unit part
    unit_apply: Optional[Callable] = None  # (u, rate) -> sample, bitwise
    #   unit_apply(unit_draw(key, shape), rate) == device_draw(key, rate, shape)


def _check(mu: float) -> float:
    """Shared host-side guard: a zero/negative rate would stall the event
    heap with infinite clocks — fail at the draw instead."""
    if not mu > 0:
        raise ValueError(f"service rate must be positive, got mu={mu}")
    return mu


_cache: dict[str, TimingLaw] = {}


def get_law(name: str) -> TimingLaw:
    """Resolve a registered law (building and caching it on first use).

    Raises ``ValueError`` listing the registered laws on an unknown name —
    the eager-validation entry point used by ``AsyncFLConfig``,
    ``make_sampler`` and ``simulate_stats``.
    """
    hit = _cache.get(name)
    if hit is None:
        hit = _cache[name] = TIMING_LAWS.get(name)()
    return hit


def law_names() -> tuple[str, ...]:
    return TIMING_LAWS.names()


# ---------------------------------------------------------------------------
# built-in laws (Section 5.3.3) — the device draws are bit-compatible with
# the historical ``repro.core.events._draw`` (same primitives, same key use)
# ---------------------------------------------------------------------------

@timing_law("exponential")
def _exponential() -> TimingLaw:
    return TimingLaw(
        host_sample=lambda mu, rng: rng.exponential(1.0 / _check(mu)),
        device_draw=lambda key, rate, shape=():
            jax.random.exponential(key, shape) / rate,
        unit_draw=lambda key, shape=(): jax.random.exponential(key, shape),
        unit_apply=lambda u, rate: u / rate)


@timing_law("deterministic")
def _deterministic() -> TimingLaw:
    return TimingLaw(
        host_sample=lambda mu, rng: 1.0 / _check(mu),
        device_draw=lambda key, rate, shape=():
            jnp.broadcast_to(1.0 / rate, shape),
        # key-free: the unit part only carries the shape
        unit_draw=lambda key, shape=(): jnp.zeros(shape),
        unit_apply=lambda u, rate: jnp.broadcast_to(1.0 / rate, jnp.shape(u)))


@timing_law("lognormal")
def _lognormal() -> TimingLaw:
    # underlying normal variance sigma_N^2 = 1, mean of LN = 1/mu
    # mean = exp(mu_N + 1/2) = 1/mu  ->  mu_N = -log(mu) - 1/2
    #
    # No unit factorization on purpose: splitting u = normal(key) from
    # exp(u - log(rate) - 0.5) puts a fusion boundary inside a
    # contraction-eligible (mul-add) float chain, so the materialized-u
    # value can differ from the fused single-step draw by 1 ulp on CPU.
    # The raw-subkey fallback replays the whole draw in one fusion
    # context — bitwise by construction.
    return TimingLaw(
        host_sample=lambda mu, rng:
            rng.lognormal(-math.log(_check(mu)) - 0.5, 1.0),
        device_draw=lambda key, rate, shape=():
            jnp.exp(jax.random.normal(key, shape) - jnp.log(rate) - 0.5))


# H2 balanced-means parameters for SCV = 4: q (1 - q) = 1 / (2 (SCV + 1))
_H2_SCV = 4.0
_H2_Q = 0.5 * (1.0 + math.sqrt((_H2_SCV - 1.0) / (_H2_SCV + 1.0)))


@timing_law("hyperexponential")
def _hyperexponential() -> TimingLaw:
    q = _H2_Q

    def host_sample(mu, rng):
        rate = (2.0 * q if rng.random() < q else 2.0 * (1.0 - q)) * _check(mu)
        return rng.exponential(1.0 / rate)

    def device_draw(key, rate, shape=()):
        k_branch, k_exp = jax.random.split(key)
        fast = jax.random.uniform(k_branch, shape) < q
        branch_rate = jnp.where(fast, 2.0 * q, 2.0 * (1.0 - q)) * rate
        return jax.random.exponential(k_exp, shape) / branch_rate

    def unit_draw(key, shape=()):
        k_branch, k_exp = jax.random.split(key)
        return (jax.random.uniform(k_branch, shape),
                jax.random.exponential(k_exp, shape))

    def unit_apply(u, rate):
        branch, e = u
        branch_rate = jnp.where(branch < q, 2.0 * q, 2.0 * (1.0 - q)) * rate
        return e / branch_rate

    return TimingLaw(host_sample=host_sample, device_draw=device_draw,
                     unit_draw=unit_draw, unit_apply=unit_apply)
