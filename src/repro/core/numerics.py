"""Numeric configuration for the queueing core.

The product-form normalization constants ``Z_{n,m}`` span hundreds of orders
of magnitude; the whole queueing core therefore runs in log space, and we
additionally enable float64 so that closed-form identities (e.g.
``sum_i E0[D_i] = m - 1``) hold to ~1e-12 in tests.

Model code is unaffected: all model/kernel modules request explicit dtypes
(bf16/f32), which x64 mode does not override.
"""
from __future__ import annotations
# contract: padded-n — reductions here are on the bitwise padding contract

import jax

jax.config.update("jax_enable_x64", True)

NEG_INF = -1e30  # used instead of -inf to keep gradients NaN-free


def safe_log(x):
    import jax.numpy as jnp

    return jnp.log(jnp.maximum(x, 1e-300))


def seqsum(x, axis: int = -1):
    """Strictly left-to-right float sum along ``axis`` (a ``lax.scan``).

    ``jnp.sum`` lowers to an XLA reduce whose association may change with
    the array *length* (vectorized/unrolled reduction trees), so summing a
    zero-padded array is not guaranteed to reproduce the unpadded sum
    bitwise.  A sequential scan is: appended zeros satisfy ``carry + 0 ==
    carry`` exactly and the real elements keep their left-to-right
    association regardless of padding.  Used for every client-axis
    reduction on the padded traced-``n`` bitwise contract
    (``pad_network`` / ``tests/test_padded_n.py``); differentiable and
    vmap-compatible like any scan.
    """
    import jax.numpy as jnp

    x = jnp.moveaxis(jnp.asarray(x), axis, 0)
    carry, _ = jax.lax.scan(lambda c, v: (c + v, None),
                            jnp.zeros(x.shape[1:], x.dtype), x)
    return carry


def seqcumsum(x, axis: int = -1):
    """Strictly left-to-right inclusive prefix sum along ``axis``.

    The prefix analogue of :func:`seqsum`: ``jnp.cumsum`` may lower to a
    parallel (tree) scan whose association changes with array length, so a
    zero-padded prefix is not guaranteed bitwise equal to the unpadded one
    on every backend.  A sequential scan is — real entries keep their
    left-to-right association and trailing zeros repeat the running total
    exactly (so the last element doubles as a padding-stable ``seqsum``).
    """
    import jax.numpy as jnp

    x = jnp.moveaxis(jnp.asarray(x), axis, 0)

    def step(c, v):
        c = c + v
        return c, c

    _, out = jax.lax.scan(step, jnp.zeros(x.shape[1:], x.dtype), x)
    return jnp.moveaxis(out, 0, axis)
