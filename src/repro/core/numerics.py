"""Numeric configuration for the queueing core.

The product-form normalization constants ``Z_{n,m}`` span hundreds of orders
of magnitude; the whole queueing core therefore runs in log space, and we
additionally enable float64 so that closed-form identities (e.g.
``sum_i E0[D_i] = m - 1``) hold to ~1e-12 in tests.

Model code is unaffected: all model/kernel modules request explicit dtypes
(bf16/f32), which x64 mode does not override.
"""
from __future__ import annotations

import jax

jax.config.update("jax_enable_x64", True)

NEG_INF = -1e30  # used instead of -inf to keep gradients NaN-free


def safe_log(x):
    import jax.numpy as jnp

    return jnp.log(jnp.maximum(x, 1e-300))
