"""Round / wall-clock complexity of Generalized AsyncSGD.

Implements:
  * Theorem 3  — round complexity ``K_eps(p, m)`` (Eq. 9) and the maximal
    learning rate ``eta_max(p, m)`` (Eq. 8);
  * Theorem 17 — the bounded-gradient-free variant with the system-wide
    staleness factor ``S_sys`` (Eq. 58);
  * Proposition 4/8 — expected wall-clock time ``E0[tau_eps] = K_eps / lambda``.

Constants follow the paper: ``B = 6 (sigma^2 + 2 M^2)``,
``C = 6 (sigma^2 + G^2)``, ``Delta = f(w_0) - f*``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import numerics  # noqa: F401
from .buzen import NetworkParams, log_normalizing_constants
from .jackson import expected_relative_delay, throughput


class LearningConstants(NamedTuple):
    """Problem-dependent constants of Assumptions A1–A5 (Section 2.5)."""

    L: float = 1.0        # smoothness (A2)
    delta: float = 1.0    # f(w_0) - f^*  (A1)
    sigma: float = 1.0    # gradient noise std (A3)
    M: float = 0.0        # gradient dissimilarity (A4)
    G: float = 1.0        # gradient norm bound (A5)
    eps: float = 1.0      # target stationarity

    @property
    def B(self) -> float:
        return 6.0 * (self.sigma**2 + 2.0 * self.M**2)

    @property
    def C(self) -> float:
        return 6.0 * (self.sigma**2 + self.G**2)


def round_complexity(params: NetworkParams, m: int, consts: LearningConstants,
                     logZ: jax.Array | None = None) -> jax.Array:
    """``K_eps(p, m)`` — Theorem 3, Eq. (9)."""
    n = params.n
    p = params.p
    eps = consts.eps
    first = (4.0 + consts.B / eps) * jnp.sum(1.0 / (n * p))
    if m > 1:  # staleness term vanishes identically at m = 1 (serial SGD)
        delays = expected_relative_delay(params, m, logZ)
        staleness = jnp.sum(delays / p**2)
        second = jnp.sqrt(consts.C * (m - 1) / eps * staleness)
    else:
        second = 0.0
    return 24.0 * consts.L * consts.delta / (n * eps) * (first + second)


def eta_max(params: NetworkParams, m: int, consts: LearningConstants,
            logZ: jax.Array | None = None) -> jax.Array:
    """Maximal admissible learning rate — Theorem 3, Eq. (8)."""
    n = params.n
    p = params.p
    L, eps = consts.L, consts.eps
    inv_p_sum = jnp.sum(1.0 / p)
    delays = expected_relative_delay(params, m, logZ)
    staleness = jnp.maximum(jnp.sum(delays / p**2), 1e-300)
    t1 = n**2 / (8.0 * L * inv_p_sum)
    t2 = n**2 * eps / (2.0 * L * consts.B * inv_p_sum)
    t3 = n * jnp.sqrt(eps) / (2.0 * L) / jnp.sqrt(
        jnp.maximum(consts.C * max(m - 1, 0) * staleness, 1e-300))
    return jnp.minimum(t1, jnp.minimum(t2, t3))


def system_staleness_factor(params: NetworkParams, m: int) -> jax.Array:
    """``S_sys`` of Theorem 17 (Eq. 58)."""
    mu_u_tot = jnp.sum(params.mu_u)
    per = (1.0 / params.mu_d + 1.0 / params.mu_u + m / params.mu_c) / params.p**2
    return (m - 1) * mu_u_tot * jnp.sum(per)


def round_complexity_unbounded(params: NetworkParams, m: int,
                               consts: LearningConstants,
                               logZ: jax.Array | None = None) -> jax.Array:
    """Theorem 17 — ``K_eps`` without the bounded-gradient assumption A5."""
    n = params.n
    p = params.p
    eps = consts.eps
    first = (2.0 + consts.B / eps) * jnp.sum(1.0 / (n * p))
    if m > 1:
        delays = expected_relative_delay(params, m, logZ)
        s_sys = system_staleness_factor(params, m)
        second = jnp.sqrt(jnp.maximum((m - 1) * s_sys, 0.0))
        third = jnp.sqrt(consts.B * (m - 1) / (2.0 * eps) * jnp.sum(delays / p**2))
    else:
        second = third = 0.0
    return 96.0 * consts.L * consts.delta / (n * eps) * (first + second + third)


def wallclock_time(params: NetworkParams, m: int, consts: LearningConstants,
                   logZ: jax.Array | None = None) -> jax.Array:
    """``E0[tau_eps] = K_eps(p, m) / lambda(p, m)`` — Prop. 4 / Prop. 8."""
    if logZ is None:
        logZ = log_normalizing_constants(params, m)
    return round_complexity(params, m, consts, logZ) / throughput(params, m, logZ)
