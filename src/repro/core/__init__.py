"""Queueing-theoretic core of the paper: product-form analysis, complexity
bounds, energy model, and routing/concurrency optimization.

``repro.core.batched`` holds the padded (traced-``m``) variants of the
closed forms that power :func:`batched_concurrency_sweep` — the one-compile
sweep over the whole ``(p, m)`` grid."""
from .batched import (batch_class_log_normalizing_constants,
                      batch_log_normalizing_constants,
                      delay_jacobian_classes, delay_jacobian_padded,
                      energy_complexity_classes,
                      expand_class_matrix,
                      expected_relative_delay_classes,
                      joint_objective_classes,
                      make_round_objective_classes,
                      make_time_objective_classes,
                      round_complexity_classes, second_moment_classes,
                      wallclock_time_classes,
                      energy_complexity_padded,
                      expected_relative_delay_padded,
                      joint_objective_padded, make_energy_objective_padded,
                      make_joint_objective_padded, make_round_objective_padded,
                      make_throughput_objective_padded,
                      make_time_objective_padded, objective_surface,
                      round_complexity_padded, second_moment_matrix_padded,
                      tau_surface, throughput_padded,
                      wallclock_time_padded)
from .buzen import (ClassParams, NetworkParams,
                    class_log_normalizing_constants, classes_from_network,
                    get_backend, log_normalizing_constants, log_Z_ratio,
                    pad_classes, pad_network, set_backend)
from .events import (EventStats, expand_class_stats, simulate_stats,
                     simulate_stats_classes, unpad_stats)
from .complexity import (LearningConstants, eta_max, round_complexity,
                         round_complexity_unbounded, system_staleness_factor,
                         wallclock_time)
from .energy import (PowerProfile, energy_complexity, energy_optimal_routing,
                     energy_per_round, energy_per_round_classes,
                     joint_objective, minimal_energy, per_task_energy)
from .jackson import (analyze, delay_jacobian, expected_relative_delay,
                      mean_total_counts, second_moment_matrix, throughput,
                      throughput_grad)
from .optimize import (OptResult, SweepResult, batched_concurrency_sweep,
                       pareto_sweep, pruned_concurrency_sweep,
                       joint_optimal, make_energy_objective,
                       make_joint_objective, make_round_objective,
                       make_throughput_objective, make_time_objective,
                       max_throughput, optimize_routing, round_optimal,
                       sequential_concurrency_search, time_optimal,
                       time_optimal_classes)

__all__ = [
    "NetworkParams", "log_normalizing_constants", "log_Z_ratio",
    "pad_network", "set_backend", "get_backend",
    "ClassParams", "class_log_normalizing_constants", "classes_from_network",
    "pad_classes",
    "EventStats", "simulate_stats", "unpad_stats",
    "simulate_stats_classes", "expand_class_stats",
    "batch_class_log_normalizing_constants",
    "expected_relative_delay_classes", "round_complexity_classes",
    "wallclock_time_classes", "energy_complexity_classes",
    "joint_objective_classes", "second_moment_classes",
    "delay_jacobian_classes", "expand_class_matrix",
    "make_time_objective_classes", "make_round_objective_classes",
    "energy_per_round_classes", "time_optimal_classes",
    "batch_log_normalizing_constants", "expected_relative_delay_padded",
    "throughput_padded", "round_complexity_padded", "wallclock_time_padded",
    "energy_complexity_padded", "joint_objective_padded",
    "second_moment_matrix_padded", "delay_jacobian_padded",
    "make_round_objective_padded", "make_throughput_objective_padded",
    "make_time_objective_padded", "make_energy_objective_padded",
    "make_joint_objective_padded", "objective_surface", "tau_surface",
    "SweepResult", "batched_concurrency_sweep", "pareto_sweep",
    "pruned_concurrency_sweep",
    "LearningConstants", "round_complexity", "round_complexity_unbounded",
    "eta_max", "system_staleness_factor", "wallclock_time",
    "PowerProfile", "per_task_energy", "energy_per_round", "energy_complexity",
    "energy_optimal_routing", "minimal_energy", "joint_objective",
    "analyze", "expected_relative_delay", "mean_total_counts",
    "second_moment_matrix", "delay_jacobian", "throughput", "throughput_grad",
    "OptResult", "optimize_routing", "sequential_concurrency_search",
    "time_optimal", "round_optimal", "max_throughput", "joint_optimal",
    "make_round_objective", "make_throughput_objective", "make_time_objective",
    "make_energy_objective", "make_joint_objective",
]
