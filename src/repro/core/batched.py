"""Batched (padded, traced-``m``) evaluation of the closed-form pipeline.

The scalar modules (``jackson``, ``complexity``, ``energy``) treat the
population ``m`` as a *static* Python int: series lengths like
``jnp.arange(1, m)`` and branches like ``if m > 1`` bake ``m`` into the
trace, so evaluating a grid of concurrency candidates recompiles once per
``m``.  This module provides the same quantities in a *padded* form — every
series runs to a static bound ``m_max`` and is masked by the traced
population — so a whole ``(p, m)`` grid can be evaluated (and
differentiated) inside one jit trace via ``jax.vmap``:

  * ``batch_log_normalizing_constants`` — ``[B, m_max+1]`` log-space Buzen
    DP for a batch of routing vectors, dispatching to either the ``jnp``
    reference or the batched Pallas TPU kernel
    (``repro.kernels.buzen.buzen_pallas_batched``) behind the backend flag
    of ``repro.core.buzen``;
  * ``*_padded`` — throughput, mean relative delay, ``K_eps``, wall-clock
    and energy complexity, and the rho-scalarized joint objective, each
    accepting a traced ``m`` and a precomputed padded ``logZ`` row;
  * ``make_*_objective_padded`` — factories matching
    ``repro.core.optimize.make_*_objective`` but with the padded call
    signature ``obj(p, m, logZ)`` used by the batched sweep engine;
  * ``tau_surface`` / ``objective_surface`` — one-jit evaluation of dense
    ``(m, p)`` grids (Figure 2 / Figure 8 style sweeps).

All padded quantities agree with their static counterparts to float64
round-off; ``tests/test_batched_optimizer.py`` cross-checks both paths.
"""
from __future__ import annotations
# contract: padded-n — reductions here are on the bitwise padding contract

from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.scipy.special import logsumexp

from . import numerics  # noqa: F401  (enables x64)
from .buzen import NetworkParams, get_backend, log_normalizing_constants
from .complexity import LearningConstants
from .energy import PowerProfile, energy_per_round
from .jackson import _log_geom_sum, _lz  # traced-idx/K safe helpers
from .numerics import NEG_INF, seqsum
from .optimize import _with_p  # shared routing-replace helper


# ---------------------------------------------------------------------------
# padded log-Z helpers
# ---------------------------------------------------------------------------

def batch_log_normalizing_constants(
    params: NetworkParams,
    p_batch: jax.Array,
    m_max: int,
    *,
    backend: Optional[str] = None,
) -> jax.Array:
    """``log Z_{n, 0..m_max}`` for every routing row of ``p_batch`` [B, n].

    Backend ``"jnp"`` vmaps the float64 reference DP of
    :func:`repro.core.buzen.log_normalizing_constants`; ``"pallas"`` runs the
    batched ``B x stations`` Pallas kernel (float32 forward, reference VJP —
    see ``repro.kernels.buzen``).  ``None`` defers to the process-wide flag
    (:func:`repro.core.buzen.set_backend` / ``REPRO_BUZEN_BACKEND``).
    """
    backend = get_backend() if backend is None else backend
    if backend == "pallas":
        from ..kernels.buzen import buzen_log_Z_batched

        log_rho = jnp.log(p_batch) - jnp.log(params.mu_c)[None, :]
        gamma = p_batch * (1.0 / params.mu_d + 1.0 / params.mu_u)[None, :]
        log_gamma_total = jnp.log(seqsum(gamma, axis=-1))
        if params.mu_cs is not None:
            # the CS single-server station folds in as one extra column
            log_load_cs = (jnp.log(seqsum(p_batch, axis=-1))
                           - jnp.log(params.mu_cs))
            log_rho = jnp.concatenate([log_rho, log_load_cs[:, None]], axis=-1)
        return buzen_log_Z_batched(log_rho, log_gamma_total, m_max)
    if backend != "jnp":
        raise ValueError(f"unknown buzen backend: {backend}")
    return jax.vmap(
        lambda p: log_normalizing_constants(params._replace(p=p), m_max,
                                            backend="jnp"))(p_batch)


def _padded_series_vs_Z(log_load: jax.Array, logZ: jax.Array, pop: jax.Array,
                        shift: int, m_max: int,
                        weights_log: Optional[jax.Array] = None) -> jax.Array:
    """Padded analogue of ``jackson._series_vs_Z`` for traced ``pop``.

    ``log sum_{k=1}^{pop-shift+1} w_k load^k Z[pop-shift+1-k] / Z[pop]``
    with the series padded to the static length ``m_max`` and masked by
    ``pop``; ``weights_log[k-1]`` optionally adds ``log w_k`` (e.g.
    ``log(2k-1)`` for the second-moment diagonal).
    """
    k = jnp.arange(1, m_max + 1)
    idx = pop - shift + 1 - k
    zterm = _lz(logZ, idx) - _lz(logZ, pop)
    terms = jnp.asarray(log_load)[..., None] * k + zterm
    if weights_log is not None:
        terms = terms + weights_log
    # contract: allow(raw-reduction): logsumexp over the k = 1..m_max convolution axis — compile-time length, never client/class padded
    return logsumexp(jnp.where(idx >= 0, terms, NEG_INF), axis=-1)


# ---------------------------------------------------------------------------
# padded closed forms (Thm 2 / Prop 4 / Thm 3 / Prop 5)
# ---------------------------------------------------------------------------

def mean_total_counts_padded(params: NetworkParams, logZ: jax.Array,
                             pop: jax.Array, m_max: int) -> jax.Array:
    """``E[sum_s X_i^s]`` per client at traced population ``pop``.

    Identical to ``jackson.mean_total_counts`` but with the series masked to
    ``pop`` rather than sized by it; at ``pop <= 0`` every term masks to
    zero, matching the static early-return.
    """
    comp = jnp.exp(_padded_series_vs_Z(params.log_rho, logZ, pop, 1, m_max))
    is_part = params.gamma * jnp.exp(_lz(logZ, pop - 1) - _lz(logZ, pop))
    total = comp + is_part
    if params.mu_cs is not None:
        log_load_cs = jnp.log(seqsum(params.p)) - jnp.log(params.mu_cs)
        cs_total = jnp.exp(_padded_series_vs_Z(log_load_cs, logZ, pop, 1,
                                               m_max))
        total = total + params.p / seqsum(params.p) * cs_total
    return total


def expected_relative_delay_padded(params: NetworkParams, m: jax.Array,
                                   logZ: jax.Array, m_max: int) -> jax.Array:
    """``E0[D_i]`` (Thm 2 Eq 3/5) for a traced concurrency ``m``."""
    return mean_total_counts_padded(params, logZ, m - 1, m_max)


def throughput_padded(logZ: jax.Array, m: jax.Array) -> jax.Array:
    """``lambda(p, m) = Z_{n,m-1} / Z_{n,m}`` for traced ``m``."""
    return jnp.exp(_lz(logZ, m - 1) - _lz(logZ, m))


def round_complexity_padded(params: NetworkParams, m: jax.Array,
                            consts: LearningConstants, logZ: jax.Array,
                            m_max: int) -> jax.Array:
    """``K_eps(p, m)`` (Thm 3 Eq 9) for traced ``m``.

    The staleness term vanishes identically at ``m = 1``; the double
    ``where`` keeps both the value and the gradient finite there (a naive
    ``sqrt(where(...))`` has a NaN cotangent at 0).

    Under the traced-``n`` convention (``params.n_active`` set) the
    per-client sums are masked to the real population — padded rows have
    ``p = 0``, whose ``1/p`` terms must not poison the sums; for real rows
    the masking is bitwise-neutral (trailing exact zeros).  The division
    runs on a pinned-safe ``p`` (padded entries replaced by 1) so the
    padded rows stay inf/NaN-free in the *primal* too — a ``where`` after
    an inf would leak a NaN cotangent into every ``p`` entry under
    ``jax.grad`` (the same trap the ``m = 1`` double-``where`` below
    guards).
    """
    n = params.active_count
    p = params.p
    mask = params.active_mask
    eps = consts.eps
    delays = expected_relative_delay_padded(params, m, logZ, m_max)
    if mask is not None:
        p_safe = jnp.where(mask, p, 1.0)
        inv_np = jnp.where(mask, 1.0 / (n * p_safe), 0.0)
        stale_terms = jnp.where(mask, delays / p_safe**2, 0.0)
    else:
        inv_np = 1.0 / (n * p)
        stale_terms = delays / p**2
    first = (4.0 + consts.B / eps) * seqsum(inv_np)
    staleness = seqsum(stale_terms)
    raw = consts.C * (m - 1.0) / eps * staleness
    safe = jnp.where(m > 1, raw, 1.0)
    second = jnp.where(m > 1, jnp.sqrt(safe), 0.0)
    return 24.0 * consts.L * consts.delta / (n * eps) * (first + second)


def wallclock_time_padded(params: NetworkParams, m: jax.Array,
                          consts: LearningConstants, logZ: jax.Array,
                          m_max: int) -> jax.Array:
    """``E0[tau_eps] = K_eps / lambda`` (Prop. 4/8) for traced ``m``."""
    return (round_complexity_padded(params, m, consts, logZ, m_max)
            / throughput_padded(logZ, m))


def energy_complexity_padded(params: NetworkParams, m: jax.Array,
                             consts: LearningConstants, power: PowerProfile,
                             logZ: jax.Array, m_max: int) -> jax.Array:
    """``E0[E_eps]`` (Prop. 5/9) for traced ``m``."""
    return (round_complexity_padded(params, m, consts, logZ, m_max)
            * energy_per_round(params, power))


def joint_objective_padded(params: NetworkParams, m: jax.Array,
                           consts: LearningConstants, power: PowerProfile,
                           rho: jax.Array, tau_star: jax.Array,
                           e_star: jax.Array, logZ: jax.Array,
                           m_max: int) -> jax.Array:
    """Normalized rho-scalarization (Eq. 18); ``rho`` may be traced/batched."""
    k_eps = round_complexity_padded(params, m, consts, logZ, m_max)
    tau = k_eps / throughput_padded(logZ, m)
    en = k_eps * energy_per_round(params, power)
    return rho * en / e_star + (1.0 - rho) * tau / tau_star


# ---------------------------------------------------------------------------
# padded second moments / delay Jacobian (Thm 2 Eq 6/4; Thm 7 Eq 24/22)
# ---------------------------------------------------------------------------

def second_moment_matrix_padded(params: NetworkParams, m: jax.Array,
                                logZ: jax.Array, m_max: int) -> jax.Array:
    """``E[S_i S_j]`` at population ``m - 1`` for traced ``m`` (and, under
    the traced-``n`` convention, per-row real populations).

    The padded analogue of :func:`repro.core.jackson.second_moment_matrix`:
    every series runs to the static bound ``m_max`` and is masked by the
    traced population, so a whole ``(p, m)`` batch evaluates (and
    differentiates) in one trace — closing the "batched second moments /
    delay Jacobians" ROADMAP item.  Values agree bitwise with the static
    form for real clients; padded rows/columns are exactly zero.
    """
    n = params.n
    log_rho = params.log_rho
    gamma = params.gamma
    mask = params.active_mask
    lr_safe = log_rho if mask is None else jnp.where(mask, log_rho, 0.0)
    pop = m - 1
    pop_c = jnp.clip(pop, 1)  # guard: at pop <= 0 everything masks to zero

    # ---- alpha (queue-queue) ----------------------------------------------
    # i == j: sum_k (2k-1) rho_i^k Z[pop-k]/Z[pop]
    wlog = jnp.log(2.0 * jnp.arange(1, m_max + 1) - 1.0)
    alpha_diag = jnp.exp(_padded_series_vs_Z(log_rho, logZ, pop_c, 1, m_max,
                                             weights_log=wlog))

    # i != j: sum_{s=2}^{pop} Z[pop-s]/Z[pop] c_ij(s),
    # c_ij(s) = exp(s lr_j) * geom_sum(lr_i - lr_j, s - 1)
    if m_max >= 2:
        s = jnp.arange(2, m_max + 1)  # [S] static; masked by s <= pop
        d = lr_safe[:, None] - lr_safe[None, :]  # [n, n]; -inf-free
        lgs = jax.vmap(lambda K: _log_geom_sum(d, K))(s - 1)  # [S, n, n]
        log_c = s[:, None, None] * lr_safe[None, None, :] + lgs
        zlog = (_lz(logZ, pop_c - s) - _lz(logZ, pop_c))[:, None, None]
        valid = (s <= pop_c)[:, None, None]
        if mask is not None:
            valid = valid & (mask[:, None] & mask[None, :])[None]
        # contract: allow(raw-reduction): logsumexp over the s = 2..m_max axis — compile-time length, never client/class padded
        alpha_off = jnp.exp(logsumexp(
            jnp.where(valid, log_c + zlog, NEG_INF), axis=0))
    else:
        alpha_off = jnp.zeros((n, n))
    eye = jnp.eye(n, dtype=bool)
    alpha = jnp.where(eye, alpha_diag[:, None], alpha_off)

    # ---- beta_{i,2} (queue-IS cross terms) --------------------------------
    beta2 = jnp.exp(_padded_series_vs_Z(log_rho, logZ, pop_c, 2, m_max))

    # ---- psi (IS-IS) -------------------------------------------------------
    z3 = jnp.exp(_lz(logZ, pop_c - 2) - _lz(logZ, pop_c))
    z2 = jnp.exp(_lz(logZ, pop_c - 1) - _lz(logZ, pop_c))
    psi = gamma[:, None] * gamma[None, :] * z3 + jnp.diag(gamma) * z2

    second = (alpha + beta2[:, None] * gamma[None, :]
              + beta2[None, :] * gamma[:, None] + psi)

    if params.mu_cs is not None:
        second = second + _cs_second_moment_terms_padded(params, logZ, pop_c,
                                                         m_max)
    return jnp.where(pop > 0, second, 0.0)


def _cs_second_moment_terms_padded(params: NetworkParams, logZ: jax.Array,
                                   pop: jax.Array, m_max: int) -> jax.Array:
    """Padded Theorem 7 Eq (24) CS terms (``pop`` traced, ``>= 1``)."""
    n = params.n
    p = params.p
    psum = seqsum(p)
    gamma = params.gamma
    log_rho = params.log_rho
    log_load_cs = jnp.log(psum) - jnp.log(params.mu_cs)

    beta_cs2 = jnp.exp(_padded_series_vs_Z(log_load_cs, logZ, pop, 2, m_max))

    k = jnp.arange(1, m_max + 1)
    base = jnp.where(k <= pop,
                     k * log_load_cs + _lz(logZ, pop - k) - _lz(logZ, pop),
                     NEG_INF)
    # contract: allow(raw-reduction): logsumexp over the k = 1..m_max axis — compile-time length, never client/class padded
    s0 = jnp.exp(logsumexp(base))
    s1_terms = jnp.where(k > 1,
                         base + jnp.log(jnp.maximum(k - 1.0, 1e-300)),
                         NEG_INF)
    # contract: allow(raw-reduction): logsumexp over the k = 1..m_max axis — compile-time length, never client/class padded
    s1 = jnp.exp(logsumexp(s1_terms))
    pi = p / psum
    alpha_cs = (pi[:, None] * pi[None, :]) * 2.0 * s1 * psum * psum
    alpha_cs = alpha_cs + jnp.diag(pi * psum) * s0

    # alpha_{CS,i} = sum_{k,l >= 1, k+l <= pop} load_cs^k rho_i^l
    #                Z[pop-k-l]/Z[pop]
    if m_max >= 2:
        kk = jnp.arange(1, m_max)
        ll = jnp.arange(1, m_max)
        # padded clients have log_rho = -inf: their alpha_{CS,i} is 0
        grid = (kk[:, None] * log_load_cs
                + ll[None, :] * log_rho[:, None, None]
                + _lz(logZ, pop - kk[:, None] - ll[None, :]) - _lz(logZ, pop))
        valid = (kk[:, None] + ll[None, :]) <= pop
        grid = jnp.where(valid[None, :, :], grid, NEG_INF)
        # contract: allow(raw-reduction): logsumexp over the (kk, ll) m-grid axes — compile-time lengths, never client/class padded
        alpha_cs_i = jnp.exp(logsumexp(grid, axis=(1, 2)))
    else:
        alpha_cs_i = jnp.zeros(n)

    extra = (alpha_cs
             + beta_cs2 * (pi[:, None] * gamma[None, :]
                           + pi[None, :] * gamma[:, None]) * psum
             + pi[:, None] * alpha_cs_i[None, :] * psum
             + pi[None, :] * alpha_cs_i[:, None] * psum)
    return extra


def delay_jacobian_padded(params: NetworkParams, m: jax.Array,
                          logZ: jax.Array, m_max: int) -> jax.Array:
    """``J[i, j] = d E0[D_i] / d p_j`` for traced ``m`` (covariance
    identity, Thm 2 Eq 4 / Thm 7 Eq 22); padded columns (``p_j = 0``) are
    masked to zero instead of dividing by zero."""
    mean = mean_total_counts_padded(params, logZ, m - 1, m_max)
    second = second_moment_matrix_padded(params, m, logZ, m_max)
    cov = second - mean[:, None] * mean[None, :]
    mask = params.active_mask
    if mask is None:
        return cov / params.p[None, :]
    p_safe = jnp.where(mask, params.p, 1.0)  # keep padded 0/0 out of the primal
    return jnp.where(mask[None, :] & mask[:, None],
                     cov / p_safe[None, :], 0.0)


# ---------------------------------------------------------------------------
# class-space closed forms: O(#classes) per evaluation (ClassParams)
# ---------------------------------------------------------------------------
#
# Every form below is the padded per-client formula evaluated on class
# representatives: the product-form marginals depend on a client only
# through its (p, mu_c, mu_d, mu_u) profile, so one member of each class
# stands for all ``count`` of them and population-level reductions weight
# by ``count`` (sequentially — padded count-0 classes add exact zeros).
# Agrees with the ``*_padded`` forms on ``classes.expand()`` to f64
# roundoff; **bitwise** invariant to class padding (``pad_classes``).


def batch_class_log_normalizing_constants(
    classes, p_batch: jax.Array, m_max: int, *,
    backend: Optional[str] = None,
) -> jax.Array:
    """``log Z_{n, 0..m_max}`` for every per-member routing row ``[B, C]``.

    The class analogue of :func:`batch_log_normalizing_constants` —
    O(C m^2) per row via the negative-binomial class DP.
    """
    from .buzen import class_log_normalizing_constants

    backend = get_backend() if backend is None else backend
    if backend == "pallas":
        from ..kernels.buzen import buzen_classes_log_Z_batched

        cnt = classes.count.astype(classes.p.dtype)
        log_rho = jnp.log(p_batch) - jnp.log(classes.mu_c)[None, :]
        gamma = p_batch * (1.0 / classes.mu_d + 1.0 / classes.mu_u)[None, :]
        log_gamma_total = jnp.log(seqsum(cnt[None, :] * gamma, axis=-1))
        counts = jnp.broadcast_to(cnt[None, :], p_batch.shape)
        if classes.mu_cs is not None:
            log_load_cs = (jnp.log(seqsum(cnt[None, :] * p_batch, axis=-1))
                           - jnp.log(classes.mu_cs))
            log_rho = jnp.concatenate([log_rho, log_load_cs[:, None]],
                                      axis=-1)
            counts = jnp.concatenate(
                [counts, jnp.ones((p_batch.shape[0], 1), counts.dtype)],
                axis=-1)
        return buzen_classes_log_Z_batched(log_rho, counts,
                                           log_gamma_total, m_max)
    if backend != "jnp":
        raise ValueError(f"unknown buzen backend: {backend}")
    return jax.vmap(
        lambda p: class_log_normalizing_constants(classes._replace(p=p),
                                                  m_max, backend="jnp")
    )(p_batch)


def mean_member_counts_classes(classes, logZ: jax.Array, pop: jax.Array,
                               m_max: int) -> jax.Array:
    """``E[sum_s X_i^s]`` for ONE member of each class at population ``pop``.

    The per-client formula of :func:`mean_total_counts_padded` evaluated on
    class representatives (``logZ`` from the class DP): all members of a
    class share the value.
    """
    comp = jnp.exp(_padded_series_vs_Z(classes.log_rho, logZ, pop, 1, m_max))
    is_part = classes.gamma * jnp.exp(_lz(logZ, pop - 1) - _lz(logZ, pop))
    total = comp + is_part
    if classes.mu_cs is not None:
        msum = seqsum(classes.mass)
        log_load_cs = jnp.log(msum) - jnp.log(classes.mu_cs)
        cs_total = jnp.exp(_padded_series_vs_Z(log_load_cs, logZ, pop, 1,
                                               m_max))
        total = total + classes.p / msum * cs_total
    return total


def expected_relative_delay_classes(classes, m: jax.Array, logZ: jax.Array,
                                    m_max: int) -> jax.Array:
    """``E0[D_i]`` (Thm 2 Eq 3/5) per class member for traced ``m``."""
    return mean_member_counts_classes(classes, logZ, m - 1, m_max)


def round_complexity_classes(classes, m: jax.Array,
                             consts: LearningConstants, logZ: jax.Array,
                             m_max: int) -> jax.Array:
    """``K_eps(p, m)`` (Thm 3 Eq 9) with class-weighted population sums.

    ``sum_i`` over clients becomes ``sum_c count_c * (member value)``;
    padded classes (count 0) contribute exact zeros through pinned-safe
    divisions, mirroring the traced-``n`` masking of
    :func:`round_complexity_padded`.
    """
    cnt = classes.count.astype(classes.p.dtype)
    n = classes.n_total.astype(classes.p.dtype)
    mask = classes.count > 0
    eps = consts.eps
    delays = expected_relative_delay_classes(classes, m, logZ, m_max)
    p_safe = jnp.where(mask, classes.p, 1.0)
    inv_np = jnp.where(mask, cnt / (n * p_safe), 0.0)
    stale_terms = jnp.where(mask, cnt * delays / p_safe**2, 0.0)
    first = (4.0 + consts.B / eps) * seqsum(inv_np)
    staleness = seqsum(stale_terms)
    raw = consts.C * (m - 1.0) / eps * staleness
    safe = jnp.where(m > 1, raw, 1.0)
    second = jnp.where(m > 1, jnp.sqrt(safe), 0.0)
    return 24.0 * consts.L * consts.delta / (n * eps) * (first + second)


def wallclock_time_classes(classes, m: jax.Array, consts: LearningConstants,
                           logZ: jax.Array, m_max: int) -> jax.Array:
    """``E0[tau_eps] = K_eps / lambda`` (Prop. 4/8), class-space."""
    return (round_complexity_classes(classes, m, consts, logZ, m_max)
            / throughput_padded(logZ, m))


def energy_complexity_classes(classes, m: jax.Array,
                              consts: LearningConstants, power: PowerProfile,
                              logZ: jax.Array, m_max: int) -> jax.Array:
    """``E0[E_eps]`` (Prop. 5/9), class-space (``power`` holds per-class
    arrays)."""
    from .energy import energy_per_round_classes

    return (round_complexity_classes(classes, m, consts, logZ, m_max)
            * energy_per_round_classes(classes, power))


def joint_objective_classes(classes, m: jax.Array,
                            consts: LearningConstants, power: PowerProfile,
                            rho: jax.Array, tau_star: jax.Array,
                            e_star: jax.Array, logZ: jax.Array,
                            m_max: int) -> jax.Array:
    """Normalized rho-scalarization (Eq. 18), class-space."""
    from .energy import energy_per_round_classes

    k_eps = round_complexity_classes(classes, m, consts, logZ, m_max)
    tau = k_eps / throughput_padded(logZ, m)
    en = k_eps * energy_per_round_classes(classes, power)
    return rho * en / e_star + (1.0 - rho) * tau / tau_star


def second_moment_classes(classes, m: jax.Array, logZ: jax.Array,
                          m_max: int):
    """Member-representative second moments ``(cross [C, C], same [C])``.

    ``cross[a, b] = E[S_i S_j]`` for a member ``i`` of class ``a`` and a
    *distinct* member ``j`` of class ``b`` (the ``a == b`` diagonal is the
    distinct-members-of-one-class value, meaningful when ``count >= 2`` —
    ``_log_geom_sum`` is exact at equal loads); ``same[c] = E[S_i^2]``.
    Together these are the full O(C^2) compression of the per-client
    ``[n, n]`` matrix (:func:`expand_class_matrix` unrolls for the oracle).
    """
    log_rho = classes.log_rho
    gamma = classes.gamma
    mask = classes.count > 0
    lr_safe = jnp.where(mask, log_rho, 0.0)
    pop = m - 1
    pop_c = jnp.clip(pop, 1)

    # ---- alpha (queue-queue) ----------------------------------------------
    wlog = jnp.log(2.0 * jnp.arange(1, m_max + 1) - 1.0)
    alpha_same = jnp.exp(_padded_series_vs_Z(log_rho, logZ, pop_c, 1, m_max,
                                             weights_log=wlog))
    if m_max >= 2:
        s = jnp.arange(2, m_max + 1)
        d = lr_safe[:, None] - lr_safe[None, :]
        lgs = jax.vmap(lambda K: _log_geom_sum(d, K))(s - 1)
        log_c = s[:, None, None] * lr_safe[None, None, :] + lgs
        zlog = (_lz(logZ, pop_c - s) - _lz(logZ, pop_c))[:, None, None]
        valid = ((s <= pop_c)[:, None, None]
                 & (mask[:, None] & mask[None, :])[None])
        # contract: allow(raw-reduction): logsumexp over the s = 2..m_max axis — compile-time length, never client/class padded
        alpha_cross = jnp.exp(logsumexp(
            jnp.where(valid, log_c + zlog, NEG_INF), axis=0))
    else:
        alpha_cross = jnp.zeros((classes.C, classes.C))

    # ---- beta / psi --------------------------------------------------------
    beta2 = jnp.exp(_padded_series_vs_Z(log_rho, logZ, pop_c, 2, m_max))
    z3 = jnp.exp(_lz(logZ, pop_c - 2) - _lz(logZ, pop_c))
    z2 = jnp.exp(_lz(logZ, pop_c - 1) - _lz(logZ, pop_c))

    cross = (alpha_cross + beta2[:, None] * gamma[None, :]
             + beta2[None, :] * gamma[:, None]
             + gamma[:, None] * gamma[None, :] * z3)
    same = alpha_same + 2.0 * beta2 * gamma + gamma**2 * z3 + gamma * z2

    if classes.mu_cs is not None:
        cross_cs, same_cs = _cs_second_moment_terms_classes(
            classes, logZ, pop_c, m_max)
        cross = cross + cross_cs
        same = same + same_cs
    return (jnp.where(pop > 0, cross, 0.0), jnp.where(pop > 0, same, 0.0))


def _cs_second_moment_terms_classes(classes, logZ: jax.Array,
                                    pop: jax.Array, m_max: int):
    """Theorem 7 Eq (24) CS terms on class representatives
    (``(cross, same)`` extras matching :func:`second_moment_classes`)."""
    p = classes.p
    psum = seqsum(classes.mass)
    gamma = classes.gamma
    log_rho = classes.log_rho
    log_load_cs = jnp.log(psum) - jnp.log(classes.mu_cs)

    beta_cs2 = jnp.exp(_padded_series_vs_Z(log_load_cs, logZ, pop, 2, m_max))

    k = jnp.arange(1, m_max + 1)
    base = jnp.where(k <= pop,
                     k * log_load_cs + _lz(logZ, pop - k) - _lz(logZ, pop),
                     NEG_INF)
    # contract: allow(raw-reduction): logsumexp over the k = 1..m_max axis — compile-time length, never client/class padded
    s0 = jnp.exp(logsumexp(base))
    s1_terms = jnp.where(k > 1,
                         base + jnp.log(jnp.maximum(k - 1.0, 1e-300)),
                         NEG_INF)
    # contract: allow(raw-reduction): logsumexp over the k = 1..m_max axis — compile-time length, never client/class padded
    s1 = jnp.exp(logsumexp(s1_terms))
    pi = p / psum

    if m_max >= 2:
        kk = jnp.arange(1, m_max)
        ll = jnp.arange(1, m_max)
        grid = (kk[:, None] * log_load_cs
                + ll[None, :] * log_rho[:, None, None]
                + _lz(logZ, pop - kk[:, None] - ll[None, :]) - _lz(logZ, pop))
        valid = (kk[:, None] + ll[None, :]) <= pop
        grid = jnp.where(valid[None, :, :], grid, NEG_INF)
        # contract: allow(raw-reduction): logsumexp over the (kk, ll) m-grid axes — compile-time lengths, never client/class padded
        alpha_cs_i = jnp.exp(logsumexp(grid, axis=(1, 2)))
    else:
        alpha_cs_i = jnp.zeros(classes.C)

    pairs = pi[:, None] * pi[None, :] * 2.0 * s1 * psum * psum
    betas = beta_cs2 * (pi[:, None] * gamma[None, :]
                        + pi[None, :] * gamma[:, None]) * psum
    alphas = (pi[:, None] * alpha_cs_i[None, :] * psum
              + pi[None, :] * alpha_cs_i[:, None] * psum)
    cross = pairs + betas + alphas
    same = (pi**2 * 2.0 * s1 * psum * psum + pi * psum * s0
            + 2.0 * beta_cs2 * pi * gamma * psum
            + 2.0 * pi * alpha_cs_i * psum)
    return cross, same


def delay_jacobian_classes(classes, m: jax.Array, logZ: jax.Array,
                           m_max: int):
    """Class-compressed delay Jacobian ``(J_cross [C, C], J_same [C])``.

    ``J_cross[a, b] = d E0[D_i] / d p_j`` for a member ``i`` of class ``a``
    and a distinct member ``j`` of class ``b`` (covariance identity, Thm 2
    Eq 4 / Thm 7 Eq 22); ``J_same[c]`` is the own-mass sensitivity.
    Padded columns mask to zero as in :func:`delay_jacobian_padded`.
    """
    mean = mean_member_counts_classes(classes, logZ, m - 1, m_max)
    cross, same = second_moment_classes(classes, m, logZ, m_max)
    cov_cross = cross - mean[:, None] * mean[None, :]
    cov_same = same - mean**2
    mask = classes.count > 0
    p_safe = jnp.where(mask, classes.p, 1.0)
    j_cross = jnp.where(mask[:, None] & mask[None, :],
                        cov_cross / p_safe[None, :], 0.0)
    j_same = jnp.where(mask, cov_same / p_safe, 0.0)
    return j_cross, j_same


def expand_class_matrix(cross, same, count) -> jax.Array:
    """Unroll class-pair values to the per-client ``[n, n]`` matrix
    (host-side oracle helper: diagonal from ``same``, off-diagonal — both
    across and within classes — from ``cross``)."""
    import numpy as np

    reps = np.asarray(count).astype(int)
    idx = np.repeat(np.arange(len(reps)), reps)
    mat = np.asarray(cross)[np.ix_(idx, idx)].copy()
    np.fill_diagonal(mat, np.asarray(same)[idx])
    return jnp.asarray(mat)


def make_time_objective_classes(classes, consts: LearningConstants,
                                m_max: int):
    """Class-space wall-clock objective with the padded sweep protocol
    ``obj(p, m, logZ)`` (``p`` per-member, ``logZ`` from the class DP)."""
    def obj(p, m, logZ):
        return wallclock_time_classes(_with_p(classes, p), m, consts, logZ,
                                      m_max)
    obj.m_max = m_max  # consumed by the sweep-side padding guard
    return obj


def make_round_objective_classes(classes, consts: LearningConstants,
                                 m_max: int):
    """Class-space ``K_eps`` objective (padded sweep protocol)."""
    def obj(p, m, logZ):
        return round_complexity_classes(_with_p(classes, p), m, consts, logZ,
                                        m_max)
    obj.m_max = m_max  # consumed by the sweep-side padding guard
    return obj


# ---------------------------------------------------------------------------
# padded objective factories (protocol: obj(p, m, logZ) -> scalar)
# ---------------------------------------------------------------------------


def make_round_objective_padded(params: NetworkParams,
                                consts: LearningConstants, m_max: int):
    def obj(p, m, logZ):
        return round_complexity_padded(_with_p(params, p), m, consts, logZ,
                                       m_max)
    obj.m_max = m_max  # consumed by the sweep-side padding guard
    return obj


def make_throughput_objective_padded(params: NetworkParams, m_max: int):
    def obj(p, m, logZ):
        return -throughput_padded(logZ, m)
    obj.m_max = m_max  # consumed by the sweep-side padding guard
    return obj


def make_time_objective_padded(params: NetworkParams,
                               consts: LearningConstants, m_max: int):
    def obj(p, m, logZ):
        return wallclock_time_padded(_with_p(params, p), m, consts, logZ,
                                     m_max)
    obj.m_max = m_max  # consumed by the sweep-side padding guard
    return obj


def make_energy_objective_padded(params: NetworkParams,
                                 consts: LearningConstants,
                                 power: PowerProfile, m_max: int):
    def obj(p, m, logZ):
        return energy_complexity_padded(_with_p(params, p), m, consts, power,
                                        logZ, m_max)
    obj.m_max = m_max  # consumed by the sweep-side padding guard
    return obj


def make_joint_objective_padded(params: NetworkParams,
                                consts: LearningConstants,
                                power: PowerProfile, tau_star, e_star,
                                m_max: int):
    """Joint objective with ``rho`` as the per-row context (see
    ``batched_concurrency_sweep(ctx=...)``) so one sweep traces the whole
    Pareto frontier."""
    def obj(p, m, logZ, rho):
        return joint_objective_padded(_with_p(params, p), m, consts, power,
                                      rho, tau_star, e_star, logZ, m_max)
    obj.m_max = m_max  # consumed by the sweep-side padding guard
    return obj


# ---------------------------------------------------------------------------
# dense surface evaluation (Figure 2 / Figure 8 grids)
# ---------------------------------------------------------------------------

def objective_surface(objective: Callable, params: NetworkParams,
                      p_grid: jax.Array, m_grid: jax.Array,
                      *, m_max: Optional[int] = None,
                      backend: Optional[str] = None) -> jax.Array:
    """Evaluate a padded objective on aligned grids ``p_grid`` [B, n] and
    ``m_grid`` [B] as ONE jitted batch: a single compile covers the whole
    grid (the jit is per-call — its cache dies with the closure — so
    repeated calls retrace but never leak cache entries)."""
    m_grid = jnp.asarray(m_grid)
    m_max = int(jnp.max(m_grid)) if m_max is None else m_max
    obj_pad = getattr(objective, "m_max", None)
    if obj_pad is not None and obj_pad != m_max:
        raise ValueError(
            f"objective was built with m_max={obj_pad} but the surface pads "
            f"logZ to m_max={m_max}; the paddings must match")
    backend = get_backend() if backend is None else backend

    @jax.jit
    def impl(params, p_grid, m_grid):
        logZ = batch_log_normalizing_constants(params, p_grid, m_max,
                                               backend=backend)
        return jax.vmap(objective)(p_grid, m_grid, logZ)

    return impl(params, jnp.asarray(p_grid), m_grid)


def tau_surface(params: NetworkParams, consts: LearningConstants,
                ms, p_rows: jax.Array,
                *, backend: Optional[str] = None) -> jax.Array:
    """``E0[tau_eps]`` on the outer grid ``ms x p_rows`` — the Figure 2
    surface — evaluated in one jitted batch.

    ``ms`` is a 1-D int array of concurrency candidates, ``p_rows`` is
    ``[P, n]`` routing vectors; returns ``[len(ms), P]``.
    """
    ms = jnp.asarray(ms)
    p_rows = jnp.asarray(p_rows)
    M, P = ms.shape[0], p_rows.shape[0]
    m_flat = jnp.repeat(ms, P)
    p_flat = jnp.tile(p_rows, (M, 1))
    obj = make_time_objective_padded(params, consts, int(jnp.max(ms)))
    vals = objective_surface(obj, params, p_flat, m_flat,
                             m_max=int(jnp.max(ms)), backend=backend)
    return vals.reshape(M, P)
