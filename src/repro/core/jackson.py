"""Closed-form stationary analysis of the Generalized AsyncSGD network.

Implements, in log space:

  * Theorem 2  — mean relative delay ``E0[D_i]`` (Eqs. 3/5), pairwise second
    moments (Eq. 6) and the routing Jacobian ``dE0[D_i]/dp_j`` (Eq. 4);
  * Proposition 4 — update throughput ``lambda(p, m)`` (Eq. 11) and its
    gradient (Eq. 12);
  * Section 7 (CS-side buffer) — Theorem 7 (Eqs. 21–24) and Proposition 8
    (Eqs. 26–27); selected automatically when ``params.mu_cs`` is set.

Population arguments ``m`` are static Python ints; everything else is
traceable, so all quantities may also be differentiated with ``jax.grad``
(used in tests to cross-validate the closed-form Jacobians).  The padded
traced-``m`` (and traced-``n``) forms of every quantity here — including
the second moments and the delay Jacobian — live in ``repro.core.batched``
(``*_padded``); this module stays the static reference they are
cross-checked against.

Conventions: ``Z[k] = 0`` for ``k < 0``; the embedded chain ``X_k`` lives at
population ``m - 1`` (Prop. 1), hence most ratios are against ``Z_{n,m-1}``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.scipy.special import logsumexp

from . import numerics  # noqa: F401
from .buzen import NetworkParams, log_normalizing_constants
from .numerics import NEG_INF


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _lz(logZ: jax.Array, idx: jax.Array) -> jax.Array:
    """``log Z[idx]`` with ``Z[idx < 0] = 0`` (log -> NEG_INF). Vectorized."""
    idx = jnp.asarray(idx)
    return jnp.where(idx >= 0, logZ[jnp.clip(idx, 0)], NEG_INF)


def _log_geom_sum(d: jax.Array, K: jax.Array) -> jax.Array:
    """``log sum_{k=1}^{K} exp(k d)`` for integer ``K >= 0`` (K=0 -> -inf).

    Stable for any sign/magnitude of ``d``; at ``|d| ~ 0`` returns ``log K``.
    """
    K = jnp.asarray(K, dtype=jnp.float64)
    small = jnp.abs(d) < 1e-12
    d_safe = jnp.where(small, 1.0, d)  # avoid 0/0 in untaken branch

    def log1mexp(a):  # log(1 - e^{-a}) for a > 0
        a = jnp.maximum(a, 1e-300)
        return jnp.where(a < 0.693, jnp.log(-jnp.expm1(-a)), jnp.log1p(-jnp.exp(-a)))

    neg = d_safe + log1mexp(K * jnp.abs(d_safe)) - log1mexp(jnp.abs(d_safe))
    pos = K * d_safe + log1mexp(K * jnp.abs(d_safe)) - log1mexp(jnp.abs(d_safe))
    out = jnp.where(d_safe > 0, pos, neg)
    out = jnp.where(small, jnp.log(jnp.maximum(K, 1e-300)), out)
    return jnp.where(K >= 1, out, NEG_INF)


def _series_vs_Z(log_load: jax.Array, logZ: jax.Array, pop: int, shift: int,
                 weights_log: jax.Array | None = None) -> jax.Array:
    """``sum_{k=1}^{pop-shift+1} w_k load^k Z[pop - shift + 1 - k] / Z[pop]``.

    Generic building block: with ``shift=1`` this is
    ``sum_k load^k Z[pop-k]/Z[pop]`` (mean counts / beta_{i,1}); with
    ``shift=2`` it is ``beta_{i,2}``-style.  ``log_load`` has shape [n] (or
    scalar); returns same shape.  ``weights_log[k-1]`` optionally adds
    ``log w_k`` (e.g. ``log(2k-1)`` for alpha_ii).
    """
    top = pop - shift + 1  # largest k with Z index >= 0
    if top < 1:
        return jnp.full(jnp.shape(log_load), NEG_INF)
    k = jnp.arange(1, top + 1)
    zterm = _lz(logZ, pop - shift + 1 - k) - logZ[pop]
    terms = jnp.asarray(log_load)[..., None] * k + zterm
    if weights_log is not None:
        terms = terms + weights_log[: top]
    return logsumexp(terms, axis=-1)


# ---------------------------------------------------------------------------
# mean station counts & relative delay (Thm 2 Eq 3/5; Thm 7 Eq 21/23)
# ---------------------------------------------------------------------------

def mean_total_counts(params: NetworkParams, logZ: jax.Array, pop: int) -> jax.Array:
    """``E[sum_s X_i^s]`` per client at population ``pop`` (includes the
    class-i CS share when the CS buffer is modelled)."""
    if pop <= 0:
        return jnp.zeros(params.n)
    log_rho = params.log_rho
    # computation queue: sum_{k>=1} rho_i^k Z[pop-k]/Z[pop]
    comp = jnp.exp(_series_vs_Z(log_rho, logZ, pop, shift=1))
    # IS stations: gamma_i Z[pop-1]/Z[pop]
    is_part = params.gamma * jnp.exp(_lz(logZ, pop - 1) - logZ[pop])
    total = comp + is_part
    if params.mu_cs is not None:
        log_load_cs = jnp.log(jnp.sum(params.p)) - jnp.log(params.mu_cs)
        cs_total = jnp.exp(_series_vs_Z(log_load_cs, logZ, pop, shift=1))
        total = total + params.p / jnp.sum(params.p) * cs_total
    return total


def expected_relative_delay(params: NetworkParams, m: int,
                            logZ: jax.Array | None = None) -> jax.Array:
    """``E0[D_i]`` for each client (Thm 2 Eq 3/5; Thm 7 Eq 21/23)."""
    if logZ is None:
        logZ = log_normalizing_constants(params, m)
    return mean_total_counts(params, logZ, m - 1)


# ---------------------------------------------------------------------------
# second moments (Thm 2 Eq 6; Thm 7 Eq 24)
# ---------------------------------------------------------------------------

def second_moment_matrix(params: NetworkParams, m: int,
                         logZ: jax.Array | None = None) -> jax.Array:
    """``E[S_i S_j]`` with ``S_i = sum_s X_i^s`` at population ``m - 1``."""
    if logZ is None:
        logZ = log_normalizing_constants(params, m)
    n = params.n
    log_rho = params.log_rho
    gamma = params.gamma
    pop = m - 1

    if pop <= 0:
        return jnp.zeros((n, n))

    # ---- alpha (queue-queue) ------------------------------------------------
    # i == j: sum_k (2k-1) rho_i^k Z[pop-k]/Z[pop]
    kmax = pop
    wlog = jnp.log(2.0 * jnp.arange(1, kmax + 1) - 1.0)
    alpha_diag = jnp.exp(_series_vs_Z(log_rho, logZ, pop, shift=1, weights_log=wlog))

    # i != j: sum_{s=2}^{pop} Z[pop-s]/Z[pop] * c_ij(s),
    # c_ij(s) = sum_{k=1}^{s-1} rho_i^k rho_j^{s-k}
    #         = exp(s * lr_j) * geom_sum(lr_i - lr_j, s - 1)
    s = jnp.arange(2, pop + 1)  # [S]
    if s.size > 0:
        d = log_rho[:, None] - log_rho[None, :]  # [n, n]
        # log c[i,j,s] = s*lr_j + log_geom_sum(d_ij, s-1)
        lgs = jax.vmap(lambda K: _log_geom_sum(d, K))(s - 1)  # [S, n, n]
        log_c = s[:, None, None] * log_rho[None, None, :] + lgs  # [S, n, n]
        zlog = (_lz(logZ, pop - s) - logZ[pop])[:, None, None]
        alpha_off = jnp.exp(logsumexp(log_c + zlog, axis=0))  # [n, n]
    else:
        alpha_off = jnp.zeros((n, n))
    eye = jnp.eye(n, dtype=bool)
    alpha = jnp.where(eye, alpha_diag[:, None] * jnp.eye(n), alpha_off)

    # ---- beta_{i,2} (queue-IS cross terms) ----------------------------------
    beta2 = jnp.exp(_series_vs_Z(log_rho, logZ, pop, shift=2))  # [n]

    # ---- psi (IS-IS) ---------------------------------------------------------
    z3 = jnp.exp(_lz(logZ, pop - 2) - logZ[pop])  # Z[m-3]/Z[m-1]
    z2 = jnp.exp(_lz(logZ, pop - 1) - logZ[pop])  # Z[m-2]/Z[m-1]
    psi = gamma[:, None] * gamma[None, :] * z3 + jnp.diag(gamma) * z2

    second = alpha + beta2[:, None] * gamma[None, :] + beta2[None, :] * gamma[:, None] + psi

    if params.mu_cs is not None:
        second = second + _cs_second_moment_terms(params, logZ, pop)
    return second


def _cs_second_moment_terms(params: NetworkParams, logZ: jax.Array, pop: int) -> jax.Array:
    """Red CS-specific terms of Theorem 7 Eq (24), at population ``pop = m-1``."""
    n = params.n
    p = params.p
    psum = jnp.sum(p)
    gamma = params.gamma
    log_rho = params.log_rho
    log_load_cs = jnp.log(psum) - jnp.log(params.mu_cs)

    # beta_CS,2 = sum_k load_cs^k W[m-2-k]/W[m-1]
    beta_cs2 = jnp.exp(_series_vs_Z(log_load_cs, logZ, pop, shift=2))

    # alpha^CS_{i,j} = p_i sum_{k=1}^{pop} load_cs^k W[pop-k]/W[pop] (2 p_j (k-1) + 1{i=j})
    k = jnp.arange(1, pop + 1)
    base = k * log_load_cs + _lz(logZ, pop - k) - logZ[pop]  # [K] log
    s0 = jnp.exp(logsumexp(base))                      # sum_k load^k W./W
    s1_terms = jnp.where(k > 1, base + jnp.log(jnp.maximum(k - 1.0, 1e-300)), NEG_INF)
    s1 = jnp.exp(logsumexp(s1_terms))                  # sum_k (k-1) load^k W./W
    # note: per-class visit share is p_i / sum(p)
    pi = p / psum
    alpha_cs = (pi[:, None] * pi[None, :]) * 2.0 * s1 * psum * psum
    alpha_cs = alpha_cs + jnp.diag(pi * psum) * s0
    # (The paper writes p_i [2 p_j (k-1) + 1{i=j}] with |p| = 1; the psum
    # factors keep the expression 1-homogeneous per class index for raw
    # partials, reducing to the paper's form on the simplex.)

    # alpha_{CS,i} = sum_{k=1}^{pop-1} sum_{l=1}^{pop-k} load_cs^k rho_i^l W[pop-k-l]/W[pop]
    if pop >= 2:
        kk = jnp.arange(1, pop)  # k
        ll = jnp.arange(1, pop)  # l
        grid = (kk[:, None] * log_load_cs + ll[None, :] * log_rho[:, None, None]
                + _lz(logZ, pop - kk[:, None] - ll[None, :]) - logZ[pop])
        valid = (kk[:, None] + ll[None, :]) <= pop
        grid = jnp.where(valid[None, :, :], grid, NEG_INF)
        alpha_cs_i = jnp.exp(logsumexp(grid, axis=(1, 2)))  # [n]
    else:
        alpha_cs_i = jnp.zeros(n)

    extra = (alpha_cs
             + beta_cs2 * (pi[:, None] * gamma[None, :] + pi[None, :] * gamma[:, None]) * psum
             + pi[:, None] * alpha_cs_i[None, :] * psum
             + pi[None, :] * alpha_cs_i[:, None] * psum)
    return extra


# ---------------------------------------------------------------------------
# routing Jacobian of the delay (Thm 2 Eq 4; Thm 7 Eq 22)
# ---------------------------------------------------------------------------

def delay_jacobian(params: NetworkParams, m: int,
                   logZ: jax.Array | None = None) -> jax.Array:
    """``J[i, j] = d E0[D_i] / d p_j`` via the covariance identity."""
    if logZ is None:
        logZ = log_normalizing_constants(params, m)
    mean = mean_total_counts(params, logZ, m - 1)
    second = second_moment_matrix(params, m, logZ)
    cov = second - mean[:, None] * mean[None, :]
    return cov / params.p[None, :]


# ---------------------------------------------------------------------------
# throughput (Prop 4 Eq 11/12; Prop 8 Eq 26/27)
# ---------------------------------------------------------------------------

def throughput(params: NetworkParams, m: int,
               logZ: jax.Array | None = None) -> jax.Array:
    """``lambda(p, m) = Z_{n,m-1} / Z_{n,m}`` — updates per unit time."""
    if logZ is None:
        logZ = log_normalizing_constants(params, m)
    return jnp.exp(logZ[m - 1] - logZ[m])


def throughput_grad(params: NetworkParams, m: int,
                    logZ: jax.Array | None = None) -> jax.Array:
    """``d lambda / d p_j`` (Eq 12/27): ``lambda/p_j * (E[S_j]_{m-1} - E[S_j]_m)``."""
    if logZ is None:
        logZ = log_normalizing_constants(params, m)
    lam = throughput(params, m, logZ)
    mean_embedded = mean_total_counts(params, logZ, m - 1)
    mean_stationary = mean_total_counts(params, logZ, m)
    return lam / params.p * (mean_embedded - mean_stationary)


# ---------------------------------------------------------------------------
# convenience bundle
# ---------------------------------------------------------------------------

def analyze(params: NetworkParams, m: int) -> dict:
    """One-shot stationary analysis at concurrency ``m``."""
    logZ = log_normalizing_constants(params, m)
    delays = expected_relative_delay(params, m, logZ)
    lam = throughput(params, m, logZ)
    return {
        "logZ": logZ,
        "delays": delays,
        "total_delay": jnp.sum(delays),  # == m - 1 (Eq 7)
        "throughput": lam,
        "delay_jacobian": delay_jacobian(params, m, logZ),
        "throughput_grad": throughput_grad(params, m, logZ),
    }
