"""Device-resident event engine for the Generalized AsyncSGD closed network.

A fully-jitted JAX re-implementation of the Fig. 1 / Fig. 6 discrete-event
dynamics: the simulation state is a **fixed-size in-flight task table** (one
row per circulating task: station/phase, owning client, dispatch round,
FIFO arrival sequence, absolute service-completion clock) advanced one event
at a time by :func:`step_event` — a pure function suitable for
``lax.scan`` / ``lax.while_loop`` and for ``jax.vmap`` over seeds and over
padded ``(p, m)`` strategy batches (the padding conventions of
``repro.core.batched``: the table is sized by a static ``m_max`` and slots
``>= m`` are inactive).

Exactness: service completions are *raced as absolute clocks* — a task
entering service draws its full service time up front and the next event is
the argmin over the table — which is exactly the semantics of the host
reference simulator for **every** service law registered in
``repro.scenario.laws`` (the Section 5.3.3 built-ins exponential /
deterministic / lognormal plus e.g. the hyperexponential H2 stress law),
not just the memoryless case the old ``jump_chain_throughput`` CTMC sampler
handled (that sampler is now a thin wrapper over this engine).

Contract with ``repro.core.simulator.AsyncNetworkSim``: the host heap
simulator remains the *exact per-task-identity reference*.  The two engines
consume randomness differently (numpy heap order vs. split JAX keys), so
cross-checks are distributional: throughput, per-client mean relative delay,
energy and occupancy statistics agree within Monte-Carlo tolerance on every
service law (``tests/test_events.py``).

State layout (all arrays ``[m_max]`` unless noted):

  * ``client``      — owning client of the task in each slot;
  * ``phase``       — station: DOWN(0) / COMP_WAIT(1) / COMP_SERV(2) /
    UP(3) / CS_WAIT(4) / CS_SERV(5); INACTIVE(-1) marks padded slots;
  * ``finish``      — absolute completion clock (``inf`` unless in service);
  * ``seq``         — FIFO arrival order within the current queue;
  * ``disp_round``  — round counter at dispatch (relative delay =
    ``round - disp_round`` at completion, Section 2.4);
  * statistics      — per-client delay sums/counts, energy integral
    (Eq. 14), time-weighted occupancy ``[3n+1]``, measured over the
    update-count window ``[warmup, cap)`` and time-capped by ``t_cap``.

Model updates (uplink or CS completion) immediately re-dispatch a fresh
task into the freed slot with routing ``p`` (Algorithm 1, lines 7-8) — the
slot index is returned so a caller can attach a payload (the parameter
snapshot ring of ``repro.fl.engine`` is indexed by slot).
"""
from __future__ import annotations
# contract: padded-n — reductions here are on the bitwise padding contract

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import numerics  # noqa: F401  (enables x64)
from ..scenario.laws import get_law
from .buzen import NetworkParams
from .numerics import seqcumsum, seqsum

# task phases
INACTIVE = -1
DOWN = 0        # downlink in service (infinite-server)
COMP_WAIT = 1   # waiting in the client's compute FIFO
COMP_SERV = 2   # in service at the client's compute queue
UP = 3          # uplink in service (infinite-server)
CS_WAIT = 4     # waiting in the CS FIFO (Section 7)
CS_SERV = 5     # in service at the CS single-server queue

_BIG_SEQ = np.iinfo(np.int32).max
_NO_CAP = np.iinfo(np.int32).max


class EventState(NamedTuple):
    """Carry of the event scan (one trajectory; vmap for batches)."""

    t: jax.Array          # current wall-clock time
    key: jax.Array        # PRNG carry
    round: jax.Array      # updates completed so far (round counter k)
    seq_ctr: jax.Array    # global FIFO arrival counter
    client: jax.Array     # [m_max]
    phase: jax.Array      # [m_max]
    finish: jax.Array     # [m_max]
    seq: jax.Array        # [m_max]
    disp_round: jax.Array  # [m_max]
    # statistics window: update-count window [warmup, cap), time cap t_cap
    warmup: jax.Array
    cap: jax.Array
    t_cap: jax.Array
    t0: jax.Array         # time of update #warmup (stats origin)
    t1: jax.Array         # time of update #cap (stats end)
    delay_sum: jax.Array  # [n]
    delay_cnt: jax.Array  # [n]
    energy: jax.Array     # scalar, Eq. 14 time integral
    occ_int: jax.Array    # [3n+1] time-weighted station occupancy
    # incrementally-maintained occupancy (each event moves exactly one task
    # between stations, so these are O(1)-update carries rather than O(m+n)
    # per-event recounts — the difference between the event scan being
    # bandwidth-bound and scatter-bound, especially under lane vmap):
    occ: jax.Array        # [3n+1] current station occupancy
    serving: jax.Array    # [n] busy indicator of each compute server
    cs_busy: jax.Array    # bool: CS server busy


class EventOut(NamedTuple):
    """Per-event emission of :func:`step_event`."""

    is_update: jax.Array
    time: jax.Array
    slot: jax.Array    # task-table row of the completed task (payload key)
    client: jax.Array  # C_k — client whose gradient would be applied
    delay: jax.Array   # relative delay round - dispatch_round


class UpdateOut(NamedTuple):
    """Result of :func:`next_update` (one model update)."""

    time: jax.Array
    slot: jax.Array
    client: jax.Array
    delay: jax.Array
    steps: jax.Array   # events consumed to reach this update


class EventStats(NamedTuple):
    """Device analogue of ``repro.core.simulator.SimStats``."""

    updates: jax.Array
    time: jax.Array
    throughput: jax.Array
    mean_delay: jax.Array        # [n] unscaled E0[R_i], 0 where no samples
    delay_counts: jax.Array      # [n]
    energy: jax.Array
    mean_queue_counts: jax.Array  # [3n+1]


def _draw(key: jax.Array, rate: jax.Array, distribution: str,
          shape=()) -> jax.Array:
    """Service time with mean ``1/rate``: the device draw of the registered
    timing law (``repro.scenario.laws``; Section 5.3.3 built-ins plus any
    ``@timing_law``-registered extension).  Unknown names raise listing the
    registry — and only at trace time; callers validate eagerly via
    :func:`repro.scenario.laws.get_law`."""
    return get_law(distribution).device_draw(key, rate, shape)


def _route_client(p: jax.Array, key: jax.Array, n_act,
                  prefix: Optional[jax.Array] = None) -> jax.Array:
    """Dispatch-routing draw ``C ~ p/sum(p)`` by inverse-CDF on one uniform.

    Deliberately *not* ``jax.random.categorical``: the Gumbel trick draws
    noise of the logits' shape, so the sampled client would depend on the
    static padded length ``n_max``.  A single scalar uniform against the
    routing prefix sums consumes shape-independent randomness, making
    event trajectories **bitwise invariant** to trailing zero-mass padding
    — the traced-``n`` analogue of the ``m_max`` slot-padding contract.
    The prefix is the strictly-sequential :func:`numerics.seqcumsum`
    (``jnp.cumsum`` may reassociate with length on parallel backends), its
    last element doubles as the padding-stable total mass (no separate
    normalization pass), padded entries repeat that total so
    ``searchsorted`` never lands on them, and the clip covers the
    measure-zero ``u * total >= total`` edge.

    ``prefix`` lets the caller pass ``seqcumsum(p)`` precomputed: the
    routing CDF is loop-invariant across an event scan, so hoisting it
    into the scan constants saves an O(n) sequential cumsum *per event*
    (:func:`_simulate_stats` does this).  The hoisted value is the same
    ``seqcumsum`` of the same ``p`` — trajectories are bitwise identical
    either way.
    """
    if prefix is None:
        prefix = seqcumsum(p)
    u = jax.random.uniform(key, dtype=p.dtype) * prefix[-1]
    idx = jnp.searchsorted(prefix, u, side="right")
    return jnp.minimum(idx, n_act - 1).astype(jnp.int32)


class EventBlocks(NamedTuple):
    """Pre-drawn randomness for a chunk of consecutive events (megastep).

    Every leaf carries a leading ``[chunk]`` axis; one row resolves one
    :func:`step_event_block` call.  The factorization follows what is
    state-independent in the per-event stream: the routing draw, the
    downlink service (its rate is keyed by the routed client, known before
    the argmin) and the CS service resolve fully up front; the uplink and
    computation services depend on the *completing* client's rate, so they
    are stored as the law's unit parts (``TimingLaw.unit_draw``) and
    rate-applied inside the step — or, for laws without a unit
    factorization, as the raw subkeys (``device_draw`` runs in-step,
    bitwise by construction).
    """

    c_new: jax.Array       # routed client (client engine) / class (class)
    member: jax.Array      # routed member within the class; () otherwise
    svc_down: jax.Array    # downlink service of the re-dispatched task
    up: jax.Array          # uplink unit part (or raw subkey)
    comp: jax.Array        # computation unit part (or raw subkey)
    svc_cs: jax.Array      # CS service draw; () when the network has no CS


def _apply_unit(u, rate, distribution: str):
    """Resolve a stored uplink/computation entry against the completing
    client's rate — ``unit_apply`` replays ``device_draw``'s exact op
    order (bitwise), the raw-subkey fallback *is* ``device_draw``."""
    law = get_law(distribution)
    if law.unit_apply is not None:
        return law.unit_apply(u, rate)
    return law.device_draw(u, rate)


def draw_event_blocks(params: NetworkParams, key: jax.Array, chunk: int, *,
                      distribution: str = "exponential",
                      route_prefix: Optional[jax.Array] = None
                      ) -> tuple[jax.Array, EventBlocks]:
    """Draw the randomness of ``chunk`` consecutive events up front.

    A tiny-carry scan (the carry is just the PRNG key) replays
    :func:`step_event`'s 6-way split per event; the draws themselves then
    resolve on the collected subkeys — the exact primitives on the exact
    keys of ``chunk`` single steps.  Laws with a unit factorization draw
    **vmapped** over the chunk axis (PRNG bits are integer-exact per key
    and the uniform→sample conversions compile bitwise elementwise);
    laws without one (e.g. lognormal, whose erf_inv/exp chain is not
    fusion-stable across a materialization boundary) stay on a strictly
    sequential scalar-shape draw scan and store raw subkeys for the
    rate-dependent services.  Returns ``(chain, blocks)``: ``chain[i]``
    is the carried key after ``i + 1`` events (the partial-chunk resume
    point) and ``blocks`` one :class:`EventBlocks` row per event.
    """
    law = get_law(distribution)
    has_cs = params.mu_cs is not None

    if law.unit_draw is None:
        def body(k, _):
            k2, k_up, k_cli, k_svc, k_comp, k_cs = jax.random.split(k, 6)
            c_new = _route_client(params.p, k_cli, params.active_count,
                                  route_prefix)
            svc_down = _draw(k_svc, params.mu_d[c_new], distribution)
            svc_cs = (_draw(k_cs, params.mu_cs, distribution)
                      if has_cs else ())
            blk = EventBlocks(c_new=c_new, member=(), svc_down=svc_down,
                              up=k_up, comp=k_comp, svc_cs=svc_cs)
            return k2, (k2, blk)

        _, (chain, blks) = jax.lax.scan(body, key, None, length=chunk)
        return chain, blks

    def split6(k, _):
        ks = jax.random.split(k, 6)
        return ks[0], (ks[0], ks[1], ks[2], ks[3], ks[4], ks[5])

    _, (chain, k_up, k_cli, k_svc, k_comp, k_cs) = jax.lax.scan(
        split6, key, None, length=chunk)
    c_new = jax.vmap(lambda k: _route_client(
        params.p, k, params.active_count, route_prefix))(k_cli)
    svc_down = jax.vmap(
        lambda k, r: _draw(k, r, distribution))(k_svc, params.mu_d[c_new])
    up = jax.vmap(law.unit_draw)(k_up)
    comp = jax.vmap(law.unit_draw)(k_comp)
    svc_cs = (jax.vmap(lambda k: _draw(k, params.mu_cs, distribution))(k_cs)
              if has_cs else ())
    return chain, EventBlocks(c_new=c_new, member=(), svc_down=svc_down,
                              up=up, comp=comp, svc_cs=svc_cs)


def _tree_select(pred, on_true, on_false):
    """Leaf-wise ``where`` — the masked-step select of the megastep scans
    (a scalar predicate; identical trees selected leaf-by-leaf)."""
    return jax.tree_util.tree_map(
        lambda a, b: jnp.where(pred, a, b), on_true, on_false)


def init_state(params: NetworkParams, m, key: jax.Array, *,
               m_max: Optional[int] = None,
               distribution: str = "exponential",
               warmup=0, cap=_NO_CAP, t_cap=jnp.inf) -> EventState:
    """Initial out-of-equilibrium state: ``m`` tasks dispatched uniformly at
    random into the downlink servers at ``t = 0`` (Section 5.3.3).

    ``m`` may be a traced scalar; ``m_max`` (static) sizes the task table —
    slots ``>= m`` are inactive, following the padded conventions of
    ``repro.core.batched``.  Under the traced-``n`` convention
    (``params.n_active`` set, see :func:`repro.core.buzen.pad_network`) the
    statistics arrays are sized by the static ``n_max = params.n`` while
    the initial dispatch draws only real clients — bitwise the same draws
    as the unpadded network.
    """
    n = params.n
    if m_max is None:
        m_max = int(m)
    key, k_cli, k_svc = jax.random.split(key, 3)
    clients = jax.random.randint(k_cli, (m_max,), 0, params.active_count)
    active = jnp.arange(m_max) < m
    svc = _draw(k_svc, params.mu_d[clients], distribution, (m_max,))
    phase0 = jnp.where(active, DOWN, INACTIVE).astype(jnp.int32)
    down, comp_total, comp_serving, up, cs_total, cs_busy = _station_counts(
        phase0, clients.astype(jnp.int32), n)
    return EventState(
        t=jnp.zeros((), jnp.float64),
        key=key,
        round=jnp.zeros((), jnp.int32),
        seq_ctr=jnp.zeros((), jnp.int32),
        client=clients.astype(jnp.int32),
        phase=phase0,
        finish=jnp.where(active, svc, jnp.inf),
        seq=jnp.zeros((m_max,), jnp.int32),
        disp_round=jnp.zeros((m_max,), jnp.int32),
        warmup=jnp.asarray(warmup, jnp.int32),
        cap=jnp.asarray(cap, jnp.int32),
        t_cap=jnp.asarray(t_cap, jnp.float64),
        t0=jnp.zeros((), jnp.float64),
        t1=jnp.zeros((), jnp.float64),
        delay_sum=jnp.zeros((n,), jnp.float64),
        delay_cnt=jnp.zeros((n,), jnp.int32),
        energy=jnp.zeros((), jnp.float64),
        occ_int=jnp.zeros((3 * n + 1,), jnp.float64),
        occ=jnp.concatenate([down, comp_total, up, cs_total[None]]),
        serving=comp_serving,
        cs_busy=cs_busy,
    )


def _station_counts(phase, client, n):
    """Per-station occupancy: down[n], comp_total[n], comp_serving[n],
    up[n], cs_total, cs_busy.

    Full recount from the task table — used to seed the O(1)-update
    occupancy carries of :class:`EventState` at :func:`init_state` and as
    the consistency oracle in the tests; the event step itself maintains
    the carries incrementally.
    """
    def count(mask):
        return jnp.zeros((n,), jnp.float64).at[client].add(
            jnp.where(mask, 1.0, 0.0))

    down = count(phase == DOWN)
    comp_total = count((phase == COMP_WAIT) | (phase == COMP_SERV))
    comp_serving = count(phase == COMP_SERV)
    up = count(phase == UP)
    # contract: allow(raw-reduction): 0/1 indicator count over the task table — exact small-integer f64 under any association, and the table axis is m_max (never padded-n)
    cs_total = jnp.sum(
        jnp.where((phase == CS_WAIT) | (phase == CS_SERV), 1.0, 0.0))
    cs_busy = jnp.any(phase == CS_SERV)
    return down, comp_total, comp_serving, up, cs_total, cs_busy


def _station_index(phase, client, n):
    """Row of the ``[3n+1]`` occupancy vector a task in ``(phase, client)``
    occupies: down_i / comp_i (WAIT and SERV share the station) / up_i /
    CS."""
    return jnp.where(
        phase == DOWN, client,
        jnp.where((phase == COMP_WAIT) | (phase == COMP_SERV), n + client,
                  jnp.where(phase == UP, 2 * n + client, 3 * n)))


def step_event(params: NetworkParams, state: EventState, *,
               distribution: str = "exponential",
               power=None,
               route_prefix: Optional[jax.Array] = None
               ) -> tuple[EventState, EventOut]:
    """Advance the network by exactly one event (one service completion).

    Pure and jit/vmap-safe.  ``params.mu_cs is None`` statically selects the
    CS-free network; ``power`` (a ``PowerProfile`` or None) statically
    enables phase-dependent energy accounting (Eq. 14).  ``route_prefix``
    optionally supplies the precomputed routing CDF ``seqcumsum(params.p)``
    (loop-invariant across a scan — see :func:`_route_client`); ``None``
    recomputes it in-body, bitwise the same.

    Structured as a one-event :class:`EventBlocks` draw followed by the
    randomness-free table transition :func:`step_event_block` — the same
    primitives on the same keys as the historical inline body (values are
    position-independent under jit), so trajectories are bitwise
    unchanged; the megastep engine reuses the block step with ``chunk``
    pre-drawn rows.
    """
    law = get_law(distribution)
    key, k_up, k_disp_cli, k_disp_svc, k_comp, k_cs = jax.random.split(
        state.key, 6)
    c_new = _route_client(params.p, k_disp_cli, params.active_count,
                          route_prefix)
    svc_down = _draw(k_disp_svc, params.mu_d[c_new], distribution)
    if law.unit_draw is not None:
        up, comp = law.unit_draw(k_up), law.unit_draw(k_comp)
    else:
        up, comp = k_up, k_comp
    svc_cs = (_draw(k_cs, params.mu_cs, distribution)
              if params.mu_cs is not None else ())
    blk = EventBlocks(c_new=c_new, member=(), svc_down=svc_down,
                      up=up, comp=comp, svc_cs=svc_cs)
    return step_event_block(params, state._replace(key=key), blk,
                            distribution=distribution, power=power)


def step_event_block(params: NetworkParams, state: EventState,
                     blk: EventBlocks, *,
                     distribution: str = "exponential",
                     power=None) -> tuple[EventState, EventOut]:
    """One event transition with its randomness pre-resolved in ``blk``.

    The randomness-free core of :func:`step_event`: consumes no PRNG key
    (``state.key`` passes through untouched — megastep callers advance it
    from the :func:`_chunk_keys` chain) and reads the routing / service
    draws from one :class:`EventBlocks` row, applying the law's unit
    parts against the completing client's rates in-step.
    """
    n = params.n
    m_max = state.phase.shape[0]
    has_cs = params.mu_cs is not None

    j = jnp.argmin(state.finish)
    t_new = state.finish[j]

    # -- statistics over the sojourn ending at this event (pre-event state) --
    # the occupancy vector / busy indicators are O(1)-update carries of the
    # state (exact small-integer f64 arithmetic: bit-identical to a full
    # per-event recount, without its O(m + n) scatter cost)
    measure = (state.round >= state.warmup) & (state.round < state.cap)
    dt_eff = jnp.where(
        measure,
        jnp.clip(jnp.minimum(t_new, state.t_cap)
                 - jnp.minimum(state.t, state.t_cap), 0.0, None),
        0.0)
    occ_int = state.occ_int + dt_eff * state.occ
    energy = state.energy
    if power is not None:
        # one sequential sum over the fused per-client power terms: the
        # energy statistic is on the padded-n bitwise contract
        pwr = seqsum(power.P_c * state.serving
                     + power.P_u * state.occ[2 * n:3 * n]
                     + power.P_d * state.occ[:n])
        if power.P_cs is not None:
            pwr = pwr + power.P_cs * state.cs_busy
        energy = energy + dt_eff * pwr

    # -- the event itself ---------------------------------------------------
    c = state.client[j]
    ph = state.phase[j]

    is_down = ph == DOWN
    is_comp = ph == COMP_SERV
    is_up = ph == UP
    is_cs = ph == CS_SERV
    is_update = is_cs if has_cs else is_up

    delay = state.round - state.disp_round[j]
    new_round = state.round + jnp.where(is_update, 1, 0).astype(jnp.int32)

    # update -> immediate re-dispatch of a fresh task into the freed slot
    c_new = blk.c_new
    svc_up = _apply_unit(blk.up, params.mu_u[c], distribution)
    svc_down = blk.svc_down

    phase_j = jnp.where(
        is_down, COMP_WAIT,
        jnp.where(is_comp, UP, jnp.where(is_update, DOWN, CS_WAIT)))
    finish_j = jnp.where(
        is_comp, t_new + svc_up,
        jnp.where(is_update, t_new + svc_down, jnp.inf))
    joins_fifo = is_down | (is_up & has_cs)
    seq_j = jnp.where(joins_fifo, state.seq_ctr, state.seq[j])
    seq_ctr = state.seq_ctr + joins_fifo.astype(jnp.int32)
    client_j = jnp.where(is_update, c_new, c)
    disp_j = jnp.where(is_update, new_round, state.disp_round[j])

    onej = jnp.arange(m_max) == j
    phase = jnp.where(onej, phase_j, state.phase).astype(jnp.int32)
    finish = jnp.where(onej, finish_j, state.finish)
    seq = jnp.where(onej, seq_j, state.seq).astype(jnp.int32)
    client = jnp.where(onej, client_j, state.client).astype(jnp.int32)
    disp_round = jnp.where(onej, disp_j, state.disp_round).astype(jnp.int32)

    # -- FIFO promotions (post-transition table) ----------------------------
    # compute station of client c: j joined its queue (is_down) or freed its
    # server (is_comp)
    promo_comp = is_down | is_comp
    serving_c = jnp.any((phase == COMP_SERV) & (client == c))
    waiting_c = (phase == COMP_WAIT) & (client == c)
    pick = jnp.argmin(jnp.where(waiting_c, seq, _BIG_SEQ))
    do_comp = promo_comp & ~serving_c & jnp.any(waiting_c)
    svc_c = _apply_unit(blk.comp, params.mu_c[c], distribution)
    onep = (jnp.arange(m_max) == pick) & do_comp
    phase = jnp.where(onep, COMP_SERV, phase)
    finish = jnp.where(onep, t_new + svc_c, finish)

    if has_cs:
        # CS station: j joined its queue (is_up) or freed its server (is_cs)
        promo_cs = is_up | is_cs
        cs_waiting = phase == CS_WAIT
        pick_cs = jnp.argmin(jnp.where(cs_waiting, seq, _BIG_SEQ))
        do_cs = promo_cs & ~jnp.any(phase == CS_SERV) & jnp.any(cs_waiting)
        onec = (jnp.arange(m_max) == pick_cs) & do_cs
        phase = jnp.where(onec, CS_SERV, phase)
        finish = jnp.where(onec, t_new + blk.svc_cs, finish)

    # -- O(1) maintenance of the occupancy carries: slot j moved stations;
    # FIFO promotions stay within theirs (WAIT and SERV share a station),
    # so they only touch the busy indicators -------------------------------
    stations = jnp.arange(3 * n + 1)
    occ_new = (state.occ
               + jnp.where(stations == _station_index(phase_j, client_j, n),
                           1.0, 0.0)
               - jnp.where(stations == _station_index(ph, c, n), 1.0, 0.0))
    delta_srv = (jnp.where(do_comp, 1.0, 0.0)
                 - jnp.where(is_comp, 1.0, 0.0))
    serving_new = state.serving + jnp.where(jnp.arange(n) == c,
                                            delta_srv, 0.0)
    cs_busy_new = ((state.cs_busy & ~is_cs) | do_cs if has_cs
                   else state.cs_busy)

    # -- delay statistics and window marks ----------------------------------
    upd_measured = is_update & measure
    delay_sum = state.delay_sum.at[c].add(
        jnp.where(upd_measured, delay.astype(jnp.float64), 0.0))
    delay_cnt = state.delay_cnt.at[c].add(
        jnp.where(upd_measured, 1, 0).astype(jnp.int32))
    t0 = jnp.where(is_update & (new_round == state.warmup), t_new, state.t0)
    t1 = jnp.where(is_update & (new_round == state.cap), t_new, state.t1)

    new_state = EventState(
        t=t_new, key=state.key, round=new_round, seq_ctr=seq_ctr,
        client=client, phase=phase, finish=finish, seq=seq,
        disp_round=disp_round,
        warmup=state.warmup, cap=state.cap, t_cap=state.t_cap,
        t0=t0, t1=t1, delay_sum=delay_sum, delay_cnt=delay_cnt,
        energy=energy, occ_int=occ_int,
        occ=occ_new, serving=serving_new, cs_busy=cs_busy_new)
    out = EventOut(is_update=is_update,
                   time=t_new,
                   slot=j.astype(jnp.int32),
                   client=c,
                   delay=delay.astype(jnp.int32))
    return new_state, out


def next_update(params: NetworkParams, state: EventState, *,
                distribution: str = "exponential", power=None,
                max_steps: Optional[int] = None,
                backend: Optional[str] = None,
                interpret: Optional[bool] = None,
                route_prefix: Optional[jax.Array] = None,
                chunk: int = 1) -> tuple[EventState, UpdateOut]:
    """Run events until the next model update (uplink/CS completion).

    A ``lax.while_loop`` bounded by ``max_steps`` (default ``3 m_max + 8``,
    ``4 m_max + 8`` with the CS station — between two consecutive updates
    each of the ``m`` tasks can complete at most its downlink, compute and
    uplink (and CS) phases, and the last such completion *is* the update,
    so the bound is never met in a valid state).

    ``backend`` selects the per-event step implementation
    (``repro.sim.backend``): under ``"pallas"`` the table transition runs
    in the ``repro.kernels.events`` TPU kernel — compiled on TPU unless
    ``interpret`` overrides — while ``"reference"``/``"batched"`` share
    the single-lane jnp step (lane batching happens in the caller's
    ``vmap``).

    ``chunk > 1`` (static) selects the megastep body: each while-loop
    iteration pre-draws a block of ``chunk`` events and retires them in an
    inner masked scan (under ``"pallas"``, one kernel launch with an
    in-VMEM early-stop loop) — events past the update, or past the
    ``max_steps`` bound, are discarded and the key chain advances by
    exactly the events consumed, so the returned update (and the state it
    leaves behind) is **bitwise** the single-step result.
    """
    from ..sim.backend import resolve_backend  # dependency-free

    use_pallas = resolve_backend(backend) == "pallas"
    if use_pallas:
        from ..kernels.events import step_event_pallas1

        # the kernel computes the routing CDF in-register; a host-hoisted
        # prefix does not apply (and is bitwise irrelevant either way)
        step_fn = functools.partial(step_event_pallas1, interpret=interpret)
    else:
        step_fn = functools.partial(step_event, route_prefix=route_prefix)
    m_max = state.phase.shape[0]
    if max_steps is None:
        max_steps = (4 if params.mu_cs is not None else 3) * m_max + 8

    dummy = EventOut(is_update=jnp.asarray(False),
                     time=jnp.zeros((), jnp.float64),
                     slot=jnp.zeros((), jnp.int32),
                     client=jnp.zeros((), jnp.int32),
                     delay=jnp.zeros((), jnp.int32))

    def cond(carry):
        _, out, steps = carry
        return (~out.is_update) & (steps < max_steps)

    if chunk == 1:
        def body(carry):
            st, _, steps = carry
            st, out = step_fn(params, st, distribution=distribution,
                              power=power)
            return st, out, steps + 1
    elif use_pallas:
        from ..kernels.events import megastep_event_pallas1

        def body(carry):
            st, out, steps = carry
            st, aux = megastep_event_pallas1(
                params, st, chunk=chunk, rem=max_steps - steps,
                distribution=distribution, power=power,
                interpret=interpret, stop_on_update=True)
            outs = EventOut(is_update=aux.update, time=aux.time,
                            slot=aux.slot, client=aux.client,
                            delay=aux.delay)

            def sel(o, x):
                keep, o2 = x
                return _tree_select(keep, o2, o), None

            out, _ = jax.lax.scan(sel, out, (aux.keep, outs))
            return st, out, steps + aux.taken
    else:
        def body(carry):
            st, out, steps = carry
            chain, blks = draw_event_blocks(
                params, st.key, chunk, distribution=distribution,
                route_prefix=route_prefix)

            def inner(c2, blk):
                st, out, taken = c2
                st2, out2 = step_event_block(
                    params, st, blk, distribution=distribution, power=power)
                take = (~out.is_update) & (steps + taken < max_steps)
                return (_tree_select(take, st2, st),
                        _tree_select(take, out2, out),
                        taken + take.astype(jnp.int32)), None

            (st, out, taken), _ = jax.lax.scan(
                inner, (st, out, jnp.zeros((), jnp.int32)), blks)
            # key chain advances by exactly the events consumed (see
            # _chunk_keys); an all-masked chunk leaves the key untouched
            k = jnp.clip(taken, 1, chunk)
            st = st._replace(key=jnp.where(taken > 0, chain[k - 1], st.key))
            return st, out, steps + taken

    st, out, steps = jax.lax.while_loop(
        cond, body, (state, dummy, jnp.zeros((), jnp.int32)))
    return st, UpdateOut(time=out.time, slot=out.slot, client=out.client,
                         delay=out.delay, steps=steps)


# ---------------------------------------------------------------------------
# stationary statistics (device analogue of AsyncNetworkSim.run)
# ---------------------------------------------------------------------------

def finalize_stats(st: EventState) -> EventStats:
    """Stationary statistics from a final event-scan state (one lane).

    The single definition every ``repro.sim`` backend assembles its
    :class:`EventStats` through — reference, batched and pallas sweeps
    stay bitwise aligned by construction.
    """
    updates = jnp.clip(st.round, 0, st.cap) - st.warmup
    horizon = jnp.where(st.round >= st.cap, st.t1 - st.t0, st.t - st.t0)
    mean_delay = jnp.where(st.delay_cnt > 0,
                           st.delay_sum / jnp.maximum(st.delay_cnt, 1), 0.0)
    return EventStats(
        updates=updates,
        time=horizon,
        throughput=jnp.where(horizon > 0, updates / jnp.maximum(horizon, 1e-12),
                             0.0),
        mean_delay=mean_delay,
        delay_counts=st.delay_cnt,
        energy=st.energy,
        mean_queue_counts=st.occ_int / jnp.maximum(horizon, 1e-12),
    )


def unpad_stats(stats: EventStats, n: int) -> EventStats:
    """Strip the traced-``n`` padding from an :class:`EventStats`.

    Per-client arrays are truncated to the real population ``n`` and the
    ``[3 n_max + 1]`` occupancy vector is re-packed segment-wise into the
    unpadded ``[3n + 1]`` station layout (down / comp / up / CS).  Works on
    any number of leading lane axes.  Because trajectories are bitwise
    invariant to the padding (see :func:`_route_client`), the result equals
    the unpadded run's statistics exactly.
    """
    nm = (stats.mean_queue_counts.shape[-1] - 1) // 3
    occ = stats.mean_queue_counts
    return stats._replace(
        mean_delay=stats.mean_delay[..., :n],
        delay_counts=stats.delay_counts[..., :n],
        mean_queue_counts=jnp.concatenate(
            [occ[..., 0:n], occ[..., nm:nm + n],
             occ[..., 2 * nm:2 * nm + n], occ[..., 3 * nm:]], axis=-1))


def _scan_chunked(step_block, draw_blocks, st, num_events: int, chunk: int,
                  ring=None, append=None):
    """Advance ``num_events`` events in megasteps of ``chunk``.

    The outer scan runs ``ceil(num_events / chunk)`` iterations; each
    draws one randomness block from the carried key and retires up to
    ``chunk`` events in a rolled inner scan.  Events past ``num_events``
    (the masked partial final chunk) are computed and discarded via
    :func:`_tree_select`, and the carried key advances by exactly the
    *real* event count from the :func:`_chunk_keys` chain — so the final
    state (statistics windows included: ``warmup``/``cap``/``t_cap`` land
    on exact event boundaries) is **bitwise** the single-step scan's.

    ``append(ring, pre, post, out, keep)`` optionally threads an obs ring
    through the chunked carry; masked events append with ``valid=False``
    (a static no-op on the ring), keeping tracing bitwise non-invasive.
    """
    n_chunks = -(-num_events // chunk)
    offsets = jnp.arange(chunk)

    def outer(carry, _):
        st, rem, ring = carry
        chain, blks = draw_blocks(st.key)

        def inner(c2, xs):
            st, ring = c2
            blk, keep = xs
            st2, out = step_block(st, blk)
            if append is not None:
                ring = append(ring, st, st2, out, keep)
            return (_tree_select(keep, st2, st), ring), None

        (st, ring), _ = jax.lax.scan(inner, (st, ring),
                                     (blks, rem > offsets))
        k = jnp.clip(jnp.minimum(rem, chunk), 1, chunk)
        st = st._replace(key=jnp.where(rem > 0, chain[k - 1], st.key))
        return (st, rem - chunk, ring), None

    (st, _, ring), _ = jax.lax.scan(
        outer, (st, jnp.asarray(num_events, jnp.int32), ring), None,
        length=n_chunks)
    return st, ring


@functools.partial(jax.jit, static_argnames=(
    "num_updates", "warmup", "distribution", "m_max", "chunk"))
def _simulate_stats(params, m, key, num_updates, warmup, distribution,
                    m_max, power, chunk=1):
    # every completed task cycle is down -> comp -> up (-> cs): exactly 3 (4)
    # events per update, plus at most one incomplete cycle per task
    mult = 4 if params.mu_cs is not None else 3
    num_events = mult * (num_updates + warmup) + mult * m_max + 8
    cap = warmup + num_updates
    st = init_state(params, m, key, m_max=m_max, distribution=distribution,
                    warmup=warmup, cap=cap)
    # the routing CDF is loop-invariant: hoist it out of the scan body so it
    # enters as a scan constant instead of an O(n) sequential cumsum per
    # event (same seqcumsum of the same p — trajectories bitwise unchanged)
    route_prefix = seqcumsum(params.p)

    if chunk == 1:
        def body(st, _):
            st, _ = step_event(params, st, distribution=distribution,
                               power=power, route_prefix=route_prefix)
            return st, None

        st, _ = jax.lax.scan(body, st, None, length=num_events)
        return finalize_stats(st)

    def draw(key):
        return draw_event_blocks(params, key, chunk,
                                 distribution=distribution,
                                 route_prefix=route_prefix)

    def step(st, blk):
        return step_event_block(params, st, blk, distribution=distribution,
                                power=power)

    st, _ = _scan_chunked(step, draw, st, num_events, chunk)
    return finalize_stats(st)


@functools.partial(jax.jit, static_argnames=(
    "num_updates", "warmup", "distribution", "m_max", "trace_events",
    "chunk"))
def _simulate_stats_traced(params, m, key, num_updates, warmup, distribution,
                           m_max, power, trace_events, chunk=1):
    """:func:`_simulate_stats` carrying an ``repro.obs`` event ring.

    A separate program on purpose: the untraced scan stays byte-for-byte
    what it was (same name for the compile sentinel, same jit cache
    entry), and the ring rides as extra carry state.  The append reads
    the *pre-event* state (the completed station) and the post-step state
    (the destination station) but never feeds back into either — no
    randomness consumed, no value altered — so the returned
    :class:`EventStats` is **bitwise** equal to the untraced run
    (``tests/test_obs.py`` property-tests this across all backends).
    """
    from ..obs.rings import event_ring_append, event_ring_init

    mult = 4 if params.mu_cs is not None else 3
    num_events = mult * (num_updates + warmup) + mult * m_max + 8
    cap = warmup + num_updates
    st = init_state(params, m, key, m_max=m_max, distribution=distribution,
                    warmup=warmup, cap=cap)
    route_prefix = seqcumsum(params.p)
    n = params.n
    ring = event_ring_init(int(trace_events))

    if chunk == 1:
        def body(carry, _):
            st, ring = carry
            st2, out = step_event(params, st, distribution=distribution,
                                  power=power, route_prefix=route_prefix)
            ph = st.phase[out.slot]
            ring = event_ring_append(
                ring, time=out.time,
                station=_station_index(ph, out.client, n),
                station_to=_station_index(st2.phase[out.slot],
                                          st2.client[out.slot], n),
                kind=ph, slot=out.slot, client=out.client, delay=out.delay,
                update=out.is_update)
            return (st2, ring), None

        (st, ring), _ = jax.lax.scan(body, (st, ring), None,
                                     length=num_events)
        return finalize_stats(st), ring

    def draw(key):
        return draw_event_blocks(params, key, chunk,
                                 distribution=distribution,
                                 route_prefix=route_prefix)

    def step(st, blk):
        return step_event_block(params, st, blk, distribution=distribution,
                                power=power)

    def append(ring, pre, post, out, keep):
        ph = pre.phase[out.slot]
        return event_ring_append(
            ring, time=out.time,
            station=_station_index(ph, out.client, n),
            station_to=_station_index(post.phase[out.slot],
                                      post.client[out.slot], n),
            kind=ph, slot=out.slot, client=out.client, delay=out.delay,
            update=out.is_update, valid=keep)

    st, ring = _scan_chunked(step, draw, st, num_events, chunk,
                             ring=ring, append=append)
    return finalize_stats(st), ring


def simulate_stats(params: NetworkParams, m, num_updates: int, *,
                   warmup: int = 0, key: Optional[jax.Array] = None,
                   seed: int = 0, distribution: str = "exponential",
                   power=None, m_max: Optional[int] = None,
                   backend: Optional[str] = None,
                   interpret: Optional[bool] = None,
                   chunk: int = 1) -> EventStats:
    """Stationary statistics over ``num_updates`` rounds, fully on device.

    Mirrors :meth:`repro.core.simulator.AsyncNetworkSim.run`: statistics are
    collected over the update-count window ``[warmup, warmup + num_updates)``
    inside ONE jitted ``lax.scan`` over events.  ``m`` may be traced and the
    whole function vmaps over seeds (``key``) and padded ``(p, m)`` batches
    (pass a static ``m_max >= m``).

    ``backend`` (default: the ``repro.sim`` process flag) picks the step
    implementation; multi-lane sweeps belong in
    :func:`repro.sim.simulate_stats_lanes`, where ``"batched"`` vs
    ``"reference"`` actually differ.  ``chunk`` (static, default 1 ==
    today's byte-identical programs) selects the megastep execution mode:
    ``chunk`` events retire per scan iteration, bitwise-equal trajectories
    (see :func:`_scan_chunked`).
    """
    from ..sim.backend import resolve_backend  # dependency-free

    get_law(distribution)  # eager: unknown laws fail here with the options
    if key is None:
        key = jax.random.PRNGKey(seed)
    if m_max is None:
        m_max = int(m)
    if resolve_backend(backend) == "pallas":
        from ..sim.batched_events import simulate_stats_lanes

        stats = simulate_stats_lanes(
            jax.tree_util.tree_map(lambda x: jnp.asarray(x)[None], params),
            jnp.asarray(m)[None], int(num_updates), warmup=int(warmup),
            keys=key[None], distribution=distribution,
            power=None if power is None else jax.tree_util.tree_map(
                lambda x: jnp.asarray(x)[None], power),
            m_max=m_max, backend="pallas", interpret=interpret, chunk=chunk)
        return jax.tree_util.tree_map(lambda x: x[0], stats)
    return _simulate_stats(params, m, key, int(num_updates), int(warmup),
                           distribution, m_max, power, int(chunk))


# ---------------------------------------------------------------------------
# class-aggregated event engine (O(#classes) per-event statistics)
# ---------------------------------------------------------------------------

class ClassEventState(NamedTuple):
    """Carry of the class-aggregated event scan.

    The task table is identical to :class:`EventState` except each task is
    owned by a ``(cls, member)`` pair — the class index plus the member
    index *within* the class — instead of a flat client id.  All per-client
    statistics collapse to per-class aggregates (members of a class are
    exchangeable, Section 2.6 product form), so the carry is O(#classes)
    wide no matter how large the population: ``n = 10^5..10^6`` simulates
    at the same per-event cost as ``n = 10^2``.
    """

    t: jax.Array          # current wall-clock time
    key: jax.Array        # PRNG carry
    round: jax.Array      # updates completed so far
    seq_ctr: jax.Array    # global FIFO arrival counter
    cls: jax.Array        # [m_max] owning class of each task
    member: jax.Array     # [m_max] member index within the class
    phase: jax.Array      # [m_max]
    finish: jax.Array     # [m_max]
    seq: jax.Array        # [m_max]
    disp_round: jax.Array  # [m_max]
    warmup: jax.Array
    cap: jax.Array
    t_cap: jax.Array
    t0: jax.Array
    t1: jax.Array
    delay_sum: jax.Array  # [C] per-class relative-delay sums
    delay_cnt: jax.Array  # [C]
    energy: jax.Array
    occ_int: jax.Array    # [3C+1] time-weighted per-class occupancy
    occ: jax.Array        # [3C+1] current per-class occupancy
    serving: jax.Array    # [C] count of busy compute servers of each class
    cs_busy: jax.Array


def _route_class(mass: jax.Array, count: jax.Array, key: jax.Array,
                 prefix: Optional[jax.Array] = None
                 ) -> tuple[jax.Array, jax.Array]:
    """Draw ``(class, member)`` for one dispatch.

    Two shape-independent draws: the class by inverse-CDF of the class
    masses ``count * p`` (one scalar uniform against the sequential prefix,
    exactly :func:`_route_client` on the class axis — padded count-0
    classes carry zero mass and repeat the total in the prefix, so
    ``searchsorted`` never lands on them), then a uniform member index in
    ``[0, count[class])`` (one scalar ``randint``; the traced bound only
    depends on the drawn class).  Trajectories are therefore **bitwise
    invariant** to trailing class padding.  The clip targets the last class
    with nonzero count (not the last row, which may be padded) so the
    measure-zero ``u * total >= total`` edge cannot select an empty class.
    """
    if prefix is None:
        prefix = seqcumsum(mass)
    k_cls, k_mem = jax.random.split(key)
    u = jax.random.uniform(k_cls, dtype=mass.dtype) * prefix[-1]
    idx = jnp.searchsorted(prefix, u, side="right")
    cum = seqcumsum(count)
    c_last = jnp.searchsorted(cum, cum[-1] - 1, side="right")
    c = jnp.minimum(idx, c_last).astype(jnp.int32)
    mb = jax.random.randint(k_mem, (), 0, jnp.maximum(count[c], 1))
    return c, mb.astype(jnp.int32)


def draw_class_event_blocks(classes, key: jax.Array, chunk: int, *,
                            distribution: str = "exponential",
                            route_prefix: Optional[jax.Array] = None
                            ) -> tuple[jax.Array, EventBlocks]:
    """Class-engine analogue of :func:`draw_event_blocks`: the routing
    draw resolves a ``(class, member)`` pair per event, the downlink/CS
    services resolve fully, uplink/computation store the law's unit parts
    (or raw subkeys).  Same tiny-carry key chain, same per-law split
    between vmapped block draws and the sequential scalar-shape fallback
    — bitwise the single-step stream."""
    law = get_law(distribution)
    has_cs = classes.mu_cs is not None

    if law.unit_draw is None:
        def body(k, _):
            k2, k_up, k_disp, k_svc, k_comp, k_cs = jax.random.split(k, 6)
            c_new, mb_new = _route_class(classes.mass, classes.count, k_disp,
                                         route_prefix)
            svc_down = _draw(k_svc, classes.mu_d[c_new], distribution)
            svc_cs = (_draw(k_cs, classes.mu_cs, distribution)
                      if has_cs else ())
            blk = EventBlocks(c_new=c_new, member=mb_new, svc_down=svc_down,
                              up=k_up, comp=k_comp, svc_cs=svc_cs)
            return k2, (k2, blk)

        _, (chain, blks) = jax.lax.scan(body, key, None, length=chunk)
        return chain, blks

    def split6(k, _):
        ks = jax.random.split(k, 6)
        return ks[0], (ks[0], ks[1], ks[2], ks[3], ks[4], ks[5])

    _, (chain, k_up, k_disp, k_svc, k_comp, k_cs) = jax.lax.scan(
        split6, key, None, length=chunk)
    c_new, mb_new = jax.vmap(lambda k: _route_class(
        classes.mass, classes.count, k, route_prefix))(k_disp)
    svc_down = jax.vmap(
        lambda k, r: _draw(k, r, distribution))(k_svc, classes.mu_d[c_new])
    up = jax.vmap(law.unit_draw)(k_up)
    comp = jax.vmap(law.unit_draw)(k_comp)
    svc_cs = (jax.vmap(lambda k: _draw(k, classes.mu_cs, distribution))(k_cs)
              if has_cs else ())
    return chain, EventBlocks(c_new=c_new, member=mb_new, svc_down=svc_down,
                              up=up, comp=comp, svc_cs=svc_cs)


def _class_station_counts(phase, cls, C):
    """Per-class occupancy recount: down[C], comp_total[C],
    comp_serving[C], up[C], cs_total, cs_busy.

    ``comp_serving[c]`` counts the COMP_SERV tasks of class ``c`` — each
    member's compute server holds at most one, so this is exactly the
    number of busy compute servers of the class.  Used to seed the O(1)
    occupancy carries at :func:`init_class_state` and as the test oracle.
    """
    def count(mask):
        return jnp.zeros((C,), jnp.float64).at[cls].add(
            jnp.where(mask, 1.0, 0.0))

    down = count(phase == DOWN)
    comp_total = count((phase == COMP_WAIT) | (phase == COMP_SERV))
    comp_serving = count(phase == COMP_SERV)
    up = count(phase == UP)
    # contract: allow(raw-reduction): 0/1 indicator count over the task table — exact small-integer f64 under any association, and the table axis is m_max (never padded-n)
    cs_total = jnp.sum(
        jnp.where((phase == CS_WAIT) | (phase == CS_SERV), 1.0, 0.0))
    cs_busy = jnp.any(phase == CS_SERV)
    return down, comp_total, comp_serving, up, cs_total, cs_busy


def init_class_state(classes, m, key: jax.Array, *,
                     m_max: Optional[int] = None,
                     distribution: str = "exponential",
                     warmup=0, cap=_NO_CAP, t_cap=jnp.inf) -> ClassEventState:
    """Initial state of the class engine: ``m`` tasks dispatched uniformly
    at random over the ``n_total`` population members at ``t = 0``.

    The uniform member is drawn as a flat index in ``[0, n_total)`` and
    split into ``(class, member)`` against the sequential count prefix —
    the same distribution as :func:`init_state` on the expanded network,
    and bitwise invariant to trailing class padding (padded classes repeat
    ``n_total`` in the prefix, and the flat draw is strictly below it).
    """
    C = classes.C
    if m_max is None:
        m_max = int(m)
    key, k_cli, k_svc = jax.random.split(key, 3)
    cum = seqcumsum(classes.count)
    idx = jax.random.randint(k_cli, (m_max,), 0, cum[-1])
    cls = jnp.searchsorted(cum, idx, side="right").astype(jnp.int32)
    member = (idx - jnp.where(cls > 0, cum[jnp.maximum(cls - 1, 0)], 0)
              ).astype(jnp.int32)
    active = jnp.arange(m_max) < m
    svc = _draw(k_svc, classes.mu_d[cls], distribution, (m_max,))
    phase0 = jnp.where(active, DOWN, INACTIVE).astype(jnp.int32)
    down, comp_total, comp_serving, up, cs_total, cs_busy = (
        _class_station_counts(phase0, cls, C))
    return ClassEventState(
        t=jnp.zeros((), jnp.float64),
        key=key,
        round=jnp.zeros((), jnp.int32),
        seq_ctr=jnp.zeros((), jnp.int32),
        cls=cls,
        member=member,
        phase=phase0,
        finish=jnp.where(active, svc, jnp.inf),
        seq=jnp.zeros((m_max,), jnp.int32),
        disp_round=jnp.zeros((m_max,), jnp.int32),
        warmup=jnp.asarray(warmup, jnp.int32),
        cap=jnp.asarray(cap, jnp.int32),
        t_cap=jnp.asarray(t_cap, jnp.float64),
        t0=jnp.zeros((), jnp.float64),
        t1=jnp.zeros((), jnp.float64),
        delay_sum=jnp.zeros((C,), jnp.float64),
        delay_cnt=jnp.zeros((C,), jnp.int32),
        energy=jnp.zeros((), jnp.float64),
        occ_int=jnp.zeros((3 * C + 1,), jnp.float64),
        occ=jnp.concatenate([down, comp_total, up, cs_total[None]]),
        serving=comp_serving,
        cs_busy=cs_busy,
    )


def step_class_event(classes, state: ClassEventState, *,
                     distribution: str = "exponential",
                     power=None,
                     route_prefix: Optional[jax.Array] = None
                     ) -> tuple[ClassEventState, EventOut]:
    """Class-aggregated :func:`step_event`: one service completion, with
    every per-client surface replaced by its per-class aggregate.

    The dynamics are *identical* to the expanded network's — FIFO
    promotion conditions on the completed task's ``(class, member)`` pair,
    so each member still owns a private single-server compute queue — only
    the carried statistics collapse.  ``power`` (when given) holds
    per-class ``[C]`` arrays.  The emitted :class:`EventOut` reports the
    completed task's *class* in the ``client`` field.

    Like :func:`step_event`, a one-event block draw over
    :func:`step_class_event_block` — bitwise the historical inline body.
    """
    law = get_law(distribution)
    key, k_up, k_disp, k_disp_svc, k_comp, k_cs = jax.random.split(
        state.key, 6)
    c_new, mb_new = _route_class(classes.mass, classes.count, k_disp,
                                 route_prefix)
    svc_down = _draw(k_disp_svc, classes.mu_d[c_new], distribution)
    if law.unit_draw is not None:
        up, comp = law.unit_draw(k_up), law.unit_draw(k_comp)
    else:
        up, comp = k_up, k_comp
    svc_cs = (_draw(k_cs, classes.mu_cs, distribution)
              if classes.mu_cs is not None else ())
    blk = EventBlocks(c_new=c_new, member=mb_new, svc_down=svc_down,
                      up=up, comp=comp, svc_cs=svc_cs)
    return step_class_event_block(classes, state._replace(key=key), blk,
                                  distribution=distribution, power=power)


def step_class_event_block(classes, state: ClassEventState,
                           blk: EventBlocks, *,
                           distribution: str = "exponential",
                           power=None) -> tuple[ClassEventState, EventOut]:
    """Class analogue of :func:`step_event_block`: one event with its
    randomness pre-resolved (``state.key`` passes through untouched)."""
    C = classes.C
    m_max = state.phase.shape[0]
    has_cs = classes.mu_cs is not None

    j = jnp.argmin(state.finish)
    t_new = state.finish[j]

    measure = (state.round >= state.warmup) & (state.round < state.cap)
    dt_eff = jnp.where(
        measure,
        jnp.clip(jnp.minimum(t_new, state.t_cap)
                 - jnp.minimum(state.t, state.t_cap), 0.0, None),
        0.0)
    occ_int = state.occ_int + dt_eff * state.occ
    energy = state.energy
    if power is not None:
        # serving is a per-class busy-server COUNT (members share the class
        # power rating), uplink/downlink go by the class occupancy segments
        pwr = seqsum(power.P_c * state.serving
                     + power.P_u * state.occ[2 * C:3 * C]
                     + power.P_d * state.occ[:C])
        if power.P_cs is not None:
            pwr = pwr + power.P_cs * state.cs_busy
        energy = energy + dt_eff * pwr

    c = state.cls[j]
    mb = state.member[j]
    ph = state.phase[j]

    is_down = ph == DOWN
    is_comp = ph == COMP_SERV
    is_up = ph == UP
    is_cs = ph == CS_SERV
    is_update = is_cs if has_cs else is_up

    delay = state.round - state.disp_round[j]
    new_round = state.round + jnp.where(is_update, 1, 0).astype(jnp.int32)

    c_new, mb_new = blk.c_new, blk.member
    svc_up = _apply_unit(blk.up, classes.mu_u[c], distribution)
    svc_down = blk.svc_down

    phase_j = jnp.where(
        is_down, COMP_WAIT,
        jnp.where(is_comp, UP, jnp.where(is_update, DOWN, CS_WAIT)))
    finish_j = jnp.where(
        is_comp, t_new + svc_up,
        jnp.where(is_update, t_new + svc_down, jnp.inf))
    joins_fifo = is_down | (is_up & has_cs)
    seq_j = jnp.where(joins_fifo, state.seq_ctr, state.seq[j])
    seq_ctr = state.seq_ctr + joins_fifo.astype(jnp.int32)
    cls_j = jnp.where(is_update, c_new, c)
    member_j = jnp.where(is_update, mb_new, mb)
    disp_j = jnp.where(is_update, new_round, state.disp_round[j])

    onej = jnp.arange(m_max) == j
    phase = jnp.where(onej, phase_j, state.phase).astype(jnp.int32)
    finish = jnp.where(onej, finish_j, state.finish)
    seq = jnp.where(onej, seq_j, state.seq).astype(jnp.int32)
    cls = jnp.where(onej, cls_j, state.cls).astype(jnp.int32)
    member = jnp.where(onej, member_j, state.member).astype(jnp.int32)
    disp_round = jnp.where(onej, disp_j, state.disp_round).astype(jnp.int32)

    # -- FIFO promotions: the compute queue belongs to MEMBER (c, mb) -------
    promo_comp = is_down | is_comp
    mine = (cls == c) & (member == mb)
    serving_m = jnp.any((phase == COMP_SERV) & mine)
    waiting_m = (phase == COMP_WAIT) & mine
    pick = jnp.argmin(jnp.where(waiting_m, seq, _BIG_SEQ))
    do_comp = promo_comp & ~serving_m & jnp.any(waiting_m)
    svc_c = _apply_unit(blk.comp, classes.mu_c[c], distribution)
    onep = (jnp.arange(m_max) == pick) & do_comp
    phase = jnp.where(onep, COMP_SERV, phase)
    finish = jnp.where(onep, t_new + svc_c, finish)

    if has_cs:
        promo_cs = is_up | is_cs
        cs_waiting = phase == CS_WAIT
        pick_cs = jnp.argmin(jnp.where(cs_waiting, seq, _BIG_SEQ))
        do_cs = promo_cs & ~jnp.any(phase == CS_SERV) & jnp.any(cs_waiting)
        onec = (jnp.arange(m_max) == pick_cs) & do_cs
        phase = jnp.where(onec, CS_SERV, phase)
        finish = jnp.where(onec, t_new + blk.svc_cs, finish)

    stations = jnp.arange(3 * C + 1)
    occ_new = (state.occ
               + jnp.where(stations == _station_index(phase_j, cls_j, C),
                           1.0, 0.0)
               - jnp.where(stations == _station_index(ph, c, C), 1.0, 0.0))
    delta_srv = (jnp.where(do_comp, 1.0, 0.0)
                 - jnp.where(is_comp, 1.0, 0.0))
    serving_new = state.serving + jnp.where(jnp.arange(C) == c,
                                            delta_srv, 0.0)
    cs_busy_new = ((state.cs_busy & ~is_cs) | do_cs if has_cs
                   else state.cs_busy)

    upd_measured = is_update & measure
    delay_sum = state.delay_sum.at[c].add(
        jnp.where(upd_measured, delay.astype(jnp.float64), 0.0))
    delay_cnt = state.delay_cnt.at[c].add(
        jnp.where(upd_measured, 1, 0).astype(jnp.int32))
    t0 = jnp.where(is_update & (new_round == state.warmup), t_new, state.t0)
    t1 = jnp.where(is_update & (new_round == state.cap), t_new, state.t1)

    new_state = ClassEventState(
        t=t_new, key=state.key, round=new_round, seq_ctr=seq_ctr,
        cls=cls, member=member, phase=phase, finish=finish, seq=seq,
        disp_round=disp_round,
        warmup=state.warmup, cap=state.cap, t_cap=state.t_cap,
        t0=t0, t1=t1, delay_sum=delay_sum, delay_cnt=delay_cnt,
        energy=energy, occ_int=occ_int,
        occ=occ_new, serving=serving_new, cs_busy=cs_busy_new)
    out = EventOut(is_update=is_update,
                   time=t_new,
                   slot=j.astype(jnp.int32),
                   client=c,
                   delay=delay.astype(jnp.int32))
    return new_state, out


@functools.partial(jax.jit, static_argnames=(
    "num_updates", "warmup", "distribution", "m_max", "chunk"))
def _simulate_stats_classes(classes, m, key, num_updates, warmup,
                            distribution, m_max, power, chunk=1):
    mult = 4 if classes.mu_cs is not None else 3
    num_events = mult * (num_updates + warmup) + mult * m_max + 8
    cap = warmup + num_updates
    st = init_class_state(classes, m, key, m_max=m_max,
                          distribution=distribution, warmup=warmup, cap=cap)
    # hoisted loop-invariant routing CDF (see _simulate_stats)
    route_prefix = seqcumsum(classes.mass)

    if chunk == 1:
        def body(st, _):
            st, _ = step_class_event(classes, st, distribution=distribution,
                                     power=power, route_prefix=route_prefix)
            return st, None

        st, _ = jax.lax.scan(body, st, None, length=num_events)
        return finalize_stats(st)

    def draw(key):
        return draw_class_event_blocks(classes, key, chunk,
                                       distribution=distribution,
                                       route_prefix=route_prefix)

    def step(st, blk):
        return step_class_event_block(classes, st, blk,
                                      distribution=distribution, power=power)

    st, _ = _scan_chunked(step, draw, st, num_events, chunk)
    return finalize_stats(st)


@functools.partial(jax.jit, static_argnames=(
    "num_updates", "warmup", "distribution", "m_max", "trace_events",
    "chunk"))
def _simulate_stats_classes_traced(classes, m, key, num_updates, warmup,
                                   distribution, m_max, power, trace_events,
                                   chunk=1):
    """:func:`_simulate_stats_classes` carrying an event ring (the
    ``client`` column records the completed task's *class*; stations use
    the ``[3C+1]`` class layout).  Bitwise non-invasive, like
    :func:`_simulate_stats_traced`."""
    from ..obs.rings import event_ring_append, event_ring_init

    mult = 4 if classes.mu_cs is not None else 3
    num_events = mult * (num_updates + warmup) + mult * m_max + 8
    cap = warmup + num_updates
    st = init_class_state(classes, m, key, m_max=m_max,
                          distribution=distribution, warmup=warmup, cap=cap)
    route_prefix = seqcumsum(classes.mass)
    C = classes.C
    ring = event_ring_init(int(trace_events))

    if chunk == 1:
        def body(carry, _):
            st, ring = carry
            st2, out = step_class_event(classes, st,
                                        distribution=distribution,
                                        power=power,
                                        route_prefix=route_prefix)
            ph = st.phase[out.slot]
            ring = event_ring_append(
                ring, time=out.time,
                station=_station_index(ph, out.client, C),
                station_to=_station_index(st2.phase[out.slot],
                                          st2.cls[out.slot], C),
                kind=ph, slot=out.slot, client=out.client, delay=out.delay,
                update=out.is_update)
            return (st2, ring), None

        (st, ring), _ = jax.lax.scan(body, (st, ring), None,
                                     length=num_events)
        return finalize_stats(st), ring

    def draw(key):
        return draw_class_event_blocks(classes, key, chunk,
                                       distribution=distribution,
                                       route_prefix=route_prefix)

    def step(st, blk):
        return step_class_event_block(classes, st, blk,
                                      distribution=distribution, power=power)

    def append(ring, pre, post, out, keep):
        ph = pre.phase[out.slot]
        return event_ring_append(
            ring, time=out.time,
            station=_station_index(ph, out.client, C),
            station_to=_station_index(post.phase[out.slot],
                                      post.cls[out.slot], C),
            kind=ph, slot=out.slot, client=out.client, delay=out.delay,
            update=out.is_update, valid=keep)

    st, ring = _scan_chunked(step, draw, st, num_events, chunk,
                             ring=ring, append=append)
    return finalize_stats(st), ring


def simulate_stats_classes(classes, m, num_updates: int, *,
                           warmup: int = 0, key: Optional[jax.Array] = None,
                           seed: int = 0, distribution: str = "exponential",
                           power=None,
                           m_max: Optional[int] = None,
                           chunk: int = 1) -> EventStats:
    """Class-aggregated :func:`simulate_stats`: statistics over
    ``num_updates`` rounds with O(#classes) per-event state.

    Returns an :class:`EventStats` whose per-client fields are per-CLASS
    aggregates (``mean_delay``/``delay_counts`` of shape ``[C]``, occupancy
    ``[3C+1]``); expand to the per-member view on demand with
    :func:`expand_class_stats`.  ``power`` (when given) must hold per-class
    ``[C]`` arrays.  Runs on the jnp step only — the class table transition
    has no Pallas kernel (per-event cost is already n-independent).
    """
    get_law(distribution)  # eager: unknown laws fail here with the options
    if key is None:
        key = jax.random.PRNGKey(seed)
    if m_max is None:
        m_max = int(m)
    return _simulate_stats_classes(classes, m, key, int(num_updates),
                                   int(warmup), distribution, m_max, power,
                                   int(chunk))


def expand_class_stats(stats: EventStats, count) -> EventStats:
    """Expand per-class :class:`EventStats` to the per-member view.

    Host-side, on demand (O(n) by construction — the class engine never
    materializes per-member state).  Members of a class are exchangeable,
    so class aggregates expand to per-member *averages*: ``mean_delay``
    repeats the class mean, ``delay_counts`` becomes the average count per
    member (``cnt_c / count_c``, a float), and each per-class occupancy
    segment divides equally among the members.  Padded count-0 classes are
    dropped.  Works on any number of leading lane axes.
    """
    cnt = np.asarray(count)
    keep = cnt > 0
    reps = cnt[keep].astype(np.int64)
    w = reps.astype(np.float64)
    C = cnt.shape[0]

    def rep(x, per_member=False):
        x = np.asarray(x)[..., keep]
        if per_member:
            x = x / w
        return np.repeat(x, reps, axis=-1)

    occ = np.asarray(stats.mean_queue_counts)
    return EventStats(
        updates=stats.updates,
        time=stats.time,
        throughput=stats.throughput,
        mean_delay=jnp.asarray(rep(stats.mean_delay)),
        delay_counts=jnp.asarray(rep(stats.delay_counts, per_member=True)),
        energy=stats.energy,
        mean_queue_counts=jnp.asarray(np.concatenate(
            [rep(occ[..., 0:C], per_member=True),
             rep(occ[..., C:2 * C], per_member=True),
             rep(occ[..., 2 * C:3 * C], per_member=True),
             occ[..., 3 * C:]], axis=-1)),
    )
